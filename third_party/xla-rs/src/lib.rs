//! Stub of the xla-rs API surface used by `qst` (see Cargo.toml).
//!
//! * [`Literal`] is fully functional: dtype + dims + host bytes, typed
//!   copy-in/copy-out — `HostTensor` marshaling round-trips for real.
//! * PJRT types ([`PjRtClient`], [`PjRtLoadedExecutable`], [`PjRtBuffer`])
//!   exist but every runtime operation fails with [`STUB_MSG`]; nothing
//!   can be executed without the real bindings.

use std::fmt;

pub const STUB_MSG: &str =
    "XLA runtime unavailable: built against the std-only stub (third_party/xla-rs); \
     point the path dependency at the real vendored xla-rs to execute artifacts";

/// Error type matching the real crate's role (std::error::Error, so it
/// converts into anyhow::Error at call sites).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub_err<T>() -> Result<T> {
    Err(Error(STUB_MSG.to_string()))
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    F16,
    S32,
    U32,
    U8,
    S8,
}

impl PrimitiveType {
    fn size(self) -> usize {
        match self {
            PrimitiveType::F32 | PrimitiveType::S32 | PrimitiveType::U32 => 4,
            PrimitiveType::F16 => 2,
            PrimitiveType::U8 | PrimitiveType::S8 => 1,
        }
    }

    fn element_type(self) -> ElementType {
        match self {
            PrimitiveType::F32 => ElementType::F32,
            PrimitiveType::F16 => ElementType::F16,
            PrimitiveType::S32 => ElementType::S32,
            PrimitiveType::U32 => ElementType::U32,
            PrimitiveType::U8 => ElementType::U8,
            PrimitiveType::S8 => ElementType::S8,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    F16,
    S32,
    U32,
    U8,
    S8,
}

/// Array shape of a non-tuple literal.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Element types marshalable through a [`Literal`].
pub trait NativeType: Copy {
    const PRIMITIVE: PrimitiveType;
    fn write_le(&self, out: &mut Vec<u8>);
    fn read_le(bytes: &[u8]) -> Self;
}

macro_rules! native {
    ($t:ty, $prim:expr, $n:expr) => {
        impl NativeType for $t {
            const PRIMITIVE: PrimitiveType = $prim;
            fn write_le(&self, out: &mut Vec<u8>) {
                out.extend_from_slice(&self.to_le_bytes());
            }
            fn read_le(bytes: &[u8]) -> Self {
                let mut buf = [0u8; $n];
                buf.copy_from_slice(&bytes[..$n]);
                <$t>::from_le_bytes(buf)
            }
        }
    };
}

native!(f32, PrimitiveType::F32, 4);
native!(i32, PrimitiveType::S32, 4);
native!(u32, PrimitiveType::U32, 4);
native!(u8, PrimitiveType::U8, 1);
native!(i8, PrimitiveType::S8, 1);

/// Host-side literal: dtype + dims + row-major little-endian bytes.
#[derive(Clone, Debug)]
pub struct Literal {
    primitive: PrimitiveType,
    dims: Vec<i64>,
    data: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape(ty: PrimitiveType, dims: &[usize]) -> Literal {
        let numel: usize = dims.iter().product();
        Literal {
            primitive: ty,
            dims: dims.iter().map(|&d| d as i64).collect(),
            data: vec![0u8; numel * ty.size()],
        }
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len()
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone(), ty: self.primitive.element_type() })
    }

    pub fn copy_raw_from<T: NativeType>(&mut self, vals: &[T]) -> Result<()> {
        if T::PRIMITIVE != self.primitive {
            return Err(Error(format!(
                "copy_raw_from type mismatch: literal is {:?}, values are {:?}",
                self.primitive,
                T::PRIMITIVE
            )));
        }
        let mut data = Vec::with_capacity(self.data.len());
        for v in vals {
            v.write_le(&mut data);
        }
        if data.len() != self.data.len() {
            return Err(Error(format!(
                "copy_raw_from size mismatch: {} bytes for a {}-byte literal",
                data.len(),
                self.data.len()
            )));
        }
        self.data = data;
        Ok(())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if T::PRIMITIVE != self.primitive {
            return Err(Error(format!(
                "to_vec type mismatch: literal is {:?}, requested {:?}",
                self.primitive,
                T::PRIMITIVE
            )));
        }
        let sz = self.primitive.size();
        Ok(self.data.chunks_exact(sz).map(T::read_le).collect())
    }

    /// Tuple literals only come out of executions, which the stub can't do.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        stub_err()
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        stub_err()
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        stub_err()
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtClient;
pub struct PjRtLoadedExecutable;
pub struct PjRtBuffer;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        stub_err()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub_err()
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        stub_err()
    }
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub_err()
    }

    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub_err()
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub_err()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let mut lit = Literal::create_from_shape(PrimitiveType::F32, &[2, 3]);
        assert_eq!(lit.size_bytes(), 24);
        lit.copy_raw_from::<f32>(&[1.0, -2.0, 3.5, 0.25, 5.0, 6.0]).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, -2.0, 3.5, 0.25, 5.0, 6.0]);
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 3]);
        assert_eq!(shape.ty(), ElementType::F32);
    }

    #[test]
    fn type_and_size_mismatches_error() {
        let mut lit = Literal::create_from_shape(PrimitiveType::F32, &[2]);
        assert!(lit.copy_raw_from::<i32>(&[1, 2]).is_err());
        assert!(lit.copy_raw_from::<f32>(&[1.0]).is_err());
        assert!(lit.to_vec::<u8>().is_err());
    }

    #[test]
    fn runtime_is_stubbed() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("stub"));
    }
}
