//! Minimal `anyhow` work-alike: context-chained error type, `Result` alias,
//! and the `anyhow!` / `bail!` / `ensure!` macros — exactly the surface the
//! `qst` crate uses.  `{e}` displays the outermost message; `{e:#}` displays
//! the whole context chain (`outer: inner: root`), matching real anyhow.

use std::fmt;

/// Context-chained error: a message plus an optional wrapped cause.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    pub fn new_msg(msg: impl Into<String>) -> Self {
        Error { msg: msg.into(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context(self, msg: impl Into<String>) -> Self {
        Error { msg: msg.into(), source: Some(Box::new(self)) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

// Like real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket From possible.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain: Vec<String> = vec![e.to_string()];
        let mut cur = e.source();
        while let Some(s) = cur {
            chain.push(s.to_string());
            cur = s.source();
        }
        let mut err: Option<Error> = None;
        for msg in chain.into_iter().rev() {
            err = Some(Error { msg, source: err.map(Box::new) });
        }
        err.expect("chain is non-empty")
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to `Result` / `Option` errors.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::new_msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::new_msg(f().to_string()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::new_msg(format!($($arg)*)) };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn context_chain_formats() {
        let e: Error = Err::<(), _>(io_err()).context("opening ckpt").unwrap_err();
        assert_eq!(format!("{e}"), "opening ckpt");
        assert_eq!(format!("{e:#}"), "opening ckpt: file missing");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "slot")).unwrap_err();
        assert_eq!(format!("{e:#}"), "missing slot");
        assert_eq!(Some(3u32).context("x").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(format!("{:#}", f(5).unwrap_err()).contains("five"));
        assert!(format!("{:#}", f(50).unwrap_err()).contains("too big"));
        let e = anyhow!("plain {}", 7);
        assert_eq!(format!("{e}"), "plain 7");
    }

    #[test]
    fn ensure_without_message() {
        fn f() -> Result<()> {
            ensure!(1 + 1 == 3);
            Ok(())
        }
        assert!(format!("{:#}", f().unwrap_err()).contains("condition failed"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = String::from_utf8(vec![0xff])?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
