//! GLUE-like benchmark driver: finetune + evaluate several methods on one
//! task and print a method-comparison table (a one-task slice of Table 1).
//!
//! Run: `cargo run --release --example glue_finetune -- [task] [steps]`
//! (task defaults to SST-2; e.g. `-- MRPC 150`).

use anyhow::Result;
use qst::data::glue::{GlueTask, ALL_TASKS};
use qst::experiments::common;
use qst::experiments::report::Table;
use qst::runtime::Runtime;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let task_name = args.get(1).cloned().unwrap_or_else(|| "SST-2".into());
    let steps: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(120);
    let task = ALL_TASKS
        .into_iter()
        .find(|t| t.name().eq_ignore_ascii_case(&task_name))
        .unwrap_or(GlueTask::Sst2);

    let mut rt = Runtime::with_default_dir()?;
    let base = common::base_for(&mut rt, "tiny-opt", false)?;
    let backbone: usize = base.tensors.values().map(|t| t.numel()).sum();

    let mut table = Table::new(
        &format!("{} ({} steps, tiny-opt proxy)", task.name(), steps),
        &["method", "trainable", "params%", "ms/step", "score"],
    );
    for method in ["qst", "qlora", "lora", "adapter", "lst"] {
        let out = common::finetune_glue(&mut rt, "tiny-opt", method, task, steps, &base, "")?;
        let score = common::eval_glue(&mut rt, "tiny-opt", method, task, &out, 256)?;
        table.row(vec![
            method.into(),
            out.trainable_params.to_string(),
            format!("{:.2}", out.trainable_params as f64 / backbone as f64 * 100.0),
            format!("{:.0}", out.median_step_secs * 1e3),
            format!("{score:.3}"),
        ]);
        eprintln!("[{method}] done: score {score:.3}");
    }
    table.print();
    println!("\npaper shape to check: QST trains the fewest params and the fastest steps");
    println!("among the quantized methods while staying within a few points of QLoRA.");
    Ok(())
}
