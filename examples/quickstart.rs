//! Quickstart: the smallest end-to-end QST run.
//!
//! 1. Pretrain a tiny backbone on the synthetic corpus (full-precision LM).
//! 2. Quantize it to NF4 in Rust.
//! 3. Finetune the side network (QST) on a GLUE-like task — Python never runs.
//! 4. Evaluate.
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`).

use anyhow::Result;
use qst::coordinator::pipeline;
use qst::data::glue::GlueTask;
use qst::experiments::common;
use qst::runtime::Runtime;

fn main() -> Result<()> {
    let mut rt = Runtime::with_default_dir()?;
    println!("== QST quickstart (config: tiny-opt, task: SST-2-like) ==");

    // 1+2. pretrain (or reuse) the base model; frozen quantization happens
    //      inside finetune_glue from the checkpoint via rust/src/quant.
    let base = pipeline::ensure_base(&mut rt, "tiny-opt", 300, 3e-3, true)?;
    println!("base checkpoint: {} tensors, {} bytes",
             base.tensors.len(), base.total_bytes());

    // 3. QST finetuning: only the side network trains.
    let out = common::finetune_glue(&mut rt, "tiny-opt", "qst", GlueTask::Sst2, 120, &base, "")?;
    println!(
        "finetuned: {} trainable params, final loss {:.4}, {:.0} ms/step",
        out.trainable_params,
        out.final_loss,
        out.median_step_secs * 1e3
    );

    // 4. evaluate on held-out data.
    let acc = common::eval_glue(&mut rt, "tiny-opt", "qst", GlueTask::Sst2, &out, 256)?;
    println!("SST-2-like accuracy: {acc:.3}");
    assert!(acc > 0.6, "QST should comfortably beat chance on the synthetic task");
    println!("quickstart OK");
    Ok(())
}
