//! End-to-end driver (the repo's headline validation run):
//!
//! pretrain a ~26M-parameter LLaMA-flavor transformer on the synthetic
//! corpus for a few hundred steps (full-precision LM, loss curve logged),
//! quantize it to NF4 in Rust, then run QST finetuning on instruction data
//! and evaluate MMLU-like 5-shot accuracy.
//!
//! All compute is AOT-compiled HLO executed from Rust via PJRT — this proves
//! the L1 (Pallas dequant kernels) / L2 (JAX graphs) / L3 (coordinator)
//! layers compose.  Results are recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example e2e_train -- [pretrain_steps] [ft_steps]`

use anyhow::Result;
use qst::coordinator::pipeline;
use qst::experiments::common;
use qst::runtime::Runtime;
use qst::util::{human_bytes, peak_rss_bytes};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let pretrain_steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(200);
    let ft_steps: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(100);
    let cfg = "e2e-llama";

    let mut rt = Runtime::with_default_dir()?;
    println!("== e2e driver: {cfg} (~26M backbone) on 1 CPU core ==");

    // Stage 1: pretrain (logs the loss curve).
    let t0 = std::time::Instant::now();
    let base = if pipeline::base_ckpt_path(cfg).exists() {
        println!("reusing existing base checkpoint");
        qst::coordinator::Checkpoint::load(&pipeline::base_ckpt_path(cfg))?
    } else {
        let (ckpt, report) = pipeline::pretrain(&mut rt, cfg, pretrain_steps, 1e-3, 0, true)?;
        ckpt.save(&pipeline::base_ckpt_path(cfg))?;
        let m = &report.metrics;
        println!(
            "pretrain: {} steps, loss {:.3} -> {:.3}, {:.2} s/step, {:.0} tok/s",
            pretrain_steps,
            m.losses.first().unwrap(),
            m.mean_loss_tail(10),
            m.median_step_secs(),
            m.tokens_per_sec()
        );
        // persist the loss curve for EXPERIMENTS.md
        m.save_csv(&qst::runs_dir().join("e2e_pretrain_loss.csv"))?;
        ckpt
    };
    println!("base: {} tensors, {}", base.tensors.len(), human_bytes(base.total_bytes() as f64));

    // Stage 2+3: NF4-quantize (inside finetune_mmlu) + QST finetune.
    let out = common::finetune_mmlu(&mut rt, cfg, "qst", ft_steps, &base, "")?;
    println!(
        "QST finetune: {} trainable params ({:.2}% of backbone), final loss {:.3}, {:.2} s/step",
        out.trainable_params,
        out.trainable_params as f64
            / base.tensors.values().map(|t| t.numel()).sum::<usize>() as f64
            * 100.0,
        out.final_loss,
        out.median_step_secs
    );

    // Stage 4: MMLU-like 5-shot eval.
    let acc = common::eval_mmlu(&mut rt, cfg, "qst", &out, 100, "")?;
    println!("MMLU-like 5-shot accuracy after QST: {acc:.3} (chance = 0.25)");
    println!(
        "total wall {:.1}s, peak RSS {}",
        t0.elapsed().as_secs_f64(),
        human_bytes(peak_rss_bytes() as f64)
    );
    println!("e2e OK");
    Ok(())
}
