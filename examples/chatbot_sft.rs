//! Chatbot SFT example (the paper's §4.7 workload): instruction-tune a
//! pretrained backbone with QST on the OASST1 stand-in, then chat — greedy
//! decoding through the AOT `generate` artifact, with the repetition-rate
//! probe that quantifies LST's known failure mode.
//!
//! Run: `cargo run --release --example chatbot_sft -- [sft_steps]`

use anyhow::Result;
use qst::coordinator::evaluator::{repetition_rate, Generator};
use qst::data::instruct::{Category, InstructGen, CATEGORIES};
use qst::data::Vocab;
use qst::experiments::common;
use qst::runtime::Runtime;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(120);
    let cfg = "small-llama";

    let mut rt = Runtime::with_default_dir()?;
    let base = common::base_for(&mut rt, cfg, false)?;

    // SFT on mixed-category instruction data (lm task reuses the MMLU train
    // artifact: same graph, different batches).
    println!("== SFT ({cfg}, {steps} steps, mixed categories) ==");
    let train = format!("{cfg}__qst__lm__train");
    let init = format!("{cfg}__qst__init");
    let art = rt.load(&train)?;
    let (b, s) = art.manifest.batch.unwrap();
    let vocab = Vocab::new(art.manifest.cfg.usize("vocab"));
    let frozen = qst::coordinator::pipeline::frozen_from_checkpoint(&art.manifest, &base)?;
    let mut gen = InstructGen::new(vocab.clone(), 99);
    let tcfg = qst::coordinator::TrainConfig::quick(steps, 2e-3);
    let out = common::run_finetune(&mut rt, &init, &train, frozen, tcfg, move |_| {
        let exs: Vec<_> = (0..b)
            .map(|_| {
                let (t, tg, m) = gen.sft_mixed(s);
                qst::data::batcher::LmExample { tokens: t, targets: tg, mask: m }
            })
            .collect();
        qst::data::batcher::lm_batch(&exs, s)
    })?;
    println!("SFT done: final loss {:.3}", out.final_loss);

    // Chat: greedy-decode responses for one prompt per category.
    let g = Generator::new(&mut rt, &format!("{cfg}__qst__generate"))?;
    let mut ig = InstructGen::new(vocab, 2024);
    println!("\n== greedy chat samples ==");
    let mut reps = vec![];
    for cat in CATEGORIES {
        let (prompt, gold) = ig.pair(cat);
        let mut full = vec![qst::data::vocabulary::BOS];
        full.extend(&prompt);
        full.push(qst::data::vocabulary::RESP);
        let resp = g.greedy(&out.trainable, &out.frozen, &full, 12)?;
        let rr = repetition_rate(&resp);
        reps.push(rr);
        println!(
            "{:<11} prompt={:?} -> resp={:?} (gold starts {:?}, rep-rate {:.2})",
            cat.name(),
            prompt,
            &resp[..resp.len().min(8)],
            &gold[..gold.len().min(3)],
            rr
        );
        // fact categories: check the first generated token against the table
        if matches!(cat, Category::Stem | Category::Extraction | Category::Reasoning) && !resp.is_empty() {
            let hit = resp[0] == gold[0];
            println!("{:<11}   fact recall: {}", "", if hit { "correct" } else { "miss" });
        }
    }
    let avg_rep = reps.iter().sum::<f64>() / reps.len() as f64;
    println!("\navg repetition rate {avg_rep:.2} (QST's α-mix keeps it near the base model's)");
    Ok(())
}
