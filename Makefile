# Repo entry points.  `make check` is the tier-1 verify plus format hygiene;
# `make artifacts` lowers the AOT HLO artifacts the Rust coordinator executes;
# `make fixtures` regenerates the cross-language quantizer golden fixture;
# `make bench-serve` runs the serving benchmark and refreshes BENCH_serve.json;
# `make bench-kernels` refreshes BENCH_kernels.json (host GEMM/W4 kernels).

.PHONY: check test artifacts fixtures bench-serve bench-kernels bench-gateway

check:
	./scripts/check.sh

test:
	cargo test -q

artifacts:
	cd python/compile && python3 aot.py --out ../../artifacts

fixtures:
	python3 scripts/gen_quant_fixture.py

bench-serve:
	cargo run --release -p qst --bin qst -- bench-serve

bench-kernels:
	cargo run --release -p qst --bin qst -- bench-kernels

bench-gateway:
	cargo run --release -p qst --bin qst -- bench-gateway
