//! L3 coordinator: the training/eval runtime built on [`crate::runtime`].
//!
//! The paper's contribution is at L1/L2 (quantized compute + side network);
//! the coordinator is the production harness around it: run configs, LR
//! schedules with warmup, gradient-accumulation, checkpointing, metrics,
//! the pretrain → quantize → finetune → evaluate pipeline, and the
//! experiment sweeps.

pub mod checkpoint;
pub mod evaluator;
pub mod metrics;
pub mod pipeline;
pub mod schedule;
pub mod trainer;

pub use checkpoint::Checkpoint;
pub use evaluator::{ClsEval, LmEval};
pub use schedule::{LrSchedule, ScheduleKind};
pub use trainer::{TrainConfig, Trainer, TrainReport};
