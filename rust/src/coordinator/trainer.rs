//! The training loop: init → (lr, batch) → step → metrics, with all compute
//! inside the AOT train artifact.
//!
//! The coordinator owns everything the paper's Appendix A/B specifies at the
//! harness level — schedules, warmup, step counts, seeds, logging — while the
//! artifact owns fwd/bwd/AdamW.  Batch shapes are baked into the artifact at
//! lowering time (bs×seq in the manifest), matching the paper's per-model
//! batch-size table.

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{Context, Result};

use super::metrics::Metrics;
use super::schedule::LrSchedule;
use crate::data::Batch;
use crate::runtime::{Artifact, Executor, Role, Runtime};
use crate::tensor::HostTensor;
use crate::util::timed;

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub schedule: LrSchedule,
    pub seed: u32,
    pub log_every: usize,
    pub verbose: bool,
}

impl TrainConfig {
    pub fn quick(steps: usize, lr: f32) -> Self {
        TrainConfig {
            steps,
            schedule: LrSchedule::paper_mmlu(steps, lr),
            seed: 0,
            log_every: 50,
            verbose: false,
        }
    }
}

pub struct TrainReport {
    pub metrics: Metrics,
    /// final trainable parameters (side network / LoRA / ... ) by name
    pub trainable: HashMap<String, HostTensor>,
    pub wall_secs: f64,
}

pub struct Trainer {
    pub exec: Executor,
    train_art: Rc<Artifact>,
    lr_slot: usize,
    data_slots: Vec<usize>,
    loss_out: usize,
    gnorm_out: usize,
}

impl Trainer {
    /// Build a trainer: runs the init artifact for trainable params, zeroes
    /// the optimizer state, uploads the frozen tensors.
    pub fn new(
        rt: &mut Runtime,
        init_name: &str,
        train_name: &str,
        frozen: &HashMap<String, HostTensor>,
        seed: u32,
    ) -> Result<Self> {
        let init_art = rt.load(init_name)?;
        let train_art = rt.load(train_name)?;

        // 1. initialize trainable params via the init artifact
        let seed_t = HostTensor::scalar_u32(seed);
        let init_out = init_art.run_host(&[seed_t])?;
        let mut trainable: HashMap<String, HostTensor> = HashMap::new();
        for (slot, t) in init_art.manifest.outputs.iter().zip(init_out) {
            trainable.insert(slot.name.clone(), t);
        }

        let mut exec = Executor::new(train_art.clone());
        let m = &train_art.manifest;
        let mut lr_slot = None;
        let mut data_slots = vec![];
        // 2. fill every input slot
        for (i, s) in m.inputs.iter().enumerate() {
            match s.role {
                Role::Trainable => {
                    let t = trainable
                        .get(&s.name)
                        .with_context(|| format!("init artifact missing '{}'", s.name))?
                        .clone();
                    exec.set(rt, i, &t)?;
                }
                Role::OptM | Role::OptV => {
                    exec.set(rt, i, &HostTensor::zeros(s.dtype, &s.shape))?;
                }
                Role::Step => exec.set(rt, i, &HostTensor::scalar_f32(0.0))?,
                Role::Lr => {
                    lr_slot = Some(i);
                    exec.set(rt, i, &HostTensor::scalar_f32(0.0))?;
                }
                Role::Frozen => {
                    let t = frozen
                        .get(&s.name)
                        .with_context(|| format!("frozen tensors missing '{}'", s.name))?;
                    exec.set(rt, i, t)?;
                }
                Role::Data => data_slots.push(i),
                _ => {}
            }
        }
        let loss_out = m.output_index(Role::Loss).context("train graph has no loss output")?;
        let gnorm_out = m.output_index(Role::Gnorm).unwrap_or(loss_out);
        Ok(Trainer {
            exec,
            train_art,
            lr_slot: lr_slot.context("train graph has no lr input")?,
            data_slots,
            loss_out,
            gnorm_out,
        })
    }

    /// Batch geometry from the manifest.
    pub fn batch_dims(&self) -> (usize, usize) {
        self.train_art.manifest.batch.unwrap_or((1, 1))
    }

    /// One optimizer step on the given batch at the given LR.
    pub fn step(&mut self, rt: &Runtime, batch: &Batch, lr: f32) -> Result<(f32, f32)> {
        self.exec.set(rt, self.lr_slot, &HostTensor::scalar_f32(lr))?;
        anyhow::ensure!(
            batch.tensors.len() == self.data_slots.len(),
            "batch arity {} != data slots {}",
            batch.tensors.len(),
            self.data_slots.len()
        );
        for (slot, t) in self.data_slots.clone().into_iter().zip(&batch.tensors) {
            self.exec.set(rt, slot, t)?;
        }
        let out = self.exec.step(rt)?;
        Ok((out[self.loss_out].scalar(), out[self.gnorm_out].scalar()))
    }

    /// Full loop with a batch generator.
    pub fn run(
        &mut self,
        rt: &Runtime,
        cfg: &TrainConfig,
        mut next_batch: impl FnMut(usize) -> Batch,
    ) -> Result<TrainReport> {
        let (b, s) = self.batch_dims();
        let mut metrics = Metrics::new(b * s);
        let (loop_result, wall) = timed(|| -> Result<()> {
            for step in 0..cfg.steps {
                let lr = cfg.schedule.lr_at(step);
                let batch = next_batch(step);
                let ((loss, gnorm), secs) = {
                    let t0 = std::time::Instant::now();
                    let r = self.step(rt, &batch, lr)?;
                    (r, t0.elapsed().as_secs_f64())
                };
                metrics.push(loss, gnorm, secs);
                if cfg.verbose && (step % cfg.log_every == 0 || step + 1 == cfg.steps) {
                    eprintln!(
                        "[train {}] step {step}/{} loss {loss:.4} gnorm {gnorm:.3} lr {lr:.2e} ({:.0} tok/s)",
                        self.train_art.name,
                        cfg.steps,
                        (b * s) as f64 / secs
                    );
                }
            }
            Ok(())
        });
        loop_result?;
        let trainable = self.exec.read_role(Role::Trainable)?;
        Ok(TrainReport { metrics, trainable, wall_secs: wall })
    }

    /// Current trainable parameters (e.g. to checkpoint mid-run).
    pub fn trainable(&self) -> Result<HashMap<String, HostTensor>> {
        self.exec.read_role(Role::Trainable)
    }
}
