//! Learning-rate schedules (paper Appendix A/B: linear or constant with a
//! warmup ratio of 0.06 / 0.03 respectively).

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScheduleKind {
    Constant,
    Linear,
    Cosine,
}

#[derive(Clone, Copy, Debug)]
pub struct LrSchedule {
    pub kind: ScheduleKind,
    pub base_lr: f32,
    pub total_steps: usize,
    pub warmup_steps: usize,
}

impl LrSchedule {
    pub fn new(kind: ScheduleKind, base_lr: f32, total_steps: usize, warmup_ratio: f64) -> Self {
        let warmup_steps = ((total_steps as f64) * warmup_ratio).round() as usize;
        LrSchedule { kind, base_lr, total_steps, warmup_steps }
    }

    /// Paper GLUE setup: linear schedule, warmup 0.06, lr 2e-4.
    pub fn paper_glue(total_steps: usize) -> Self {
        Self::new(ScheduleKind::Linear, 2e-4, total_steps, 0.06)
    }

    /// Paper MMLU setup: constant schedule, warmup 0.03.
    pub fn paper_mmlu(total_steps: usize, lr: f32) -> Self {
        Self::new(ScheduleKind::Constant, lr, total_steps, 0.03)
    }

    pub fn lr_at(&self, step: usize) -> f32 {
        if self.warmup_steps > 0 && step < self.warmup_steps {
            return self.base_lr * (step as f32 + 1.0) / self.warmup_steps as f32;
        }
        let progress = if self.total_steps > self.warmup_steps {
            (step - self.warmup_steps) as f32
                / (self.total_steps - self.warmup_steps).max(1) as f32
        } else {
            0.0
        };
        let progress = progress.clamp(0.0, 1.0);
        match self.kind {
            ScheduleKind::Constant => self.base_lr,
            ScheduleKind::Linear => self.base_lr * (1.0 - progress),
            ScheduleKind::Cosine => {
                self.base_lr * 0.5 * (1.0 + (std::f32::consts::PI * progress).cos())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps() {
        let s = LrSchedule::new(ScheduleKind::Linear, 1.0, 100, 0.1);
        assert!(s.lr_at(0) < s.lr_at(5));
        assert!((s.lr_at(9) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn linear_decays_to_zero() {
        let s = LrSchedule::new(ScheduleKind::Linear, 1.0, 100, 0.0);
        assert!(s.lr_at(99) < 0.02);
        assert!(s.lr_at(50) > 0.4 && s.lr_at(50) < 0.6);
    }

    #[test]
    fn constant_stays() {
        let s = LrSchedule::new(ScheduleKind::Constant, 0.5, 100, 0.03);
        assert_eq!(s.lr_at(50), 0.5);
        assert_eq!(s.lr_at(99), 0.5);
    }

    #[test]
    fn cosine_midpoint() {
        let s = LrSchedule::new(ScheduleKind::Cosine, 1.0, 100, 0.0);
        assert!((s.lr_at(50) - 0.5).abs() < 0.02);
    }
}
