//! High-level flows: pretrain → quantize → finetune, and the frozen-input
//! assembly that bridges checkpoints to artifact manifests.

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{Context, Result};

use super::checkpoint::Checkpoint;
use super::trainer::{TrainConfig, Trainer, TrainReport};
use crate::data::{batcher, corpus::Corpus, Vocab};
use crate::runtime::{Manifest, Role, Runtime};
use crate::tensor::HostTensor;

/// Build the frozen-input map an artifact expects from a full-precision
/// backbone checkpoint, quantizing `q.*` tensors with `rust/src/quant`.
///
/// Quantization parameters (qdtype/qblock/qgroup) come from the manifest's
/// config echo, so a Table-4 FP4 artifact automatically gets FP4 packing.
pub fn frozen_from_checkpoint(man: &Manifest, ckpt: &Checkpoint) -> Result<HashMap<String, HostTensor>> {
    let qdtype = man.cfg.get("qdtype").unwrap_or("nf4").to_string();
    let qblock = man.cfg.usize("qblock").max(1);
    let qgroup = man.cfg.usize("qgroup").max(1);
    let mut out = HashMap::new();
    let mut qcache: HashMap<String, crate::quant::QMatrix> = HashMap::new();
    for slot in man.inputs_with_role(Role::Frozen) {
        if let Some(rest) = slot.name.strip_prefix("q.") {
            let (wname, field) = rest.rsplit_once('.').context("bad q.* name")?;
            if !qcache.contains_key(wname) {
                let w = ckpt
                    .tensors
                    .get(wname)
                    .with_context(|| format!("checkpoint missing '{wname}'"))?;
                qcache.insert(wname.into(), crate::quant::quantize_matrix(w, &qdtype, qblock, qgroup));
            }
            let q = &qcache[wname];
            let t = match field {
                "packed" => q.packed.clone(),
                "qscales" => q.qscales.clone(),
                "gabs" => q.gabs.clone(),
                "gmean" => q.gmean.clone(),
                other => anyhow::bail!("unknown q field '{other}'"),
            };
            out.insert(slot.name.clone(), t);
        } else {
            let t = ckpt
                .tensors
                .get(&slot.name)
                .with_context(|| format!("checkpoint missing '{}'", slot.name))?;
            out.insert(slot.name.clone(), t.clone());
        }
    }
    Ok(out)
}

/// Pretrain a backbone with the `full`/`lm` artifact on the synthetic corpus;
/// returns the final backbone parameters as a checkpoint.
pub fn pretrain(
    rt: &mut Runtime,
    cfg_name: &str,
    steps: usize,
    lr: f32,
    seed: u32,
    verbose: bool,
) -> Result<(Checkpoint, TrainReport)> {
    let init = format!("{cfg_name}__full__init");
    let train = format!("{cfg_name}__full__lm__train");
    let frozen = HashMap::new(); // full finetuning has no frozen inputs
    let mut trainer = Trainer::new(rt, &init, &train, &frozen, seed)?;
    let (b, s) = trainer.batch_dims();
    let art = rt.load(&train)?;
    let vocab = Vocab::new(art.manifest.cfg.usize("vocab"));
    let mut corpus = Corpus::new(vocab, seed as u64 + 1);
    let mut tcfg = TrainConfig::quick(steps, lr);
    tcfg.verbose = verbose;
    tcfg.seed = seed;
    let report = trainer.run(rt, &tcfg, |_| {
        let exs: Vec<_> = (0..b)
            .map(|_| {
                let (t, tg, m) = corpus.lm_example(s);
                batcher::LmExample { tokens: t, targets: tg, mask: m }
            })
            .collect();
        batcher::lm_batch(&exs, s)
    })?;
    Ok((Checkpoint::new(report.trainable.clone()), report))
}

/// Standard checkpoint path for a pretrained backbone.
pub fn base_ckpt_path(cfg_name: &str) -> PathBuf {
    crate::runs_dir().join(format!("{cfg_name}__base.ckpt"))
}

/// Pretrain-or-load: reuse an existing base checkpoint when present.
pub fn ensure_base(
    rt: &mut Runtime,
    cfg_name: &str,
    steps: usize,
    lr: f32,
    verbose: bool,
) -> Result<Checkpoint> {
    let path = base_ckpt_path(cfg_name);
    if path.exists() {
        return Checkpoint::load(&path);
    }
    let (ckpt, report) = pretrain(rt, cfg_name, steps, lr, 0, verbose)?;
    eprintln!(
        "[pretrain {cfg_name}] {} steps, loss {:.3} -> {:.3}, {:.1}s",
        steps,
        report.metrics.losses.first().copied().unwrap_or(f32::NAN),
        report.metrics.mean_loss_tail(10),
        report.wall_secs
    );
    ckpt.save(&path)?;
    Ok(ckpt)
}

/// Finetune `method` on a prepared frozen map with a caller-supplied batch
/// generator; thin wrapper for the experiment harness.
pub fn finetune(
    rt: &mut Runtime,
    init_name: &str,
    train_name: &str,
    frozen: &HashMap<String, HostTensor>,
    tcfg: &TrainConfig,
    next_batch: impl FnMut(usize) -> crate::data::Batch,
) -> Result<TrainReport> {
    let mut trainer = Trainer::new(rt, init_name, train_name, frozen, tcfg.seed)?;
    trainer.run(rt, tcfg, next_batch)
}
