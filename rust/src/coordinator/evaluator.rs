//! Evaluation: GLUE-style accuracy/correlation, LM loss, MMLU-style k-shot
//! choice scoring, and greedy generation (for the chatbot experiment).

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{Context, Result};

use crate::data::batcher::{cls_batch, ClsExample};
use crate::data::mmlu::MmluItem;
use crate::runtime::{Artifact, Role, Runtime};
use crate::tensor::HostTensor;

/// Assemble the ordered input vector for a (trainable..., frozen..., data...)
/// graph from named maps.
fn assemble_inputs(
    art: &Artifact,
    trainable: &HashMap<String, HostTensor>,
    frozen: &HashMap<String, HostTensor>,
    data: &[HostTensor],
) -> Result<Vec<HostTensor>> {
    let mut inputs = Vec::with_capacity(art.manifest.inputs.len());
    let mut d = data.iter();
    for s in &art.manifest.inputs {
        let t = match s.role {
            Role::Trainable => trainable
                .get(&s.name)
                .with_context(|| format!("missing trainable '{}'", s.name))?
                .clone(),
            Role::Frozen => frozen
                .get(&s.name)
                .with_context(|| format!("missing frozen '{}'", s.name))?
                .clone(),
            Role::Data => d.next().context("not enough data tensors")?.clone(),
            other => anyhow::bail!("unexpected input role {other:?} in eval graph"),
        };
        inputs.push(t);
    }
    Ok(inputs)
}

/// Classification evaluator over a cls eval artifact.
pub struct ClsEval {
    art: Rc<Artifact>,
    pub batch: (usize, usize),
}

pub struct ClsResult {
    pub accuracy: f64,
    pub pearson: f64,
    pub n: usize,
}

impl ClsEval {
    pub fn new(rt: &mut Runtime, eval_name: &str) -> Result<Self> {
        let art = rt.load(eval_name)?;
        let batch = art.manifest.batch.context("eval artifact missing batch dims")?;
        Ok(ClsEval { art, batch })
    }

    /// Accuracy by argmax over the task's label tokens; Pearson between the
    /// predicted and true bucket for regression-style tasks.
    pub fn evaluate(
        &self,
        trainable: &HashMap<String, HostTensor>,
        frozen: &HashMap<String, HostTensor>,
        examples: &[ClsExample],
        label_tokens: &[i32],
    ) -> Result<ClsResult> {
        let (b, s) = self.batch;
        let mut correct = 0usize;
        let mut n = 0usize;
        let mut preds: Vec<f64> = vec![];
        let mut golds: Vec<f64> = vec![];
        for chunk in examples.chunks(b) {
            if chunk.len() < b {
                break; // fixed-shape artifact; drop the ragged tail
            }
            let batch = cls_batch(chunk, s);
            let inputs = assemble_inputs(&self.art, trainable, frozen, &batch.tensors)?;
            let out = self.art.run_host(&inputs)?;
            let logits = &out[0]; // [B, V]
            let v = logits.shape[1];
            for (row, ex) in chunk.iter().enumerate() {
                let mut best = 0usize;
                let mut bestv = f32::NEG_INFINITY;
                for (k, &tok) in label_tokens.iter().enumerate() {
                    let val = logits.f32_at(row * v + tok as usize);
                    if val > bestv {
                        bestv = val;
                        best = k;
                    }
                }
                if best == ex.label {
                    correct += 1;
                }
                preds.push(best as f64);
                golds.push(ex.label as f64);
                n += 1;
            }
        }
        Ok(ClsResult { accuracy: correct as f64 / n.max(1) as f64, pearson: pearson(&preds, &golds), n })
    }
}

pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for i in 0..a.len() {
        cov += (a[i] - ma) * (b[i] - mb);
        va += (a[i] - ma).powi(2);
        vb += (b[i] - mb).powi(2);
    }
    if va == 0.0 || vb == 0.0 {
        return 0.0;
    }
    cov / (va * vb).sqrt()
}

/// LM evaluator: average masked loss over batches (perplexity proxy).
pub struct LmEval {
    art: Rc<Artifact>,
    pub batch: (usize, usize),
}

impl LmEval {
    pub fn new(rt: &mut Runtime, eval_name: &str) -> Result<Self> {
        let art = rt.load(eval_name)?;
        let batch = art.manifest.batch.context("eval artifact missing batch dims")?;
        Ok(LmEval { art, batch })
    }

    pub fn avg_loss(
        &self,
        trainable: &HashMap<String, HostTensor>,
        frozen: &HashMap<String, HostTensor>,
        batches: &[crate::data::Batch],
    ) -> Result<f64> {
        let mut total = 0.0;
        for b in batches {
            let inputs = assemble_inputs(&self.art, trainable, frozen, &b.tensors)?;
            let out = self.art.run_host(&inputs)?;
            total += out[0].scalar() as f64;
        }
        Ok(total / batches.len().max(1) as f64)
    }
}

/// Position-indexed logit scorer over a `generate` artifact (B = 1):
/// used for MMLU choice ranking and greedy decoding.
pub struct Generator {
    art: Rc<Artifact>,
    pub seq: usize,
}

impl Generator {
    pub fn new(rt: &mut Runtime, gen_name: &str) -> Result<Self> {
        let art = rt.load(gen_name)?;
        let (b, s) = art.manifest.batch.context("generate artifact missing batch dims")?;
        anyhow::ensure!(b == 1, "generator expects B=1 artifacts");
        Ok(Generator { art, seq: s })
    }

    /// Logits at `pos` for a single (right-padded) row.
    pub fn logits_at(
        &self,
        trainable: &HashMap<String, HostTensor>,
        frozen: &HashMap<String, HostTensor>,
        tokens: &[i32],
        pos: usize,
    ) -> Result<HostTensor> {
        anyhow::ensure!(tokens.len() == self.seq, "row must be padded to {}", self.seq);
        let data = vec![
            HostTensor::from_i32(&[1, self.seq], tokens),
            HostTensor::from_i32(&[1], &[pos as i32]),
        ];
        let inputs = assemble_inputs(&self.art, trainable, frozen, &data)?;
        let out = self.art.run_host(&inputs)?;
        Ok(out[0].clone())
    }

    /// MMLU scoring: fraction of items whose correct choice token has the
    /// highest logit at the query position.
    pub fn mmlu_accuracy(
        &self,
        trainable: &HashMap<String, HostTensor>,
        frozen: &HashMap<String, HostTensor>,
        items: &[MmluItem],
    ) -> Result<f64> {
        let mut correct = 0usize;
        for it in items {
            let logits = self.logits_at(trainable, frozen, &it.tokens, it.pos)?;
            let mut best = 0usize;
            let mut bestv = f32::NEG_INFINITY;
            for (k, &tok) in it.choices.iter().enumerate() {
                let v = logits.f32_at(tok as usize);
                if v > bestv {
                    bestv = v;
                    best = k;
                }
            }
            if best == it.answer {
                correct += 1;
            }
        }
        Ok(correct as f64 / items.len().max(1) as f64)
    }

    /// Greedy decoding from a prompt; returns generated token ids.
    pub fn greedy(
        &self,
        trainable: &HashMap<String, HostTensor>,
        frozen: &HashMap<String, HostTensor>,
        prompt: &[i32],
        max_new: usize,
    ) -> Result<Vec<i32>> {
        let mut toks = prompt.to_vec();
        let mut out = vec![];
        for _ in 0..max_new {
            let pos = toks.len() - 1;
            anyhow::ensure!(toks.len() <= self.seq, "context overflow");
            let mut padded = toks.clone();
            padded.resize(self.seq, crate::data::vocabulary::PAD);
            let logits = self.logits_at(trainable, frozen, &padded, pos)?;
            let v = logits.numel();
            let mut best = 0usize;
            let mut bestv = f32::NEG_INFINITY;
            for i in 0..v {
                let val = logits.f32_at(i);
                if val > bestv {
                    bestv = val;
                    best = i;
                }
            }
            toks.push(best as i32);
            out.push(best as i32);
            if best as i32 == crate::data::vocabulary::EOS {
                break;
            }
        }
        Ok(out)
    }
}

/// Repetition rate of a generated sequence: fraction of 3-grams that repeat
/// (the paper's qualitative LST failure mode, made quantitative).
pub fn repetition_rate(tokens: &[i32]) -> f64 {
    if tokens.len() < 6 {
        return 0.0;
    }
    let mut seen = std::collections::HashSet::new();
    let mut repeats = 0usize;
    let mut total = 0usize;
    for w in tokens.windows(3) {
        total += 1;
        if !seen.insert((w[0], w[1], w[2])) {
            repeats += 1;
        }
    }
    repeats as f64 / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pearson_perfect() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-9);
        let c = [4.0, 3.0, 2.0, 1.0];
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn pearson_degenerate() {
        assert_eq!(pearson(&[1.0], &[1.0]), 0.0);
        assert_eq!(pearson(&[1.0, 1.0], &[1.0, 2.0]), 0.0);
    }

    #[test]
    fn repetition_extremes() {
        let constant = vec![5i32; 30];
        assert!(repetition_rate(&constant) > 0.9);
        let distinct: Vec<i32> = (0..30).collect();
        assert_eq!(repetition_rate(&distinct), 0.0);
    }
}
