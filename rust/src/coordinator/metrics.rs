//! Training metrics: loss curves, throughput, simple CSV logging.

use std::path::Path;

/// Rolling metrics for one run.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub losses: Vec<f32>,
    pub gnorms: Vec<f32>,
    pub step_secs: Vec<f64>,
    pub tokens_per_step: usize,
}

impl Metrics {
    pub fn new(tokens_per_step: usize) -> Self {
        Metrics { tokens_per_step, ..Default::default() }
    }

    pub fn push(&mut self, loss: f32, gnorm: f32, secs: f64) {
        self.losses.push(loss);
        self.gnorms.push(gnorm);
        self.step_secs.push(secs);
    }

    pub fn last_loss(&self) -> f32 {
        *self.losses.last().unwrap_or(&f32::NAN)
    }

    /// Mean loss over the last `n` steps.
    pub fn mean_loss_tail(&self, n: usize) -> f32 {
        let tail = &self.losses[self.losses.len().saturating_sub(n)..];
        if tail.is_empty() {
            return f32::NAN;
        }
        tail.iter().sum::<f32>() / tail.len() as f32
    }

    /// Median step time (robust to compile-on-first-step spikes).
    pub fn median_step_secs(&self) -> f64 {
        if self.step_secs.is_empty() {
            return f64::NAN;
        }
        let mut v = self.step_secs.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[v.len() / 2]
    }

    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens_per_step as f64 / self.median_step_secs()
    }

    /// True iff any recorded loss is NaN/Inf — the Table 5 divergence signal.
    pub fn diverged(&self) -> bool {
        self.losses.iter().any(|l| !l.is_finite())
    }

    pub fn save_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(p) = path.parent() {
            std::fs::create_dir_all(p)?;
        }
        let mut s = String::from("step,loss,gnorm,secs\n");
        for i in 0..self.losses.len() {
            s.push_str(&format!(
                "{},{},{},{:.6}\n",
                i, self.losses[i], self.gnorms[i], self.step_secs[i]
            ));
        }
        std::fs::write(path, s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tail_mean_and_median() {
        let mut m = Metrics::new(128);
        for i in 0..10 {
            m.push(10.0 - i as f32, 1.0, if i == 0 { 5.0 } else { 0.1 });
        }
        assert_eq!(m.last_loss(), 1.0);
        assert!((m.mean_loss_tail(2) - 1.5).abs() < 1e-6);
        // median ignores the first-step compile spike
        assert!(m.median_step_secs() < 0.2);
        assert!(m.tokens_per_sec() > 1000.0);
    }

    #[test]
    fn divergence_detection() {
        let mut m = Metrics::new(1);
        m.push(1.0, 1.0, 0.1);
        assert!(!m.diverged());
        m.push(f32::NAN, 1.0, 0.1);
        assert!(m.diverged());
    }
}
