//! Checkpoint format: a minimal named-tensor binary container.
//!
//! Layout (little-endian):
//! ```text
//! magic "QSTCKPT1" | u32 count | entries...
//! entry: u32 name_len | name bytes | u8 dtype | u8 ndim | u64 dims[ndim] | data
//! ```
//! Used for pretrained backbones, quantized backbones, and side-network
//! (trainable) state.  Tensors are stored sorted by name so files are
//! byte-reproducible.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::{DType, HostTensor};

const MAGIC: &[u8; 8] = b"QSTCKPT1";

fn dtype_code(d: DType) -> u8 {
    match d {
        DType::F32 => 0,
        DType::F16 => 1,
        DType::I32 => 2,
        DType::U32 => 3,
        DType::U8 => 4,
        DType::I8 => 5,
    }
}

fn code_dtype(c: u8) -> Result<DType> {
    Ok(match c {
        0 => DType::F32,
        1 => DType::F16,
        2 => DType::I32,
        3 => DType::U32,
        4 => DType::U8,
        5 => DType::I8,
        other => bail!("bad dtype code {other}"),
    })
}

#[derive(Clone, Debug, Default)]
pub struct Checkpoint {
    pub tensors: HashMap<String, HostTensor>,
}

impl Checkpoint {
    pub fn new(tensors: HashMap<String, HostTensor>) -> Self {
        Checkpoint { tensors }
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut names: Vec<&String> = self.tensors.keys().collect();
        names.sort();
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(MAGIC)?;
        w.write_all(&(names.len() as u32).to_le_bytes())?;
        for name in names {
            let t = &self.tensors[name];
            w.write_all(&(name.len() as u32).to_le_bytes())?;
            w.write_all(name.as_bytes())?;
            w.write_all(&[dtype_code(t.dtype), t.shape.len() as u8])?;
            for &d in &t.shape {
                w.write_all(&(d as u64).to_le_bytes())?;
            }
            w.write_all(&t.data)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let mut r = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
        );
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{} is not a QST checkpoint", path.display());
        }
        let mut u32buf = [0u8; 4];
        r.read_exact(&mut u32buf)?;
        let count = u32::from_le_bytes(u32buf);
        let mut tensors = HashMap::with_capacity(count as usize);
        for _ in 0..count {
            r.read_exact(&mut u32buf)?;
            let nlen = u32::from_le_bytes(u32buf) as usize;
            let mut nbuf = vec![0u8; nlen];
            r.read_exact(&mut nbuf)?;
            let name = String::from_utf8(nbuf)?;
            let mut hdr = [0u8; 2];
            r.read_exact(&mut hdr)?;
            let dtype = code_dtype(hdr[0])?;
            let ndim = hdr[1] as usize;
            let mut shape = Vec::with_capacity(ndim);
            let mut u64buf = [0u8; 8];
            for _ in 0..ndim {
                r.read_exact(&mut u64buf)?;
                shape.push(u64::from_le_bytes(u64buf) as usize);
            }
            let numel: usize = shape.iter().product();
            let mut data = vec![0u8; numel * dtype.size()];
            r.read_exact(&mut data)?;
            tensors.insert(name, HostTensor { dtype, shape, data });
        }
        Ok(Checkpoint { tensors })
    }

    pub fn total_bytes(&self) -> usize {
        self.tensors.values().map(|t| t.bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("qst_test_{}_{}", std::process::id(), name))
    }

    #[test]
    fn roundtrip() {
        let mut tensors = HashMap::new();
        tensors.insert("w".into(), HostTensor::from_f32(&[2, 3], &[1., 2., 3., 4., 5., 6.]));
        tensors.insert("q".into(), HostTensor::from_u8(&[4], vec![1, 2, 3, 255]));
        tensors.insert("s".into(), HostTensor::scalar_f32(7.5));
        let ck = Checkpoint::new(tensors);
        let path = tmpfile("roundtrip.ckpt");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.tensors.len(), 3);
        assert_eq!(back.tensors["w"].as_f32().unwrap(), vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(back.tensors["q"].data, vec![1, 2, 3, 255]);
        assert_eq!(back.tensors["s"].scalar(), 7.5);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn byte_reproducible() {
        let mut tensors = HashMap::new();
        for i in 0..10 {
            tensors.insert(format!("t{i}"), HostTensor::from_f32(&[3], &[i as f32, 0., 1.]));
        }
        let ck = Checkpoint::new(tensors);
        let p1 = tmpfile("rep1.ckpt");
        let p2 = tmpfile("rep2.ckpt");
        ck.save(&p1).unwrap();
        ck.save(&p2).unwrap();
        assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
        std::fs::remove_file(p1).ok();
        std::fs::remove_file(p2).ok();
    }

    #[test]
    fn rejects_garbage() {
        let path = tmpfile("garbage.ckpt");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
