//! Checkpoint format: a minimal named-tensor binary container.
//!
//! Layout (little-endian):
//! ```text
//! magic "QSTCKPT1" | u32 count | entries...
//! entry: u32 name_len | name bytes | u8 dtype | u8 ndim | u64 dims[ndim] | data
//! ```
//! Used for pretrained backbones, quantized backbones, and side-network
//! (trainable) state.  Tensors are stored sorted by name so files are
//! byte-reproducible.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::{DType, HostTensor};

const MAGIC: &[u8; 8] = b"QSTCKPT1";
/// Sanity caps for load-time validation (far above anything `save` emits).
const MAX_NAME_LEN: u64 = 4096;
const MAX_NDIM: usize = 8;

fn dtype_code(d: DType) -> u8 {
    match d {
        DType::F32 => 0,
        DType::F16 => 1,
        DType::I32 => 2,
        DType::U32 => 3,
        DType::U8 => 4,
        DType::I8 => 5,
    }
}

fn code_dtype(c: u8) -> Result<DType> {
    Ok(match c {
        0 => DType::F32,
        1 => DType::F16,
        2 => DType::I32,
        3 => DType::U32,
        4 => DType::U8,
        5 => DType::I8,
        other => bail!("bad dtype code {other}"),
    })
}

#[derive(Clone, Debug, Default)]
pub struct Checkpoint {
    pub tensors: HashMap<String, HostTensor>,
}

impl Checkpoint {
    pub fn new(tensors: HashMap<String, HostTensor>) -> Self {
        Checkpoint { tensors }
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        // enforce the same caps load() validates, so save can never produce
        // a file that load refuses
        for (name, t) in &self.tensors {
            if name.is_empty() || name.len() as u64 > MAX_NAME_LEN {
                bail!("tensor name length {} out of range 1..={MAX_NAME_LEN}", name.len());
            }
            if t.shape.len() > MAX_NDIM {
                bail!("tensor '{name}' has {} dims (max {MAX_NDIM})", t.shape.len());
            }
        }
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut names: Vec<&String> = self.tensors.keys().collect();
        names.sort();
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(MAGIC)?;
        w.write_all(&(names.len() as u32).to_le_bytes())?;
        for name in names {
            let t = &self.tensors[name];
            w.write_all(&(name.len() as u32).to_le_bytes())?;
            w.write_all(name.as_bytes())?;
            w.write_all(&[dtype_code(t.dtype), t.shape.len() as u8])?;
            for &d in &t.shape {
                w.write_all(&(d as u64).to_le_bytes())?;
            }
            w.write_all(&t.data)?;
        }
        Ok(())
    }

    /// Load a checkpoint, validating every header-declared size against the
    /// actual file length before allocating.  Serving loads run directories
    /// it does not control, so a truncated or corrupt file must fail with a
    /// clear error — never a huge allocation or a panic.
    pub fn load(path: &Path) -> Result<Self> {
        let file = std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
        let file_len = file.metadata()?.len();
        let mut r = std::io::BufReader::new(file);
        let corrupt = |what: &str| {
            anyhow::anyhow!("corrupt checkpoint {}: {}", path.display(), what)
        };
        fn take(remaining: &mut u64, n: u64, path: &Path) -> Result<()> {
            if n > *remaining {
                bail!(
                    "corrupt checkpoint {}: header declares {n} bytes but only {} remain (truncated file?)",
                    path.display(),
                    remaining
                );
            }
            *remaining -= n;
            Ok(())
        }
        let mut remaining = file_len;
        let mut magic = [0u8; 8];
        take(&mut remaining, 8, path)?;
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{} is not a QST checkpoint", path.display());
        }
        let mut u32buf = [0u8; 4];
        take(&mut remaining, 4, path)?;
        r.read_exact(&mut u32buf)?;
        let count = u32::from_le_bytes(u32buf);
        // each entry takes >= 4 (name_len) + 2 (dtype+ndim) bytes
        if count as u64 * 6 > remaining {
            return Err(corrupt(&format!("implausible tensor count {count} for a {file_len}-byte file")));
        }
        let mut tensors = HashMap::with_capacity(count as usize);
        for i in 0..count {
            take(&mut remaining, 4, path)?;
            r.read_exact(&mut u32buf)?;
            let nlen = u32::from_le_bytes(u32buf) as u64;
            if nlen == 0 || nlen > MAX_NAME_LEN {
                return Err(corrupt(&format!("entry {i} name length {nlen} (max {MAX_NAME_LEN})")));
            }
            take(&mut remaining, nlen, path)?;
            let mut nbuf = vec![0u8; nlen as usize];
            r.read_exact(&mut nbuf)?;
            let name = String::from_utf8(nbuf).map_err(|_| corrupt(&format!("entry {i} name is not UTF-8")))?;
            let mut hdr = [0u8; 2];
            take(&mut remaining, 2, path)?;
            r.read_exact(&mut hdr)?;
            let dtype = code_dtype(hdr[0])?;
            let ndim = hdr[1] as usize;
            if ndim > MAX_NDIM {
                return Err(corrupt(&format!("'{name}' has {ndim} dims (max {MAX_NDIM})")));
            }
            let mut shape = Vec::with_capacity(ndim);
            let mut u64buf = [0u8; 8];
            for _ in 0..ndim {
                take(&mut remaining, 8, path)?;
                r.read_exact(&mut u64buf)?;
                shape.push(u64::from_le_bytes(u64buf) as usize);
            }
            let numel = shape.iter().try_fold(1u64, |acc, &d| acc.checked_mul(d as u64));
            let nbytes = numel.and_then(|n| n.checked_mul(dtype.size() as u64));
            let nbytes = nbytes
                .ok_or_else(|| corrupt(&format!("'{name}' shape {shape:?} overflows a byte count")))?;
            take(&mut remaining, nbytes, path).with_context(|| format!("reading tensor '{name}'"))?;
            let mut data = vec![0u8; nbytes as usize];
            r.read_exact(&mut data)?;
            tensors.insert(name, HostTensor { dtype, shape, data });
        }
        Ok(Checkpoint { tensors })
    }

    pub fn total_bytes(&self) -> usize {
        self.tensors.values().map(|t| t.bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("qst_test_{}_{}", std::process::id(), name))
    }

    #[test]
    fn roundtrip() {
        let mut tensors = HashMap::new();
        tensors.insert("w".into(), HostTensor::from_f32(&[2, 3], &[1., 2., 3., 4., 5., 6.]));
        tensors.insert("q".into(), HostTensor::from_u8(&[4], vec![1, 2, 3, 255]));
        tensors.insert("s".into(), HostTensor::scalar_f32(7.5));
        let ck = Checkpoint::new(tensors);
        let path = tmpfile("roundtrip.ckpt");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.tensors.len(), 3);
        assert_eq!(back.tensors["w"].as_f32().unwrap(), vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(back.tensors["q"].data, vec![1, 2, 3, 255]);
        assert_eq!(back.tensors["s"].scalar(), 7.5);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn byte_reproducible() {
        let mut tensors = HashMap::new();
        for i in 0..10 {
            tensors.insert(format!("t{i}"), HostTensor::from_f32(&[3], &[i as f32, 0., 1.]));
        }
        let ck = Checkpoint::new(tensors);
        let p1 = tmpfile("rep1.ckpt");
        let p2 = tmpfile("rep2.ckpt");
        ck.save(&p1).unwrap();
        ck.save(&p2).unwrap();
        assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
        std::fs::remove_file(p1).ok();
        std::fs::remove_file(p2).ok();
    }

    #[test]
    fn rejects_garbage() {
        let path = tmpfile("garbage.ckpt");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    fn valid_bytes() -> Vec<u8> {
        let mut tensors = HashMap::new();
        tensors.insert("w".into(), HostTensor::from_f32(&[8, 4], &[0.25; 32]));
        let ck = Checkpoint::new(tensors);
        let path = tmpfile("valid_src.ckpt");
        ck.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::remove_file(path).ok();
        bytes
    }

    fn load_bytes(name: &str, bytes: &[u8]) -> Result<Checkpoint> {
        let path = tmpfile(name);
        std::fs::write(&path, bytes).unwrap();
        let r = Checkpoint::load(&path);
        std::fs::remove_file(path).ok();
        r
    }

    #[test]
    fn truncated_file_fails_cleanly() {
        let bytes = valid_bytes();
        // cut the file at every prefix length: must error, never panic
        for cut in [8, 12, 13, 20, 30, bytes.len() - 1] {
            let err = load_bytes("trunc.ckpt", &bytes[..cut]);
            assert!(err.is_err(), "cut at {cut} must fail");
        }
    }

    #[test]
    fn huge_name_len_rejected_without_allocation() {
        let mut bytes = valid_bytes();
        // entry header starts right after magic(8) + count(4)
        bytes[12..16].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = load_bytes("bigname.ckpt", &bytes).unwrap_err();
        assert!(format!("{err:#}").contains("name length"), "{err:#}");
    }

    #[test]
    fn huge_dim_rejected_against_file_length() {
        let mut bytes = valid_bytes();
        // dims start after magic(8)+count(4)+name_len(4)+"w"(1)+dtype+ndim(2)
        let dims_at = 8 + 4 + 4 + 1 + 2;
        bytes[dims_at..dims_at + 8].copy_from_slice(&(u64::MAX / 8).to_le_bytes());
        let err = load_bytes("bigdim.ckpt", &bytes).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("remain") || msg.contains("overflow"), "{msg}");
    }

    #[test]
    fn overflowing_shape_product_rejected() {
        let mut bytes = valid_bytes();
        let dims_at = 8 + 4 + 4 + 1 + 2;
        // two dims of 2^40: numel overflows nothing (2^80 > u64) -> checked_mul trips
        bytes[dims_at..dims_at + 8].copy_from_slice(&(1u64 << 40).to_le_bytes());
        bytes[dims_at + 8..dims_at + 16].copy_from_slice(&(1u64 << 40).to_le_bytes());
        let err = load_bytes("ovfl.ckpt", &bytes).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("overflow") || msg.contains("remain"), "{msg}");
    }

    #[test]
    fn save_refuses_what_load_would_reject() {
        let mut tensors = HashMap::new();
        tensors.insert("x".repeat(5000), HostTensor::scalar_f32(1.0));
        let err = Checkpoint::new(tensors).save(&tmpfile("longname.ckpt")).unwrap_err();
        assert!(format!("{err:#}").contains("name length"));

        let mut tensors = HashMap::new();
        tensors.insert("t".into(), HostTensor::zeros(crate::tensor::DType::F32, &[1; 9]));
        let err = Checkpoint::new(tensors).save(&tmpfile("deepdims.ckpt")).unwrap_err();
        assert!(format!("{err:#}").contains("dims"));
    }

    #[test]
    fn implausible_count_rejected() {
        let mut bytes = valid_bytes();
        bytes[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = load_bytes("bigcount.ckpt", &bytes).unwrap_err();
        assert!(format!("{err:#}").contains("count"), "{err:#}");
    }
}
