//! `qst` — the Layer-3 coordinator CLI.
//!
//! Python never runs here: every command executes AOT-compiled HLO artifacts
//! via PJRT.  See `qst help` for the command list.

use anyhow::{bail, Context, Result};

use qst::cli::{Args, USAGE};
use qst::coordinator::pipeline;
use qst::coordinator::Checkpoint;
use qst::data::glue::{GlueTask, ALL_TASKS};
use qst::runtime::Runtime;

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn glue_task(name: &str) -> Result<GlueTask> {
    ALL_TASKS
        .into_iter()
        .find(|t| t.name().eq_ignore_ascii_case(name))
        .with_context(|| format!("unknown GLUE task '{name}'"))
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "" | "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        "info" => {
            let rt = Runtime::with_default_dir()?;
            println!("platform: {} ({} devices)", rt.client.platform_name(), rt.client.device_count());
            println!("artifacts dir: {}", qst::artifacts_dir().display());
            println!("runs dir: {}", qst::runs_dir().display());
            println!("artifacts available: {}", rt.available().len());
            Ok(())
        }
        "artifacts" => {
            let rt = Runtime::with_default_dir()?;
            for name in rt.available() {
                println!("{name}");
            }
            Ok(())
        }
        "pretrain" => {
            let cfg = args.require("config")?.to_string();
            let steps = args.usize_or("steps", 300)?;
            let lr = args.f32_or("lr", 3e-3)?;
            let mut rt = Runtime::with_default_dir()?;
            let (ckpt, report) = pipeline::pretrain(&mut rt, &cfg, steps, lr, 0, true)?;
            let path = pipeline::base_ckpt_path(&cfg);
            ckpt.save(&path)?;
            println!(
                "pretrained {cfg}: loss {:.3} -> {:.3} in {:.1}s; saved {}",
                report.metrics.losses.first().copied().unwrap_or(f32::NAN),
                report.metrics.mean_loss_tail(10),
                report.wall_secs,
                path.display()
            );
            Ok(())
        }
        "quantize" => {
            let cfg = args.require("config")?.to_string();
            let qdtype = args.str_or("qdtype", "nf4");
            let path = pipeline::base_ckpt_path(&cfg);
            let ckpt = Checkpoint::load(&path)
                .with_context(|| format!("no base checkpoint at {} — run pretrain", path.display()))?;
            let mut total = 0usize;
            let mut qbytes = 0usize;
            let mut mse_sum = 0.0f64;
            let mut mats = 0usize;
            for (name, t) in &ckpt.tensors {
                if t.shape.len() == 2 && name.contains("layers") && t.shape[0] % 64 == 0 {
                    let w = t.as_f32()?;
                    let (p, s) = qst::quant::quantize_matrix_raw(&w, t.shape[0], t.shape[1], &qdtype, 64);
                    let back = qst::quant::dequantize_matrix_raw(&p, &s, t.shape[0], t.shape[1], &qdtype, 64);
                    mse_sum += w.iter().zip(&back).map(|(a, b)| (a - b).powi(2) as f64).sum::<f64>()
                        / w.len() as f64;
                    mats += 1;
                    total += t.bytes();
                    qbytes += p.len() + s.len() / 2; // packed + ~8-bit scales
                }
            }
            println!(
                "{cfg}: quantized {mats} matrices ({} -> {}, {:.2} bits/param), mean MSE {:.3e}",
                qst::util::human_bytes(total as f64),
                qst::util::human_bytes(qbytes as f64),
                qst::quant::storage_bits_per_param(64, 256),
                mse_sum / mats.max(1) as f64
            );
            Ok(())
        }
        "finetune" => {
            let cfg = args.require("config")?.to_string();
            let method = args.require("method")?.to_string();
            let task = args.str_or("task", "cls");
            let steps = args.usize_or("steps", 150)?;
            let mut rt = Runtime::with_default_dir()?;
            let base = qst::experiments::common::base_for(&mut rt, &cfg, false)?;
            let out = if task == "cls" {
                let gtask = glue_task(&args.str_or("glue-task", "SST-2"))?;
                let out = qst::experiments::common::finetune_glue(
                    &mut rt, &cfg, &method, gtask, steps, &base, "",
                )?;
                let acc = qst::experiments::common::eval_glue(&mut rt, &cfg, &method, gtask, &out, 256)?;
                println!("{cfg}/{method}/{}: final loss {:.4}, eval score {:.3}", gtask.name(), out.final_loss, acc);
                out
            } else {
                let out = qst::experiments::common::finetune_mmlu(&mut rt, &cfg, &method, steps, &base, "")?;
                let acc = qst::experiments::common::eval_mmlu(&mut rt, &cfg, &method, &out, 150, "")?;
                println!("{cfg}/{method}/lm: final loss {:.4}, MMLU-like acc {:.3}", out.final_loss, acc);
                out
            };
            let ckpt_path = qst::runs_dir().join(format!("{cfg}__{method}__{task}.ckpt"));
            Checkpoint::new(out.trainable).save(&ckpt_path)?;
            println!("saved trainable state to {}", ckpt_path.display());
            Ok(())
        }
        "generate" => {
            let cfg = args.require("config")?.to_string();
            let method = args.str_or("method", "qst");
            let max_new = args.usize_or("max-new", 16)?;
            let mut rt = Runtime::with_default_dir()?;
            let base = qst::experiments::common::base_for(&mut rt, &cfg, false)?;
            let out = qst::experiments::common::finetune_mmlu(&mut rt, &cfg, &method, 50, &base, "")?;
            let gen_name = format!("{cfg}__{method}__generate");
            let g = qst::coordinator::evaluator::Generator::new(&mut rt, &gen_name)?;
            let vocab = qst::data::Vocab::new(rt.load(&gen_name)?.manifest.cfg.usize("vocab"));
            let mut ig = qst::data::instruct::InstructGen::new(vocab, 7);
            let (prompt, _) = ig.pair(qst::data::instruct::Category::Writing);
            let toks = g.greedy(&out.trainable, &out.frozen, &prompt, max_new)?;
            println!("prompt: {prompt:?}");
            println!("generated: {toks:?}");
            println!("repetition rate: {:.2}", qst::coordinator::evaluator::repetition_rate(&toks));
            Ok(())
        }
        "experiments" => {
            let id = args.str_or("id", "all");
            qst::experiments::run(&id, args.has("fast"))
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}
