//! `qst` — the Layer-3 coordinator CLI.
//!
//! Python never runs here: every command executes AOT-compiled HLO artifacts
//! via PJRT.  See `qst help` for the command list.

use anyhow::{Context, Result};

use qst::cli::{Args, USAGE};
use qst::coordinator::pipeline;
use qst::coordinator::Checkpoint;
use qst::data::glue::{GlueTask, ALL_TASKS};
use qst::runtime::Runtime;
use qst::serve::{self, Engine, ServeConfig, Server};

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn glue_task(name: &str) -> Result<GlueTask> {
    ALL_TASKS
        .into_iter()
        .find(|t| t.name().eq_ignore_ascii_case(name))
        .with_context(|| format!("unknown GLUE task '{name}'"))
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    if args.get("threads").is_some() {
        // host kernels (serve forwards, quantizer) honor --threads globally;
        // results are bit-identical for any value — wall-clock only
        qst::kernels::set_default_threads(args.usize_or("threads", 1)?);
    }
    match args.command.as_str() {
        "" | "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        "info" => {
            let rt = Runtime::with_default_dir()?;
            println!("platform: {} ({} devices)", rt.client.platform_name(), rt.client.device_count());
            println!("artifacts dir: {}", qst::artifacts_dir().display());
            println!("runs dir: {}", qst::runs_dir().display());
            println!("artifacts available: {}", rt.available().len());
            Ok(())
        }
        "artifacts" => {
            let rt = Runtime::with_default_dir()?;
            for name in rt.available() {
                println!("{name}");
            }
            Ok(())
        }
        "pretrain" => {
            let cfg = args.require("config")?.to_string();
            let steps = args.usize_or("steps", 300)?;
            let lr = args.f32_or("lr", 3e-3)?;
            let mut rt = Runtime::with_default_dir()?;
            let (ckpt, report) = pipeline::pretrain(&mut rt, &cfg, steps, lr, 0, true)?;
            let path = pipeline::base_ckpt_path(&cfg);
            ckpt.save(&path)?;
            println!(
                "pretrained {cfg}: loss {:.3} -> {:.3} in {:.1}s; saved {}",
                report.metrics.losses.first().copied().unwrap_or(f32::NAN),
                report.metrics.mean_loss_tail(10),
                report.wall_secs,
                path.display()
            );
            Ok(())
        }
        "quantize" => {
            let cfg = args.require("config")?.to_string();
            let qdtype = args.str_or("qdtype", "nf4");
            let path = pipeline::base_ckpt_path(&cfg);
            let ckpt = Checkpoint::load(&path)
                .with_context(|| format!("no base checkpoint at {} — run pretrain", path.display()))?;
            let mut total = 0usize;
            let mut qbytes = 0usize;
            let mut mse_sum = 0.0f64;
            let mut mats = 0usize;
            for (name, t) in &ckpt.tensors {
                if t.shape.len() == 2 && name.contains("layers") && t.shape[0] % 64 == 0 {
                    let w = t.as_f32()?;
                    let (p, s) = qst::quant::quantize_matrix_raw(&w, t.shape[0], t.shape[1], &qdtype, 64);
                    let back = qst::quant::dequantize_matrix_raw(&p, &s, t.shape[0], t.shape[1], &qdtype, 64);
                    mse_sum += w.iter().zip(&back).map(|(a, b)| (a - b).powi(2) as f64).sum::<f64>()
                        / w.len() as f64;
                    mats += 1;
                    total += t.bytes();
                    // packed nibbles + 8-bit double-quantized scales (1 byte
                    // each) + per-group f32 gabs/gmean — matches the 64/256
                    // storage_bits_per_param reported below
                    qbytes += p.len() + s.len() + 8 * s.len().div_ceil(256);
                }
            }
            println!(
                "{cfg}: quantized {mats} matrices ({} -> {}, {:.2} bits/param), mean MSE {:.3e}",
                qst::util::human_bytes(total as f64),
                qst::util::human_bytes(qbytes as f64),
                qst::quant::storage_bits_per_param(64, 256),
                mse_sum / mats.max(1) as f64
            );
            Ok(())
        }
        "finetune" => {
            let cfg = args.require("config")?.to_string();
            let method = args.require("method")?.to_string();
            let task = args.str_or("task", "cls");
            let steps = args.usize_or("steps", 150)?;
            let mut rt = Runtime::with_default_dir()?;
            let base = qst::experiments::common::base_for(&mut rt, &cfg, false)?;
            let out = if task == "cls" {
                let gtask = glue_task(&args.str_or("glue-task", "SST-2"))?;
                let out = qst::experiments::common::finetune_glue(
                    &mut rt, &cfg, &method, gtask, steps, &base, "",
                )?;
                let acc = qst::experiments::common::eval_glue(&mut rt, &cfg, &method, gtask, &out, 256)?;
                println!("{cfg}/{method}/{}: final loss {:.4}, eval score {:.3}", gtask.name(), out.final_loss, acc);
                out
            } else {
                let out = qst::experiments::common::finetune_mmlu(&mut rt, &cfg, &method, steps, &base, "")?;
                let acc = qst::experiments::common::eval_mmlu(&mut rt, &cfg, &method, &out, 150, "")?;
                println!("{cfg}/{method}/lm: final loss {:.4}, MMLU-like acc {:.3}", out.final_loss, acc);
                out
            };
            let ckpt_path = qst::runs_dir().join(format!("{cfg}__{method}__{task}.ckpt"));
            Checkpoint::new(out.trainable).save(&ckpt_path)?;
            println!("saved trainable state to {}", ckpt_path.display());
            Ok(())
        }
        "generate" => {
            let cfg = args.require("config")?.to_string();
            let method = args.str_or("method", "qst");
            let max_new = args.usize_or("max-new", 16)?;
            let mut rt = Runtime::with_default_dir()?;
            let base = qst::experiments::common::base_for(&mut rt, &cfg, false)?;
            let out = qst::experiments::common::finetune_mmlu(&mut rt, &cfg, &method, 50, &base, "")?;
            let gen_name = format!("{cfg}__{method}__generate");
            let g = qst::coordinator::evaluator::Generator::new(&mut rt, &gen_name)?;
            let vocab = qst::data::Vocab::new(rt.load(&gen_name)?.manifest.cfg.usize("vocab"));
            let mut ig = qst::data::instruct::InstructGen::new(vocab, 7);
            let (prompt, _) = ig.pair(qst::data::instruct::Category::Writing);
            let toks = g.greedy(&out.trainable, &out.frozen, &prompt, max_new)?;
            println!("prompt: {prompt:?}");
            println!("generated: {toks:?}");
            println!("repetition rate: {:.2}", qst::coordinator::evaluator::repetition_rate(&toks));
            Ok(())
        }
        "experiments" => {
            let id = args.str_or("id", "all");
            qst::experiments::run(&id, args.has("fast"))
        }
        "serve" => cmd_serve(&args),
        "gateway" => cmd_gateway(&args),
        "shard-worker" => cmd_shard_worker(&args),
        "bench-serve" => cmd_bench_serve(&args),
        "bench-gateway" => cmd_bench_gateway(&args),
        "bench-kernels" => cmd_bench_kernels(&args),
        "bench-registry" => cmd_bench_registry(&args),
        other => {
            eprintln!("error: unknown command '{other}'\n");
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    }
}

/// Arm the span recorder when `--trace-out <path>` is given; returns the
/// requested output path.  Tracing is parity-safe — it cannot change one
/// output bit — so arming costs nothing but the recording itself.
fn trace_arm(args: &Args) -> Option<String> {
    let path = args.get("trace-out").map(|s| s.to_string());
    if path.is_some() {
        qst::obs::set_enabled(true);
    }
    path
}

/// Drain the local recorder, append worker-shipped spans, and write the
/// Chrome trace-event file (loadable in Perfetto / chrome://tracing).
/// `counters` carries the shards' gauge flight-recorder series (empty
/// when `--series-ms` was off) rendered as counter tracks beside the
/// spans.
fn trace_finish(
    path: &str,
    remote: Vec<qst::obs::trace::TraceSpan>,
    counters: &[qst::obs::trace::CounterTrack],
) -> Result<()> {
    qst::obs::set_enabled(false);
    let (spans, dropped) = qst::obs::drain();
    let mut all = qst::obs::trace::local(spans);
    all.extend(remote);
    qst::obs::trace::write_file_with_counters(path, &all, counters)
        .with_context(|| format!("writing trace {path}"))?;
    let points: usize = counters.iter().map(|t| t.points.len()).sum();
    eprintln!(
        "wrote {} span(s){} to {path}{}",
        all.len(),
        if points > 0 { format!(" + {points} gauge point(s)") } else { String::new() },
        if dropped > 0 { format!(" ({dropped} lost to ring overwrite)") } else { String::new() }
    );
    Ok(())
}

/// Shared serve tuning from flags.
fn serve_config(args: &Args) -> Result<ServeConfig> {
    Ok(ServeConfig {
        cache_bytes: args.u64_or("cache-bytes", 64 << 20)? as usize,
        registry_bytes: args.u64_or("registry-bytes", 256 << 20)? as usize,
        max_batch: args.usize_or("batch", 8)?,
        prefix_block: args.usize_or("prefix-block", 16)?,
    })
}

/// stdin-driven request loop: one request per line, `<task> <tok> <tok> ...`.
///
/// On a TTY every line is answered immediately; on piped input requests
/// accumulate until `--batch` pending (or EOF), so the micro-batcher and
/// the hidden-state cache's within-batch dedupe actually engage.
fn serve_loop<E: Engine>(server: &mut Server<E>) -> Result<()> {
    use qst::proto::text::{self, TextLine};
    use std::io::{BufRead, IsTerminal};
    let interactive = std::io::stdin().is_terminal();
    eprintln!(
        "serving tasks {:?} (seq {}, cache {}, batch {}{}); one request per line: '<task> <tok> ...'",
        server.registry.known_tasks(),
        server.engine.seq_len(),
        if server.cache.enabled() {
            qst::util::human_bytes(server.cache.budget() as f64)
        } else {
            "off".into()
        },
        server.max_batch(),
        if interactive { ", interactive" } else { ", piped" }
    );
    let stdin = std::io::stdin();
    for line in stdin.lock().lines() {
        let line = line?;
        // the canonical text codec (shared with `qst gateway`) — one
        // parser, one set of error messages
        let (task, tokens) = match text::parse_line(&line) {
            Ok(TextLine::Empty) => continue,
            Ok(TextLine::Stats) => {
                println!("{}", server.stats.summary(server.cache.hit_rate()));
                continue;
            }
            Ok(TextLine::Prom) => {
                // single-process exposition: present this server as a
                // one-shard fleet (gauges only a gateway can observe —
                // backpressure rejections, per-engine row counters behind
                // the generic `Engine` — stay zero)
                let pending = server.pending() as u64;
                let rep = qst::proto::ShardReport {
                    stats: server.stats.snapshot(),
                    cache_hits: server.cache.hits,
                    cache_misses: server.cache.misses,
                    prefix_hits: server.cache.prefix_hits,
                    cache_evictions: server.cache.evictions,
                    cache_entries: server.cache.len(),
                    cache_bytes: server.cache.bytes(),
                    registry_bytes: server.registry.bytes(),
                    registry_evictions: server.registry.evictions,
                    swap_hist: server.registry.swap_hist.clone(),
                    queue_depth: pending,
                    ..Default::default()
                };
                let gauges = qst::obs::prom::GatewayGauges {
                    submitted: rep.stats.requests + pending,
                    rejected: 0,
                    dropped: rep.stats.dropped,
                    in_flight: pending,
                };
                let report = qst::gateway::aggregate(vec![rep]);
                // no heartbeat registry in single-process serve
                print!("{}", qst::obs::prom::render(&report, &gauges, None));
                continue;
            }
            Ok(TextLine::Request { task, tokens }) => (task, tokens),
            Err(e) => {
                eprintln!("{e}");
                continue;
            }
        };
        if let Err(e) = server.submit(&task, &tokens) {
            eprintln!("rejected: {e:#}");
            continue;
        }
        // interactive: answer every line; piped: let micro-batches fill
        if interactive || server.pending() >= server.max_batch() {
            drain_and_print(server);
        }
    }
    drain_and_print(server); // EOF: flush the final partial batch
    println!("{}", server.stats.summary(server.cache.hit_rate()));
    println!(
        "cache: {} entries, {} | registry: {} resident, {} evictions",
        server.cache.len(),
        qst::util::human_bytes(server.cache.bytes() as f64),
        server.registry.resident_count(),
        server.registry.evictions
    );
    Ok(())
}

fn drain_and_print<E: Engine>(server: &mut Server<E>) {
    match server.drain() {
        Err(e) => eprintln!("request failed: {e:#}"),
        Ok(responses) => {
            for r in responses {
                println!("{}", qst::proto::text::format_response(&r, None));
            }
        }
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    let trace_out = trace_arm(args);
    let cfg = serve_config(args)?;
    if args.has("synthetic") || args.get("config").is_none() {
        let seq = args.usize_or("seq", 64)?;
        let seed = args.u64_or("seed", 0)?;
        let n_tasks = args.usize_or("num-tasks", 2)?.max(1);
        let preset = serve::EnginePreset::parse(&args.str_or("preset", "small"))?;
        let backbone = serve::BackboneKind::parse(&args.str_or("backbone", "f32"))?;
        let mut engine = preset.build_backbone(seed, seq, backbone);
        engine.set_threads(args.usize_or("threads", 1)?);
        eprintln!(
            "backbone: {} preset stored as {} ({} resident)",
            preset.name(),
            backbone.name(),
            qst::util::human_bytes(engine.backbone_resident_bytes() as f64)
        );
        let mut server = Server::new(engine, cfg);
        for i in 0..n_tasks {
            server.registry.register_synthetic(&format!("task{i}"), seed ^ ((i as u64 + 1) << 32), 1 << 16)?;
        }
        serve_loop(&mut server)?;
        if let Some(p) = &trace_out {
            trace_finish(p, Vec::new(), &[])?;
        }
        return Ok(());
    }
    // artifact mode: per-task eval graphs over one shared quantized backbone
    let cfg_name = args.require("config")?.to_string();
    let method = args.str_or("method", "qst");
    let tasks: Vec<String> =
        args.str_or("tasks", "cls").split(',').map(|s| s.trim().to_string()).collect();
    let rt = Runtime::with_default_dir()?;
    let mut engine = serve::ExecutorEngine::new(rt);
    let base = Checkpoint::load(&pipeline::base_ckpt_path(&cfg_name)).with_context(|| {
        format!("no base checkpoint for '{cfg_name}' — run `qst pretrain --config {cfg_name}`")
    })?;
    let mut server_registry = serve::Registry::new(cfg.registry_bytes);
    for (i, task) in tasks.iter().enumerate() {
        let artifact = format!("{cfg_name}__{method}__{task}__eval");
        let side_path = qst::runs_dir().join(format!("{cfg_name}__{method}__{task}.ckpt"));
        let side = Checkpoint::load(&side_path).with_context(|| {
            format!(
                "no side checkpoint for task '{task}' — run `qst finetune --config {cfg_name} --method {method} --task {task}`"
            )
        })?;
        let man = engine.rt.load(&artifact)?.manifest.clone();
        let frozen = pipeline::frozen_from_checkpoint(&man, &base)?;
        engine.bind_task(task, &artifact, &side.tensors, &frozen)?;
        // the executor keeps the side state device-resident, so the registry
        // only tracks a lightweight handle (no tensor residency to thrash)
        server_registry.register_synthetic(task, i as u64 + 1, 1 << 12)?;
    }
    let mut server = Server::new(engine, cfg);
    server.registry = server_registry;
    serve_loop(&mut server)?;
    if let Some(p) = &trace_out {
        trace_finish(p, Vec::new(), &[])?;
    }
    Ok(())
}

/// `qst gateway`: the asynchronous sharded front-end over the line
/// protocol (submission decoupled from execution; responses print in
/// completion order).  Shards run as in-process threads by default, or
/// as `qst shard-worker` processes with `--connect addr,addr,...`
/// (`unix:<path>` or `<host>:<port>`; the shard count is the address
/// count, and each worker is configured over the wire from this
/// gateway's flags).  Synthetic backend only — artifact serving stays on
/// `qst serve` until split backbone artifacts land.
fn cmd_gateway(args: &Args) -> Result<()> {
    let trace_out = trace_arm(args);
    let connect: Option<Vec<String>> = args
        .get("connect")
        .map(|s| s.split(',').map(|a| a.trim().to_string()).filter(|a| !a.is_empty()).collect());
    let cfg = qst::gateway::GatewayConfig {
        shards: args.usize_or("shards", 2)?.max(1),
        queue_cap: args.usize_or("queue-cap", 64)?.max(1),
        serve: serve_config(args)?,
        preset: serve::EnginePreset::parse(&args.str_or("preset", "small"))?,
        backbone: serve::BackboneKind::parse(&args.str_or("backbone", "f32"))?,
        seed: args.u64_or("seed", 0)?,
        seq: args.usize_or("seq", 64)?,
        tasks: args.usize_or("num-tasks", 2)?.max(1),
        threads_per_shard: args.usize_or("threads", 1)?,
        trace: trace_out.is_some(),
        // health plane: both cadences default off (zero overhead; the
        // serving loops keep their plain blocking recv)
        heartbeat_ms: args.u64_or("heartbeat-ms", 0)?,
        health_mult: args.u64_or("health-mult", qst::obs::health::DEFAULT_HEALTH_MULT)?.max(1),
        series_ms: args.u64_or("series-ms", 0)?,
        series_cap: args.usize_or("series-cap", qst::obs::series::SERIES_DEFAULT_CAP)?.max(1),
    };
    // Gateway::connect owns the shards-from-addresses derivation, so the
    // banner reads the fleet shape back from the constructed gateway
    // rather than re-deriving it
    let mut gw = match &connect {
        None => qst::gateway::Gateway::launch(&cfg)?,
        Some(addrs) => qst::gateway::Gateway::connect(&cfg, addrs)?,
    };
    let shards = gw.shard_count();
    let resident = match &connect {
        None => qst::costmodel::memory::gateway_resident_bytes(
            cfg.preset,
            cfg.backbone,
            shards,
            cfg.tasks,
            cfg.serve.cache_bytes,
        ),
        Some(_) => qst::costmodel::memory::gateway_resident_bytes_multiproc(
            cfg.preset,
            cfg.backbone,
            shards,
            cfg.tasks,
            cfg.serve.cache_bytes,
        ),
    };
    eprintln!(
        "gateway: {} {} shard(s), {} preset backbone as {} ({} modeled fleet residency), {} tasks, queue cap {}; one request per line: '<task> <tok> ...'",
        shards,
        if connect.is_some() { "socket" } else { "in-proc" },
        cfg.preset.name(),
        cfg.backbone.name(),
        qst::util::human_bytes(resident as f64),
        cfg.tasks,
        cfg.queue_cap
    );
    let stdin = std::io::stdin();
    let mut out = std::io::stdout();
    qst::gateway::line_loop(&mut gw, stdin.lock(), &mut out)?;
    let remote = if trace_out.is_some() {
        // one last report pulls the socket workers' final span batches
        // (each `Telemetry` rides ahead of its `Report` on the per-shard
        // FIFO); in-proc shard rings live in this process and are drained
        // by `trace_finish` directly
        let _ = gw.report();
        gw.take_remote_spans()
    } else {
        Vec::new()
    };
    let (report, leftover) = gw.shutdown()?;
    debug_assert!(leftover.is_empty(), "line_loop flushes before returning");
    println!("{}", report.summary());
    let table = report.task_table(8);
    if !table.is_empty() {
        print!("{table}");
    }
    if let Some(p) = &trace_out {
        // shard i's gauge series renders on counter lane i+1, matching
        // its worker span lane (empty unless --series-ms armed it)
        let counters: Vec<qst::obs::trace::CounterTrack> = report
            .shards
            .iter()
            .filter(|r| !r.series.is_empty())
            .map(|r| qst::obs::trace::CounterTrack {
                pid: r.shard as u32 + 1,
                points: r.series.clone(),
            })
            .collect();
        trace_finish(p, remote, &counters)?;
    }
    // shard engines fanned kernel workers out of the process-wide pool;
    // join them on the way out instead of leaking parked threads
    qst::kernels::shutdown_pool();
    Ok(())
}

/// `qst shard-worker --listen <addr>`: one gateway shard as its own
/// process.  Binds `unix:<path>` or `<host>:<port>`, accepts one gateway
/// connection, receives its `Configure` frame (so it takes no model
/// flags and cannot drift from the fleet spec), serves until the gateway
/// shuts the fleet down, then exits.
fn cmd_shard_worker(args: &Args) -> Result<()> {
    let listen = args.require("listen")?;
    qst::gateway::worker::listen_and_serve(listen)?;
    qst::kernels::shutdown_pool();
    Ok(())
}

fn cmd_bench_gateway(args: &Args) -> Result<()> {
    let shard_counts: Vec<usize> = args
        .str_or("shards", "1,2,4")
        .split(',')
        .map(|s| {
            s.trim()
                .parse::<usize>()
                .with_context(|| format!("--shards expects comma-separated integers, got '{s}'"))
        })
        .collect::<Result<_>>()?;
    let transports: Vec<qst::proto::TransportKind> = args
        .str_or("transports", "inproc,socket")
        .split(',')
        .map(|s| qst::proto::TransportKind::parse(s.trim()))
        .collect::<Result<_>>()?;
    let opts = qst::gateway::bench::BenchGatewayOpts {
        shard_counts,
        transports,
        tasks: args.usize_or("tasks", 3)?.max(1),
        requests: args.usize_or("requests", 256)?,
        families: args.usize_or("families", 8)?,
        per_family: args.usize_or("per-family", 4)?,
        prefix_len: args.usize_or("prefix-len", 32)?,
        prompt_len: args.usize_or("prompt-len", 48)?,
        seq: args.usize_or("seq", 64)?,
        max_batch: args.usize_or("batch", 8)?,
        cache_bytes: args.u64_or("cache-bytes", 64 << 20)? as usize,
        registry_bytes: args.u64_or("registry-bytes", 64 << 20)? as usize,
        prefix_block: args.usize_or("prefix-block", 16)?,
        queue_cap: args.usize_or("queue-cap", 64)?,
        seed: args.u64_or("seed", 0)?,
        threads_per_shard: args.usize_or("threads-per-shard", 1)?,
        preset: serve::EnginePreset::parse(&args.str_or("preset", "large"))?,
        backbone: serve::BackboneKind::parse(&args.str_or("backbone", "w4"))?,
        trace_out: args.get("trace-out").map(|s| s.to_string()),
        mixed_requests: args.usize_or("mixed-requests", 96)?,
        mixed_wave: args.usize_or("mixed-wave", 0)?,
    };
    let report = qst::gateway::bench::run_bench(&opts)?;
    println!("{}", report.summary());
    let json_path = args.str_or("json", "BENCH_gateway.json");
    std::fs::write(&json_path, report.to_json())
        .with_context(|| format!("writing {json_path}"))?;
    println!("wrote {json_path}");
    qst::kernels::shutdown_pool();
    Ok(())
}

fn cmd_bench_serve(args: &Args) -> Result<()> {
    let opts = serve::workload::BenchServeOpts {
        tasks: args.usize_or("tasks", 3)?.max(2), // the point is multi-task sharing
        requests: args.usize_or("requests", 512)?,
        unique_prompts: args.usize_or("unique-prompts", 32)?,
        prompt_len: args.usize_or("prompt-len", 48)?,
        seq: args.usize_or("seq", 64)?,
        max_batch: args.usize_or("batch", 8)?,
        cache_bytes: args.u64_or("cache-bytes", 64 << 20)? as usize,
        registry_bytes: args.u64_or("registry-bytes", 64 << 20)? as usize,
        burst: args.usize_or("burst", 64)?,
        seed: args.u64_or("seed", 0)?,
        threads: args.usize_or("threads", 1)?,
        preset: serve::EnginePreset::parse(&args.str_or("preset", "small"))?,
        backbone: serve::BackboneKind::parse(&args.str_or("backbone", "f32"))?,
        // off by default so the BENCH_serve.json trajectory stays
        // comparable across PRs; bench-gateway owns the prefix story
        prefix_block: args.usize_or("prefix-block", 0)?,
        trace_out: args.get("trace-out").map(|s| s.to_string()),
    };
    let report = serve::workload::run_bench(&opts)?;
    println!("{}", report.summary());
    let json_path = args.str_or("json", "BENCH_serve.json");
    std::fs::write(&json_path, report.to_json())
        .with_context(|| format!("writing {json_path}"))?;
    println!("wrote {json_path}");
    Ok(())
}

fn cmd_bench_registry(args: &Args) -> Result<()> {
    let opts = qst::gateway::bench_registry::BenchRegistryOpts {
        tasks: args.usize_or("tasks", 1000)?.max(1),
        requests: args.usize_or("requests", 3000)?,
        zipf_s: args.f32_or("zipf-s", 1.0)? as f64,
        budget_pct: args.usize_or("budget-pct", 8)?,
        seq: args.usize_or("seq", 32)?,
        prompt_len: args.usize_or("prompt-len", 12)?,
        max_batch: args.usize_or("batch", 8)?,
        parity_requests: args.usize_or("parity-requests", 24)?,
        seed: args.u64_or("seed", 0)?,
        threads: args.usize_or("threads", 1)?,
    };
    let report = qst::gateway::bench_registry::run_bench(&opts)?;
    println!("{}", report.summary());
    let json_path = args.str_or("json", "BENCH_registry.json");
    std::fs::write(&json_path, report.to_json())
        .with_context(|| format!("writing {json_path}"))?;
    println!("wrote {json_path}");
    qst::kernels::shutdown_pool();
    Ok(())
}

fn cmd_bench_kernels(args: &Args) -> Result<()> {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
    let dims: Vec<usize> = args
        .str_or("dims", "96,256,512")
        .split(',')
        .map(|s| {
            s.trim()
                .parse::<usize>()
                .with_context(|| format!("--dims expects comma-separated integers, got '{s}'"))
        })
        .collect::<Result<_>>()?;
    let opts = qst::kernels::bench::BenchKernelsOpts {
        dims,
        m: args.usize_or("m", 64)?,
        threads: args.usize_or("threads", cores)?,
        seed: args.u64_or("seed", 0)?,
        naive_cap_macs: args
            .usize_or("naive-cap-macs", qst::kernels::bench::NAIVE_CAP_MACS)?,
    };
    let report = qst::kernels::bench::run_bench(&opts)?;
    println!("{}", report.summary());
    let json_path = args.str_or("json", "BENCH_kernels.json");
    std::fs::write(&json_path, report.to_json())
        .with_context(|| format!("writing {json_path}"))?;
    println!("wrote {json_path}");
    Ok(())
}
