//! The canonical text codec for the stdin line protocol.
//!
//! `qst serve` and `qst gateway` speak the same human-typable protocol —
//! one request per line (`<task> <tok> <tok> ...`), `stats` for a
//! telemetry summary.  Before this module each binary carried its own
//! hand-rolled parser, so `stats` handling and error wording could
//! drift; both loops now parse through [`parse_line`] and print through
//! [`format_response`], and the output stays byte-identical to the
//! pre-`proto` sessions (pinned by the tests below).

use std::fmt;

use crate::serve::Response;

/// One parsed input line.
#[derive(Clone, Debug, PartialEq)]
pub enum TextLine {
    /// blank (or whitespace-only) line — skipped
    Empty,
    /// the `stats` command
    Stats,
    /// the `STATS` command: Prometheus-style text exposition
    /// ([`crate::obs::prom`]).  Case-sensitive and exact, so the
    /// lowercase human `stats` summary is untouched — and on old peers
    /// `STATS` was always an unknown-task request, never a valid one,
    /// so claiming it breaks nothing.
    Prom,
    /// the `HEALTH` command: one JSON line of fleet liveness
    /// ([`crate::obs::health::FleetHealth::to_json`]).  Claimed the same
    /// way as `STATS`: case-sensitive and exact, never a valid request
    /// on old peers.
    Health,
    /// a request: task name + prompt tokens
    Request { task: String, tokens: Vec<i32> },
}

/// A line that names a task but whose tokens do not parse as integers.
/// Displays the exact message the pre-`proto` loops printed, so piped
/// sessions see byte-identical stderr.
#[derive(Debug)]
pub struct TextError(std::num::ParseIntError);

impl fmt::Display for TextError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "bad request (tokens must be integers): {}", self.0)
    }
}

impl std::error::Error for TextError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        Some(&self.0)
    }
}

/// Parse one line of the serve/gateway stdin protocol.
pub fn parse_line(line: &str) -> Result<TextLine, TextError> {
    let line = line.trim();
    if line.is_empty() {
        return Ok(TextLine::Empty);
    }
    if line == "stats" {
        return Ok(TextLine::Stats);
    }
    if line == "STATS" {
        return Ok(TextLine::Prom);
    }
    if line == "HEALTH" {
        return Ok(TextLine::Health);
    }
    let mut parts = line.split_whitespace();
    let task = parts.next().expect("a trimmed non-empty line has a first token").to_string();
    let tokens: Vec<i32> =
        parts.map(|t| t.parse()).collect::<Result<_, _>>().map_err(TextError)?;
    Ok(TextLine::Request { task, tokens })
}

/// Format one completed response for the line protocol.  `shard: None`
/// prints the `qst serve` form (`[cache hit]` / `[backbone]`); `Some(s)`
/// prints the gateway form (`[shard s]` / `[shard s, cache hit]`).
pub fn format_response(r: &Response, shard: Option<usize>) -> String {
    let (tok, logit) = r.top1();
    match shard {
        None => format!(
            "{}#{}: next-token {} (logit {:.4}) [{}]",
            r.task,
            r.id,
            tok,
            logit,
            if r.cache_hit { "cache hit" } else { "backbone" }
        ),
        Some(s) => format!(
            "{}#{}: next-token {} (logit {:.4}) [shard {}{}]",
            r.task,
            r.id,
            tok,
            logit,
            s,
            if r.cache_hit { ", cache hit" } else { "" }
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_three_line_shapes() {
        assert_eq!(parse_line("").unwrap(), TextLine::Empty);
        assert_eq!(parse_line("   \t ").unwrap(), TextLine::Empty);
        assert_eq!(parse_line(" stats ").unwrap(), TextLine::Stats);
        assert_eq!(parse_line("STATS").unwrap(), TextLine::Prom);
        assert_eq!(parse_line("HEALTH").unwrap(), TextLine::Health);
        assert_eq!(
            parse_line("Health").unwrap(),
            TextLine::Request { task: "Health".into(), tokens: vec![] }
        );
        // only the exact uppercase form is the exposition command; mixed
        // case stays a (rejectable) request, as on old peers
        assert_eq!(
            parse_line("Stats").unwrap(),
            TextLine::Request { task: "Stats".into(), tokens: vec![] }
        );
        assert_eq!(
            parse_line("task0 5 -2 7").unwrap(),
            TextLine::Request { task: "task0".into(), tokens: vec![5, -2, 7] }
        );
        // a bare task name is a zero-token request, as before
        assert_eq!(
            parse_line("task1").unwrap(),
            TextLine::Request { task: "task1".into(), tokens: vec![] }
        );
    }

    #[test]
    fn bad_tokens_keep_the_exact_legacy_message() {
        let err = parse_line("task0 1 two 3").unwrap_err();
        let legacy = {
            // what both pre-proto parsers printed
            let e = "two".parse::<i32>().unwrap_err();
            format!("bad request (tokens must be integers): {e}")
        };
        assert_eq!(format!("{err}"), legacy);
        // composes as a std error (source chain intact)
        let dyn_err: &dyn std::error::Error = &err;
        assert!(dyn_err.source().is_some());
    }

    #[test]
    fn response_lines_match_both_legacy_forms() {
        let r = Response { id: 3, task: "task0".into(), logits: vec![0.1, 1.5, -2.0], cache_hit: false };
        assert_eq!(format_response(&r, None), "task0#3: next-token 1 (logit 1.5000) [backbone]");
        assert_eq!(format_response(&r, Some(2)), "task0#3: next-token 1 (logit 1.5000) [shard 2]");
        let hit = Response { cache_hit: true, ..r };
        assert_eq!(format_response(&hit, None), "task0#3: next-token 1 (logit 1.5000) [cache hit]");
        assert_eq!(
            format_response(&hit, Some(0)),
            "task0#3: next-token 1 (logit 1.5000) [shard 0, cache hit]"
        );
    }
}
