//! `proto` — the versioned, typed wire protocol of the QST serving
//! gateway, and the pluggable [`Transport`] seam that carries it.
//!
//! # Why a wire protocol
//!
//! QST's frozen 4-bit backbone makes shard *replicas* nearly free
//! (~42 KB packed W4 for the small preset), so the serving road map runs
//! through fan-out: first shard threads (PR 4), now shard **processes**.
//! The only thing PR 4's gateway lacked was a real message surface — its
//! `ShardMsg`/`ShardEvent` were in-memory enums welded to `std::sync::mpsc`
//! (flush acks and stats replies traveled on ad-hoc reply channels), and
//! the user-facing request surface was a whitespace line protocol
//! duplicated across two binaries.  This module makes the API first-class:
//!
//! * **Typed messages** — [`Request`], [`GatewayResponse`], [`ShardMsg`],
//!   [`ShardEvent`], [`ShardSpec`], [`ShardReport`] (which carries
//!   [`crate::serve::StatsSnapshot`]) are *the* gateway message surface,
//!   used identically by shard threads and shard processes.
//! * **Versioned binary framing** ([`frame`]) — `magic | version | tag |
//!   length | payload`, little-endian, floats as IEEE bit patterns so
//!   logits survive the wire bit-exactly.  Decoding returns typed
//!   [`DecodeError`]s — bad magic, unknown version/tag, truncation,
//!   over-cap lengths, malformed payloads — and never panics.
//! * **Canonical text codec** ([`text`]) — the single parser/printer for
//!   the stdin line protocol `qst serve` and `qst gateway` share.
//! * **Transport trait** ([`transport`]) — submit / recv / flush /
//!   report / shutdown over either bounded in-process inboxes
//!   (`gateway::transport::InProc`) or framed unix/TCP sockets
//!   ([`SocketTransport`]), with the same backpressure contract:
//!   bounded queues **reject** ([`SubmitError::Backpressure`]), they
//!   never deadlock.
//!
//! The parity gates extend across the seam: `tests/gateway.rs` and
//! `qst bench-gateway` pin socket-transport responses bit-identical to
//! the in-proc gateway and to an unsharded `Server` reference.

pub mod frame;
pub mod text;
pub mod transport;
pub mod wire;

use std::fmt;

use crate::serve::{BackboneKind, EnginePreset, Response, ServeConfig, StatsSnapshot};

pub use transport::{SocketTransport, Stream, Transport, TransportKind, WireAddr};
pub use wire::DecodeError;

/// One request as it travels to a shard: the gateway-assigned id survives
/// the trip (shards rewrite their server-local ids back to this one).
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    pub id: u64,
    pub task: String,
    pub tokens: Vec<i32>,
}

/// A completed request, tagged with the shard that served it.
#[derive(Clone, Debug, PartialEq)]
pub struct GatewayResponse {
    pub shard: usize,
    pub resp: Response,
}

/// Everything a worker needs to build its bit-identical `Server` replica.
/// The gateway sends this as the first frame on every connection, so one
/// config (the gateway's) drives the whole fleet — workers take no model
/// flags and cannot drift out of parity.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShardSpec {
    pub preset: EnginePreset,
    pub backbone: BackboneKind,
    /// engine seed — identical across shards, so replicas are bit-identical
    pub seed: u64,
    pub seq: usize,
    /// synthetic tasks registered on every shard (`task0`…)
    pub tasks: usize,
    /// kernel worker threads for the shard's engine
    pub threads: usize,
    /// per-shard server tuning (cache budget, prefix block, batch cap)
    pub serve: ServeConfig,
    /// enable the span recorder in the worker (`--trace-out`); workers
    /// ship their rings back as `Telemetry` events.  Appended last on the
    /// wire so v1 peers that predate it still interoperate (absent ⇒
    /// `false`).
    pub trace: bool,
    /// heartbeat cadence in milliseconds; 0 disables.  An armed shard
    /// emits a [`ShardEvent::Heartbeat`] roughly every `heartbeat_ms`,
    /// even while idle.  Appended as a wire tail after `trace` (with
    /// `series_ms`/`series_cap`) so pre-health peers interoperate
    /// (absent ⇒ 0 ⇒ disabled, so old gateways never see a Heartbeat
    /// frame they cannot decode).
    pub heartbeat_ms: u64,
    /// gauge flight-recorder cadence in milliseconds; 0 disables.  The
    /// recorded series rides back in the `Report` tail.
    pub series_ms: u64,
    /// flight-recorder ring capacity, in points.
    pub series_cap: usize,
}

/// Wire-decode sanity bounds for [`ShardSpec`] fields.  A shard-worker
/// builds an engine straight from a decoded spec, so a structurally
/// valid frame from an untrusted peer must not be able to panic it
/// (`seq == 0` trips an engine assert) or drive unbounded allocation
/// (`seq`/`cache_bytes` scale the resident working set directly).
pub const MAX_SPEC_SEQ: usize = 1 << 16;
/// Upper bound on `tasks` a Configure frame may request.
pub const MAX_SPEC_TASKS: usize = 1 << 12;
/// Upper bound on `threads` a Configure frame may request.
pub const MAX_SPEC_THREADS: usize = 1 << 10;
/// Upper bound on `serve.max_batch` / `serve.prefix_block`.
pub const MAX_SPEC_BATCH: usize = 1 << 16;
/// Upper bound on the byte budgets (cache, registry): 1 TiB.
pub const MAX_SPEC_BYTES: usize = 1 << 40;
/// Upper bound on the heartbeat / series cadences: one hour.
pub const MAX_SPEC_CADENCE_MS: u64 = 3_600_000;
/// Upper bound on the gauge flight-recorder ring capacity.
pub const MAX_SPEC_SERIES_CAP: usize = 1 << 16;

impl ShardSpec {
    /// Range-check a spec (enforced on wire decode; see the
    /// `MAX_SPEC_*` bounds).  Returns the offending field on failure.
    pub fn validate(&self) -> Result<(), String> {
        let check = |name: &str, v: usize, lo: usize, hi: usize| {
            if v < lo || v > hi {
                Err(format!("spec {name} {v} out of range {lo}..={hi}"))
            } else {
                Ok(())
            }
        };
        check("seq", self.seq, 1, MAX_SPEC_SEQ)?;
        check("tasks", self.tasks, 0, MAX_SPEC_TASKS)?;
        check("threads", self.threads, 0, MAX_SPEC_THREADS)?;
        check("max_batch", self.serve.max_batch, 0, MAX_SPEC_BATCH)?;
        check("prefix_block", self.serve.prefix_block, 0, MAX_SPEC_BATCH)?;
        check("cache_bytes", self.serve.cache_bytes, 0, MAX_SPEC_BYTES)?;
        check("registry_bytes", self.serve.registry_bytes, 0, MAX_SPEC_BYTES)?;
        if self.heartbeat_ms > MAX_SPEC_CADENCE_MS {
            return Err(format!("spec heartbeat_ms {} out of range 0..={MAX_SPEC_CADENCE_MS}", self.heartbeat_ms));
        }
        if self.series_ms > MAX_SPEC_CADENCE_MS {
            return Err(format!("spec series_ms {} out of range 0..={MAX_SPEC_CADENCE_MS}", self.series_ms));
        }
        check("series_cap", self.series_cap, 0, MAX_SPEC_SERIES_CAP)?;
        Ok(())
    }
}

/// Control + data messages into one shard (thread inbox or socket frame).
#[derive(Clone, Debug, PartialEq)]
pub enum ShardMsg {
    /// first frame on a socket connection: build the server replica
    /// (in-proc shards are constructed directly and never see this)
    Configure { shard: usize, spec: ShardSpec },
    Submit(Request),
    /// drain everything pending, emit the results, then emit `FlushAck`
    Flush,
    /// snapshot serving stats + cache/engine counters into a `Report` event
    Report,
    /// push a task artifact (a `store::artifact` blob) to the shard and
    /// hot-register it in the shard's side-network registry without a
    /// restart.  The shard answers with a [`ShardEvent::DeployAck`]
    /// carrying the content fingerprint it computed (so the gateway can
    /// verify every replica registered identical bytes).  Strictly
    /// opt-in: only `Gateway::deploy` emits the tag, so peers that
    /// predate it never see a frame they cannot decode.
    Deploy { task: String, artifact: Vec<u8> },
    /// drain, emit, and exit the shard
    Shutdown,
}

/// Upper bound on a `Deploy` artifact payload (16 MiB) — far above any
/// side network this repo serves, far below the 64 MiB frame cap, and
/// enforced on decode *before* allocation so a hostile length cannot
/// balloon memory.
pub const MAX_DEPLOY_ARTIFACT: usize = 1 << 24;

/// Events out of a shard.  One stream carries everything, in per-shard
/// FIFO order — which is what makes flush a transport-independent
/// barrier: a shard's `FlushAck` provably follows every outcome of work
/// submitted before the flush.
#[derive(Clone, Debug, PartialEq)]
pub enum ShardEvent {
    Done(GatewayResponse),
    /// requests dropped inside a failing micro-batch (count only; the
    /// server logs the cause)
    Dropped { shard: usize, n: usize },
    /// a submit the shard's server refused — belt-and-braces: the gateway
    /// validates task and length before routing, so this signals a bug or
    /// a mid-flight deregistration rather than routine traffic
    Rejected { shard: usize, id: u64, err: String },
    /// everything submitted before the matching `Flush` has been resolved
    FlushAck { shard: usize },
    Report(ShardReport),
    /// a batch of lifecycle spans drained from a traced worker's recorder
    /// (socket workers only — in-proc shards share the gateway's rings).
    /// Pure telemetry: credit-neutral for backpressure accounting and
    /// never acts as a barrier.
    Telemetry(TelemetryBatch),
    /// periodic liveness beacon from a heartbeat-armed shard (spec
    /// `heartbeat_ms > 0`), emitted even while idle.  Pure telemetry:
    /// credit-neutral, never a barrier.  Strictly opt-in — a gateway
    /// that never sets `heartbeat_ms` never receives one, so peers that
    /// predate the tag still interoperate.
    Heartbeat(Heartbeat),
    /// response to a [`ShardMsg::Deploy`]: the artifact's content
    /// fingerprint as this shard computed it, or a non-empty `err` if
    /// storing/registering failed.  Credit-neutral (control traffic,
    /// not request outcomes) and only ever sent in response to a
    /// `Deploy`, so legacy gateways never see the tag.
    DeployAck { shard: usize, task: String, digest: u64, err: String },
}

/// The cheap health snapshot a heartbeat carries.  Everything here is a
/// counter/gauge the shard already maintains — sampling reads no
/// request data, so heartbeats cannot perturb results (pinned by the
/// bench parity gate, which runs its traced replay heartbeat-armed).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Heartbeat {
    pub shard: usize,
    /// requests accepted but not yet drained
    pub queue_depth: u64,
    /// requests occupying continuous-batching micro-batch slots
    pub inflight_slots: u64,
    /// spans lost to recorder ring overwrite (cumulative)
    pub spans_dropped: u64,
    /// resident hidden-state cache bytes
    pub cache_bytes: u64,
}

/// Spans drained from one worker's recorder, shipped alongside a
/// `Report`/shutdown.  Carries its own inner schema version on the wire
/// (see [`frame`]) so the span layout can evolve without a protocol bump.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TelemetryBatch {
    pub shard: usize,
    /// spans lost to ring overwrite since the last drain
    pub dropped: u64,
    pub spans: Vec<crate::obs::Span>,
}

/// Counters snapshot one shard ships to the aggregator.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ShardReport {
    pub shard: usize,
    pub stats: StatsSnapshot,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub prefix_hits: u64,
    pub cache_evictions: u64,
    pub cache_entries: usize,
    pub cache_bytes: usize,
    pub backbone_rows: u64,
    pub resumed_rows: u64,
    pub resumed_positions: u64,
    pub backbone_resident_bytes: usize,
    pub registry_bytes: usize,
    /// requests accepted by the shard but not yet drained, at report time
    pub queue_depth: u64,
    /// largest micro-batch of in-flight requests the shard has assembled
    pub inflight_peak: u64,
    /// micro-batch soaks that filled to the batch cap — the shard's
    /// saturation signal
    pub full_soaks: u64,
    /// requests occupying micro-batch slots (admitted into the shard's
    /// continuous-batching pool, not yet served), at report time
    pub inflight_slots: u64,
    /// spans lost to recorder ring overwrite on this shard (cumulative;
    /// wire tail — absent ⇒ 0)
    pub spans_dropped: u64,
    /// the shard's gauge flight-recorder series (chronological; empty
    /// when the recorder is disarmed; wire tail)
    pub series: Vec<crate::obs::series::GaugePoint>,
    /// side networks evicted from the shard's registry under byte
    /// pressure (cumulative; registry-churn wire tail — absent ⇒ 0)
    pub registry_evictions: u64,
    /// distribution of cold side-network load latencies (registration +
    /// post-eviction swap-ins), merged exactly fleet-wide like the
    /// request-latency histogram (registry-churn wire tail)
    pub swap_hist: crate::obs::LogHistogram,
}

/// Why a gateway submit was refused.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// the routed shard's inbox/credit window is at capacity — collect
    /// responses and retry; bounded queues reject, they never deadlock
    Backpressure { shard: usize },
    /// malformed request (unknown task or over-length prompt)
    Invalid(String),
    /// the routed shard's thread or connection is gone
    ShardDown { shard: usize },
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::Backpressure { shard } => {
                write!(f, "shard {shard} inbox full (backpressure — retry after collecting)")
            }
            SubmitError::Invalid(msg) => write!(f, "{msg}"),
            SubmitError::ShardDown { shard } => write!(f, "shard {shard} is down"),
        }
    }
}

impl std::error::Error for SubmitError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn submit_error_displays() {
        assert!(format!("{}", SubmitError::Backpressure { shard: 3 }).contains("shard 3"));
        assert!(format!("{}", SubmitError::Invalid("nope".into())).contains("nope"));
        assert!(format!("{}", SubmitError::ShardDown { shard: 1 }).contains("down"));
    }

    #[test]
    fn submit_error_composes_with_anyhow_context() {
        use anyhow::Context;
        let r: Result<(), SubmitError> = Err(SubmitError::ShardDown { shard: 2 });
        let e = r.context("gateway refused a bench request").unwrap_err();
        assert_eq!(format!("{e:#}"), "gateway refused a bench request: shard 2 is down");
    }
}
