//! Length-prefixed binary framing for the QST wire protocol.
//!
//! Every message travels as one frame:
//!
//! ```text
//!   offset  size  field
//!   0       4     magic  "QSTW"
//!   4       2     protocol version (u16 LE) — this build speaks VERSION
//!   6       1     message tag (request tags 1–6, event tags 16–23)
//!   7       4     payload length (u32 LE), capped at MAX_PAYLOAD
//!   11      n     payload (message-specific, see [`super::wire`])
//! ```
//!
//! # Payload evolution without a version bump
//!
//! Fields added after v1 shipped (the spec's `trace` flag, the report's
//! histogram/stride/queue-gauge tail) are appended at the **end** of
//! their payload, where [`Dec::remaining`] is unambiguous: a decoder
//! reads them iff bytes remain, and treats absence as defaults.  Old
//! frames decode on new builds (defaults), and old builds reject new
//! frames with a typed trailing-bytes `Malformed` — never a panic.  The
//! `Telemetry` event instead carries its own inner schema version, since
//! its span array must be able to change layout, not just grow a tail.
//!
//! Decoding **never panics**: bad magic, an unknown version, an unknown
//! tag, a truncated buffer/stream, an over-cap length, or a structurally
//! invalid payload all come back as typed [`DecodeError`]s (pinned by the
//! `tests/proto.rs` property suite).  The version field is checked before
//! the tag, so a frame from a future protocol revision is rejected as
//! [`DecodeError::BadVersion`] rather than misparsed.
//!
//! The streaming readers ([`read_msg`] / [`read_event`]) distinguish a
//! *clean* EOF (the peer closed between frames → `Ok(None)`) from a
//! connection dropped mid-frame (→ [`DecodeError::Truncated`]).

use std::io::Read;

use anyhow::{Context, Result};

use crate::obs::hist::HIST_BUCKETS;
use crate::obs::series::GaugePoint;
use crate::obs::{LogHistogram, Span, SpanKind};
use crate::serve::{Response, StatsSnapshot, TaskStat};

use super::wire::{Dec, DecodeError, Enc};
use super::{
    GatewayResponse, Heartbeat, Request, ShardEvent, ShardMsg, ShardReport, ShardSpec,
    TelemetryBatch, MAX_DEPLOY_ARTIFACT,
};

/// Frame magic: the first four bytes of every frame.
pub const MAGIC: [u8; 4] = *b"QSTW";
/// Protocol version this build encodes and accepts.
pub const VERSION: u16 = 1;
/// Bytes of frame header before the payload.
pub const HEADER_LEN: usize = 11;
/// Hard cap on a single frame's payload (the largest honest frame — a
/// shard report with a full 64Ki latency reservoir — is ~0.5 MiB).
pub const MAX_PAYLOAD: usize = 1 << 26;

// Gateway → shard message tags.
const TAG_CONFIGURE: u8 = 1;
const TAG_SUBMIT: u8 = 2;
const TAG_FLUSH: u8 = 3;
const TAG_REPORT: u8 = 4;
const TAG_SHUTDOWN: u8 = 5;
const TAG_DEPLOY: u8 = 6;
// Shard → gateway event tags.
const TAG_DONE: u8 = 16;
const TAG_DROPPED: u8 = 17;
const TAG_REJECTED: u8 = 18;
const TAG_FLUSH_ACK: u8 = 19;
const TAG_REPORT_REPLY: u8 = 20;
const TAG_TELEMETRY: u8 = 21;
const TAG_HEARTBEAT: u8 = 22;
const TAG_DEPLOY_ACK: u8 = 23;

/// Inner schema version of the `Telemetry` payload — the span layout can
/// evolve without bumping the whole protocol.  A mismatch is a typed
/// `Malformed`, never a panic.
pub const TELEMETRY_VERSION: u16 = 1;
/// Encoded bytes per span (kind u8, id u64, start_ns u64, dur_ns u64,
/// tid u32) — the allocation guard for the declared span count.
const SPAN_BYTES: usize = 1 + 8 + 8 + 8 + 4;

/// Start a frame: header with the length field zeroed, payload appended
/// by the caller, length patched by [`seal_frame`].  One buffer, no
/// payload copy — encode runs per Submit/Done on the socket hot path.
fn new_frame(tag: u8) -> Enc {
    let mut e = Enc::new();
    e.raw(&MAGIC);
    e.u16(VERSION);
    e.u8(tag);
    e.u32(0); // payload length, patched in seal_frame
    e
}

fn seal_frame(e: Enc) -> Vec<u8> {
    let mut buf = e.into_bytes();
    let len = buf.len() - HEADER_LEN;
    debug_assert!(len <= MAX_PAYLOAD, "frame payload over cap");
    buf[7..11].copy_from_slice(&(len as u32).to_le_bytes());
    buf
}

/// Validate a frame header; returns `(tag, payload_len)`.
pub fn parse_header(h: &[u8]) -> Result<(u8, usize), DecodeError> {
    if h.len() < HEADER_LEN {
        return Err(DecodeError::Truncated { what: "frame header" });
    }
    if h[0..4] != MAGIC {
        return Err(DecodeError::BadMagic([h[0], h[1], h[2], h[3]]));
    }
    let version = u16::from_le_bytes([h[4], h[5]]);
    if version != VERSION {
        return Err(DecodeError::BadVersion { got: version, want: VERSION });
    }
    let len = u32::from_le_bytes([h[7], h[8], h[9], h[10]]) as usize;
    if len > MAX_PAYLOAD {
        return Err(DecodeError::Oversize { len, max: MAX_PAYLOAD });
    }
    Ok((h[6], len))
}

/// Split one complete frame buffer into `(tag, payload)`.
pub fn split_frame(bytes: &[u8]) -> Result<(u8, &[u8]), DecodeError> {
    let (tag, len) = parse_header(bytes)?;
    let body = &bytes[HEADER_LEN..];
    if body.len() < len {
        return Err(DecodeError::Truncated { what: "frame payload" });
    }
    if body.len() > len {
        return Err(DecodeError::Malformed(format!(
            "{} trailing byte(s) after the frame payload",
            body.len() - len
        )));
    }
    Ok((tag, body))
}

fn enc_request(e: &mut Enc, r: &Request) {
    e.u64(r.id);
    e.str_(&r.task);
    e.vec_i32(&r.tokens);
}

fn dec_request(d: &mut Dec) -> Result<Request, DecodeError> {
    Ok(Request {
        id: d.u64("request id")?,
        task: d.str_("request task")?,
        tokens: d.vec_i32("request tokens")?,
    })
}

fn enc_response(e: &mut Enc, r: &Response) {
    e.u64(r.id);
    e.str_(&r.task);
    e.vec_f32(&r.logits);
    e.bool(r.cache_hit);
}

fn dec_response(d: &mut Dec) -> Result<Response, DecodeError> {
    Ok(Response {
        id: d.u64("response id")?,
        task: d.str_("response task")?,
        logits: d.vec_f32("response logits")?,
        cache_hit: d.bool("response cache_hit")?,
    })
}

fn enc_spec(e: &mut Enc, s: &ShardSpec) {
    e.str_(s.preset.name());
    e.str_(s.backbone.name());
    e.u64(s.seed);
    e.u64(s.seq as u64);
    e.u64(s.tasks as u64);
    e.u64(s.threads as u64);
    e.u64(s.serve.cache_bytes as u64);
    e.u64(s.serve.registry_bytes as u64);
    e.u64(s.serve.max_batch as u64);
    e.u64(s.serve.prefix_block as u64);
    // tail field (see the module docs): absent on old frames ⇒ false
    e.bool(s.trace);
    // health-plane tail (ships after the trace tail; decoders gate on
    // remaining() a second time): heartbeat + flight-recorder cadences
    e.u64(s.heartbeat_ms);
    e.u64(s.series_ms);
    e.u64(s.series_cap as u64);
}

fn dec_spec(d: &mut Dec) -> Result<ShardSpec, DecodeError> {
    let preset_name = d.str_("spec preset")?;
    let preset = crate::serve::EnginePreset::parse(&preset_name)
        .map_err(|_| DecodeError::Malformed(format!("unknown preset '{preset_name}'")))?;
    let backbone_name = d.str_("spec backbone")?;
    let backbone = crate::serve::BackboneKind::parse(&backbone_name)
        .map_err(|_| DecodeError::Malformed(format!("unknown backbone '{backbone_name}'")))?;
    let mut spec = ShardSpec {
        preset,
        backbone,
        seed: d.u64("spec seed")?,
        seq: d.usize_("spec seq")?,
        tasks: d.usize_("spec tasks")?,
        threads: d.usize_("spec threads")?,
        serve: crate::serve::ServeConfig {
            cache_bytes: d.usize_("spec cache_bytes")?,
            registry_bytes: d.usize_("spec registry_bytes")?,
            max_batch: d.usize_("spec max_batch")?,
            prefix_block: d.usize_("spec prefix_block")?,
        },
        // tail field: a frame from before the flag existed ends here
        trace: if d.remaining() > 0 { d.bool("spec trace")? } else { false },
        heartbeat_ms: 0,
        series_ms: 0,
        series_cap: 0,
    };
    // health-plane tail: a frame from before the cadences existed ends
    // at the trace flag — absent ⇒ disarmed (all zero)
    if d.remaining() > 0 {
        spec.heartbeat_ms = d.u64("spec heartbeat_ms")?;
        spec.series_ms = d.u64("spec series_ms")?;
        spec.series_cap = d.usize_("spec series_cap")?;
    }
    // a worker builds an engine straight from this, so an untrusted but
    // well-formed frame must not panic it or drive unbounded allocation
    spec.validate().map_err(DecodeError::Malformed)?;
    Ok(spec)
}

fn enc_snapshot(e: &mut Enc, s: &StatsSnapshot) {
    e.u64(s.requests);
    e.u64(s.batches);
    e.u64(s.tokens);
    e.u64(s.dropped);
    e.u64(s.prefix_resumes);
    e.f64(s.busy_secs);
    e.vec_f64(&s.lat);
}

fn dec_snapshot(d: &mut Dec) -> Result<StatsSnapshot, DecodeError> {
    Ok(StatsSnapshot {
        requests: d.u64("stats requests")?,
        batches: d.u64("stats batches")?,
        tokens: d.u64("stats tokens")?,
        dropped: d.u64("stats dropped")?,
        prefix_resumes: d.u64("stats prefix_resumes")?,
        busy_secs: d.f64("stats busy_secs")?,
        lat: d.vec_f64("stats latency reservoir")?,
        // the snapshot is nested mid-report, so its stride/histogram ride
        // the *report's* tail (where `remaining()` is unambiguous) and are
        // patched into these defaults by `dec_report`
        ..StatsSnapshot::default()
    })
}

fn enc_report(e: &mut Enc, r: &ShardReport) {
    e.u64(r.shard as u64);
    enc_snapshot(e, &r.stats);
    e.u64(r.cache_hits);
    e.u64(r.cache_misses);
    e.u64(r.prefix_hits);
    e.u64(r.cache_evictions);
    e.u64(r.cache_entries as u64);
    e.u64(r.cache_bytes as u64);
    e.u64(r.backbone_rows);
    e.u64(r.resumed_rows);
    e.u64(r.resumed_positions);
    e.u64(r.backbone_resident_bytes as u64);
    e.u64(r.registry_bytes as u64);
    // tail fields (see the module docs): reservoir stride, the exact
    // latency histogram (trailing zero buckets trimmed), queue gauges
    e.u64(r.stats.lat_stride.max(1));
    e.u64(r.stats.hist.count());
    e.f64(r.stats.hist.sum());
    e.f64(r.stats.hist.min());
    e.f64(r.stats.hist.max());
    e.vec_u64(&r.stats.hist.counts()[..r.stats.hist.trimmed_len()]);
    e.u64(r.queue_depth);
    e.u64(r.inflight_peak);
    e.u64(r.full_soaks);
    // continuous-batching tail (this block's fields ship after the PR 6
    // tail, so decoders gate on remaining() a second time)
    e.vec_f64(&r.stats.qlat);
    e.u64(r.stats.qlat_stride.max(1));
    e.u64(r.inflight_slots);
    // health-plane tail (third tail block): span-drop accounting, the
    // per-task ledger, and the gauge flight-recorder series
    e.u64(r.spans_dropped);
    e.u32(r.stats.tasks.len() as u32);
    for t in &r.stats.tasks {
        e.str_(&t.task);
        e.u64(t.requests);
        e.u64(t.tokens);
        e.u64(t.cache_hits);
        e.u64(t.swap_ins);
    }
    e.u32(r.series.len() as u32);
    for p in &r.series {
        e.u64(p.t_ms);
        e.u64(p.queue_depth);
        e.u64(p.inflight_slots);
        e.u64(p.cache_bytes);
        e.u64(p.registry_bytes);
        e.u64(p.requests);
    }
    // registry-churn tail (fourth tail block): eviction counter and the
    // swap-in latency histogram, same trimmed-bucket wire shape as the
    // request-latency histogram above
    e.u64(r.registry_evictions);
    e.u64(r.swap_hist.count());
    e.f64(r.swap_hist.sum());
    e.f64(r.swap_hist.min());
    e.f64(r.swap_hist.max());
    e.vec_u64(&r.swap_hist.counts()[..r.swap_hist.trimmed_len()]);
}

/// Minimum encoded bytes per task-ledger entry (empty name: u32 length
/// prefix + 4 counters) — the allocation guard for the declared count.
const TASK_MIN_BYTES: usize = 4 + 8 * 4;
/// Encoded bytes per flight-recorder gauge point (6 × u64).
const POINT_BYTES: usize = 8 * 6;

fn dec_report(d: &mut Dec) -> Result<ShardReport, DecodeError> {
    let mut r = ShardReport {
        shard: d.usize_("report shard")?,
        stats: dec_snapshot(d)?,
        cache_hits: d.u64("report cache_hits")?,
        cache_misses: d.u64("report cache_misses")?,
        prefix_hits: d.u64("report prefix_hits")?,
        cache_evictions: d.u64("report cache_evictions")?,
        cache_entries: d.usize_("report cache_entries")?,
        cache_bytes: d.usize_("report cache_bytes")?,
        backbone_rows: d.u64("report backbone_rows")?,
        resumed_rows: d.u64("report resumed_rows")?,
        resumed_positions: d.u64("report resumed_positions")?,
        backbone_resident_bytes: d.usize_("report backbone_resident_bytes")?,
        registry_bytes: d.usize_("report registry_bytes")?,
        queue_depth: 0,
        inflight_peak: 0,
        full_soaks: 0,
        inflight_slots: 0,
        spans_dropped: 0,
        series: Vec::new(),
        registry_evictions: 0,
        swap_hist: LogHistogram::default(),
    };
    // a frame from before the tail fields existed ends here
    if d.remaining() > 0 {
        r.stats.lat_stride = d.u64("report lat_stride")?.max(1);
        let count = d.u64("report hist count")?;
        let sum = d.f64("report hist sum")?;
        let min = d.f64("report hist min")?;
        let max = d.f64("report hist max")?;
        let counts = d.vec_u64("report hist buckets")?;
        if counts.len() > HIST_BUCKETS {
            return Err(DecodeError::Malformed(format!(
                "report histogram has {} buckets (this build has {HIST_BUCKETS})",
                counts.len()
            )));
        }
        r.stats.hist = LogHistogram::from_parts(counts, count, sum, min, max);
        r.queue_depth = d.u64("report queue_depth")?;
        r.inflight_peak = d.u64("report inflight_peak")?;
        r.full_soaks = d.u64("report full_soaks")?;
        // a frame from before the continuous-batching tail ends here
        if d.remaining() > 0 {
            r.stats.qlat = d.vec_f64("report queue-wait reservoir")?;
            r.stats.qlat_stride = d.u64("report qlat_stride")?.max(1);
            r.inflight_slots = d.u64("report inflight_slots")?;
            // a frame from before the health-plane tail ends here
            if d.remaining() > 0 {
                r.spans_dropped = d.u64("report spans_dropped")?;
                let n = d.u32("report task count")? as usize;
                if n > d.remaining() / TASK_MIN_BYTES {
                    return Err(DecodeError::Truncated { what: "report task ledger" });
                }
                let mut tasks = Vec::with_capacity(n);
                for _ in 0..n {
                    tasks.push(TaskStat {
                        task: d.str_("task name")?,
                        requests: d.u64("task requests")?,
                        tokens: d.u64("task tokens")?,
                        cache_hits: d.u64("task cache_hits")?,
                        swap_ins: d.u64("task swap_ins")?,
                    });
                }
                r.stats.tasks = tasks;
                let n = d.u32("report series count")? as usize;
                if n > d.remaining() / POINT_BYTES {
                    return Err(DecodeError::Truncated { what: "report gauge series" });
                }
                let mut series = Vec::with_capacity(n);
                for _ in 0..n {
                    series.push(GaugePoint {
                        t_ms: d.u64("point t_ms")?,
                        queue_depth: d.u64("point queue_depth")?,
                        inflight_slots: d.u64("point inflight_slots")?,
                        cache_bytes: d.u64("point cache_bytes")?,
                        registry_bytes: d.u64("point registry_bytes")?,
                        requests: d.u64("point requests")?,
                    });
                }
                r.series = series;
                // a frame from before the registry-churn tail ends here
                if d.remaining() > 0 {
                    r.registry_evictions = d.u64("report registry_evictions")?;
                    let count = d.u64("report swap hist count")?;
                    let sum = d.f64("report swap hist sum")?;
                    let min = d.f64("report swap hist min")?;
                    let max = d.f64("report swap hist max")?;
                    let counts = d.vec_u64("report swap hist buckets")?;
                    if counts.len() > HIST_BUCKETS {
                        return Err(DecodeError::Malformed(format!(
                            "swap histogram has {} buckets (this build has {HIST_BUCKETS})",
                            counts.len()
                        )));
                    }
                    r.swap_hist = LogHistogram::from_parts(counts, count, sum, min, max);
                }
            }
        }
    }
    Ok(r)
}

fn msg_tag(m: &ShardMsg) -> u8 {
    match m {
        ShardMsg::Configure { .. } => TAG_CONFIGURE,
        ShardMsg::Submit(_) => TAG_SUBMIT,
        ShardMsg::Flush => TAG_FLUSH,
        ShardMsg::Report => TAG_REPORT,
        ShardMsg::Shutdown => TAG_SHUTDOWN,
        ShardMsg::Deploy { .. } => TAG_DEPLOY,
    }
}

/// Encode one gateway→shard message as a complete frame.
pub fn encode_msg(m: &ShardMsg) -> Vec<u8> {
    let mut e = new_frame(msg_tag(m));
    match m {
        ShardMsg::Configure { shard, spec } => {
            e.u64(*shard as u64);
            enc_spec(&mut e, spec);
        }
        ShardMsg::Submit(r) => enc_request(&mut e, r),
        ShardMsg::Flush | ShardMsg::Report | ShardMsg::Shutdown => {}
        ShardMsg::Deploy { task, artifact } => {
            e.str_(task);
            e.u32(artifact.len() as u32);
            e.raw(artifact);
        }
    }
    seal_frame(e)
}

/// Decode a gateway→shard message payload for a known-good header tag.
pub fn decode_msg_payload(tag: u8, payload: &[u8]) -> Result<ShardMsg, DecodeError> {
    let mut d = Dec::new(payload);
    let m = match tag {
        TAG_CONFIGURE => ShardMsg::Configure { shard: d.usize_("configure shard")?, spec: dec_spec(&mut d)? },
        TAG_SUBMIT => ShardMsg::Submit(dec_request(&mut d)?),
        TAG_FLUSH => ShardMsg::Flush,
        TAG_REPORT => ShardMsg::Report,
        TAG_SHUTDOWN => ShardMsg::Shutdown,
        TAG_DEPLOY => {
            let task = d.str_("deploy task")?;
            let len = d.u32("deploy artifact length")? as usize;
            // the artifact cap is tighter than MAX_PAYLOAD: reject an
            // over-cap declared length before any allocation happens
            if len > MAX_DEPLOY_ARTIFACT {
                return Err(DecodeError::Oversize { len, max: MAX_DEPLOY_ARTIFACT });
            }
            let artifact = d.bytes_(len, "deploy artifact")?;
            ShardMsg::Deploy { task, artifact }
        }
        other => return Err(DecodeError::BadTag(other)),
    };
    d.finish("message payload")?;
    Ok(m)
}

/// Decode one complete gateway→shard frame buffer.
pub fn decode_msg(bytes: &[u8]) -> Result<ShardMsg, DecodeError> {
    let (tag, payload) = split_frame(bytes)?;
    decode_msg_payload(tag, payload)
}

fn event_tag(ev: &ShardEvent) -> u8 {
    match ev {
        ShardEvent::Done(_) => TAG_DONE,
        ShardEvent::Dropped { .. } => TAG_DROPPED,
        ShardEvent::Rejected { .. } => TAG_REJECTED,
        ShardEvent::FlushAck { .. } => TAG_FLUSH_ACK,
        ShardEvent::Report(_) => TAG_REPORT_REPLY,
        ShardEvent::Telemetry(_) => TAG_TELEMETRY,
        ShardEvent::Heartbeat(_) => TAG_HEARTBEAT,
        ShardEvent::DeployAck { .. } => TAG_DEPLOY_ACK,
    }
}

/// Encode one shard→gateway event as a complete frame.
pub fn encode_event(ev: &ShardEvent) -> Vec<u8> {
    let mut e = new_frame(event_tag(ev));
    match ev {
        ShardEvent::Done(gr) => {
            e.u64(gr.shard as u64);
            enc_response(&mut e, &gr.resp);
        }
        ShardEvent::Dropped { shard, n } => {
            e.u64(*shard as u64);
            e.u64(*n as u64);
        }
        ShardEvent::Rejected { shard, id, err } => {
            e.u64(*shard as u64);
            e.u64(*id);
            e.str_(err);
        }
        ShardEvent::FlushAck { shard } => e.u64(*shard as u64),
        ShardEvent::Report(r) => enc_report(&mut e, r),
        ShardEvent::Telemetry(t) => {
            e.u64(t.shard as u64);
            e.u16(TELEMETRY_VERSION);
            e.u64(t.dropped);
            e.u32(t.spans.len() as u32);
            for s in &t.spans {
                e.u8(s.kind as u8);
                e.u64(s.id);
                e.u64(s.start_ns);
                e.u64(s.dur_ns);
                e.u32(s.tid);
            }
        }
        ShardEvent::Heartbeat(hb) => {
            e.u64(hb.shard as u64);
            e.u64(hb.queue_depth);
            e.u64(hb.inflight_slots);
            e.u64(hb.spans_dropped);
            e.u64(hb.cache_bytes);
        }
        ShardEvent::DeployAck { shard, task, digest, err } => {
            e.u64(*shard as u64);
            e.str_(task);
            e.u64(*digest);
            e.str_(err);
        }
    }
    seal_frame(e)
}

/// Decode a shard→gateway event payload for a known-good header tag.
pub fn decode_event_payload(tag: u8, payload: &[u8]) -> Result<ShardEvent, DecodeError> {
    let mut d = Dec::new(payload);
    let ev = match tag {
        TAG_DONE => ShardEvent::Done(GatewayResponse {
            shard: d.usize_("done shard")?,
            resp: dec_response(&mut d)?,
        }),
        TAG_DROPPED => ShardEvent::Dropped { shard: d.usize_("dropped shard")?, n: d.usize_("dropped n")? },
        TAG_REJECTED => ShardEvent::Rejected {
            shard: d.usize_("rejected shard")?,
            id: d.u64("rejected id")?,
            err: d.str_("rejected err")?,
        },
        TAG_FLUSH_ACK => ShardEvent::FlushAck { shard: d.usize_("flush-ack shard")? },
        TAG_REPORT_REPLY => ShardEvent::Report(dec_report(&mut d)?),
        TAG_TELEMETRY => {
            let shard = d.usize_("telemetry shard")?;
            let version = d.u16("telemetry version")?;
            if version != TELEMETRY_VERSION {
                return Err(DecodeError::Malformed(format!(
                    "telemetry schema version {version} (this build speaks {TELEMETRY_VERSION})"
                )));
            }
            let dropped = d.u64("telemetry dropped")?;
            // validate the declared span count against the bytes actually
            // remaining before allocating (same guard as `Dec::vec_len`)
            let n = d.u32("telemetry span count")? as usize;
            if n > d.remaining() / SPAN_BYTES {
                return Err(DecodeError::Truncated { what: "telemetry spans" });
            }
            let mut spans = Vec::with_capacity(n);
            for _ in 0..n {
                let kind_byte = d.u8("span kind")?;
                let kind = SpanKind::from_u8(kind_byte).ok_or_else(|| {
                    DecodeError::Malformed(format!("unknown span kind {kind_byte}"))
                })?;
                spans.push(Span {
                    kind,
                    id: d.u64("span id")?,
                    start_ns: d.u64("span start_ns")?,
                    dur_ns: d.u64("span dur_ns")?,
                    tid: d.u32("span tid")?,
                });
            }
            ShardEvent::Telemetry(TelemetryBatch { shard, dropped, spans })
        }
        TAG_HEARTBEAT => ShardEvent::Heartbeat(Heartbeat {
            shard: d.usize_("heartbeat shard")?,
            queue_depth: d.u64("heartbeat queue_depth")?,
            inflight_slots: d.u64("heartbeat inflight_slots")?,
            spans_dropped: d.u64("heartbeat spans_dropped")?,
            cache_bytes: d.u64("heartbeat cache_bytes")?,
        }),
        TAG_DEPLOY_ACK => ShardEvent::DeployAck {
            shard: d.usize_("deploy-ack shard")?,
            task: d.str_("deploy-ack task")?,
            digest: d.u64("deploy-ack digest")?,
            err: d.str_("deploy-ack err")?,
        },
        other => return Err(DecodeError::BadTag(other)),
    };
    d.finish("event payload")?;
    Ok(ev)
}

/// Decode one complete shard→gateway frame buffer.
pub fn decode_event(bytes: &[u8]) -> Result<ShardEvent, DecodeError> {
    let (tag, payload) = split_frame(bytes)?;
    decode_event_payload(tag, payload)
}

/// Read until `buf` is full or EOF; returns the bytes actually read.
fn read_chunk(r: &mut impl Read, buf: &mut [u8]) -> std::io::Result<usize> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => break,
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(got)
}

/// Read one raw frame from a stream.  `Ok(None)` on clean EOF (the peer
/// closed *between* frames); mid-frame EOF is a typed truncation error.
fn read_frame(r: &mut impl Read) -> Result<Option<(u8, Vec<u8>)>> {
    let mut header = [0u8; HEADER_LEN];
    let got = read_chunk(r, &mut header).context("reading frame header")?;
    if got == 0 {
        return Ok(None);
    }
    if got < HEADER_LEN {
        return Err(DecodeError::Truncated { what: "frame header" }.into());
    }
    let (tag, len) = parse_header(&header)?;
    let mut payload = vec![0u8; len];
    let got = read_chunk(r, &mut payload).context("reading frame payload")?;
    if got < len {
        return Err(DecodeError::Truncated { what: "frame payload" }.into());
    }
    Ok(Some((tag, payload)))
}

/// Read one gateway→shard message from a stream (`Ok(None)` = clean EOF).
pub fn read_msg(r: &mut impl Read) -> Result<Option<ShardMsg>> {
    match read_frame(r)? {
        None => Ok(None),
        Some((tag, payload)) => Ok(Some(decode_msg_payload(tag, &payload)?)),
    }
}

/// Read one shard→gateway event from a stream (`Ok(None)` = clean EOF).
pub fn read_event(r: &mut impl Read) -> Result<Option<ShardEvent>> {
    match read_frame(r)? {
        None => Ok(None),
        Some((tag, payload)) => Ok(Some(decode_event_payload(tag, &payload)?)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{BackboneKind, EnginePreset, ServeConfig};

    fn spec() -> ShardSpec {
        ShardSpec {
            preset: EnginePreset::Small,
            backbone: BackboneKind::W4,
            seed: 11,
            seq: 24,
            tasks: 3,
            threads: 2,
            serve: ServeConfig { cache_bytes: 1 << 20, registry_bytes: 1 << 18, max_batch: 4, prefix_block: 8 },
            trace: true,
            heartbeat_ms: 50,
            series_ms: 10,
            series_cap: 128,
        }
    }

    #[test]
    fn all_msg_variants_round_trip() {
        let msgs = vec![
            ShardMsg::Configure { shard: 3, spec: spec() },
            ShardMsg::Submit(Request { id: 9, task: "task0".into(), tokens: vec![-1, 0, 7] }),
            ShardMsg::Flush,
            ShardMsg::Report,
            ShardMsg::Shutdown,
            ShardMsg::Deploy { task: "hot-task".into(), artifact: vec![0xAB; 257] },
            ShardMsg::Deploy { task: "empty".into(), artifact: Vec::new() },
        ];
        for m in msgs {
            let bytes = encode_msg(&m);
            assert_eq!(decode_msg(&bytes).unwrap(), m);
        }
    }

    #[test]
    fn all_event_variants_round_trip() {
        let events = vec![
            ShardEvent::Done(GatewayResponse {
                shard: 1,
                resp: Response {
                    id: 4,
                    task: "t".into(),
                    logits: vec![0.5, -2.0, f32::from_bits(0x7FC0_0001)],
                    cache_hit: true,
                },
            }),
            ShardEvent::Dropped { shard: 0, n: 0 },
            ShardEvent::Rejected { shard: 2, id: 17, err: "unknown task 'x'".into() },
            ShardEvent::FlushAck { shard: 5 },
            ShardEvent::Report(ShardReport::default()),
            ShardEvent::Report({
                let mut r = ShardReport {
                    shard: 2,
                    queue_depth: 7,
                    inflight_peak: 4,
                    full_soaks: 1,
                    inflight_slots: 3,
                    ..Default::default()
                };
                r.stats.lat = vec![0.01, 0.02];
                r.stats.lat_stride = 4;
                r.stats.qlat = vec![0.003];
                r.stats.qlat_stride = 2;
                r.stats.hist.record(0.01);
                r.stats.hist.record(0.02);
                r.spans_dropped = 5;
                r.stats.tasks = vec![
                    TaskStat { task: "task0".into(), requests: 9, tokens: 40, cache_hits: 3, swap_ins: 1 },
                    TaskStat { task: "task1".into(), requests: 2, tokens: 8, cache_hits: 0, swap_ins: 0 },
                ];
                r.series = vec![GaugePoint {
                    t_ms: 100,
                    queue_depth: 4,
                    inflight_slots: 2,
                    cache_bytes: 1 << 16,
                    registry_bytes: 1 << 12,
                    requests: 11,
                }];
                r.registry_evictions = 6;
                r.swap_hist.record(0.004);
                r.swap_hist.record(0.12);
                r
            }),
            ShardEvent::DeployAck { shard: 1, task: "hot-task".into(), digest: 0xDEAD_BEEF, err: String::new() },
            ShardEvent::DeployAck { shard: 0, task: "t".into(), digest: 0, err: "store full".into() },
            ShardEvent::Heartbeat(Heartbeat {
                shard: 4,
                queue_depth: 12,
                inflight_slots: 3,
                spans_dropped: 1,
                cache_bytes: 1 << 20,
            }),
            ShardEvent::Telemetry(TelemetryBatch { shard: 3, dropped: 0, spans: vec![] }),
            ShardEvent::Telemetry(TelemetryBatch {
                shard: 1,
                dropped: 12,
                spans: vec![
                    Span { kind: SpanKind::Backbone, id: 42, start_ns: 1_000, dur_ns: 2_500, tid: 0 },
                    Span { kind: SpanKind::ShardQueue, id: 43, start_ns: 900, dur_ns: 3_000, tid: 7 },
                ],
            }),
        ];
        for ev in events {
            let bytes = encode_event(&ev);
            let back = decode_event(&bytes).unwrap();
            // NaN logits defeat PartialEq, so compare bit patterns for Done
            match (&ev, &back) {
                (ShardEvent::Done(a), ShardEvent::Done(b)) => {
                    assert_eq!(a.shard, b.shard);
                    assert_eq!(a.resp.id, b.resp.id);
                    assert_eq!(a.resp.task, b.resp.task);
                    assert_eq!(a.resp.cache_hit, b.resp.cache_hit);
                    let ab: Vec<u32> = a.resp.logits.iter().map(|x| x.to_bits()).collect();
                    let bb: Vec<u32> = b.resp.logits.iter().map(|x| x.to_bits()).collect();
                    assert_eq!(ab, bb, "logits must round-trip bit-exactly");
                }
                _ => assert_eq!(ev, back),
            }
        }
    }

    #[test]
    fn header_rejections_are_typed() {
        let good = encode_msg(&ShardMsg::Flush);
        // magic
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        assert!(matches!(decode_msg(&bad).unwrap_err(), DecodeError::BadMagic(_)));
        // version
        let mut bad = good.clone();
        bad[4] = 99;
        assert_eq!(
            decode_msg(&bad).unwrap_err(),
            DecodeError::BadVersion { got: 99, want: VERSION }
        );
        // tag (an event tag is wrong-direction for decode_msg)
        let done = encode_event(&ShardEvent::FlushAck { shard: 0 });
        assert!(matches!(decode_msg(&done).unwrap_err(), DecodeError::BadTag(_)));
        // oversize length field, validated before any allocation
        let mut bad = good.clone();
        bad[7..11].copy_from_slice(&(MAX_PAYLOAD as u32 + 1).to_le_bytes());
        assert!(matches!(decode_msg(&bad).unwrap_err(), DecodeError::Oversize { .. }));
        // trailing junk
        let mut bad = good;
        bad.push(0);
        assert!(matches!(decode_msg(&bad).unwrap_err(), DecodeError::Malformed(_)));
    }

    #[test]
    fn streaming_reader_distinguishes_clean_eof_from_truncation() {
        let mut bytes = encode_msg(&ShardMsg::Submit(Request {
            id: 1,
            task: "task0".into(),
            tokens: vec![1, 2, 3],
        }));
        // two frames back to back, then EOF
        let second = encode_msg(&ShardMsg::Shutdown);
        bytes.extend_from_slice(&second);
        let mut cur = std::io::Cursor::new(bytes.clone());
        assert!(matches!(read_msg(&mut cur).unwrap(), Some(ShardMsg::Submit(_))));
        assert!(matches!(read_msg(&mut cur).unwrap(), Some(ShardMsg::Shutdown)));
        assert!(read_msg(&mut cur).unwrap().is_none(), "clean EOF is Ok(None)");
        // mid-frame EOF is an error, not a silent None
        let mut cur = std::io::Cursor::new(bytes[..bytes.len() - 3].to_vec());
        assert!(matches!(read_msg(&mut cur).unwrap(), Some(ShardMsg::Submit(_))));
        assert!(read_msg(&mut cur).is_err());
    }

    #[test]
    fn legacy_frames_without_tail_fields_still_decode() {
        // hand-encode the payloads a v1 peer from before the tail fields
        // emitted: its Report ends at registry_bytes, its spec at
        // prefix_block — both must decode to defaults, not error
        let mut e = new_frame(TAG_REPORT_REPLY);
        e.u64(3); // shard
        e.u64(10); // requests
        e.u64(2); // batches
        e.u64(40); // tokens
        e.u64(0); // dropped
        e.u64(1); // prefix_resumes
        e.f64(0.5); // busy_secs
        e.vec_f64(&[0.01, 0.02]); // reservoir
        for c in 1..=11u64 {
            e.u64(c); // the 11 legacy cache/engine counters
        }
        let ShardEvent::Report(r) = decode_event(&seal_frame(e)).unwrap() else {
            panic!("expected Report");
        };
        assert_eq!(r.shard, 3);
        assert_eq!(r.stats.requests, 10);
        assert_eq!(r.stats.lat, vec![0.01, 0.02]);
        assert_eq!(r.cache_hits, 1);
        assert_eq!(r.registry_bytes, 11);
        // absent tail ⇒ defaults
        assert_eq!(r.stats.lat_stride, 1);
        assert_eq!(r.stats.hist.count(), 0);
        assert_eq!((r.queue_depth, r.inflight_peak, r.full_soaks), (0, 0, 0));
        assert_eq!(r.stats.qlat, Vec::<f64>::new());
        assert_eq!(r.stats.qlat_stride, 1);
        assert_eq!(r.inflight_slots, 0);
        assert_eq!(r.spans_dropped, 0);
        assert!(r.stats.tasks.is_empty());
        assert!(r.series.is_empty());
        assert_eq!(r.registry_evictions, 0, "absent churn tail must decode as zero");
        assert_eq!(r.swap_hist.count(), 0);

        let mut e = new_frame(TAG_CONFIGURE);
        e.u64(0); // shard
        e.str_("small");
        e.str_("w4");
        e.u64(11); // seed
        e.u64(24); // seq
        e.u64(3); // tasks
        e.u64(2); // threads
        e.u64(1 << 20); // cache_bytes
        e.u64(1 << 18); // registry_bytes
        e.u64(4); // max_batch
        e.u64(8); // prefix_block
        let ShardMsg::Configure { spec, .. } = decode_msg(&seal_frame(e)).unwrap() else {
            panic!("expected Configure");
        };
        assert!(!spec.trace, "absent trace flag must decode as false");
        assert_eq!(spec.seq, 24);
        assert_eq!(spec.heartbeat_ms, 0, "absent heartbeat cadence must decode as disarmed");
        assert_eq!(spec.series_ms, 0);
        assert_eq!(spec.series_cap, 0);

        // a spec ending at the trace flag (pre-health-plane) also decodes
        let mut e = new_frame(TAG_CONFIGURE);
        e.u64(0);
        e.str_("small");
        e.str_("w4");
        e.u64(11);
        e.u64(24);
        e.u64(3);
        e.u64(2);
        e.u64(1 << 20);
        e.u64(1 << 18);
        e.u64(4);
        e.u64(8);
        e.bool(true); // trace tail present, cadence tail absent
        let ShardMsg::Configure { spec, .. } = decode_msg(&seal_frame(e)).unwrap() else {
            panic!("expected Configure");
        };
        assert!(spec.trace);
        assert_eq!(spec.heartbeat_ms, 0);
    }

    #[test]
    fn corrupt_report_tail_counts_cannot_balloon_allocation() {
        let mut r = ShardReport { shard: 0, ..Default::default() };
        r.spans_dropped = 1;
        let good = encode_event(&ShardEvent::Report(r));
        // the task count is the u32 right after spans_dropped; find it by
        // re-encoding with a poisoned count instead of byte surgery
        let mut e = new_frame(TAG_REPORT_REPLY);
        let payload = &good[HEADER_LEN..];
        // everything up to the health tail: the empty task count (4) and
        // series count (4) sit just before the 44-byte registry-churn
        // tail (evictions u64 + empty swap histogram: 4×8 + count u32)
        let head = &payload[..payload.len() - 8 - 44];
        e.raw(head);
        e.u32(u32::MAX); // task count with no bytes behind it
        e.u32(0);
        assert!(matches!(
            decode_event(&seal_frame(e)).unwrap_err(),
            DecodeError::Truncated { .. }
        ));
    }

    #[test]
    fn deploy_artifact_cap_is_enforced_before_allocation() {
        // hand-craft a Deploy whose declared artifact length is over the
        // 16 MiB cap while the frame itself is tiny: the decoder must
        // return Oversize from the length field alone, never allocate
        let mut e = new_frame(TAG_DEPLOY);
        e.str_("task0");
        e.u32((MAX_DEPLOY_ARTIFACT + 1) as u32);
        assert_eq!(
            decode_msg(&seal_frame(e)).unwrap_err(),
            DecodeError::Oversize { len: MAX_DEPLOY_ARTIFACT + 1, max: MAX_DEPLOY_ARTIFACT }
        );
        // an in-cap declared length with fewer bytes behind it is a
        // typed truncation, also before allocation
        let mut e = new_frame(TAG_DEPLOY);
        e.str_("task0");
        e.u32(1 << 20);
        e.raw(&[0u8; 16]);
        assert!(matches!(
            decode_msg(&seal_frame(e)).unwrap_err(),
            DecodeError::Truncated { .. }
        ));
    }

    #[test]
    fn telemetry_rejections_are_typed() {
        let batch = TelemetryBatch {
            shard: 0,
            dropped: 0,
            spans: vec![Span { kind: SpanKind::Admit, id: 1, start_ns: 2, dur_ns: 3, tid: 4 }],
        };
        let good = encode_event(&ShardEvent::Telemetry(batch));
        // future inner schema version → Malformed, not a panic
        let mut bad = good.clone();
        bad[HEADER_LEN + 8] = 99; // the inner version u16's low byte
        assert!(matches!(decode_event(&bad).unwrap_err(), DecodeError::Malformed(_)));
        // unknown span kind → Malformed
        let mut bad = good.clone();
        bad[HEADER_LEN + 8 + 2 + 8 + 4] = 200; // first span's kind byte
        assert!(matches!(decode_event(&bad).unwrap_err(), DecodeError::Malformed(_)));
        // a corrupt span count cannot balloon allocation
        let mut e = new_frame(TAG_TELEMETRY);
        e.u64(0);
        e.u16(TELEMETRY_VERSION);
        e.u64(0);
        e.u32(u32::MAX);
        assert!(matches!(
            decode_event(&seal_frame(e)).unwrap_err(),
            DecodeError::Truncated { .. }
        ));
    }

    #[test]
    fn bad_spec_names_are_malformed_not_panics() {
        let mut m = encode_msg(&ShardMsg::Configure { shard: 0, spec: spec() });
        // corrupt the preset string ("small" starts right after the
        // header + shard u64 + str length u32)
        let off = HEADER_LEN + 8 + 4;
        assert_eq!(&m[off..off + 5], b"small");
        m[off] = b'x';
        assert!(matches!(decode_msg(&m).unwrap_err(), DecodeError::Malformed(_)));
    }
}
