//! The pluggable gateway transport: one trait, two wire-ups.
//!
//! [`Transport`] is the seam between the gateway's routing/aggregation
//! logic and however its shards actually run.  The contract:
//!
//! * **submit** is non-blocking.  A shard that cannot accept more work
//!   surfaces [`SubmitError::Backpressure`] — the caller's signal to
//!   collect responses and retry.  Bounded queues reject; they never
//!   deadlock.
//! * **one event stream.** Everything a shard says — `Done` / `Dropped` /
//!   `Rejected` outcomes, `FlushAck`s, `Report`s — comes back through
//!   `recv`/`try_recv` in per-shard FIFO order.  Because a shard answers
//!   a `Flush` only after draining everything submitted before it, all
//!   pre-flush outcomes are guaranteed to precede that shard's ack in
//!   the stream; the gateway's barrier logic is transport-independent.
//! * **start_flush / start_report** broadcast the control message and
//!   return how many live shards were reached (the number of
//!   `FlushAck`/`Report` events to await).
//!
//! Implementations:
//!
//! * [`crate::gateway::transport::InProc`] — shard threads behind
//!   bounded `mpsc` inboxes (the PR 4 design, behavior-preserving).
//! * [`SocketTransport`] — shards as separate processes behind
//!   Unix-domain or TCP streams carrying [`super::frame`]d messages.
//!   Backpressure is credit-based: at most `window` requests may be
//!   outstanding (submitted, not yet resolved) per shard, so a slow
//!   worker back-pressures the gateway instead of ballooning kernel
//!   socket buffers.

use std::io::Write;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::frame;
use super::{Request, ShardEvent, ShardMsg, ShardSpec, SubmitError};

/// How long `recv` waits for the next shard event before concluding the
/// fleet is wedged (a live shard answers control messages in
/// milliseconds; a minute of silence means a worker died mid-request).
pub const EVENT_TIMEOUT: Duration = Duration::from_secs(60);

/// While blocked in `recv`, how often the transports re-check shard
/// liveness (thread/connection death) so a dead shard fails the caller
/// in tens of milliseconds instead of the full [`EVENT_TIMEOUT`].
pub const LIVENESS_POLL: Duration = Duration::from_millis(50);

/// Which transport a gateway (or bench pass) runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// shard threads in this process (bounded mpsc inboxes)
    InProc,
    /// shard processes behind framed unix/tcp sockets
    Socket,
}

impl TransportKind {
    pub fn parse(name: &str) -> Result<Self> {
        match name {
            "inproc" => Ok(TransportKind::InProc),
            "socket" => Ok(TransportKind::Socket),
            other => bail!("unknown transport '{other}' (expected 'inproc' or 'socket')"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TransportKind::InProc => "inproc",
            TransportKind::Socket => "socket",
        }
    }
}

/// The transport seam (see module docs for the contract).
pub trait Transport: Send {
    /// Number of shards this transport fans out to.
    fn shards(&self) -> usize;

    /// Non-blocking submit into shard `shard`'s inbox/window.
    fn submit(&mut self, shard: usize, req: Request) -> Result<(), SubmitError>;

    /// Next shard event if one is already available (`None` when the
    /// stream is momentarily empty *or* every shard is gone — liveness
    /// errors surface on the blocking [`Transport::recv`]).
    fn try_recv(&mut self) -> Option<ShardEvent>;

    /// Next shard event, blocking up to [`EVENT_TIMEOUT`]; errors when
    /// every shard is disconnected or the fleet goes silent.
    fn recv(&mut self) -> Result<ShardEvent>;

    /// Ask every live shard to drain and ack; returns how many were
    /// reached (== the number of `FlushAck` events to await).
    fn start_flush(&mut self) -> usize;

    /// Ask every live shard for a stats report; returns how many were
    /// reached (== the number of `Report` events to await).
    fn start_report(&mut self) -> usize;

    /// Push a task artifact to every live shard for hot registration;
    /// returns how many were reached (== the number of `DeployAck`
    /// events to await).
    fn start_deploy(&mut self, task: &str, artifact: &[u8]) -> usize;

    /// Stop every shard and release transport resources (idempotent).
    fn shutdown(&mut self) -> Result<()>;
}

/// The blocking-receive loop both transports share: wait on `events` up
/// to [`EVENT_TIMEOUT`], re-checking `dead_shard` every
/// [`LIVENESS_POLL`] — a dead shard (panicked thread, closed worker
/// connection) whose queue has drained can never produce the awaited
/// event, so the caller is failed in tens of milliseconds with the
/// reason `dead_shard` returns instead of sitting out the full timeout.
/// Keeping this in one place is what keeps the two transports' failure
/// behavior identical.
pub fn recv_event(
    events: &Receiver<ShardEvent>,
    timeout_hint: &str,
    mut dead_shard: impl FnMut() -> Option<String>,
) -> Result<ShardEvent> {
    let deadline = std::time::Instant::now() + EVENT_TIMEOUT;
    loop {
        match events.recv_timeout(LIVENESS_POLL) {
            Ok(ev) => return Ok(ev),
            Err(RecvTimeoutError::Timeout) => {
                if let Some(why) = dead_shard() {
                    bail!("{why}");
                }
                if std::time::Instant::now() >= deadline {
                    bail!("no shard events for {}s — {timeout_hint}", EVENT_TIMEOUT.as_secs());
                }
            }
            Err(RecvTimeoutError::Disconnected) => bail!("all shards disconnected"),
        }
    }
}

/// A connected byte stream the socket transport can frame messages over:
/// cloneable (one half per direction) and shutdown-able (so blocked
/// readers on both sides unblock at teardown).
pub trait Stream: std::io::Read + Write + Send {
    fn try_clone_stream(&self) -> std::io::Result<Box<dyn Stream>>;
    fn shutdown_both(&self) -> std::io::Result<()>;
}

impl Stream for std::net::TcpStream {
    fn try_clone_stream(&self) -> std::io::Result<Box<dyn Stream>> {
        Ok(Box::new(self.try_clone()?))
    }

    fn shutdown_both(&self) -> std::io::Result<()> {
        self.shutdown(std::net::Shutdown::Both)
    }
}

#[cfg(unix)]
impl Stream for std::os::unix::net::UnixStream {
    fn try_clone_stream(&self) -> std::io::Result<Box<dyn Stream>> {
        Ok(Box::new(self.try_clone()?))
    }

    fn shutdown_both(&self) -> std::io::Result<()> {
        self.shutdown(std::net::Shutdown::Both)
    }
}

/// A parsed wire address: `unix:<path>` or a TCP `<host>:<port>`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireAddr {
    Unix(String),
    Tcp(String),
}

pub fn parse_addr(addr: &str) -> WireAddr {
    match addr.strip_prefix("unix:") {
        Some(path) => WireAddr::Unix(path.to_string()),
        None => WireAddr::Tcp(addr.to_string()),
    }
}

#[cfg(unix)]
fn dial_unix(path: &str) -> std::io::Result<Box<dyn Stream>> {
    Ok(Box::new(std::os::unix::net::UnixStream::connect(path)?))
}

#[cfg(not(unix))]
fn dial_unix(_path: &str) -> std::io::Result<Box<dyn Stream>> {
    Err(std::io::Error::new(
        std::io::ErrorKind::Unsupported,
        "unix:<path> addresses need a unix platform",
    ))
}

/// Connect one stream to a worker address.
pub fn dial(addr: &str) -> std::io::Result<Box<dyn Stream>> {
    match parse_addr(addr) {
        WireAddr::Unix(path) => dial_unix(&path),
        WireAddr::Tcp(a) => {
            let s = std::net::TcpStream::connect(a)?;
            let _ = s.set_nodelay(true);
            Ok(Box::new(s))
        }
    }
}

/// [`dial`] with retries — `qst gateway --connect` is routinely started
/// moments before (or after) its `qst shard-worker`s finish binding.
pub fn dial_retry(addr: &str, attempts: usize, delay: Duration) -> std::io::Result<Box<dyn Stream>> {
    let attempts = attempts.max(1);
    let mut last = None;
    for attempt in 0..attempts {
        match dial(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                last = Some(e);
                if attempt + 1 < attempts {
                    std::thread::sleep(delay);
                }
            }
        }
    }
    Err(last.expect("at least one attempt"))
}

/// [`Transport`] over framed byte streams — one connected worker per
/// shard, credit-window backpressure, a reader thread per connection
/// draining events into one shared channel (so the sockets are always
/// being read and a busy gateway can never wedge against a busy worker).
pub struct SocketTransport {
    /// write halves, `None` once a connection is known dead
    writers: Vec<Option<Box<dyn Stream>>>,
    /// requests submitted to each shard and not yet resolved
    outstanding: Vec<usize>,
    /// per-shard cap on `outstanding` before `Backpressure`
    window: usize,
    events: Receiver<ShardEvent>,
    readers: Vec<JoinHandle<()>>,
}

impl SocketTransport {
    /// Take ownership of pre-connected streams (shard i = `streams[i]`),
    /// send each worker its `Configure` frame, and start the reader
    /// threads.  `window` is the per-shard backpressure credit.
    pub fn from_streams(
        streams: Vec<Box<dyn Stream>>,
        spec: &ShardSpec,
        window: usize,
    ) -> Result<SocketTransport> {
        // fail here, with the typed range error, rather than shipping a
        // Configure frame every worker will reject — otherwise a config
        // accepted in-proc surfaces over sockets only as opaque
        // "shard N is down" noise while the real error lands on the
        // workers' stderr
        if let Err(why) = spec.validate() {
            bail!("shard spec is not expressible on the wire: {why}");
        }
        let (tx, rx): (Sender<ShardEvent>, Receiver<ShardEvent>) = std::sync::mpsc::channel();
        let n = streams.len();
        let mut writers = Vec::with_capacity(n);
        let mut readers = Vec::with_capacity(n);
        for (i, stream) in streams.into_iter().enumerate() {
            let mut read_half =
                stream.try_clone_stream().with_context(|| format!("cloning shard {i} stream"))?;
            let mut write_half = stream;
            write_half
                .write_all(&frame::encode_msg(&ShardMsg::Configure { shard: i, spec: *spec }))
                .with_context(|| format!("sending Configure to shard worker {i}"))?;
            let tx = tx.clone();
            let join = std::thread::Builder::new()
                .name(format!("qst-gateway-conn-{i}"))
                .spawn(move || loop {
                    match frame::read_event(&mut read_half) {
                        Ok(Some(ev)) => {
                            if tx.send(ev).is_err() {
                                break; // transport dropped
                            }
                        }
                        Ok(None) => break, // worker closed cleanly
                        Err(e) => {
                            eprintln!("gateway: shard {i} connection error: {e:#}");
                            break;
                        }
                    }
                })
                .with_context(|| format!("spawning reader for shard {i}"))?;
            writers.push(Some(write_half));
            readers.push(join);
        }
        Ok(SocketTransport {
            writers,
            outstanding: vec![0; n],
            window: window.max(1),
            events: rx,
            readers,
        })
    }

    /// Dial a worker fleet (shard i = `addrs[i]`) and configure it.
    /// Each dial retries for a few seconds so gateway and workers can be
    /// started in any order.
    pub fn connect(addrs: &[String], spec: &ShardSpec, window: usize) -> Result<SocketTransport> {
        let mut streams = Vec::with_capacity(addrs.len());
        for a in addrs {
            streams.push(
                dial_retry(a, 100, Duration::from_millis(50))
                    .with_context(|| format!("connecting to shard worker at {a}"))?,
            );
        }
        Self::from_streams(streams, spec, window)
    }

    /// Credit accounting: every resolved request frees one slot.
    fn note(&mut self, ev: &ShardEvent) {
        match ev {
            ShardEvent::Done(gr) => {
                if let Some(o) = self.outstanding.get_mut(gr.shard) {
                    *o = o.saturating_sub(1);
                }
            }
            ShardEvent::Dropped { shard, n } => {
                if let Some(o) = self.outstanding.get_mut(*shard) {
                    *o = o.saturating_sub(*n);
                }
            }
            ShardEvent::Rejected { shard, .. } => {
                if let Some(o) = self.outstanding.get_mut(*shard) {
                    *o = o.saturating_sub(1);
                }
            }
            // control/telemetry/heartbeat events are credit-neutral:
            // they do not resolve a submitted request
            ShardEvent::FlushAck { .. }
            | ShardEvent::Report(_)
            | ShardEvent::Telemetry(_)
            | ShardEvent::Heartbeat(_)
            | ShardEvent::DeployAck { .. } => {}
        }
    }

    /// Broadcast a control message; returns how many live shards took it.
    fn broadcast(&mut self, msg: &ShardMsg) -> usize {
        let bytes = frame::encode_msg(msg);
        let mut reached = 0;
        for w in self.writers.iter_mut() {
            if let Some(s) = w.as_mut() {
                if s.write_all(&bytes).is_ok() {
                    reached += 1;
                } else {
                    *w = None;
                }
            }
        }
        reached
    }
}

impl Transport for SocketTransport {
    fn shards(&self) -> usize {
        self.writers.len()
    }

    fn submit(&mut self, shard: usize, req: Request) -> Result<(), SubmitError> {
        if self.writers.get(shard).map(|w| w.is_none()).unwrap_or(true) {
            return Err(SubmitError::ShardDown { shard });
        }
        if self.outstanding[shard] >= self.window {
            return Err(SubmitError::Backpressure { shard });
        }
        let bytes = frame::encode_msg(&ShardMsg::Submit(req));
        match self.writers[shard].as_mut().expect("checked live above").write_all(&bytes) {
            Ok(()) => {
                self.outstanding[shard] += 1;
                Ok(())
            }
            Err(_) => {
                self.writers[shard] = None;
                Err(SubmitError::ShardDown { shard })
            }
        }
    }

    fn try_recv(&mut self) -> Option<ShardEvent> {
        match self.events.try_recv() {
            Ok(ev) => {
                self.note(&ev);
                Some(ev)
            }
            Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => None,
        }
    }

    fn recv(&mut self) -> Result<ShardEvent> {
        // a dead worker's reader thread exits on EOF/bad frame; with the
        // event queue drained nothing more can arrive from it.  Only
        // *newly* discovered deaths fail the call (marking the writer
        // dead records the discovery), so one lost worker doesn't poison
        // every later barrier the healthy shards could still answer.
        let readers = &self.readers;
        let writers = &mut self.writers;
        let ev = recv_event(&self.events, "a worker likely died mid-request", move || {
            readers
                .iter()
                .enumerate()
                .find(|(i, r)| r.is_finished() && writers[*i].is_some())
                .map(|(i, _)| {
                    writers[i] = None;
                    format!("shard {i}'s worker connection closed while events were awaited")
                })
        })?;
        self.note(&ev);
        Ok(ev)
    }

    fn start_flush(&mut self) -> usize {
        self.broadcast(&ShardMsg::Flush)
    }

    fn start_report(&mut self) -> usize {
        self.broadcast(&ShardMsg::Report)
    }

    fn start_deploy(&mut self, task: &str, artifact: &[u8]) -> usize {
        self.broadcast(&ShardMsg::Deploy { task: task.to_string(), artifact: artifact.to_vec() })
    }

    fn shutdown(&mut self) -> Result<()> {
        self.broadcast(&ShardMsg::Shutdown);
        for w in self.writers.iter_mut() {
            if let Some(s) = w.as_ref() {
                // unblocks the worker's reader (FIN) and our own
                let _ = s.shutdown_both();
            }
            *w = None;
        }
        for j in self.readers.drain(..) {
            let _ = j.join();
        }
        Ok(())
    }
}

impl Drop for SocketTransport {
    fn drop(&mut self) {
        // best-effort: close connections so detached readers and workers
        // unblock even when shutdown() was never called (error paths)
        for w in self.writers.iter_mut() {
            if let Some(s) = w.as_ref() {
                let _ = s.shutdown_both();
            }
            *w = None;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_kind_parses_and_names() {
        assert_eq!(TransportKind::parse("inproc").unwrap(), TransportKind::InProc);
        assert_eq!(TransportKind::parse("socket").unwrap(), TransportKind::Socket);
        assert!(TransportKind::parse("carrier-pigeon").is_err());
        assert_eq!(TransportKind::Socket.name(), "socket");
    }

    #[test]
    fn addr_parsing_prefixes() {
        assert_eq!(parse_addr("unix:/tmp/s.sock"), WireAddr::Unix("/tmp/s.sock".into()));
        assert_eq!(parse_addr("127.0.0.1:7000"), WireAddr::Tcp("127.0.0.1:7000".into()));
    }

    #[test]
    fn dial_retry_reports_the_last_error() {
        // nothing listens here; retries must exhaust and surface an error
        let err = dial_retry("127.0.0.1:1", 2, Duration::from_millis(1));
        assert!(err.is_err());
    }
}
