//! Byte-level codec primitives for the wire protocol: a little-endian
//! writer/reader pair and the typed [`DecodeError`] every decode path
//! returns instead of panicking.
//!
//! Layout conventions (all little-endian):
//! * integers — fixed width (`u8`/`u16`/`u32`/`u64`); `usize` fields
//!   travel as `u64`
//! * floats — IEEE-754 bit patterns (`to_bits`/`from_bits`), so a value
//!   round-trips **bit-exactly**, NaN payloads included — the serving
//!   parity gates compare logits bit-for-bit across transports
//! * `bool` — one byte, `0` or `1`; anything else is [`DecodeError::Malformed`]
//! * strings — `u32` byte length + UTF-8 bytes
//! * vectors — `u32` element count + packed elements
//!
//! [`Dec`] is a bounds-checked cursor over a borrowed payload: every read
//! that would run past the end returns [`DecodeError::Truncated`], and
//! vector lengths are validated against the bytes actually remaining
//! *before* any allocation, so a corrupt length field cannot balloon
//! memory.

use std::fmt;

/// Why a frame or payload failed to decode.  Every variant is a typed,
/// non-panicking rejection; implements [`std::error::Error`] so call
/// sites compose with `anyhow::Context` instead of formatting by hand.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DecodeError {
    /// the first four bytes were not the protocol magic
    BadMagic([u8; 4]),
    /// the frame's protocol version is not the one this build speaks
    BadVersion { got: u16, want: u16 },
    /// unknown (or wrong-direction) message tag
    BadTag(u8),
    /// the buffer/stream ended before `what` was fully read
    Truncated { what: &'static str },
    /// the frame header declares a payload larger than the protocol cap
    Oversize { len: usize, max: usize },
    /// structurally invalid payload (bad UTF-8, bad bool, trailing bytes, …)
    Malformed(String),
}

impl fmt::Display for DecodeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DecodeError::BadMagic(got) => {
                write!(f, "bad frame magic {got:?} (expected {:?})", super::frame::MAGIC)
            }
            DecodeError::BadVersion { got, want } => {
                write!(f, "unsupported protocol version {got} (this build speaks {want})")
            }
            DecodeError::BadTag(tag) => write!(f, "unknown message tag {tag}"),
            DecodeError::Truncated { what } => write!(f, "truncated frame while reading {what}"),
            DecodeError::Oversize { len, max } => {
                write!(f, "frame payload of {len} bytes exceeds the {max}-byte cap")
            }
            DecodeError::Malformed(msg) => write!(f, "malformed payload: {msg}"),
        }
    }
}

impl std::error::Error for DecodeError {}

/// Little-endian byte writer backing [`super::frame`]'s encoders.
#[derive(Default)]
pub struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    pub fn new() -> Self {
        Enc { buf: Vec::new() }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn raw(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }

    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    pub fn bool(&mut self, v: bool) {
        self.u8(v as u8);
    }

    pub fn str_(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn vec_i32(&mut self, v: &[i32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.i32(x);
        }
    }

    pub fn vec_f32(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.f32(x);
        }
    }

    pub fn vec_f64(&mut self, v: &[f64]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.f64(x);
        }
    }

    pub fn vec_u64(&mut self, v: &[u64]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.u64(x);
        }
    }
}

/// Bounds-checked little-endian cursor over a borrowed payload.
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Dec { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize, what: &'static str) -> Result<&'a [u8], DecodeError> {
        if self.remaining() < n {
            return Err(DecodeError::Truncated { what });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self, what: &'static str) -> Result<u8, DecodeError> {
        Ok(self.take(1, what)?[0])
    }

    pub fn u16(&mut self, what: &'static str) -> Result<u16, DecodeError> {
        let b = self.take(2, what)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    pub fn u32(&mut self, what: &'static str) -> Result<u32, DecodeError> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self, what: &'static str) -> Result<u64, DecodeError> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    /// A `usize` field (encoded as `u64`); rejects values this platform
    /// cannot represent rather than wrapping.
    pub fn usize_(&mut self, what: &'static str) -> Result<usize, DecodeError> {
        let v = self.u64(what)?;
        usize::try_from(v).map_err(|_| DecodeError::Malformed(format!("{what} {v} overflows usize")))
    }

    pub fn i32(&mut self, what: &'static str) -> Result<i32, DecodeError> {
        let b = self.take(4, what)?;
        Ok(i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn f32(&mut self, what: &'static str) -> Result<f32, DecodeError> {
        Ok(f32::from_bits(self.u32(what)?))
    }

    pub fn f64(&mut self, what: &'static str) -> Result<f64, DecodeError> {
        Ok(f64::from_bits(self.u64(what)?))
    }

    pub fn bool(&mut self, what: &'static str) -> Result<bool, DecodeError> {
        match self.u8(what)? {
            0 => Ok(false),
            1 => Ok(true),
            other => Err(DecodeError::Malformed(format!("{what}: bool byte must be 0 or 1, got {other}"))),
        }
    }

    pub fn str_(&mut self, what: &'static str) -> Result<String, DecodeError> {
        let len = self.u32(what)? as usize;
        let bytes = self.take(len, what)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| DecodeError::Malformed(format!("{what}: invalid UTF-8")))
    }

    /// Exactly `len` raw bytes.  The caller validates `len` against its
    /// own schema cap *before* calling (e.g. the `Deploy` artifact cap);
    /// this only guards against reading past the payload, so a declared
    /// length larger than the bytes remaining is `Truncated`, never an
    /// allocation.
    pub fn bytes_(&mut self, len: usize, what: &'static str) -> Result<Vec<u8>, DecodeError> {
        Ok(self.take(len, what)?.to_vec())
    }

    /// Element-count guard shared by the vector readers: the declared
    /// count must fit in the bytes actually remaining *before* any
    /// allocation happens.
    fn vec_len(&mut self, elem_bytes: usize, what: &'static str) -> Result<usize, DecodeError> {
        let len = self.u32(what)? as usize;
        if len > self.remaining() / elem_bytes {
            return Err(DecodeError::Truncated { what });
        }
        Ok(len)
    }

    pub fn vec_i32(&mut self, what: &'static str) -> Result<Vec<i32>, DecodeError> {
        let len = self.vec_len(4, what)?;
        let mut v = Vec::with_capacity(len);
        for _ in 0..len {
            v.push(self.i32(what)?);
        }
        Ok(v)
    }

    pub fn vec_f32(&mut self, what: &'static str) -> Result<Vec<f32>, DecodeError> {
        let len = self.vec_len(4, what)?;
        let mut v = Vec::with_capacity(len);
        for _ in 0..len {
            v.push(self.f32(what)?);
        }
        Ok(v)
    }

    pub fn vec_f64(&mut self, what: &'static str) -> Result<Vec<f64>, DecodeError> {
        let len = self.vec_len(8, what)?;
        let mut v = Vec::with_capacity(len);
        for _ in 0..len {
            v.push(self.f64(what)?);
        }
        Ok(v)
    }

    pub fn vec_u64(&mut self, what: &'static str) -> Result<Vec<u64>, DecodeError> {
        let len = self.vec_len(8, what)?;
        let mut v = Vec::with_capacity(len);
        for _ in 0..len {
            v.push(self.u64(what)?);
        }
        Ok(v)
    }

    /// Decoding is done; any unconsumed bytes mean the payload does not
    /// match the schema this build expects.
    pub fn finish(self, what: &'static str) -> Result<(), DecodeError> {
        if self.remaining() != 0 {
            return Err(DecodeError::Malformed(format!(
                "{what}: {} trailing byte(s) after the last field",
                self.remaining()
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_round_trip_bit_exact() {
        let mut e = Enc::new();
        e.u8(7);
        e.u16(65535);
        e.u32(0xDEAD_BEEF);
        e.u64(u64::MAX);
        e.i32(-42);
        e.f32(f32::from_bits(0x7FC0_1234)); // NaN with payload
        e.f64(-0.0);
        e.bool(true);
        e.str_("héllo");
        e.vec_u64(&[0, u64::MAX, 42]);
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes);
        assert_eq!(d.u8("a").unwrap(), 7);
        assert_eq!(d.u16("b").unwrap(), 65535);
        assert_eq!(d.u32("c").unwrap(), 0xDEAD_BEEF);
        assert_eq!(d.u64("d").unwrap(), u64::MAX);
        assert_eq!(d.i32("e").unwrap(), -42);
        assert_eq!(d.f32("f").unwrap().to_bits(), 0x7FC0_1234);
        assert_eq!(d.f64("g").unwrap().to_bits(), (-0.0f64).to_bits());
        assert!(d.bool("h").unwrap());
        assert_eq!(d.str_("i").unwrap(), "héllo");
        assert_eq!(d.vec_u64("j").unwrap(), vec![0, u64::MAX, 42]);
        d.finish("tail").unwrap();
    }

    #[test]
    fn truncation_is_typed_not_a_panic() {
        let mut e = Enc::new();
        e.u64(99);
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes[..5]);
        assert_eq!(d.u64("value").unwrap_err(), DecodeError::Truncated { what: "value" });
    }

    #[test]
    fn vec_length_is_validated_before_allocation() {
        // a corrupt 4-billion-element count must not allocate
        let mut e = Enc::new();
        e.u32(u32::MAX);
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes);
        assert!(matches!(d.vec_f64("lat").unwrap_err(), DecodeError::Truncated { .. }));
    }

    #[test]
    fn bad_bool_and_utf8_are_malformed() {
        let mut d = Dec::new(&[2]);
        assert!(matches!(d.bool("flag").unwrap_err(), DecodeError::Malformed(_)));
        let mut e = Enc::new();
        e.u32(1);
        let mut bytes = e.into_bytes();
        bytes.push(0xFF); // invalid UTF-8
        let mut d = Dec::new(&bytes);
        assert!(matches!(d.str_("task").unwrap_err(), DecodeError::Malformed(_)));
    }

    #[test]
    fn trailing_bytes_fail_finish() {
        let d = Dec::new(&[1, 2, 3]);
        assert!(matches!(d.finish("payload").unwrap_err(), DecodeError::Malformed(_)));
    }

    #[test]
    fn decode_error_is_a_std_error_with_messages() {
        let errs: Vec<DecodeError> = vec![
            DecodeError::BadMagic(*b"NOPE"),
            DecodeError::BadVersion { got: 9, want: 1 },
            DecodeError::BadTag(200),
            DecodeError::Truncated { what: "frame header" },
            DecodeError::Oversize { len: 1 << 30, max: 1 << 26 },
            DecodeError::Malformed("x".into()),
        ];
        for e in errs {
            let dyn_err: &dyn std::error::Error = &e;
            assert!(!dyn_err.to_string().is_empty());
        }
        // and it composes with the vendored anyhow's context chaining
        use anyhow::Context;
        let r: Result<(), DecodeError> = Err(DecodeError::BadTag(3));
        let e = r.context("decoding shard event").unwrap_err();
        assert_eq!(format!("{e:#}"), "decoding shard event: unknown message tag 3");
    }
}
