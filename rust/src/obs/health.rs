//! Fleet liveness: the gateway-side heartbeat registry.
//!
//! Workers armed with a heartbeat cadence emit a periodic `Heartbeat`
//! event carrying a cheap health snapshot; the gateway records each
//! beat here and classifies every shard by **heartbeat age** against
//! configurable timeout multiples:
//!
//! * `Healthy` — last beat within one timeout (`interval × mult`);
//! * `Suspect` — silent for more than one timeout;
//! * `Dead` — silent for more than **two** timeouts (the contract the
//!   kill-a-worker test and CI smoke pin: a SIGKILLed worker is marked
//!   dead within two heartbeat timeouts);
//! * `Unknown` — heartbeats are not armed (`interval == 0`), so age
//!   says nothing.
//!
//! A worker that *never* beats still goes `Dead`: age is measured from
//! the registry's arm time until the first beat arrives.  This is
//! detection only — re-routing a dead shard's prefix families is the
//! ROADMAP's follow-up.  Exposition: `qst_worker_up{shard}` /
//! `qst_heartbeat_age_seconds{shard}` in `STATS` ([`super::prom`]) and
//! the `HEALTH` line-protocol command ([`FleetHealth::to_json`]).

use std::time::{Duration, Instant};

/// Default timeout multiple: a shard is suspect after missing ~3 beats.
pub const DEFAULT_HEALTH_MULT: u64 = 3;

/// The cheap per-shard gauges a heartbeat carries (mirrors
/// `proto::Heartbeat` minus the shard index; `obs` stays independent of
/// the wire layer).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HealthSnapshot {
    pub queue_depth: u64,
    pub inflight_slots: u64,
    pub spans_dropped: u64,
    pub cache_bytes: u64,
}

/// Liveness classification by heartbeat age (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HealthState {
    Unknown,
    Healthy,
    Suspect,
    Dead,
}

impl HealthState {
    pub fn name(self) -> &'static str {
        match self {
            HealthState::Unknown => "unknown",
            HealthState::Healthy => "healthy",
            HealthState::Suspect => "suspect",
            HealthState::Dead => "dead",
        }
    }
}

#[derive(Clone, Copy, Debug)]
struct ShardHealth {
    last_seen: Option<Instant>,
    beats: u64,
    last: HealthSnapshot,
}

/// Gateway-side liveness registry: one slot per shard, fed by
/// [`FleetHealth::beat`], read by `STATS` / `HEALTH`.
#[derive(Clone, Debug)]
pub struct FleetHealth {
    interval: Duration,
    mult: u64,
    armed_at: Instant,
    shards: Vec<ShardHealth>,
}

impl FleetHealth {
    /// `heartbeat_ms == 0` builds a disarmed registry (every shard
    /// reports `Unknown` and the prom health gauges stay absent).
    pub fn new(shards: usize, heartbeat_ms: u64, mult: u64) -> Self {
        FleetHealth {
            interval: Duration::from_millis(heartbeat_ms),
            mult: mult.max(1),
            armed_at: Instant::now(),
            shards: vec![
                ShardHealth { last_seen: None, beats: 0, last: HealthSnapshot::default() };
                shards
            ],
        }
    }

    pub fn armed(&self) -> bool {
        !self.interval.is_zero() && !self.shards.is_empty()
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// One timeout: `interval × mult`.  `Suspect` past one, `Dead` past
    /// two.
    pub fn timeout(&self) -> Duration {
        self.interval * self.mult as u32
    }

    /// Record a heartbeat from `shard` (out-of-range indices are
    /// ignored — a malformed shard index must not panic the gateway).
    pub fn beat(&mut self, shard: usize, snap: HealthSnapshot) {
        self.beat_at(shard, snap, Instant::now());
    }

    /// Test seam: record a beat at an explicit instant.
    pub fn beat_at(&mut self, shard: usize, snap: HealthSnapshot, now: Instant) {
        if let Some(s) = self.shards.get_mut(shard) {
            s.last_seen = Some(now);
            s.beats += 1;
            s.last = snap;
        }
    }

    /// Heartbeat age: time since the shard's last beat (or since the
    /// registry was armed, for a shard that has never beaten).  `None`
    /// when disarmed or out of range.
    pub fn age(&self, shard: usize) -> Option<Duration> {
        self.age_at(shard, Instant::now())
    }

    fn age_at(&self, shard: usize, now: Instant) -> Option<Duration> {
        if !self.armed() {
            return None;
        }
        let s = self.shards.get(shard)?;
        Some(now.saturating_duration_since(s.last_seen.unwrap_or(self.armed_at)))
    }

    pub fn state(&self, shard: usize) -> HealthState {
        self.state_at(shard, Instant::now())
    }

    /// Test seam: classify at an explicit instant.
    pub fn state_at(&self, shard: usize, now: Instant) -> HealthState {
        match self.age_at(shard, now) {
            None => HealthState::Unknown,
            Some(age) => {
                let timeout = self.timeout();
                if age <= timeout {
                    HealthState::Healthy
                } else if age <= timeout * 2 {
                    HealthState::Suspect
                } else {
                    HealthState::Dead
                }
            }
        }
    }

    /// The `qst_worker_up` gauge: 1 until a shard is classified `Dead`
    /// (an `Unknown`/disarmed shard is presumed up — absence of
    /// evidence is not death).
    pub fn up(&self, shard: usize) -> bool {
        self.state(shard) != HealthState::Dead
    }

    /// Total heartbeats recorded for `shard`.
    pub fn beats(&self, shard: usize) -> u64 {
        self.shards.get(shard).map_or(0, |s| s.beats)
    }

    /// The gauges from the shard's most recent beat.
    pub fn last_snapshot(&self, shard: usize) -> HealthSnapshot {
        self.shards.get(shard).map_or_else(HealthSnapshot::default, |s| s.last)
    }

    /// The `HEALTH` line-protocol reply: one JSON object summarizing
    /// the fleet.  Hand-rolled like the trace writer — every string is
    /// a static identifier, so no escaping is needed.
    pub fn to_json(&self) -> String {
        let now = Instant::now();
        let mut out = String::with_capacity(128 + self.shards.len() * 160);
        out.push_str(&format!(
            "{{\"armed\":{},\"heartbeat_ms\":{},\"timeout_ms\":{},\"shards\":[",
            self.armed(),
            self.interval.as_millis(),
            self.timeout().as_millis()
        ));
        for (i, s) in self.shards.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let age_ms = self
                .age_at(i, now)
                .map(|a| a.as_millis().to_string())
                .unwrap_or_else(|| "null".into());
            out.push_str(&format!(
                "{{\"shard\":{},\"state\":\"{}\",\"up\":{},\"age_ms\":{},\"beats\":{},\"queue_depth\":{},\"inflight_slots\":{},\"spans_dropped\":{},\"cache_bytes\":{}}}",
                i,
                self.state_at(i, now).name(),
                self.state_at(i, now) != HealthState::Dead,
                age_ms,
                s.beats,
                s.last.queue_depth,
                s.last.inflight_slots,
                s.last.spans_dropped,
                s.last.cache_bytes
            ));
        }
        out.push_str("]}");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_registry_is_unknown_and_up() {
        let h = FleetHealth::new(2, 0, DEFAULT_HEALTH_MULT);
        assert!(!h.armed());
        assert_eq!(h.state(0), HealthState::Unknown);
        assert!(h.up(0));
        assert_eq!(h.age(0), None);
        let j = h.to_json();
        assert!(j.contains("\"armed\":false"));
        assert!(j.contains("\"state\":\"unknown\""));
        assert!(j.contains("\"age_ms\":null"));
    }

    #[test]
    fn states_step_through_timeout_multiples() {
        let mut h = FleetHealth::new(1, 10, 3); // timeout = 30 ms
        let t0 = Instant::now();
        h.beat_at(0, HealthSnapshot { queue_depth: 4, ..Default::default() }, t0);
        let ms = |m: u64| t0 + Duration::from_millis(m);
        assert_eq!(h.state_at(0, ms(5)), HealthState::Healthy);
        assert_eq!(h.state_at(0, ms(30)), HealthState::Healthy, "exactly one timeout is still healthy");
        assert_eq!(h.state_at(0, ms(31)), HealthState::Suspect);
        assert_eq!(h.state_at(0, ms(60)), HealthState::Suspect, "exactly two timeouts is still suspect");
        assert_eq!(h.state_at(0, ms(61)), HealthState::Dead);
        // a fresh beat resurrects the shard
        h.beat_at(0, HealthSnapshot::default(), ms(100));
        assert_eq!(h.state_at(0, ms(101)), HealthState::Healthy);
        assert_eq!(h.beats(0), 2);
        assert_eq!(h.last_snapshot(0), HealthSnapshot::default());
    }

    #[test]
    fn never_beating_shard_dies_from_arm_time() {
        let h = FleetHealth::new(2, 10, 3);
        let late = Instant::now() + Duration::from_millis(61);
        assert_eq!(h.state_at(0, late), HealthState::Dead);
        assert_eq!(h.state_at(1, late), HealthState::Dead);
    }

    #[test]
    fn out_of_range_beats_are_ignored() {
        let mut h = FleetHealth::new(1, 10, 3);
        h.beat(7, HealthSnapshot::default()); // must not panic
        assert_eq!(h.beats(7), 0);
        assert_eq!(h.state(7), HealthState::Unknown);
    }

    #[test]
    fn json_shape_is_wellformed() {
        let mut h = FleetHealth::new(2, 50, 3);
        h.beat(0, HealthSnapshot { queue_depth: 1, inflight_slots: 2, spans_dropped: 0, cache_bytes: 99 });
        let j = h.to_json();
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"armed\":true"));
        assert!(j.contains("\"heartbeat_ms\":50"));
        assert!(j.contains("\"timeout_ms\":150"));
        assert!(j.contains("\"shard\":0"));
        assert!(j.contains("\"shard\":1"));
        assert!(j.contains("\"cache_bytes\":99"));
        for (open, close) in [('{', '}'), ('[', ']')] {
            assert_eq!(j.matches(open).count(), j.matches(close).count());
        }
    }
}
