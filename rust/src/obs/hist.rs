//! A mergeable log-bucketed latency histogram.
//!
//! The decimated reservoir in [`crate::serve::stats`] is exact for one
//! server but lossy to merge: two reservoirs at different decimation
//! strides weight their shards' samples unequally.  A fixed-bucket
//! histogram has the complementary trade-off — each sample lands in a
//! bucket whose width bounds the error, and merging is *exact*: bucket
//! counts add, so a fleet percentile computed from N merged shard
//! histograms is identical to the percentile of one histogram fed every
//! raw sample, across threads and across processes (the bucket counts
//! travel verbatim in the `Report` frame).
//!
//! Buckets are geometric with [`HIST_SUB`] subdivisions per octave
//! (power of two), covering [`HIST_MIN_SECS`] up to ~4.6 hours; bucket
//! `b` spans `MIN * 2^(b/SUB) .. MIN * 2^((b+1)/SUB)`, so the relative
//! width of every bucket is `2^(1/4) - 1 ≈ 19%` — percentiles come back
//! within one bucket width of the exact sample value.

/// Number of buckets (plus an implicit underflow fold into bucket 0 and
/// overflow fold into the last bucket).
pub const HIST_BUCKETS: usize = 128;
/// Subdivisions per octave (factor-of-2 range).
pub const HIST_SUB: usize = 4;
/// Lower edge of bucket 0, in seconds (2^-20 s ≈ 0.95 µs).
pub const HIST_MIN_SECS: f64 = 1.0 / (1 << 20) as f64;

/// Log-bucketed histogram over positive `f64` seconds.  `Default` is the
/// empty histogram; [`LogHistogram::merge`] is commutative, associative,
/// and exact.
#[derive(Clone, Debug, PartialEq)]
pub struct LogHistogram {
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        LogHistogram {
            counts: vec![0; HIST_BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: 0.0,
        }
    }

    /// Reassemble a histogram from its wire fields.  `counts` may be
    /// shorter than [`HIST_BUCKETS`] (encoders trim trailing zero
    /// buckets); longer is the caller's decode error to reject.  An
    /// empty histogram (`count == 0`) is normalized to the canonical
    /// empty state so wire round-trips compare equal.
    pub fn from_parts(counts: Vec<u64>, count: u64, sum: f64, min: f64, max: f64) -> Self {
        if count == 0 {
            return Self::new();
        }
        let mut full = counts;
        full.resize(HIST_BUCKETS, 0);
        LogHistogram { counts: full, count, sum, min, max }
    }

    /// Bucket index for a sample (under/overflow fold into the edges).
    pub fn bucket_of(v: f64) -> usize {
        if !(v > HIST_MIN_SECS) {
            // non-positive, NaN, and sub-resolution samples land in 0
            return 0;
        }
        let b = ((v / HIST_MIN_SECS).log2() * HIST_SUB as f64).floor();
        (b as usize).min(HIST_BUCKETS - 1)
    }

    /// `[lo, hi)` bounds of bucket `b`, in seconds.
    pub fn bucket_bounds(b: usize) -> (f64, f64) {
        let scale = |i: usize| HIST_MIN_SECS * 2f64.powf(i as f64 / HIST_SUB as f64);
        (scale(b), scale(b + 1))
    }

    pub fn record(&mut self, v: f64) {
        let v = if v.is_finite() && v > 0.0 { v } else { 0.0 };
        self.counts[Self::bucket_of(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Exact merge: bucket counts add.  Counts saturate rather than
    /// wrap — a fleet that really records 2^64 samples gets a pinned
    /// bucket, not a corrupted distribution.
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a = a.saturating_add(*b);
        }
        self.count = self.count.saturating_add(other.count);
        self.sum += other.sum;
        if other.count > 0 {
            self.min = self.min.min(other.min);
            self.max = self.max.max(other.max);
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Raw bucket counts (for the wire encoder and the `STATS`
    /// exposition).
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Index past the last non-zero bucket — encoders trim here.
    pub fn trimmed_len(&self) -> usize {
        self.counts.iter().rposition(|&c| c != 0).map_or(0, |i| i + 1)
    }

    /// Nearest-rank percentile: the upper edge of the bucket holding the
    /// rank-th sample, clamped to the observed min/max.  Since the rank
    /// falls in the same bucket as the exact sample would, the result is
    /// within one bucket width of the true percentile.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                let (_, hi) = Self::bucket_bounds(b);
                return hi.clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn p50_secs(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95_secs(&self) -> f64 {
        self.percentile(95.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic xorshift so tests need no external RNG.
    fn xorshift(state: &mut u64) -> u64 {
        let mut x = *state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        *state = x;
        x
    }

    fn sample(state: &mut u64) -> f64 {
        // latencies spread over ~5 orders of magnitude: 10 µs .. 1 s
        let u = (xorshift(state) >> 11) as f64 / (1u64 << 53) as f64;
        1e-5 * (1e5f64).powf(u)
    }

    fn true_pct(sorted: &[f64], p: f64) -> f64 {
        let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
        sorted[rank.clamp(1, sorted.len()) - 1]
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LogHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn bucket_bounds_cover_samples() {
        for &v in &[1e-7, 1e-6, 3.2e-5, 0.001, 0.25, 1.0, 60.0, 1e6] {
            let b = LogHistogram::bucket_of(v);
            let (lo, hi) = LogHistogram::bucket_bounds(b);
            if b > 0 && b < HIST_BUCKETS - 1 {
                assert!(lo <= v && v < hi, "sample {v} outside bucket {b} [{lo},{hi})");
            }
        }
        // degenerate inputs fold into bucket 0, never panic
        for &v in &[0.0, -1.0, f64::NAN, f64::NEG_INFINITY] {
            assert_eq!(LogHistogram::bucket_of(v), 0);
        }
        assert_eq!(LogHistogram::bucket_of(f64::INFINITY), HIST_BUCKETS - 1);
    }

    #[test]
    fn percentile_within_one_bucket_width() {
        let mut state = 0xC0FFEE;
        let mut h = LogHistogram::new();
        let mut raw = Vec::new();
        for _ in 0..20_000 {
            let v = sample(&mut state);
            h.record(v);
            raw.push(v);
        }
        raw.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for p in [1.0, 10.0, 50.0, 90.0, 95.0, 99.0, 99.9] {
            let want = true_pct(&raw, p);
            let got = h.percentile(p);
            let (lo, hi) = LogHistogram::bucket_bounds(LogHistogram::bucket_of(want));
            let width = hi - lo;
            assert!(
                (got - want).abs() <= width,
                "p{p}: got {got}, want {want}, bucket width {width}"
            );
        }
    }

    #[test]
    fn four_shard_merge_matches_concatenated_raw_samples() {
        // the acceptance property: merging 4 shards' histograms gives the
        // same percentiles as one histogram over all raw samples, and
        // both land within one bucket width of the exact sorted answer —
        // even when the shards saw very different load (sample counts)
        let mut state = 0xBADC0DE;
        let mut shard_hists: Vec<LogHistogram> = Vec::new();
        let mut all_raw: Vec<f64> = Vec::new();
        let mut reference = LogHistogram::new();
        for n in [10_000usize, 3_000, 400, 25] {
            let mut h = LogHistogram::new();
            for _ in 0..n {
                let v = sample(&mut state);
                h.record(v);
                reference.record(v);
                all_raw.push(v);
            }
            shard_hists.push(h);
        }
        let mut merged = LogHistogram::new();
        for h in &shard_hists {
            merged.merge(h);
        }
        // merge is EXACT: identical to feeding every raw sample into one
        assert_eq!(merged, reference);
        assert_eq!(merged.count() as usize, all_raw.len());
        all_raw.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for p in [50.0, 90.0, 95.0, 99.0] {
            let want = true_pct(&all_raw, p);
            let got = merged.percentile(p);
            let (lo, hi) = LogHistogram::bucket_bounds(LogHistogram::bucket_of(want));
            assert!(
                (got - want).abs() <= hi - lo,
                "p{p}: merged {got} vs raw {want} (bucket width {})",
                hi - lo
            );
        }
    }

    #[test]
    fn merge_order_does_not_matter() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        for i in 1..100 {
            a.record(i as f64 / 1000.0);
            b.record(i as f64 / 10.0);
        }
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, ba);
        let empty = LogHistogram::new();
        let mut ae = a.clone();
        ae.merge(&empty);
        assert_eq!(ae, a, "merging the empty histogram is the identity");
    }

    #[test]
    fn empty_merges_in_both_directions() {
        let mut a = LogHistogram::new();
        a.record(0.010);
        a.record(0.250);
        // empty ⊕ nonempty adopts the nonempty side verbatim
        let mut e = LogHistogram::new();
        e.merge(&a);
        assert_eq!(e, a);
        assert_eq!(e.min(), a.min());
        assert_eq!(e.max(), a.max());
        // nonempty ⊕ empty is the identity — and must not let the empty
        // side's sentinel min (+inf) / max (0) leak into the result
        let mut a2 = a.clone();
        a2.merge(&LogHistogram::new());
        assert_eq!(a2, a);
        assert!(a2.min() > 0.0);
        // empty ⊕ empty stays canonical empty
        let mut ee = LogHistogram::new();
        ee.merge(&LogHistogram::new());
        assert_eq!(ee, LogHistogram::new());
        assert_eq!(ee.min(), 0.0);
    }

    #[test]
    fn merge_handles_mismatched_trimmed_lengths() {
        // one side trimmed short (single tiny sample), the other long
        // (sample near the top bucket) — from_parts resizes both to
        // HIST_BUCKETS, so the zip in merge never silently truncates
        let mut short = LogHistogram::new();
        short.record(0.00001);
        let mut long = LogHistogram::new();
        long.record(100.0);
        let short_wire = LogHistogram::from_parts(
            short.counts()[..short.trimmed_len()].to_vec(),
            short.count(),
            short.sum(),
            short.min,
            short.max,
        );
        let long_wire = LogHistogram::from_parts(
            long.counts()[..long.trimmed_len()].to_vec(),
            long.count(),
            long.sum(),
            long.min,
            long.max,
        );
        assert!(short_wire.trimmed_len() < long_wire.trimmed_len());
        let mut m1 = short_wire.clone();
        m1.merge(&long_wire);
        let mut m2 = long_wire.clone();
        m2.merge(&short_wire);
        assert_eq!(m1, m2);
        assert_eq!(m1.count(), 2);
        assert_eq!(m1.counts().len(), HIST_BUCKETS);
        // both samples are findable: p1 in the low bucket, p99 high
        assert!(m1.percentile(1.0) < 0.001);
        assert!(m1.percentile(99.0) > 1.0);
    }

    #[test]
    fn merge_saturates_instead_of_wrapping() {
        let mut a = LogHistogram::from_parts(vec![u64::MAX - 1], u64::MAX - 1, 1.0, 0.5, 0.5);
        let b = LogHistogram::from_parts(vec![5], 5, 1.0, 0.5, 0.5);
        a.merge(&b);
        assert_eq!(a.count(), u64::MAX, "count must saturate, not wrap");
        assert_eq!(a.counts()[0], u64::MAX, "bucket must saturate, not wrap");
        // percentiles still answer without panicking
        assert!(a.percentile(50.0) > 0.0);
    }

    #[test]
    fn merge_commutes_on_random_histogram_pairs() {
        // property test: for random pairs (including empties and
        // mismatched trims), a⊕b == b⊕a
        let mut state = 0xF00DF00Du64;
        for round in 0..50 {
            let mut a = LogHistogram::new();
            let mut b = LogHistogram::new();
            let na = (xorshift(&mut state) % 40) as usize;
            let nb = (xorshift(&mut state) % 40) as usize;
            for _ in 0..na {
                a.record(sample(&mut state));
            }
            for _ in 0..nb {
                b.record(sample(&mut state));
            }
            let mut ab = a.clone();
            ab.merge(&b);
            let mut ba = b.clone();
            ba.merge(&a);
            assert_eq!(ab, ba, "round {round}: merge must commute (na={na}, nb={nb})");
            assert_eq!(ab.count(), (na + nb) as u64);
        }
    }

    #[test]
    fn from_parts_round_trips_trimmed_counts() {
        let mut h = LogHistogram::new();
        for &v in &[0.001, 0.002, 0.004, 1.5] {
            h.record(v);
        }
        let trimmed = h.counts()[..h.trimmed_len()].to_vec();
        let back = LogHistogram::from_parts(trimmed, h.count(), h.sum(), h.min, h.max);
        assert_eq!(back, h);
    }
}
