//! The request-lifecycle span recorder: per-thread bounded rings behind
//! one global on/off switch.
//!
//! Design constraints (see the module docs in [`super`]):
//!
//! * **Disabled cost ≈ one branch.**  Every instrumentation site first
//!   loads one relaxed [`AtomicBool`]; when tracing is off nothing else
//!   runs — no clock reads, no allocation, no locks.
//! * **Parity-safe.**  Recording only reads the monotonic clock and
//!   appends to a ring; it never touches request data, so turning
//!   tracing on cannot change a single output bit (pinned by the
//!   `bench-gateway` trace-parity gate).
//! * **Bounded memory.**  Each thread owns a fixed-capacity ring
//!   ([`RING_CAP`] spans); at capacity the oldest span is overwritten
//!   and counted in `dropped`, so a long-running server can trace
//!   forever without growing.
//! * **Uncontended fast path.**  A thread records into its own ring
//!   through a thread-local `Arc`; the per-ring mutex is only ever
//!   contended by [`drain`] (export time), so the hot-path lock is one
//!   uncontended compare-and-swap.
//!
//! Timestamps are nanoseconds on a process-local monotonic epoch (first
//! use of the recorder).  Spans shipped across processes in `Telemetry`
//! frames keep the *worker's* epoch — Chrome trace viewers only need
//! per-process (`pid`) consistency, which is exactly what they get.

use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Per-thread ring capacity, in spans (~32 B each → ≤ ~256 KiB/thread).
pub const RING_CAP: usize = 8192;

/// The fixed span vocabulary.  The first eight are the request
/// lifecycle, in pipeline order; then three kernel-level kinds; the
/// continuous-batching scheduler kinds append at the end (wire tags are
/// stable forever, so new kinds may only ever be added at the back).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum SpanKind {
    /// request validation + admission (gateway or server ingress)
    Admit = 0,
    /// routing the prompt to a shard / a task's side network
    Route = 1,
    /// time spent queued in a shard inbox / server queue before batching
    ShardQueue = 2,
    /// micro-batch assembly: padding + cache-key resolution
    BatchAssemble = 3,
    /// the frozen backbone forward over fresh rows
    Backbone = 4,
    /// resuming a cached prefix instead of a full backbone forward
    PrefixResume = 5,
    /// the per-task side-network forward
    Sidenet = 6,
    /// response construction + latency accounting
    Respond = 7,
    /// dense f32 GEMM kernel
    Gemm = 8,
    /// packed-W4 fused dequant GEMM kernel
    Qgemm = 9,
    /// handing row runs to the persistent kernel worker pool
    PoolDispatch = 10,
    /// admitting a request into an open micro-batch slot of a shard's
    /// continuous-batching pool
    AdmitSlot = 11,
    /// slot-pool wait: admission into the pool until the micro-batch
    /// containing the request starts executing
    QueueWait = 12,
}

impl SpanKind {
    /// Every kind, in tag order.
    pub const ALL: [SpanKind; 13] = [
        SpanKind::Admit,
        SpanKind::Route,
        SpanKind::ShardQueue,
        SpanKind::BatchAssemble,
        SpanKind::Backbone,
        SpanKind::PrefixResume,
        SpanKind::Sidenet,
        SpanKind::Respond,
        SpanKind::Gemm,
        SpanKind::Qgemm,
        SpanKind::PoolDispatch,
        SpanKind::AdmitSlot,
        SpanKind::QueueWait,
    ];

    /// The eight request-lifecycle kinds (what the tracing smoke in
    /// `scripts/check.sh` requires to appear in a trace).
    pub const LIFECYCLE: [SpanKind; 8] = [
        SpanKind::Admit,
        SpanKind::Route,
        SpanKind::ShardQueue,
        SpanKind::BatchAssemble,
        SpanKind::Backbone,
        SpanKind::PrefixResume,
        SpanKind::Sidenet,
        SpanKind::Respond,
    ];

    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Admit => "admit",
            SpanKind::Route => "route",
            SpanKind::ShardQueue => "shard_queue",
            SpanKind::BatchAssemble => "batch_assemble",
            SpanKind::Backbone => "backbone",
            SpanKind::PrefixResume => "prefix_resume",
            SpanKind::Sidenet => "sidenet",
            SpanKind::Respond => "respond",
            SpanKind::Gemm => "gemm",
            SpanKind::Qgemm => "qgemm",
            SpanKind::PoolDispatch => "pool_dispatch",
            SpanKind::AdmitSlot => "admit_slot",
            SpanKind::QueueWait => "queue_wait",
        }
    }

    /// Wire decode; `None` for an unknown tag (the telemetry decoder
    /// turns that into a typed `Malformed`, never a panic).
    pub fn from_u8(b: u8) -> Option<SpanKind> {
        SpanKind::ALL.get(b as usize).copied()
    }
}

/// One completed span: what happened (`kind`), to which request (`id`,
/// 0 when the work is not request-scoped, e.g. kernel spans), when, for
/// how long, on which thread.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Span {
    pub kind: SpanKind,
    pub id: u64,
    /// nanoseconds since the recording process's trace epoch
    pub start_ns: u64,
    pub dur_ns: u64,
    /// recorder-assigned thread index (stable per thread per process)
    pub tid: u32,
}

struct Ring {
    spans: Vec<Span>,
    /// next write slot (the ring overwrites oldest-first at capacity)
    head: usize,
    dropped: u64,
}

impl Ring {
    fn push(&mut self, s: Span) {
        if self.spans.len() < RING_CAP {
            self.spans.push(s);
        } else {
            self.spans[self.head] = s;
            self.dropped += 1;
        }
        self.head = (self.head + 1) % RING_CAP;
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static EPOCH: OnceLock<Instant> = OnceLock::new();
static NEXT_TID: AtomicU32 = AtomicU32::new(0);

fn registry() -> &'static Mutex<Vec<Arc<Mutex<Ring>>>> {
    static REGISTRY: OnceLock<Mutex<Vec<Arc<Mutex<Ring>>>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

thread_local! {
    static LOCAL: std::cell::OnceCell<(u32, Arc<Mutex<Ring>>)> = const { std::cell::OnceCell::new() };
}

/// Is span recording on?  One relaxed atomic load — the entire cost of
/// an instrumentation site when tracing is disabled.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn recording on or off (process-wide).  Enabling pins the trace
/// epoch on first use so all timestamps share one origin.
pub fn set_enabled(on: bool) {
    if on {
        let _ = EPOCH.get_or_init(Instant::now);
    }
    ENABLED.store(on, Ordering::Relaxed);
}

/// Nanoseconds since the trace epoch.
fn now_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// Open a span: returns the start timestamp, or 0 when recording is
/// off.  Pair with [`end`].
#[inline]
pub fn start() -> u64 {
    if !enabled() {
        return 0;
    }
    now_ns().max(1)
}

/// Close a span opened by [`start`].  A 0 start (recording was off at
/// open) is a no-op, so a mid-span toggle never records garbage.
#[inline]
pub fn end(kind: SpanKind, start_ns: u64, id: u64) {
    if start_ns == 0 || !enabled() {
        return;
    }
    let dur = now_ns().saturating_sub(start_ns);
    record(Span { kind, id, start_ns, dur_ns: dur, tid: 0 });
}

/// Record a span whose start lies `dur_ns` in the past (used for queue
/// wait: the enqueue instant predates the batch that observes it).
#[inline]
pub fn end_backdated(kind: SpanKind, dur_ns: u64, id: u64) {
    if !enabled() {
        return;
    }
    let now = now_ns();
    record(Span { kind, id, start_ns: now.saturating_sub(dur_ns), dur_ns, tid: 0 });
}

fn record(mut s: Span) {
    LOCAL.with(|cell| {
        let (tid, ring) = cell.get_or_init(|| {
            let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            let ring = Arc::new(Mutex::new(Ring {
                spans: Vec::with_capacity(64),
                head: 0,
                dropped: 0,
            }));
            registry().lock().expect("span registry poisoned").push(Arc::clone(&ring));
            (tid, ring)
        });
        s.tid = *tid;
        // uncontended except against drain(); never blocks the hot path
        // for longer than the drain's memcpy
        ring.lock().expect("span ring poisoned").push(s);
    });
}

/// Collect (and clear) every thread's recorded spans, sorted by start
/// time.  Returns the spans and the total count of spans lost to ring
/// overwrites.  Threads keep their rings registered, so a drain mid-run
/// loses nothing that comes after it.
pub fn drain() -> (Vec<Span>, u64) {
    let mut out = Vec::new();
    let mut dropped = 0u64;
    for ring in registry().lock().expect("span registry poisoned").iter() {
        let mut r = ring.lock().expect("span ring poisoned");
        // restore chronological order across the wrap point (a full ring's
        // oldest entry sits at `head`, the next overwrite slot)
        if r.spans.len() == RING_CAP && r.head != 0 {
            out.extend_from_slice(&r.spans[r.head..]);
            out.extend_from_slice(&r.spans[..r.head]);
        } else {
            out.extend_from_slice(&r.spans);
        }
        dropped += r.dropped;
        r.spans.clear();
        r.head = 0;
        r.dropped = 0;
    }
    out.sort_by_key(|s| (s.start_ns, s.tid));
    (out, dropped)
}

#[cfg(test)]
mod tests {
    use super::*;

    // The recorder is process-global and `cargo test` threads share it,
    // so: serialize toggling tests behind the crate-wide obs test lock,
    // and filter drained spans by a test-unique id marker — spans from
    // instrumented code in concurrently running tests are not ours.
    fn ours(spans: &[Span], marker: u64) -> Vec<Span> {
        spans.iter().copied().filter(|s| s.id & 0xFFFF_0000_0000_0000 == marker).collect()
    }

    #[test]
    fn disabled_recorder_records_nothing() {
        let _g = super::super::test_lock();
        set_enabled(false);
        let _ = drain();
        let marker = 0x00A1_0000_0000_0000u64;
        let t = start();
        assert_eq!(t, 0, "disabled start() must not read the clock");
        end(SpanKind::Backbone, t, marker | 1);
        end_backdated(SpanKind::ShardQueue, 500, marker | 1);
        let (spans, _) = drain();
        assert!(ours(&spans, marker).is_empty());
    }

    #[test]
    fn spans_round_trip_through_drain() {
        let _g = super::super::test_lock();
        set_enabled(false);
        let _ = drain();
        set_enabled(true);
        let marker = 0x00A2_0000_0000_0000u64;
        let t = start();
        assert!(t > 0);
        end(SpanKind::Gemm, t, marker | 7);
        end_backdated(SpanKind::ShardQueue, 1_000, marker | 9);
        set_enabled(false);
        let (all, _) = drain();
        let spans = ours(&all, marker);
        assert_eq!(spans.len(), 2);
        let kinds: Vec<&str> = spans.iter().map(|s| s.kind.name()).collect();
        assert!(kinds.contains(&"gemm") && kinds.contains(&"shard_queue"));
        let sq = spans.iter().find(|s| s.kind == SpanKind::ShardQueue).unwrap();
        assert_eq!(sq.dur_ns, 1_000);
        assert_eq!(sq.id, marker | 9);
        // chronological output
        assert!(all.windows(2).all(|w| w[0].start_ns <= w[1].start_ns));
    }

    #[test]
    fn ring_bounds_memory_and_counts_drops() {
        let _g = super::super::test_lock();
        set_enabled(false);
        let _ = drain();
        set_enabled(true);
        let marker = 0x00A3_0000_0000_0000u64;
        for i in 0..(RING_CAP + 100) as u64 {
            let t = start();
            end(SpanKind::Respond, t, marker | i);
        }
        set_enabled(false);
        let (all, dropped) = drain();
        let spans = ours(&all, marker);
        // this thread's ring held the cap and overwrote exactly 100
        assert_eq!(spans.len(), RING_CAP);
        assert!(dropped >= 100);
        // the ring kept the NEWEST spans (oldest overwritten), in order
        assert_eq!(spans.first().unwrap().id, marker | 100);
        assert_eq!(spans.last().unwrap().id, marker | (RING_CAP + 100 - 1) as u64);
    }

    #[test]
    fn kind_names_and_tags_are_stable() {
        for (i, k) in SpanKind::ALL.iter().enumerate() {
            assert_eq!(*k as u8 as usize, i);
            assert_eq!(SpanKind::from_u8(i as u8), Some(*k));
        }
        assert_eq!(SpanKind::from_u8(SpanKind::ALL.len() as u8), None);
        assert_eq!(SpanKind::LIFECYCLE.len(), 8);
        assert_eq!(SpanKind::LIFECYCLE[0].name(), "admit");
        assert_eq!(SpanKind::LIFECYCLE[7].name(), "respond");
    }
}
