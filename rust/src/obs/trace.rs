//! Chrome trace-event JSON export (the `--trace-out` file).
//!
//! Emits the "JSON Object Format" of the Trace Event spec — a
//! `{"traceEvents": [...]}` object of complete (`"ph":"X"`) events —
//! which loads directly in Perfetto (<https://ui.perfetto.dev>) and
//! `chrome://tracing`.  Timestamps are microseconds; each event carries
//! the span name from the fixed vocabulary, the recording process's
//! `pid` lane (0 = this process, shard `i` ships as `i + 1`), the
//! recorder thread id, and the request id in `args`.
//!
//! Hand-rolled like [`crate::benchkit::Json`]: every name in a trace is
//! a `'static` identifier from [`SpanKind::name`], so no string escaping
//! is needed — the writer stays ~40 lines and dependency-free.

use std::io::Write;

use super::series::GaugePoint;
use super::span::{Span, SpanKind};

/// One span tagged with its origin process lane for the trace file.
#[derive(Clone, Copy, Debug)]
pub struct TraceSpan {
    /// 0 = the local process; socket shard workers ship as `shard + 1`
    pub pid: u32,
    pub span: Span,
}

/// Tag local spans with pid lane 0.
pub fn local(spans: Vec<Span>) -> Vec<TraceSpan> {
    spans.into_iter().map(|span| TraceSpan { pid: 0, span }).collect()
}

/// One shard's gauge flight-recorder series, tagged with its trace
/// lane.  Rendered as Chrome **counter** events (`"ph":"C"`), one track
/// per gauge name, so Perfetto shows load curves beside the spans.
#[derive(Clone, Debug)]
pub struct CounterTrack {
    /// counter lane: shard `i` renders as pid `i + 1` (matching the
    /// lane its worker spans ship under; lane 0 is the gateway process)
    pub pid: u32,
    pub points: Vec<GaugePoint>,
}

/// The gauge names a [`CounterTrack`] expands into (one counter track
/// each), plus the derived `rps` track.
const COUNTER_GAUGES: [&str; 4] = ["queue_depth", "inflight_slots", "cache_bytes", "registry_bytes"];

fn push_counter(out: &mut String, first: &mut bool, name: &str, pid: u32, ts_us: f64, v: f64) {
    if !*first {
        out.push(',');
    }
    *first = false;
    // counters carry their value in args under their own name; tid 0
    // (counter tracks are per-process, not per-thread)
    out.push_str(&format!(
        "{{\"name\":\"{name}\",\"cat\":\"qst\",\"ph\":\"C\",\"ts\":{ts_us:.3},\"pid\":{pid},\"tid\":0,\"args\":{{\"{name}\":{v}}}}}"
    ));
}

fn render_counters(out: &mut String, first: &mut bool, tracks: &[CounterTrack]) {
    for track in tracks {
        let mut prev: Option<&GaugePoint> = None;
        for p in &track.points {
            let ts_us = p.t_ms as f64 * 1e3;
            for (name, v) in COUNTER_GAUGES.iter().zip([
                p.queue_depth,
                p.inflight_slots,
                p.cache_bytes,
                p.registry_bytes,
            ]) {
                push_counter(out, first, name, track.pid, ts_us, v as f64);
            }
            // request *rate* between consecutive points (requests is a
            // cumulative counter; the first point has no baseline)
            if let Some(q) = prev {
                let dt_s = (p.t_ms.saturating_sub(q.t_ms)) as f64 / 1e3;
                if dt_s > 0.0 {
                    let rps = p.requests.saturating_sub(q.requests) as f64 / dt_s;
                    push_counter(out, first, "rps", track.pid, ts_us, rps);
                }
            }
            prev = Some(p);
        }
    }
}

/// Serialize spans plus gauge counter tracks as Chrome trace-event
/// JSON (`"ph":"X"` spans and `"ph":"C"` counters in one event list).
pub fn render_with_counters(spans: &[TraceSpan], counters: &[CounterTrack]) -> String {
    let mut out = String::with_capacity(128 + spans.len() * 96);
    out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
    let mut first = true;
    for ts in spans.iter() {
        if !first {
            out.push(',');
        }
        first = false;
        let s = &ts.span;
        out.push_str(&format!(
            "{{\"name\":\"{}\",\"cat\":\"qst\",\"ph\":\"X\",\"ts\":{:.3},\"dur\":{:.3},\"pid\":{},\"tid\":{},\"args\":{{\"id\":{}}}}}",
            s.kind.name(),
            s.start_ns as f64 / 1e3,
            s.dur_ns as f64 / 1e3,
            ts.pid,
            s.tid,
            s.id
        ));
    }
    render_counters(&mut out, &mut first, counters);
    out.push_str("]}\n");
    out
}

/// Serialize spans as Chrome trace-event JSON.
pub fn render(spans: &[TraceSpan]) -> String {
    render_with_counters(spans, &[])
}

/// Write a trace file; parent directories must exist.
pub fn write_file(path: &str, spans: &[TraceSpan]) -> std::io::Result<()> {
    write_file_with_counters(path, spans, &[])
}

/// Write a trace file including gauge counter tracks.
pub fn write_file_with_counters(
    path: &str,
    spans: &[TraceSpan],
    counters: &[CounterTrack],
) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(render_with_counters(spans, counters).as_bytes())?;
    f.flush()
}

/// Which span names appear in a span set — the tracing smoke asserts
/// every lifecycle name is present.
pub fn kinds_present(spans: &[TraceSpan]) -> Vec<&'static str> {
    let mut seen = [false; SpanKind::ALL.len()];
    for ts in spans {
        seen[ts.span.kind as u8 as usize] = true;
    }
    SpanKind::ALL.iter().filter(|k| seen[**k as u8 as usize]).map(|k| k.name()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(kind: SpanKind, start: u64, dur: u64, id: u64) -> TraceSpan {
        TraceSpan { pid: 0, span: Span { kind, id, start_ns: start, dur_ns: dur, tid: 3 } }
    }

    #[test]
    fn render_is_wellformed_trace_json() {
        let spans =
            vec![span(SpanKind::Backbone, 1_500, 2_000, 42), span(SpanKind::Respond, 4_000, 10, 42)];
        let j = render(&spans);
        assert!(j.starts_with("{\"displayTimeUnit\":\"ms\",\"traceEvents\":["));
        assert!(j.trim_end().ends_with("]}"));
        assert!(j.contains("\"name\":\"backbone\""));
        assert!(j.contains("\"ph\":\"X\""));
        assert!(j.contains("\"ts\":1.500")); // ns -> µs
        assert!(j.contains("\"dur\":2.000"));
        assert!(j.contains("\"args\":{\"id\":42}"));
        // brace/bracket balance is a cheap structural well-formedness check
        for (open, close) in [('{', '}'), ('[', ']')] {
            let o = j.matches(open).count();
            let c = j.matches(close).count();
            assert_eq!(o, c, "unbalanced {open}{close}");
        }
        assert_eq!(render(&[]), "{\"displayTimeUnit\":\"ms\",\"traceEvents\":[]}\n");
    }

    #[test]
    fn counter_tracks_render_as_counter_events_with_derived_rps() {
        let track = CounterTrack {
            pid: 2,
            points: vec![
                GaugePoint { t_ms: 10, queue_depth: 3, inflight_slots: 1, cache_bytes: 64, registry_bytes: 16, requests: 4 },
                GaugePoint { t_ms: 20, queue_depth: 1, inflight_slots: 2, cache_bytes: 64, registry_bytes: 16, requests: 9 },
            ],
        };
        let spans = vec![span(SpanKind::Backbone, 1_000, 500, 7)];
        let j = render_with_counters(&spans, &[track]);
        assert!(j.contains("\"ph\":\"X\""), "spans still render");
        assert!(j.contains("\"ph\":\"C\""), "counters render as counter events");
        assert!(j.contains("\"name\":\"queue_depth\""));
        assert!(j.contains("\"args\":{\"queue_depth\":3}"));
        assert!(j.contains("\"args\":{\"inflight_slots\":2}"));
        // ms -> µs: t_ms 10 renders at ts 10000
        assert!(j.contains("\"ts\":10000.000"));
        // rps derived between the two points: (9-4)/(10ms) = 500/s
        assert!(j.contains("\"args\":{\"rps\":500}"));
        assert!(j.contains("\"pid\":2"));
        for (open, close) in [('{', '}'), ('[', ']')] {
            assert_eq!(j.matches(open).count(), j.matches(close).count());
        }
        // no counters -> byte-identical to the plain renderer
        assert_eq!(render_with_counters(&spans, &[]), render(&spans));
    }

    #[test]
    fn kinds_present_lists_names_once() {
        let spans = vec![
            span(SpanKind::Admit, 0, 1, 1),
            span(SpanKind::Admit, 2, 1, 2),
            span(SpanKind::Gemm, 3, 1, 0),
        ];
        assert_eq!(kinds_present(&spans), vec!["admit", "gemm"]);
    }
}
