//! Chrome trace-event JSON export (the `--trace-out` file).
//!
//! Emits the "JSON Object Format" of the Trace Event spec — a
//! `{"traceEvents": [...]}` object of complete (`"ph":"X"`) events —
//! which loads directly in Perfetto (<https://ui.perfetto.dev>) and
//! `chrome://tracing`.  Timestamps are microseconds; each event carries
//! the span name from the fixed vocabulary, the recording process's
//! `pid` lane (0 = this process, shard `i` ships as `i + 1`), the
//! recorder thread id, and the request id in `args`.
//!
//! Hand-rolled like [`crate::benchkit::Json`]: every name in a trace is
//! a `'static` identifier from [`SpanKind::name`], so no string escaping
//! is needed — the writer stays ~40 lines and dependency-free.

use std::io::Write;

use super::span::{Span, SpanKind};

/// One span tagged with its origin process lane for the trace file.
#[derive(Clone, Copy, Debug)]
pub struct TraceSpan {
    /// 0 = the local process; socket shard workers ship as `shard + 1`
    pub pid: u32,
    pub span: Span,
}

/// Tag local spans with pid lane 0.
pub fn local(spans: Vec<Span>) -> Vec<TraceSpan> {
    spans.into_iter().map(|span| TraceSpan { pid: 0, span }).collect()
}

/// Serialize spans as Chrome trace-event JSON.
pub fn render(spans: &[TraceSpan]) -> String {
    let mut out = String::with_capacity(128 + spans.len() * 96);
    out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
    for (i, ts) in spans.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let s = &ts.span;
        out.push_str(&format!(
            "{{\"name\":\"{}\",\"cat\":\"qst\",\"ph\":\"X\",\"ts\":{:.3},\"dur\":{:.3},\"pid\":{},\"tid\":{},\"args\":{{\"id\":{}}}}}",
            s.kind.name(),
            s.start_ns as f64 / 1e3,
            s.dur_ns as f64 / 1e3,
            ts.pid,
            s.tid,
            s.id
        ));
    }
    out.push_str("]}\n");
    out
}

/// Write a trace file; parent directories must exist.
pub fn write_file(path: &str, spans: &[TraceSpan]) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(render(spans).as_bytes())?;
    f.flush()
}

/// Which span names appear in a span set — the tracing smoke asserts
/// every lifecycle name is present.
pub fn kinds_present(spans: &[TraceSpan]) -> Vec<&'static str> {
    let mut seen = [false; SpanKind::ALL.len()];
    for ts in spans {
        seen[ts.span.kind as u8 as usize] = true;
    }
    SpanKind::ALL.iter().filter(|k| seen[**k as u8 as usize]).map(|k| k.name()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(kind: SpanKind, start: u64, dur: u64, id: u64) -> TraceSpan {
        TraceSpan { pid: 0, span: Span { kind, id, start_ns: start, dur_ns: dur, tid: 3 } }
    }

    #[test]
    fn render_is_wellformed_trace_json() {
        let spans =
            vec![span(SpanKind::Backbone, 1_500, 2_000, 42), span(SpanKind::Respond, 4_000, 10, 42)];
        let j = render(&spans);
        assert!(j.starts_with("{\"displayTimeUnit\":\"ms\",\"traceEvents\":["));
        assert!(j.trim_end().ends_with("]}"));
        assert!(j.contains("\"name\":\"backbone\""));
        assert!(j.contains("\"ph\":\"X\""));
        assert!(j.contains("\"ts\":1.500")); // ns -> µs
        assert!(j.contains("\"dur\":2.000"));
        assert!(j.contains("\"args\":{\"id\":42}"));
        // brace/bracket balance is a cheap structural well-formedness check
        for (open, close) in [('{', '}'), ('[', ']')] {
            let o = j.matches(open).count();
            let c = j.matches(close).count();
            assert_eq!(o, c, "unbalanced {open}{close}");
        }
        assert_eq!(render(&[]), "{\"displayTimeUnit\":\"ms\",\"traceEvents\":[]}\n");
    }

    #[test]
    fn kinds_present_lists_names_once() {
        let spans = vec![
            span(SpanKind::Admit, 0, 1, 1),
            span(SpanKind::Admit, 2, 1, 2),
            span(SpanKind::Gemm, 3, 1, 0),
        ];
        assert_eq!(kinds_present(&spans), vec!["admit", "gemm"]);
    }
}
