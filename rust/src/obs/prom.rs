//! Prometheus-style text exposition of the merged fleet snapshot — what
//! the gateway line protocol returns for the `STATS` command.
//!
//! Format is the Prometheus text format (`# HELP` / `# TYPE` headers,
//! `name{labels} value` samples): fleet-wide counters and gauges from
//! the merged [`GatewayReport`], per-shard gauges labelled
//! `{shard="i"}`, and the request-latency distribution as a cumulative
//! `_bucket{le="…"}` histogram straight from the mergeable
//! [`LogHistogram`] — the buckets merged exactly across shards and
//! processes, so fleet percentiles scraped here are not skewed by
//! uneven shard load.

use std::fmt::Write;

use crate::gateway::GatewayReport;

use super::health::FleetHealth;
use super::hist::LogHistogram;

/// Gateway-side (transport-ingress) counters that no shard can see:
/// admission and backpressure happen before a request reaches a shard.
#[derive(Clone, Copy, Debug, Default)]
pub struct GatewayGauges {
    pub submitted: u64,
    pub rejected: u64,
    pub dropped: u64,
    pub in_flight: u64,
}

fn counter(out: &mut String, name: &str, help: &str, v: u64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} counter");
    let _ = writeln!(out, "{name} {v}");
}

fn gauge(out: &mut String, name: &str, help: &str, v: u64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} gauge");
    let _ = writeln!(out, "{name} {v}");
}

fn per_shard(out: &mut String, name: &str, help: &str, kind: &str, vals: &[(usize, u64)]) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
    for (shard, v) in vals {
        let _ = writeln!(out, "{name}{{shard=\"{shard}\"}} {v}");
    }
}

fn histogram(out: &mut String, name: &str, help: &str, h: &LogHistogram) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} histogram");
    let mut cum = 0u64;
    for (b, &c) in h.counts().iter().enumerate().take(h.trimmed_len()) {
        cum += c;
        if c == 0 {
            continue; // keep the exposition compact: only buckets that moved
        }
        let (_, le) = LogHistogram::bucket_bounds(b);
        let _ = writeln!(out, "{name}_bucket{{le=\"{le:.9}\"}} {cum}");
    }
    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", h.count());
    let _ = writeln!(out, "{name}_sum {:.9}", h.sum());
    let _ = writeln!(out, "{name}_count {}", h.count());
}

/// Render the merged fleet snapshot as Prometheus text exposition.
/// `health` is the gateway's heartbeat registry when one exists (serve
/// mode and disarmed gateways pass `None`; a disarmed registry also
/// renders nothing — heartbeat age means nothing without heartbeats).
pub fn render(report: &GatewayReport, gw: &GatewayGauges, health: Option<&FleetHealth>) -> String {
    let mut out = String::with_capacity(4096);
    let m = &report.merged;
    counter(&mut out, "qst_requests_total", "requests served by the fleet", m.requests);
    counter(&mut out, "qst_tokens_total", "prompt tokens served", m.tokens);
    counter(&mut out, "qst_batches_total", "micro-batches processed", m.batches);
    counter(&mut out, "qst_dropped_total", "requests dropped in failing micro-batches", m.dropped);
    counter(
        &mut out,
        "qst_prefix_resumes_total",
        "cache misses served by resuming a cached prefix",
        m.prefix_resumes,
    );
    counter(&mut out, "qst_cache_hits_total", "whole-prompt hidden-state cache hits", report.cache_hits);
    counter(&mut out, "qst_cache_misses_total", "whole-prompt hidden-state cache misses", report.cache_misses);
    counter(&mut out, "qst_cache_evictions_total", "hidden-state cache evictions", report.cache_evictions);
    counter(&mut out, "qst_backbone_rows_total", "rows through the full frozen backbone", report.backbone_rows);
    counter(&mut out, "qst_resumed_rows_total", "rows resumed from a cached prefix", report.resumed_rows);
    gauge(&mut out, "qst_cache_bytes", "resident hidden-state cache bytes (fleet sum)", report.cache_bytes as u64);
    gauge(&mut out, "qst_registry_bytes", "resident side-network registry bytes (fleet sum)", report.registry_bytes as u64);
    gauge(
        &mut out,
        "qst_registry_resident_bytes",
        "resident side-network registry bytes (fleet sum; alias of qst_registry_bytes)",
        report.registry_bytes as u64,
    );
    counter(
        &mut out,
        "qst_registry_evictions_total",
        "side networks evicted under the registry byte budget (fleet sum)",
        report.registry_evictions,
    );
    gauge(
        &mut out,
        "qst_backbone_resident_bytes",
        "resident backbone bytes (one replica per shard)",
        report.backbone_resident_bytes as u64,
    );
    counter(
        &mut out,
        "qst_spans_dropped_total",
        "trace spans lost to recorder ring overwrites (fleet sum)",
        report.spans_dropped,
    );
    if !m.tasks.is_empty() {
        let _ = writeln!(out, "# HELP qst_task_requests_total requests served per task");
        let _ = writeln!(out, "# TYPE qst_task_requests_total counter");
        for t in &m.tasks {
            let _ = writeln!(out, "qst_task_requests_total{{task=\"{}\"}} {}", t.task, t.requests);
        }
        let _ = writeln!(out, "# HELP qst_task_tokens_total prompt tokens served per task");
        let _ = writeln!(out, "# TYPE qst_task_tokens_total counter");
        for t in &m.tasks {
            let _ = writeln!(out, "qst_task_tokens_total{{task=\"{}\"}} {}", t.task, t.tokens);
        }
        let _ = writeln!(out, "# HELP qst_task_swap_ins_total side-network registry reloads per task");
        let _ = writeln!(out, "# TYPE qst_task_swap_ins_total counter");
        for t in &m.tasks {
            let _ = writeln!(out, "qst_task_swap_ins_total{{task=\"{}\"}} {}", t.task, t.swap_ins);
        }
    }
    counter(&mut out, "qst_gateway_submitted_total", "requests accepted by the gateway", gw.submitted);
    counter(
        &mut out,
        "qst_gateway_backpressure_rejections_total",
        "submits refused because the routed shard was saturated",
        gw.rejected,
    );
    gauge(&mut out, "qst_gateway_in_flight", "requests accepted but not yet answered", gw.in_flight);
    per_shard(
        &mut out,
        "qst_shard_requests_total",
        "requests served per shard",
        "counter",
        &report.shards.iter().map(|r| (r.shard, r.stats.requests)).collect::<Vec<_>>(),
    );
    per_shard(
        &mut out,
        "qst_shard_queue_depth",
        "requests accepted by the shard but not yet drained (at report time)",
        "gauge",
        &report.shards.iter().map(|r| (r.shard, r.queue_depth)).collect::<Vec<_>>(),
    );
    per_shard(
        &mut out,
        "qst_shard_inflight_peak",
        "largest micro-batch of in-flight requests the shard has assembled",
        "gauge",
        &report.shards.iter().map(|r| (r.shard, r.inflight_peak)).collect::<Vec<_>>(),
    );
    per_shard(
        &mut out,
        "qst_inflight_slots",
        "micro-batch slots occupied by admitted-but-unserved requests (at report time)",
        "gauge",
        &report.shards.iter().map(|r| (r.shard, r.inflight_slots)).collect::<Vec<_>>(),
    );
    per_shard(
        &mut out,
        "qst_shard_full_soaks_total",
        "micro-batch soaks that filled to the batch cap (saturation signal)",
        "counter",
        &report.shards.iter().map(|r| (r.shard, r.full_soaks)).collect::<Vec<_>>(),
    );
    histogram(
        &mut out,
        "qst_request_latency_seconds",
        "request latency (queue + compute), merged exactly across shards",
        &m.hist,
    );
    histogram(
        &mut out,
        "qst_swap_in_seconds",
        "cold side-network load (registry swap-in) latency, merged exactly across shards",
        &report.swap_hist,
    );
    // queue-wait distribution: the merged qlat reservoir re-bucketed at
    // render time.  Reservoir-sampled past LAT_CAP per shard (unlike the
    // exact latency histogram), which the HELP text declares.
    if !m.qlat.is_empty() {
        let mut qh = LogHistogram::new();
        for &q in &m.qlat {
            qh.record(q);
        }
        histogram(
            &mut out,
            "qst_queue_wait_seconds",
            "queue wait before batch execution (reservoir-sampled, count-weighted merge)",
            &qh,
        );
    }
    if let Some(h) = health.filter(|h| h.armed()) {
        let _ = writeln!(out, "# HELP qst_worker_up 1 until the shard's heartbeats go silent past two timeouts");
        let _ = writeln!(out, "# TYPE qst_worker_up gauge");
        for s in 0..h.shard_count() {
            let _ = writeln!(out, "qst_worker_up{{shard=\"{s}\"}} {}", u64::from(h.up(s)));
        }
        let _ = writeln!(out, "# HELP qst_heartbeat_age_seconds seconds since the shard's last heartbeat");
        let _ = writeln!(out, "# TYPE qst_heartbeat_age_seconds gauge");
        for s in 0..h.shard_count() {
            if let Some(age) = h.age(s) {
                let _ = writeln!(out, "qst_heartbeat_age_seconds{{shard=\"{s}\"}} {:.3}", age.as_secs_f64());
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gateway::aggregate;
    use crate::proto::ShardReport;

    fn report() -> GatewayReport {
        let mut a = ShardReport { shard: 0, ..Default::default() };
        a.stats.requests = 6;
        a.stats.hist.record(0.010);
        a.stats.hist.record(0.020);
        a.cache_hits = 3;
        a.queue_depth = 2;
        a.inflight_slots = 2;
        a.spans_dropped = 4;
        a.stats.qlat = vec![0.001, 0.002];
        a.stats.tasks = vec![crate::serve::TaskStat {
            task: "task0".into(),
            requests: 6,
            tokens: 24,
            cache_hits: 3,
            swap_ins: 1,
        }];
        let mut b = ShardReport { shard: 1, ..Default::default() };
        b.stats.requests = 4;
        b.stats.hist.record(0.040);
        b.full_soaks = 5;
        b.registry_evictions = 2;
        b.registry_bytes = 4096;
        b.swap_hist.record(0.005);
        aggregate(vec![a, b])
    }

    #[test]
    fn exposition_has_counters_gauges_and_histogram() {
        let text = render(
            &report(),
            &GatewayGauges { submitted: 10, rejected: 2, dropped: 0, in_flight: 1 },
            None,
        );
        assert!(text.contains("# TYPE qst_requests_total counter"));
        assert!(text.contains("qst_requests_total 10"));
        assert!(text.contains("qst_cache_hits_total 3"));
        assert!(text.contains("qst_gateway_backpressure_rejections_total 2"));
        assert!(text.contains("qst_shard_queue_depth{shard=\"0\"} 2"));
        assert!(text.contains("qst_inflight_slots{shard=\"0\"} 2"));
        assert!(text.contains("qst_inflight_slots{shard=\"1\"} 0"));
        assert!(text.contains("qst_shard_full_soaks_total{shard=\"1\"} 5"));
        assert!(text.contains("# TYPE qst_request_latency_seconds histogram"));
        assert!(text.contains("qst_request_latency_seconds_bucket{le=\"+Inf\"} 3"));
        assert!(text.contains("qst_request_latency_seconds_count 3"));
        assert!(text.contains("qst_spans_dropped_total 4"));
        assert!(text.contains("qst_task_requests_total{task=\"task0\"} 6"));
        assert!(text.contains("qst_task_tokens_total{task=\"task0\"} 24"));
        assert!(text.contains("qst_task_swap_ins_total{task=\"task0\"} 1"));
        assert!(text.contains("# TYPE qst_queue_wait_seconds histogram"));
        assert!(text.contains("qst_queue_wait_seconds_count 2"));
        // registry churn: evictions counter, residency gauge, swap-in histogram
        assert!(text.contains("qst_registry_evictions_total 2"));
        assert!(text.contains("qst_registry_resident_bytes 4096"));
        assert!(text.contains("# TYPE qst_swap_in_seconds histogram"));
        assert!(text.contains("qst_swap_in_seconds_count 1"));
        // no registry passed: the health gauges stay absent
        assert!(!text.contains("qst_worker_up"));
        assert!(!text.contains("qst_heartbeat_age_seconds"));
        // cumulative buckets are monotonically non-decreasing
        let mut last = 0u64;
        for line in text.lines().filter(|l| l.starts_with("qst_request_latency_seconds_bucket")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "bucket counts must be cumulative: {line}");
            last = v;
        }
        // every sample line parses as `name[{labels}] number`
        for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
            let (_, val) = line.rsplit_once(' ').unwrap();
            assert!(val.parse::<f64>().is_ok(), "unparseable sample: {line}");
        }
    }

    #[test]
    fn armed_health_registry_renders_liveness_gauges() {
        use crate::obs::health::{FleetHealth, HealthSnapshot};
        let mut h = FleetHealth::new(2, 20, 3);
        h.beat(0, HealthSnapshot::default());
        let text = render(&report(), &GatewayGauges::default(), Some(&h));
        assert!(text.contains("# TYPE qst_worker_up gauge"));
        assert!(text.contains("qst_worker_up{shard=\"0\"} 1"));
        assert!(text.contains("qst_worker_up{shard=\"1\"} "));
        assert!(text.contains("qst_heartbeat_age_seconds{shard=\"0\"} "));
        // a disarmed registry renders nothing
        let disarmed = FleetHealth::new(2, 0, 3);
        let text = render(&report(), &GatewayGauges::default(), Some(&disarmed));
        assert!(!text.contains("qst_worker_up"));
    }
}
