//! The gauge flight recorder: a fixed-capacity time-series ring of
//! cheap load gauges, sampled at a configured cadence on every shard.
//!
//! End-of-run reports answer "what happened in total"; the flight
//! recorder answers "how did load *evolve*" — queue depth, in-flight
//! micro-batch slots, cache/registry residency, and request rate over
//! the life of the run.  Design constraints mirror the span recorder
//! ([`super::span`]):
//!
//! * **Parity-safe.**  Sampling reads counters and a clock; it never
//!   touches request data, so arming the recorder cannot change one
//!   output bit (pinned by the `bench-gateway` parity gate, which runs
//!   its traced replay with the series armed).
//! * **Bounded memory.**  The ring holds at most `cap` points; at
//!   capacity the oldest point is overwritten and counted in
//!   `dropped`, so a long-running shard records forever without
//!   growing.
//! * **Zero disabled cost.**  A shard with `series_ms == 0` never
//!   constructs a series — the serving loop keeps its plain blocking
//!   `recv` and no clock is read.
//!
//! Points ship gateway-side as a `Report` tail and are exported as
//! Chrome trace **counter** events (`"ph":"C"`), so Perfetto shows the
//! load curves on counter tracks beside the request-lifecycle spans.

use std::time::{Duration, Instant};

/// Default ring capacity when `--series-cap` is not given.
pub const SERIES_DEFAULT_CAP: usize = 256;

/// One sample of a shard's load gauges.  `t_ms` is milliseconds since
/// the series was armed (each recording process keeps its own epoch,
/// exactly like span timestamps — trace viewers only need per-process
/// consistency).  `requests` is the *cumulative* served count at sample
/// time; rate (rps) is derived between consecutive points at export.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GaugePoint {
    pub t_ms: u64,
    pub queue_depth: u64,
    pub inflight_slots: u64,
    pub cache_bytes: u64,
    pub registry_bytes: u64,
    pub requests: u64,
}

/// Fixed-capacity gauge time-series ring with a sampling cadence.
#[derive(Debug)]
pub struct GaugeSeries {
    interval: Duration,
    cap: usize,
    epoch: Instant,
    next_due: Instant,
    points: Vec<GaugePoint>,
    /// next write slot once the ring is full (oldest-first overwrite)
    head: usize,
    dropped: u64,
}

impl GaugeSeries {
    /// A series sampling every `interval_ms` (must be > 0; gate on the
    /// config before constructing) into a ring of `cap` points.
    pub fn new(interval_ms: u64, cap: usize) -> Self {
        let interval = Duration::from_millis(interval_ms.max(1));
        let now = Instant::now();
        GaugeSeries {
            interval,
            cap: cap.max(1),
            epoch: now,
            next_due: now + interval,
            points: Vec::new(),
            head: 0,
            dropped: 0,
        }
    }

    pub fn interval(&self) -> Duration {
        self.interval
    }

    /// Is the next sample due at `now`?  The serving loop uses this (and
    /// [`GaugeSeries::until_due`]) to bound its idle `recv_timeout`.
    pub fn due(&self, now: Instant) -> bool {
        now >= self.next_due
    }

    /// Time until the next sample is due (zero when overdue).
    pub fn until_due(&self, now: Instant) -> Duration {
        self.next_due.saturating_duration_since(now)
    }

    /// Record one sample (stamping `t_ms` from the series epoch) and
    /// schedule the next.  A stalled shard that wakes late records one
    /// catch-up point rather than a backlog burst: the next due time is
    /// `now + interval`, not `next_due + interval`.
    pub fn sample(&mut self, now: Instant, mut point: GaugePoint) {
        point.t_ms = now.saturating_duration_since(self.epoch).as_millis() as u64;
        if self.points.len() < self.cap {
            self.points.push(point);
        } else {
            self.points[self.head] = point;
            self.dropped += 1;
        }
        self.head = (self.head + 1) % self.cap;
        self.next_due = now + self.interval;
    }

    /// Points lost to ring overwrites.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// The recorded points in chronological order (reassembled across
    /// the ring's wrap point) — what ships in the `Report` tail.
    pub fn snapshot(&self) -> Vec<GaugePoint> {
        let mut out = Vec::with_capacity(self.points.len());
        if self.points.len() == self.cap && self.head != 0 {
            out.extend_from_slice(&self.points[self.head..]);
            out.extend_from_slice(&self.points[..self.head]);
        } else {
            out.extend_from_slice(&self.points);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(q: u64, r: u64) -> GaugePoint {
        GaugePoint { queue_depth: q, requests: r, ..Default::default() }
    }

    #[test]
    fn samples_stamp_monotonic_times_and_keep_order() {
        let mut s = GaugeSeries::new(5, 8);
        let t0 = Instant::now();
        for i in 0..4u64 {
            s.sample(t0 + Duration::from_millis(5 * (i + 1)), pt(i, i * 2));
        }
        let snap = s.snapshot();
        assert_eq!(snap.len(), 4);
        assert!(snap.windows(2).all(|w| w[0].t_ms <= w[1].t_ms));
        assert_eq!(snap[3].queue_depth, 3);
        assert_eq!(s.dropped(), 0);
    }

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let mut s = GaugeSeries::new(1, 3);
        let t0 = Instant::now();
        for i in 0..5u64 {
            s.sample(t0 + Duration::from_millis(i + 1), pt(i, i));
        }
        let snap = s.snapshot();
        assert_eq!(snap.len(), 3, "ring is bounded at cap");
        assert_eq!(s.dropped(), 2);
        // the NEWEST points survive, chronologically ordered
        let qs: Vec<u64> = snap.iter().map(|p| p.queue_depth).collect();
        assert_eq!(qs, vec![2, 3, 4]);
    }

    #[test]
    fn due_and_catch_up_schedule() {
        let mut s = GaugeSeries::new(10, 4);
        let now = Instant::now();
        assert!(!s.due(now), "freshly armed series is not immediately due");
        let late = now + Duration::from_millis(100);
        assert!(s.due(late));
        s.sample(late, pt(0, 0));
        // one catch-up point, not a 10-point backlog burst
        assert!(!s.due(late));
        assert!(s.due(late + Duration::from_millis(10)));
        assert_eq!(s.snapshot().len(), 1);
        assert!(s.until_due(late) >= Duration::from_millis(9));
        assert_eq!(s.until_due(late + Duration::from_millis(20)), Duration::ZERO);
    }
}
