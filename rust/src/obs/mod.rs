//! `obs` — request-lifecycle tracing and mergeable fleet metrics.
//!
//! The QST paper's claims are quantitative (memory and wall-clock), yet
//! until this module the repo could only report end-of-run p50/p95 from
//! a decimated reservoir — no visibility into *where* a request spends
//! its time (queue vs. backbone GEMM vs. prefix resume vs. side net) or
//! *why* a shard stalls.  `obs` is the always-compiled, runtime-toggled
//! observability layer that closes that gap without taking a
//! dependency or perturbing results:
//!
//! * [`span`] — a per-thread ring-buffer span recorder with a fixed
//!   vocabulary covering the request lifecycle (`admit → route →
//!   shard_queue → batch_assemble → backbone → prefix_resume → sidenet
//!   → respond`) plus kernel spans (`gemm`, `qgemm`, `pool_dispatch`).
//!   Disabled cost is one relaxed atomic load per site.
//! * [`hist`] — a log-bucketed histogram whose merge is *exact*, so
//!   fleet percentiles aggregated across shards and processes are not
//!   skewed by uneven load (unlike merged decimated reservoirs).
//! * [`trace`] — Chrome trace-event JSON export (`--trace-out`,
//!   loadable in Perfetto / `chrome://tracing`).
//! * [`prom`] — Prometheus-style text exposition of the merged fleet
//!   snapshot (the gateway line protocol's `STATS` command).
//! * [`health`] — the gateway's heartbeat liveness registry
//!   (Healthy→Suspect→Dead by heartbeat age; the `HEALTH` command and
//!   the `qst_worker_up` / `qst_heartbeat_age_seconds` gauges).
//! * [`series`] — the gauge flight recorder: a fixed-capacity
//!   time-series ring of load gauges per shard, exported as Chrome
//!   trace counter tracks (`"ph":"C"`).
//!
//! **Parity invariant**: recording reads clocks and appends to rings —
//! it never touches request data, so tracing on/off cannot change one
//! output bit.  `bench-gateway` runs a traced pass and refuses to
//! serialize its report unless the responses are bit-identical to the
//! untraced pass.

pub mod health;
pub mod hist;
pub mod prom;
pub mod series;
pub mod span;
pub mod trace;

pub use hist::LogHistogram;
pub use span::{SpanKind, Span};
pub use span::{drain, enabled, end, end_backdated, set_enabled, start};

/// Serialize tests that toggle the process-global recorder (the
/// `cargo test` harness runs tests on concurrent threads, and both the
/// enable flag and the span registry are shared).  Test-only helper —
/// exported because integration tests live in a separate crate.
#[doc(hidden)]
pub fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static GATE: std::sync::Mutex<()> = std::sync::Mutex::new(());
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}
