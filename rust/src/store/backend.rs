//! Storage backends: where artifact bytes live.
//!
//! [`Storage`] is deliberately shaped like an object store — opaque ids,
//! whole-object put, length query, ranged get — so the [`LocalDir`]
//! filesystem backend can later be swapped for an S3-like remote without
//! changing the registry or the deploy path.  All methods take `&self`:
//! backends manage their own interior mutability (the registry holds one
//! behind an `Rc<dyn Storage>`).

use std::cell::RefCell;
use std::collections::HashMap;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

/// FNV-1a over a byte slice — the content address of an artifact.  Same
/// prime/offset as the checkpoint fingerprint in `serve::registry`, so a
/// fingerprint anywhere in the repo means the same function.
pub fn fingerprint_bytes(bytes: &[u8]) -> u64 {
    const FNV_PRIME: u64 = 0x100000001b3;
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Content-addressed blob storage.  `put` derives the id from the bytes
/// themselves; `read_range` is the streaming primitive everything else
/// builds on (the artifact reader issues one ranged read per section it
/// actually needs).
pub trait Storage {
    /// Store `bytes` under their content fingerprint and return it.
    /// Idempotent: putting identical bytes again returns the same id
    /// without rewriting.
    fn put(&self, bytes: &[u8]) -> Result<u64>;
    /// Total length of the object, erroring if the id is unknown.
    fn len(&self, id: u64) -> Result<u64>;
    /// Read exactly `len` bytes starting at `offset`.  Short objects are
    /// an error, never a short read.
    fn read_range(&self, id: u64, offset: u64, len: usize) -> Result<Vec<u8>>;
    /// Is this id present?
    fn contains(&self, id: u64) -> bool;
}

/// Filesystem backend: one file per artifact under a root directory,
/// named by the 16-hex-digit id.  Writes go to a temp file in the same
/// directory and land via atomic rename, so a crashed writer never
/// leaves a half-written object under a valid id and concurrent writers
/// of the same content converge on one file.
pub struct LocalDir {
    root: PathBuf,
}

impl LocalDir {
    pub fn new(root: impl Into<PathBuf>) -> Result<Self> {
        let root = root.into();
        std::fs::create_dir_all(&root)
            .with_context(|| format!("creating artifact store dir {}", root.display()))?;
        Ok(LocalDir { root })
    }

    fn object_path(&self, id: u64) -> PathBuf {
        self.root.join(format!("{id:016x}.qsta"))
    }
}

impl Storage for LocalDir {
    fn put(&self, bytes: &[u8]) -> Result<u64> {
        let id = fingerprint_bytes(bytes);
        let path = self.object_path(id);
        if path.is_file() {
            return Ok(id); // content-addressed: same bytes, same object
        }
        // unique temp name per writer, then atomic rename into place
        let tmp = self.root.join(format!(".tmp-{}-{id:016x}", std::process::id()));
        {
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(bytes).with_context(|| format!("writing {}", tmp.display()))?;
            f.sync_all().ok(); // best effort — rename is the atomicity line
        }
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("publishing artifact {id:016x} into {}", self.root.display()))?;
        Ok(id)
    }

    fn len(&self, id: u64) -> Result<u64> {
        let path = self.object_path(id);
        let meta = std::fs::metadata(&path)
            .with_context(|| format!("artifact {id:016x} not in store {}", self.root.display()))?;
        Ok(meta.len())
    }

    fn read_range(&self, id: u64, offset: u64, len: usize) -> Result<Vec<u8>> {
        let path = self.object_path(id);
        let mut f = std::fs::File::open(&path)
            .with_context(|| format!("artifact {id:016x} not in store {}", self.root.display()))?;
        f.seek(SeekFrom::Start(offset)).with_context(|| format!("seeking artifact {id:016x}"))?;
        let mut buf = vec![0u8; len];
        f.read_exact(&mut buf).with_context(|| {
            format!("artifact {id:016x} shorter than range [{offset}, {offset}+{len})")
        })?;
        Ok(buf)
    }

    fn contains(&self, id: u64) -> bool {
        self.object_path(id).is_file()
    }
}

/// In-memory backend: what a `shard-worker` keeps deployed artifacts in
/// (no disk on the worker side of a `Deploy`), and what tests use.
#[derive(Default)]
pub struct Mem {
    objects: RefCell<HashMap<u64, Vec<u8>>>,
}

impl Mem {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Storage for Mem {
    fn put(&self, bytes: &[u8]) -> Result<u64> {
        let id = fingerprint_bytes(bytes);
        self.objects.borrow_mut().entry(id).or_insert_with(|| bytes.to_vec());
        Ok(id)
    }

    fn len(&self, id: u64) -> Result<u64> {
        match self.objects.borrow().get(&id) {
            Some(b) => Ok(b.len() as u64),
            None => bail!("artifact {id:016x} not in memory store"),
        }
    }

    fn read_range(&self, id: u64, offset: u64, len: usize) -> Result<Vec<u8>> {
        let objects = self.objects.borrow();
        let Some(b) = objects.get(&id) else {
            bail!("artifact {id:016x} not in memory store");
        };
        let start = offset as usize;
        let end = start.checked_add(len).filter(|&e| e <= b.len());
        match end {
            Some(end) => Ok(b[start..end].to_vec()),
            None => bail!("artifact {id:016x} shorter than range [{offset}, {offset}+{len})"),
        }
    }

    fn contains(&self, id: u64) -> bool {
        self.objects.borrow().contains_key(&id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("qst_store_{}_{}", std::process::id(), name));
        std::fs::remove_dir_all(&d).ok();
        d
    }

    #[test]
    fn fingerprint_is_stable_and_content_sensitive() {
        assert_eq!(fingerprint_bytes(b""), 0xcbf29ce484222325);
        assert_eq!(fingerprint_bytes(b"abc"), fingerprint_bytes(b"abc"));
        assert_ne!(fingerprint_bytes(b"abc"), fingerprint_bytes(b"abd"));
        assert_ne!(fingerprint_bytes(b"abc"), fingerprint_bytes(b"ab"));
    }

    fn exercise(store: &dyn Storage) {
        let a = store.put(b"hello artifact").unwrap();
        assert_eq!(a, fingerprint_bytes(b"hello artifact"));
        assert!(store.contains(a));
        assert_eq!(store.len(a).unwrap(), 14);
        // idempotent put, ranged reads, missing-id and over-range errors
        assert_eq!(store.put(b"hello artifact").unwrap(), a);
        assert_eq!(store.read_range(a, 0, 5).unwrap(), b"hello");
        assert_eq!(store.read_range(a, 6, 8).unwrap(), b"artifact");
        assert_eq!(store.read_range(a, 0, 0).unwrap(), b"");
        assert!(store.read_range(a, 10, 5).is_err(), "over-range must error, not short-read");
        assert!(store.read_range(a, 1 << 40, 1).is_err());
        let missing = fingerprint_bytes(b"never stored");
        assert!(!store.contains(missing));
        assert!(store.len(missing).is_err());
        assert!(store.read_range(missing, 0, 1).is_err());
        // distinct contents get distinct ids and independent bytes
        let b = store.put(b"other bytes").unwrap();
        assert_ne!(a, b);
        assert_eq!(store.read_range(b, 0, 11).unwrap(), b"other bytes");
    }

    #[test]
    fn mem_backend_contract() {
        exercise(&Mem::new());
    }

    #[test]
    fn localdir_backend_contract() {
        let dir = tmpdir("contract");
        let store = LocalDir::new(&dir).unwrap();
        exercise(&store);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn localdir_survives_reopen_and_leaves_no_temp_files() {
        let dir = tmpdir("reopen");
        let id = {
            let store = LocalDir::new(&dir).unwrap();
            store.put(b"persistent").unwrap()
        };
        // a fresh handle over the same root sees the object
        let store = LocalDir::new(&dir).unwrap();
        assert!(store.contains(id));
        assert_eq!(store.read_range(id, 0, 10).unwrap(), b"persistent");
        // the atomic-rename protocol leaves no .tmp- droppings
        for entry in std::fs::read_dir(&dir).unwrap() {
            let name = entry.unwrap().file_name().into_string().unwrap();
            assert!(!name.starts_with(".tmp-"), "leftover temp file {name}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
