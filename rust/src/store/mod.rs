//! Content-addressed task-artifact store.
//!
//! Side networks stop being whole-file checkpoint loads and become
//! **artifacts**: immutable byte blobs keyed by the FNV-1a fingerprint of
//! their own contents.  Content addressing gives three properties the
//! serving stack leans on:
//!
//! * **Deduplication** — putting the same bytes twice yields the same id
//!   and stores one object.
//! * **Integrity** — an artifact id *is* its checksum, so a reader can
//!   verify what it got without a side channel.
//! * **Deploy parity** — a task pushed across the fleet as bytes and the
//!   same task loaded from a local store agree on their id, hence on the
//!   side network the engine derives; bit-identical serving falls out.
//!
//! Two layers:
//! * [`backend`] — the [`Storage`] trait (put / len / ranged read) with a
//!   [`LocalDir`] filesystem backend (temp-file + atomic rename writes)
//!   and an in-memory [`Mem`] backend for workers and tests.  The trait
//!   is shaped like an object store (S3 `PutObject` / `HeadObject` /
//!   ranged `GetObject`), so a remote backend slots in without touching
//!   callers.
//! * [`artifact`] — the sectioned artifact format: a tiny index header
//!   maps section names to `(offset, len, digest)`, so
//!   [`crate::serve::Registry`] streams exactly the sections it needs via
//!   ranged reads and never allocates the whole file.

pub mod artifact;
pub mod backend;

pub use artifact::{
    decode_tensor_section, side_artifact_from_tensors, side_artifact_synthetic, ArtifactBuilder,
    ArtifactReader, SECTION_SYNTHETIC, TENSOR_SECTION_PREFIX,
};
pub use backend::{fingerprint_bytes, LocalDir, Mem, Storage};
