//! Sectioned artifact format with an index header.
//!
//! ```text
//! "QSTA" | u16 version | u32 nsec | u32 index_len      (14-byte header)
//! nsec × ( u32 name_len | name | u64 off | u64 len | u64 digest )
//! section payloads (tightly packed, offsets absolute)
//! ```
//!
//! All integers little-endian.  The index is tiny (tens of bytes per
//! section), so [`ArtifactReader::open`] costs two ranged reads — header,
//! then index — and each [`ArtifactReader::section`] call costs exactly
//! one more, sized to that section.  Nothing ever allocates the whole
//! artifact; a registry loading one side net out of a multi-section
//! artifact reads only the bytes it will keep.
//!
//! Every section carries its own FNV-1a digest in the index, verified on
//! read — a ranged read cannot re-check the whole-object content address,
//! so integrity is per-section.
//!
//! Side-network conventions (what `serve::Registry` understands):
//! * [`SECTION_SYNTHETIC`] — 16 bytes, `u64 seed | u64 approx_bytes`; the
//!   synthetic engine derives the task function from the seed.
//! * `tensor:<name>` — `u8 dtype | u8 ndim | u64 dims[] | data`, one
//!   tensor per section so each can stream independently.

use std::collections::HashMap;

use anyhow::{bail, ensure, Context, Result};

use crate::tensor::{DType, HostTensor};

use super::backend::{fingerprint_bytes, Storage};

const MAGIC: &[u8; 4] = b"QSTA";
const VERSION: u16 = 1;
const HEADER_LEN: usize = 14;
/// Per-section index overhead beyond the name bytes: u32 name_len +
/// u64 offset + u64 len + u64 digest.
pub const INDEX_ENTRY_FIXED_BYTES: usize = 4 + 8 + 8 + 8;
/// Fixed artifact overhead: magic + version + section count + index length.
pub const ARTIFACT_HEADER_BYTES: usize = HEADER_LEN;

const MAX_SECTIONS: u32 = 1 << 16;
const MAX_SECTION_NAME: usize = 4096;
const MAX_INDEX_BYTES: u32 = 1 << 22;
const MAX_NDIM: usize = 8;

/// Section name of the synthetic side-net payload (`u64 seed | u64 bytes`).
pub const SECTION_SYNTHETIC: &str = "synthetic";
/// Prefix of per-tensor sections: `tensor:<tensor name>`.
pub const TENSOR_SECTION_PREFIX: &str = "tensor:";

/// Accumulates named sections and serializes the artifact.
#[derive(Default)]
pub struct ArtifactBuilder {
    sections: Vec<(String, Vec<u8>)>,
}

impl ArtifactBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn section(mut self, name: &str, bytes: Vec<u8>) -> Self {
        assert!(name.len() <= MAX_SECTION_NAME, "section name too long");
        assert!(
            !self.sections.iter().any(|(n, _)| n == name),
            "duplicate section '{name}'"
        );
        self.sections.push((name.to_string(), bytes));
        self
    }

    pub fn finish(self) -> Vec<u8> {
        assert!((self.sections.len() as u32) < MAX_SECTIONS, "too many sections");
        let index_len: usize = self
            .sections
            .iter()
            .map(|(n, _)| INDEX_ENTRY_FIXED_BYTES + n.len())
            .sum();
        assert!((index_len as u32) < MAX_INDEX_BYTES, "index too large");
        let mut out = Vec::with_capacity(
            HEADER_LEN + index_len + self.sections.iter().map(|(_, b)| b.len()).sum::<usize>(),
        );
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(self.sections.len() as u32).to_le_bytes());
        out.extend_from_slice(&(index_len as u32).to_le_bytes());
        let mut off = (HEADER_LEN + index_len) as u64;
        for (name, bytes) in &self.sections {
            out.extend_from_slice(&(name.len() as u32).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            out.extend_from_slice(&off.to_le_bytes());
            out.extend_from_slice(&(bytes.len() as u64).to_le_bytes());
            out.extend_from_slice(&fingerprint_bytes(bytes).to_le_bytes());
            off += bytes.len() as u64;
        }
        for (_, bytes) in &self.sections {
            out.extend_from_slice(bytes);
        }
        out
    }
}

#[derive(Clone, Debug)]
struct SectionEntry {
    name: String,
    offset: u64,
    len: u64,
    digest: u64,
}

/// Streaming view of one stored artifact: the parsed index, plus ranged
/// per-section reads that verify the index digest.
pub struct ArtifactReader {
    id: u64,
    total: u64,
    index: Vec<SectionEntry>,
}

fn le_u32(b: &[u8]) -> u32 {
    u32::from_le_bytes([b[0], b[1], b[2], b[3]])
}

fn le_u64(b: &[u8]) -> u64 {
    u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
}

impl ArtifactReader {
    /// Parse the header + index with two ranged reads.  Every length and
    /// offset is bounds-checked against the stored object, so a corrupt
    /// or hostile index errors instead of driving huge allocations.
    pub fn open(store: &dyn Storage, id: u64) -> Result<Self> {
        let total = store.len(id)?;
        ensure!(total >= HEADER_LEN as u64, "artifact {id:016x}: shorter than its header");
        let header = store.read_range(id, 0, HEADER_LEN)?;
        ensure!(&header[..4] == MAGIC, "artifact {id:016x}: bad magic");
        let version = u16::from_le_bytes([header[4], header[5]]);
        ensure!(version == VERSION, "artifact {id:016x}: version {version} (want {VERSION})");
        let nsec = le_u32(&header[6..10]);
        let index_len = le_u32(&header[10..14]);
        ensure!(nsec < MAX_SECTIONS, "artifact {id:016x}: {nsec} sections (cap {MAX_SECTIONS})");
        ensure!(
            index_len < MAX_INDEX_BYTES && (HEADER_LEN as u64 + index_len as u64) <= total,
            "artifact {id:016x}: index length {index_len} out of bounds"
        );
        // the minimal entry is the fixed fields with an empty name
        ensure!(
            (nsec as u64) * (INDEX_ENTRY_FIXED_BYTES as u64) <= index_len as u64,
            "artifact {id:016x}: {nsec} sections cannot fit a {index_len}-byte index"
        );
        let raw = store.read_range(id, HEADER_LEN as u64, index_len as usize)?;
        let mut index = Vec::with_capacity(nsec as usize);
        let mut pos = 0usize;
        for s in 0..nsec {
            ensure!(pos + 4 <= raw.len(), "artifact {id:016x}: index truncated at section {s}");
            let name_len = le_u32(&raw[pos..]) as usize;
            pos += 4;
            ensure!(
                name_len <= MAX_SECTION_NAME && pos + name_len + 24 <= raw.len(),
                "artifact {id:016x}: section {s} name length {name_len} out of bounds"
            );
            let name = std::str::from_utf8(&raw[pos..pos + name_len])
                .with_context(|| format!("artifact {id:016x}: section {s} name not utf-8"))?
                .to_string();
            pos += name_len;
            let offset = le_u64(&raw[pos..]);
            let len = le_u64(&raw[pos + 8..]);
            let digest = le_u64(&raw[pos + 16..]);
            pos += 24;
            let end = offset.checked_add(len);
            ensure!(
                end.is_some_and(|e| e <= total),
                "artifact {id:016x}: section '{name}' range [{offset}, +{len}) exceeds {total} bytes"
            );
            index.push(SectionEntry { name, offset, len, digest });
        }
        ensure!(pos == raw.len(), "artifact {id:016x}: {} trailing index bytes", raw.len() - pos);
        Ok(ArtifactReader { id, total, index })
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    /// Total stored bytes (header + index + payloads).
    pub fn total_bytes(&self) -> u64 {
        self.total
    }

    pub fn section_names(&self) -> Vec<&str> {
        self.index.iter().map(|e| e.name.as_str()).collect()
    }

    pub fn has(&self, name: &str) -> bool {
        self.index.iter().any(|e| e.name == name)
    }

    pub fn section_len(&self, name: &str) -> Option<u64> {
        self.index.iter().find(|e| e.name == name).map(|e| e.len)
    }

    /// One ranged read of exactly this section, verified against the
    /// index digest — torn writes and bit rot surface as typed errors,
    /// never as silently-wrong side weights.
    pub fn section(&self, store: &dyn Storage, name: &str) -> Result<Vec<u8>> {
        let entry = self
            .index
            .iter()
            .find(|e| e.name == name)
            .with_context(|| format!("artifact {:016x} has no section '{name}'", self.id))?;
        let bytes = store.read_range(self.id, entry.offset, entry.len as usize)?;
        ensure!(
            fingerprint_bytes(&bytes) == entry.digest,
            "artifact {:016x}: section '{name}' failed digest verification",
            self.id
        );
        Ok(bytes)
    }
}

fn dtype_code(d: DType) -> u8 {
    match d {
        DType::F32 => 0,
        DType::F16 => 1,
        DType::I32 => 2,
        DType::U32 => 3,
        DType::U8 => 4,
        DType::I8 => 5,
    }
}

fn code_dtype(c: u8) -> Result<DType> {
    Ok(match c {
        0 => DType::F32,
        1 => DType::F16,
        2 => DType::I32,
        3 => DType::U32,
        4 => DType::U8,
        5 => DType::I8,
        other => bail!("unknown dtype code {other}"),
    })
}

fn encode_tensor_section(t: &HostTensor) -> Vec<u8> {
    let mut out = Vec::with_capacity(2 + 8 * t.shape.len() + t.data.len());
    out.push(dtype_code(t.dtype));
    out.push(t.shape.len() as u8);
    for &d in &t.shape {
        out.extend_from_slice(&(d as u64).to_le_bytes());
    }
    out.extend_from_slice(&t.data);
    out
}

/// Decode one `tensor:` section payload back into a [`HostTensor`], with
/// shape/dtype/length cross-checks (a section that passed its digest can
/// still be a hostile or version-skewed encoding).
pub fn decode_tensor_section(bytes: &[u8]) -> Result<HostTensor> {
    ensure!(bytes.len() >= 2, "tensor section shorter than its dtype/ndim header");
    let dtype = code_dtype(bytes[0])?;
    let ndim = bytes[1] as usize;
    ensure!(ndim <= MAX_NDIM, "tensor section declares {ndim} dims (cap {MAX_NDIM})");
    ensure!(bytes.len() >= 2 + 8 * ndim, "tensor section truncated in its dims");
    let mut shape = Vec::with_capacity(ndim);
    let mut numel = 1u64;
    for i in 0..ndim {
        let d = le_u64(&bytes[2 + 8 * i..]);
        numel = numel.checked_mul(d).context("tensor section shape overflows")?;
        shape.push(d as usize);
    }
    let data = &bytes[2 + 8 * ndim..];
    let want = numel
        .checked_mul(dtype.size() as u64)
        .context("tensor section byte count overflows")?;
    ensure!(
        data.len() as u64 == want,
        "tensor section carries {} data bytes for a {want}-byte shape",
        data.len()
    );
    Ok(HostTensor { dtype, shape, data: data.to_vec() })
}

/// Build a side-network artifact from checkpoint-style tensors, one
/// `tensor:<name>` section per tensor in sorted-name order (so identical
/// tensor maps always serialize to identical bytes → identical ids).
pub fn side_artifact_from_tensors(tensors: &HashMap<String, HostTensor>) -> Vec<u8> {
    let mut names: Vec<&String> = tensors.keys().collect();
    names.sort();
    let mut b = ArtifactBuilder::new();
    for name in names {
        b = b.section(
            &format!("{TENSOR_SECTION_PREFIX}{name}"),
            encode_tensor_section(&tensors[name]),
        );
    }
    b.finish()
}

/// Build a synthetic side-network artifact: no tensors, just the seed the
/// engine derives the task function from and the nominal residency bytes
/// it charges the registry.
pub fn side_artifact_synthetic(seed: u64, approx_bytes: u64) -> Vec<u8> {
    let mut payload = Vec::with_capacity(16);
    payload.extend_from_slice(&seed.to_le_bytes());
    payload.extend_from_slice(&approx_bytes.to_le_bytes());
    ArtifactBuilder::new().section(SECTION_SYNTHETIC, payload).finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::backend::Mem;

    fn put(bytes: Vec<u8>) -> (Mem, u64) {
        let store = Mem::new();
        let id = store.put(&bytes).unwrap();
        (store, id)
    }

    #[test]
    fn build_open_and_stream_sections() {
        let art = ArtifactBuilder::new()
            .section("alpha", b"aaaa".to_vec())
            .section("beta", vec![])
            .section("gamma", (0..=255u8).collect())
            .finish();
        let (store, id) = put(art);
        let r = ArtifactReader::open(&store, id).unwrap();
        assert_eq!(r.id(), id);
        assert_eq!(r.section_names(), vec!["alpha", "beta", "gamma"]);
        assert_eq!(r.section_len("alpha"), Some(4));
        assert_eq!(r.section_len("beta"), Some(0));
        assert_eq!(r.section(&store, "alpha").unwrap(), b"aaaa");
        assert_eq!(r.section(&store, "beta").unwrap(), Vec::<u8>::new());
        assert_eq!(r.section(&store, "gamma").unwrap(), (0..=255u8).collect::<Vec<_>>());
        assert!(r.section(&store, "missing").is_err());
        assert!(!r.has("missing") && r.has("beta"));
    }

    #[test]
    fn section_reads_are_ranged_not_whole_file() {
        // a backend that counts the largest single read proves streaming:
        // with a multi-MiB payload next to a tiny one, reading the tiny
        // section must never touch the big one's bytes
        struct Counting {
            inner: Mem,
            max_read: std::cell::Cell<usize>,
        }
        impl Storage for Counting {
            fn put(&self, b: &[u8]) -> Result<u64> {
                self.inner.put(b)
            }
            fn len(&self, id: u64) -> Result<u64> {
                self.inner.len(id)
            }
            fn read_range(&self, id: u64, off: u64, len: usize) -> Result<Vec<u8>> {
                self.max_read.set(self.max_read.get().max(len));
                self.inner.read_range(id, off, len)
            }
            fn contains(&self, id: u64) -> bool {
                self.inner.contains(id)
            }
        }
        let big = vec![7u8; 4 << 20];
        let art = ArtifactBuilder::new()
            .section("big", big)
            .section("small", b"tiny".to_vec())
            .finish();
        let store = Counting { inner: Mem::new(), max_read: std::cell::Cell::new(0) };
        let id = store.put(&art).unwrap();
        store.max_read.set(0);
        let r = ArtifactReader::open(&store, id).unwrap();
        assert_eq!(r.section(&store, "small").unwrap(), b"tiny");
        assert!(
            store.max_read.get() < 1024,
            "largest read was {} bytes — whole-file, not streaming",
            store.max_read.get()
        );
    }

    #[test]
    fn corrupted_section_fails_digest_verification() {
        let art = ArtifactBuilder::new()
            .section("w", vec![1, 2, 3, 4, 5, 6, 7, 8])
            .finish();
        let mut evil = art.clone();
        let n = evil.len();
        evil[n - 3] ^= 0xFF; // flip a payload byte, leave index intact
        let store = Mem::new();
        let good_id = store.put(&art).unwrap();
        let evil_id = store.put(&evil).unwrap();
        assert_ne!(good_id, evil_id, "content addressing separates the two");
        let r = ArtifactReader::open(&store, evil_id).unwrap();
        let err = r.section(&store, "w").unwrap_err();
        assert!(format!("{err:#}").contains("digest"), "{err:#}");
        // the untouched artifact still verifies
        let r = ArtifactReader::open(&store, good_id).unwrap();
        assert_eq!(r.section(&store, "w").unwrap(), vec![1, 2, 3, 4, 5, 6, 7, 8]);
    }

    #[test]
    fn hostile_headers_error_instead_of_allocating() {
        let store = Mem::new();
        // too short for a header
        let id = store.put(b"QSTA").unwrap();
        assert!(ArtifactReader::open(&store, id).is_err());
        // bad magic
        let id = store.put(&[b'N', b'O', b'P', b'E', 1, 0, 0, 0, 0, 0, 0, 0, 0, 0]).unwrap();
        assert!(ArtifactReader::open(&store, id).is_err());
        // future version
        let mut v2 = ArtifactBuilder::new().section("x", vec![1]).finish();
        v2[4] = 2;
        let id = store.put(&v2).unwrap();
        assert!(ArtifactReader::open(&store, id).is_err());
        // section count ballooned past what the index can hold
        let mut huge = ArtifactBuilder::new().section("x", vec![1]).finish();
        huge[6..10].copy_from_slice(&u32::MAX.to_le_bytes());
        let id = store.put(&huge).unwrap();
        assert!(ArtifactReader::open(&store, id).is_err());
        // index length pointing past the object
        let mut long = ArtifactBuilder::new().section("x", vec![1]).finish();
        long[10..14].copy_from_slice(&1_000_000u32.to_le_bytes());
        let id = store.put(&long).unwrap();
        assert!(ArtifactReader::open(&store, id).is_err());
        // a section whose range escapes the object
        let good = ArtifactBuilder::new().section("x", vec![1, 2, 3]).finish();
        let mut escape = good.clone();
        // index entry layout after header: u32 name_len | "x" | u64 off...
        let off_pos = HEADER_LEN + 4 + 1;
        escape[off_pos..off_pos + 8].copy_from_slice(&(1u64 << 40).to_le_bytes());
        let id = store.put(&escape).unwrap();
        assert!(ArtifactReader::open(&store, id).is_err());
    }

    #[test]
    fn tensor_sections_round_trip_and_reject_skew() {
        let t = HostTensor::from_f32(&[2, 3], &[1.0, -2.5, 3.25, 0.0, 5.5, -6.75]);
        let enc = encode_tensor_section(&t);
        let back = decode_tensor_section(&enc).unwrap();
        assert_eq!(back.dtype, t.dtype);
        assert_eq!(back.shape, t.shape);
        assert_eq!(back.data, t.data);
        // truncated, hostile ndim, wrong byte count, unknown dtype
        assert!(decode_tensor_section(&[]).is_err());
        assert!(decode_tensor_section(&[0, 9]).is_err(), "ndim over cap");
        let mut short = enc.clone();
        short.pop();
        assert!(decode_tensor_section(&short).is_err());
        let mut bad_dtype = enc.clone();
        bad_dtype[0] = 200;
        assert!(decode_tensor_section(&bad_dtype).is_err());
        let mut huge_dim = enc;
        huge_dim[2..10].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(decode_tensor_section(&huge_dim).is_err(), "shape overflow must error");
    }

    #[test]
    fn side_artifacts_are_deterministic_and_self_describing() {
        let mut tensors = HashMap::new();
        tensors.insert("side.b".to_string(), HostTensor::from_f32(&[4], &[1.0; 4]));
        tensors.insert("side.a".to_string(), HostTensor::from_f32(&[2, 2], &[2.0; 4]));
        let a1 = side_artifact_from_tensors(&tensors);
        let a2 = side_artifact_from_tensors(&tensors);
        assert_eq!(a1, a2, "same tensors must serialize identically (stable ids)");
        let (store, id) = put(a1);
        let r = ArtifactReader::open(&store, id).unwrap();
        // sorted by name regardless of HashMap iteration order
        assert_eq!(r.section_names(), vec!["tensor:side.a", "tensor:side.b"]);
        let t = decode_tensor_section(&r.section(&store, "tensor:side.a").unwrap()).unwrap();
        assert_eq!(t.shape, vec![2, 2]);
        assert_eq!(t.as_f32().unwrap(), vec![2.0; 4]);

        let syn = side_artifact_synthetic(0xDEAD_BEEF, 1 << 16);
        let (store, id) = put(syn);
        let r = ArtifactReader::open(&store, id).unwrap();
        let payload = r.section(&store, SECTION_SYNTHETIC).unwrap();
        assert_eq!(payload.len(), 16);
        assert_eq!(u64::from_le_bytes(payload[..8].try_into().unwrap()), 0xDEAD_BEEF);
        assert_eq!(u64::from_le_bytes(payload[8..].try_into().unwrap()), 1 << 16);
    }
}
