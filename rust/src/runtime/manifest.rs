//! Artifact manifest parser — the line-based `.meta.txt` format emitted by
//! `python/compile/aot.py`.  The manifest is the only contract between the
//! Python build path and the Rust runtime: ordered input/output tensor specs
//! (name, dtype, shape, role) plus the model configuration echo.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::DType;

/// Role of an input/output in a graph (drives the trainer's buffer wiring).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Trainable,
    OptM,
    OptV,
    Step,
    Lr,
    Frozen,
    Data,
    Seed,
    Loss,
    Gnorm,
    Logits,
}

impl Role {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "trainable" => Role::Trainable,
            "optm" => Role::OptM,
            "optv" => Role::OptV,
            "step" => Role::Step,
            "lr" => Role::Lr,
            "frozen" => Role::Frozen,
            "data" => Role::Data,
            "seed" => Role::Seed,
            "loss" => Role::Loss,
            "gnorm" => Role::Gnorm,
            "logits" => Role::Logits,
            other => bail!("unknown role '{other}'"),
        })
    }
}

/// One input or output tensor slot.
#[derive(Clone, Debug)]
pub struct Slot {
    pub index: usize,
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub role: Role,
}

impl Slot {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Echo of the Python `ModelConfig` (subset the coordinator needs).
#[derive(Clone, Debug, Default)]
pub struct CfgEcho {
    pub fields: HashMap<String, String>,
}

impl CfgEcho {
    pub fn get(&self, k: &str) -> Option<&str> {
        self.fields.get(k).map(|s| s.as_str())
    }

    pub fn usize(&self, k: &str) -> usize {
        self.get(k).and_then(|v| v.parse().ok()).unwrap_or(0)
    }
}

/// Parsed manifest for one artifact.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub config: String,
    pub method: String,
    pub graph: String,
    pub task: String,
    pub batch: Option<(usize, usize)>,
    pub cfg: CfgEcho,
    pub inputs: Vec<Slot>,
    pub outputs: Vec<Slot>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let mut lines = text.lines();
        let header = lines.next().context("empty manifest")?;
        if header.trim() != "qst-manifest-v1" {
            bail!("bad manifest header '{header}'");
        }
        let mut m = Manifest {
            config: String::new(),
            method: String::new(),
            graph: String::new(),
            task: String::new(),
            batch: None,
            cfg: CfgEcho::default(),
            inputs: vec![],
            outputs: vec![],
        };
        for line in lines {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut parts = line.split_whitespace();
            let kind = parts.next().unwrap();
            match kind {
                "config" => m.config = parts.next().context("config")?.into(),
                "method" => m.method = parts.next().context("method")?.into(),
                "graph" => m.graph = parts.next().context("graph")?.into(),
                "task" => m.task = parts.next().context("task")?.into(),
                "batch" => {
                    let b: usize = parts.next().context("batch b")?.parse()?;
                    let s: usize = parts.next().context("batch s")?.parse()?;
                    m.batch = Some((b, s));
                }
                "cfgfield" => {
                    let k = parts.next().context("cfgfield key")?;
                    let v = parts.next().unwrap_or("");
                    m.cfg.fields.insert(k.into(), v.into());
                }
                "meta" => {
                    let k = parts.next().context("meta key")?;
                    let v = parts.next().unwrap_or("");
                    m.cfg.fields.insert(format!("meta.{k}"), v.into());
                }
                "input" | "output" => {
                    let index: usize = parts.next().context("slot index")?.parse()?;
                    let name = parts.next().context("slot name")?.to_string();
                    let dtype = DType::parse(parts.next().context("slot dtype")?)?;
                    let dims = parts.next().context("slot dims")?;
                    let shape = if dims == "scalar" {
                        vec![]
                    } else {
                        dims.split('x')
                            .map(|d| d.parse::<usize>().map_err(Into::into))
                            .collect::<Result<Vec<_>>>()?
                    };
                    let role_kv = parts.next().context("slot role")?;
                    let role = Role::parse(role_kv.strip_prefix("role=").context("role=")?)?;
                    let slot = Slot { index, name, dtype, shape, role };
                    let list = if kind == "input" { &mut m.inputs } else { &mut m.outputs };
                    if slot.index != list.len() {
                        bail!("non-contiguous slot index {} (expected {})", slot.index, list.len());
                    }
                    list.push(slot);
                }
                other => bail!("unknown manifest line kind '{other}'"),
            }
        }
        Ok(m)
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn inputs_with_role(&self, role: Role) -> impl Iterator<Item = &Slot> {
        self.inputs.iter().filter(move |s| s.role == role)
    }

    pub fn outputs_with_role(&self, role: Role) -> impl Iterator<Item = &Slot> {
        self.outputs.iter().filter(move |s| s.role == role)
    }

    /// Index of the first input with the given role.
    pub fn input_index(&self, role: Role) -> Option<usize> {
        self.inputs.iter().position(|s| s.role == role)
    }

    pub fn output_index(&self, role: Role) -> Option<usize> {
        self.outputs.iter().position(|s| s.role == role)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "qst-manifest-v1\n\
config tiny-opt\n\
method qst\n\
graph train\n\
task cls\n\
batch 8 32\n\
cfgfield d_model 128\n\
cfgfield reduction 8\n\
input 0 g.alpha f32 scalar role=trainable\n\
input 1 g.down.00.l1 f32 128x8 role=trainable\n\
input 2 opt.step f32 scalar role=step\n\
input 3 batch.tokens i32 8x32 role=data\n\
output 0 g.alpha f32 scalar role=trainable\n\
output 1 loss f32 scalar role=loss\n";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.config, "tiny-opt");
        assert_eq!(m.method, "qst");
        assert_eq!(m.batch, Some((8, 32)));
        assert_eq!(m.cfg.usize("d_model"), 128);
        assert_eq!(m.inputs.len(), 4);
        assert_eq!(m.inputs[1].shape, vec![128, 8]);
        assert_eq!(m.inputs[3].dtype, DType::I32);
        assert_eq!(m.output_index(Role::Loss), Some(1));
        assert_eq!(m.inputs_with_role(Role::Trainable).count(), 2);
    }

    #[test]
    fn rejects_bad_header() {
        assert!(Manifest::parse("nope\n").is_err());
    }

    #[test]
    fn rejects_gap_in_indices() {
        let bad = "qst-manifest-v1\ninput 1 x f32 scalar role=data\n";
        assert!(Manifest::parse(bad).is_err());
    }

    #[test]
    fn scalar_shape_is_empty() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.inputs[0].shape.is_empty());
        assert_eq!(m.inputs[0].numel(), 1);
    }
}
