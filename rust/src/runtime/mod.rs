//! PJRT runtime: load HLO-text artifacts, compile once, execute with
//! device-resident state.
//!
//! Interchange is HLO **text** (see `python/compile/aot.py`): jax ≥ 0.5 emits
//! protos with 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids.  Compiled executables are cached per artifact
//! name; training state stays on device as `PjRtBuffer`s between steps.

pub mod executor;
pub mod manifest;

use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

pub use executor::Executor;
pub use manifest::{Manifest, Role, Slot};

use crate::tensor::HostTensor;

/// Process-wide PJRT CPU client + compiled-executable cache.
pub struct Runtime {
    pub client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, Rc<Artifact>>,
}

/// One loaded artifact: manifest + compiled executable.
pub struct Artifact {
    pub name: String,
    pub manifest: Manifest,
    pub exe: xla::PjRtLoadedExecutable,
}

impl Runtime {
    pub fn new(artifact_dir: PathBuf) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, dir: artifact_dir, cache: HashMap::new() })
    }

    pub fn with_default_dir() -> Result<Self> {
        Self::new(crate::artifacts_dir())
    }

    /// List artifact names available on disk.
    pub fn available(&self) -> Vec<String> {
        let mut names = vec![];
        if let Ok(rd) = std::fs::read_dir(&self.dir) {
            for e in rd.flatten() {
                let f = e.file_name().to_string_lossy().to_string();
                if let Some(stem) = f.strip_suffix(".hlo.txt") {
                    names.push(stem.to_string());
                }
            }
        }
        names.sort();
        names
    }

    /// Load + compile an artifact (cached).
    pub fn load(&mut self, name: &str) -> Result<Rc<Artifact>> {
        if let Some(a) = self.cache.get(name) {
            return Ok(a.clone());
        }
        let hlo = self.dir.join(format!("{name}.hlo.txt"));
        let meta = self.dir.join(format!("{name}.meta.txt"));
        if !hlo.exists() {
            bail!(
                "artifact '{name}' not found in {} — run `make artifacts` first",
                self.dir.display()
            );
        }
        let manifest = Manifest::load(&meta)?;
        let proto = xla::HloModuleProto::from_text_file(hlo.to_str().unwrap())
            .with_context(|| format!("parsing HLO text for {name}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        let art = Rc::new(Artifact { name: name.to_string(), manifest, exe });
        self.cache.insert(name.to_string(), art.clone());
        Ok(art)
    }

    /// Upload a host tensor to the device.
    ///
    /// Uses `buffer_from_host_buffer` (kImmutableOnlyDuringCall: the bytes
    /// are copied synchronously) — NOT `buffer_from_host_literal`, whose
    /// transfer is async in the xla crate's shim and races with the
    /// literal's drop.
    pub fn upload(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        use crate::tensor::DType;
        let dims = &t.shape;
        let buf = match t.dtype {
            DType::F32 => self.client.buffer_from_host_buffer::<f32>(&t.as_f32()?, dims, None),
            DType::I32 => self.client.buffer_from_host_buffer::<i32>(&t.as_i32()?, dims, None),
            DType::U32 => {
                let v: Vec<u32> = t
                    .data
                    .chunks_exact(4)
                    .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                self.client.buffer_from_host_buffer::<u32>(&v, dims, None)
            }
            DType::U8 => self.client.buffer_from_host_buffer::<u8>(&t.data, dims, None),
            DType::I8 => {
                let v: Vec<i8> = t.data.iter().map(|&b| b as i8).collect();
                self.client.buffer_from_host_buffer::<i8>(&v, dims, None)
            }
            DType::F16 => anyhow::bail!("f16 upload unsupported"),
        };
        buf.context("uploading host buffer to device")
    }
}

impl Artifact {
    /// Validate that host tensors match the manifest's input slots.
    pub fn check_inputs(&self, tensors: &[HostTensor]) -> Result<()> {
        let ins = &self.manifest.inputs;
        if tensors.len() != ins.len() {
            bail!("{}: expected {} inputs, got {}", self.name, ins.len(), tensors.len());
        }
        for (t, s) in tensors.iter().zip(ins) {
            if t.shape != s.shape || t.dtype != s.dtype {
                bail!(
                    "{}: input '{}' expects {:?}{:?}, got {:?}{:?}",
                    self.name, s.name, s.dtype, s.shape, t.dtype, t.shape
                );
            }
        }
        Ok(())
    }

    /// Execute with host tensors; returns all outputs as host tensors.
    /// (Convenience path — the trainer uses the buffer path below.)
    pub fn run_host(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.check_inputs(inputs)?;
        let lits: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let out = self.exe.execute::<xla::Literal>(&lits)?;
        let result = out[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        let mut tensors = Vec::with_capacity(parts.len());
        for p in &parts {
            tensors.push(HostTensor::from_literal(p)?);
        }
        if tensors.len() != self.manifest.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest says {}",
                self.name, tensors.len(), self.manifest.outputs.len()
            );
        }
        Ok(tensors)
    }

    /// Fetch one output buffer back to the host.
    pub fn fetch(&self, buf: &xla::PjRtBuffer) -> Result<HostTensor> {
        let lit = buf.to_literal_sync()?;
        HostTensor::from_literal(&lit)
    }
}
