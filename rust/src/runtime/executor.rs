//! Step executor: persistent device-resident input slots for an artifact.
//!
//! The xla crate's PJRT shim returns multi-output computations as a single
//! tuple buffer, so state threading works as: frozen inputs are uploaded
//! **once** and stay device-resident; each step uploads only the small
//! mutable state (trainable params + optimizer moments + scalars + batch),
//! executes, and decomposes the output tuple into host literals.  Output →
//! input rewiring is by slot *name* (trainable/opt/step names match across
//! the train graph's signature by construction in `aot.py`).

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use super::{Artifact, Role, Runtime};
use crate::tensor::HostTensor;

pub struct Executor {
    pub artifact: Rc<Artifact>,
    slots: Vec<Option<xla::PjRtBuffer>>,
    /// output index -> input index for state threading (matched by name)
    rewire: Vec<(usize, usize)>,
    /// input name -> index
    by_name: HashMap<String, usize>,
    pub steps: u64,
}

impl Executor {
    pub fn new(artifact: Rc<Artifact>) -> Self {
        let m = &artifact.manifest;
        let mut by_name = HashMap::new();
        for (i, s) in m.inputs.iter().enumerate() {
            by_name.insert(s.name.clone(), i);
        }
        let mut rewire = vec![];
        for (oi, os) in m.outputs.iter().enumerate() {
            if matches!(os.role, Role::Trainable | Role::OptM | Role::OptV | Role::Step) {
                if let Some(&ii) = by_name.get(&os.name) {
                    rewire.push((oi, ii));
                }
            }
        }
        let n = m.inputs.len();
        Executor { artifact, slots: (0..n).map(|_| None).collect(), rewire, by_name, steps: 0 }
    }

    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.by_name
            .get(name)
            .copied()
            .with_context(|| format!("no input named '{name}' in {}", self.artifact.name))
    }

    /// Upload a host tensor into input slot `i` (validates the manifest spec).
    pub fn set(&mut self, rt: &Runtime, i: usize, t: &HostTensor) -> Result<()> {
        let spec = &self.artifact.manifest.inputs[i];
        if t.shape != spec.shape || t.dtype != spec.dtype {
            bail!(
                "slot {} ('{}') expects {:?}{:?}, got {:?}{:?}",
                i, spec.name, spec.dtype, spec.shape, t.dtype, t.shape
            );
        }
        self.slots[i] = Some(rt.upload(t)?);
        Ok(())
    }

    pub fn set_by_name(&mut self, rt: &Runtime, name: &str, t: &HostTensor) -> Result<()> {
        let i = self.input_index(name)?;
        self.set(rt, i, t)
    }

    /// Upload a whole named map (e.g. frozen checkpoint) into matching slots.
    /// Returns how many slots were filled.
    pub fn set_many(&mut self, rt: &Runtime, tensors: &HashMap<String, HostTensor>) -> Result<usize> {
        let mut n = 0;
        let idx: Vec<(usize, String)> = self
            .artifact
            .manifest
            .inputs
            .iter()
            .enumerate()
            .map(|(i, s)| (i, s.name.clone()))
            .collect();
        for (i, name) in idx {
            if let Some(t) = tensors.get(&name) {
                self.set(rt, i, t)?;
                n += 1;
            }
        }
        Ok(n)
    }

    /// Which input slots are still empty (must be filled before `step`).
    pub fn missing(&self) -> Vec<&str> {
        self.artifact
            .manifest
            .inputs
            .iter()
            .enumerate()
            .filter(|(i, _)| self.slots[*i].is_none())
            .map(|(_, s)| s.name.as_str())
            .collect()
    }

    /// Execute one step.  Outputs come back as host tensors; any output whose
    /// role is state (trainable/opt/step) is re-uploaded into its input slot.
    pub fn step(&mut self, rt: &Runtime) -> Result<Vec<HostTensor>> {
        let missing = self.missing();
        if !missing.is_empty() {
            bail!("{}: unset inputs: {:?}", self.artifact.name, &missing[..missing.len().min(5)]);
        }
        let bufs: Vec<&xla::PjRtBuffer> = self.slots.iter().map(|b| b.as_ref().unwrap()).collect();
        let out = self.artifact.exe.execute_b::<&xla::PjRtBuffer>(&bufs)?;
        let row = &out[0];
        let mut outputs = Vec::with_capacity(self.artifact.manifest.outputs.len());
        if row.len() == 1 && self.artifact.manifest.outputs.len() > 1 {
            // single tuple buffer: pull to host and decompose
            let mut lit = row[0].to_literal_sync()?;
            let parts = lit.decompose_tuple()?;
            for p in &parts {
                outputs.push(HostTensor::from_literal(p)?);
            }
        } else {
            for b in row {
                outputs.push(HostTensor::from_literal(&b.to_literal_sync()?)?);
            }
        }
        if outputs.len() != self.artifact.manifest.outputs.len() {
            bail!(
                "{}: output arity {} != manifest {}",
                self.artifact.name, outputs.len(), self.artifact.manifest.outputs.len()
            );
        }
        // thread state outputs back into input slots
        for &(oi, ii) in &self.rewire.clone() {
            self.set(rt, ii, &outputs[oi].clone())?;
        }
        self.steps += 1;
        Ok(outputs)
    }

    /// Read back the current value of an input slot (e.g. final params).
    pub fn read_slot(&self, i: usize) -> Result<HostTensor> {
        let buf = self.slots[i].as_ref().context("slot empty")?;
        let lit = buf.to_literal_sync()?;
        HostTensor::from_literal(&lit)
    }

    /// Collect all current tensors of a role (by input slot), keyed by name.
    pub fn read_role(&self, role: Role) -> Result<HashMap<String, HostTensor>> {
        let mut out = HashMap::new();
        for (i, s) in self.artifact.manifest.inputs.iter().enumerate() {
            if s.role == role {
                out.insert(s.name.clone(), self.read_slot(i)?);
            }
        }
        Ok(out)
    }
}
