//! The in-process gateway transport + the stdin line-protocol loop.
//!
//! [`InProc`] is the PR 4 design behind the [`Transport`] trait: N shard
//! threads, each owning a bit-identical `Server` replica behind a
//! **bounded** mpsc inbox (`try_send` — a full inbox surfaces
//! [`SubmitError::Backpressure`], so the gateway *rejects* under
//! overload instead of deadlocking or buffering without bound), all
//! emitting into one shared event channel.  Flush acks and stats
//! reports travel on that same channel as typed [`ShardEvent`]s — the
//! exact message surface the socket transport frames over the wire
//! ([`crate::proto`]), so the two transports cannot diverge semantically.
//!
//! [`line_loop`] adapts the shared stdin protocol (`<task> <tok> ...`,
//! plus `stats` — parsed by the canonical [`crate::proto::text`] codec)
//! to the asynchronous gateway: lines are submitted as fast as the
//! inboxes accept them and responses are printed as they complete, in
//! completion order.

use std::io::{BufRead, Write};
use std::sync::mpsc::{Receiver, Sender, TryRecvError};

use anyhow::{Context, Result};

use crate::proto::text::{self, TextLine};
use crate::proto::transport::recv_event;
use crate::proto::{GatewayResponse, Request, ShardEvent, ShardMsg, SubmitError, Transport};

use super::shard::ShardHandle;
use super::{Gateway, GatewayConfig};

/// [`Transport`] over shard threads in this process (see module docs).
pub struct InProc {
    shards: Vec<ShardHandle>,
    /// shard deaths already surfaced through `recv` — each is reported
    /// exactly once, so one lost shard doesn't poison every later
    /// barrier the healthy shards could still answer
    dead_reported: Vec<bool>,
    events: Receiver<ShardEvent>,
}

impl InProc {
    /// Spawn the shard fleet; shard `i` serves `cfg.shard_spec()` behind
    /// a `cfg.queue_cap`-slot inbox.
    pub fn spawn(cfg: &GatewayConfig) -> InProc {
        let (ev_tx, ev_rx): (Sender<ShardEvent>, Receiver<ShardEvent>) =
            std::sync::mpsc::channel();
        let spec = cfg.shard_spec();
        let shards: Vec<ShardHandle> = (0..cfg.shards)
            .map(|i| ShardHandle::spawn(i, spec, cfg.queue_cap, ev_tx.clone()))
            .collect();
        let dead_reported = vec![false; shards.len()];
        InProc { shards, dead_reported, events: ev_rx }
    }
}

impl Transport for InProc {
    fn shards(&self) -> usize {
        self.shards.len()
    }

    fn submit(&mut self, shard: usize, req: Request) -> Result<(), SubmitError> {
        self.shards[shard].try_submit(req)
    }

    fn try_recv(&mut self) -> Option<ShardEvent> {
        match self.events.try_recv() {
            Ok(ev) => Some(ev),
            Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => None,
        }
    }

    fn recv(&mut self) -> Result<ShardEvent> {
        // a shard thread only exits early by dying (panic mid-drain);
        // with the event queue drained its outcomes can never arrive.
        // Each death is reported once (see `dead_reported`).
        let shards = &self.shards;
        let dead_reported = &mut self.dead_reported;
        recv_event(&self.events, "a shard thread likely died mid-request", move || {
            shards
                .iter()
                .enumerate()
                .find(|(i, s)| s.is_dead() && !dead_reported[*i])
                .map(|(i, s)| {
                    dead_reported[i] = true;
                    format!("gateway shard {} thread died while events were awaited", s.index)
                })
        })
    }

    fn start_flush(&mut self) -> usize {
        self.shards.iter().filter(|s| s.send(ShardMsg::Flush)).count()
    }

    fn start_report(&mut self) -> usize {
        self.shards.iter().filter(|s| s.send(ShardMsg::Report)).count()
    }

    fn start_deploy(&mut self, task: &str, artifact: &[u8]) -> usize {
        self.shards
            .iter()
            .filter(|s| {
                s.send(ShardMsg::Deploy { task: task.to_string(), artifact: artifact.to_vec() })
            })
            .count()
    }

    fn shutdown(&mut self) -> Result<()> {
        for s in &mut self.shards {
            s.stop();
        }
        Ok(())
    }
}

fn print_responses(out: &mut impl Write, responses: &[GatewayResponse]) -> Result<()> {
    for gr in responses {
        writeln!(out, "{}", text::format_response(&gr.resp, Some(gr.shard)))?;
    }
    Ok(())
}

/// Drive a gateway over the line protocol: one request per line
/// (`<task> <tok> <tok> ...`), `stats` for a merged fleet summary.
/// Submission is asynchronous — a line is accepted the moment its shard
/// inbox has room, and completed responses are printed as they arrive
/// (completion order, tagged with ids).  On backpressure the loop drains
/// whatever has completed and retries the line, so input is never
/// dropped.  Returns after EOF once every outstanding request has been
/// answered.  Works identically over in-proc and socket transports.
pub fn line_loop(gw: &mut Gateway, input: impl BufRead, out: &mut impl Write) -> Result<()> {
    for line in input.lines() {
        let line = line.context("reading request line")?;
        let (task, tokens) = match text::parse_line(&line) {
            Ok(TextLine::Empty) => continue,
            Ok(TextLine::Stats) => {
                let report = gw.report()?;
                writeln!(out, "{}", report.summary())?;
                continue;
            }
            Ok(TextLine::Prom) => {
                let report = gw.report()?;
                let gauges = crate::obs::prom::GatewayGauges {
                    submitted: gw.submitted,
                    rejected: gw.rejected,
                    dropped: gw.dropped,
                    in_flight: gw.in_flight() as u64,
                };
                // render() ends each sample with \n; no extra newline
                write!(out, "{}", crate::obs::prom::render(&report, &gauges, Some(gw.health())))?;
                continue;
            }
            Ok(TextLine::Health) => {
                // liveness is judged from heartbeats already absorbed; drain
                // the event queue first so the freshest beats count, but do
                // NOT barrier on a report — HEALTH must answer even when a
                // dead shard would stall the report rendezvous
                let done = gw.try_collect();
                print_responses(out, &done)?;
                writeln!(out, "{}", gw.health().to_json())?;
                continue;
            }
            Ok(TextLine::Request { task, tokens }) => (task, tokens),
            Err(e) => {
                eprintln!("{e}");
                continue;
            }
        };
        loop {
            match gw.submit(&task, &tokens) {
                Ok(_) => break,
                Err(SubmitError::Backpressure { .. }) => {
                    // the routed shard's inbox is full: surface whatever has
                    // completed and retry shortly — no fleet-wide barrier, so
                    // the other shards keep eating while this one catches up
                    let done = gw.try_collect();
                    print_responses(out, &done)?;
                    std::thread::sleep(std::time::Duration::from_micros(200));
                }
                Err(e) => {
                    eprintln!("rejected: {e}");
                    break;
                }
            }
        }
        let done = gw.try_collect();
        print_responses(out, &done)?;
    }
    // EOF: answer everything still in flight
    let done = gw.flush()?;
    print_responses(out, &done)?;
    let report = gw.report()?;
    writeln!(out, "{}", report.summary())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gateway::GatewayConfig;

    #[test]
    fn line_loop_serves_parses_and_reports() {
        let cfg = GatewayConfig { shards: 2, seq: 16, ..GatewayConfig::default() };
        let mut gw = Gateway::launch(&cfg).unwrap();
        let input =
            b"task0 5 6 7\n\nbogus-line x y\ntask1 5 6 7\nnosuchtask 1\nstats\nSTATS\n" as &[u8];
        let mut out = Vec::new();
        line_loop(&mut gw, input, &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        // both well-formed requests answered (ids 0 and 1), each tagged
        assert!(text.contains("task0#0"), "{text}");
        assert!(text.contains("task1#1"), "{text}");
        // stats line + final summary
        assert!(text.matches("req").count() >= 2, "{text}");
        // STATS returns the Prometheus exposition with exact fleet counts
        assert!(text.contains("qst_requests_total 2"), "{text}");
        assert!(text.contains("qst_request_latency_seconds_count 2"), "{text}");
        let (report, leftover) = gw.shutdown().unwrap();
        assert!(leftover.is_empty());
        assert_eq!(report.merged.requests, 2);
    }

    #[test]
    fn inproc_flush_ack_follows_outcomes() {
        let cfg = GatewayConfig { shards: 1, seq: 16, ..GatewayConfig::default() };
        let mut t = InProc::spawn(&cfg);
        t.submit(0, Request { id: 5, task: "task0".into(), tokens: vec![1, 2] }).unwrap();
        assert_eq!(t.start_flush(), 1);
        assert!(matches!(t.recv().unwrap(), ShardEvent::Done(_)));
        assert!(matches!(t.recv().unwrap(), ShardEvent::FlushAck { shard: 0 }));
        assert_eq!(t.start_report(), 1);
        assert!(matches!(t.recv().unwrap(), ShardEvent::Report(_)));
        t.shutdown().unwrap();
    }
}
