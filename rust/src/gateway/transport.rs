//! Gateway transport: non-blocking request intake over bounded channels.
//!
//! The pre-gateway `qst serve` loop was synchronous — read a line, maybe
//! drain, print.  The gateway decouples submission from execution: a
//! request is routed to a shard's **bounded** inbox (`try_send`, never
//! blocking), the shard thread batches and serves it, and the completed
//! response comes back on a shared event channel whenever it is ready.
//! A full inbox is surfaced as [`SubmitError::Backpressure`] — the
//! caller's signal to collect responses and retry — so the gateway
//! *rejects* under overload instead of deadlocking or buffering without
//! bound.
//!
//! [`line_loop`] adapts the same stdin protocol `qst serve` speaks
//! (`<task> <tok> <tok> ...`, plus `stats`) to this asynchronous path for
//! `qst gateway`: lines are submitted as fast as the inboxes accept them
//! and responses are printed as they complete, in completion order.

use std::io::{BufRead, Write};

use anyhow::{Context, Result};

use super::Gateway;
use crate::serve::Response;

/// One request as it travels to a shard: the gateway-assigned id survives
/// the trip (shards rewrite their server-local ids back to this one).
#[derive(Clone, Debug)]
pub struct GatewayRequest {
    pub id: u64,
    pub task: String,
    pub tokens: Vec<i32>,
}

/// A completed request, tagged with the shard that served it.
#[derive(Clone, Debug)]
pub struct GatewayResponse {
    pub shard: usize,
    pub resp: Response,
}

/// Control + data messages into one shard thread (bounded inbox).
pub enum ShardMsg {
    Submit(GatewayRequest),
    /// drain everything pending, emit the results, then ack
    Flush(std::sync::mpsc::Sender<()>),
    /// snapshot serving stats + cache/engine counters
    Report(std::sync::mpsc::Sender<super::shard::ShardReport>),
    /// drain, emit, and exit the shard thread
    Shutdown,
}

/// Events out of shard threads (shared unbounded channel, so a shard can
/// never deadlock against a slow collector).
pub enum ShardEvent {
    Done(GatewayResponse),
    /// requests dropped inside a failing micro-batch (count only; the
    /// server logs the cause)
    Dropped { shard: usize, n: usize },
    /// a submit the shard's server refused — belt-and-braces: the gateway
    /// validates task and length before routing, so this signals a bug or
    /// a mid-flight deregistration rather than routine traffic
    Rejected { shard: usize, id: u64, err: String },
}

/// Why [`Gateway::submit`] refused a request.
#[derive(Debug)]
pub enum SubmitError {
    /// the routed shard's inbox is at capacity — collect responses and
    /// retry; the queue is bounded by design (reject, don't deadlock)
    Backpressure { shard: usize },
    /// malformed request (unknown task or over-length prompt)
    Invalid(String),
    /// the routed shard's thread is gone
    ShardDown { shard: usize },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Backpressure { shard } => {
                write!(f, "shard {shard} inbox full (backpressure — retry after collecting)")
            }
            SubmitError::Invalid(msg) => write!(f, "{msg}"),
            SubmitError::ShardDown { shard } => write!(f, "shard {shard} is down"),
        }
    }
}

impl std::error::Error for SubmitError {}

fn print_responses(out: &mut impl Write, responses: &[GatewayResponse]) -> Result<()> {
    for gr in responses {
        let (tok, logit) = gr.resp.top1();
        writeln!(
            out,
            "{}#{}: next-token {} (logit {:.4}) [shard {}{}]",
            gr.resp.task,
            gr.resp.id,
            tok,
            logit,
            gr.shard,
            if gr.resp.cache_hit { ", cache hit" } else { "" }
        )?;
    }
    Ok(())
}

/// Drive a gateway over the line protocol: one request per line
/// (`<task> <tok> <tok> ...`), `stats` for a merged fleet summary.
/// Submission is asynchronous — a line is accepted the moment its shard
/// inbox has room, and completed responses are printed as they arrive
/// (completion order, tagged with ids).  On backpressure the loop flushes
/// the fleet (collecting every outstanding response) and retries the
/// line, so input is never dropped.  Returns after EOF once every
/// outstanding request has been answered.
pub fn line_loop(gw: &mut Gateway, input: impl BufRead, out: &mut impl Write) -> Result<()> {
    for line in input.lines() {
        let line = line.context("reading request line")?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if line == "stats" {
            let report = gw.report()?;
            writeln!(out, "{}", report.summary())?;
            continue;
        }
        let mut parts = line.split_whitespace();
        let task = parts.next().unwrap().to_string();
        let tokens: Vec<i32> = match parts.map(|t| t.parse()).collect::<Result<_, _>>() {
            Ok(t) => t,
            Err(e) => {
                eprintln!("bad request (tokens must be integers): {e}");
                continue;
            }
        };
        loop {
            match gw.submit(&task, &tokens) {
                Ok(_) => break,
                Err(SubmitError::Backpressure { .. }) => {
                    // the routed shard's inbox is full: surface whatever has
                    // completed and retry shortly — no fleet-wide barrier, so
                    // the other shards keep eating while this one catches up
                    let done = gw.try_collect();
                    print_responses(out, &done)?;
                    std::thread::sleep(std::time::Duration::from_micros(200));
                }
                Err(e) => {
                    eprintln!("rejected: {e}");
                    break;
                }
            }
        }
        let done = gw.try_collect();
        print_responses(out, &done)?;
    }
    // EOF: answer everything still in flight
    let done = gw.flush()?;
    print_responses(out, &done)?;
    let report = gw.report()?;
    writeln!(out, "{}", report.summary())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gateway::GatewayConfig;

    #[test]
    fn submit_error_displays() {
        assert!(format!("{}", SubmitError::Backpressure { shard: 3 }).contains("shard 3"));
        assert!(format!("{}", SubmitError::Invalid("nope".into())).contains("nope"));
        assert!(format!("{}", SubmitError::ShardDown { shard: 1 }).contains("down"));
    }

    #[test]
    fn line_loop_serves_parses_and_reports() {
        let cfg = GatewayConfig { shards: 2, seq: 16, ..GatewayConfig::default() };
        let mut gw = Gateway::launch(&cfg).unwrap();
        let input = b"task0 5 6 7\n\nbogus-line x y\ntask1 5 6 7\nnosuchtask 1\nstats\n" as &[u8];
        let mut out = Vec::new();
        line_loop(&mut gw, input, &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        // both well-formed requests answered (ids 0 and 1), each tagged
        assert!(text.contains("task0#0"), "{text}");
        assert!(text.contains("task1#1"), "{text}");
        // stats line + final summary
        assert!(text.matches("req").count() >= 2, "{text}");
        let (report, leftover) = gw.shutdown().unwrap();
        assert!(leftover.is_empty());
        assert_eq!(report.merged.requests, 2);
    }
}
