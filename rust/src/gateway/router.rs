//! Prompt→shard routing and per-shard report aggregation.
//!
//! Each shard owns a private replica of the frozen backbone and a private
//! hidden-state cache, so the router's one job is **cache locality**: a
//! prompt must land on the shard most likely to already hold its hidden
//! states.  Routing therefore hashes only the prompt's *head* — its first
//! `block` tokens, the same block size the prefix index keys on — so
//! exact repeats AND prefix-sharing families of prompts all map to one
//! shard, where the whole-prompt cache and the per-block prefix index can
//! serve them.  Because every replica computes bit-identical results, the
//! routing choice affects only wall-clock, never logits (pinned by the
//! sharded-vs-single-shard parity tests).

use crate::proto::ShardReport;
use crate::serve::cache::prompt_key;
use crate::serve::StatsSnapshot;

/// Salt for the routing hash: routing must not correlate with cache keys
/// (same tokens, different purpose), so it gets its own backbone-id slot.
const ROUTE_SALT: u64 = 0x5248_4153_4852_4400; // "RHASHRD"

/// Deterministic prompt→shard router (see module doc).
#[derive(Clone, Copy, Debug)]
pub struct Router {
    shards: usize,
    /// head length the route key hashes; 0 = hash the whole prompt
    /// (still groups exact repeats, but not prefix families)
    block: usize,
}

impl Router {
    pub fn new(shards: usize, block: usize) -> Self {
        Router { shards: shards.max(1), block }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Shard index for a prompt (unpadded tokens).
    pub fn route(&self, tokens: &[i32]) -> usize {
        let head = if self.block == 0 { tokens } else { &tokens[..tokens.len().min(self.block)] };
        (prompt_key(ROUTE_SALT, head) % self.shards as u64) as usize
    }
}

/// Fleet-wide view: per-shard reports plus their merged serving stats and
/// summed cache/engine counters.
#[derive(Clone, Debug, Default)]
pub struct GatewayReport {
    /// per-shard reports, sorted by shard index
    pub shards: Vec<ShardReport>,
    /// merged serving stats (requests, latency percentiles, …)
    pub merged: StatsSnapshot,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub prefix_hits: u64,
    pub cache_evictions: u64,
    pub backbone_rows: u64,
    pub resumed_rows: u64,
    pub resumed_positions: u64,
    /// summed resident backbone bytes — one replica per shard
    pub backbone_resident_bytes: usize,
    pub cache_bytes: usize,
    pub registry_bytes: usize,
    /// spans lost to recorder ring overwrites, summed across shards
    /// (from the report tail each worker fills in)
    pub spans_dropped: u64,
    /// side networks evicted under the registry budget, summed fleet-wide
    pub registry_evictions: u64,
    /// cold side-network load (swap-in) latency, merged across shards
    pub swap_hist: crate::obs::LogHistogram,
}

impl GatewayReport {
    pub fn hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// Share of whole-prompt misses rescued by a prefix resume.
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.cache_misses == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / self.cache_misses as f64
        }
    }

    /// One-line human summary for the CLI.
    pub fn summary(&self) -> String {
        format!(
            "gateway [{} shards]: {} req in {} batches | p50 {:.2} ms, p95 {:.2} ms | cache hit {:.1}%, prefix rescue {:.1}% ({} resumes) | {} full + {} resumed backbone rows | backbone {} resident total{}",
            self.shards.len(),
            self.merged.requests,
            self.merged.batches,
            self.merged.p50_secs() * 1e3,
            self.merged.p95_secs() * 1e3,
            self.hit_rate() * 100.0,
            self.prefix_hit_rate() * 100.0,
            self.resumed_rows,
            self.backbone_rows,
            self.resumed_rows,
            crate::util::human_bytes(self.backbone_resident_bytes as f64),
            if self.merged.dropped > 0 {
                format!(" | {} dropped", self.merged.dropped)
            } else {
                String::new()
            }
        )
    }

    /// Multi-line top-K per-task accounting table for the CLI (empty
    /// string when no per-task rows were recorded).  Tasks sort by
    /// request count, ties by name — the count-weighted merge across
    /// shards happened in [`StatsSnapshot::merge`].
    pub fn task_table(&self, k: usize) -> String {
        let top = self.merged.top_tasks(k);
        if top.is_empty() {
            return String::new();
        }
        let mut out = String::from("task            requests    tokens  cache-hits  swap-ins\n");
        for t in top {
            out.push_str(&format!(
                "{:<14} {:>9} {:>9} {:>11} {:>9}\n",
                t.task, t.requests, t.tokens, t.cache_hits, t.swap_ins
            ));
        }
        out
    }
}

/// Merge per-shard reports into the fleet view (`reports` in any order;
/// the result keeps them sorted by shard index).
pub fn aggregate(mut reports: Vec<ShardReport>) -> GatewayReport {
    reports.sort_by_key(|r| r.shard);
    let mut g = GatewayReport::default();
    for r in &reports {
        g.merged.merge(&r.stats);
        g.cache_hits += r.cache_hits;
        g.cache_misses += r.cache_misses;
        g.prefix_hits += r.prefix_hits;
        g.cache_evictions += r.cache_evictions;
        g.backbone_rows += r.backbone_rows;
        g.resumed_rows += r.resumed_rows;
        g.resumed_positions += r.resumed_positions;
        g.backbone_resident_bytes += r.backbone_resident_bytes;
        g.cache_bytes += r.cache_bytes;
        g.registry_bytes += r.registry_bytes;
        g.spans_dropped += r.spans_dropped;
        g.registry_evictions += r.registry_evictions;
        g.swap_hist.merge(&r.swap_hist);
    }
    g.shards = reports;
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn routes_are_deterministic_and_in_range() {
        let r = Router::new(4, 8);
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let len = rng.range(1, 24);
            let p: Vec<i32> = (0..len).map(|_| rng.range(1, 256) as i32).collect();
            let s = r.route(&p);
            assert!(s < 4);
            assert_eq!(s, r.route(&p), "routing must be deterministic");
        }
    }

    #[test]
    fn prefix_families_and_repeats_share_a_shard() {
        let r = Router::new(4, 8);
        let prefix: Vec<i32> = (1..=8).collect();
        let mut family_shards = std::collections::HashSet::new();
        for tail in 0..16 {
            let mut p = prefix.clone();
            p.extend([100 + tail, 200 + tail]);
            family_shards.insert(r.route(&p));
        }
        assert_eq!(family_shards.len(), 1, "one family must map to one shard");
        // whole-prompt hashing (block 0) still groups exact repeats
        let r0 = Router::new(4, 0);
        let p: Vec<i32> = (5..25).collect();
        assert_eq!(r0.route(&p), r0.route(&p));
    }

    #[test]
    fn load_spreads_across_shards() {
        let r = Router::new(4, 8);
        let mut rng = Rng::new(9);
        let mut used = std::collections::HashSet::new();
        for _ in 0..256 {
            let p: Vec<i32> = (0..12).map(|_| rng.range(1, 512) as i32).collect();
            used.insert(r.route(&p));
        }
        assert_eq!(used.len(), 4, "256 random prompts must reach every shard");
    }

    #[test]
    fn single_shard_router_is_total() {
        let r = Router::new(1, 8);
        assert_eq!(r.route(&[1, 2, 3]), 0);
        assert_eq!(r.route(&[]), 0);
        // shards clamp to >= 1
        assert_eq!(Router::new(0, 8).shards(), 1);
    }

    #[test]
    fn aggregate_sums_and_sorts() {
        let mk = |shard: usize, hits: u64| {
            let mut r = ShardReport::default();
            r.shard = shard;
            r.cache_hits = hits;
            r.cache_misses = 10 - hits;
            r.backbone_resident_bytes = 100;
            r.registry_evictions = hits;
            r.swap_hist.record(0.01);
            r
        };
        let g = aggregate(vec![mk(1, 4), mk(0, 6)]);
        assert_eq!(g.shards[0].shard, 0);
        assert_eq!(g.cache_hits, 10);
        assert_eq!(g.cache_misses, 10);
        assert!((g.hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(g.backbone_resident_bytes, 200);
        assert_eq!(g.registry_evictions, 10);
        assert_eq!(g.swap_hist.count(), 2, "swap-in histograms merge fleet-wide");
        assert_eq!(GatewayReport::default().hit_rate(), 0.0);
        assert_eq!(GatewayReport::default().prefix_hit_rate(), 0.0);
    }

    #[test]
    fn aggregate_merges_task_ledgers_and_span_drops() {
        use crate::serve::TaskStat;
        let mk = |shard: usize, reqs: u64, dropped: u64| {
            let mut r = ShardReport::default();
            r.shard = shard;
            r.spans_dropped = dropped;
            r.stats.tasks = vec![TaskStat {
                task: "task0".into(),
                requests: reqs,
                tokens: reqs * 4,
                cache_hits: 1,
                swap_ins: 0,
            }];
            r
        };
        let g = aggregate(vec![mk(0, 3, 2), mk(1, 5, 7)]);
        assert_eq!(g.spans_dropped, 9);
        assert_eq!(g.merged.tasks.len(), 1, "same task merges across shards");
        assert_eq!(g.merged.tasks[0].requests, 8);
        assert_eq!(g.merged.tasks[0].tokens, 32);
        assert_eq!(g.merged.tasks[0].cache_hits, 2);
        let table = g.task_table(8);
        assert!(table.contains("task0"));
        assert!(table.lines().count() >= 2, "header plus one row");
        assert_eq!(GatewayReport::default().task_table(8), "");
    }
}
