//! `bench-registry`: registry churn under a 1000-task Zipf request mix,
//! gated by live-Deploy parity.
//!
//! The workload models a large multi-tenant catalog: `tasks` synthetic
//! side-network artifacts are written into a content-addressed
//! [`crate::store`] backend (a real [`LocalDir`] under a scratch dir, so
//! every cold load crosses the file-backed streaming read path), then
//! registered against a registry whose byte budget is a small percent
//! (`budget_pct`, enforced < 10) of the catalog's resident footprint.
//! A Zipf-distributed request stream ([`Zipf`], seeded) then hammers the
//! registry: hot ranks stay resident, the long tail thrashes through
//! LRU eviction, and every cold load lands in the registry's swap-in
//! histogram — the p50/p95, hit rate, eviction count, and resident
//! bytes this bench reports.
//!
//! Before anything is serialized, a **deploy-parity gate** runs: a fresh
//! artifact is pushed with [`Gateway::deploy`] to a live 2-worker
//! *socket* fleet (real wire framing via [`spawn_local_fleet`]) and the
//! same artifact is registered from a store by a direct single `Server`
//! — the restart-loaded replica.  Both serve the same prompt stream; the
//! FNV-folded logit digests must match bit-for-bit or `run_bench`
//! refuses to produce a report at all.  `BENCH_registry.json` therefore
//! can only ever record runs where live deployment is provably
//! equivalent to a restart.

use anyhow::{ensure, Context, Result};
use std::rc::Rc;

use crate::proto::TransportKind;
use crate::serve::workload::{prompt_pool, prompt_pool_capacity, Zipf};
use crate::serve::{EnginePreset, ServeConfig, Server};
use crate::store::{fingerprint_bytes, side_artifact_synthetic, LocalDir, Storage};
use crate::util::rng::Rng;

use super::worker::launch_gateway;
use super::{task_name, task_seed, GatewayConfig};

/// Resident bytes each synthetic task charges against the registry
/// budget (the artifact on disk is a few dozen bytes; the *declared*
/// footprint is what the LRU arbitrates).
pub const TASK_RESIDENT_BYTES: usize = 1 << 16;

#[derive(Clone, Debug)]
pub struct BenchRegistryOpts {
    /// catalog size (the acceptance floor is 1000)
    pub tasks: usize,
    /// Zipf-sampled requests driven through the registry
    pub requests: usize,
    /// Zipf exponent (1.0 = classic rank-inverse popularity)
    pub zipf_s: f64,
    /// registry budget as a percent of catalog resident bytes; must stay
    /// below 10 so the bench always measures churn, never full residency
    pub budget_pct: usize,
    pub seq: usize,
    pub prompt_len: usize,
    pub max_batch: usize,
    /// distinct prompts served by BOTH legs of the deploy-parity gate
    pub parity_requests: usize,
    pub seed: u64,
    pub threads: usize,
}

impl Default for BenchRegistryOpts {
    fn default() -> Self {
        BenchRegistryOpts {
            tasks: 1000,
            requests: 3000,
            zipf_s: 1.0,
            budget_pct: 8,
            seq: 32,
            prompt_len: 12,
            max_batch: 8,
            parity_requests: 24,
            seed: 0,
            threads: 1,
        }
    }
}

#[derive(Clone, Debug)]
pub struct BenchRegistryReport {
    pub opts: BenchRegistryOpts,
    /// summed declared resident footprint of the whole catalog
    pub catalog_bytes: u64,
    pub budget_bytes: u64,
    pub wall_secs: f64,
    pub requests_per_sec: f64,
    /// cold side-network loads over the whole run (registration included)
    pub swap_ins: u64,
    pub swap_in_p50_ms: f64,
    pub swap_in_p95_ms: f64,
    /// share of requests answered by an already-resident side network
    pub hit_rate: f64,
    pub evictions: u64,
    pub resident_bytes: u64,
    pub resident_tasks: usize,
    /// content digest of the artifact the parity gate deployed
    pub deploy_digest: u64,
}

impl BenchRegistryReport {
    pub fn to_json(&self) -> String {
        crate::benchkit::Json::new()
            .provenance()
            .str("bench", "registry")
            .int("tasks", self.opts.tasks as u64)
            .int("requests", self.opts.requests as u64)
            .num("zipf_s", self.opts.zipf_s)
            .int("budget_pct", self.opts.budget_pct as u64)
            .int("catalog_bytes", self.catalog_bytes)
            .int("budget_bytes", self.budget_bytes)
            .int("seed", self.opts.seed)
            .int("threads", self.opts.threads as u64)
            .num("requests_per_sec", self.requests_per_sec)
            .int("swap_ins", self.swap_ins)
            .num("swap_in_p50_ms", self.swap_in_p50_ms)
            .num("swap_in_p95_ms", self.swap_in_p95_ms)
            .num("hit_rate", self.hit_rate)
            .int("evictions", self.evictions)
            .int("resident_bytes", self.resident_bytes)
            .int("resident_tasks", self.resident_tasks as u64)
            // run_bench refuses to return otherwise, so this is always 1
            // when present — recorded so the JSON is self-auditing
            .int("deploy_parity", 1)
            .finish()
    }

    pub fn summary(&self) -> String {
        format!(
            "registry bench: {} tasks ({} catalog) under {} budget ({}%) | {} req ({:.1} req/s) | hit {:.1}%, {} swap-ins (p50 {:.3} ms, p95 {:.3} ms), {} evictions | {} resident as {} task(s) | deploy parity ok ({:016x})",
            self.opts.tasks,
            crate::util::human_bytes(self.catalog_bytes as f64),
            crate::util::human_bytes(self.budget_bytes as f64),
            self.opts.budget_pct,
            self.opts.requests,
            self.requests_per_sec,
            self.hit_rate * 100.0,
            self.swap_ins,
            self.swap_in_p50_ms,
            self.swap_in_p95_ms,
            self.evictions,
            crate::util::human_bytes(self.resident_bytes as f64),
            self.resident_tasks,
            self.deploy_digest,
        )
    }
}

/// FNV-1a fold step over one 64-bit value.
fn fnv(h: u64, v: u64) -> u64 {
    (h ^ v).wrapping_mul(0x0000_0100_0000_01B3)
}

/// Digest a response set independent of completion order: fold (id,
/// logit bits) sorted by request id.
fn digest_responses(mut pairs: Vec<(u64, Vec<f32>)>) -> u64 {
    pairs.sort_by_key(|(id, _)| *id);
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for (id, logits) in &pairs {
        h = fnv(h, *id);
        for &v in logits {
            h = fnv(h, v.to_bits() as u64);
        }
    }
    h
}

/// The parity gate: deploy `artifact` live to a 2-worker socket fleet,
/// register the same bytes from a store into a fresh single server (the
/// restart path), serve the same prompts through both, and return the
/// two digests plus the fleet-reported deploy digest.
fn deploy_parity(opts: &BenchRegistryOpts, artifact: &[u8]) -> Result<(u64, u64, u64)> {
    let cfg = GatewayConfig {
        shards: 2,
        queue_cap: 64,
        serve: ServeConfig {
            cache_bytes: 0, // cache is parity-invisible; keep the legs minimal
            registry_bytes: 64 << 20,
            max_batch: opts.max_batch,
            prefix_block: 0,
        },
        preset: EnginePreset::Small,
        backbone: crate::serve::BackboneKind::F32,
        seed: opts.seed,
        seq: opts.seq,
        tasks: 1,
        threads_per_shard: opts.threads,
        trace: false,
        heartbeat_ms: 0,
        health_mult: crate::obs::health::DEFAULT_HEALTH_MULT,
        series_ms: 0,
        series_cap: crate::obs::series::SERIES_DEFAULT_CAP,
    };
    let mut rng = Rng::new(opts.seed.wrapping_add(0xDE91));
    let vocab = cfg.preset.vocab();
    let n = opts.parity_requests.max(1).min(prompt_pool_capacity(opts.prompt_len, vocab));
    let prompts = prompt_pool(&mut rng, n, opts.prompt_len, vocab);

    // leg 1: live Deploy into a running socket fleet
    let (mut gw, joins) = launch_gateway(&cfg, TransportKind::Socket)?;
    let deployed_digest = gw.deploy("deployed", artifact).context("fleet-wide deploy")?;
    let mut fleet_pairs = Vec::with_capacity(prompts.len());
    for p in &prompts {
        gw.submit("deployed", p).map_err(anyhow::Error::from)?;
    }
    for gr in gw.flush()? {
        fleet_pairs.push((gr.resp.id, gr.resp.logits.clone()));
    }
    ensure!(fleet_pairs.len() == prompts.len(), "parity fleet lost responses");
    let (_report, leftover) = gw.shutdown()?;
    ensure!(leftover.is_empty(), "parity fleet left responses behind");
    for j in joins {
        let _ = j.join();
    }

    // leg 2: the restart path — a fresh server loads the same bytes
    // through the content-addressed store
    let mut engine = cfg.preset.build_backbone(cfg.seed, cfg.seq, cfg.backbone);
    engine.set_threads(opts.threads);
    let mut server = Server::new(engine, cfg.serve);
    let store = Rc::new(crate::store::Mem::new());
    let id = store.put(artifact)?;
    server.registry.attach_store(store);
    server.registry.register_store("deployed", id)?;
    let mut direct_pairs = Vec::with_capacity(prompts.len());
    for p in &prompts {
        server.submit("deployed", p)?;
    }
    for r in server.drain()? {
        direct_pairs.push((r.id, r.logits));
    }
    ensure!(direct_pairs.len() == prompts.len(), "parity server lost responses");
    Ok((digest_responses(fleet_pairs), digest_responses(direct_pairs), deployed_digest))
}

pub fn run_bench(opts: &BenchRegistryOpts) -> Result<BenchRegistryReport> {
    ensure!(opts.tasks >= 1 && opts.requests >= 1, "need at least one task and one request");
    ensure!(
        opts.budget_pct >= 1 && opts.budget_pct < 10,
        "--budget-pct must be in 1..10: the bench exists to measure the registry churning \
         well under full catalog residency"
    );
    ensure!(opts.prompt_len <= opts.seq, "prompt_len must be <= seq");

    // ---- parity gate first: nothing is measured, let alone serialized,
    // unless a live-Deployed task serves bit-identically to a
    // restart-loaded replica across a real socket fleet ----
    let deployed = side_artifact_synthetic(task_seed(opts.seed, opts.tasks + 1), 1 << 14);
    let (fleet_digest, direct_digest, deploy_digest) = deploy_parity(opts, &deployed)?;
    ensure!(
        fleet_digest == direct_digest,
        "live-Deployed task diverged from the restart-loaded replica \
         ({fleet_digest:016x} != {direct_digest:016x}) — refusing to serialize"
    );
    ensure!(
        deploy_digest == fingerprint_bytes(&deployed),
        "fleet acked a different artifact digest than the one deployed"
    );

    // ---- churn leg: catalog in a real file-backed store ----
    let scratch = std::env::temp_dir()
        .join(format!("qst-bench-registry-{}-{:x}", std::process::id(), opts.seed));
    let store = Rc::new(LocalDir::new(&scratch)?);
    let mut ids = Vec::with_capacity(opts.tasks);
    for i in 0..opts.tasks {
        let art = side_artifact_synthetic(task_seed(opts.seed, i), TASK_RESIDENT_BYTES as u64);
        ids.push(store.put(&art)?);
    }
    let catalog_bytes = (opts.tasks * TASK_RESIDENT_BYTES) as u64;
    let budget_bytes = catalog_bytes * opts.budget_pct as u64 / 100;

    let preset = EnginePreset::Small;
    let mut engine = preset.build_backbone(opts.seed, opts.seq, crate::serve::BackboneKind::F32);
    engine.set_threads(opts.threads);
    let vocab = engine.vocab;
    let mut server = Server::new(
        engine,
        ServeConfig {
            // hidden-state cache off: requests must reach the registry,
            // otherwise prompt reuse would mask the swap-in story
            cache_bytes: 0,
            registry_bytes: budget_bytes as usize,
            max_batch: opts.max_batch,
            prefix_block: 0,
        },
    );
    server.registry.attach_store(store);
    for (i, &id) in ids.iter().enumerate() {
        server
            .registry
            .register_store(&task_name(i), id)
            .with_context(|| format!("registering catalog task {i}"))?;
    }
    let registration_loads = server.registry.loads;

    let mut zipf = Zipf::new(opts.tasks, opts.zipf_s, opts.seed.wrapping_add(0x21BF));
    let mut rng = Rng::new(opts.seed.wrapping_add(0x7A11));
    let pool_n = 16.min(prompt_pool_capacity(opts.prompt_len, vocab));
    let prompts = prompt_pool(&mut rng, pool_n, opts.prompt_len, vocab);
    let t0 = std::time::Instant::now();
    let mut submitted = 0usize;
    let mut completed = 0usize;
    while submitted < opts.requests {
        let burst = opts.max_batch.min(opts.requests - submitted);
        for _ in 0..burst {
            let task = task_name(zipf.sample());
            let prompt = &prompts[rng.below(prompts.len())];
            server.submit(&task, prompt)?;
            submitted += 1;
        }
        completed += server.drain()?.len();
    }
    let wall = t0.elapsed().as_secs_f64();
    ensure!(completed == opts.requests, "completed {completed} of {} requests", opts.requests);

    let cold = server.registry.loads - registration_loads;
    let hit_rate = 1.0 - cold as f64 / opts.requests as f64;
    let report = BenchRegistryReport {
        opts: opts.clone(),
        catalog_bytes,
        budget_bytes,
        wall_secs: wall,
        requests_per_sec: opts.requests as f64 / wall.max(1e-12),
        swap_ins: server.registry.swap_hist.count(),
        swap_in_p50_ms: server.registry.swap_hist.p50_secs() * 1e3,
        swap_in_p95_ms: server.registry.swap_hist.p95_secs() * 1e3,
        hit_rate,
        evictions: server.registry.evictions,
        resident_bytes: server.registry.bytes() as u64,
        resident_tasks: server.registry.resident_count(),
        deploy_digest,
    };
    let _ = std::fs::remove_dir_all(&scratch);
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> BenchRegistryOpts {
        BenchRegistryOpts {
            tasks: 40,
            requests: 120,
            zipf_s: 1.0,
            budget_pct: 8,
            seq: 16,
            prompt_len: 8,
            max_batch: 4,
            parity_requests: 4,
            seed: 3,
            threads: 1,
        }
    }

    #[test]
    fn churn_bench_measures_evictions_and_holds_budget() {
        let rep = run_bench(&tiny()).unwrap();
        // 8% of a 40-task catalog keeps ~3 tasks resident: the Zipf tail
        // must thrash
        assert!(rep.evictions > 0, "no evictions — the budget never bit");
        assert!(rep.swap_ins >= rep.opts.tasks as u64, "every registration is a cold load");
        assert!(rep.resident_bytes <= rep.budget_bytes, "residency exceeded the budget");
        assert!((0.0..=1.0).contains(&rep.hit_rate), "hit rate {} out of range", rep.hit_rate);
        assert!(rep.hit_rate > 0.0, "a Zipf head this hot must rehit resident tasks");
        assert!(rep.swap_in_p95_ms >= rep.swap_in_p50_ms);
        assert_ne!(rep.deploy_digest, 0);
    }

    #[test]
    fn json_report_is_wellformed_and_parity_stamped() {
        let rep = run_bench(&tiny()).unwrap();
        let j = rep.to_json();
        assert!(j.contains("\"bench\": \"registry\""));
        assert!(j.contains("\"tasks\": 40"));
        assert!(j.contains("\"deploy_parity\": 1"));
        assert!(j.contains("\"swap_in_p50_ms\""));
        assert!(j.contains("\"swap_in_p95_ms\""));
        assert!(j.contains("\"hit_rate\""));
        assert!(j.contains("\"evictions\""));
        assert!(j.contains("\"resident_bytes\""));
        assert!(j.trim_end().ends_with('}'));
    }

    #[test]
    fn over_budget_pct_is_rejected() {
        let mut o = tiny();
        o.budget_pct = 10;
        assert!(run_bench(&o).is_err(), "budget >= 10% of catalog must be refused");
        o.budget_pct = 0;
        assert!(run_bench(&o).is_err());
    }

    #[test]
    fn response_digest_is_order_independent() {
        let a = vec![(0u64, vec![1.0f32, 2.0]), (1, vec![3.0])];
        let b = vec![(1u64, vec![3.0f32]), (0, vec![1.0, 2.0])];
        assert_eq!(digest_responses(a.clone()), digest_responses(b));
        let c = vec![(0u64, vec![1.0f32, 2.5]), (1, vec![3.0])];
        assert_ne!(digest_responses(a), digest_responses(c));
    }
}
