//! One gateway shard: a worker thread owning a private `Server` replica.
//!
//! `serve::Engine` state is deliberately single-threaded (`Rc` side
//! networks, mutable counters), so a shard never shares its server —
//! the thread *constructs* engine + server locally from the gateway
//! config (same seed ⇒ bit-identical backbone replica; the W4 packing
//! from PR 3 makes a replica ~7.6× cheaper to hold than f32) and owns
//! them until shutdown.  Communication is message-passing only: a
//! bounded inbox of [`ShardMsg`]s in, an unbounded stream of
//! [`ShardEvent`]s out.
//!
//! The serving loop favours batching under load and latency when idle:
//! after a blocking receive it soaks up whatever else is already queued
//! (up to the micro-batch cap) before draining, so open-loop load forms
//! real micro-batches while a lone interactive request is answered
//! immediately.

use std::collections::HashMap;
use std::sync::mpsc::{Receiver, Sender, SyncSender, TryRecvError, TrySendError};
use std::thread::JoinHandle;

use crate::serve::{Server, SyntheticEngine};

use super::transport::{GatewayRequest, GatewayResponse, ShardEvent, ShardMsg, SubmitError};
use super::GatewayConfig;

/// Counters snapshot one shard ships to the aggregator.
#[derive(Clone, Debug, Default)]
pub struct ShardReport {
    pub shard: usize,
    pub stats: crate::serve::StatsSnapshot,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub prefix_hits: u64,
    pub cache_evictions: u64,
    pub cache_entries: usize,
    pub cache_bytes: usize,
    pub backbone_rows: u64,
    pub resumed_rows: u64,
    pub resumed_positions: u64,
    pub backbone_resident_bytes: usize,
    pub registry_bytes: usize,
}

/// The gateway-side handle: bounded sender + join handle.  Dropping the
/// handle stops the shard (idempotent with [`ShardHandle::stop`]).
pub struct ShardHandle {
    pub index: usize,
    tx: SyncSender<ShardMsg>,
    join: Option<JoinHandle<()>>,
}

impl ShardHandle {
    /// Spawn shard `index`: builds its engine/server replica *inside* the
    /// thread and serves until `Shutdown` (or the gateway drops).
    pub fn spawn(index: usize, cfg: &GatewayConfig, events: Sender<ShardEvent>) -> ShardHandle {
        let (tx, rx) = std::sync::mpsc::sync_channel(cfg.queue_cap.max(1));
        let cfg = *cfg;
        let join = std::thread::Builder::new()
            .name(format!("qst-gateway-shard-{index}"))
            .spawn(move || run_shard(index, cfg, rx, events))
            .expect("spawning gateway shard");
        ShardHandle { index, tx, join: Some(join) }
    }

    /// Non-blocking submit into the bounded inbox.
    pub fn try_submit(&self, req: GatewayRequest) -> Result<(), SubmitError> {
        match self.tx.try_send(ShardMsg::Submit(req)) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(_)) => Err(SubmitError::Backpressure { shard: self.index }),
            Err(TrySendError::Disconnected(_)) => Err(SubmitError::ShardDown { shard: self.index }),
        }
    }

    /// Blocking control-message send (flush/report/shutdown); `false` if
    /// the shard thread is gone.
    pub fn send(&self, msg: ShardMsg) -> bool {
        self.tx.send(msg).is_ok()
    }

    /// Stop and join the shard thread (idempotent).
    pub fn stop(&mut self) {
        if let Some(join) = self.join.take() {
            let _ = self.tx.send(ShardMsg::Shutdown);
            let _ = join.join();
        }
    }
}

impl Drop for ShardHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

fn report(index: usize, server: &Server<SyntheticEngine>) -> ShardReport {
    ShardReport {
        shard: index,
        stats: server.stats.snapshot(),
        cache_hits: server.cache.hits,
        cache_misses: server.cache.misses,
        prefix_hits: server.cache.prefix_hits,
        cache_evictions: server.cache.evictions,
        cache_entries: server.cache.len(),
        cache_bytes: server.cache.bytes(),
        backbone_rows: server.engine.backbone_rows,
        resumed_rows: server.engine.resumed_rows,
        resumed_positions: server.engine.resumed_positions,
        backbone_resident_bytes: server.engine.backbone_resident_bytes(),
        registry_bytes: server.registry.bytes(),
    }
}

fn run_shard(index: usize, cfg: GatewayConfig, rx: Receiver<ShardMsg>, events: Sender<ShardEvent>) {
    let mut engine = cfg.preset.build_backbone(cfg.seed, cfg.seq, cfg.backbone);
    engine.set_threads(cfg.threads_per_shard);
    let mut server = Server::new(engine, cfg.serve);
    for i in 0..cfg.tasks.max(1) {
        server
            .registry
            .register_synthetic(
                &super::task_name(i),
                super::task_seed(cfg.seed, i),
                super::SYNTHETIC_TASK_BYTES,
            )
            .expect("registering synthetic gateway task");
    }
    // server-local request id -> gateway id, rewritten on the way out
    let mut id_map: HashMap<u64, u64> = HashMap::new();
    let submit = |server: &mut Server<SyntheticEngine>,
                      id_map: &mut HashMap<u64, u64>,
                      req: GatewayRequest| {
        match server.submit(&req.task, &req.tokens) {
            Ok(sid) => {
                id_map.insert(sid, req.id);
            }
            Err(e) => {
                let _ = events.send(ShardEvent::Rejected {
                    shard: index,
                    id: req.id,
                    err: format!("{e:#}"),
                });
            }
        }
    };
    let drain_and_emit =
        |server: &mut Server<SyntheticEngine>, id_map: &mut HashMap<u64, u64>| {
            if server.pending() == 0 {
                return;
            }
            let before_dropped = server.stats.dropped;
            match server.drain() {
                Ok(responses) => {
                    for mut r in responses {
                        r.id = id_map.get(&r.id).copied().unwrap_or(r.id);
                        let _ = events.send(ShardEvent::Done(GatewayResponse {
                            shard: index,
                            resp: r,
                        }));
                    }
                }
                Err(e) => eprintln!("gateway shard {index}: drain failed: {e:#}"),
            }
            let dropped = server.stats.dropped - before_dropped;
            if dropped > 0 {
                let _ = events.send(ShardEvent::Dropped { shard: index, n: dropped as usize });
            }
            // drain() leaves nothing pending: every id was answered or dropped
            id_map.clear();
        };
    // a control message pulled out of the inbox mid-batch, parked until
    // the drain it interrupted completes
    let mut parked: Option<ShardMsg> = None;
    loop {
        let msg = match parked.take() {
            Some(m) => m,
            None => match rx.recv() {
                Ok(m) => m,
                Err(_) => break, // gateway gone: drain and exit
            },
        };
        match msg {
            ShardMsg::Submit(req) => {
                submit(&mut server, &mut id_map, req);
                // soak up already-queued submits so micro-batches form
                // under load; park any control message for after the drain
                while server.pending() < server.max_batch() {
                    match rx.try_recv() {
                        Ok(ShardMsg::Submit(r)) => submit(&mut server, &mut id_map, r),
                        Ok(ctrl) => {
                            parked = Some(ctrl);
                            break;
                        }
                        Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
                    }
                }
                drain_and_emit(&mut server, &mut id_map);
            }
            ShardMsg::Flush(ack) => {
                drain_and_emit(&mut server, &mut id_map);
                let _ = ack.send(());
            }
            ShardMsg::Report(reply) => {
                let _ = reply.send(report(index, &server));
            }
            ShardMsg::Shutdown => {
                drain_and_emit(&mut server, &mut id_map);
                break;
            }
        }
    }
    drain_and_emit(&mut server, &mut id_map);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{BackboneKind, EnginePreset, ServeConfig};

    fn tiny_cfg(queue_cap: usize) -> GatewayConfig {
        GatewayConfig {
            shards: 1,
            queue_cap,
            seq: 16,
            seed: 7,
            tasks: 2,
            threads_per_shard: 1,
            preset: EnginePreset::Small,
            backbone: BackboneKind::F32,
            serve: ServeConfig {
                cache_bytes: 4 << 20,
                registry_bytes: 1 << 20,
                max_batch: 4,
                prefix_block: 4,
            },
        }
    }

    #[test]
    fn shard_round_trip_matches_direct_server() {
        let cfg = tiny_cfg(16);
        let (ev_tx, ev_rx) = std::sync::mpsc::channel();
        let mut shard = ShardHandle::spawn(0, &cfg, ev_tx);
        let prompt = vec![3i32, 1, 4, 1, 5];
        shard
            .try_submit(GatewayRequest { id: 42, task: "task0".into(), tokens: prompt.clone() })
            .unwrap();
        let (ack_tx, ack_rx) = std::sync::mpsc::channel();
        assert!(shard.send(ShardMsg::Flush(ack_tx)));
        ack_rx.recv().unwrap();
        let ev = ev_rx.recv().unwrap();
        let ShardEvent::Done(gr) = ev else { panic!("expected Done") };
        assert_eq!(gr.resp.id, 42, "gateway id must survive the trip");
        assert_eq!(gr.shard, 0);
        // reference: same engine seed, same task registration, no threads
        let mut engine = cfg.preset.build_backbone(cfg.seed, cfg.seq, cfg.backbone);
        engine.set_threads(1);
        let mut server = Server::new(engine, cfg.serve);
        server
            .registry
            .register_synthetic(
                "task0",
                crate::gateway::task_seed(cfg.seed, 0),
                crate::gateway::SYNTHETIC_TASK_BYTES,
            )
            .unwrap();
        server.submit("task0", &prompt).unwrap();
        let want = server.drain().unwrap();
        assert_eq!(gr.resp.logits, want[0].logits, "shard replica must be bit-identical");
        // report carries the serve counters
        let (rep_tx, rep_rx) = std::sync::mpsc::channel();
        assert!(shard.send(ShardMsg::Report(rep_tx)));
        let rep = rep_rx.recv().unwrap();
        assert_eq!(rep.stats.requests, 1);
        assert_eq!(rep.backbone_rows, 1);
        assert!(rep.backbone_resident_bytes > 0);
        shard.stop();
        shard.stop(); // idempotent
    }

    #[test]
    fn shard_rejects_bad_tasks_via_events() {
        let cfg = tiny_cfg(16);
        let (ev_tx, ev_rx) = std::sync::mpsc::channel();
        let mut shard = ShardHandle::spawn(0, &cfg, ev_tx);
        shard
            .try_submit(GatewayRequest { id: 9, task: "nope".into(), tokens: vec![1] })
            .unwrap();
        let (ack_tx, ack_rx) = std::sync::mpsc::channel();
        assert!(shard.send(ShardMsg::Flush(ack_tx)));
        ack_rx.recv().unwrap();
        match ev_rx.try_recv().unwrap() {
            ShardEvent::Rejected { id, .. } => assert_eq!(id, 9),
            _ => panic!("expected Rejected"),
        }
        shard.stop();
    }

    #[test]
    fn bounded_inbox_backpressures_when_thread_is_busy() {
        // a 1-slot inbox with the shard wedged behind a slow flush can
        // only ever hold one message; the second try_submit must reject
        // rather than block — this is the no-deadlock guarantee
        let cfg = tiny_cfg(1);
        let (ev_tx, _ev_rx) = std::sync::mpsc::channel();
        let mut shard = ShardHandle::spawn(0, &cfg, ev_tx);
        let req = |id| GatewayRequest { id, task: "task0".into(), tokens: vec![1, 2] };
        // fill the inbox: accepted messages beyond the first are consumed
        // as the thread wakes, so loop until a rejection surfaces
        let mut saw_backpressure = false;
        for id in 0..2000 {
            match shard.try_submit(req(id)) {
                Ok(()) => continue,
                Err(SubmitError::Backpressure { shard: s }) => {
                    assert_eq!(s, 0);
                    saw_backpressure = true;
                    break;
                }
                Err(e) => panic!("unexpected: {e}"),
            }
        }
        assert!(saw_backpressure, "a 1-slot inbox must reject under load");
        shard.stop();
    }

    #[test]
    fn shard_report_default_is_zeroed() {
        let r = ShardReport::default();
        assert_eq!(r.cache_hits, 0);
        assert_eq!(r.stats.requests, 0);
    }
}
