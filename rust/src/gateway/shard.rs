//! One gateway shard: a `Server` replica driven by [`ShardMsg`]s.
//!
//! `serve::Engine` state is deliberately single-threaded (`Rc` side
//! networks, mutable counters), so a shard never shares its server — it
//! *constructs* engine + server locally from a [`ShardSpec`] (same seed
//! ⇒ bit-identical backbone replica; the W4 packing from PR 3 makes a
//! replica ~7.6× cheaper to hold than f32) and owns them until shutdown.
//! Communication is message-passing only: [`ShardMsg`]s in,
//! [`ShardEvent`]s out.
//!
//! The split here is what makes the transport pluggable:
//!
//! * [`ShardCore`] — the transport-free state machine (server, id map,
//!   event emission).
//! * [`run_core_loop`] — the serving loop over an `mpsc::Receiver`.
//!   In-proc shards feed it straight from a bounded inbox
//!   ([`ShardHandle`]); socket workers feed it from a reader thread
//!   decoding frames ([`super::worker`]).  **One loop, both transports**
//!   — so batching behavior (and therefore perf shape) cannot diverge.
//!
//! The loop batches **continuously**: it keeps a bounded pool of up to
//! `max_batch` admitted requests, executes exactly one micro-batch at a
//! time, and tops the freed slots back up from the inbox between
//! executions — responses stream out per completed micro-batch instead
//! of per drain, so short prompts never wait out a long wave behind
//! them.  It still favours latency when idle (a lone interactive request
//! is admitted by a blocking receive and served immediately) and batching
//! under load (open-loop traffic fills the pool before each execution).
//! `Flush` is *not* a scheduling trigger — it is a pure consistency
//! barrier, acked only once the pool and queue are empty, used by
//! tests/bench to delimit comparisons.

use std::collections::HashMap;
use std::rc::Rc;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, SyncSender, TryRecvError, TrySendError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::obs::series::{GaugePoint, GaugeSeries};
use crate::obs::{self, SpanKind};
use crate::proto::{
    GatewayResponse, Heartbeat, Request, ShardEvent, ShardMsg, ShardReport, ShardSpec,
    SubmitError, TelemetryBatch,
};
use crate::serve::{Server, SyntheticEngine};
use crate::store::Storage;

/// A periodic emission schedule (the heartbeat cadence).
struct Cadence {
    interval: Duration,
    next: Instant,
}

/// The transport-free shard state machine: owns the server replica and
/// the gateway-id bookkeeping, emits [`ShardEvent`]s through a callback.
pub struct ShardCore {
    index: usize,
    server: Server<SyntheticEngine>,
    /// server-local request id -> gateway id, rewritten on the way out
    id_map: HashMap<u64, u64>,
    /// most slots ever occupied when a micro-batch started executing
    /// (saturation gauge; never exceeds `max_batch` — the slot-cap
    /// invariant the gateway property test pins)
    inflight_peak: u64,
    /// micro-batch executions that started with every slot occupied
    /// (pending == max_batch)
    full_soaks: u64,
    /// heartbeat schedule; `None` when the spec leaves heartbeats
    /// disarmed (`heartbeat_ms == 0`) — the loop then never ticks
    beat: Option<Cadence>,
    /// gauge flight recorder; `None` when disarmed (`series_ms == 0`)
    series: Option<GaugeSeries>,
    /// spans dropped by this process's recorder, accumulated from
    /// telemetry drains — shipped in heartbeats and the report tail
    spans_dropped: u64,
    /// the shard-local artifact store `Deploy`ed bytes land in (workers
    /// have no shared disk, so deployed artifacts live in memory); the
    /// registry holds a clone and streams sections out of it on swap-in
    store: Rc<crate::store::Mem>,
}

impl ShardCore {
    /// Build shard `index`'s bit-identical replica from the fleet spec.
    pub fn from_spec(index: usize, spec: &ShardSpec) -> anyhow::Result<ShardCore> {
        let mut engine = spec.preset.build_backbone(spec.seed, spec.seq, spec.backbone);
        engine.set_threads(spec.threads);
        let mut server = Server::new(engine, spec.serve);
        let store = Rc::new(crate::store::Mem::new());
        server.registry.attach_store(store.clone());
        for i in 0..spec.tasks.max(1) {
            server.registry.register_synthetic(
                &super::task_name(i),
                super::task_seed(spec.seed, i),
                super::SYNTHETIC_TASK_BYTES,
            )?;
        }
        let beat = (spec.heartbeat_ms > 0).then(|| {
            let interval = Duration::from_millis(spec.heartbeat_ms);
            Cadence { interval, next: Instant::now() + interval }
        });
        let series = (spec.series_ms > 0)
            .then(|| GaugeSeries::new(spec.series_ms, spec.series_cap));
        Ok(ShardCore {
            index,
            server,
            id_map: HashMap::new(),
            inflight_peak: 0,
            full_soaks: 0,
            beat,
            series,
            spans_dropped: 0,
            store,
        })
    }

    pub fn index(&self) -> usize {
        self.index
    }

    pub fn pending(&self) -> usize {
        self.server.pending()
    }

    pub fn max_batch(&self) -> usize {
        self.server.max_batch()
    }

    fn submit(&mut self, req: Request, emit: &mut dyn FnMut(ShardEvent)) {
        let t_slot = obs::start();
        match self.server.submit(&req.task, &req.tokens) {
            Ok(sid) => {
                self.id_map.insert(sid, req.id);
                obs::end(SpanKind::AdmitSlot, t_slot, req.id);
            }
            Err(e) => emit(ShardEvent::Rejected {
                shard: self.index,
                id: req.id,
                err: format!("{e:#}"),
            }),
        }
    }

    /// Execute exactly **one** micro-batch from the slot pool and stream
    /// its outcomes; a no-op when nothing is pooled.  This is the unit
    /// [`run_core_loop`] interleaves with admission — completed responses
    /// leave the shard while later submits are still arriving.
    fn step_and_emit(&mut self, emit: &mut dyn FnMut(ShardEvent)) {
        if self.server.pending() == 0 {
            return;
        }
        let pending = self.server.pending() as u64;
        self.inflight_peak = self.inflight_peak.max(pending);
        if pending as usize >= self.server.max_batch() {
            self.full_soaks += 1;
        }
        let before_dropped = self.server.stats.dropped;
        match self.server.step() {
            Ok(responses) => {
                for mut r in responses {
                    r.id = self.id_map.remove(&r.id).unwrap_or(r.id);
                    emit(ShardEvent::Done(GatewayResponse { shard: self.index, resp: r }));
                }
            }
            Err(e) => eprintln!("gateway shard {}: batch failed: {e:#}", self.index),
        }
        let dropped = self.server.stats.dropped - before_dropped;
        if dropped > 0 {
            emit(ShardEvent::Dropped { shard: self.index, n: dropped as usize });
        }
        if self.server.pending() == 0 {
            // dropped requests leave stale id entries behind; an empty
            // pool has no live ids, so clearing here bounds the map
            self.id_map.clear();
        }
    }

    /// Land a `Deploy`ed artifact: store the bytes under their content
    /// fingerprint and hot-register the task through the store source.
    /// Never panics — a malformed artifact comes back as the ack's `err`
    /// and the shard keeps serving its existing tasks.
    fn deploy(&mut self, task: &str, artifact: &[u8]) -> (u64, String) {
        let digest = crate::store::fingerprint_bytes(artifact);
        let res = self
            .store
            .put(artifact)
            .and_then(|id| self.server.registry.register_store(task, id));
        match res {
            Ok(()) => (digest, String::new()),
            Err(e) => (digest, format!("{e:#}")),
        }
    }

    /// One sample of this shard's load gauges (cheap counter reads).
    fn gauge_point(&self) -> GaugePoint {
        GaugePoint {
            t_ms: 0, // stamped by GaugeSeries::sample
            queue_depth: self.server.pending() as u64,
            inflight_slots: self.server.pending() as u64,
            cache_bytes: self.server.cache.bytes() as u64,
            registry_bytes: self.server.registry.bytes() as u64,
            requests: self.server.stats.requests,
        }
    }

    /// Time until the next heartbeat or series sample is due — the idle
    /// `recv_timeout` bound.  `None` when both cadences are disarmed
    /// (the loop then keeps its plain blocking `recv`: zero overhead).
    fn until_next(&self, now: Instant) -> Option<Duration> {
        let beat = self.beat.as_ref().map(|c| c.next.saturating_duration_since(now));
        let series = self.series.as_ref().map(|s| s.until_due(now));
        match (beat, series) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (Some(a), None) => Some(a),
            (None, Some(b)) => Some(b),
            (None, None) => None,
        }
    }

    /// Emit a heartbeat and/or record a gauge sample if due.  Called on
    /// every idle wake-up and after every micro-batch execution.
    fn tick(&mut self, emit: &mut dyn FnMut(ShardEvent)) {
        if self.beat.is_none() && self.series.is_none() {
            return;
        }
        let now = Instant::now();
        if let Some(c) = &mut self.beat {
            if now >= c.next {
                // catch-up schedule, same as the series: a shard that
                // stalled past several beats emits one, not a burst
                c.next = now + c.interval;
                let hb = Heartbeat {
                    shard: self.index,
                    queue_depth: self.server.pending() as u64,
                    inflight_slots: self.server.pending() as u64,
                    spans_dropped: self.spans_dropped,
                    cache_bytes: self.server.cache.bytes() as u64,
                };
                emit(ShardEvent::Heartbeat(hb));
            }
        }
        if self.series.as_ref().is_some_and(|s| s.due(now)) {
            let point = self.gauge_point();
            self.series.as_mut().expect("due implies armed").sample(now, point);
        }
    }

    fn report(&self) -> ShardReport {
        let server = &self.server;
        ShardReport {
            shard: self.index,
            stats: server.stats.snapshot(),
            cache_hits: server.cache.hits,
            cache_misses: server.cache.misses,
            prefix_hits: server.cache.prefix_hits,
            cache_evictions: server.cache.evictions,
            cache_entries: server.cache.len(),
            cache_bytes: server.cache.bytes(),
            backbone_rows: server.engine.backbone_rows,
            resumed_rows: server.engine.resumed_rows,
            resumed_positions: server.engine.resumed_positions,
            backbone_resident_bytes: server.engine.backbone_resident_bytes(),
            registry_bytes: server.registry.bytes(),
            queue_depth: server.pending() as u64,
            inflight_peak: self.inflight_peak,
            full_soaks: self.full_soaks,
            inflight_slots: server.pending() as u64,
            spans_dropped: self.spans_dropped,
            series: self.series.as_ref().map(GaugeSeries::snapshot).unwrap_or_default(),
            registry_evictions: server.registry.evictions,
            swap_hist: server.registry.swap_hist.clone(),
        }
    }
}

/// Drain this process's span recorder into a credit-neutral `Telemetry`
/// event; returns how many spans the recorder dropped since the last
/// drain (accumulated into the core's `spans_dropped` ledger).  Only
/// socket workers do this — an in-proc shard shares the gateway's
/// rings, so shipping would double-count its spans.
fn emit_telemetry(shard: usize, emit: &mut dyn FnMut(ShardEvent)) -> u64 {
    let (spans, dropped) = crate::obs::drain();
    if spans.is_empty() && dropped == 0 {
        return 0;
    }
    emit(ShardEvent::Telemetry(TelemetryBatch { shard, dropped, spans }));
    dropped
}

/// Serve [`ShardMsg`]s from `rx` until `Shutdown` (or the sender side
/// hangs up), emitting every outcome through `emit`.  Used verbatim by
/// in-proc shard threads and socket workers — continuous admission and
/// the flush/report semantics are identical across transports by
/// construction.
///
/// The loop alternates two moves:
///
/// 1. **Admit** — pull submits from the inbox into open slots, blocking
///    only when the pool is completely idle.  A `Submit` is never pulled
///    once every slot is occupied, so `pending` can never exceed
///    `max_batch` (the slot-cap invariant).
/// 2. **Step** — execute exactly one micro-batch and stream its
///    responses out, freeing slots for the next admission pass.
///
/// Control messages are parked when they arrive: `Report` answers
/// immediately (it is a snapshot — mid-pool gauges are the point);
/// `Flush`/`Shutdown` are barriers that act only once every request
/// admitted before them has been served, which keeps the PR 5 contract —
/// per-shard FIFO events mean a `FlushAck` always follows the outcomes
/// of everything submitted before the flush.
///
/// `ship_telemetry` is set only by traced socket workers: alongside each
/// `Report` (and at shutdown) the worker drains its span recorder into a
/// `Telemetry` event so the gateway can assemble one fleet trace.
/// In-proc shards pass `false` — they already share the gateway's rings.
pub fn run_core_loop(
    mut core: ShardCore,
    rx: &Receiver<ShardMsg>,
    emit: &mut dyn FnMut(ShardEvent),
    ship_telemetry: bool,
) {
    // a control message pulled out of the inbox during admission, held
    // until its semantics allow acting on it
    let mut parked: Option<ShardMsg> = None;
    'serve: loop {
        // admission: top the open slots up from the inbox
        while parked.is_none() && core.pending() < core.max_batch() {
            let msg = if core.pending() == 0 {
                // idle: block for the next message — but only until the
                // next heartbeat/sample is due when a cadence is armed.
                // Disarmed shards keep the plain blocking recv (no clock
                // reads, no timeout bookkeeping: zero added overhead).
                match core.until_next(Instant::now()) {
                    None => match rx.recv() {
                        Ok(m) => m,
                        Err(_) => break 'serve, // gateway gone: drain and exit
                    },
                    Some(wait) => match rx.recv_timeout(wait) {
                        Ok(m) => m,
                        Err(RecvTimeoutError::Timeout) => {
                            core.tick(emit);
                            continue;
                        }
                        Err(RecvTimeoutError::Disconnected) => break 'serve,
                    },
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => break 'serve,
                }
            };
            match msg {
                ShardMsg::Submit(req) => core.submit(req, emit),
                ctrl => parked = Some(ctrl),
            }
        }
        if matches!(parked, Some(ShardMsg::Report)) {
            parked = None;
            // telemetry first: per-shard FIFO means the gateway sees
            // the span batch before the Report that ends its wait
            if ship_telemetry {
                core.spans_dropped += emit_telemetry(core.index, emit);
            }
            // a due gauge sample belongs in the snapshot being shipped
            core.tick(emit);
            emit(ShardEvent::Report(core.report()));
            continue 'serve;
        }
        if matches!(parked, Some(ShardMsg::Deploy { .. })) {
            // like Report, a Deploy acts immediately: registering a task
            // touches only the registry, so in-flight requests for other
            // tasks are unaffected and the ack never waits out the pool
            let Some(ShardMsg::Deploy { task, artifact }) = parked.take() else {
                unreachable!("matched Deploy above")
            };
            let (digest, err) = core.deploy(&task, &artifact);
            emit(ShardEvent::DeployAck { shard: core.index, task, digest, err });
            continue 'serve;
        }
        if matches!(parked, Some(ShardMsg::Configure { .. })) {
            parked = None;
            // in-proc shards are built from their spec directly; a
            // socket worker consumes Configure before entering this
            // loop — seeing one here is a protocol bug, not fatal
            eprintln!("gateway shard {}: unexpected Configure (already configured)", core.index());
            continue 'serve;
        }
        if core.pending() == 0 {
            // the barrier messages act only on an empty pool
            match parked.take() {
                Some(ShardMsg::Flush) => {
                    emit(ShardEvent::FlushAck { shard: core.index });
                    continue 'serve;
                }
                Some(ShardMsg::Shutdown) => break 'serve,
                _ => {}
            }
        }
        // exactly one micro-batch, then back to admission — responses
        // stream out while later submits refill the freed slots.  The
        // admission pass above guarantees pending > 0 here whenever no
        // control message is parked, so this never spins.
        core.step_and_emit(emit);
        // under sustained load the idle recv never runs, so beats and
        // samples are driven from here, between micro-batches
        core.tick(emit);
    }
    // Shutdown, or the sender hung up, with work still pooled: serve it
    while core.pending() > 0 {
        core.step_and_emit(emit);
    }
    if ship_telemetry {
        core.spans_dropped += emit_telemetry(core.index, emit);
    }
}

/// An in-proc shard: [`run_core_loop`] on its own thread behind a
/// **bounded** inbox.  The gateway-side handle pairs the sender with the
/// join handle; dropping it stops the shard (idempotent with
/// [`ShardHandle::stop`]).
pub struct ShardHandle {
    pub index: usize,
    tx: SyncSender<ShardMsg>,
    join: Option<JoinHandle<()>>,
}

impl ShardHandle {
    /// Spawn shard `index`: builds its engine/server replica *inside* the
    /// thread and serves until `Shutdown` (or the gateway drops).
    pub fn spawn(
        index: usize,
        spec: ShardSpec,
        queue_cap: usize,
        events: Sender<ShardEvent>,
    ) -> ShardHandle {
        let (tx, rx) = std::sync::mpsc::sync_channel(queue_cap.max(1));
        let join = std::thread::Builder::new()
            .name(format!("qst-gateway-shard-{index}"))
            .spawn(move || {
                let core = ShardCore::from_spec(index, &spec)
                    .expect("building gateway shard replica");
                let mut emit = |ev: ShardEvent| {
                    let _ = events.send(ev);
                };
                // in-proc: the recorder rings live in the gateway's own
                // process, so spans are read locally — never shipped
                run_core_loop(core, &rx, &mut emit, false);
            })
            .expect("spawning gateway shard");
        ShardHandle { index, tx, join: Some(join) }
    }

    /// Non-blocking submit into the bounded inbox.
    pub fn try_submit(&self, req: Request) -> Result<(), SubmitError> {
        match self.tx.try_send(ShardMsg::Submit(req)) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(_)) => Err(SubmitError::Backpressure { shard: self.index }),
            Err(TrySendError::Disconnected(_)) => Err(SubmitError::ShardDown { shard: self.index }),
        }
    }

    /// Blocking control-message send (flush/report/shutdown); `false` if
    /// the shard thread is gone.
    pub fn send(&self, msg: ShardMsg) -> bool {
        self.tx.send(msg).is_ok()
    }

    /// Whether the serving thread has exited.  Before [`ShardHandle::stop`]
    /// a shard thread only ever exits by dying (panic mid-drain, poisoned
    /// engine), so a `true` here while events are awaited means its
    /// outcomes will never arrive — the transports poll this to fail fast
    /// instead of sitting out the full event timeout.
    pub fn is_dead(&self) -> bool {
        self.join.as_ref().map(|j| j.is_finished()).unwrap_or(true)
    }

    /// Stop and join the shard thread (idempotent).
    pub fn stop(&mut self) {
        if let Some(join) = self.join.take() {
            let _ = self.tx.send(ShardMsg::Shutdown);
            let _ = join.join();
        }
    }
}

impl Drop for ShardHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{BackboneKind, EnginePreset, ServeConfig};

    fn tiny_spec() -> ShardSpec {
        ShardSpec {
            preset: EnginePreset::Small,
            backbone: BackboneKind::F32,
            seed: 7,
            seq: 16,
            tasks: 2,
            threads: 1,
            serve: ServeConfig {
                cache_bytes: 4 << 20,
                registry_bytes: 1 << 20,
                max_batch: 4,
                prefix_block: 4,
            },
            trace: false,
            heartbeat_ms: 0,
            series_ms: 0,
            series_cap: 0,
        }
    }

    #[test]
    fn shard_round_trip_matches_direct_server_and_acks_after_outcomes() {
        let spec = tiny_spec();
        let (ev_tx, ev_rx) = std::sync::mpsc::channel();
        let mut shard = ShardHandle::spawn(0, spec, 16, ev_tx);
        let prompt = vec![3i32, 1, 4, 1, 5];
        shard
            .try_submit(Request { id: 42, task: "task0".into(), tokens: prompt.clone() })
            .unwrap();
        assert!(shard.send(ShardMsg::Flush));
        // per-shard FIFO: the Done for id 42 must precede the FlushAck
        let ev = ev_rx.recv().unwrap();
        let ShardEvent::Done(gr) = ev else { panic!("expected Done before the ack") };
        assert_eq!(gr.resp.id, 42, "gateway id must survive the trip");
        assert_eq!(gr.shard, 0);
        assert!(matches!(ev_rx.recv().unwrap(), ShardEvent::FlushAck { shard: 0 }));
        // reference: same spec, same task registration, no threads
        let mut engine = spec.preset.build_backbone(spec.seed, spec.seq, spec.backbone);
        engine.set_threads(1);
        let mut server = Server::new(engine, spec.serve);
        server
            .registry
            .register_synthetic(
                "task0",
                crate::gateway::task_seed(spec.seed, 0),
                crate::gateway::SYNTHETIC_TASK_BYTES,
            )
            .unwrap();
        server.submit("task0", &prompt).unwrap();
        let want = server.drain().unwrap();
        assert_eq!(gr.resp.logits, want[0].logits, "shard replica must be bit-identical");
        // report comes back as an event carrying the serve counters
        assert!(shard.send(ShardMsg::Report));
        let ShardEvent::Report(rep) = ev_rx.recv().unwrap() else { panic!("expected Report") };
        assert_eq!(rep.stats.requests, 1);
        assert_eq!(rep.backbone_rows, 1);
        assert!(rep.backbone_resident_bytes > 0);
        // gauges: the lone request drained as a 1-deep micro-batch
        assert_eq!(rep.queue_depth, 0, "nothing pending after a flush");
        assert_eq!(rep.inflight_peak, 1);
        assert_eq!(rep.full_soaks, 0, "a 1-deep soak never hits max_batch 4");
        shard.stop();
        shard.stop(); // idempotent
    }

    #[test]
    fn shard_rejects_bad_tasks_via_events() {
        let (ev_tx, ev_rx) = std::sync::mpsc::channel();
        let mut shard = ShardHandle::spawn(0, tiny_spec(), 16, ev_tx);
        shard.try_submit(Request { id: 9, task: "nope".into(), tokens: vec![1] }).unwrap();
        assert!(shard.send(ShardMsg::Flush));
        match ev_rx.recv().unwrap() {
            ShardEvent::Rejected { id, .. } => assert_eq!(id, 9),
            other => panic!("expected Rejected, got {other:?}"),
        }
        assert!(matches!(ev_rx.recv().unwrap(), ShardEvent::FlushAck { .. }));
        shard.stop();
    }

    #[test]
    fn bounded_inbox_backpressures_when_thread_is_busy() {
        // a 1-slot inbox with the shard busy serving can only ever hold
        // one message; a sustained burst must reject rather than block —
        // this is the no-deadlock guarantee
        let (ev_tx, _ev_rx) = std::sync::mpsc::channel();
        let mut shard = ShardHandle::spawn(0, tiny_spec(), 1, ev_tx);
        let req = |id| Request { id, task: "task0".into(), tokens: vec![1, 2] };
        let mut saw_backpressure = false;
        for id in 0..2000 {
            match shard.try_submit(req(id)) {
                Ok(()) => continue,
                Err(SubmitError::Backpressure { shard: s }) => {
                    assert_eq!(s, 0);
                    saw_backpressure = true;
                    break;
                }
                Err(e) => panic!("unexpected: {e}"),
            }
        }
        assert!(saw_backpressure, "a 1-slot inbox must reject under load");
        shard.stop();
    }

    #[test]
    fn deploy_hot_registers_a_new_task_without_restart() {
        let (ev_tx, ev_rx) = std::sync::mpsc::channel();
        let mut shard = ShardHandle::spawn(0, tiny_spec(), 16, ev_tx);
        // before the deploy the task does not exist on this shard
        shard.try_submit(Request { id: 1, task: "hot".into(), tokens: vec![1, 2] }).unwrap();
        assert!(shard.send(ShardMsg::Flush));
        assert!(matches!(ev_rx.recv().unwrap(), ShardEvent::Rejected { id: 1, .. }));
        assert!(matches!(ev_rx.recv().unwrap(), ShardEvent::FlushAck { .. }));
        // deploy an artifact; the ack carries its content fingerprint
        let artifact = crate::store::side_artifact_synthetic(99, 1 << 12);
        assert!(shard.send(ShardMsg::Deploy { task: "hot".into(), artifact: artifact.clone() }));
        match ev_rx.recv().unwrap() {
            ShardEvent::DeployAck { shard: s, task, digest, err } => {
                assert_eq!(s, 0);
                assert_eq!(task, "hot");
                assert_eq!(digest, crate::store::fingerprint_bytes(&artifact));
                assert!(err.is_empty(), "deploy failed: {err}");
            }
            other => panic!("expected DeployAck, got {other:?}"),
        }
        // the same request now serves
        shard.try_submit(Request { id: 2, task: "hot".into(), tokens: vec![1, 2] }).unwrap();
        assert!(shard.send(ShardMsg::Flush));
        let ShardEvent::Done(gr) = ev_rx.recv().unwrap() else { panic!("expected Done") };
        assert_eq!(gr.resp.id, 2);
        assert!(matches!(ev_rx.recv().unwrap(), ShardEvent::FlushAck { .. }));
        // a malformed artifact is a typed ack error, not a dead shard
        assert!(shard.send(ShardMsg::Deploy { task: "bad".into(), artifact: vec![1, 2, 3] }));
        match ev_rx.recv().unwrap() {
            ShardEvent::DeployAck { task, err, .. } => {
                assert_eq!(task, "bad");
                assert!(!err.is_empty(), "junk bytes must fail registration");
            }
            other => panic!("expected DeployAck, got {other:?}"),
        }
        // and the shard still serves afterwards
        shard.try_submit(Request { id: 3, task: "hot".into(), tokens: vec![4] }).unwrap();
        assert!(shard.send(ShardMsg::Flush));
        assert!(matches!(ev_rx.recv().unwrap(), ShardEvent::Done(_)));
        assert!(matches!(ev_rx.recv().unwrap(), ShardEvent::FlushAck { .. }));
        shard.stop();
    }

    #[test]
    fn shard_report_default_is_zeroed() {
        let r = ShardReport::default();
        assert_eq!(r.cache_hits, 0);
        assert_eq!(r.stats.requests, 0);
        assert_eq!(r.spans_dropped, 0);
        assert!(r.series.is_empty());
    }

    #[test]
    fn armed_shard_heartbeats_while_idle_and_records_series() {
        let spec = ShardSpec { heartbeat_ms: 10, series_ms: 5, series_cap: 64, ..tiny_spec() };
        let (ev_tx, ev_rx) = std::sync::mpsc::channel();
        let mut shard = ShardHandle::spawn(0, spec, 16, ev_tx);
        // serve one request so the gauges have something to show
        shard.try_submit(Request { id: 1, task: "task0".into(), tokens: vec![1, 2, 3] }).unwrap();
        // idle-wait long enough for several beats, then report
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        let mut beats = 0u64;
        let mut report = None;
        let mut asked = false;
        while std::time::Instant::now() < deadline {
            if beats >= 2 && !asked {
                assert!(shard.send(ShardMsg::Report));
                asked = true;
            }
            match ev_rx.recv_timeout(Duration::from_millis(200)) {
                Ok(ShardEvent::Heartbeat(hb)) => {
                    assert_eq!(hb.shard, 0);
                    beats += 1;
                }
                Ok(ShardEvent::Report(r)) => {
                    report = Some(r);
                    break;
                }
                Ok(_) => {}
                Err(_) => {}
            }
        }
        let report = report.expect("armed idle shard must beat and then report");
        assert!(beats >= 2, "expected repeated idle heartbeats, saw {beats}");
        assert!(!report.series.is_empty(), "armed series must have sampled");
        assert!(report.series.iter().all(|p| p.registry_bytes > 0));
        let last = report.series.last().unwrap();
        assert_eq!(last.requests, 1, "cumulative request counter reaches the series");
        shard.stop();
    }
}
