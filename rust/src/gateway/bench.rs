//! `qst bench-gateway`: shard-count × transport scaling under open-loop
//! load.
//!
//! One deterministic shared-prefix request stream (see
//! [`shared_prefix_pool`]) is driven through the gateway at every
//! configured shard count, once per configured transport (`inproc` shard
//! threads, `socket` shard workers behind real framed socket pairs).
//! The driver is open-loop: it submits as fast as the bounded
//! inboxes/credit windows accept, backing off only on
//! [`SubmitError::Backpressure`], and collects responses as they
//! complete — so the wall-clock measures aggregate fleet throughput, not
//! lock-step round trips.  Each pass reports req/s, merged p50/p95,
//! cache + prefix-hit rates, and the modeled fleet residency — both the
//! in-process figure ([`gateway_resident_bytes`]) and the per-process
//! deployment figure ([`gateway_resident_bytes_multiproc`]).  The report
//! refuses to serialize unless three parity proofs hold:
//!
//! * **sharded parity** — within each transport, every shard count
//!   produced bit-identical logits for every request id (sharding is
//!   wall-clock only);
//! * **transport parity** — socket-transport responses are bit-identical
//!   to the in-proc gateway's (framing is representation only);
//! * **prefix parity** — sampled responses equal a from-scratch,
//!   cache-disabled server's (prefix resumes change nothing but time).
//!
//! `BENCH_gateway.json` accumulates the scaling trajectory across PRs
//! the same way `BENCH_serve.json` does for the single-process server
//! (in-proc passes keep their original key names; socket passes are
//! `socket_`-prefixed).

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::costmodel::memory::{gateway_resident_bytes, gateway_resident_bytes_multiproc};
use crate::proto::TransportKind;
use crate::serve::stats::Json;
use crate::serve::workload::{mixed_length_pool, shared_prefix_pool};
use crate::serve::{BackboneKind, EnginePreset, ServeConfig, Server};
use crate::util::rng::Rng;

use super::{task_name, worker, GatewayConfig, SubmitError};

/// Workload + fleet shape for one `bench-gateway` run.
#[derive(Clone, Debug)]
pub struct BenchGatewayOpts {
    /// shard counts to sweep (same request stream each time)
    pub shard_counts: Vec<usize>,
    /// transports to sweep the shard counts under
    pub transports: Vec<TransportKind>,
    pub tasks: usize,
    pub requests: usize,
    /// prefix families in the prompt pool; members of a family share
    /// their first `prefix_len` tokens (the prefix-cache workload)
    pub families: usize,
    pub per_family: usize,
    pub prefix_len: usize,
    pub prompt_len: usize,
    pub seq: usize,
    pub max_batch: usize,
    pub cache_bytes: usize,
    pub registry_bytes: usize,
    pub prefix_block: usize,
    pub queue_cap: usize,
    pub seed: u64,
    pub threads_per_shard: usize,
    pub preset: EnginePreset,
    pub backbone: BackboneKind,
    /// when set, replay the first (transport, shard-count) pass with the
    /// span recorder armed, refuse to report unless the replay is
    /// bit-identical, and write the fleet Chrome trace file here
    pub trace_out: Option<String>,
    /// requests in the mixed-prompt-length open-loop sweep that compares
    /// the continuous scheduler against a wave-barriered driver (0
    /// disables the sweep)
    pub mixed_requests: usize,
    /// requests per wave in the waved reference pass; 0 picks
    /// `max_shards * max_batch` (one full fleet batch per wave)
    pub mixed_wave: usize,
}

impl Default for BenchGatewayOpts {
    fn default() -> Self {
        BenchGatewayOpts {
            shard_counts: vec![1, 2, 4],
            transports: vec![TransportKind::InProc, TransportKind::Socket],
            tasks: 3,
            requests: 256,
            families: 8,
            per_family: 4,
            prefix_len: 32,
            prompt_len: 48,
            seq: 64,
            max_batch: 8,
            cache_bytes: 64 << 20,
            registry_bytes: 64 << 20,
            prefix_block: 16,
            queue_cap: 64,
            seed: 0,
            threads_per_shard: 1,
            // the scaling acceptance target: the large preset on the
            // packed-W4 backbone (replicas are cheap, compute is heavy)
            preset: EnginePreset::Large,
            backbone: BackboneKind::W4,
            trace_out: None,
            mixed_requests: 96,
            mixed_wave: 0,
        }
    }
}

/// One measured (transport, shard-count) pass.
#[derive(Clone, Debug)]
pub struct GatewayPass {
    pub transport: TransportKind,
    pub shards: usize,
    pub wall_secs: f64,
    pub requests_per_sec: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    /// fleet queue-wait p95 (enqueue → micro-batch execution start),
    /// split out of the total latency by `serve::stats`
    pub queue_p95_ms: f64,
    pub hit_rate: f64,
    pub prefix_hit_rate: f64,
    pub prefix_resumes: u64,
    pub backbone_rows: u64,
    pub resumed_rows: u64,
    /// submits refused with backpressure (each was retried until accepted)
    pub backpressure_rejects: u64,
    /// modeled fleet residency at this shard count, shards in one process
    pub resident_bytes: usize,
    /// modeled fleet residency with each shard its own worker process
    pub resident_bytes_multiproc: usize,
    /// request id -> logits, for the cross-pass parity proofs
    responses: HashMap<u64, Vec<f32>>,
    /// worker-shipped spans absorbed during a traced pass (standalone
    /// socket workers only; empty otherwise and on untraced passes)
    remote_spans: Vec<crate::obs::trace::TraceSpan>,
    /// per-shard gauge flight-recorder series from the final report,
    /// already on their trace lanes (shard i -> pid i+1); only a traced
    /// pass arms the series, so this is empty on measured passes
    counter_tracks: Vec<crate::obs::trace::CounterTrack>,
}

/// The mixed-prompt-length continuous-vs-waved comparison: one open-loop
/// pass under the continuous slot scheduler, one under a driver that
/// re-imposes the old wave barrier (submit a wave, stall until the fleet
/// is fully idle, repeat).  Every request nominally arrives at t0, so a
/// request's latency is its completion time — the p95 is the 95%
/// completion point, measured identically for both modes.
#[derive(Clone, Copy, Debug)]
pub struct MixedSweep {
    pub shards: usize,
    /// requests per wave in the waved reference
    pub wave: usize,
    pub requests: usize,
    pub continuous_wall_secs: f64,
    pub waved_wall_secs: f64,
    pub continuous_p50_ms: f64,
    pub continuous_p95_ms: f64,
    pub waved_p50_ms: f64,
    pub waved_p95_ms: f64,
    /// both modes served bit-identical logits (run_bench refuses to
    /// report otherwise, so this is always true when present)
    pub parity: bool,
}

impl MixedSweep {
    /// Continuous p95 over waved p95 — the headline: < 1.0 means killing
    /// the wave barrier shortened the latency tail.
    pub fn p95_ratio(&self) -> f64 {
        self.continuous_p95_ms / self.waved_p95_ms.max(1e-12)
    }

    pub fn wall_ratio(&self) -> f64 {
        self.continuous_wall_secs / self.waved_wall_secs.max(1e-12)
    }
}

/// The full sweep + parity verdicts.
#[derive(Clone, Debug)]
pub struct BenchGatewayReport {
    pub opts: BenchGatewayOpts,
    pub passes: Vec<GatewayPass>,
    pub sharded_parity: bool,
    pub transport_parity: bool,
    pub prefix_parity: bool,
    /// the continuous-vs-waved mixed-length sweep (`None` when disabled)
    pub mixed: Option<MixedSweep>,
    /// `Some(true)` when a traced replay ran (`--trace-out`) and matched
    /// the untraced pass bit-for-bit — `run_bench` refuses to return
    /// otherwise; `None` when no trace was requested
    pub trace_parity: Option<bool>,
    /// spans written to the trace file (0 when untraced)
    pub trace_spans: usize,
    /// distinct span names in the trace file
    pub trace_kinds: Vec<String>,
    /// gauge flight-recorder points written as counter events alongside
    /// the spans (0 when untraced — only the traced replay arms the
    /// series)
    pub trace_counter_points: usize,
}

/// The deterministic (task, prompt) request stream: the r-th accepted
/// submission always gets gateway id r, so this doubles as the id→request
/// map for the parity probes.
fn stream_choices(opts: &BenchGatewayOpts, pool_len: usize) -> Vec<(usize, usize)> {
    let mut rng = Rng::new(opts.seed.wrapping_add(0x47415445)); // "GATE"
    (0..opts.requests).map(|_| (rng.below(opts.tasks), rng.below(pool_len))).collect()
}

fn run_pass(
    opts: &BenchGatewayOpts,
    transport: TransportKind,
    shards: usize,
    pool: &[Vec<i32>],
    trace: bool,
) -> Result<GatewayPass> {
    let cfg = GatewayConfig {
        shards,
        queue_cap: opts.queue_cap,
        serve: ServeConfig {
            cache_bytes: opts.cache_bytes,
            registry_bytes: opts.registry_bytes,
            max_batch: opts.max_batch,
            prefix_block: opts.prefix_block,
        },
        preset: opts.preset,
        backbone: opts.backbone,
        seed: opts.seed,
        seq: opts.seq,
        tasks: opts.tasks,
        threads_per_shard: opts.threads_per_shard,
        trace,
        // the traced replay doubles as the health-plane parity proof:
        // heartbeats and the gauge flight recorder are armed there (and
        // only there), and the bits must still match the quiet pass.
        // The 1ms series cadence guarantees samples even on a tiny
        // replay that serves in a few milliseconds.
        heartbeat_ms: if trace { 25 } else { 0 },
        health_mult: crate::obs::health::DEFAULT_HEALTH_MULT,
        series_ms: if trace { 1 } else { 0 },
        series_cap: crate::obs::series::SERIES_DEFAULT_CAP,
    };
    let (mut gw, worker_joins) = worker::launch_gateway(&cfg, transport)?;
    let choices = stream_choices(opts, pool.len());
    let mut responses: HashMap<u64, Vec<f32>> = HashMap::with_capacity(opts.requests);
    let t0 = Instant::now();
    for &(task_i, prompt_i) in &choices {
        let task = task_name(task_i);
        loop {
            match gw.submit(&task, &pool[prompt_i]) {
                Ok(_) => break,
                Err(SubmitError::Backpressure { .. }) => {
                    // open-loop back-off: absorb finished work, then sleep
                    // rather than spin — a busy-waiting driver would steal
                    // the very cores the shards are being measured on
                    for gr in gw.try_collect() {
                        responses.insert(gr.resp.id, gr.resp.logits);
                    }
                    std::thread::sleep(std::time::Duration::from_micros(100));
                }
                // SubmitError: std::error::Error, so it chains through
                // anyhow::Context instead of being formatted by hand
                Err(e) => return Err(e).context("gateway refused a bench request"),
            }
        }
        for gr in gw.try_collect() {
            responses.insert(gr.resp.id, gr.resp.logits);
        }
    }
    for gr in gw.flush()? {
        responses.insert(gr.resp.id, gr.resp.logits);
    }
    let wall = t0.elapsed().as_secs_f64();
    let backpressure_rejects = gw.rejected;
    let remote_spans = if trace {
        // one extra report pulls any standalone workers' span batches
        // (Telemetry rides ahead of each Report on the per-shard FIFO)
        let _ = gw.report();
        gw.take_remote_spans()
    } else {
        Vec::new()
    };
    let (report, leftover) = gw.shutdown()?;
    for j in worker_joins {
        let _ = j.join();
    }
    for gr in leftover {
        responses.insert(gr.resp.id, gr.resp.logits);
    }
    // shard i's gauge series renders on counter lane i+1, matching the
    // lane its worker spans ship under (lane 0 = the gateway process)
    let counter_tracks: Vec<crate::obs::trace::CounterTrack> = report
        .shards
        .iter()
        .filter(|r| !r.series.is_empty())
        .map(|r| crate::obs::trace::CounterTrack {
            pid: r.shard as u32 + 1,
            points: r.series.clone(),
        })
        .collect();
    ensure!(
        responses.len() == opts.requests,
        "completed {} of {} requests at {shards} shard(s) over {}",
        responses.len(),
        opts.requests,
        transport.name()
    );
    Ok(GatewayPass {
        transport,
        shards,
        wall_secs: wall,
        requests_per_sec: opts.requests as f64 / wall.max(1e-12),
        p50_ms: report.merged.p50_secs() * 1e3,
        p95_ms: report.merged.p95_secs() * 1e3,
        queue_p95_ms: report.merged.queue_p95_secs() * 1e3,
        hit_rate: report.hit_rate(),
        prefix_hit_rate: report.prefix_hit_rate(),
        prefix_resumes: report.merged.prefix_resumes,
        backbone_rows: report.backbone_rows,
        resumed_rows: report.resumed_rows,
        backpressure_rejects,
        resident_bytes: gateway_resident_bytes(
            opts.preset,
            opts.backbone,
            shards,
            opts.tasks,
            opts.cache_bytes,
        ),
        resident_bytes_multiproc: gateway_resident_bytes_multiproc(
            opts.preset,
            opts.backbone,
            shards,
            opts.tasks,
            opts.cache_bytes,
        ),
        responses,
        remote_spans,
        counter_tracks,
    })
}

/// Nearest-rank percentile of a sorted sample, converted to ms.
fn pct_ms(sorted_secs: &[f64], p: f64) -> f64 {
    if sorted_secs.is_empty() {
        return 0.0;
    }
    let rank = ((p / 100.0) * sorted_secs.len() as f64).ceil() as usize;
    sorted_secs[rank.clamp(1, sorted_secs.len()) - 1] * 1e3
}

/// One mode of the mixed-length sweep: completion times (seconds from
/// pass start, one per request) plus the responses for the parity check.
struct MixedPass {
    wall_secs: f64,
    completions: Vec<f64>,
    responses: HashMap<u64, Vec<f32>>,
}

/// The prompt lengths the mixed sweep interleaves: quarter-, half-, and
/// full-length prompts (requires `prompt_len >= 6` so they are distinct).
fn mixed_lens(prompt_len: usize) -> [usize; 3] {
    [(prompt_len / 4).max(2), prompt_len / 2, prompt_len]
}

/// Drive `pool` through a fresh in-proc fleet in submission order (the
/// pool already interleaves short and long prompts).  `wave == 0` is the
/// continuous mode: pure open-loop, backing off only on backpressure.
/// `wave > 0` re-imposes the pre-continuous scheduler at the driver:
/// after every `wave` submissions it stalls until the entire fleet is
/// idle — the barrier that made short prompts wait out long ones.
/// Collection is identical in both modes (poll + timestamp), so the
/// measured distributions differ only by scheduling.
fn run_mixed_pass(
    opts: &BenchGatewayOpts,
    shards: usize,
    pool: &[Vec<i32>],
    wave: usize,
) -> Result<MixedPass> {
    let cfg = GatewayConfig {
        shards,
        queue_cap: opts.queue_cap,
        serve: ServeConfig {
            cache_bytes: opts.cache_bytes,
            registry_bytes: opts.registry_bytes,
            max_batch: opts.max_batch,
            prefix_block: opts.prefix_block,
        },
        preset: opts.preset,
        backbone: opts.backbone,
        seed: opts.seed,
        seq: opts.seq,
        tasks: opts.tasks,
        threads_per_shard: opts.threads_per_shard,
        trace: false,
        heartbeat_ms: 0,
        health_mult: crate::obs::health::DEFAULT_HEALTH_MULT,
        series_ms: 0,
        series_cap: crate::obs::series::SERIES_DEFAULT_CAP,
    };
    let (mut gw, worker_joins) = worker::launch_gateway(&cfg, TransportKind::InProc)?;
    let deadline = std::time::Duration::from_secs(60);
    let mut completions: Vec<f64> = Vec::with_capacity(pool.len());
    let mut responses: HashMap<u64, Vec<f32>> = HashMap::with_capacity(pool.len());
    let t0 = Instant::now();
    for (r, prompt) in pool.iter().enumerate() {
        let task = task_name(r % opts.tasks);
        loop {
            match gw.submit(&task, prompt) {
                Ok(_) => break,
                Err(SubmitError::Backpressure { .. }) => {
                    ensure!(t0.elapsed() < deadline, "mixed sweep wedged under backpressure");
                    for gr in gw.try_collect() {
                        completions.push(t0.elapsed().as_secs_f64());
                        responses.insert(gr.resp.id, gr.resp.logits);
                    }
                    std::thread::sleep(std::time::Duration::from_micros(50));
                }
                Err(e) => return Err(e).context("gateway refused a mixed-sweep request"),
            }
        }
        for gr in gw.try_collect() {
            completions.push(t0.elapsed().as_secs_f64());
            responses.insert(gr.resp.id, gr.resp.logits);
        }
        if wave > 0 && (r + 1) % wave == 0 {
            // the wave barrier: nothing new is submitted until every
            // request of this wave has been answered
            while gw.in_flight() > 0 {
                ensure!(t0.elapsed() < deadline, "mixed sweep wedged at a wave barrier");
                for gr in gw.try_collect() {
                    completions.push(t0.elapsed().as_secs_f64());
                    responses.insert(gr.resp.id, gr.resp.logits);
                }
                std::thread::sleep(std::time::Duration::from_micros(50));
            }
        }
    }
    // tail: poll (same timestamp resolution as mid-stream), then flush —
    // by now a pure consistency barrier over an already-empty fleet
    while gw.in_flight() > 0 {
        ensure!(t0.elapsed() < deadline, "mixed sweep wedged draining the tail");
        for gr in gw.try_collect() {
            completions.push(t0.elapsed().as_secs_f64());
            responses.insert(gr.resp.id, gr.resp.logits);
        }
        std::thread::sleep(std::time::Duration::from_micros(50));
    }
    for gr in gw.flush()? {
        completions.push(t0.elapsed().as_secs_f64());
        responses.insert(gr.resp.id, gr.resp.logits);
    }
    let wall_secs = t0.elapsed().as_secs_f64();
    let (_report, leftover) = gw.shutdown()?;
    for j in worker_joins {
        let _ = j.join();
    }
    for gr in leftover {
        completions.push(wall_secs);
        responses.insert(gr.resp.id, gr.resp.logits);
    }
    ensure!(
        responses.len() == pool.len(),
        "mixed sweep completed {} of {} requests",
        responses.len(),
        pool.len()
    );
    completions.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok(MixedPass { wall_secs, completions, responses })
}

/// Recompute a sample of the stream on a fresh, cache-disabled,
/// prefix-disabled single server and compare bit-for-bit.
fn check_prefix_parity(
    opts: &BenchGatewayOpts,
    pool: &[Vec<i32>],
    pass: &GatewayPass,
) -> Result<bool> {
    let mut engine = opts.preset.build_backbone(opts.seed, opts.seq, opts.backbone);
    engine.set_threads(1);
    let mut server = Server::new(
        engine,
        ServeConfig {
            cache_bytes: 0,
            registry_bytes: opts.registry_bytes,
            max_batch: 1,
            prefix_block: 0,
        },
    );
    for i in 0..opts.tasks {
        server.registry.register_synthetic(
            &task_name(i),
            super::task_seed(opts.seed, i),
            super::SYNTHETIC_TASK_BYTES,
        )?;
    }
    let choices = stream_choices(opts, pool.len());
    let step = (opts.requests / 8).max(1);
    for r in (0..opts.requests).step_by(step) {
        let (task_i, prompt_i) = choices[r];
        server.submit(&task_name(task_i), &pool[prompt_i])?;
        let mut got = server.drain()?;
        let want = got.remove(0).logits;
        match pass.responses.get(&(r as u64)) {
            Some(l) if *l == want => {}
            _ => return Ok(false),
        }
    }
    Ok(true)
}

impl BenchGatewayReport {
    /// The passes the headline scaling figure is computed over: the
    /// in-proc sweep when one ran (so `shard_scaling_speedup` stays
    /// comparable with pre-socket PRs regardless of `--transports`
    /// order), otherwise whichever single transport did run.
    fn headline_passes(&self) -> Vec<&GatewayPass> {
        let preferred = if self.passes.iter().any(|p| p.transport == TransportKind::InProc) {
            TransportKind::InProc
        } else {
            match self.passes.first() {
                Some(p) => p.transport,
                None => return Vec::new(),
            }
        };
        self.passes.iter().filter(|p| p.transport == preferred).collect()
    }

    /// Aggregate-throughput ratio of the widest fleet over the narrowest
    /// (see [`Self::headline_passes`] for which transport it reflects).
    pub fn scaling_speedup(&self) -> f64 {
        let passes = self.headline_passes();
        let lo = passes.iter().min_by_key(|p| p.shards);
        let hi = passes.iter().max_by_key(|p| p.shards);
        match (lo, hi) {
            (Some(lo), Some(hi)) => hi.requests_per_sec / lo.requests_per_sec.max(1e-12),
            _ => 1.0,
        }
    }

    /// Socket / in-proc aggregate-throughput ratio at the widest common
    /// shard count — the measured cost of the wire (1.0 when only one
    /// transport ran).
    pub fn transport_rps_ratio(&self) -> f64 {
        let at = |t: TransportKind| {
            self.passes.iter().filter(|p| p.transport == t).max_by_key(|p| p.shards)
        };
        match (at(TransportKind::InProc), at(TransportKind::Socket)) {
            (Some(i), Some(s)) if i.shards == s.shards => {
                s.requests_per_sec / i.requests_per_sec.max(1e-12)
            }
            _ => 1.0,
        }
    }

    pub fn to_json(&self) -> String {
        let (d, layers, vocab, r) = self.opts.preset.shape();
        let transports: Vec<&str> = self.opts.transports.iter().map(|t| t.name()).collect();
        let mut j = Json::new()
            .provenance()
            .str("bench", "gateway")
            .str("preset", self.opts.preset.name())
            .int("d", d as u64)
            .int("layers", layers as u64)
            .int("vocab", vocab as u64)
            .int("reduction", r as u64)
            .str("backbone", self.opts.backbone.name())
            .str("transports", &transports.join(","))
            .int("proto_version", crate::proto::frame::VERSION as u64)
            .int("tasks", self.opts.tasks as u64)
            .int("requests", self.opts.requests as u64)
            .int("unique_prompts", (self.opts.families * self.opts.per_family) as u64)
            .int("families", self.opts.families as u64)
            .int("per_family", self.opts.per_family as u64)
            .int("prefix_len", self.opts.prefix_len as u64)
            .int("prompt_len", self.opts.prompt_len as u64)
            .int("seq", self.opts.seq as u64)
            .int("max_batch", self.opts.max_batch as u64)
            .int("cache_bytes", self.opts.cache_bytes as u64)
            .int("prefix_block", self.opts.prefix_block as u64)
            .int("queue_cap", self.opts.queue_cap as u64)
            .int("threads_per_shard", self.opts.threads_per_shard as u64)
            .int("seed", self.opts.seed);
        for p in &self.passes {
            // in-proc passes keep the PR 4 key names so the JSON
            // trajectory stays comparable; socket passes are prefixed
            let prefix = match p.transport {
                TransportKind::InProc => "",
                TransportKind::Socket => "socket_",
            };
            let k = |name: &str| format!("{prefix}shards{}_{name}", p.shards);
            j = j
                .num(&k("rps"), p.requests_per_sec)
                .num(&k("wall_secs"), p.wall_secs)
                .num(&k("p50_ms"), p.p50_ms)
                .num(&k("p95_ms"), p.p95_ms)
                .num(&k("queue_p95_ms"), p.queue_p95_ms)
                .num(&k("hit_rate"), p.hit_rate)
                .num(&k("prefix_hit_rate"), p.prefix_hit_rate)
                .int(&k("prefix_resumes"), p.prefix_resumes)
                .int(&k("backbone_rows"), p.backbone_rows)
                .int(&k("resumed_rows"), p.resumed_rows)
                .int(&k("backpressure_rejects"), p.backpressure_rejects)
                .int(&k("resident_bytes"), p.resident_bytes as u64)
                .int(&k("resident_bytes_multiproc"), p.resident_bytes_multiproc as u64);
        }
        j = j
            .num("shard_scaling_speedup", self.scaling_speedup())
            .num("transport_rps_ratio", self.transport_rps_ratio())
            .int("sharded_parity", self.sharded_parity as u64)
            .int("transport_parity", self.transport_parity as u64)
            .int("prefix_parity", self.prefix_parity as u64);
        if let Some(m) = &self.mixed {
            j = j
                .int("mixed_requests", m.requests as u64)
                .int("mixed_wave", m.wave as u64)
                .int("mixed_shards", m.shards as u64)
                .num("mixed_continuous_wall_secs", m.continuous_wall_secs)
                .num("mixed_waved_wall_secs", m.waved_wall_secs)
                .num("mixed_continuous_p50_ms", m.continuous_p50_ms)
                .num("mixed_continuous_p95_ms", m.continuous_p95_ms)
                .num("mixed_waved_p50_ms", m.waved_p50_ms)
                .num("mixed_waved_p95_ms", m.waved_p95_ms)
                .num("continuous_p95_ratio", m.p95_ratio())
                .num("continuous_wall_ratio", m.wall_ratio())
                // run_bench refuses to serialize otherwise, so this is
                // always 1 when present — recorded to be self-auditing
                .int("mixed_parity", m.parity as u64);
        }
        if let Some(tp) = self.trace_parity {
            j = j
                .int("trace_parity", tp as u64)
                .int("trace_spans", self.trace_spans as u64)
                .str("trace_kinds", &self.trace_kinds.join(","))
                .int("trace_counter_points", self.trace_counter_points as u64);
        }
        j.finish()
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "gateway bench [{} preset, {} backbone, {} req over {} prompts ({} families x {}), block {}]:",
            self.opts.preset.name(),
            self.opts.backbone.name(),
            self.opts.requests,
            self.opts.families * self.opts.per_family,
            self.opts.families,
            self.opts.per_family,
            self.opts.prefix_block,
        );
        for p in &self.passes {
            s.push_str(&format!(
                " | {} {} shard(s): {:.1} req/s, p95 {:.2} ms, hit {:.0}%, prefix rescue {:.0}%, {} resident ({} as processes)",
                p.transport.name(),
                p.shards,
                p.requests_per_sec,
                p.p95_ms,
                p.hit_rate * 100.0,
                p.prefix_hit_rate * 100.0,
                crate::util::human_bytes(p.resident_bytes as f64),
                crate::util::human_bytes(p.resident_bytes_multiproc as f64),
            ));
        }
        if let Some(m) = &self.mixed {
            s.push_str(&format!(
                " | mixed {} req @ {} shard(s), wave {}: continuous p95 {:.2} ms vs waved {:.2} ms (ratio {:.2}, parity {})",
                m.requests,
                m.shards,
                m.wave,
                m.continuous_p95_ms,
                m.waved_p95_ms,
                m.p95_ratio(),
                m.parity,
            ));
        }
        s.push_str(&format!(
            " | scaling {:.2}x | socket/inproc rps {:.2}x | parity sharded={} transport={} prefix={}",
            self.scaling_speedup(),
            self.transport_rps_ratio(),
            self.sharded_parity,
            self.transport_parity,
            self.prefix_parity
        ));
        if let Some(tp) = self.trace_parity {
            s.push_str(&format!(
                " trace={tp} ({} spans, {} kinds, {} gauge points)",
                self.trace_spans,
                self.trace_kinds.len(),
                self.trace_counter_points
            ));
        }
        s
    }
}

/// Run the sweep; refuses to report if any parity proof fails.
pub fn run_bench(opts: &BenchGatewayOpts) -> Result<BenchGatewayReport> {
    ensure!(!opts.shard_counts.is_empty(), "need at least one shard count");
    ensure!(opts.shard_counts.iter().all(|&n| n >= 1), "shard counts must be >= 1");
    ensure!(!opts.transports.is_empty(), "need at least one transport");
    ensure!(opts.tasks >= 1 && opts.requests >= 1);
    ensure!(opts.prompt_len <= opts.seq, "prompt_len must be <= seq");
    ensure!(opts.prefix_len >= 1 && opts.prefix_len < opts.prompt_len);
    ensure!(opts.prefix_block >= 1, "bench-gateway exercises the prefix cache");
    ensure!(
        opts.prefix_len % opts.prefix_block == 0,
        "--prefix-len {} must be a multiple of --prefix-block {} so family prefixes are index-visible",
        opts.prefix_len,
        opts.prefix_block
    );
    let vocab = opts.preset.vocab();
    let mut rng = Rng::new(opts.seed.wrapping_add(0xBEAC));
    let pool = shared_prefix_pool(
        &mut rng,
        opts.families,
        opts.per_family,
        opts.prefix_len,
        opts.prompt_len,
        vocab,
    );
    let mut passes = Vec::with_capacity(opts.shard_counts.len() * opts.transports.len());
    for &t in &opts.transports {
        for &n in &opts.shard_counts {
            passes.push(run_pass(opts, t, n, &pool, false)?);
        }
    }
    // within each transport, every shard count must agree bit-for-bit
    let sharded_parity = opts.transports.iter().all(|&t| {
        let mut group = passes.iter().filter(|p| p.transport == t);
        match group.next() {
            None => true,
            Some(first) => group.all(|p| p.responses == first.responses),
        }
    });
    ensure!(
        sharded_parity,
        "sharded logits diverged across shard counts — sharding must be wall-clock only"
    );
    // and the transports must agree with each other
    let transport_parity = passes.iter().all(|p| p.responses == passes[0].responses);
    ensure!(
        transport_parity,
        "socket-transport logits diverged from the in-proc gateway — framing must be representation only"
    );
    let prefix_parity = check_prefix_parity(opts, &pool, &passes[0])?;
    ensure!(
        prefix_parity,
        "prefix-resumed logits diverged from the from-scratch reference"
    );
    // continuous-vs-waved mixed-length sweep: same mixed pool through a
    // slot-admitting fleet and through a driver-emulated wave barrier —
    // refuse to report unless the bits agree
    let mixed = if opts.mixed_requests > 0 {
        ensure!(
            opts.prompt_len >= 6,
            "mixed sweep needs prompt_len >= 6 to derive three distinct lengths"
        );
        let shards = *opts.shard_counts.iter().max().unwrap();
        let wave =
            if opts.mixed_wave > 0 { opts.mixed_wave } else { (shards * opts.max_batch).max(1) };
        let mut mrng = Rng::new(opts.seed.wrapping_add(0x4D495845)); // "MIXE"
        let mixed_pool =
            mixed_length_pool(&mut mrng, opts.mixed_requests, &mixed_lens(opts.prompt_len), vocab);
        let cont = run_mixed_pass(opts, shards, &mixed_pool, 0)?;
        let waved = run_mixed_pass(opts, shards, &mixed_pool, wave)?;
        let parity = cont.responses == waved.responses;
        ensure!(parity, "continuous-admission logits diverged from the waved reference");
        Some(MixedSweep {
            shards,
            wave,
            requests: opts.mixed_requests,
            continuous_wall_secs: cont.wall_secs,
            waved_wall_secs: waved.wall_secs,
            continuous_p50_ms: pct_ms(&cont.completions, 50.0),
            continuous_p95_ms: pct_ms(&cont.completions, 95.0),
            waved_p50_ms: pct_ms(&waved.completions, 50.0),
            waved_p95_ms: pct_ms(&waved.completions, 95.0),
            parity,
        })
    } else {
        None
    };
    // fourth parity proof, when a trace was requested: replay the first
    // pass with the recorder armed and refuse to report unless the traced
    // fleet served the exact same bits
    let (trace_parity, trace_spans, trace_kinds, trace_counter_points) = match &opts.trace_out {
        None => (None, 0, Vec::new(), 0),
        Some(path) => {
            let _ = crate::obs::drain(); // discard any stale spans
            crate::obs::set_enabled(true);
            let traced = run_pass(opts, opts.transports[0], opts.shard_counts[0], &pool, true);
            crate::obs::set_enabled(false);
            let traced = traced?;
            let (spans, dropped) = crate::obs::drain();
            ensure!(
                traced.responses == passes[0].responses,
                "tracing changed the served bits — refusing to write {path}"
            );
            if dropped > 0 {
                eprintln!("trace: {dropped} span(s) lost to ring overwrite");
            }
            let mut all = crate::obs::trace::local(spans);
            all.extend(traced.remote_spans);
            let kinds: Vec<String> =
                crate::obs::trace::kinds_present(&all).iter().map(|s| s.to_string()).collect();
            let counter_points: usize =
                traced.counter_tracks.iter().map(|t| t.points.len()).sum();
            crate::obs::trace::write_file_with_counters(path, &all, &traced.counter_tracks)
                .with_context(|| format!("writing trace {path}"))?;
            (Some(true), all.len(), kinds, counter_points)
        }
    };
    Ok(BenchGatewayReport {
        opts: opts.clone(),
        passes,
        sharded_parity,
        transport_parity,
        prefix_parity,
        mixed,
        trace_parity,
        trace_spans,
        trace_kinds,
        trace_counter_points,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> BenchGatewayOpts {
        BenchGatewayOpts {
            shard_counts: vec![1, 2],
            transports: vec![TransportKind::InProc, TransportKind::Socket],
            tasks: 2,
            requests: 32,
            families: 2,
            per_family: 3,
            prefix_len: 8,
            prompt_len: 12,
            seq: 16,
            // batch of 1 ⇒ every family's first member is cached before the
            // next member arrives, so prefix resumes are deterministic
            max_batch: 1,
            cache_bytes: 16 << 20,
            registry_bytes: 1 << 20,
            prefix_block: 4,
            queue_cap: 8,
            seed: 5,
            threads_per_shard: 1,
            preset: EnginePreset::Small,
            backbone: BackboneKind::F32,
            trace_out: None,
            // prompt_len 12 ⇒ mixed lengths [3, 6, 12]; wave of 4 makes the
            // waved reference genuinely bursty even at this tiny scale
            mixed_requests: 24,
            mixed_wave: 4,
        }
    }

    #[test]
    fn bench_completes_with_parity_across_transports_and_prefix_rescues() {
        let rep = run_bench(&tiny()).unwrap();
        assert_eq!(rep.passes.len(), 4, "2 shard counts x 2 transports");
        assert!(rep.sharded_parity && rep.transport_parity && rep.prefix_parity);
        for p in &rep.passes {
            assert!(p.requests_per_sec > 0.0);
            assert!(p.resident_bytes > 0);
            assert!(
                p.resident_bytes_multiproc > p.resident_bytes,
                "process deployment must model extra overhead"
            );
            // warm cache: far fewer full forwards than requests
            assert!(p.backbone_rows + p.resumed_rows <= 32);
        }
        // the shared-prefix workload must actually exercise the resume path
        assert!(
            rep.passes.iter().all(|p| p.prefix_resumes > 0),
            "shared-prefix workload produced no prefix resumes"
        );
        assert!(rep.transport_rps_ratio() > 0.0);
        // mixed sweep ran, held bit-parity, and measured both modes —
        // the timing *ratio* is deliberately not asserted here (CI noise);
        // scripts/check.sh gates it on the real smoke run
        let m = rep.mixed.expect("tiny opts enable the mixed sweep");
        assert!(m.parity);
        assert_eq!(m.requests, 24);
        assert_eq!(m.shards, 2);
        assert!(m.continuous_p95_ms > 0.0 && m.waved_p95_ms > 0.0);
        assert!(m.p95_ratio() > 0.0 && m.wall_ratio() > 0.0);
    }

    #[test]
    fn json_report_is_wellformed() {
        let rep = run_bench(&tiny()).unwrap();
        let j = rep.to_json();
        assert!(j.contains("\"bench\": \"gateway\""));
        assert!(j.contains("\"transports\": \"inproc,socket\""));
        assert!(j.contains("\"proto_version\": 1"));
        assert!(j.contains("\"shards1_rps\""));
        assert!(j.contains("\"shards2_rps\""));
        assert!(j.contains("\"shards2_prefix_hit_rate\""));
        assert!(j.contains("\"socket_shards1_rps\""));
        assert!(j.contains("\"socket_shards2_rps\""));
        assert!(j.contains("\"shards2_resident_bytes_multiproc\""));
        assert!(j.contains("\"shard_scaling_speedup\""));
        assert!(j.contains("\"transport_rps_ratio\""));
        assert!(j.contains("\"sharded_parity\": 1"));
        assert!(j.contains("\"transport_parity\": 1"));
        assert!(j.contains("\"prefix_parity\": 1"));
        assert!(j.contains("\"shards2_queue_p95_ms\""));
        assert!(j.contains("\"mixed_parity\": 1"));
        assert!(j.contains("\"continuous_p95_ratio\""));
        assert!(j.contains("\"mixed_continuous_p95_ms\""));
        assert!(j.contains("\"mixed_waved_p95_ms\""));
        assert!(j.contains("\"shards2_resident_bytes\""));
        assert!(j.trim_end().ends_with('}'));
        assert!(rep.summary().contains("scaling"));
        assert!(rep.summary().contains("socket"));
    }

    #[test]
    fn rejects_misaligned_prefix_and_empty_sweeps() {
        let mut o = tiny();
        o.prefix_len = 6; // not a multiple of block 4
        assert!(run_bench(&o).is_err());
        let mut o = tiny();
        o.shard_counts = vec![];
        assert!(run_bench(&o).is_err());
        let mut o = tiny();
        o.transports = vec![];
        assert!(run_bench(&o).is_err());
        let mut o = tiny();
        o.prompt_len = 32; // > seq
        assert!(run_bench(&o).is_err());
    }

    #[test]
    fn traced_replay_holds_parity_and_writes_the_fleet_trace() {
        // serializes against the obs unit tests — the recorder is
        // process-global
        let _g = crate::obs::test_lock();
        let path = std::env::temp_dir().join("qst_bench_gateway_trace_test.json");
        let mut o = tiny();
        o.shard_counts = vec![2];
        o.transports = vec![TransportKind::Socket];
        o.trace_out = Some(path.to_string_lossy().into_owned());
        let rep = run_bench(&o).unwrap();
        assert_eq!(rep.trace_parity, Some(true));
        assert!(rep.trace_spans > 0);
        for k in
            ["admit", "route", "shard_queue", "batch_assemble", "backbone", "prefix_resume", "sidenet", "respond"]
        {
            assert!(rep.trace_kinds.iter().any(|s| s == k), "missing span kind {k}: {:?}", rep.trace_kinds);
        }
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.starts_with("{\"displayTimeUnit\""));
        assert!(body.contains("\"name\":\"backbone\""));
        // the replay arms the gauge flight recorder: counter events ride
        // in the same trace, on the shard lanes (pid = shard + 1)
        assert!(
            rep.trace_counter_points > 0,
            "traced replay must record gauge series points"
        );
        assert!(body.contains("\"ph\":\"C\""), "gauge counters render as counter events");
        assert!(body.contains("\"name\":\"queue_depth\""));
        assert!(body.contains("\"name\":\"cache_bytes\""));
        let j = rep.to_json();
        assert!(j.contains("\"trace_parity\": 1"));
        assert!(j.contains("\"trace_counter_points\""));
        assert!(j.contains("\"schema_version\": 2"));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn inproc_only_sweep_still_reports() {
        let mut o = tiny();
        o.transports = vec![TransportKind::InProc];
        o.shard_counts = vec![1];
        o.requests = 12;
        let rep = run_bench(&o).unwrap();
        assert!(rep.transport_parity, "single-transport sweep is trivially transport-consistent");
        assert_eq!(rep.transport_rps_ratio(), 1.0);
    }
}
