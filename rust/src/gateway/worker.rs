//! The socket shard worker: `qst shard-worker --listen <addr>`.
//!
//! A worker is one gateway shard running as its own process.  It binds a
//! Unix-domain (`unix:/path`) or TCP (`host:port`) listener, accepts the
//! gateway's connection, and waits for the first frame — a
//! [`ShardMsg::Configure`] carrying the fleet's [`ShardSpec`] — before
//! building its engine/server replica.  One config (the gateway's)
//! drives every worker, so replicas are bit-identical by construction
//! and workers take **no** model flags.
//!
//! After configuration the worker runs the exact same serving loop as an
//! in-proc shard thread ([`run_core_loop`]): a reader thread decodes
//! frames into an mpsc channel (mirroring the in-proc inbox, so the
//! micro-batch soak behaves identically), the main thread serves and
//! writes [`ShardEvent`] frames back.  Backpressure is enforced
//! gateway-side (credit window, see [`crate::proto::transport`]), which
//! keeps the worker's channel effectively bounded.
//!
//! [`spawn_local_fleet`] runs the same worker loop on in-process threads
//! over real socket pairs — how `tests/gateway.rs` and `bench-gateway`
//! exercise the full framing + socket path without spawning processes;
//! `scripts/check.sh` covers the true multi-process flow.

use std::io::Write;
use std::sync::mpsc::Receiver;
use std::thread::JoinHandle;

use anyhow::{bail, Context, Result};

use crate::proto::transport::{parse_addr, SocketTransport, Stream, TransportKind, WireAddr};
use crate::proto::{frame, ShardEvent, ShardMsg};

use super::shard::{run_core_loop, ShardCore};
use super::{Gateway, GatewayConfig};

/// Serve one gateway connection to completion (Shutdown frame, clean
/// peer close, or a fatal protocol error).
///
/// `standalone` says this worker owns its process (`qst shard-worker`):
/// only then does the spec's `trace` flag drive the process-global span
/// recorder and get the rings shipped back as `Telemetry` events.  The
/// in-process socket fleet ([`spawn_local_fleet`]) passes `false` — its
/// worker threads share the gateway's rings, so toggling or draining
/// them here would steal (or double-count) the gateway's own spans.
pub fn serve_stream(stream: Box<dyn Stream>, standalone: bool) -> Result<()> {
    let mut read_half = stream.try_clone_stream().context("cloning worker stream")?;
    let mut write_half = stream;
    // the first frame must configure this shard
    let first = frame::read_msg(&mut read_half)
        .context("reading Configure frame")?
        .context("gateway closed the connection before Configure")?;
    let (index, spec) = match first {
        ShardMsg::Configure { shard, spec } => (shard, spec),
        other => bail!("expected Configure as the first frame, got {other:?}"),
    };
    let core = ShardCore::from_spec(index, &spec)
        .with_context(|| format!("building shard {index} replica from the gateway's spec"))?;
    // the gateway's --trace-out flag rides the spec: a traced fleet turns
    // every standalone worker's span recorder on, and the rings come back
    // as Telemetry events (credit-neutral, see run_core_loop)
    let ship_telemetry = standalone && spec.trace;
    if standalone {
        crate::obs::set_enabled(spec.trace);
    }
    eprintln!(
        "shard-worker: configured as shard {index} ({} preset, {} backbone, {} task(s), seq {})",
        spec.preset.name(),
        spec.backbone.name(),
        spec.tasks,
        spec.seq
    );
    // reader thread: frames -> channel (the worker's "inbox", mirroring
    // the in-proc bounded queue; boundedness comes from the gateway's
    // credit window)
    let (tx, rx): (std::sync::mpsc::Sender<ShardMsg>, Receiver<ShardMsg>) =
        std::sync::mpsc::channel();
    let reader = std::thread::Builder::new()
        .name(format!("qst-worker-reader-{index}"))
        .spawn(move || loop {
            match frame::read_msg(&mut read_half) {
                Ok(Some(m)) => {
                    if tx.send(m).is_err() {
                        break; // serving loop exited first
                    }
                }
                Ok(None) => break, // gateway closed cleanly
                Err(e) => {
                    eprintln!("shard-worker: dropping connection on bad frame: {e:#}");
                    break;
                }
            }
        })
        .context("spawning worker reader thread")?;
    let mut emit = |ev: ShardEvent| {
        // a write failure means the gateway is gone; the reader will see
        // EOF and the loop will wind down via the closed channel
        let _ = write_half.write_all(&frame::encode_event(&ev));
    };
    run_core_loop(core, &rx, &mut emit, ship_telemetry);
    // unblock + join the reader: closing our write half sends FIN only
    // on some platforms, so shut the socket down both ways explicitly
    let _ = write_half.shutdown_both();
    drop(rx);
    let _ = reader.join();
    eprintln!("shard-worker: shard {index} done");
    Ok(())
}

/// Bind `addr`, accept exactly one gateway connection, and serve it to
/// completion.  This is the whole life of a `qst shard-worker` process.
pub fn listen_and_serve(addr: &str) -> Result<()> {
    match parse_addr(addr) {
        WireAddr::Unix(path) => listen_unix(&path),
        WireAddr::Tcp(a) => {
            let listener = std::net::TcpListener::bind(&a)
                .with_context(|| format!("binding shard-worker listener on {a}"))?;
            eprintln!(
                "shard-worker: listening on {}",
                listener.local_addr().map(|x| x.to_string()).unwrap_or(a)
            );
            let (stream, peer) = listener.accept().context("accepting gateway connection")?;
            let _ = stream.set_nodelay(true);
            eprintln!("shard-worker: gateway connected from {peer}");
            serve_stream(Box::new(stream), true)
        }
    }
}

#[cfg(unix)]
fn listen_unix(path: &str) -> Result<()> {
    let _ = std::fs::remove_file(path); // stale socket from a previous run
    let listener = std::os::unix::net::UnixListener::bind(path)
        .with_context(|| format!("binding shard-worker listener on unix:{path}"))?;
    eprintln!("shard-worker: listening on unix:{path}");
    let accepted = listener.accept().context("accepting gateway connection");
    let result = accepted.and_then(|(stream, _)| {
        eprintln!("shard-worker: gateway connected");
        serve_stream(Box::new(stream), true)
    });
    let _ = std::fs::remove_file(path);
    result
}

#[cfg(not(unix))]
fn listen_unix(_path: &str) -> Result<()> {
    bail!("unix:<path> addresses need a unix platform; use a <host>:<port> TCP address")
}

/// One end-pair of connected streams for an in-process socket fleet.
#[cfg(unix)]
fn local_pair() -> Result<(Box<dyn Stream>, Box<dyn Stream>)> {
    let (a, b) = std::os::unix::net::UnixStream::pair().context("creating socketpair")?;
    Ok((Box::new(a), Box::new(b)))
}

#[cfg(not(unix))]
fn local_pair() -> Result<(Box<dyn Stream>, Box<dyn Stream>)> {
    let listener =
        std::net::TcpListener::bind("127.0.0.1:0").context("binding loopback listener")?;
    let addr = listener.local_addr().context("loopback listener address")?;
    let client = std::net::TcpStream::connect(addr).context("connecting loopback pair")?;
    let (server, _) = listener.accept().context("accepting loopback pair")?;
    let _ = client.set_nodelay(true);
    let _ = server.set_nodelay(true);
    Ok((Box::new(client), Box::new(server)))
}

/// Launch a [`Gateway`] on either transport: in-proc shard threads, or
/// an in-process socket fleet ([`spawn_local_fleet`]).  The one
/// construction path `bench-gateway` and the parity tests share, so
/// they cannot drift into exercising different wirings.  Returns the
/// worker join handles to join after the gateway shuts down (empty for
/// in-proc).
pub fn launch_gateway(
    cfg: &GatewayConfig,
    kind: TransportKind,
) -> Result<(Gateway, Vec<JoinHandle<()>>)> {
    match kind {
        TransportKind::InProc => Ok((Gateway::launch(cfg)?, Vec::new())),
        TransportKind::Socket => {
            let (transport, joins) = spawn_local_fleet(cfg)?;
            Ok((Gateway::with_transport(cfg, Box::new(transport))?, joins))
        }
    }
}

/// Spawn `cfg.shards` worker *threads*, each running the real socket
/// worker loop over its own connected stream pair, and return the
/// configured [`SocketTransport`] plus the worker join handles (join
/// them after the gateway shuts down).  Everything crosses genuine
/// socket framing — only the process boundary is elided.
pub fn spawn_local_fleet(cfg: &GatewayConfig) -> Result<(SocketTransport, Vec<JoinHandle<()>>)> {
    let mut gw_ends: Vec<Box<dyn Stream>> = Vec::with_capacity(cfg.shards);
    let mut joins = Vec::with_capacity(cfg.shards);
    for i in 0..cfg.shards {
        let (gw_end, worker_end) = local_pair()?;
        let join = std::thread::Builder::new()
            .name(format!("qst-socket-shard-{i}"))
            .spawn(move || {
                // not standalone: these threads share the gateway's
                // process, so spans stay in the local rings (drained by
                // the gateway directly, exactly like in-proc shards)
                if let Err(e) = serve_stream(worker_end, false) {
                    eprintln!("socket shard {i}: {e:#}");
                }
            })
            .with_context(|| format!("spawning socket shard {i}"))?;
        gw_ends.push(gw_end);
        joins.push(join);
    }
    let transport = SocketTransport::from_streams(gw_ends, &cfg.shard_spec(), cfg.queue_cap)?;
    Ok((transport, joins))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gateway::{task_name, task_seed, Gateway};
    use crate::proto::transport::dial_retry;
    use crate::serve::{BackboneKind, EnginePreset, ServeConfig, Server};

    fn cfg(shards: usize) -> GatewayConfig {
        GatewayConfig {
            shards,
            queue_cap: 8,
            seq: 16,
            seed: 13,
            tasks: 2,
            threads_per_shard: 1,
            preset: EnginePreset::Small,
            backbone: BackboneKind::F32,
            serve: ServeConfig {
                cache_bytes: 4 << 20,
                registry_bytes: 1 << 20,
                max_batch: 4,
                prefix_block: 4,
            },
            trace: false,
            heartbeat_ms: 0,
            health_mult: crate::obs::health::DEFAULT_HEALTH_MULT,
            series_ms: 0,
            series_cap: crate::obs::series::SERIES_DEFAULT_CAP,
        }
    }

    #[test]
    fn local_socket_fleet_round_trips_and_matches_direct_server() {
        let c = cfg(2);
        let (transport, joins) = spawn_local_fleet(&c).unwrap();
        let mut gw = Gateway::with_transport(&c, Box::new(transport)).unwrap();
        let prompt = vec![2i32, 7, 1];
        let id = gw.submit("task1", &prompt).unwrap();
        let got = gw.flush().unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].resp.id, id);
        // reference server with the same spec
        let spec = c.shard_spec();
        let mut engine = spec.preset.build_backbone(spec.seed, spec.seq, spec.backbone);
        engine.set_threads(1);
        let mut server = Server::new(engine, spec.serve);
        for i in 0..spec.tasks {
            server
                .registry
                .register_synthetic(&task_name(i), task_seed(spec.seed, i), 1 << 12)
                .unwrap();
        }
        server.submit("task1", &prompt).unwrap();
        let want = server.drain().unwrap();
        assert_eq!(got[0].resp.logits, want[0].logits, "socket shard must be bit-identical");
        let (report, leftover) = gw.shutdown().unwrap();
        assert!(leftover.is_empty());
        assert_eq!(report.merged.requests, 1);
        assert_eq!(report.shards.len(), 2);
        for j in joins {
            j.join().unwrap();
        }
    }

    #[test]
    fn report_interleaved_with_in_flight_drain_over_sockets() {
        // start_report races the shards' own drains: every shard already
        // has submits queued ahead of the Report frame, so the Done
        // events are in flight on the wire while report() awaits.  No
        // response may be lost and no shard's counters may be dropped.
        let c = cfg(2);
        let (transport, joins) = spawn_local_fleet(&c).unwrap();
        let mut gw = Gateway::with_transport(&c, Box::new(transport)).unwrap();
        for i in 0..8 {
            gw.submit(&task_name(i % 2), &[i as i32 + 1, 2, 3]).unwrap();
        }
        let report = gw.report().unwrap();
        assert_eq!(report.shards.len(), 2);
        // responses that crossed the report are stashed, not dropped
        let got = gw.flush().unwrap();
        assert_eq!(got.len(), 8, "every in-flight response survives the racing report");
        let (final_report, leftover) = gw.shutdown().unwrap();
        assert!(leftover.is_empty());
        assert_eq!(final_report.merged.requests, 8);
        assert_eq!(final_report.merged.hist.count(), 8, "fleet histogram counts every request");
        for j in joins {
            j.join().unwrap();
        }
    }

    #[test]
    fn socket_credit_window_backpressures_deterministically() {
        // window of 1: with no events collected, the second submit MUST
        // reject — credit-based backpressure is exact, not racy
        let mut c = cfg(1);
        c.queue_cap = 1;
        let (transport, joins) = spawn_local_fleet(&c).unwrap();
        let mut gw = Gateway::with_transport(&c, Box::new(transport)).unwrap();
        gw.submit("task0", &[1]).unwrap();
        match gw.submit("task0", &[2]) {
            Err(crate::proto::SubmitError::Backpressure { shard: 0 }) => {}
            other => panic!("expected deterministic backpressure, got {other:?}"),
        }
        assert_eq!(gw.rejected, 1);
        // collecting outcomes frees credit and the fleet drains fine
        let got = gw.flush().unwrap();
        assert_eq!(got.len(), 1);
        gw.submit("task0", &[2]).unwrap();
        assert_eq!(gw.flush().unwrap().len(), 1);
        let _ = gw.shutdown().unwrap();
        for j in joins {
            j.join().unwrap();
        }
    }

    #[test]
    fn tcp_listener_and_dial_serve_a_request() {
        // the real listen/accept/dial path over TCP loopback, worker on a
        // thread — what `qst shard-worker` does, minus the process fork
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let worker = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let _ = stream.set_nodelay(true);
            // standalone=false: keep the test from toggling the
            // process-global recorder under parallel test threads
            serve_stream(Box::new(stream), false).unwrap();
        });
        let c = cfg(1);
        let stream = dial_retry(&addr, 20, std::time::Duration::from_millis(10)).unwrap();
        let transport =
            SocketTransport::from_streams(vec![stream], &c.shard_spec(), c.queue_cap).unwrap();
        let mut gw = Gateway::with_transport(&c, Box::new(transport)).unwrap();
        gw.submit("task0", &[5, 6, 7]).unwrap();
        let got = gw.flush().unwrap();
        assert_eq!(got.len(), 1);
        let (report, _) = gw.shutdown().unwrap();
        assert_eq!(report.merged.requests, 1);
        worker.join().unwrap();
    }
}
