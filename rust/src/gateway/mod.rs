//! Asynchronous sharded serving gateway over the QST multi-task server.
//!
//! # Design
//!
//! QST's economics make replication the natural way to scale serving: the
//! frozen backbone is shared by every task and, packed as W4 (PR 3), a
//! replica costs ~7.6× less than f32 — so N shards each hold a private
//! backbone replica + hidden-state cache + side-network registry, and
//! the gateway's job is transport, routing, and aggregation:
//!
//! ```text
//!   submit(task, tokens) ──▶ [router]  hash(first prefix-block tokens)
//!         │ SubmitError::Backpressure when the inbox is full
//!         ▼
//!   [shard 0] [shard 1] … [shard N-1]    bounded inboxes (try_send)
//!      each: thread-owned Server<SyntheticEngine>
//!            queue → prefix-aware cache → backbone/resume → side nets
//!         │ ShardEvent::Done / Dropped / Rejected
//!         ▼
//!   [events channel] ──▶ try_collect() / flush() ──▶ responses
//!   [aggregator]     ──▶ report(): merged stats + summed cache counters
//! ```
//!
//! * [`transport`] — request/response/event types, [`SubmitError`]
//!   backpressure semantics, and the `qst gateway` line-protocol loop.
//! * [`router`] — prefix-locality routing (prompts sharing a
//!   `prefix_block`-aligned head land on one shard, where the prefix
//!   cache can resume them) + per-shard report aggregation.
//! * [`shard`] — the worker threads; each owns a bit-identical engine
//!   replica, so sharding changes wall-clock only, never logits.
//! * [`bench`] — `qst bench-gateway`: shard-count scaling curves,
//!   prefix-hit rates, and p50/p95 under open-loop load
//!   (`BENCH_gateway.json`).

pub mod bench;
pub mod router;
pub mod shard;
pub mod transport;

use std::sync::mpsc::{Receiver, Sender, TryRecvError};

use anyhow::{bail, Result};

use crate::serve::{BackboneKind, EnginePreset, ServeConfig};

pub use router::{aggregate, GatewayReport, Router};
pub use shard::{ShardHandle, ShardReport};
pub use transport::{line_loop, GatewayRequest, GatewayResponse, ShardEvent, ShardMsg, SubmitError};

pub use crate::serve::registry::SYNTHETIC_TASK_BYTES;

/// Canonical name of synthetic gateway task `i` (`task0`, `task1`, …).
pub fn task_name(i: usize) -> String {
    format!("task{i}")
}

/// Canonical side-network seed of synthetic gateway task `i`.  Every shard
/// registers with this, and every parity reference (tests, `bench-gateway`
/// probes, cost-model pins) must derive the *same* seed — one formula, one
/// place.
pub fn task_seed(gateway_seed: u64, i: usize) -> u64 {
    gateway_seed ^ ((i as u64 + 1) << 32)
}

/// Gateway shape + per-shard server tuning.
#[derive(Clone, Copy, Debug)]
pub struct GatewayConfig {
    /// worker shards, each with a private backbone replica
    pub shards: usize,
    /// bounded per-shard inbox capacity (requests buffered before
    /// [`SubmitError::Backpressure`])
    pub queue_cap: usize,
    /// per-shard server tuning (cache budget, prefix block, batch cap)
    pub serve: ServeConfig,
    pub preset: EnginePreset,
    pub backbone: BackboneKind,
    /// engine seed — identical across shards, so replicas are bit-identical
    pub seed: u64,
    pub seq: usize,
    /// synthetic tasks registered on every shard (`task0`…)
    pub tasks: usize,
    /// kernel worker threads per shard engine
    pub threads_per_shard: usize,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig {
            shards: 2,
            queue_cap: 64,
            serve: ServeConfig::default(),
            preset: EnginePreset::Small,
            backbone: BackboneKind::F32,
            seed: 0,
            seq: 64,
            tasks: 2,
            threads_per_shard: 1,
        }
    }
}

/// The running gateway: shard fleet + router + event collector.
pub struct Gateway {
    cfg: GatewayConfig,
    router: Router,
    shards: Vec<ShardHandle>,
    events: Receiver<ShardEvent>,
    tasks: Vec<String>,
    next_id: u64,
    in_flight: usize,
    /// requests accepted into shard inboxes
    pub submitted: u64,
    /// submits refused with [`SubmitError::Backpressure`]
    pub rejected: u64,
    /// requests dropped by failing shard micro-batches
    pub dropped: u64,
}

impl Gateway {
    /// Spawn the shard fleet and return the ready gateway.
    pub fn launch(cfg: &GatewayConfig) -> Result<Gateway> {
        if cfg.shards == 0 || cfg.tasks == 0 {
            bail!("gateway needs at least one shard and one task");
        }
        let (ev_tx, ev_rx): (Sender<ShardEvent>, Receiver<ShardEvent>) =
            std::sync::mpsc::channel();
        let shards: Vec<ShardHandle> =
            (0..cfg.shards).map(|i| ShardHandle::spawn(i, cfg, ev_tx.clone())).collect();
        Ok(Gateway {
            cfg: *cfg,
            router: Router::new(cfg.shards, cfg.serve.prefix_block),
            shards,
            events: ev_rx,
            tasks: (0..cfg.tasks).map(task_name).collect(),
            next_id: 0,
            in_flight: 0,
            submitted: 0,
            rejected: 0,
            dropped: 0,
        })
    }

    pub fn config(&self) -> &GatewayConfig {
        &self.cfg
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Requests accepted but not yet answered.
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// Non-blocking submit: validate, route by prompt head, `try_send`
    /// into the shard's bounded inbox.  Returns the gateway request id,
    /// or [`SubmitError::Backpressure`] when the routed inbox is full —
    /// the caller should collect responses and retry (bounded queues
    /// reject; they never deadlock).
    pub fn submit(&mut self, task: &str, tokens: &[i32]) -> Result<u64, SubmitError> {
        if !self.tasks.iter().any(|t| t == task) {
            return Err(SubmitError::Invalid(format!(
                "unknown task '{task}' (registered: {:?})",
                self.tasks
            )));
        }
        if tokens.len() > self.cfg.seq {
            return Err(SubmitError::Invalid(format!(
                "prompt of {} tokens exceeds the serving sequence length {}",
                tokens.len(),
                self.cfg.seq
            )));
        }
        let shard = self.router.route(tokens);
        let id = self.next_id;
        let req = GatewayRequest { id, task: task.to_string(), tokens: tokens.to_vec() };
        match self.shards[shard].try_submit(req) {
            Ok(()) => {
                self.next_id += 1;
                self.in_flight += 1;
                self.submitted += 1;
                Ok(id)
            }
            Err(e) => {
                if matches!(e, SubmitError::Backpressure { .. }) {
                    self.rejected += 1;
                }
                Err(e)
            }
        }
    }

    fn absorb(&mut self, ev: ShardEvent, out: &mut Vec<GatewayResponse>) {
        match ev {
            ShardEvent::Done(gr) => {
                self.in_flight = self.in_flight.saturating_sub(1);
                out.push(gr);
            }
            ShardEvent::Dropped { n, .. } => {
                self.in_flight = self.in_flight.saturating_sub(n);
                self.dropped += n as u64;
            }
            ShardEvent::Rejected { shard, id, err } => {
                self.in_flight = self.in_flight.saturating_sub(1);
                self.dropped += 1;
                eprintln!("gateway: shard {shard} rejected request {id}: {err}");
            }
        }
    }

    /// Drain whatever responses have already completed (non-blocking).
    pub fn try_collect(&mut self) -> Vec<GatewayResponse> {
        let mut out = Vec::new();
        loop {
            match self.events.try_recv() {
                Ok(ev) => self.absorb(ev, &mut out),
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
        out
    }

    /// Barrier: make every shard drain its inbox + server, then collect
    /// until nothing submitted before this call is outstanding.  Returns
    /// the responses gathered along the way.
    pub fn flush(&mut self) -> Result<Vec<GatewayResponse>> {
        let (ack_tx, ack_rx) = std::sync::mpsc::channel();
        let mut expected = 0usize;
        for s in &self.shards {
            if s.send(ShardMsg::Flush(ack_tx.clone())) {
                expected += 1;
            }
        }
        drop(ack_tx);
        for _ in 0..expected {
            if ack_rx.recv().is_err() {
                bail!("a gateway shard died mid-flush");
            }
        }
        // inbox order guarantees every pre-flush outcome is now in the
        // event channel; drain until the in-flight ledger clears
        let mut out = Vec::new();
        while self.in_flight > 0 {
            match self.events.recv() {
                Ok(ev) => self.absorb(ev, &mut out),
                Err(_) => bail!("all shards disconnected with {} request(s) in flight", self.in_flight),
            }
        }
        Ok(out)
    }

    /// Snapshot every shard and merge into the fleet-wide report.
    pub fn report(&self) -> Result<GatewayReport> {
        let (tx, rx) = std::sync::mpsc::channel();
        let mut expected = 0usize;
        for s in &self.shards {
            if s.send(ShardMsg::Report(tx.clone())) {
                expected += 1;
            }
        }
        drop(tx);
        let mut reports = Vec::with_capacity(expected);
        for _ in 0..expected {
            match rx.recv() {
                Ok(r) => reports.push(r),
                Err(_) => bail!("a gateway shard died mid-report"),
            }
        }
        if reports.is_empty() {
            bail!("no live shards to report");
        }
        Ok(aggregate(reports))
    }

    /// Flush outstanding work, take the final merged report, then stop and
    /// join every shard thread.  Responses the caller had not collected
    /// yet are returned rather than dropped.  (The process-wide kernel
    /// pool is left alone — other servers may share it; CLI teardown calls
    /// [`crate::kernels::shutdown_pool`] explicitly.)
    pub fn shutdown(mut self) -> Result<(GatewayReport, Vec<GatewayResponse>)> {
        let leftover = self.flush()?;
        let report = self.report()?;
        for s in &mut self.shards {
            s.stop();
        }
        Ok((report, leftover))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::Server;
    use std::collections::HashMap;

    fn cfg(shards: usize, prefix_block: usize) -> GatewayConfig {
        GatewayConfig {
            shards,
            queue_cap: 32,
            seq: 16,
            seed: 11,
            tasks: 2,
            threads_per_shard: 1,
            preset: EnginePreset::Small,
            backbone: BackboneKind::F32,
            serve: ServeConfig {
                cache_bytes: 8 << 20,
                registry_bytes: 1 << 20,
                max_batch: 4,
                prefix_block,
            },
        }
    }

    /// Reference logits: a plain single-threaded uncached server.
    fn reference(cfgv: &GatewayConfig, reqs: &[(String, Vec<i32>)]) -> Vec<Vec<f32>> {
        let mut engine = cfgv.preset.build_backbone(cfgv.seed, cfgv.seq, cfgv.backbone);
        engine.set_threads(1);
        let mut server = Server::new(
            engine,
            ServeConfig { cache_bytes: 0, registry_bytes: 1 << 20, max_batch: 1, prefix_block: 0 },
        );
        for i in 0..cfgv.tasks {
            server
                .registry
                .register_synthetic(&task_name(i), task_seed(cfgv.seed, i), 1 << 10)
                .unwrap();
        }
        let mut out = Vec::new();
        for (task, tokens) in reqs {
            server.submit(task, tokens).unwrap();
            let mut r = server.drain().unwrap();
            out.push(r.remove(0).logits);
        }
        out
    }

    #[test]
    fn gateway_matches_unsharded_reference_and_merges_stats() {
        let c = cfg(2, 4);
        let reqs: Vec<(String, Vec<i32>)> = vec![
            ("task0".into(), vec![1, 2, 3, 4, 9, 9]),
            ("task1".into(), vec![1, 2, 3, 4, 9, 9]),
            ("task0".into(), vec![5, 6]),
            ("task0".into(), vec![1, 2, 3, 4, 7, 7, 7]), // prefix family
            ("task1".into(), vec![8]),
        ];
        let want = reference(&c, &reqs);
        let mut gw = Gateway::launch(&c).unwrap();
        let mut ids = Vec::new();
        for (task, tokens) in &reqs {
            ids.push(gw.submit(task, tokens).unwrap());
        }
        let mut got: HashMap<u64, Vec<f32>> = HashMap::new();
        for gr in gw.flush().unwrap() {
            got.insert(gr.resp.id, gr.resp.logits);
        }
        assert_eq!(got.len(), reqs.len());
        assert_eq!(gw.in_flight(), 0);
        for (id, want_logits) in ids.iter().zip(&want) {
            assert_eq!(&got[id], want_logits, "sharded logits must match the reference");
        }
        let (report, leftover) = gw.shutdown().unwrap();
        assert!(leftover.is_empty());
        assert_eq!(report.merged.requests as usize, reqs.len());
        assert_eq!(report.shards.len(), 2);
        assert_eq!(report.backbone_resident_bytes, 2 * report.shards[0].backbone_resident_bytes);
    }

    #[test]
    fn gateway_validates_before_routing() {
        let mut gw = Gateway::launch(&cfg(2, 4)).unwrap();
        assert!(matches!(gw.submit("nope", &[1]), Err(SubmitError::Invalid(_))));
        assert!(matches!(gw.submit("task0", &vec![1; 17]), Err(SubmitError::Invalid(_))));
        assert_eq!(gw.submitted, 0);
        let (report, _) = gw.shutdown().unwrap();
        assert_eq!(report.merged.requests, 0);
    }

    #[test]
    fn launch_rejects_empty_fleet() {
        assert!(Gateway::launch(&cfg(0, 4)).is_err());
        let mut c = cfg(1, 4);
        c.tasks = 0;
        assert!(Gateway::launch(&c).is_err());
    }

    #[test]
    fn repeated_flush_and_interleaved_submits() {
        let mut gw = Gateway::launch(&cfg(2, 4)).unwrap();
        for wave in 0..3 {
            for i in 0..6 {
                gw.submit(&task_name(i % 2), &[wave as i32 + 1, i as i32 + 1]).unwrap();
            }
            let got = gw.flush().unwrap();
            assert_eq!(got.len(), 6, "wave {wave}");
        }
        let report = gw.report().unwrap();
        assert_eq!(report.merged.requests, 18);
    }
}
