//! Asynchronous sharded serving gateway over the QST multi-task server.
//!
//! # Design
//!
//! QST's economics make replication the natural way to scale serving: the
//! frozen backbone is shared by every task and, packed as W4 (PR 3), a
//! replica costs ~7.6× less than f32 — so N shards each hold a private
//! backbone replica + hidden-state cache + side-network registry, and
//! the gateway's job is transport, routing, and aggregation:
//!
//! ```text
//!   submit(task, tokens) ──▶ [router]  hash(first prefix-block tokens)
//!         │ SubmitError::Backpressure when the shard is saturated
//!         ▼
//!   [Transport]  ─ InProc: bounded mpsc inboxes to shard threads
//!              └─ Socket: framed unix/tcp streams to shard processes
//!   [shard 0] [shard 1] … [shard N-1]
//!      each: thread/process-owned Server<SyntheticEngine>
//!            queue → prefix-aware cache → backbone/resume → side nets
//!         │ ShardEvent::Done / Dropped / Rejected / FlushAck / Report / Telemetry / Heartbeat / DeployAck
//!         ▼
//!   [event stream] ──▶ try_collect() / flush() ──▶ responses
//!   [aggregator]   ──▶ report(): merged stats + summed cache counters
//! ```
//!
//! Since PR 5 the message surface is the versioned wire protocol in
//! [`crate::proto`], and the gateway is generic over its
//! [`Transport`]: `Gateway::launch` runs shard threads in-process
//! (PR 4's design, behavior-preserving), `Gateway::connect` drives a
//! fleet of `qst shard-worker` processes over sockets.  Both transports
//! are pinned bit-identical to each other and to an unsharded `Server`
//! by `tests/gateway.rs` and the `bench-gateway` parity gates.
//!
//! * [`transport`] — the in-process [`Transport`] (bounded mpsc,
//!   [`SubmitError`] backpressure semantics) and the `qst gateway`
//!   line-protocol loop.
//! * [`worker`] — the socket shard worker (`qst shard-worker`).
//! * [`router`] — prefix-locality routing (prompts sharing a
//!   `prefix_block`-aligned head land on one shard, where the prefix
//!   cache can resume them) + per-shard report aggregation.
//! * [`shard`] — the shard serving core, shared verbatim by shard
//!   threads and shard processes.
//! * [`bench`] — `qst bench-gateway`: shard-count × transport scaling
//!   curves, prefix-hit rates, p50/p95 under open-loop load
//!   (`BENCH_gateway.json`).

pub mod bench;
pub mod bench_registry;
pub mod router;
pub mod shard;
pub mod transport;
pub mod worker;

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::obs::health::{FleetHealth, HealthSnapshot, DEFAULT_HEALTH_MULT};
use crate::obs::series::SERIES_DEFAULT_CAP;
use crate::obs::{self, trace::TraceSpan, SpanKind};
use crate::serve::{BackboneKind, EnginePreset, ServeConfig};

pub use router::{aggregate, GatewayReport, Router};
pub use shard::ShardHandle;
pub use transport::{line_loop, InProc};
pub use crate::proto::{
    GatewayResponse, Request, ShardEvent, ShardMsg, ShardReport, ShardSpec, SubmitError, Transport,
};

pub use crate::serve::registry::SYNTHETIC_TASK_BYTES;

/// Canonical name of synthetic gateway task `i` (`task0`, `task1`, …).
pub fn task_name(i: usize) -> String {
    format!("task{i}")
}

/// Canonical side-network seed of synthetic gateway task `i`.  Every shard
/// registers with this, and every parity reference (tests, `bench-gateway`
/// probes, cost-model pins) must derive the *same* seed — one formula, one
/// place.
pub fn task_seed(gateway_seed: u64, i: usize) -> u64 {
    gateway_seed ^ ((i as u64 + 1) << 32)
}

/// Gateway shape + per-shard server tuning.
#[derive(Clone, Copy, Debug)]
pub struct GatewayConfig {
    /// worker shards, each with a private backbone replica
    pub shards: usize,
    /// per-shard backpressure bound: inbox capacity (in-proc) or
    /// outstanding-request credit window (socket) before
    /// [`SubmitError::Backpressure`]
    pub queue_cap: usize,
    /// per-shard server tuning (cache budget, prefix block, batch cap)
    pub serve: ServeConfig,
    pub preset: EnginePreset,
    pub backbone: BackboneKind,
    /// engine seed — identical across shards, so replicas are bit-identical
    pub seed: u64,
    pub seq: usize,
    /// synthetic tasks registered on every shard (`task0`…)
    pub tasks: usize,
    /// kernel worker threads per shard engine
    pub threads_per_shard: usize,
    /// enable the span recorder fleet-wide (`--trace-out`): locally and,
    /// via the spec's trace flag, in every socket worker
    pub trace: bool,
    /// worker heartbeat cadence in ms (0 = disarmed): every shard emits
    /// a periodic `Heartbeat` event the gateway's [`FleetHealth`] reads
    pub heartbeat_ms: u64,
    /// liveness timeout multiple: a shard is `Suspect` after
    /// `heartbeat_ms × health_mult` of silence, `Dead` after twice that
    pub health_mult: u64,
    /// gauge flight-recorder cadence in ms (0 = disarmed)
    pub series_ms: u64,
    /// flight-recorder ring capacity (points per shard)
    pub series_cap: usize,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig {
            shards: 2,
            queue_cap: 64,
            serve: ServeConfig::default(),
            preset: EnginePreset::Small,
            backbone: BackboneKind::F32,
            seed: 0,
            seq: 64,
            tasks: 2,
            threads_per_shard: 1,
            trace: false,
            heartbeat_ms: 0,
            health_mult: DEFAULT_HEALTH_MULT,
            series_ms: 0,
            series_cap: SERIES_DEFAULT_CAP,
        }
    }
}

impl GatewayConfig {
    /// The per-shard spec this fleet serves — what in-proc shards build
    /// from directly and the socket transport ships in its `Configure`
    /// frame, so both transports construct identical replicas.
    pub fn shard_spec(&self) -> ShardSpec {
        ShardSpec {
            preset: self.preset,
            backbone: self.backbone,
            seed: self.seed,
            seq: self.seq,
            tasks: self.tasks,
            threads: self.threads_per_shard,
            serve: self.serve,
            trace: self.trace,
            heartbeat_ms: self.heartbeat_ms,
            series_ms: self.series_ms,
            series_cap: self.series_cap,
        }
    }
}

/// The running gateway: router + aggregation over a pluggable transport.
pub struct Gateway {
    cfg: GatewayConfig,
    router: Router,
    transport: Box<dyn Transport>,
    tasks: Vec<String>,
    next_id: u64,
    in_flight: usize,
    /// data responses absorbed while awaiting control events (reports),
    /// handed out on the next try_collect/flush
    stash: Vec<GatewayResponse>,
    /// shard reports absorbed on the data path (an earlier `report()`
    /// over-counted its live shards, or a worker volunteered one at
    /// shutdown); the next `report()` consumes them, latest per shard
    pending_reports: Vec<ShardReport>,
    /// spans shipped by traced socket workers, pid-tagged `shard + 1`
    /// (in-proc shards record into this process's rings directly)
    remote_spans: Vec<TraceSpan>,
    /// worker-side spans lost to ring overwrites (from `Telemetry` frames)
    pub telemetry_dropped: u64,
    /// heartbeat liveness registry, fed by `Heartbeat` events on the
    /// data path; read by the `HEALTH` command and the `STATS` gauges
    health: FleetHealth,
    /// requests accepted into shard inboxes
    pub submitted: u64,
    /// submits refused with [`SubmitError::Backpressure`]
    pub rejected: u64,
    /// requests dropped by failing shard micro-batches
    pub dropped: u64,
}

impl Gateway {
    /// Spawn an in-process shard fleet and return the ready gateway.
    pub fn launch(cfg: &GatewayConfig) -> Result<Gateway> {
        if cfg.shards == 0 || cfg.tasks == 0 {
            bail!("gateway needs at least one shard and one task");
        }
        Self::with_transport(cfg, Box::new(InProc::spawn(cfg)))
    }

    /// Drive a fleet of `qst shard-worker` processes: shard `i` is the
    /// worker at `addrs[i]` (`unix:<path>` or `<host>:<port>`).  The
    /// shard count comes from the address list; each worker receives this
    /// gateway's [`ShardSpec`] on connect, so one config drives the whole
    /// fleet.
    pub fn connect(cfg: &GatewayConfig, addrs: &[String]) -> Result<Gateway> {
        if addrs.is_empty() {
            bail!("gateway --connect needs at least one worker address");
        }
        let mut cfg = *cfg;
        cfg.shards = addrs.len();
        let transport =
            crate::proto::SocketTransport::connect(addrs, &cfg.shard_spec(), cfg.queue_cap)?;
        Self::with_transport(&cfg, Box::new(transport))
    }

    /// Assemble a gateway over an already-running transport.
    pub fn with_transport(cfg: &GatewayConfig, transport: Box<dyn Transport>) -> Result<Gateway> {
        if transport.shards() == 0 || cfg.tasks == 0 {
            bail!("gateway needs at least one shard and one task");
        }
        let shards = transport.shards();
        Ok(Gateway {
            cfg: *cfg,
            router: Router::new(shards, cfg.serve.prefix_block),
            transport,
            tasks: (0..cfg.tasks).map(task_name).collect(),
            next_id: 0,
            in_flight: 0,
            stash: Vec::new(),
            pending_reports: Vec::new(),
            remote_spans: Vec::new(),
            telemetry_dropped: 0,
            health: FleetHealth::new(shards, cfg.heartbeat_ms, cfg.health_mult),
            submitted: 0,
            rejected: 0,
            dropped: 0,
        })
    }

    pub fn config(&self) -> &GatewayConfig {
        &self.cfg
    }

    pub fn shard_count(&self) -> usize {
        self.transport.shards()
    }

    /// Requests accepted but not yet answered.
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// The fleet liveness registry (heartbeat ages and states).  Call
    /// [`Gateway::try_collect`] first to absorb any heartbeats already
    /// queued on the event stream.
    pub fn health(&self) -> &FleetHealth {
        &self.health
    }

    /// Spans shipped by traced socket workers since the last take,
    /// pid-tagged `shard + 1`.  The trace writer combines these with the
    /// local `obs::drain()` (pid 0) when serializing `--trace-out`.
    pub fn take_remote_spans(&mut self) -> Vec<TraceSpan> {
        std::mem::take(&mut self.remote_spans)
    }

    /// Non-blocking submit: validate, route by prompt head, hand to the
    /// transport.  Returns the gateway request id, or
    /// [`SubmitError::Backpressure`] when the routed shard is saturated —
    /// the caller should collect responses and retry (bounded queues
    /// reject; they never deadlock).
    pub fn submit(&mut self, task: &str, tokens: &[i32]) -> Result<u64, SubmitError> {
        let t_admit = obs::start();
        if !self.tasks.iter().any(|t| t == task) {
            return Err(SubmitError::Invalid(format!(
                "unknown task '{task}' (registered: {:?})",
                self.tasks
            )));
        }
        if tokens.len() > self.cfg.seq {
            return Err(SubmitError::Invalid(format!(
                "prompt of {} tokens exceeds the serving sequence length {}",
                tokens.len(),
                self.cfg.seq
            )));
        }
        obs::end(SpanKind::Admit, t_admit, self.next_id);
        let t_route = obs::start();
        let shard = self.router.route(tokens);
        let id = self.next_id;
        obs::end(SpanKind::Route, t_route, id);
        let req = Request { id, task: task.to_string(), tokens: tokens.to_vec() };
        match self.transport.submit(shard, req) {
            Ok(()) => {
                self.next_id += 1;
                self.in_flight += 1;
                self.submitted += 1;
                Ok(id)
            }
            Err(e) => {
                if matches!(e, SubmitError::Backpressure { .. }) {
                    self.rejected += 1;
                }
                Err(e)
            }
        }
    }

    fn absorb(&mut self, ev: ShardEvent, out: &mut Vec<GatewayResponse>) {
        match ev {
            ShardEvent::Done(gr) => {
                self.in_flight = self.in_flight.saturating_sub(1);
                out.push(gr);
            }
            ShardEvent::Dropped { n, .. } => {
                self.in_flight = self.in_flight.saturating_sub(n);
                self.dropped += n as u64;
            }
            ShardEvent::Rejected { shard, id, err } => {
                self.in_flight = self.in_flight.saturating_sub(1);
                self.dropped += 1;
                eprintln!("gateway: shard {shard} rejected request {id}: {err}");
            }
            // a stray ack means an earlier flush over-counted its live
            // shards; the barrier it belonged to already gave up on it
            ShardEvent::FlushAck { .. } => {}
            // a report racing the data path carries real counters — stash
            // it for the next `report()` instead of dropping the shard's
            // telemetry on the floor
            ShardEvent::Report(r) => self.pending_reports.push(r),
            ShardEvent::Telemetry(t) => {
                self.telemetry_dropped += t.dropped;
                let pid = t.shard as u32 + 1;
                self.remote_spans.extend(t.spans.into_iter().map(|span| TraceSpan { pid, span }));
            }
            ShardEvent::Heartbeat(hb) => self.health.beat(
                hb.shard,
                HealthSnapshot {
                    queue_depth: hb.queue_depth,
                    inflight_slots: hb.inflight_slots,
                    spans_dropped: hb.spans_dropped,
                    cache_bytes: hb.cache_bytes,
                },
            ),
            // a stray ack means an earlier deploy barrier gave up on this
            // shard (or a different task's ack raced past); the shard did
            // register the task, so the ack is safe to drop
            ShardEvent::DeployAck { .. } => {}
        }
    }

    /// Drain whatever responses have already completed (non-blocking).
    pub fn try_collect(&mut self) -> Vec<GatewayResponse> {
        let mut out = std::mem::take(&mut self.stash);
        while let Some(ev) = self.transport.try_recv() {
            self.absorb(ev, &mut out);
        }
        out
    }

    /// Barrier: make every shard drain everything submitted before this
    /// call, and collect until nothing is outstanding.  Works over any
    /// transport because events are per-shard FIFO — a shard's `FlushAck`
    /// always follows the outcomes of its pre-flush work.  Returns the
    /// responses gathered along the way; if the barrier fails (a shard
    /// died), responses already completed stay stashed for the next
    /// `try_collect`/`flush` rather than being dropped with the error.
    pub fn flush(&mut self) -> Result<Vec<GatewayResponse>> {
        let expected = self.transport.start_flush();
        let mut out = std::mem::take(&mut self.stash);
        match self.flush_inner(expected, &mut out) {
            Ok(()) => Ok(out),
            Err(e) => {
                self.stash.append(&mut out);
                Err(e)
            }
        }
    }

    fn flush_inner(&mut self, expected: usize, out: &mut Vec<GatewayResponse>) -> Result<()> {
        if expected == 0 {
            if self.in_flight > 0 {
                bail!("no live shards with {} request(s) in flight", self.in_flight);
            }
            return Ok(());
        }
        let mut acks = 0usize;
        while acks < expected {
            match self.transport.recv() {
                Ok(ShardEvent::FlushAck { .. }) => acks += 1,
                Ok(ev) => self.absorb(ev, out),
                Err(e) => bail!("a gateway shard died mid-flush: {e:#}"),
            }
        }
        // FIFO guarantees every pre-flush outcome has been absorbed by
        // now; anything left in flight belongs to a dead shard
        while self.in_flight > 0 {
            match self.transport.recv() {
                Ok(ev) => self.absorb(ev, out),
                Err(_) => {
                    bail!("all shards disconnected with {} request(s) in flight", self.in_flight)
                }
            }
        }
        Ok(())
    }

    /// Push a task artifact to every live shard and hot-register it
    /// fleet-wide, without restarting anything.  Blocks until every
    /// reached shard acks its `Deploy`; the acks must all be error-free
    /// and agree on the artifact's content digest, which is returned.
    /// On success the task joins the gateway's advertised set, so
    /// `submit` accepts it immediately.  Data responses that complete
    /// while acks are in transit are stashed for the next
    /// `try_collect`/`flush` — never dropped, even on failure.
    pub fn deploy(&mut self, task: &str, artifact: &[u8]) -> Result<u64> {
        let expected = self.transport.start_deploy(task, artifact);
        if expected == 0 {
            bail!("no live shards to deploy '{task}' to");
        }
        let mut stashed = Vec::new();
        let res = self.deploy_inner(task, expected, &mut stashed);
        self.stash.append(&mut stashed);
        let digest = res?;
        if !self.tasks.iter().any(|t| t == task) {
            self.tasks.push(task.to_string());
        }
        Ok(digest)
    }

    fn deploy_inner(
        &mut self,
        task: &str,
        expected: usize,
        stashed: &mut Vec<GatewayResponse>,
    ) -> Result<u64> {
        let mut digests = Vec::with_capacity(expected);
        while digests.len() < expected {
            match self.transport.recv() {
                Ok(ShardEvent::DeployAck { shard, task: t, digest, err }) if t == task => {
                    if !err.is_empty() {
                        bail!("shard {shard} failed to deploy '{task}': {err}");
                    }
                    digests.push(digest);
                }
                Ok(ev) => self.absorb(ev, stashed),
                Err(e) => bail!("a gateway shard died mid-deploy: {e:#}"),
            }
        }
        let first = digests[0];
        if digests.iter().any(|&d| d != first) {
            bail!("deploy of '{task}' diverged: shards report different artifact digests");
        }
        Ok(first)
    }

    /// Snapshot every shard and merge into the fleet-wide report.  Data
    /// responses that complete while reports are in transit are stashed
    /// for the next `try_collect`/`flush` — never dropped, even when the
    /// report itself fails.  Reports that arrived early on the data path
    /// (stashed by `absorb`) count too, superseded per shard by a fresh
    /// one when both exist.
    pub fn report(&mut self) -> Result<GatewayReport> {
        let expected = self.transport.start_report();
        if expected == 0 && self.pending_reports.is_empty() {
            bail!("no live shards to report");
        }
        let mut fresh = Vec::with_capacity(expected);
        let mut stashed = Vec::new();
        let res = self.report_inner(expected, &mut fresh, &mut stashed);
        self.stash.append(&mut stashed);
        res?;
        let mut by_shard: HashMap<usize, ShardReport> = HashMap::new();
        for r in self.pending_reports.drain(..).chain(fresh) {
            by_shard.insert(r.shard, r); // later (fresher) wins
        }
        Ok(aggregate(by_shard.into_values().collect()))
    }

    fn report_inner(
        &mut self,
        expected: usize,
        reports: &mut Vec<ShardReport>,
        stashed: &mut Vec<GatewayResponse>,
    ) -> Result<()> {
        while reports.len() < expected {
            match self.transport.recv() {
                Ok(ShardEvent::Report(r)) => reports.push(r),
                Ok(ev) => self.absorb(ev, stashed),
                Err(e) => bail!("a gateway shard died mid-report: {e:#}"),
            }
        }
        Ok(())
    }

    /// Flush outstanding work, take the final merged report, then stop
    /// the transport (joining shard threads / closing worker
    /// connections).  Responses the caller had not collected yet are
    /// returned rather than dropped.  (The process-wide kernel pool is
    /// left alone — other servers may share it; CLI teardown calls
    /// [`crate::kernels::shutdown_pool`] explicitly.)
    pub fn shutdown(mut self) -> Result<(GatewayReport, Vec<GatewayResponse>)> {
        let mut leftover = self.flush()?;
        let report = self.report()?;
        leftover.append(&mut self.stash);
        self.transport.shutdown()?;
        Ok((report, leftover))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::Server;
    use std::collections::HashMap;

    fn cfg(shards: usize, prefix_block: usize) -> GatewayConfig {
        GatewayConfig {
            shards,
            queue_cap: 32,
            seq: 16,
            seed: 11,
            tasks: 2,
            threads_per_shard: 1,
            preset: EnginePreset::Small,
            backbone: BackboneKind::F32,
            serve: ServeConfig {
                cache_bytes: 8 << 20,
                registry_bytes: 1 << 20,
                max_batch: 4,
                prefix_block,
            },
            trace: false,
            heartbeat_ms: 0,
            health_mult: DEFAULT_HEALTH_MULT,
            series_ms: 0,
            series_cap: SERIES_DEFAULT_CAP,
        }
    }

    /// Reference logits: a plain single-threaded uncached server.
    fn reference(cfgv: &GatewayConfig, reqs: &[(String, Vec<i32>)]) -> Vec<Vec<f32>> {
        let mut engine = cfgv.preset.build_backbone(cfgv.seed, cfgv.seq, cfgv.backbone);
        engine.set_threads(1);
        let mut server = Server::new(
            engine,
            ServeConfig { cache_bytes: 0, registry_bytes: 1 << 20, max_batch: 1, prefix_block: 0 },
        );
        for i in 0..cfgv.tasks {
            server
                .registry
                .register_synthetic(&task_name(i), task_seed(cfgv.seed, i), 1 << 10)
                .unwrap();
        }
        let mut out = Vec::new();
        for (task, tokens) in reqs {
            server.submit(task, tokens).unwrap();
            let mut r = server.drain().unwrap();
            out.push(r.remove(0).logits);
        }
        out
    }

    #[test]
    fn gateway_matches_unsharded_reference_and_merges_stats() {
        let c = cfg(2, 4);
        let reqs: Vec<(String, Vec<i32>)> = vec![
            ("task0".into(), vec![1, 2, 3, 4, 9, 9]),
            ("task1".into(), vec![1, 2, 3, 4, 9, 9]),
            ("task0".into(), vec![5, 6]),
            ("task0".into(), vec![1, 2, 3, 4, 7, 7, 7]), // prefix family
            ("task1".into(), vec![8]),
        ];
        let want = reference(&c, &reqs);
        let mut gw = Gateway::launch(&c).unwrap();
        let mut ids = Vec::new();
        for (task, tokens) in &reqs {
            ids.push(gw.submit(task, tokens).unwrap());
        }
        let mut got: HashMap<u64, Vec<f32>> = HashMap::new();
        for gr in gw.flush().unwrap() {
            got.insert(gr.resp.id, gr.resp.logits);
        }
        assert_eq!(got.len(), reqs.len());
        assert_eq!(gw.in_flight(), 0);
        for (id, want_logits) in ids.iter().zip(&want) {
            assert_eq!(&got[id], want_logits, "sharded logits must match the reference");
        }
        let (report, leftover) = gw.shutdown().unwrap();
        assert!(leftover.is_empty());
        assert_eq!(report.merged.requests as usize, reqs.len());
        assert_eq!(report.shards.len(), 2);
        assert_eq!(report.backbone_resident_bytes, 2 * report.shards[0].backbone_resident_bytes);
    }

    #[test]
    fn gateway_validates_before_routing() {
        let mut gw = Gateway::launch(&cfg(2, 4)).unwrap();
        assert!(matches!(gw.submit("nope", &[1]), Err(SubmitError::Invalid(_))));
        assert!(matches!(gw.submit("task0", &vec![1; 17]), Err(SubmitError::Invalid(_))));
        assert_eq!(gw.submitted, 0);
        let (report, _) = gw.shutdown().unwrap();
        assert_eq!(report.merged.requests, 0);
    }

    #[test]
    fn launch_rejects_empty_fleet() {
        assert!(Gateway::launch(&cfg(0, 4)).is_err());
        let mut c = cfg(1, 4);
        c.tasks = 0;
        assert!(Gateway::launch(&c).is_err());
        assert!(Gateway::connect(&cfg(1, 4), &[]).is_err());
    }

    #[test]
    fn repeated_flush_and_interleaved_submits() {
        let mut gw = Gateway::launch(&cfg(2, 4)).unwrap();
        for wave in 0..3 {
            for i in 0..6 {
                gw.submit(&task_name(i % 2), &[wave as i32 + 1, i as i32 + 1]).unwrap();
            }
            let got = gw.flush().unwrap();
            assert_eq!(got.len(), 6, "wave {wave}");
        }
        let report = gw.report().unwrap();
        assert_eq!(report.merged.requests, 18);
    }

    /// A transport whose event stream and liveness answers are scripted
    /// from the test — the only way to pin down *exact* interleavings of
    /// control and data events (real shards race).
    struct Scripted {
        queue: std::sync::Arc<std::sync::Mutex<std::collections::VecDeque<ShardEvent>>>,
        flush_live: usize,
        report_live: usize,
    }

    impl Transport for Scripted {
        fn shards(&self) -> usize {
            1
        }
        fn submit(&mut self, _shard: usize, _req: Request) -> Result<(), SubmitError> {
            Ok(())
        }
        fn try_recv(&mut self) -> Option<ShardEvent> {
            self.queue.lock().unwrap().pop_front()
        }
        fn recv(&mut self) -> Result<ShardEvent> {
            self.try_recv().ok_or_else(|| anyhow::anyhow!("script exhausted"))
        }
        fn start_flush(&mut self) -> usize {
            self.flush_live
        }
        fn start_report(&mut self) -> usize {
            self.report_live
        }
        fn start_deploy(&mut self, _task: &str, _artifact: &[u8]) -> usize {
            0
        }
        fn shutdown(&mut self) -> Result<()> {
            Ok(())
        }
    }

    fn report_with_requests(n: u64) -> ShardReport {
        let mut r = ShardReport::default();
        r.stats.requests = n;
        r
    }

    #[test]
    fn report_racing_the_data_path_is_stashed_and_survives_shard_death() {
        // interleaving under test: a shard volunteers its Report *before*
        // the Done and the FlushAck of the same drain, then dies.  The
        // old absorb() dropped that report on the floor; now it must feed
        // the next report() even though start_report() reaches 0 shards.
        let queue = std::sync::Arc::new(std::sync::Mutex::new(
            std::collections::VecDeque::new(),
        ));
        let transport = Scripted { queue: queue.clone(), flush_live: 1, report_live: 0 };
        let mut gw = Gateway::with_transport(&cfg(1, 4), Box::new(transport)).unwrap();
        let id = gw.submit("task0", &[1, 2]).unwrap();
        queue.lock().unwrap().extend([
            ShardEvent::Report(report_with_requests(1)),
            ShardEvent::Done(GatewayResponse {
                shard: 0,
                resp: crate::serve::Response {
                    id,
                    task: "task0".into(),
                    logits: vec![0.5],
                    cache_hit: false,
                },
            }),
            ShardEvent::FlushAck { shard: 0 },
        ]);
        let got = gw.flush().unwrap();
        assert_eq!(got.len(), 1, "the Done interleaved with the Report must come through");
        // shard is now "dead": start_report reaches nobody, yet the
        // stashed report still answers
        let report = gw.report().unwrap();
        assert_eq!(report.merged.requests, 1);
        assert_eq!(report.shards.len(), 1);
    }

    #[test]
    fn fresh_report_supersedes_a_stashed_one_per_shard() {
        let queue = std::sync::Arc::new(std::sync::Mutex::new(
            std::collections::VecDeque::new(),
        ));
        let transport = Scripted { queue: queue.clone(), flush_live: 1, report_live: 1 };
        let mut gw = Gateway::with_transport(&cfg(1, 4), Box::new(transport)).unwrap();
        // a stale report arrives on the data path during a flush…
        queue
            .lock()
            .unwrap()
            .extend([ShardEvent::Report(report_with_requests(1)), ShardEvent::FlushAck { shard: 0 }]);
        assert!(gw.flush().unwrap().is_empty());
        // …then report() asks and gets a fresher one from the same shard
        queue.lock().unwrap().push_back(ShardEvent::Report(report_with_requests(5)));
        let report = gw.report().unwrap();
        assert_eq!(report.shards.len(), 1, "one report per shard, latest wins");
        assert_eq!(report.merged.requests, 5);
    }

    #[test]
    fn heartbeats_feed_the_liveness_registry() {
        let mut c = cfg(2, 4);
        c.heartbeat_ms = 10;
        let mut gw = Gateway::launch(&c).unwrap();
        assert!(gw.health().armed());
        assert_eq!(gw.health().shard_count(), 2);
        // idle shards beat on their recv_timeout; absorb via try_collect
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while (gw.health().beats(0) == 0 || gw.health().beats(1) == 0)
            && std::time::Instant::now() < deadline
        {
            let _ = gw.try_collect();
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(gw.health().beats(0) > 0, "shard 0 never beat");
        assert!(gw.health().beats(1) > 0, "shard 1 never beat");
        assert_eq!(gw.health().state(0), crate::obs::health::HealthState::Healthy);
        let j = gw.health().to_json();
        assert!(j.contains("\"state\":\"healthy\""));
        // heartbeats are absorbed, never returned as data responses
        gw.submit("task0", &[1, 2, 3]).unwrap();
        let got = gw.flush().unwrap();
        assert_eq!(got.len(), 1);
        let (report, _) = gw.shutdown().unwrap();
        assert_eq!(report.merged.requests, 1);
    }

    #[test]
    fn deploy_registers_fleet_wide_and_serves() {
        let mut gw = Gateway::launch(&cfg(2, 4)).unwrap();
        assert!(matches!(gw.submit("deployed", &[1]), Err(SubmitError::Invalid(_))));
        let artifact = crate::store::side_artifact_synthetic(1234, 1 << 12);
        let digest = gw.deploy("deployed", &artifact).unwrap();
        assert_eq!(digest, crate::store::fingerprint_bytes(&artifact));
        // both shards now serve the task; spread prompts across the router
        for i in 0..6i32 {
            gw.submit("deployed", &[i + 1, 2 * i]).unwrap();
        }
        let got = gw.flush().unwrap();
        assert_eq!(got.len(), 6);
        // deploying identical bytes again is idempotent — same digest
        assert_eq!(gw.deploy("deployed", &artifact).unwrap(), digest);
        // junk bytes fail with a typed error and the fleet keeps serving
        assert!(gw.deploy("junk", b"not an artifact").is_err());
        gw.submit("deployed", &[9]).unwrap();
        assert_eq!(gw.flush().unwrap().len(), 1);
        let (report, _) = gw.shutdown().unwrap();
        assert_eq!(report.merged.requests, 7);
    }

    #[test]
    fn stats_mid_stream_stashes_data_responses() {
        // a report racing in-flight work must not lose responses
        let mut gw = Gateway::launch(&cfg(2, 4)).unwrap();
        for i in 0..8 {
            gw.submit(&task_name(i % 2), &[i as i32 + 1, 3]).unwrap();
        }
        let report = gw.report().unwrap();
        assert_eq!(report.shards.len(), 2);
        // everything submitted is eventually collected, stash included
        let got = gw.flush().unwrap();
        let stashed_plus_flushed = got.len();
        assert_eq!(stashed_plus_flushed, 8);
        let (_, leftover) = gw.shutdown().unwrap();
        assert!(leftover.is_empty());
    }
}
