//! [`Linear`] — a frozen weight matrix stored either as f32 or as packed
//! W4 nibbles with double-quantized scales, behind one forward entry.
//!
//! The QST memory story only materializes if the frozen backbone is
//! *resident* in 4 bits: quantize once at build time, drop the f32
//! original, and serve every matmul through the fused dequant-GEMM
//! ([`crate::kernels::qgemm::w4_matmul_dq`]).  Because that kernel — and
//! the per-row dequant in [`Linear::row_into`] — reproduce the exact
//! single-rounded `code * scale` products of
//! [`crate::quant::dequantize_matrix_raw`], a W4 linear is **bit-identical**
//! to an f32 linear holding the quantize→dequantize round-trip of the same
//! weights.  The serve parity tests pin this across presets, batch shapes,
//! and thread counts.

use crate::kernels::{gemm, qgemm, Threads};
use crate::quant::codebook::codebook;
use crate::quant::{
    dequantize_matrix_raw, dequantize_scales, qblock_for, quantize_matrix_raw, quantize_scales,
    scale_at,
};

/// How the frozen backbone weights are held in memory (`--backbone`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackboneKind {
    /// Plain `Vec<f32>` — the pre-refactor storage; 4 bytes/param.
    F32,
    /// Packed 4-bit nibbles + double-quantized scales; ~4.13 bits/param.
    W4,
}

impl BackboneKind {
    pub fn parse(name: &str) -> anyhow::Result<Self> {
        match name {
            "f32" => Ok(BackboneKind::F32),
            "w4" => Ok(BackboneKind::W4),
            other => anyhow::bail!("unknown backbone '{other}' (expected 'f32' or 'w4')"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BackboneKind::F32 => "f32",
            BackboneKind::W4 => "w4",
        }
    }

    /// The other storage kind (for side-by-side benchmark passes).
    pub fn other(self) -> Self {
        match self {
            BackboneKind::F32 => BackboneKind::W4,
            BackboneKind::W4 => BackboneKind::F32,
        }
    }
}

/// Quantized-scale group size used for backbone matrices (paper default).
pub const QGROUP: usize = 256;
/// Code table used for backbone matrices (paper default).
pub const QDTYPE: &str = "nf4";

/// One `[K, N]` matrix in the W4 storage format, raw-vec flavored for the
/// serving hot path (the tensor-wrapped sibling is [`crate::quant::QMatrix`]).
pub struct W4Linear {
    /// `[K/2, N]` nibble pairs (row 2i low, 2i+1 high)
    pub packed: Vec<u8>,
    /// `[K/qblock · N]` 8-bit double-quantized scales
    pub q8: Vec<i8>,
    /// per-group absmax of the centered scales
    pub gabs: Vec<f32>,
    /// per-group mean of the scales
    pub gmean: Vec<f32>,
    pub k: usize,
    pub n: usize,
    pub qblock: usize,
}

/// Resident bytes of one `[K, N]` matrix in the W4 storage format: packed
/// nibbles + 1-byte scales + two f32s per scale group.
pub fn w4_resident_bytes(k: usize, n: usize, qblock: usize, qgroup: usize) -> usize {
    let nscales = (k / qblock) * n;
    (k / 2) * n + nscales + 8 * nscales.div_ceil(qgroup)
}

/// A frozen weight matrix `W[K, N]` with a storage-dispatched forward.
pub enum Linear {
    F32 { w: Vec<f32>, k: usize, n: usize },
    W4(W4Linear),
}

impl Linear {
    /// Hold `w` as plain f32 (takes ownership; no copy).
    pub fn from_f32(w: Vec<f32>, k: usize, n: usize) -> Self {
        assert_eq!(w.len(), k * n);
        Linear::F32 { w, k, n }
    }

    /// Quantize `w` to the W4 storage format and drop the f32 original.
    /// `qblock` defaults to the largest supported stripe dividing `k`.
    pub fn quantize(w: Vec<f32>, k: usize, n: usize) -> Self {
        let qblock = qblock_for(k)
            .unwrap_or_else(|| panic!("K={k} has no even qblock — cannot store as W4"));
        let (packed, scales) = quantize_matrix_raw(&w, k, n, QDTYPE, qblock);
        drop(w); // the f32 copy dies here; only the 4-bit form stays resident
        let (q8, gabs, gmean) = quantize_scales(&scales, QGROUP);
        Linear::W4(W4Linear { packed, q8, gabs, gmean, k, n, qblock })
    }

    /// Build with the storage selected by `kind` (`--backbone`).
    pub fn build(kind: BackboneKind, w: Vec<f32>, k: usize, n: usize) -> Self {
        match kind {
            BackboneKind::F32 => Linear::from_f32(w, k, n),
            BackboneKind::W4 => Linear::quantize(w, k, n),
        }
    }

    pub fn kind(&self) -> BackboneKind {
        match self {
            Linear::F32 { .. } => BackboneKind::F32,
            Linear::W4(_) => BackboneKind::W4,
        }
    }

    /// `(K, N)`
    pub fn shape(&self) -> (usize, usize) {
        match self {
            Linear::F32 { k, n, .. } => (*k, *n),
            Linear::W4(q) => (q.k, q.n),
        }
    }

    /// `y[m, N] = x[m, K] · W[K, N]`, dispatching to the blocked f32 GEMM
    /// or the fused W4 dequant-GEMM.  Bit-identical across thread counts
    /// either way.
    pub fn forward(&self, threads: &Threads, x: &[f32], m: usize) -> Vec<f32> {
        match self {
            Linear::F32 { w, k, n } => gemm::matmul(threads, x, w, m, *k, *n),
            Linear::W4(q) => qgemm::w4_matmul_dq(
                threads, x, &q.packed, &q.q8, &q.gabs, &q.gmean, QGROUP, m, q.k, q.n, QDTYPE,
                q.qblock,
            ),
        }
    }

    /// Copy row `r` (length N) into `out` — the embedding-gather path.
    /// The W4 arm decodes `code[nibble] · scale` with the same single
    /// roundings as [`dequantize_matrix_raw`], so gathers match the f32
    /// round-trip exactly.
    pub fn row_into(&self, r: usize, out: &mut [f32]) {
        match self {
            Linear::F32 { w, k, n } => {
                assert!(r < *k);
                out.copy_from_slice(&w[r * n..(r + 1) * n]);
            }
            Linear::W4(q) => {
                assert!(r < q.k);
                assert_eq!(out.len(), q.n);
                let code = codebook(QDTYPE);
                let srow = (r / q.qblock) * q.n;
                let prow = &q.packed[(r / 2) * q.n..(r / 2 + 1) * q.n];
                let hi = r % 2 == 1;
                for (j, (v, &byte)) in out.iter_mut().zip(prow).enumerate() {
                    let s = scale_at(&q.q8, &q.gabs, &q.gmean, QGROUP, srow + j);
                    let nib = if hi { byte >> 4 } else { byte & 0xF };
                    *v = code[nib as usize] * s;
                }
            }
        }
    }

    /// Bytes this matrix keeps resident (weight payload only).
    pub fn resident_bytes(&self) -> usize {
        match self {
            Linear::F32 { w, .. } => w.len() * 4,
            Linear::W4(q) => w4_resident_bytes(q.k, q.n, q.qblock, QGROUP),
        }
    }

    /// Materialize the full f32 matrix this linear computes with: the raw
    /// weights for `F32`, the quantize→dequantize round-trip for `W4`.
    pub fn dequantized(&self) -> Vec<f32> {
        match self {
            Linear::F32 { w, .. } => w.clone(),
            Linear::W4(q) => {
                let scales = dequantize_scales(&q.q8, &q.gabs, &q.gmean, QGROUP);
                dequantize_matrix_raw(&q.packed, &scales, q.k, q.n, QDTYPE, q.qblock)
            }
        }
    }

    /// An `F32` linear computing exactly what this one computes — the
    /// reference the W4 parity tests compare against.
    pub fn to_f32_roundtrip(&self) -> Linear {
        let (k, n) = self.shape();
        Linear::from_f32(self.dequantized(), k, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.normal() as f32 * 0.5).collect()
    }

    #[test]
    fn backbone_kind_parse_roundtrip() {
        for k in [BackboneKind::F32, BackboneKind::W4] {
            assert_eq!(BackboneKind::parse(k.name()).unwrap(), k);
            assert_eq!(k.other().other(), k);
        }
        assert!(BackboneKind::parse("int8").is_err());
    }

    #[test]
    fn w4_forward_matches_f32_roundtrip_bitwise() {
        let mut rng = Rng::new(11);
        for (k, n) in [(96usize, 96usize), (256, 64), (512, 96)] {
            let w = rand(&mut rng, k * n);
            let q = Linear::quantize(w.clone(), k, n);
            let rt = q.to_f32_roundtrip();
            for m in [1usize, 5, 40] {
                let x = rand(&mut rng, m * k);
                for t in [1usize, 4] {
                    let threads = Threads::new(t);
                    assert_eq!(
                        q.forward(&threads, &x, m),
                        rt.forward(&threads, &x, m),
                        "k={k} n={n} m={m} t={t}"
                    );
                }
            }
        }
    }

    #[test]
    fn row_gather_matches_dequantized_rows() {
        let mut rng = Rng::new(12);
        let (k, n) = (128usize, 48usize);
        let q = Linear::quantize(rand(&mut rng, k * n), k, n);
        let full = q.dequantized();
        let mut row = vec![0f32; n];
        for r in [0usize, 1, 63, 64, 127] {
            q.row_into(r, &mut row);
            assert_eq!(row, full[r * n..(r + 1) * n], "row {r}");
        }
    }

    #[test]
    fn f32_row_and_forward_are_raw() {
        let mut rng = Rng::new(13);
        let (k, n) = (8usize, 6usize);
        let w = rand(&mut rng, k * n);
        let lin = Linear::from_f32(w.clone(), k, n);
        let mut row = vec![0f32; n];
        lin.row_into(3, &mut row);
        assert_eq!(row, w[3 * n..4 * n]);
        assert_eq!(lin.resident_bytes(), k * n * 4);
    }

    #[test]
    fn w4_resident_bytes_is_much_smaller() {
        let mut rng = Rng::new(14);
        for (k, n) in [(96usize, 96usize), (256, 256), (512, 256)] {
            let w = rand(&mut rng, k * n);
            let f = Linear::from_f32(w.clone(), k, n);
            let q = Linear::quantize(w, k, n);
            assert!(
                q.resident_bytes() * 5 <= f.resident_bytes(),
                "{k}x{n}: w4 {} vs f32 {}",
                q.resident_bytes(),
                f.resident_bytes()
            );
            // accounting helper must match the actual payload sizes
            if let Linear::W4(ref raw) = q {
                assert_eq!(
                    q.resident_bytes(),
                    raw.packed.len()
                        + raw.q8.len()
                        + 4 * (raw.gabs.len() + raw.gmean.len())
                );
            }
        }
    }
}
