//! Host-side neural-net building blocks shared by serving and benches.
//!
//! Today this is [`Linear`] — the backbone weight abstraction that lets
//! [`crate::serve::SyntheticEngine`] hold its frozen matrices either as
//! plain f32 or as packed 4-bit nibbles with double-quantized scales
//! (the paper's storage format), behind one `forward` entry point.

pub mod linear;

pub use linear::{w4_resident_bytes, BackboneKind, Linear, W4Linear};
