//! Host-side tensors and PJRT literal marshaling.
//!
//! [`HostTensor`] is the coordinator's universal value type: a dtype, a shape
//! and a flat byte buffer, convertible to/from `xla::Literal` for artifact
//! execution and serialized by `coordinator::checkpoint`.

use anyhow::{bail, Context, Result};

/// Element types used by the artifacts (subset of XLA's PrimitiveType).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    F16,
    I32,
    U32,
    U8,
    I8,
}

impl DType {
    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 | DType::U32 => 4,
            DType::F16 => 2,
            DType::U8 | DType::I8 => 1,
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => DType::F32,
            "f16" => DType::F16,
            "i32" => DType::I32,
            "u32" => DType::U32,
            "u8" => DType::U8,
            "i8" => DType::I8,
            other => bail!("unknown dtype '{other}'"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F16 => "f16",
            DType::I32 => "i32",
            DType::U32 => "u32",
            DType::U8 => "u8",
            DType::I8 => "i8",
        }
    }

    pub fn primitive(self) -> xla::PrimitiveType {
        match self {
            DType::F32 => xla::PrimitiveType::F32,
            DType::F16 => xla::PrimitiveType::F16,
            DType::I32 => xla::PrimitiveType::S32,
            DType::U32 => xla::PrimitiveType::U32,
            DType::U8 => xla::PrimitiveType::U8,
            DType::I8 => xla::PrimitiveType::S8,
        }
    }
}

/// A dense host tensor: dtype + shape + row-major bytes.
#[derive(Clone, Debug)]
pub struct HostTensor {
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl HostTensor {
    pub fn zeros(dtype: DType, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        HostTensor { dtype, shape: shape.to_vec(), data: vec![0; n * dtype.size()] }
    }

    pub fn from_f32(shape: &[usize], vals: &[f32]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), vals.len());
        let mut data = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        HostTensor { dtype: DType::F32, shape: shape.to_vec(), data }
    }

    pub fn from_i32(shape: &[usize], vals: &[i32]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), vals.len());
        let mut data = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        HostTensor { dtype: DType::I32, shape: shape.to_vec(), data }
    }

    pub fn from_u8(shape: &[usize], vals: Vec<u8>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), vals.len());
        HostTensor { dtype: DType::U8, shape: shape.to_vec(), data: vals }
    }

    pub fn from_i8(shape: &[usize], vals: &[i8]) -> Self {
        let data = vals.iter().map(|&v| v as u8).collect();
        HostTensor { dtype: DType::I8, shape: shape.to_vec(), data }
    }

    pub fn scalar_f32(v: f32) -> Self {
        Self::from_f32(&[], &[v])
    }

    pub fn scalar_u32(v: u32) -> Self {
        HostTensor { dtype: DType::U32, shape: vec![], data: v.to_le_bytes().to_vec() }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> usize {
        self.data.len()
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            bail!("as_f32 on {:?}", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != DType::I32 {
            bail!("as_i32 on {:?}", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn f32_at(&self, i: usize) -> f32 {
        let c = &self.data[i * 4..i * 4 + 4];
        f32::from_le_bytes([c[0], c[1], c[2], c[3]])
    }

    /// Scalar convenience (loss/gnorm outputs).
    pub fn scalar(&self) -> f32 {
        self.f32_at(0)
    }

    /// Convert to an XLA literal for execution.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<usize> = self.shape.clone();
        let mut lit = xla::Literal::create_from_shape(self.dtype.primitive(), &dims);
        if lit.size_bytes() != self.data.len() {
            bail!(
                "literal size mismatch for shape {:?} {:?}: {} vs {}",
                self.shape,
                self.dtype,
                lit.size_bytes(),
                self.data.len()
            );
        }
        // copy_raw_from is typed; route through the element type
        match self.dtype {
            DType::F32 => lit.copy_raw_from::<f32>(&self.as_f32()?)?,
            DType::I32 => lit.copy_raw_from::<i32>(&self.as_i32()?)?,
            DType::U32 => {
                let vals: Vec<u32> = self
                    .data
                    .chunks_exact(4)
                    .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                lit.copy_raw_from::<u32>(&vals)?
            }
            DType::U8 => lit.copy_raw_from::<u8>(&self.data)?,
            DType::I8 => {
                let vals: Vec<i8> = self.data.iter().map(|&b| b as i8).collect();
                lit.copy_raw_from::<i8>(&vals)?
            }
            DType::F16 => bail!("f16 host tensors are storage-only"),
        }
        Ok(lit)
    }

    /// Read back from an XLA literal.
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape().context("literal has no array shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let dtype = match shape.ty() {
            xla::ElementType::F32 => DType::F32,
            xla::ElementType::S32 => DType::I32,
            xla::ElementType::U32 => DType::U32,
            xla::ElementType::U8 => DType::U8,
            xla::ElementType::S8 => DType::I8,
            xla::ElementType::F16 => DType::F16,
            other => bail!("unsupported literal type {other:?}"),
        };
        let mut out = HostTensor::zeros(dtype, &dims);
        match dtype {
            DType::F32 => {
                let v = lit.to_vec::<f32>()?;
                out = HostTensor::from_f32(&dims, &v);
            }
            DType::I32 => {
                let v = lit.to_vec::<i32>()?;
                out = HostTensor::from_i32(&dims, &v);
            }
            DType::U32 => {
                let v = lit.to_vec::<u32>()?;
                let mut data = Vec::with_capacity(v.len() * 4);
                for x in v {
                    data.extend_from_slice(&x.to_le_bytes());
                }
                out.data = data;
            }
            DType::U8 => {
                out.data = lit.to_vec::<u8>()?;
            }
            DType::I8 => {
                let v = lit.to_vec::<i8>()?;
                out.data = v.iter().map(|&x| x as u8).collect();
            }
            DType::F16 => bail!("f16 readback unsupported"),
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let t = HostTensor::from_f32(&[2, 3], &[1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.as_f32().unwrap(), vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.f32_at(4), 5.0);
    }

    #[test]
    fn zeros_sizing() {
        let t = HostTensor::zeros(DType::U8, &[7, 3]);
        assert_eq!(t.bytes(), 21);
        let t = HostTensor::zeros(DType::F32, &[7, 3]);
        assert_eq!(t.bytes(), 84);
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::from_f32(&[2, 2], &[1., -2., 3.5, 0.25]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back.shape, vec![2, 2]);
        assert_eq!(back.as_f32().unwrap(), t.as_f32().unwrap());
    }

    #[test]
    fn literal_roundtrip_u8_i32() {
        let t = HostTensor::from_u8(&[4], vec![7, 0, 255, 128]);
        let back = HostTensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(back.data, t.data);

        let t = HostTensor::from_i32(&[3], &[-1, 0, 42]);
        let back = HostTensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(back.as_i32().unwrap(), vec![-1, 0, 42]);
    }

    #[test]
    fn scalar_literal() {
        let t = HostTensor::scalar_f32(3.25);
        let back = HostTensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(back.scalar(), 3.25);
        assert!(back.shape.is_empty());
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("f32").unwrap(), DType::F32);
        assert_eq!(DType::parse("u8").unwrap(), DType::U8);
        assert!(DType::parse("f64").is_err());
    }
}
