//! Hand-rolled CLI argument parser (clap is unavailable offline).
//!
//! Supports `qst <command> [--flag value] [--switch] [positional...]` with
//! typed accessors, defaults, and a usage printer.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

/// Flags that never take a value (so `--verbose positional` parses right).
const KNOWN_SWITCHES: &[&str] = &["verbose", "fast", "force", "help", "synthetic"];

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub flags: HashMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `std::env::args()`-style input (element 0 = program name).
    pub fn parse(argv: &[String]) -> Result<Self> {
        let mut args = Args::default();
        let mut it = argv.iter().skip(1).peekable();
        if let Some(cmd) = it.next() {
            if cmd.starts_with('-') {
                bail!("expected a command before flags (got '{cmd}')");
            }
            args.command = cmd.clone();
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if KNOWN_SWITCHES.contains(&name) {
                    args.switches.push(name.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.flags.insert(name.to_string(), v.clone());
                } else {
                    args.switches.push(name.to_string());
                }
            } else {
                args.positional.push(a.clone());
            }
        }
        Ok(args)
    }

    pub fn get(&self, k: &str) -> Option<&str> {
        self.flags.get(k).map(|s| s.as_str())
    }

    pub fn str_or(&self, k: &str, default: &str) -> String {
        self.get(k).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, k: &str, default: usize) -> Result<usize> {
        match self.get(k) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{k} expects an integer, got '{v}'")),
        }
    }

    /// u64 flag accessor (byte budgets, seeds — values that can exceed
    /// 32 bits and must never be negative).
    pub fn u64_or(&self, k: &str, default: u64) -> Result<u64> {
        match self.get(k) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{k} expects a non-negative integer, got '{v}'")),
        }
    }

    pub fn f32_or(&self, k: &str, default: f32) -> Result<f32> {
        match self.get(k) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{k} expects a float, got '{v}'")),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn require(&self, k: &str) -> Result<&str> {
        self.get(k).with_context(|| format!("missing required flag --{k}"))
    }
}

pub const USAGE: &str = "\
qst — Quantized Side Tuning (ACL 2024) coordinator

USAGE: qst <command> [flags]

COMMANDS:
  pretrain     --config <name> [--steps N] [--lr F] [--verbose]
               Pretrain a backbone on the synthetic corpus; saves runs/<cfg>__base.ckpt
  quantize     --config <name> [--qdtype nf4|fp4]
               Quantize a pretrained backbone checkpoint (reports error stats)
  finetune     --config <name> --method qst|qlora|lora|adapter|lst
               [--task cls|lm] [--glue-task SST-2|...] [--steps N] [--lr F] [--verbose]
  eval         --config <name> --method <m> [--task cls|lm] ...
  generate     --config <name> --method <m> [--prompt-len N] [--max-new N]
  experiments  --id table1|table2|table3|table4|table5|table6|table7|
                    fig1a|fig1b|fig4|fig5|fig6|calib|all  [--fast]
  serve        [--synthetic [--num-tasks N]] | [--config <name> --method <m> --tasks cls,lm]
               [--preset small|large|xl] [--backbone f32|w4] [--threads N]
               [--cache-bytes N] [--registry-bytes N] [--batch N] [--seq N]
               [--prefix-block N] [--seed N] [--trace-out PATH]
               In-process multi-task inference server: one shared frozen
               backbone, per-task side networks, hidden-state cache.
               --threads N runs the host kernels on N workers (bit-identical
               results for any N); --preset large is d=256, 8 layers
               and --preset xl is d=512, 12 layers (packed-panel kernels);
               --backbone w4 keeps the frozen backbone packed in 4 bits and
               serves through the fused dequant-GEMM (~7x less resident);
               --prefix-block N lets prompts that extend a cached prompt
               resume the frozen forward from the deepest cached N-token
               block (0 = whole-prompt caching only);
               --trace-out PATH records request-lifecycle + kernel spans and
               writes a Chrome trace-event file on exit (load in Perfetto /
               chrome://tracing); tracing never changes one output bit.
               Reads requests from stdin, one per line: '<task> <tok> <tok> ...'
               The exact line 'STATS' returns Prometheus-style text metrics
               (lowercase 'stats' keeps the human summary).
  gateway      [--shards N | --connect ADDR,ADDR,...] [--queue-cap N]
               [--num-tasks N] [--preset small|large|xl] [--backbone f32|w4]
               [--threads N] [--cache-bytes N] [--registry-bytes N]
               [--batch N] [--seq N] [--prefix-block N] [--seed N]
               [--trace-out PATH] [--heartbeat-ms N] [--health-mult N]
               [--series-ms N] [--series-cap N]
               Asynchronous sharded serving front-end: N worker shards each
               hold a private backbone replica + prefix-aware hidden-state
               cache behind a bounded inbox (full inbox => backpressure, not
               deadlock); prompts are routed by their leading prefix block so
               repeats and prefix families stay cache-local.  Same stdin line
               protocol as serve, but submission is decoupled from execution
               and responses print in completion order.
               --connect drives shard-worker processes over the versioned
               wire protocol instead of in-process threads: one address per
               shard (unix:<path> or <host>:<port>, so --shards is ignored);
               each worker is configured over the wire from this gateway's
               flags, and responses are bit-identical to the in-proc fleet.
               --trace-out PATH additionally arms tracing in every shard
               (workers ship span batches back as Telemetry frames) and
               writes one fleet-wide Chrome trace file; the line 'STATS'
               returns Prometheus-style text metrics with exactly-merged
               fleet latency buckets.
               --heartbeat-ms N makes every shard emit a liveness
               heartbeat each N ms (queue depth, in-flight slots, span
               drops, cache bytes); the gateway grades shards
               Healthy/Suspect/Dead at 1x/2x the timeout
               (N * --health-mult, default 3) and exports
               qst_worker_up{shard} / qst_heartbeat_age_seconds{shard}
               in 'STATS'.  The exact line 'HEALTH' returns the fleet
               liveness registry as one JSON line without a report
               barrier (it answers even with a dead shard).
               --series-ms N arms the gauge flight recorder: each shard
               samples queue depth, in-flight slots, and cache/registry
               bytes every N ms into a --series-cap ring (default 256,
               oldest overwritten); with --trace-out the merged series
               render as Chrome counter tracks ('ph':'C') beside the
               spans, including derived rps.  Both cadences default 0
               (off) and cost nothing when disabled.
  shard-worker --listen ADDR
               One gateway shard as its own process: binds unix:<path> or
               <host>:<port>, accepts one `gateway --connect` session,
               builds its backbone replica from the gateway's Configure
               frame (no model flags here), serves, exits on shutdown.
  bench-serve  [--tasks N] [--requests N] [--unique-prompts N] [--prompt-len N]
               [--seq N] [--batch N] [--burst N] [--cache-bytes N]
               [--registry-bytes N] [--prefix-block N] [--seed N]
               [--preset small|large|xl] [--backbone f32|w4] [--threads N]
               [--json PATH] [--trace-out PATH]
               Repeated-prompt serving benchmark over >=2 side networks;
               reports cached vs uncached throughput, cache hit rate,
               p50/p95 latency, f32-vs-W4 backbone residency + latency
               side-by-side, and the measured disabled-tracing overhead
               (trace_off_overhead_pct); --trace-out re-runs the cached
               pass with tracing armed (verifying bit-parity) and writes
               the Chrome trace; writes BENCH_serve.json
  bench-gateway [--shards N,N,...] [--transports inproc,socket] [--tasks N]
               [--requests N] [--families N] [--per-family N]
               [--prefix-len N] [--prompt-len N] [--seq N] [--batch N]
               [--cache-bytes N] [--registry-bytes N] [--prefix-block N]
               [--queue-cap N] [--threads-per-shard N] [--seed N]
               [--preset small|large|xl] [--backbone f32|w4] [--json PATH]
               [--trace-out PATH] [--mixed-requests N] [--mixed-wave N]
               Shard-count x transport scaling sweep under open-loop
               shared-prefix load: one deterministic request stream per
               (transport, shard count); socket passes run real shard
               workers over framed socket pairs.  Reports aggregate req/s,
               merged p50/p95 (total + queue-wait), cache + prefix-hit
               rates, modeled fleet residency (in-process and
               per-process), and refuses to write BENCH_gateway.json
               unless sharded, transport, prefix-resume, traced-run, and
               continuous-vs-waved parity all hold bit-for-bit
               (--trace-out arms tracing on a parity replay and writes
               the fleet Chrome trace).  The mixed sweep replays a
               mixed-prompt-length pool through slot-based continuous
               admission and through a driver-emulated wave barrier
               (--mixed-wave, 0 = shards x batch) and reports
               continuous_p95_ratio (--mixed-requests 0 disables)
  bench-kernels [--dims 96,256,512] [--m N] [--threads N] [--seed N]
               [--naive-cap-macs N] [--json PATH]
               Host kernel microbenchmarks: naive vs cache-blocked vs
               packed-panel (serial + threaded) f32 GEMM, and fused W4
               dequant-GEMM (panel-shared decode, serial + threaded) vs
               the row-run baseline vs dequantize-then-matmul; verifies
               exact equivalence, then writes BENCH_kernels.json with
               per-kernel ms + GFLOP/s (--threads defaults to all cores;
               the O(m*k*n) naive baseline is skipped above a MAC budget
               and the blocked kernel stands in as reference)
  bench-registry [--tasks N] [--requests N] [--zipf-s F] [--budget-pct N]
               [--seq N] [--prompt-len N] [--batch N] [--parity-requests N]
               [--seed N] [--threads N] [--json PATH]
               Task-artifact registry churn benchmark: writes N synthetic
               task artifacts (default 1000) into a file-backed
               content-addressed store, registers them against a registry
               budgeted at --budget-pct percent of the catalog (must be
               < 10, so the long tail must thrash), and drives a seeded
               Zipf-distributed request mix through it; reports swap-in
               p50/p95, registry hit rate, evictions, and resident bytes.
               Before writing BENCH_registry.json it live-Deploys a fresh
               artifact to a running 2-worker socket fleet and refuses to
               serialize unless the deployed task serves bit-identically
               to a replica loaded from the store after a restart
  artifacts    List available AOT artifacts
  info         Print environment / runtime info
  help         This message
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        let v: Vec<String> = std::iter::once("qst").chain(s.iter().copied()).map(String::from).collect();
        Args::parse(&v).unwrap()
    }

    #[test]
    fn basic() {
        let a = parse(&["finetune", "--config", "tiny-opt", "--steps", "100", "--verbose", "pos1"]);
        assert_eq!(a.command, "finetune");
        assert_eq!(a.get("config"), Some("tiny-opt"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 100);
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn equals_form() {
        let a = parse(&["x", "--lr=0.002"]);
        assert_eq!(a.f32_or("lr", 0.0).unwrap(), 0.002);
    }

    #[test]
    fn trailing_switch() {
        let a = parse(&["x", "--verbose"]);
        assert!(a.has("verbose"));
    }

    #[test]
    fn bad_int_errors() {
        let a = parse(&["x", "--steps", "abc"]);
        assert!(a.usize_or("steps", 0).is_err());
    }

    #[test]
    fn u64_parses_defaults_and_rejects() {
        let a = parse(&["x", "--cache-bytes", "68719476736"]); // 64 GiB > u32
        assert_eq!(a.u64_or("cache-bytes", 0).unwrap(), 68_719_476_736);
        assert_eq!(a.u64_or("seed", 7).unwrap(), 7, "missing flag falls back to default");
        let bad = parse(&["x", "--cache-bytes", "-1"]);
        assert!(bad.u64_or("cache-bytes", 0).is_err(), "negative must be rejected");
        let junk = parse(&["x", "--cache-bytes", "12MB"]);
        assert!(junk.u64_or("cache-bytes", 0).is_err());
    }

    #[test]
    fn u64_zero_is_valid() {
        // `--cache-bytes 0` is the documented cache-off switch
        let a = parse(&["x", "--cache-bytes", "0"]);
        assert_eq!(a.u64_or("cache-bytes", 1).unwrap(), 0);
    }

    #[test]
    fn missing_required() {
        let a = parse(&["x"]);
        assert!(a.require("config").is_err());
    }
}
