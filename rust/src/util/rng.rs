//! Deterministic xorshift128+ RNG — the single randomness source for data
//! generation and tests, so every experiment is reproducible from a seed.

#[derive(Clone, Debug)]
pub struct Rng {
    s0: u64,
    s1: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 seeding to avoid weak low-entropy states
        let mut z = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            z = z.wrapping_add(0x9E3779B97F4A7C15);
            let mut x = z;
            x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
            x ^ (x >> 31)
        };
        let s0 = next();
        let s1 = next().max(1);
        Rng { s0, s1 }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.s0;
        let y = self.s1;
        self.s0 = y;
        x ^= x << 23;
        self.s1 = x ^ y ^ (x >> 17) ^ (y >> 26);
        self.s1.wrapping_add(y)
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Derive an independent stream (for per-task generators).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02, "mean {}", sum / 10_000.0);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            m += x;
            v += x * x;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.03, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
