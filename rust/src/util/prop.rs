//! Minimal property-testing driver (proptest is unavailable offline).
//!
//! `check(n, seed, |rng| ...)` runs a property n times with derived seeds and
//! reports the first failing seed so failures are reproducible:
//!
//! ```text
//! prop::check(64, 0xC0FFEE, |rng| {
//!     let x = rng.below(100);
//!     assert!(x < 100);
//! });
//! ```

use super::rng::Rng;

/// Run `f` `n` times with independent RNGs; panic with the failing seed.
pub fn check(n: usize, seed: u64, f: impl Fn(&mut Rng)) {
    for i in 0..n {
        let case_seed = seed ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property failed on case {i} (seed {case_seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial() {
        check(32, 1, |rng| assert!(rng.below(10) < 10));
    }

    #[test]
    #[should_panic]
    fn reports_failure() {
        check(32, 2, |rng| assert!(rng.below(10) < 5));
    }
}
