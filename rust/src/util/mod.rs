//! Small in-repo substrates: deterministic RNG, timing, property-test driver.
//!
//! The sandbox has no network access to crates.io beyond the vendored `xla`
//! closure, so `rand`, `proptest`, and `criterion` equivalents live here.

pub mod prop;
pub mod rng;

use std::time::Instant;

/// Wall-clock a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Peak resident set size of this process in bytes (Linux, /proc/self/status).
pub fn peak_rss_bytes() -> u64 {
    let Ok(s) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in s.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches(" kB").trim().parse().unwrap_or(0);
            return kb * 1024;
        }
    }
    0
}

/// Format a byte count as a human string (GiB with 1 decimal for big values).
pub fn human_bytes(b: f64) -> String {
    if b >= 1e9 {
        format!("{:.1} GB", b / 1e9)
    } else if b >= 1e6 {
        format!("{:.1} MB", b / 1e6)
    } else {
        format!("{:.1} KB", b / 1e3)
    }
}
