//! 4-bit blockwise quantization with double-quantized scales — the Rust
//! mirror of `python/compile/quant.py` (paper §3.1).
//!
//! The coordinator quantizes pretrained checkpoints itself before a QST or
//! QLoRA run, producing exactly the `q.<name>.{packed,qscales,gabs,gmean}`
//! tensors the artifacts expect.  The nibble convention (code 2i in the low
//! nibble of byte i, nibbles running down the K axis of a `W[K, N]` matrix)
//! and the scale layout are bit-identical to the Python side; the
//! cross-language golden tests in `rust/tests/golden.rs` pin this.

pub mod codebook;

use crate::kernels::Threads;
use crate::tensor::{DType, HostTensor};
use codebook::{codebook, nearest_code};

/// Per-block absmax scales for a column-stripe layout: W[K, N] split into
/// (qblock x 1) stripes. Returns (packed u8[K/2, N], scales f32[K/qblock, N]).
///
/// Both passes run row-partitioned on [`Threads::default`] (scale stripes,
/// then packed nibble rows); every output element has exactly one writer,
/// so results are identical for any worker count.
pub fn quantize_matrix_raw(w: &[f32], k: usize, n: usize, qdtype: &str, qblock: usize)
    -> (Vec<u8>, Vec<f32>) {
    assert_eq!(w.len(), k * n);
    assert_eq!(k % qblock, 0, "K must divide by qblock");
    assert_eq!(k % 2, 0);
    let code = codebook(qdtype);
    let threads = Threads::default();
    let kb = k / qblock;
    // absmax per (stripe, col)
    let mut scales = vec![0f32; kb * n];
    threads.par_rows(&mut scales, n, |b0, run| {
        for (bb, srow) in run.chunks_mut(n).enumerate() {
            let b = b0 + bb;
            for (c, s) in srow.iter_mut().enumerate() {
                let mut m = 0f32;
                for r in 0..qblock {
                    m = m.max(w[(b * qblock + r) * n + c].abs());
                }
                *s = m;
            }
        }
    });
    // nearest-code packing: codes for rows 2i (low) and 2i+1 (high)
    let mut packed = vec![0u8; (k / 2) * n];
    let scales_ref = &scales;
    threads.par_rows(&mut packed, n, |half0, run| {
        for (hh, prow) in run.chunks_mut(n).enumerate() {
            let half = half0 + hh;
            for (c, p) in prow.iter_mut().enumerate() {
                let get_code = |row: usize| -> u8 {
                    let s = scales_ref[(row / qblock) * n + c];
                    let safe = if s == 0.0 { 1.0 } else { s };
                    nearest_code(w[row * n + c] / safe, code)
                };
                *p = get_code(2 * half) | (get_code(2 * half + 1) << 4);
            }
        }
    });
    (packed, scales)
}

/// Dequantize a column-stripe matrix back to f32 (for tests / analysis),
/// row-partitioned on [`Threads::default`] with contiguous row writes.
pub fn dequantize_matrix_raw(packed: &[u8], scales: &[f32], k: usize, n: usize,
                             qdtype: &str, qblock: usize) -> Vec<f32> {
    assert_eq!(k % 2, 0);
    assert_eq!(packed.len(), (k / 2) * n);
    let code = codebook(qdtype);
    let mut w = vec![0f32; k * n];
    Threads::default().par_rows(&mut w, n, |row0, run| {
        for (rr, wrow) in run.chunks_mut(n).enumerate() {
            let row = row0 + rr;
            let prow = &packed[(row / 2) * n..(row / 2 + 1) * n];
            let srow = &scales[(row / qblock) * n..][..n];
            let hi = row % 2 == 1;
            for ((v, &byte), &s) in wrow.iter_mut().zip(prow).zip(srow) {
                let nib = if hi { byte >> 4 } else { byte & 0xF };
                *v = code[nib as usize] * s;
            }
        }
    });
    w
}

/// Double quantization of scales (paper: 8-bit quantized quantization
/// constants): group by `qgroup`, subtract group mean, symmetric int8.
pub fn quantize_scales(scales: &[f32], qgroup: usize) -> (Vec<i8>, Vec<f32>, Vec<f32>) {
    let n = scales.len();
    let ngroups = n.div_ceil(qgroup);
    let mut q8 = vec![0i8; n];
    let mut gabs = vec![0f32; ngroups];
    let mut gmean = vec![0f32; ngroups];
    for g in 0..ngroups {
        let lo = g * qgroup;
        let hi = (lo + qgroup).min(n);
        let cnt = (hi - lo) as f32;
        let mean: f32 = scales[lo..hi].iter().sum::<f32>() / cnt;
        let mut amax = 0f32;
        for &s in &scales[lo..hi] {
            amax = amax.max((s - mean).abs());
        }
        gmean[g] = mean;
        gabs[g] = amax;
        let safe = if amax == 0.0 { 1.0 } else { amax };
        for i in lo..hi {
            // jnp.round rounds half-to-even; .round() would round half-away
            q8[i] = ((scales[i] - mean) / safe * 127.0).round_ties_even() as i8;
        }
    }
    (q8, gabs, gmean)
}

/// Decode one double-quantized scale.  This is THE defining expression of
/// the 8-bit scale format: every consumer (full decode below, the fused
/// kernel's stripe fill in [`crate::kernels::qgemm`], the embedding row
/// gather in [`crate::nn::Linear`]) must call this single-rounded form so
/// their outputs stay bit-identical to each other.
#[inline]
pub fn scale_at(q8: &[i8], gabs: &[f32], gmean: &[f32], qgroup: usize, i: usize) -> f32 {
    let g = i / qgroup;
    q8[i] as f32 / 127.0 * gabs[g] + gmean[g]
}

pub fn dequantize_scales(q8: &[i8], gabs: &[f32], gmean: &[f32], qgroup: usize) -> Vec<f32> {
    (0..q8.len()).map(|i| scale_at(q8, gabs, gmean, qgroup, i)).collect()
}

/// The 4 artifact tensors for one quantized matrix, keyed by field name.
pub struct QMatrix {
    pub packed: HostTensor,
    pub qscales: HostTensor,
    pub gabs: HostTensor,
    pub gmean: HostTensor,
}

/// Full pipeline: f32 weight matrix -> QST storage format (matches
/// `quant.quantize_matrix` in Python and the shapes in the artifact manifests).
pub fn quantize_matrix(w: &HostTensor, qdtype: &str, qblock: usize, qgroup: usize) -> QMatrix {
    assert_eq!(w.dtype, DType::F32);
    assert_eq!(w.shape.len(), 2, "quantize_matrix wants [K, N]");
    let (k, n) = (w.shape[0], w.shape[1]);
    let vals = w.as_f32().expect("f32 weight");
    let (packed, scales) = quantize_matrix_raw(&vals, k, n, qdtype, qblock);
    let (q8, gabs, gmean) = quantize_scales(&scales, qgroup);
    QMatrix {
        packed: HostTensor::from_u8(&[k / 2, n], packed),
        qscales: HostTensor::from_i8(&[q8.len()], &q8),
        gabs: HostTensor::from_f32(&[gabs.len()], &gabs),
        gmean: HostTensor::from_f32(&[gmean.len()], &gmean),
    }
}

/// Effective storage bits per parameter (paper: ~4.127 b/param at 64/256).
pub fn storage_bits_per_param(qblock: usize, qgroup: usize) -> f64 {
    4.0 + 8.0 / qblock as f64 + 64.0 / (qblock as f64 * qgroup as f64)
}

/// Largest supported scale-stripe size dividing `k` (the paper's 64 when it
/// fits, else the next even divisor); `None` for odd `k`, which cannot pack
/// nibble pairs at all.
pub fn qblock_for(k: usize) -> Option<usize> {
    [64usize, 32, 16, 8, 4, 2].into_iter().find(|qb| k % qb == 0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    fn rand_matrix(rng: &mut Rng, k: usize, n: usize, scale: f32) -> Vec<f32> {
        (0..k * n).map(|_| rng.normal() as f32 * scale).collect()
    }

    #[test]
    fn roundtrip_error_bounded() {
        let mut rng = Rng::new(0);
        let (k, n) = (128, 32);
        let w = rand_matrix(&mut rng, k, n, 0.5);
        let (packed, scales) = quantize_matrix_raw(&w, k, n, "nf4", 64);
        let back = dequantize_matrix_raw(&packed, &scales, k, n, "nf4", 64);
        let amax = w.iter().fold(0f32, |a, &b| a.max(b.abs()));
        // widest NF4 gap is ~0.30 -> worst case error ~0.15*absmax
        for (a, b) in w.iter().zip(&back) {
            assert!((a - b).abs() <= 0.16 * amax + 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn absmax_exact() {
        // the absmax of each block must round-trip exactly (maps to ±1 code)
        let k = 64;
        let mut w = vec![0.1f32; k];
        w[17] = -3.5;
        let (p, s) = quantize_matrix_raw(&w, k, 1, "nf4", 64);
        let back = dequantize_matrix_raw(&p, &s, k, 1, "nf4", 64);
        assert_eq!(back[17], -3.5);
        assert_eq!(s[0], 3.5);
    }

    #[test]
    fn zeros_stay_zero() {
        let w = vec![0f32; 128];
        let (p, s) = quantize_matrix_raw(&w, 128, 1, "nf4", 64);
        let back = dequantize_matrix_raw(&p, &s, 128, 1, "nf4", 64);
        assert!(back.iter().all(|&v| v == 0.0));
        assert!(s.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn scales_double_quant_roundtrip() {
        let mut rng = Rng::new(1);
        let scales: Vec<f32> = (0..600).map(|_| rng.f32() + 0.01).collect();
        let (q8, gabs, gmean) = quantize_scales(&scales, 256);
        assert_eq!(gabs.len(), 3); // 600 -> 3 groups
        let back = dequantize_scales(&q8, &gabs, &gmean, 256);
        let tol = gabs.iter().fold(0f32, |a, &b| a.max(b)) / 127.0 + 1e-6;
        for (a, b) in scales.iter().zip(&back) {
            assert!((a - b).abs() <= tol);
        }
    }

    #[test]
    fn storage_bits_matches_paper() {
        assert!((storage_bits_per_param(64, 256) - 4.127).abs() < 0.01);
    }

    #[test]
    fn qblock_for_picks_largest_even_divisor() {
        assert_eq!(qblock_for(256), Some(64));
        assert_eq!(qblock_for(96), Some(32)); // the small preset's d
        assert_eq!(qblock_for(6), Some(2));
        assert_eq!(qblock_for(33), None, "odd K cannot pack nibble pairs");
        for k in [96usize, 128, 256, 512] {
            let qb = qblock_for(k).unwrap();
            assert_eq!(k % qb, 0);
            assert_eq!(qb % 2, 0);
        }
    }

    #[test]
    fn quantize_identical_across_thread_counts() {
        let mut rng = Rng::new(5);
        let (k, n) = (256, 33);
        let w = rand_matrix(&mut rng, k, n, 0.8);
        let baseline = quantize_matrix_raw(&w, k, n, "nf4", 64);
        let back1 = dequantize_matrix_raw(&baseline.0, &baseline.1, k, n, "nf4", 64);
        let _guard = crate::kernels::threads::TEST_GLOBAL_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let before = crate::kernels::default_threads();
        crate::kernels::set_default_threads(4);
        let threaded = quantize_matrix_raw(&w, k, n, "nf4", 64);
        let back4 = dequantize_matrix_raw(&threaded.0, &threaded.1, k, n, "nf4", 64);
        crate::kernels::set_default_threads(before);
        assert_eq!(baseline, threaded, "packing must not depend on worker count");
        assert_eq!(back1, back4, "dequant must not depend on worker count");
    }

    #[test]
    fn qmatrix_shapes_match_manifest_convention() {
        let w = HostTensor::from_f32(&[128, 16], &vec![0.5f32; 128 * 16]);
        let q = quantize_matrix(&w, "nf4", 64, 256);
        assert_eq!(q.packed.shape, vec![64, 16]);
        assert_eq!(q.qscales.shape, vec![32]); // (128/64)*16 blocks
        assert_eq!(q.gabs.shape, vec![1]);
        assert_eq!(q.gmean.shape, vec![1]);
    }

    #[test]
    fn prop_roundtrip_all_dtypes() {
        prop::check(24, 0xDEC0DE, |rng| {
            let k = 64 * rng.range(1, 4);
            let n = rng.range(1, 24);
            let qdtype = if rng.bool(0.5) { "nf4" } else { "fp4" };
            let scale = (rng.f32() * 3.0 + 0.01) as f32;
            let w = rand_matrix(rng, k, n, scale);
            let (p, s) = quantize_matrix_raw(&w, k, n, qdtype, 64);
            assert_eq!(p.len(), k / 2 * n);
            assert_eq!(s.len(), k / 64 * n);
            let back = dequantize_matrix_raw(&p, &s, k, n, qdtype, 64);
            let amax = w.iter().fold(0f32, |a, &b| a.max(b.abs()));
            // FP4's widest gap (normalized) is 2/6 -> error <= amax/6 + eps
            let bound = 0.17f32 * amax + 1e-6;
            for (a, b) in w.iter().zip(&back) {
                assert!((a - b).abs() <= bound, "{a} vs {b} (bound {bound})");
            }
        });
    }

    #[test]
    fn prop_nf4_beats_fp4_on_gaussian() {
        prop::check(8, 0xFACE, |rng| {
            let (k, n) = (256, 16);
            let w = rand_matrix(rng, k, n, 1.0);
            let mse = |dt: &str| {
                let (p, s) = quantize_matrix_raw(&w, k, n, dt, 64);
                let back = dequantize_matrix_raw(&p, &s, k, n, dt, 64);
                w.iter().zip(&back).map(|(a, b)| (a - b).powi(2)).sum::<f32>()
            };
            assert!(mse("nf4") < mse("fp4"), "NF4 must beat FP4 on N(0,1) data");
        });
    }
}
