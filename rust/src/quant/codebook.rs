//! NF4 / FP4 codebooks — byte-identical to `python/compile/quant.py`.

/// NF4 (Dettmers et al. 2023): quantile-optimal 4-bit type for N(0,1) data.
pub const NF4: [f32; 16] = [
    -1.0,
    -0.6961928009986877,
    -0.5250730514526367,
    -0.39491748809814453,
    -0.28444138169288635,
    -0.18477343022823334,
    -0.09105003625154495,
    0.0,
    0.07958029955625534,
    0.16093020141124725,
    0.24611230194568634,
    0.33791524171829224,
    0.44070982933044434,
    0.5626170039176941,
    0.7229568362236023,
    1.0,
];

/// FP4 e2m1 magnitudes {0,.5,1,1.5,2,3,4,6}/6, sign-symmetric; layout matches
/// the Python `FP4_CODE` construction: [pos..., -pos[1:]..., -1].
pub const FP4: [f32; 16] = [
    0.0,
    0.5 / 6.0,
    1.0 / 6.0,
    1.5 / 6.0,
    2.0 / 6.0,
    3.0 / 6.0,
    4.0 / 6.0,
    1.0,
    -0.5 / 6.0,
    -1.0 / 6.0,
    -1.5 / 6.0,
    -2.0 / 6.0,
    -3.0 / 6.0,
    -4.0 / 6.0,
    -1.0,
    -1.0, // FP4_CODE has 15 entries from concat + explicit -1 tail
];

pub fn codebook(qdtype: &str) -> &'static [f32; 16] {
    match qdtype {
        "nf4" => &NF4,
        "fp4" => &FP4,
        other => panic!("unknown qdtype {other}"),
    }
}

/// Index of the nearest codebook entry (ties -> lowest index, matching
/// `jnp.argmin` semantics in the Python quantizer).
pub fn nearest_code(v: f32, code: &[f32; 16]) -> u8 {
    let mut best = 0usize;
    let mut bd = f32::INFINITY;
    for (i, &c) in code.iter().enumerate() {
        let d = (v - c).abs();
        if d < bd {
            bd = d;
            best = i;
        }
    }
    best as u8
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nf4_monotone() {
        for w in NF4.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert_eq!(NF4[7], 0.0);
    }

    #[test]
    fn nearest_endpoints() {
        assert_eq!(nearest_code(1.0, &NF4), 15);
        assert_eq!(nearest_code(-1.0, &NF4), 0);
        assert_eq!(nearest_code(0.0, &NF4), 7);
        assert_eq!(nearest_code(100.0, &NF4), 15);
    }

    #[test]
    fn nearest_ties_lowest_index() {
        // exactly between entries 7 (0.0) and 8 (0.0796) -> argmin picks 7
        let mid = (NF4[7] + NF4[8]) / 2.0;
        assert_eq!(nearest_code(mid, &NF4), 7);
    }
}
