//! The regenerators: one function per paper table/figure.
//!
//! Cost-model items (Fig 1a, Fig 4, Table 3, memory columns) evaluate the
//! analytical models at the paper's true dims.  Accuracy items run the
//! pretrain → quantize → finetune → eval pipeline on the scaled-down proxy
//! models (DESIGN.md §3) and report *shape*: method ordering and rough
//! factors, printed beside the paper's numbers.

use anyhow::Result;

use super::common::{self, FinetuneOutcome};
use super::report::{fmt_gb, Table};
use crate::costmodel::paperdims::{paper_model, Method, ALL_METHODS};
use crate::costmodel::{flops_per_token, memory_bytes};
use crate::costmodel::memory::memory_bytes_r;
use crate::coordinator::evaluator::repetition_rate;
use crate::data::glue::{GlueTask, ALL_TASKS};
use crate::data::instruct::{InstructGen, CATEGORIES};
use crate::data::batcher::{lm_batch, LmExample};
use crate::data::Vocab;
use crate::runtime::Runtime;
use crate::util::{human_bytes, peak_rss_bytes, timed};

fn rt() -> Result<Runtime> {
    Runtime::with_default_dir()
}

// ---------------------------------------------------------------------------
// Fig 1a — memory footprint of methods finetuning LLaMA-2-70B (bs16, s384)
// ---------------------------------------------------------------------------
pub fn fig1a() -> Result<()> {
    let m = paper_model("LLaMA-2-70B").unwrap();
    let mut t = Table::new(
        "Figure 1a — memory (GB) finetuning LLaMA-2-70B (batch 16, seq 384)",
        &["method", "weights", "optimizer", "activations", "total GB"],
    );
    for meth in ALL_METHODS {
        let mb = memory_bytes(m, meth, 16, 384);
        t.row(vec![
            meth.name().into(),
            fmt_gb(mb.weights),
            fmt_gb(mb.optimizer),
            fmt_gb(mb.activations),
            fmt_gb(mb.total()),
        ]);
    }
    t.print();
    t.save("fig1a")?;
    println!("shape check: QST lowest; Full >5x QST; QLoRA/LoRA dominated by activations.");
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig 4 — memory vs batch size / total model bits / sequence length
// ---------------------------------------------------------------------------
pub fn fig4() -> Result<()> {
    let m70 = paper_model("LLaMA-2-70B").unwrap();
    let mut a = Table::new(
        "Figure 4a — memory (GB) vs batch size (LLaMA-2-70B, seq 512)",
        &["batch", "QLoRA", "LST", "LoRA", "Adapter", "QST"],
    );
    for &b in &[1usize, 2, 4, 8, 16, 32] {
        a.row(vec![
            b.to_string(),
            fmt_gb(memory_bytes(m70, Method::QLora, b, 512).total()),
            fmt_gb(memory_bytes(m70, Method::Lst, b, 512).total()),
            fmt_gb(memory_bytes(m70, Method::Lora, b, 512).total()),
            fmt_gb(memory_bytes(m70, Method::Adapter, b, 512).total()),
            fmt_gb(memory_bytes(m70, Method::Qst, b, 512).total()),
        ]);
    }
    a.print();
    a.save("fig4a")?;

    let mut bt = Table::new(
        "Figure 4b — memory (GB) vs model size (OPT series, batch 4, seq 512)",
        &["model", "16-bit LoRA", "LST", "QLoRA", "QST"],
    );
    for name in ["OPT-1.3B", "OPT-2.7B", "OPT-6.7B", "OPT-13B", "OPT-30B", "OPT-66B"] {
        let m = paper_model(name).unwrap();
        bt.row(vec![
            name.into(),
            fmt_gb(memory_bytes(m, Method::Lora, 4, 512).total()),
            fmt_gb(memory_bytes(m, Method::Lst, 4, 512).total()),
            fmt_gb(memory_bytes(m, Method::QLora, 4, 512).total()),
            fmt_gb(memory_bytes(m, Method::Qst, 4, 512).total()),
        ]);
    }
    bt.print();
    bt.save("fig4b")?;

    let mut c = Table::new(
        "Figure 4c — memory (GB) vs sequence length (LLaMA-2-70B, batch 4)",
        &["seq", "QLoRA", "LST", "LoRA", "Adapter", "QST"],
    );
    for &s in &[128usize, 256, 512, 1024, 2048] {
        c.row(vec![
            s.to_string(),
            fmt_gb(memory_bytes(m70, Method::QLora, 4, s).total()),
            fmt_gb(memory_bytes(m70, Method::Lst, 4, s).total()),
            fmt_gb(memory_bytes(m70, Method::Lora, 4, s).total()),
            fmt_gb(memory_bytes(m70, Method::Adapter, 4, s).total()),
            fmt_gb(memory_bytes(m70, Method::Qst, 4, s).total()),
        ]);
    }
    c.print();
    c.save("fig4c")?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 3 — FLOPS per token (model; paper numbers alongside)
// ---------------------------------------------------------------------------
pub fn table3() -> Result<()> {
    let paper: &[(&str, [f64; 5])] = &[
        // (model, [QLoRA, LST, LoRA, Adapter, QST]) x 1e10 in the paper's units
        ("LLaMA-2-7B", [11.7, 11.0, 11.3, 11.2, 4.4]),
        ("LLaMA-2-13B", [16.0, 19.0, 15.6, 15.6, 6.1]),
        ("LLaMA-2-70B", [38.1, 80.7, 37.2, 27.2, 15.3]),
    ];
    let mut t = Table::new(
        "Table 3 — FLOPs/token (×1e10); 'ours' from the analytical model",
        &["model", "method", "paper", "ours", "ours/QST"],
    );
    for (name, nums) in paper {
        let m = paper_model(name).unwrap();
        let qst = flops_per_token(m, Method::Qst);
        for (meth, pval) in [Method::QLora, Method::Lst, Method::Lora, Method::Adapter, Method::Qst]
            .iter()
            .zip(nums)
        {
            let ours = flops_per_token(m, *meth);
            t.row(vec![
                name.to_string(),
                meth.name().into(),
                format!("{pval:.1}"),
                format!("{:.1}", ours / 1e10),
                format!("{:.2}x", ours / qst),
            ]);
        }
    }
    t.print();
    t.save("table3")?;
    println!("shape check: QST lowest everywhere (~2.5-3x); LST worst at 70B.");
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 1 — GLUE (proxy models; paper OPT-1.3B..6.7B)
// ---------------------------------------------------------------------------
pub fn table1(fast: bool) -> Result<()> {
    let mut rt = rt()?;
    let sets: &[(&str, &str, &[&str])] = &[
        ("tiny-opt", "OPT-1.3B", &["qst", "qlora", "lora", "adapter", "lst"]),
        ("small-opt", "OPT-2.7B", &["qst", "qlora"]),
        ("med-opt", "OPT-6.7B", &["qst", "qlora"]),
    ];
    let tasks: &[GlueTask] = if fast {
        &[GlueTask::Sst2, GlueTask::Mrpc]
    } else {
        &ALL_TASKS
    };
    let steps = if fast { 60 } else { 150 };
    let n_eval = if fast { 96 } else { 256 };

    let mut t = Table::new(
        "Table 1 — GLUE-like (proxy models; metric: accuracy / Pearson)",
        &["proxy (paper)", "method", "params%", "mem GB (model@paper dims)", "avg score", "tasks"],
    );
    for (cfg, paper_name, methods) in sets {
        let base = common::base_for(&mut rt, cfg, fast)?;
        let pm = paper_model(paper_name).unwrap();
        let backbone_params: usize = base.tensors.values().map(|v| v.numel()).sum();
        for method in *methods {
            let meth_enum = ALL_METHODS.iter().find(|m| m.key() == *method).copied().unwrap();
            let mut scores = vec![];
            let mut params_pct = 0.0;
            for task in tasks {
                let out = common::finetune_glue(&mut rt, cfg, method, *task, steps, &base, "")?;
                params_pct = out.trainable_params as f64 / backbone_params as f64 * 100.0;
                let score = common::eval_glue(&mut rt, cfg, method, *task, &out, n_eval)?;
                scores.push((task.name(), score));
                eprintln!("  [{cfg} {method} {}] score {:.3}", task.name(), score);
            }
            let avg = scores.iter().map(|(_, s)| s).sum::<f64>() / scores.len() as f64;
            let mem = memory_bytes(pm, meth_enum, 16, 512).total();
            t.row(vec![
                format!("{cfg} ({paper_name})"),
                method.to_string(),
                format!("{params_pct:.2}"),
                fmt_gb(mem),
                format!("{avg:.3}"),
                scores.iter().map(|(n, s)| format!("{n}:{s:.2}")).collect::<Vec<_>>().join(" "),
            ]);
        }
    }
    t.print();
    t.save("table1")?;
    println!("paper shape: QST within ~1-2 pts of QLoRA with ~2x less memory, ~5-10x fewer params.");
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 2 — MMLU-like accuracy/memory, QST vs QLoRA
// ---------------------------------------------------------------------------
pub fn table2(fast: bool) -> Result<()> {
    let mut rt = rt()?;
    let sets: &[(&str, &str)] = &[
        ("tiny-llama", "LLaMA-2-7B"),
        ("small-llama", "LLaMA-2-13B"),
        ("med-llama", "LLaMA-2-70B"),
    ];
    let steps = if fast { 60 } else { 200 };
    let n_items = if fast { 60 } else { 200 };
    let mut t = Table::new(
        "Table 2 — MMLU-like 5-shot (accuracy / memory-GB@paper-dims)",
        &["proxy (paper)", "QLoRA acc", "QST acc", "QLoRA GB", "QST GB", "paper (acc/mem)"],
    );
    let paper: &[(&str, &str)] = &[
        ("LLaMA-2-7B", "45.9/15.6 vs 45.1/7.3"),
        ("LLaMA-2-13B", "54.7/25.4 vs 56.8/12.6"),
        ("LLaMA-2-70B", "64.1/95.5 vs 63.9/56.0"),
    ];
    for ((cfg, paper_name), (_, pstr)) in sets.iter().zip(paper) {
        let base = common::base_for(&mut rt, cfg, fast)?;
        let pm = paper_model(paper_name).unwrap();
        let mut accs = std::collections::HashMap::new();
        for method in ["qlora", "qst"] {
            let out = common::finetune_mmlu(&mut rt, cfg, method, steps, &base, "")?;
            let acc = common::eval_mmlu(&mut rt, cfg, method, &out, n_items, "")?;
            eprintln!("  [{cfg} {method}] mmlu acc {acc:.3}");
            accs.insert(method, acc);
        }
        t.row(vec![
            format!("{cfg} ({paper_name})"),
            format!("{:.3}", accs["qlora"]),
            format!("{:.3}", accs["qst"]),
            fmt_gb(memory_bytes(pm, Method::QLora, 4, 384).total()),
            fmt_gb(memory_bytes(pm, Method::Qst, 4, 384).total()),
            pstr.to_string(),
        ]);
    }
    t.print();
    t.save("table2")?;
    println!("paper shape: QST ≈ QLoRA accuracy (±1-2 pts) at ~1.8x less memory.");
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig 1b — accuracy-vs-memory scatter (from table2-style runs, printed as rows)
// ---------------------------------------------------------------------------
pub fn fig1b(fast: bool) -> Result<()> {
    println!("Figure 1b reuses the Table 2 pipeline (accuracy vs memory scatter):");
    table2(fast)
}

// ---------------------------------------------------------------------------
// Table 4 — NF4 vs FP4
// ---------------------------------------------------------------------------
pub fn table4(fast: bool) -> Result<()> {
    let mut rt = rt()?;
    let cfg = "tiny-llama";
    let base = common::base_for(&mut rt, cfg, fast)?;
    let steps = if fast { 60 } else { 200 };
    let n_items = if fast { 80 } else { 200 };

    // quantization-error side experiment (the Table 4 mechanism)
    let some_w = base.tensors.iter().find(|(k, v)| k.contains("attn.wq") && v.shape.len() == 2).unwrap();
    let w = some_w.1.as_f32()?;
    let (k, n) = (some_w.1.shape[0], some_w.1.shape[1]);
    let mse = |dt: &str| {
        let (p, s) = crate::quant::quantize_matrix_raw(&w, k, n, dt, 64);
        let back = crate::quant::dequantize_matrix_raw(&p, &s, k, n, dt, 64);
        w.iter().zip(&back).map(|(a, b)| (a - b).powi(2)).sum::<f32>() / w.len() as f32
    };
    println!("weight quantization MSE: nf4 {:.3e}  fp4 {:.3e}", mse("nf4"), mse("fp4"));

    let mut t = Table::new(
        "Table 4 — 4-bit data types (proxy MMLU-like acc; paper avg: NF4 55.3 vs FP4 54.5)",
        &["dtype", "accuracy"],
    );
    for (variant, label) in [("", "nf4"), ("__fp4", "fp4")] {
        let out = common::finetune_mmlu(&mut rt, cfg, "qst", steps, &base, variant)?;
        let acc = common::eval_mmlu(&mut rt, cfg, "qst", &out, n_items, variant)?;
        t.row(vec![label.into(), format!("{acc:.3}")]);
    }
    t.print();
    t.save("table4")?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 5 — FP16 stability: QLoRA diverges, QST doesn't
// ---------------------------------------------------------------------------
pub fn table5(fast: bool) -> Result<()> {
    let mut rt = rt()?;
    let cfg = "tiny-opt";
    let base = common::base_for(&mut rt, cfg, fast)?;
    let steps = if fast { 40 } else { 120 };
    let mut t = Table::new(
        "Table 5 — FP16 compute: divergence across seeds (paper: QLoRA fails MRPC/QNLI 2/3 seeds)",
        &["method", "task", "diverged seeds", "final loss (finite seeds)"],
    );
    for method in ["qlora", "qst"] {
        for task in [GlueTask::Mrpc, GlueTask::Qnli] {
            let mut diverged = 0;
            let mut losses = vec![];
            for seed in 0..3u32 {
                // fp16 variant uses a hot LR to mirror the paper's half-precision
                // fragility at scale (outlier activations -> overflow)
                let init = format!("{cfg}__{method}__init");
                let train = format!("{cfg}__{method}__cls__train__fp16");
                let art = rt.load(&train)?;
                let (b, s) = art.manifest.batch.unwrap();
                let vocab = Vocab::new(art.manifest.cfg.usize("vocab"));
                let frozen = crate::coordinator::pipeline::frozen_from_checkpoint(&art.manifest, &base)?;
                let mut gen = crate::data::glue::GlueGen::new(task, vocab, s, 50 + seed as u64);
                let mut tcfg = crate::coordinator::TrainConfig::quick(steps, 3e-2);
                tcfg.seed = seed;
                let out = common::run_finetune(&mut rt, &init, &train, frozen, tcfg, move |_| {
                    crate::data::batcher::cls_batch(&gen.examples(b), s)
                })?;
                if out.diverged || !out.final_loss.is_finite() {
                    diverged += 1;
                } else {
                    losses.push(out.final_loss);
                }
            }
            let loss_str = if losses.is_empty() {
                "-".into()
            } else {
                format!("{:.3}", losses.iter().sum::<f32>() / losses.len() as f32)
            };
            t.row(vec![method.into(), task.name().into(), format!("{diverged}/3"), loss_str]);
        }
    }
    t.print();
    t.save("table5")?;
    println!("paper shape: QLoRA-fp16 unstable (gradients through the full 4-bit backbone);");
    println!("QST-fp16 stable (gradients confined to the small side network).");
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 6 — downsample-module ablation
// ---------------------------------------------------------------------------
pub fn table6(fast: bool) -> Result<()> {
    let mut rt = rt()?;
    let cfg = "tiny-llama";
    let base = common::base_for(&mut rt, cfg, fast)?;
    let steps = if fast { 60 } else { 200 };
    let n_items = if fast { 80 } else { 200 };
    let paper: &[(&str, &str)] = &[
        ("linear", "0.85% / 44.9"),
        ("lora", "0.41% / 44.7"),
        ("adapter", "0.41% / 45.1"),
        ("maxpool", "0.38% / 43.7"),
        ("avgpool", "0.38% / 42.5"),
    ];
    let mut t = Table::new(
        "Table 6 — downsample modules (params% / proxy accuracy; paper values alongside)",
        &["module", "params%", "down-ratio%", "accuracy", "paper (params%/acc)"],
    );
    let backbone_params: usize = base.tensors.values().map(|v| v.numel()).sum();
    for (ds, pstr) in paper {
        let variant = if *ds == "adapter" { String::new() } else { format!("__ds_{ds}") };
        let out = common::finetune_mmlu(&mut rt, cfg, "qst", steps, &base, &variant)?;
        let acc = common::eval_mmlu(&mut rt, cfg, "qst", &out, n_items, &variant)?;
        let down: usize = out
            .trainable
            .iter()
            .filter(|(k, _)| k.starts_with("g.down."))
            .map(|(_, v)| v.numel())
            .sum();
        t.row(vec![
            ds.to_string(),
            format!("{:.2}", out.trainable_params as f64 / backbone_params as f64 * 100.0),
            format!("{:.1}", down as f64 / out.trainable_params as f64 * 100.0),
            format!("{acc:.3}"),
            pstr.to_string(),
        ]);
    }
    t.print();
    t.save("table6")?;
    println!("paper shape: linear has ~56% of trainables in downsamplers; pooling 0%; adapter best acc.");
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 7 + Fig 6 — chatbot SFT: training time, memory, per-category scores
// ---------------------------------------------------------------------------
fn chatbot_runs(fast: bool) -> Result<(FinetuneOutcome, FinetuneOutcome, f64, f64, Runtime)> {
    let mut rt = rt()?;
    let cfg = "small-llama";
    let base = common::base_for(&mut rt, cfg, fast)?;
    let steps = if fast { 60 } else { 200 };
    // SFT on mixed-category instruction data
    let mut run = |method: &str| -> Result<(FinetuneOutcome, f64)> {
        let init = format!("{cfg}__{method}__init");
        let train = format!("{cfg}__{method}__lm__train");
        let art = rt.load(&train)?;
        let (b, s) = art.manifest.batch.unwrap();
        let vocab = Vocab::new(art.manifest.cfg.usize("vocab"));
        let frozen = crate::coordinator::pipeline::frozen_from_checkpoint(&art.manifest, &base)?;
        let mut gen = InstructGen::new(vocab, 4242);
        let tcfg = crate::coordinator::TrainConfig::quick(steps, 2e-3);
        let (out, secs) = {
            let t0 = std::time::Instant::now();
            let o = common::run_finetune(&mut rt, &init, &train, frozen, tcfg, move |_| {
                let exs: Vec<LmExample> = (0..b)
                    .map(|_| {
                        let (t, tg, m) = gen.sft_mixed(s);
                        LmExample { tokens: t, targets: tg, mask: m }
                    })
                    .collect();
                lm_batch(&exs, s)
            })?;
            (o, t0.elapsed().as_secs_f64())
        };
        Ok((out, secs))
    };
    let (qlora, t_qlora) = run("qlora")?;
    let (qst, t_qst) = run("qst")?;
    Ok((qlora, qst, t_qlora, t_qst, rt))
}

/// Per-category NLL -> MT-Bench-like score proxy: 10·exp(nll_floor − nll).
fn category_scores(
    rt: &mut Runtime,
    cfg: &str,
    method: &str,
    out: &FinetuneOutcome,
    fast: bool,
) -> Result<Vec<(&'static str, f64)>> {
    let eval_name = format!("{cfg}__{method}__lm__eval");
    let art = rt.load(&eval_name)?;
    let (b, s) = art.manifest.batch.unwrap();
    let vocab = Vocab::new(art.manifest.cfg.usize("vocab"));
    let n_batches = if fast { 3 } else { 8 };
    let mut scores = vec![];
    for cat in CATEGORIES {
        let mut gen = InstructGen::new(vocab.clone(), 777_000 + cat as u64);
        let batches: Vec<_> = (0..n_batches)
            .map(|_| {
                let exs: Vec<LmExample> = (0..b)
                    .map(|_| {
                        let (t, tg, m) = gen.sft_example(cat, s);
                        LmExample { tokens: t, targets: tg, mask: m }
                    })
                    .collect();
                lm_batch(&exs, s)
            })
            .collect();
        let nll = common::eval_lm_loss(rt, &eval_name, out, &batches)?;
        scores.push((cat.name(), 10.0 * (-nll).exp().min(1.0)));
    }
    Ok(scores)
}

pub fn table7(fast: bool) -> Result<()> {
    let (qlora, qst, t_qlora, t_qst, mut rt) = chatbot_runs(fast)?;
    let cfg = "small-llama";
    let pm = paper_model("LLaMA-2-70B").unwrap();
    let avg = |scores: &[(&str, f64)]| scores.iter().map(|(_, s)| s).sum::<f64>() / scores.len() as f64;
    let s_qlora = category_scores(&mut rt, cfg, "qlora", &qlora, fast)?;
    let s_qst = category_scores(&mut rt, cfg, "qst", &qst, fast)?;
    let mut t = Table::new(
        "Table 7 — chatbot SFT (paper: QLoRA ~80h/96.3GB/6.61 vs QST ~25h/56.1GB/7.07)",
        &["method", "train secs (proxy)", "mem GB (model@70B)", "avg score proxy"],
    );
    t.row(vec![
        "QLoRA".into(),
        format!("{t_qlora:.1}"),
        fmt_gb(memory_bytes(pm, Method::QLora, 16, 384).total()),
        format!("{:.2}", avg(&s_qlora)),
    ]);
    t.row(vec![
        "QST".into(),
        format!("{t_qst:.1}"),
        fmt_gb(memory_bytes(pm, Method::Qst, 16, 384).total()),
        format!("{:.2}", avg(&s_qst)),
    ]);
    t.print();
    t.save("table7")?;
    println!("speedup (train time): {:.2}x (paper 3.2x)", t_qlora / t_qst);

    // LST repetition pathology probe (paper §3.2's qualitative claim)
    let gen_name = format!("{cfg}__qst__generate");
    if let Ok(g) = crate::coordinator::evaluator::Generator::new(&mut rt, &gen_name) {
        let vocab = Vocab::new(rt.load(&gen_name)?.manifest.cfg.usize("vocab"));
        let mut ig = InstructGen::new(vocab, 31);
        let (prompt, _) = ig.pair(crate::data::instruct::Category::Writing);
        let toks = g.greedy(&qst.trainable, &qst.frozen, &prompt, 24)?;
        println!("QST greedy sample repetition rate: {:.2}", repetition_rate(&toks));
    }
    Ok(())
}

pub fn fig6(fast: bool) -> Result<()> {
    let (qlora, qst, _, _, mut rt) = chatbot_runs(fast)?;
    let cfg = "small-llama";
    let s_qlora = category_scores(&mut rt, cfg, "qlora", &qlora, fast)?;
    let s_qst = category_scores(&mut rt, cfg, "qst", &qst, fast)?;
    let mut t = Table::new(
        "Figure 6 — per-category score proxies (paper: QST wins STEM/Extraction/Coding/Roleplay)",
        &["category", "QLoRA", "QST"],
    );
    for ((cat, a), (_, b)) in s_qlora.iter().zip(&s_qst) {
        t.row(vec![cat.to_string(), format!("{a:.2}"), format!("{b:.2}")]);
    }
    t.print();
    t.save("fig6")?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig 5 — reduction factor r: accuracy / memory / FLOPs
// ---------------------------------------------------------------------------
pub fn fig5(fast: bool) -> Result<()> {
    let mut rt = rt()?;
    let cfg = "tiny-llama";
    let base = common::base_for(&mut rt, cfg, fast)?;
    let steps = if fast { 50 } else { 150 };
    let n_items = if fast { 60 } else { 150 };
    let m7 = paper_model("LLaMA-2-7B").unwrap();
    let mut t = Table::new(
        "Figure 5 — reduction factor r (proxy acc; memory/FLOPs at LLaMA-2-7B dims)",
        &["r", "accuracy", "memory GB", "FLOPs/token x1e10"],
    );
    for r in [2usize, 4, 8, 16, 32] {
        let variant = if r == 8 { String::new() } else { format!("__r{r}") };
        let out = common::finetune_mmlu(&mut rt, cfg, "qst", steps, &base, &variant)?;
        let acc = common::eval_mmlu(&mut rt, cfg, "qst", &out, n_items, &variant)?;
        let mem = memory_bytes_r(m7, Method::Qst, 4, 384, r).total();
        let fl = crate::costmodel::flops::flops_per_token_r(m7, Method::Qst, r);
        t.row(vec![
            r.to_string(),
            format!("{acc:.3}"),
            fmt_gb(mem),
            format!("{:.1}", fl / 1e10),
        ]);
    }
    t.print();
    t.save("fig5")?;
    println!("paper shape: memory/FLOPs fall steeply to r=16 then flatten; accuracy varies mildly.");
    Ok(())
}

// ---------------------------------------------------------------------------
// Calibration: measured proxy runs vs the analytical models
// ---------------------------------------------------------------------------
pub fn calibrate() -> Result<()> {
    let mut rt = rt()?;
    let cfg = "tiny-llama";
    let base = common::base_for(&mut rt, cfg, true)?;
    let mut t = Table::new(
        "Calibration — measured proxy step time & RSS vs analytical ratios",
        &["method", "median step ms", "meas. step ratio vs QST", "model FLOPs ratio", "peak RSS"],
    );
    let mut rows = vec![];
    for method in ["qst", "qlora"] {
        let out = common::finetune_mmlu(&mut rt, cfg, method, 12, &base, "")?;
        rows.push((method.to_string(), out.median_step_secs));
    }
    let qst_secs = rows.iter().find(|(m, _)| m == "qst").unwrap().1;
    let m7 = paper_model("LLaMA-2-7B").unwrap();
    let fl_ratio = flops_per_token(m7, Method::QLora) / flops_per_token(m7, Method::Qst);
    for (method, secs) in &rows {
        t.row(vec![
            method.clone(),
            format!("{:.1}", secs * 1e3),
            format!("{:.2}x", secs / qst_secs),
            if method == "qlora" { format!("{fl_ratio:.2}x") } else { "1.00x".into() },
            human_bytes(peak_rss_bytes() as f64),
        ]);
    }
    t.print();
    t.save("calib")?;
    let (_, wall) = timed(|| ());
    let _ = wall;
    Ok(())
}
