//! Table printer: aligned paper-vs-measured rows + result persistence.

use std::fmt::Write as _;

pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "\n## {}", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("| ");
            for i in 0..ncols {
                let _ = write!(s, "{:<w$} | ", cells[i], w = widths[i]);
            }
            s.trim_end().to_string()
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{}|", "-".repeat(w + 2));
        }
        let _ = writeln!(out, "{sep}");
        for r in &self.rows {
            let _ = writeln!(out, "{}", line(r, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// Persist the rendered table under runs/results/<name>.md.
    pub fn save(&self, name: &str) -> std::io::Result<()> {
        let dir = crate::runs_dir().join("results");
        std::fs::create_dir_all(&dir)?;
        std::fs::write(dir.join(format!("{name}.md")), self.render())
    }
}

pub fn fmt_gb(bytes: f64) -> String {
    format!("{:.1}", bytes / 1e9)
}

pub fn fmt_pct(x: f64) -> String {
    format!("{:.1}", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["a", "long-header", "c"]);
        t.row(vec!["1".into(), "2".into(), "3".into()]);
        t.row(vec!["xxxx".into(), "y".into(), "z".into()]);
        let s = t.render();
        assert!(s.contains("## demo"));
        assert!(s.contains("| long-header |"));
        let lines: Vec<&str> = s.lines().filter(|l| l.starts_with('|')).collect();
        assert_eq!(lines.len(), 4);
        // all rows same width
        assert_eq!(lines[0].len(), lines[2].len());
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
