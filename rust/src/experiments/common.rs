//! Shared experiment plumbing: pretrain-or-load base checkpoints, run one
//! finetune+eval cycle for a (config, method, task) triple.

use std::collections::HashMap;

use anyhow::{Context, Result};

use crate::coordinator::evaluator::{ClsEval, Generator, LmEval};
use crate::coordinator::pipeline::{ensure_base, frozen_from_checkpoint};
use crate::coordinator::{Checkpoint, LrSchedule, TrainConfig};
use crate::data::batcher::{cls_batch, lm_batch, LmExample};
use crate::data::glue::{GlueGen, GlueTask};
use crate::data::mmlu::MmluGen;
use crate::data::{Batch, Vocab};
use crate::runtime::Runtime;
use crate::tensor::HostTensor;

/// Default pretraining budget per config (steps, lr).  Tuned so each base
/// reaches a clearly-sub-random LM loss on the single-core testbed.
pub fn pretrain_budget(cfg: &str, fast: bool) -> (usize, f32) {
    let steps = match cfg {
        c if c.starts_with("nano") => 60,
        c if c.starts_with("tiny") => 300,
        c if c.starts_with("small") => 250,
        c if c.starts_with("med") => 200,
        _ => 200,
    };
    (if fast { steps / 4 } else { steps }, 3e-3)
}

pub struct FinetuneOutcome {
    pub trainable: HashMap<String, HostTensor>,
    pub frozen: HashMap<String, HostTensor>,
    pub final_loss: f32,
    pub median_step_secs: f64,
    pub trainable_params: usize,
    pub diverged: bool,
    pub wall_secs: f64,
}

/// Finetune `method` on a GLUE-like task; returns state for evaluation.
pub fn finetune_glue(
    rt: &mut Runtime,
    cfg: &str,
    method: &str,
    task: GlueTask,
    steps: usize,
    base: &Checkpoint,
    variant: &str,
) -> Result<FinetuneOutcome> {
    let init = format!("{cfg}__{method}__init");
    let train = format!("{cfg}__{method}__cls__train{variant}");
    let art = rt.load(&train)?;
    let (b, s) = art.manifest.batch.context("batch dims")?;
    let vocab = Vocab::new(art.manifest.cfg.usize("vocab"));
    let frozen = frozen_from_checkpoint(&art.manifest, base)?;
    let mut gen = GlueGen::new(task, vocab, s, 1234);
    let mut tcfg = TrainConfig::quick(steps, 2e-3);
    tcfg.schedule = LrSchedule::paper_glue(steps);
    tcfg.schedule.base_lr = 2e-3; // proxy-scale LR (paper's 2e-4 is for B-scale)
    run_finetune(rt, &init, &train, frozen, tcfg, move |_| cls_batch(&gen.examples(b), s))
}

/// Finetune on MMLU-style instruction data (lm task).
pub fn finetune_mmlu(
    rt: &mut Runtime,
    cfg: &str,
    method: &str,
    steps: usize,
    base: &Checkpoint,
    variant: &str,
) -> Result<FinetuneOutcome> {
    let init = format!("{cfg}__{method}__init{variant}");
    let init = if rt.load(&init).is_ok() { init } else { format!("{cfg}__{method}__init") };
    let train = format!("{cfg}__{method}__lm__train{variant}");
    let art = rt.load(&train)?;
    let (b, s) = art.manifest.batch.context("batch dims")?;
    let vocab = Vocab::new(art.manifest.cfg.usize("vocab"));
    let frozen = frozen_from_checkpoint(&art.manifest, base)?;
    let mut gen = MmluGen::new(vocab, s, 77);
    let tcfg = TrainConfig::quick(steps, 2e-3);
    run_finetune(rt, &init, &train, frozen, tcfg, move |_| {
        let exs: Vec<LmExample> = (0..b)
            .map(|_| {
                let (t, tg, m) = gen.finetune_example(s);
                LmExample { tokens: t, targets: tg, mask: m }
            })
            .collect();
        lm_batch(&exs, s)
    })
}

pub fn run_finetune(
    rt: &mut Runtime,
    init: &str,
    train: &str,
    frozen: HashMap<String, HostTensor>,
    tcfg: TrainConfig,
    next_batch: impl FnMut(usize) -> Batch,
) -> Result<FinetuneOutcome> {
    let mut trainer = crate::coordinator::Trainer::new(rt, init, train, &frozen, tcfg.seed)?;
    let report = trainer.run(rt, &tcfg, next_batch)?;
    let trainable_params: usize = report.trainable.values().map(|t| t.numel()).sum();
    Ok(FinetuneOutcome {
        final_loss: report.metrics.mean_loss_tail(10),
        median_step_secs: report.metrics.median_step_secs(),
        diverged: report.metrics.diverged(),
        wall_secs: report.wall_secs,
        trainable: report.trainable,
        frozen,
        trainable_params,
    })
}

/// GLUE accuracy of a finetuned state.
pub fn eval_glue(
    rt: &mut Runtime,
    cfg: &str,
    method: &str,
    task: GlueTask,
    out: &FinetuneOutcome,
    n_eval: usize,
) -> Result<f64> {
    let eval = ClsEval::new(rt, &format!("{cfg}__{method}__cls__eval"))?;
    let art = rt.load(&format!("{cfg}__{method}__cls__eval"))?;
    let vocab = Vocab::new(art.manifest.cfg.usize("vocab"));
    let label_tokens: Vec<i32> = (0..task.n_classes()).map(|k| vocab.label(k)).collect();
    let mut gen = GlueGen::new(task, vocab, eval.batch.1, 999_999); // held-out seed
    let res = eval.evaluate(&out.trainable, &out.frozen, &gen.examples(n_eval), &label_tokens)?;
    Ok(if task.is_regression() { res.pearson } else { res.accuracy })
}

/// MMLU 5-shot accuracy of a finetuned state.
pub fn eval_mmlu(
    rt: &mut Runtime,
    cfg: &str,
    method: &str,
    out: &FinetuneOutcome,
    n_items: usize,
    variant: &str,
) -> Result<f64> {
    let name = format!("{cfg}__{method}__generate{variant}");
    let name = if rt.load(&name).is_ok() { name } else { format!("{cfg}__{method}__generate") };
    let g = Generator::new(rt, &name)?;
    let art = rt.load(&name)?;
    let vocab = Vocab::new(art.manifest.cfg.usize("vocab"));
    let mut gen = MmluGen::new(vocab, g.seq, 31_337);
    let items: Vec<_> = (0..n_items).map(|_| gen.item(5, true)).collect();
    g.mmlu_accuracy(&out.trainable, &out.frozen, &items)
}

/// Held-out LM loss (NLL proxy scores for the chatbot experiment).
pub fn eval_lm_loss(
    rt: &mut Runtime,
    eval_name: &str,
    out: &FinetuneOutcome,
    batches: &[Batch],
) -> Result<f64> {
    let ev = LmEval::new(rt, eval_name)?;
    ev.avg_loss(&out.trainable, &out.frozen, batches)
}

/// Pretrain-or-load the base for `cfg` with the default budget.
pub fn base_for(rt: &mut Runtime, cfg: &str, fast: bool) -> Result<Checkpoint> {
    let (steps, lr) = pretrain_budget(cfg, fast);
    ensure_base(rt, cfg, steps, lr, true)
}
