//! Experiment harness: one regenerator per paper table/figure.
//! `qst experiments --id <id>` prints the paper's numbers next to ours and
//! appends machine-readable results under `runs/results/`.

pub mod common;
pub mod report;
pub mod tables;

use anyhow::{bail, Result};

pub fn run(id: &str, fast: bool) -> Result<()> {
    match id {
        "table1" => tables::table1(fast),
        "table2" => tables::table2(fast),
        "table3" => tables::table3(),
        "table4" => tables::table4(fast),
        "table5" => tables::table5(fast),
        "table6" => tables::table6(fast),
        "table7" => tables::table7(fast),
        "fig1a" => tables::fig1a(),
        "fig1b" => tables::fig1b(fast),
        "fig4" => tables::fig4(),
        "fig5" => tables::fig5(fast),
        "fig6" => tables::fig6(fast),
        "calib" => tables::calibrate(),
        "all" => {
            for id in [
                "fig1a", "fig4", "table3", "calib", "table1", "table2", "fig1b",
                "table4", "table5", "table6", "fig5", "table7", "fig6",
            ] {
                println!("\n════════════════════════ {id} ════════════════════════");
                run(id, fast)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment id '{other}' (see --help)"),
    }
}
