//! Serving telemetry: request/token throughput, batch shapes, and two
//! latency distributions — total (queue + service) and the queue-wait
//! component alone, so a scheduler change (e.g. continuous slot admission
//! vs. waved drains) is visible as a queue-time shift rather than buried
//! in the total.  The [`Json`] writer `bench-serve` uses to persist
//! `BENCH_serve.json` lives in [`crate::benchkit`] (it's a generic
//! substrate, also used by `bench-kernels`) and is re-exported here for
//! the serve-side callers.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::obs::LogHistogram;

pub use crate::benchkit::Json;

/// Per-task accounting: one row per task id, keyed and merged by name.
/// The tenancy counterpart of the fleet counters — at thousand-task
/// scale "who is using the fleet" needs attribution, not just totals.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TaskStat {
    pub task: String,
    pub requests: u64,
    pub tokens: u64,
    /// whole-prompt hidden-state cache hits attributed to this task
    pub cache_hits: u64,
    /// registry cold loads (initial registration + post-eviction
    /// reloads) triggered by this task's batches
    pub swap_ins: u64,
}

impl TaskStat {
    fn absorb(&mut self, other: &TaskStat) {
        self.requests += other.requests;
        self.tokens += other.tokens;
        self.cache_hits += other.cache_hits;
        self.swap_ins += other.swap_ins;
    }
}

/// Cap on retained latency samples; at the cap the reservoir is decimated
/// (every 2nd sample kept) so memory stays bounded and the distribution
/// stays representative for long-running servers.
const LAT_CAP: usize = 65_536;

/// A stride-decimated, lazily-sorted sample reservoir: bounded memory
/// ([`LAT_CAP`]), each retained sample standing for `stride` recorded
/// ones.  Used once for total latency and once for queue wait.
struct Reservoir {
    v: Vec<f64>,
    /// whether `v` has unsorted appends since the last percentile read
    dirty: bool,
    /// decimation factor (a power of two, ≥ 1)
    stride: u64,
    skip: u64,
}

impl Reservoir {
    fn new() -> Self {
        Reservoir { v: Vec::new(), dirty: false, stride: 1, skip: 0 }
    }

    fn push(&mut self, sample: f64) {
        self.skip += 1;
        if self.skip < self.stride {
            return;
        }
        self.skip = 0;
        if self.v.len() >= LAT_CAP {
            // decimation keeps every 2nd retained sample; `v` may be in
            // sorted order here, which thins the distribution evenly
            let mut keep = false;
            self.v.retain(|_| {
                keep = !keep;
                keep
            });
            self.stride *= 2;
        }
        self.v.push(sample);
        self.dirty = true;
    }

    /// The reservoir in sorted order, re-sorting in place only when new
    /// samples arrived since the last read — `summary()` reads percentiles
    /// per request line in interactive serving, so this must not
    /// clone-and-sort 64Ki samples per call.
    fn sorted(&mut self) -> &[f64] {
        if self.dirty {
            self.v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.dirty = false;
        }
        &self.v
    }

    /// Nearest-rank percentile, in the samples' own unit.
    fn pct(&mut self, p: f64) -> f64 {
        nearest_rank(self.sorted(), p)
    }
}

/// Nearest-rank percentile of an already-sorted slice (0.0 when empty).
fn nearest_rank(v: &[f64], p: f64) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let rank = ((p / 100.0) * v.len() as f64).ceil() as usize;
    v[rank.clamp(1, v.len()) - 1]
}

pub struct ServeStats {
    started: Instant,
    pub requests: u64,
    pub batches: u64,
    pub tokens: u64,
    /// requests dropped by failing micro-batches (see `Server::drain`)
    pub dropped: u64,
    /// cache misses served by resuming from a cached prefix instead of a
    /// full frozen forward (see `Server::process_batch`)
    pub prefix_resumes: u64,
    /// seconds spent actually processing batches — the throughput
    /// denominator, so idle time (waiting on stdin/transport) between
    /// requests doesn't dilute req/s
    pub busy_secs: f64,
    /// every request latency, log-bucketed — unlike the reservoirs this is
    /// never decimated, and merges exactly across shards (see
    /// [`crate::obs::hist`])
    hist: LogHistogram,
    /// total request latencies in seconds (queue + service)
    lat: Reservoir,
    /// queue-wait component alone: enqueue → micro-batch execution start
    queue: Reservoir,
    /// per-task accounting, keyed by task id (BTreeMap so snapshots list
    /// tasks in a stable name order)
    tasks: BTreeMap<String, TaskStat>,
}

impl Default for ServeStats {
    fn default() -> Self {
        Self::new()
    }
}

impl ServeStats {
    pub fn new() -> Self {
        ServeStats {
            started: Instant::now(),
            requests: 0,
            batches: 0,
            tokens: 0,
            dropped: 0,
            prefix_resumes: 0,
            busy_secs: 0.0,
            hist: LogHistogram::new(),
            lat: Reservoir::new(),
            queue: Reservoir::new(),
            tasks: BTreeMap::new(),
        }
    }

    /// Record one completed micro-batch of `n` requests covering `tokens`
    /// prompt tokens, processed in `batch_secs`, with per-request total
    /// latencies and per-request queue waits (enqueue → execution start).
    /// The two slices are parallel; an empty `queue_secs` records no
    /// queue-wait samples (callers that cannot split still get totals).
    pub fn record_batch(
        &mut self,
        n: usize,
        tokens: usize,
        batch_secs: f64,
        latencies_secs: &[f64],
        queue_secs: &[f64],
    ) {
        self.batches += 1;
        self.requests += n as u64;
        self.tokens += tokens as u64;
        self.busy_secs += batch_secs.max(0.0);
        for &l in latencies_secs {
            self.hist.record(l);
            self.lat.push(l);
        }
        for &q in queue_secs {
            self.queue.push(q);
        }
    }

    /// Attribute one micro-batch to its task: `n` requests covering
    /// `tokens` prompt tokens, of which `cache_hits` were whole-prompt
    /// cache hits and `swap_ins` registry cold loads were triggered.
    pub fn record_task(&mut self, task: &str, n: u64, tokens: u64, cache_hits: u64, swap_ins: u64) {
        let e = self.tasks.entry(task.to_string()).or_insert_with(|| TaskStat {
            task: task.to_string(),
            ..Default::default()
        });
        e.requests += n;
        e.tokens += tokens;
        e.cache_hits += cache_hits;
        e.swap_ins += swap_ins;
    }

    /// Wall time since the server came up (includes idle).
    pub fn elapsed_secs(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Serving throughput over *busy* time — an interactive session with
    /// long idle gaps between requests still reports real speed.
    pub fn requests_per_sec(&self) -> f64 {
        self.requests as f64 / self.busy_secs.max(1e-9)
    }

    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens as f64 / self.busy_secs.max(1e-9)
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }

    /// Nearest-rank percentile of recorded total latencies, in seconds.
    pub fn latency_pct(&mut self, p: f64) -> f64 {
        self.lat.pct(p)
    }

    pub fn p50_secs(&mut self) -> f64 {
        self.latency_pct(50.0)
    }

    pub fn p95_secs(&mut self) -> f64 {
        self.latency_pct(95.0)
    }

    /// Nearest-rank percentile of recorded queue waits, in seconds.
    pub fn queue_pct(&mut self, p: f64) -> f64 {
        self.queue.pct(p)
    }

    pub fn queue_p95_secs(&mut self) -> f64 {
        self.queue_pct(95.0)
    }

    /// Counters + the latency reservoirs, detached from the live server —
    /// what a gateway shard ships to the aggregator.  Snapshots from many
    /// shards [`StatsSnapshot::merge`] into fleet-wide percentiles.
    pub fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            requests: self.requests,
            batches: self.batches,
            tokens: self.tokens,
            dropped: self.dropped,
            prefix_resumes: self.prefix_resumes,
            busy_secs: self.busy_secs,
            lat: self.lat.v.clone(),
            lat_stride: self.lat.stride,
            qlat: self.queue.v.clone(),
            qlat_stride: self.queue.stride,
            hist: self.hist.clone(),
            tasks: self.tasks.values().cloned().collect(),
        }
    }

    /// One-line human summary for the CLI.
    pub fn summary(&mut self, cache_hit_rate: f64) -> String {
        let dropped = if self.dropped > 0 { format!(" | {} dropped", self.dropped) } else { String::new() };
        let p50_ms = self.p50_secs() * 1e3;
        let p95_ms = self.p95_secs() * 1e3;
        let q95_ms = self.queue_p95_secs() * 1e3;
        format!(
            "{} req in {} batches ({:.1} req/batch) | {:.1} req/s, {:.0} tok/s | p50 {p50_ms:.2} ms, p95 {p95_ms:.2} ms (queue p95 {q95_ms:.2} ms) | cache hit rate {:.1}%{dropped}",
            self.requests,
            self.batches,
            self.mean_batch_size(),
            self.requests_per_sec(),
            self.tokens_per_sec(),
            cache_hit_rate * 100.0
        )
    }
}

/// A detached, mergeable view of [`ServeStats`]: plain counters, the
/// (decimated) latency reservoirs tagged with their decimation strides,
/// and the exact [`LogHistogram`].  Gateway shards run their own servers
/// on their own threads; each ships a snapshot and the aggregator merges
/// them into fleet-wide throughput and percentiles.  [`merge`] weighs
/// reservoirs by stride so a lightly-loaded shard cannot outvote a
/// heavily-loaded one, and the histogram merge is *exact* — fleet
/// percentiles from it match one histogram fed every raw sample.
///
/// [`merge`]: StatsSnapshot::merge
#[derive(Clone, Debug, PartialEq)]
pub struct StatsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub tokens: u64,
    pub dropped: u64,
    pub prefix_resumes: u64,
    /// summed busy seconds across shards — divide by shard count for the
    /// mean per-shard busy time; wall-clock throughput needs the caller's
    /// own clock (shards overlap in time)
    pub busy_secs: f64,
    /// merged total-latency samples in seconds (unsorted)
    pub lat: Vec<f64>,
    /// decimation factor of `lat`: each retained sample stands for this
    /// many requests (a power of two, ≥ 1)
    pub lat_stride: u64,
    /// merged queue-wait samples in seconds (unsorted) — the
    /// pre-execution component of `lat`
    pub qlat: Vec<f64>,
    /// decimation factor of `qlat` (a power of two, ≥ 1)
    pub qlat_stride: u64,
    /// every request latency, log-bucketed; merges exactly
    pub hist: LogHistogram,
    /// per-task accounting rows, in stable task-name order; merges by
    /// name with counters summing (wire tail — absent ⇒ empty)
    pub tasks: Vec<TaskStat>,
}

impl Default for StatsSnapshot {
    /// The empty snapshot; strides are 1 (each sample stands for
    /// itself), matching what [`ServeStats::snapshot`] ships.
    fn default() -> Self {
        StatsSnapshot {
            requests: 0,
            batches: 0,
            tokens: 0,
            dropped: 0,
            prefix_resumes: 0,
            busy_secs: 0.0,
            lat: Vec::new(),
            lat_stride: 1,
            qlat: Vec::new(),
            qlat_stride: 1,
            hist: LogHistogram::new(),
            tasks: Vec::new(),
        }
    }
}

/// Keep every `k`-th sample of `v` in place (`k == 1` keeps all).
fn decimate(v: &mut Vec<f64>, k: u64) {
    if k <= 1 {
        return;
    }
    let mut i = 0u64;
    v.retain(|_| {
        let keep = i % k == 0;
        i += 1;
        keep
    });
}

/// Count-weighted merge of two stride-tagged reservoirs: the finer-strided
/// side is decimated down to the coarser stride before concatenating
/// (strides are powers of two, so the ratio is integral).  Plain
/// concatenation let a stride-1 shard outvote a stride-8 shard
/// eight-to-one per request in the fleet percentile.
fn merge_reservoir(mine: &mut Vec<f64>, my_stride: &mut u64, theirs: &[f64], their_stride: u64) {
    let target = my_stride.max(1).max(their_stride.max(1));
    decimate(mine, target / (*my_stride).max(1));
    let mut other = theirs.to_vec();
    decimate(&mut other, target / their_stride.max(1));
    mine.append(&mut other);
    *my_stride = target;
}

impl StatsSnapshot {
    pub fn merge(&mut self, other: &StatsSnapshot) {
        self.requests += other.requests;
        self.batches += other.batches;
        self.tokens += other.tokens;
        self.dropped += other.dropped;
        self.prefix_resumes += other.prefix_resumes;
        self.busy_secs += other.busy_secs;
        self.hist.merge(&other.hist);
        let mut stride = self.lat_stride;
        merge_reservoir(&mut self.lat, &mut stride, &other.lat, other.lat_stride);
        self.lat_stride = stride;
        let mut qstride = self.qlat_stride;
        merge_reservoir(&mut self.qlat, &mut qstride, &other.qlat, other.qlat_stride);
        self.qlat_stride = qstride;
        if !other.tasks.is_empty() {
            let mut by_name: BTreeMap<String, TaskStat> =
                std::mem::take(&mut self.tasks).into_iter().map(|t| (t.task.clone(), t)).collect();
            for t in &other.tasks {
                by_name
                    .entry(t.task.clone())
                    .and_modify(|mine| mine.absorb(t))
                    .or_insert_with(|| t.clone());
            }
            self.tasks = by_name.into_values().collect();
        }
    }

    /// The `k` busiest tasks by request count (ties broken by name for
    /// determinism) — the `GatewayReport` top-K table.
    pub fn top_tasks(&self, k: usize) -> Vec<&TaskStat> {
        let mut v: Vec<&TaskStat> = self.tasks.iter().collect();
        v.sort_by(|a, b| b.requests.cmp(&a.requests).then_with(|| a.task.cmp(&b.task)));
        v.truncate(k);
        v
    }

    /// Nearest-rank percentile of the merged total latencies, in seconds.
    pub fn latency_pct(&self, p: f64) -> f64 {
        let mut v = self.lat.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        nearest_rank(&v, p)
    }

    pub fn p50_secs(&self) -> f64 {
        self.latency_pct(50.0)
    }

    pub fn p95_secs(&self) -> f64 {
        self.latency_pct(95.0)
    }

    /// Nearest-rank percentile of the merged queue waits, in seconds.
    pub fn queue_pct(&self, p: f64) -> f64 {
        let mut v = self.qlat.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        nearest_rank(&v, p)
    }

    /// Fleet queue-wait p95 in seconds — the slot scheduler's
    /// head-of-line signal, split out from total latency.
    pub fn queue_p95_secs(&self) -> f64 {
        self.queue_pct(95.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_nearest_rank() {
        let mut s = ServeStats::new();
        let lats: Vec<f64> = (1..=100).map(|i| i as f64 / 1000.0).collect();
        s.record_batch(100, 400, 0.25, &lats, &[]);
        assert!((s.p50_secs() - 0.050).abs() < 1e-9);
        assert!((s.p95_secs() - 0.095).abs() < 1e-9);
        assert_eq!(s.requests, 100);
        assert_eq!(s.tokens, 400);
        assert_eq!(s.batches, 1);
        // throughput uses busy time, not wall time since construction
        assert!((s.requests_per_sec() - 400.0).abs() < 1e-6);
        assert!((s.tokens_per_sec() - 1600.0).abs() < 1e-6);
    }

    #[test]
    fn empty_stats_are_zero() {
        let mut s = ServeStats::new();
        assert_eq!(s.p50_secs(), 0.0);
        assert_eq!(s.queue_p95_secs(), 0.0);
        assert_eq!(s.mean_batch_size(), 0.0);
    }

    #[test]
    fn queue_wait_is_recorded_separately_from_total_latency() {
        let mut s = ServeStats::new();
        // 4 requests: totals 10/20/30/40 ms, queue waits 1/2/3/4 ms
        s.record_batch(4, 8, 0.04, &[0.010, 0.020, 0.030, 0.040], &[0.001, 0.002, 0.003, 0.004]);
        assert!((s.p95_secs() - 0.040).abs() < 1e-12);
        assert!((s.queue_p95_secs() - 0.004).abs() < 1e-12);
        assert!((s.queue_pct(50.0) - 0.002).abs() < 1e-12);
        // the split survives the snapshot
        let snap = s.snapshot();
        assert!((snap.p95_secs() - 0.040).abs() < 1e-12);
        assert!((snap.queue_p95_secs() - 0.004).abs() < 1e-12);
        assert_eq!(snap.qlat_stride, 1);
    }

    #[test]
    fn percentiles_track_interleaved_reads_and_writes() {
        // the lazily-sorted reservoir must re-sort after every new batch
        let mut s = ServeStats::new();
        s.record_batch(2, 4, 0.01, &[0.010, 0.020], &[]);
        assert!((s.p95_secs() - 0.020).abs() < 1e-12);
        s.record_batch(2, 4, 0.01, &[0.100, 0.005], &[]);
        assert!((s.p95_secs() - 0.100).abs() < 1e-12, "new max must surface");
        assert!((s.p50_secs() - 0.010).abs() < 1e-12); // rank 2 of [5,10,20,100]ms
        // repeated reads with no writes are stable (and hit the cached sort)
        assert_eq!(s.p95_secs(), s.p95_secs());
    }

    #[test]
    fn reservoir_stays_bounded() {
        let mut s = ServeStats::new();
        let chunk = vec![0.001f64; 1000];
        for _ in 0..200 {
            s.record_batch(1000, 1000, 0.001, &chunk, &chunk);
        }
        assert!(s.lat.v.len() <= LAT_CAP);
        assert!(s.queue.v.len() <= LAT_CAP);
        assert_eq!(s.requests, 200_000);
        assert!((s.p95_secs() - 0.001).abs() < 1e-9);
        assert!((s.queue_p95_secs() - 0.001).abs() < 1e-9);
    }

    #[test]
    fn snapshots_merge_counters_and_percentiles() {
        let mut a = ServeStats::new();
        a.record_batch(2, 10, 0.1, &[0.010, 0.020], &[0.001, 0.002]);
        a.prefix_resumes = 3;
        let mut b = ServeStats::new();
        b.record_batch(2, 6, 0.2, &[0.030, 0.040], &[0.003, 0.004]);
        let mut m = a.snapshot();
        m.merge(&b.snapshot());
        assert_eq!(m.requests, 4);
        assert_eq!(m.tokens, 16);
        assert_eq!(m.batches, 2);
        assert_eq!(m.prefix_resumes, 3);
        assert!((m.busy_secs - 0.3).abs() < 1e-12);
        assert!((m.p50_secs() - 0.020).abs() < 1e-12);
        assert!((m.p95_secs() - 0.040).abs() < 1e-12);
        assert!((m.queue_p95_secs() - 0.004).abs() < 1e-12);
        assert_eq!(StatsSnapshot::default().p95_secs(), 0.0);
        assert_eq!(StatsSnapshot::default().queue_p95_secs(), 0.0);
    }

    #[test]
    fn task_accounting_records_and_merges_by_name() {
        let mut a = ServeStats::new();
        a.record_task("qa", 2, 10, 1, 1);
        a.record_task("sum", 1, 4, 0, 0);
        a.record_task("qa", 3, 12, 2, 0); // same task accumulates
        let sa = a.snapshot();
        assert_eq!(sa.tasks.len(), 2);
        // BTreeMap iteration: stable name order
        assert_eq!(sa.tasks[0].task, "qa");
        assert_eq!(sa.tasks[0].requests, 5);
        assert_eq!(sa.tasks[0].tokens, 22);
        assert_eq!(sa.tasks[0].cache_hits, 3);
        assert_eq!(sa.tasks[0].swap_ins, 1);
        assert_eq!(sa.tasks[1].task, "sum");

        let mut b = ServeStats::new();
        b.record_task("qa", 4, 16, 4, 2);
        b.record_task("cls", 7, 7, 0, 1);
        let mut m = sa.clone();
        m.merge(&b.snapshot());
        // shared names sum, disjoint names union, order stays sorted
        assert_eq!(
            m.tasks.iter().map(|t| t.task.as_str()).collect::<Vec<_>>(),
            vec!["cls", "qa", "sum"]
        );
        let qa = m.tasks.iter().find(|t| t.task == "qa").unwrap();
        assert_eq!((qa.requests, qa.tokens, qa.cache_hits, qa.swap_ins), (9, 38, 7, 3));
        // merging into an empty snapshot adopts the other side
        let mut e = StatsSnapshot::default();
        e.merge(&m);
        assert_eq!(e.tasks, m.tasks);
        // top-K: sorted by requests desc, ties by name
        let top = m.top_tasks(2);
        assert_eq!(top[0].task, "qa");
        assert_eq!(top[1].task, "cls");
        assert_eq!(m.top_tasks(10).len(), 3);
    }

    #[test]
    fn merge_is_count_weighted_across_decimation_strides() {
        // Shard A serves 100k fast requests (1 ms): its reservoir hits
        // LAT_CAP and decimates to stride 2.  Shard B serves 30k slow
        // requests (1 s) at stride 1.  Ground truth over all 130k
        // requests: p70 falls at rank 91k, inside A's 100k — 1 ms.
        // The old concatenating merge weighted each of B's samples 2x
        // relative to A's and reported p70 = 1 s.
        let mut a = ServeStats::new();
        let fast = vec![0.001f64; 1000];
        for _ in 0..100 {
            a.record_batch(1000, 1000, 0.01, &fast, &fast);
        }
        let mut b = ServeStats::new();
        let slow = vec![1.0f64; 1000];
        for _ in 0..30 {
            b.record_batch(1000, 1000, 0.01, &slow, &slow);
        }
        let sa = a.snapshot();
        assert!(sa.lat_stride >= 2, "shard A must actually have decimated");
        assert_eq!(b.snapshot().lat_stride, 1);
        let mut m = sa.clone();
        m.merge(&b.snapshot());
        assert_eq!(m.requests, 130_000);
        let p70 = m.latency_pct(70.0);
        assert!((p70 - 0.001).abs() < 1e-9, "fleet p70 must be 1 ms, got {p70}");
        // and the merge didn't erase the slow tail: ground-truth p80 is
        // rank 104k — past A's 100k, so 1 s
        assert!((m.latency_pct(80.0) - 1.0).abs() < 1e-9);
        // the queue-wait reservoir merges with the same count weighting
        assert!((m.queue_pct(70.0) - 0.001).abs() < 1e-9);
        assert!((m.queue_pct(80.0) - 1.0).abs() < 1e-9);
        // the histogram counted every request exactly once
        assert_eq!(m.hist.count(), 130_000);
        let hp70 = m.hist.percentile(70.0);
        assert!(hp70 >= 0.001 && hp70 <= 0.0013, "hist p70 within one bucket of 1 ms, got {hp70}");
        // merge direction doesn't change the weighting
        let mut m2 = b.snapshot();
        m2.merge(&a.snapshot());
        assert!((m2.latency_pct(70.0) - 0.001).abs() < 1e-9);
        assert_eq!(m2.hist, m.hist);
        assert_eq!(m2.lat_stride, m.lat_stride);
        assert_eq!(m2.qlat_stride, m.qlat_stride);
    }
}
