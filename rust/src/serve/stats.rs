//! Serving telemetry: request/token throughput, batch shapes, and a
//! latency distribution (p50/p95).  The [`Json`] writer `bench-serve`
//! uses to persist `BENCH_serve.json` lives in [`crate::benchkit`]
//! (it's a generic substrate, also used by `bench-kernels`) and is
//! re-exported here for the serve-side callers.

use std::time::Instant;

pub use crate::benchkit::Json;

/// Cap on retained latency samples; at the cap the reservoir is decimated
/// (every 2nd sample kept) so memory stays bounded and the distribution
/// stays representative for long-running servers.
const LAT_CAP: usize = 65_536;

pub struct ServeStats {
    started: Instant,
    pub requests: u64,
    pub batches: u64,
    pub tokens: u64,
    /// requests dropped by failing micro-batches (see `Server::drain`)
    pub dropped: u64,
    /// cache misses served by resuming from a cached prefix instead of a
    /// full frozen forward (see `Server::process_batch`)
    pub prefix_resumes: u64,
    /// seconds spent actually processing batches — the throughput
    /// denominator, so idle time (waiting on stdin/transport) between
    /// requests doesn't dilute req/s
    pub busy_secs: f64,
    /// request latencies in seconds (queue + compute), decimated reservoir;
    /// kept sorted lazily — see [`ServeStats::sorted_lat`]
    lat: Vec<f64>,
    /// whether `lat` has unsorted appends since the last percentile read
    lat_dirty: bool,
    /// decimation factor (each retained sample stands for this many)
    lat_stride: u64,
    lat_skip: u64,
}

impl Default for ServeStats {
    fn default() -> Self {
        Self::new()
    }
}

impl ServeStats {
    pub fn new() -> Self {
        ServeStats {
            started: Instant::now(),
            requests: 0,
            batches: 0,
            tokens: 0,
            dropped: 0,
            prefix_resumes: 0,
            busy_secs: 0.0,
            lat: Vec::new(),
            lat_dirty: false,
            lat_stride: 1,
            lat_skip: 0,
        }
    }

    /// Record one completed micro-batch of `n` requests covering `tokens`
    /// prompt tokens, processed in `batch_secs`, with per-request latencies.
    pub fn record_batch(&mut self, n: usize, tokens: usize, batch_secs: f64, latencies_secs: &[f64]) {
        self.batches += 1;
        self.requests += n as u64;
        self.tokens += tokens as u64;
        self.busy_secs += batch_secs.max(0.0);
        for &l in latencies_secs {
            self.lat_skip += 1;
            if self.lat_skip < self.lat_stride {
                continue;
            }
            self.lat_skip = 0;
            if self.lat.len() >= LAT_CAP {
                // decimation keeps every 2nd retained sample; `lat` may be
                // in sorted order here, which thins the distribution evenly
                let mut keep = false;
                self.lat.retain(|_| {
                    keep = !keep;
                    keep
                });
                self.lat_stride *= 2;
            }
            self.lat.push(l);
            self.lat_dirty = true;
        }
    }

    /// Wall time since the server came up (includes idle).
    pub fn elapsed_secs(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Serving throughput over *busy* time — an interactive session with
    /// long idle gaps between requests still reports real speed.
    pub fn requests_per_sec(&self) -> f64 {
        self.requests as f64 / self.busy_secs.max(1e-9)
    }

    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens as f64 / self.busy_secs.max(1e-9)
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }

    /// The reservoir in sorted order, re-sorting in place only when new
    /// samples arrived since the last read — `summary()` reads two
    /// percentiles per request line in interactive serving, so this must
    /// not clone-and-sort 64Ki samples per call.
    fn sorted_lat(&mut self) -> &[f64] {
        if self.lat_dirty {
            self.lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.lat_dirty = false;
        }
        &self.lat
    }

    /// Nearest-rank percentile of recorded latencies, in seconds.
    pub fn latency_pct(&mut self, p: f64) -> f64 {
        let v = self.sorted_lat();
        if v.is_empty() {
            return 0.0;
        }
        let rank = ((p / 100.0) * v.len() as f64).ceil() as usize;
        v[rank.clamp(1, v.len()) - 1]
    }

    pub fn p50_secs(&mut self) -> f64 {
        self.latency_pct(50.0)
    }

    pub fn p95_secs(&mut self) -> f64 {
        self.latency_pct(95.0)
    }

    /// Counters + the latency reservoir, detached from the live server —
    /// what a gateway shard ships to the aggregator.  Snapshots from many
    /// shards [`StatsSnapshot::merge`] into fleet-wide percentiles.
    pub fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            requests: self.requests,
            batches: self.batches,
            tokens: self.tokens,
            dropped: self.dropped,
            prefix_resumes: self.prefix_resumes,
            busy_secs: self.busy_secs,
            lat: self.lat.clone(),
        }
    }

    /// One-line human summary for the CLI.
    pub fn summary(&mut self, cache_hit_rate: f64) -> String {
        let dropped = if self.dropped > 0 { format!(" | {} dropped", self.dropped) } else { String::new() };
        let p50_ms = self.p50_secs() * 1e3;
        let p95_ms = self.p95_secs() * 1e3;
        format!(
            "{} req in {} batches ({:.1} req/batch) | {:.1} req/s, {:.0} tok/s | p50 {p50_ms:.2} ms, p95 {p95_ms:.2} ms | cache hit rate {:.1}%{dropped}",
            self.requests,
            self.batches,
            self.mean_batch_size(),
            self.requests_per_sec(),
            self.tokens_per_sec(),
            cache_hit_rate * 100.0
        )
    }
}

/// A detached, mergeable view of [`ServeStats`]: plain counters plus the
/// (decimated) latency reservoir.  Gateway shards run their own servers on
/// their own threads; each ships a snapshot and the aggregator merges them
/// into fleet-wide throughput and percentiles.  Merging reservoirs with
/// different decimation strides weighs shards slightly unevenly — fine for
/// telemetry, and exact when strides match (they do under balanced load).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StatsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub tokens: u64,
    pub dropped: u64,
    pub prefix_resumes: u64,
    /// summed busy seconds across shards — divide by shard count for the
    /// mean per-shard busy time; wall-clock throughput needs the caller's
    /// own clock (shards overlap in time)
    pub busy_secs: f64,
    /// merged latency samples in seconds (unsorted)
    pub lat: Vec<f64>,
}

impl StatsSnapshot {
    pub fn merge(&mut self, other: &StatsSnapshot) {
        self.requests += other.requests;
        self.batches += other.batches;
        self.tokens += other.tokens;
        self.dropped += other.dropped;
        self.prefix_resumes += other.prefix_resumes;
        self.busy_secs += other.busy_secs;
        self.lat.extend_from_slice(&other.lat);
    }

    /// Nearest-rank percentile of the merged latencies, in seconds.
    pub fn latency_pct(&self, p: f64) -> f64 {
        if self.lat.is_empty() {
            return 0.0;
        }
        let mut v = self.lat.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * v.len() as f64).ceil() as usize;
        v[rank.clamp(1, v.len()) - 1]
    }

    pub fn p50_secs(&self) -> f64 {
        self.latency_pct(50.0)
    }

    pub fn p95_secs(&self) -> f64 {
        self.latency_pct(95.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_nearest_rank() {
        let mut s = ServeStats::new();
        let lats: Vec<f64> = (1..=100).map(|i| i as f64 / 1000.0).collect();
        s.record_batch(100, 400, 0.25, &lats);
        assert!((s.p50_secs() - 0.050).abs() < 1e-9);
        assert!((s.p95_secs() - 0.095).abs() < 1e-9);
        assert_eq!(s.requests, 100);
        assert_eq!(s.tokens, 400);
        assert_eq!(s.batches, 1);
        // throughput uses busy time, not wall time since construction
        assert!((s.requests_per_sec() - 400.0).abs() < 1e-6);
        assert!((s.tokens_per_sec() - 1600.0).abs() < 1e-6);
    }

    #[test]
    fn empty_stats_are_zero() {
        let mut s = ServeStats::new();
        assert_eq!(s.p50_secs(), 0.0);
        assert_eq!(s.mean_batch_size(), 0.0);
    }

    #[test]
    fn percentiles_track_interleaved_reads_and_writes() {
        // the lazily-sorted reservoir must re-sort after every new batch
        let mut s = ServeStats::new();
        s.record_batch(2, 4, 0.01, &[0.010, 0.020]);
        assert!((s.p95_secs() - 0.020).abs() < 1e-12);
        s.record_batch(2, 4, 0.01, &[0.100, 0.005]);
        assert!((s.p95_secs() - 0.100).abs() < 1e-12, "new max must surface");
        assert!((s.p50_secs() - 0.010).abs() < 1e-12); // rank 2 of [5,10,20,100]ms
        // repeated reads with no writes are stable (and hit the cached sort)
        assert_eq!(s.p95_secs(), s.p95_secs());
    }

    #[test]
    fn reservoir_stays_bounded() {
        let mut s = ServeStats::new();
        let chunk = vec![0.001f64; 1000];
        for _ in 0..200 {
            s.record_batch(1000, 1000, 0.001, &chunk);
        }
        assert!(s.lat.len() <= LAT_CAP);
        assert_eq!(s.requests, 200_000);
        assert!((s.p95_secs() - 0.001).abs() < 1e-9);
    }

    #[test]
    fn snapshots_merge_counters_and_percentiles() {
        let mut a = ServeStats::new();
        a.record_batch(2, 10, 0.1, &[0.010, 0.020]);
        a.prefix_resumes = 3;
        let mut b = ServeStats::new();
        b.record_batch(2, 6, 0.2, &[0.030, 0.040]);
        let mut m = a.snapshot();
        m.merge(&b.snapshot());
        assert_eq!(m.requests, 4);
        assert_eq!(m.tokens, 16);
        assert_eq!(m.batches, 2);
        assert_eq!(m.prefix_resumes, 3);
        assert!((m.busy_secs - 0.3).abs() < 1e-12);
        assert!((m.p50_secs() - 0.020).abs() < 1e-12);
        assert!((m.p95_secs() - 0.040).abs() < 1e-12);
        assert_eq!(StatsSnapshot::default().p95_secs(), 0.0);
    }
}
