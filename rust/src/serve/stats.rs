//! Serving telemetry: request/token throughput, batch shapes, and a
//! latency distribution (p50/p95) — plus a tiny JSON writer (serde is
//! unavailable offline) so `bench-serve` can persist `BENCH_serve.json`.

use std::time::Instant;

/// Cap on retained latency samples; at the cap the reservoir is decimated
/// (every 2nd sample kept) so memory stays bounded and the distribution
/// stays representative for long-running servers.
const LAT_CAP: usize = 65_536;

pub struct ServeStats {
    started: Instant,
    pub requests: u64,
    pub batches: u64,
    pub tokens: u64,
    /// requests dropped by failing micro-batches (see `Server::drain`)
    pub dropped: u64,
    /// seconds spent actually processing batches — the throughput
    /// denominator, so idle time (waiting on stdin/transport) between
    /// requests doesn't dilute req/s
    pub busy_secs: f64,
    /// request latencies in seconds (queue + compute), decimated reservoir
    lat: Vec<f64>,
    /// decimation factor (each retained sample stands for this many)
    lat_stride: u64,
    lat_skip: u64,
}

impl Default for ServeStats {
    fn default() -> Self {
        Self::new()
    }
}

impl ServeStats {
    pub fn new() -> Self {
        ServeStats {
            started: Instant::now(),
            requests: 0,
            batches: 0,
            tokens: 0,
            dropped: 0,
            busy_secs: 0.0,
            lat: Vec::new(),
            lat_stride: 1,
            lat_skip: 0,
        }
    }

    /// Record one completed micro-batch of `n` requests covering `tokens`
    /// prompt tokens, processed in `batch_secs`, with per-request latencies.
    pub fn record_batch(&mut self, n: usize, tokens: usize, batch_secs: f64, latencies_secs: &[f64]) {
        self.batches += 1;
        self.requests += n as u64;
        self.tokens += tokens as u64;
        self.busy_secs += batch_secs.max(0.0);
        for &l in latencies_secs {
            self.lat_skip += 1;
            if self.lat_skip < self.lat_stride {
                continue;
            }
            self.lat_skip = 0;
            if self.lat.len() >= LAT_CAP {
                let mut keep = false;
                self.lat.retain(|_| {
                    keep = !keep;
                    keep
                });
                self.lat_stride *= 2;
            }
            self.lat.push(l);
        }
    }

    /// Wall time since the server came up (includes idle).
    pub fn elapsed_secs(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Serving throughput over *busy* time — an interactive session with
    /// long idle gaps between requests still reports real speed.
    pub fn requests_per_sec(&self) -> f64 {
        self.requests as f64 / self.busy_secs.max(1e-9)
    }

    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens as f64 / self.busy_secs.max(1e-9)
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }

    /// Nearest-rank percentile of recorded latencies, in seconds.
    pub fn latency_pct(&self, p: f64) -> f64 {
        if self.lat.is_empty() {
            return 0.0;
        }
        let mut v = self.lat.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * v.len() as f64).ceil() as usize;
        v[rank.clamp(1, v.len()) - 1]
    }

    pub fn p50_secs(&self) -> f64 {
        self.latency_pct(50.0)
    }

    pub fn p95_secs(&self) -> f64 {
        self.latency_pct(95.0)
    }

    /// One-line human summary for the CLI.
    pub fn summary(&self, cache_hit_rate: f64) -> String {
        let dropped = if self.dropped > 0 { format!(" | {} dropped", self.dropped) } else { String::new() };
        format!(
            "{} req in {} batches ({:.1} req/batch) | {:.1} req/s, {:.0} tok/s | p50 {:.2} ms, p95 {:.2} ms | cache hit rate {:.1}%{dropped}",
            self.requests,
            self.batches,
            self.mean_batch_size(),
            self.requests_per_sec(),
            self.tokens_per_sec(),
            self.p50_secs() * 1e3,
            self.p95_secs() * 1e3,
            cache_hit_rate * 100.0
        )
    }
}

/// Minimal JSON object writer (flat objects of numbers/strings — all the
/// bench reports need).
pub struct Json {
    buf: String,
    first: bool,
}

impl Default for Json {
    fn default() -> Self {
        Self::new()
    }
}

impl Json {
    pub fn new() -> Self {
        Json { buf: String::from("{"), first: true }
    }

    fn key(&mut self, k: &str) {
        if !self.first {
            self.buf.push(',');
        }
        self.first = false;
        self.buf.push('\n');
        self.buf.push_str("  \"");
        self.buf.push_str(k);
        self.buf.push_str("\": ");
    }

    pub fn num(mut self, k: &str, v: f64) -> Self {
        self.key(k);
        if v.is_finite() {
            self.buf.push_str(&format!("{v:.6}"));
        } else {
            self.buf.push_str("null");
        }
        self
    }

    pub fn int(mut self, k: &str, v: u64) -> Self {
        self.key(k);
        self.buf.push_str(&v.to_string());
        self
    }

    pub fn str(mut self, k: &str, v: &str) -> Self {
        self.key(k);
        self.buf.push('"');
        for c in v.chars() {
            match c {
                '"' => self.buf.push_str("\\\""),
                '\\' => self.buf.push_str("\\\\"),
                '\n' => self.buf.push_str("\\n"),
                c if (c as u32) < 0x20 => self.buf.push_str(&format!("\\u{:04x}", c as u32)),
                c => self.buf.push(c),
            }
        }
        self.buf.push('"');
        self
    }

    pub fn finish(mut self) -> String {
        self.buf.push_str("\n}\n");
        self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_nearest_rank() {
        let mut s = ServeStats::new();
        let lats: Vec<f64> = (1..=100).map(|i| i as f64 / 1000.0).collect();
        s.record_batch(100, 400, 0.25, &lats);
        assert!((s.p50_secs() - 0.050).abs() < 1e-9);
        assert!((s.p95_secs() - 0.095).abs() < 1e-9);
        assert_eq!(s.requests, 100);
        assert_eq!(s.tokens, 400);
        assert_eq!(s.batches, 1);
        // throughput uses busy time, not wall time since construction
        assert!((s.requests_per_sec() - 400.0).abs() < 1e-6);
        assert!((s.tokens_per_sec() - 1600.0).abs() < 1e-6);
    }

    #[test]
    fn empty_stats_are_zero() {
        let s = ServeStats::new();
        assert_eq!(s.p50_secs(), 0.0);
        assert_eq!(s.mean_batch_size(), 0.0);
    }

    #[test]
    fn reservoir_stays_bounded() {
        let mut s = ServeStats::new();
        let chunk = vec![0.001f64; 1000];
        for _ in 0..200 {
            s.record_batch(1000, 1000, 0.001, &chunk);
        }
        assert!(s.lat.len() <= LAT_CAP);
        assert_eq!(s.requests, 200_000);
        assert!((s.p95_secs() - 0.001).abs() < 1e-9);
    }

    #[test]
    fn json_escapes_and_shapes() {
        let s = Json::new().str("name", "a\"b\\c").int("n", 3).num("x", 1.5).finish();
        assert!(s.starts_with('{') && s.ends_with("}\n"));
        assert!(s.contains("\"name\": \"a\\\"b\\\\c\""));
        assert!(s.contains("\"n\": 3"));
        assert!(s.contains("\"x\": 1.5"));
    }

    #[test]
    fn json_nonfinite_is_null() {
        let s = Json::new().num("bad", f64::NAN).finish();
        assert!(s.contains("\"bad\": null"));
    }
}
