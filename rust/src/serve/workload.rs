//! Synthetic serving workloads + the `bench-serve` runner.
//!
//! Production prompt streams are heavy-tailed: a small set of prompts (and
//! prompt prefixes) recurs across requests and across tenants.  The
//! workload here models that with a pool of `unique_prompts` distinct
//! prompts sampled uniformly by `requests` requests spread over `tasks`
//! side networks — so the expected cache hit rate is
//! `1 - unique_prompts/requests` once the cache is warm.
//!
//! `run_bench` drives the same workload twice over the deterministic
//! synthetic engine — once with the hidden-state cache enabled, once
//! disabled — and reports both throughputs, the speedup, the hit rate,
//! and p50/p95 latencies; `bench-serve` persists this as
//! `BENCH_serve.json` so the perf trajectory accumulates across PRs.

use anyhow::{ensure, Context, Result};

use super::stats::Json;
use super::{BackboneKind, EnginePreset, ServeConfig, Server};
use crate::util::rng::Rng;

/// Workload + engine shape for a serving benchmark run.
#[derive(Clone, Debug)]
pub struct BenchServeOpts {
    pub tasks: usize,
    pub requests: usize,
    pub unique_prompts: usize,
    /// prompt length in tokens (≤ seq)
    pub prompt_len: usize,
    pub seq: usize,
    pub max_batch: usize,
    pub cache_bytes: usize,
    pub registry_bytes: usize,
    /// requests submitted between drains (burst size)
    pub burst: usize,
    pub seed: u64,
    /// kernel worker count for the engine forwards (`--threads`)
    pub threads: usize,
    /// engine shape (`--preset small|large|xl`)
    pub preset: EnginePreset,
    /// frozen-backbone storage (`--backbone f32|w4`) for the primary passes
    pub backbone: BackboneKind,
    /// prefix-index block size in tokens (0 = whole-prompt caching only,
    /// the pre-gateway default — keeps the trajectory numbers comparable)
    pub prefix_block: usize,
    /// when set, replay the cached pass with the span recorder armed,
    /// refuse unless the replay is bit-identical, and write the Chrome
    /// trace-event file here (`--trace-out`)
    pub trace_out: Option<String>,
}

impl Default for BenchServeOpts {
    fn default() -> Self {
        BenchServeOpts {
            tasks: 3,
            requests: 512,
            unique_prompts: 32,
            prompt_len: 48,
            seq: 64,
            max_batch: 8,
            cache_bytes: 64 << 20,
            registry_bytes: 64 << 20,
            burst: 64,
            seed: 0,
            threads: 1,
            preset: EnginePreset::Small,
            backbone: BackboneKind::F32,
            prefix_block: 0,
            trace_out: None,
        }
    }
}

/// One measured pass (cache on or off, one backbone kind).
#[derive(Clone, Copy, Debug)]
pub struct PassReport {
    pub wall_secs: f64,
    pub requests_per_sec: f64,
    pub tokens_per_sec: f64,
    pub hit_rate: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub backbone_rows: u64,
    pub cache_evictions: u64,
    /// bytes the frozen backbone kept resident during this pass
    pub backbone_bytes: usize,
    /// misses served by resuming from a cached prefix (0 unless
    /// `prefix_block > 0` and the workload shares prefixes)
    pub prefix_resumes: u64,
    /// FNV-1a fold of every response's id + logit bits, in completion
    /// order — two passes over the same stream must agree exactly
    /// (cache on/off, tracing on/off: serving is bit-deterministic)
    pub digest: u64,
}

/// The full comparison: cached-vs-uncached on the primary backbone kind,
/// plus one cached pass on the *other* kind so every report carries
/// f32-vs-W4 latency and resident-bytes side-by-side.
#[derive(Clone, Debug)]
pub struct BenchServeReport {
    pub opts: BenchServeOpts,
    pub cached: PassReport,
    pub uncached: PassReport,
    /// cached pass over the other backbone storage (same workload stream)
    pub alt_cached: PassReport,
    /// measured cost of the *disabled* instrumentation (one relaxed
    /// atomic load per site), as a percent of the cached p50 latency —
    /// the always-compiled tracing must stay under 2% when off
    pub trace_off_overhead_pct: f64,
    /// cached-pass replay with the recorder armed (`trace_out` only)
    pub traced: Option<PassReport>,
    /// distinct span names written to the trace file (empty when untraced)
    pub trace_kinds: Vec<String>,
    /// spans written to the trace file
    pub trace_spans: usize,
}

impl BenchServeReport {
    pub fn speedup(&self) -> f64 {
        self.cached.requests_per_sec / self.uncached.requests_per_sec.max(1e-12)
    }

    /// Resident backbone bytes by kind, regardless of which was primary.
    pub fn backbone_bytes(&self, kind: BackboneKind) -> usize {
        if kind == self.opts.backbone {
            self.cached.backbone_bytes
        } else {
            self.alt_cached.backbone_bytes
        }
    }

    /// f32 resident bytes over W4 resident bytes (~7x for these presets).
    pub fn backbone_bytes_ratio(&self) -> f64 {
        self.backbone_bytes(BackboneKind::F32) as f64
            / self.backbone_bytes(BackboneKind::W4).max(1) as f64
    }

    pub fn to_json(&self) -> String {
        let (d, layers, vocab, r) = self.opts.preset.shape();
        let mut j = Json::new()
            .provenance()
            .str("bench", "serve")
            .str("preset", self.opts.preset.name())
            // engine shape, so trajectory files are self-describing
            .int("d", d as u64)
            .int("layers", layers as u64)
            .int("vocab", vocab as u64)
            .int("reduction", r as u64)
            .str("backbone", self.opts.backbone.name())
            .int("threads", self.opts.threads as u64)
            .int("tasks", self.opts.tasks as u64)
            .int("requests", self.opts.requests as u64)
            .int("unique_prompts", self.opts.unique_prompts as u64)
            .int("prompt_len", self.opts.prompt_len as u64)
            .int("seq", self.opts.seq as u64)
            .int("max_batch", self.opts.max_batch as u64)
            .int("cache_bytes", self.opts.cache_bytes as u64)
            .int("seed", self.opts.seed)
            .num("cached_rps", self.cached.requests_per_sec)
            .num("cached_tokens_per_sec", self.cached.tokens_per_sec)
            .num("cached_hit_rate", self.cached.hit_rate)
            .num("cached_p50_ms", self.cached.p50_ms)
            .num("cached_p95_ms", self.cached.p95_ms)
            .int("cached_backbone_rows", self.cached.backbone_rows)
            .int("cache_evictions", self.cached.cache_evictions)
            .int("prefix_block", self.opts.prefix_block as u64)
            .int("cached_prefix_resumes", self.cached.prefix_resumes)
            .num("uncached_rps", self.uncached.requests_per_sec)
            .num("uncached_p50_ms", self.uncached.p50_ms)
            .num("uncached_p95_ms", self.uncached.p95_ms)
            .int("uncached_backbone_rows", self.uncached.backbone_rows)
            .num("speedup", self.speedup())
            // f32-vs-w4 side-by-side: residency + cached latency
            .int("backbone_bytes", self.cached.backbone_bytes as u64)
            .int("backbone_bytes_f32", self.backbone_bytes(BackboneKind::F32) as u64)
            .int("backbone_bytes_w4", self.backbone_bytes(BackboneKind::W4) as u64)
            .num("backbone_bytes_ratio", self.backbone_bytes_ratio())
            .str("alt_backbone", self.opts.backbone.other().name())
            .num("alt_cached_rps", self.alt_cached.requests_per_sec)
            .num("alt_cached_p50_ms", self.alt_cached.p50_ms)
            .num("alt_cached_p95_ms", self.alt_cached.p95_ms)
            .num("trace_off_overhead_pct", self.trace_off_overhead_pct);
        if let Some(t) = &self.traced {
            j = j
                .num("traced_rps", t.requests_per_sec)
                .num("traced_p50_ms", t.p50_ms)
                .int("trace_spans", self.trace_spans as u64)
                .str("trace_kinds", &self.trace_kinds.join(","))
                // the run refuses to report otherwise, so this is always
                // true when present — recorded so the JSON is self-auditing
                .int("trace_parity", 1);
        }
        j.finish()
    }

    pub fn summary(&self) -> String {
        let traced = match &self.traced {
            None => String::new(),
            Some(t) => format!(
                " | traced {:.1} req/s, {} spans ({} kinds), parity ok",
                t.requests_per_sec,
                self.trace_spans,
                self.trace_kinds.len()
            ),
        };
        format!(
            "serve bench [{} preset, {} backbone, {} threads]: {} req, {} tasks, {} unique prompts | cached {:.1} req/s (hit {:.1}%, p50 {:.2} ms, p95 {:.2} ms) | uncached {:.1} req/s | speedup {:.2}x | backbone {} resident ({} as {}; f32/w4 = {:.2}x) | {} cached {:.1} req/s | trace-off overhead {:.3}%{}",
            self.opts.preset.name(),
            self.opts.backbone.name(),
            self.opts.threads,
            self.opts.requests,
            self.opts.tasks,
            self.opts.unique_prompts,
            self.cached.requests_per_sec,
            self.cached.hit_rate * 100.0,
            self.cached.p50_ms,
            self.cached.p95_ms,
            self.uncached.requests_per_sec,
            self.speedup(),
            crate::util::human_bytes(self.cached.backbone_bytes as f64),
            crate::util::human_bytes(self.alt_cached.backbone_bytes as f64),
            self.opts.backbone.other().name(),
            self.backbone_bytes_ratio(),
            self.opts.backbone.other().name(),
            self.alt_cached.requests_per_sec,
            self.trace_off_overhead_pct,
            traced,
        )
    }
}

/// How many distinct prompts of `len` tokens the pool can stamp (base
/// vocab-1 positional encoding of the index, saturating).
pub fn prompt_pool_capacity(len: usize, vocab: usize) -> usize {
    let base = (vocab.saturating_sub(1)).max(2);
    let mut cap: usize = 1;
    for _ in 0..len.max(1).min(8) {
        cap = cap.saturating_mul(base);
    }
    cap
}

/// Deterministic prompt pool: `n` rows of `len` tokens, guaranteed pairwise
/// distinct by stamping the pool index in base vocab-1 over the leading
/// positions.  Panics if `n` exceeds [`prompt_pool_capacity`] — callers
/// ([`run_bench`]) validate first, so the benchmark's unique-prompt count
/// (the hit-rate denominator) is always what was asked for.
pub fn prompt_pool(rng: &mut Rng, n: usize, len: usize, vocab: usize) -> Vec<Vec<i32>> {
    assert!(
        n <= prompt_pool_capacity(len, vocab),
        "{n} unique prompts don't fit in {len} tokens over a {vocab}-token vocab"
    );
    let base = (vocab.saturating_sub(1)).max(2);
    (0..n)
        .map(|i| {
            let mut p: Vec<i32> = (0..len.max(1))
                .map(|_| rng.range(1, vocab.max(3)) as i32) // avoid PAD=0
                .collect();
            // stamp index digits (token ids 1..=base, never PAD)
            let mut rest = i;
            for slot in p.iter_mut() {
                *slot = 1 + (rest % base) as i32;
                rest /= base;
                if rest == 0 {
                    break;
                }
            }
            p
        })
        .collect()
}

/// Deterministic shared-prefix pool for prefix-cache workloads: `families`
/// pairwise-distinct prefixes of `prefix_len` tokens, each extended by
/// `per_family` pairwise-distinct tails to `len` tokens.  Prompts within a
/// family share exactly their first `prefix_len` tokens, so with
/// `prefix_len` a multiple of the cache's block size every non-first
/// member of a family can resume from the family's deepest cached block.
pub fn shared_prefix_pool(
    rng: &mut Rng,
    families: usize,
    per_family: usize,
    prefix_len: usize,
    len: usize,
    vocab: usize,
) -> Vec<Vec<i32>> {
    assert!(prefix_len >= 1 && prefix_len < len, "prefix must be a proper prefix");
    assert!(families >= 1 && per_family >= 1);
    let prefixes = prompt_pool(rng, families, prefix_len, vocab);
    let tails = prompt_pool(rng, per_family, len - prefix_len, vocab);
    let mut out = Vec::with_capacity(families * per_family);
    for pref in &prefixes {
        for tail in &tails {
            let mut p = pref.clone();
            p.extend_from_slice(tail);
            out.push(p);
        }
    }
    out
}

/// Deterministic mixed-length pool for head-of-line-blocking workloads:
/// `n` prompts spread round-robin across the pairwise-distinct lengths in
/// `lens`, so consecutive submissions alternate short and long prompts —
/// exactly the stream where waved scheduling makes short prompts wait out
/// long ones.  Each length's prompts come from [`prompt_pool`] (pairwise
/// distinct; distinct lengths make the pool distinct across groups too).
pub fn mixed_length_pool(rng: &mut Rng, n: usize, lens: &[usize], vocab: usize) -> Vec<Vec<i32>> {
    assert!(!lens.is_empty(), "need at least one prompt length");
    for (i, a) in lens.iter().enumerate() {
        assert!(*a >= 1, "prompt lengths must be positive");
        assert!(!lens[i + 1..].contains(a), "prompt lengths must be distinct");
    }
    let per = (n + lens.len() - 1) / lens.len();
    let pools: Vec<Vec<Vec<i32>>> = lens
        .iter()
        .map(|&len| {
            assert!(
                per <= prompt_pool_capacity(len, vocab),
                "{per} unique prompts don't fit in {len} tokens over a {vocab}-token vocab"
            );
            prompt_pool(rng, per, len, vocab)
        })
        .collect();
    // interleave so every admission window sees a mix of lengths
    let mut out = Vec::with_capacity(n);
    'fill: for i in 0..per {
        for pool in &pools {
            out.push(pool[i].clone());
            if out.len() == n {
                break 'fill;
            }
        }
    }
    out
}

/// Deterministic Zipf-distributed rank sampler: rank `r` (0-based) is
/// drawn with probability proportional to `1/(r+1)^s`, the canonical
/// heavy-tailed task-popularity model (a few hot tasks, a long cold
/// tail).  Sampling inverts a precomputed CDF with a binary search, so
/// `sample` is O(log n) and — driven by the seeded xorshift generator —
/// the stream is bit-reproducible for a given `(n, s, seed)`.
pub struct Zipf {
    cdf: Vec<f64>,
    rng: Rng,
}

impl Zipf {
    pub fn new(n: usize, s: f64, seed: u64) -> Zipf {
        assert!(n >= 1, "zipf needs at least one rank");
        assert!(s.is_finite() && s >= 0.0, "zipf exponent must be finite and >= 0");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for rank in 1..=n {
            acc += 1.0 / (rank as f64).powf(s);
            cdf.push(acc);
        }
        for c in cdf.iter_mut() {
            *c /= acc;
        }
        // the running sum is monotone, so only float roundoff could leave
        // the final entry below 1.0; pin it so `sample` can never fall off
        // the end
        *cdf.last_mut().expect("n >= 1") = 1.0;
        Zipf { cdf, rng: Rng::new(seed) }
    }

    pub fn ranks(&self) -> usize {
        self.cdf.len()
    }

    /// Next rank in `0..n` (0 = hottest).
    pub fn sample(&mut self) -> usize {
        // 53 high bits -> uniform f64 in [0, 1)
        let u = (self.rng.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        // first index whose CDF exceeds u; u < 1.0 = cdf[n-1] keeps it in range
        self.cdf.partition_point(|&c| c <= u)
    }
}

/// FNV-1a fold step over one 64-bit value.
fn fnv(h: u64, v: u64) -> u64 {
    (h ^ v).wrapping_mul(0x0000_0100_0000_01B3)
}

fn run_pass(opts: &BenchServeOpts, cache_bytes: usize, backbone: BackboneKind) -> Result<PassReport> {
    let mut engine = opts.preset.build_backbone(opts.seed, opts.seq, backbone);
    engine.set_threads(opts.threads);
    let vocab = engine.vocab;
    let backbone_bytes = engine.backbone_resident_bytes();
    let mut server = Server::new(
        engine,
        ServeConfig {
            cache_bytes,
            registry_bytes: opts.registry_bytes,
            max_batch: opts.max_batch,
            prefix_block: opts.prefix_block,
        },
    );
    let names: Vec<String> = (0..opts.tasks).map(|i| format!("task{i}")).collect();
    for (i, name) in names.iter().enumerate() {
        // side nets are seed-derived; charge a nominal footprint
        server.registry.register_synthetic(name, opts.seed ^ ((i as u64 + 1) << 32), 1 << 16)?;
    }
    let mut rng = Rng::new(opts.seed.wrapping_add(0xBEAC));
    let pool = if opts.prefix_block > 0 && opts.prompt_len > opts.prefix_block {
        // with the prefix index on, share block-aligned prefixes so the
        // index actually engages (mirrors the gateway bench's stream);
        // pool size stays <= unique_prompts
        let per_family = opts.unique_prompts.min(4).max(1);
        let families = (opts.unique_prompts / per_family).max(1);
        let prefix_len = ((opts.prompt_len / 2 / opts.prefix_block).max(1) * opts.prefix_block)
            .min(opts.prompt_len - 1);
        shared_prefix_pool(&mut rng, families, per_family, prefix_len, opts.prompt_len, vocab)
    } else {
        prompt_pool(&mut rng, opts.unique_prompts, opts.prompt_len, vocab)
    };
    let t0 = std::time::Instant::now();
    let mut submitted = 0usize;
    let mut completed = 0usize;
    let mut digest = 0xcbf2_9ce4_8422_2325u64; // FNV-1a offset basis
    while submitted < opts.requests {
        let burst = opts.burst.min(opts.requests - submitted);
        for _ in 0..burst {
            let task = &names[rng.below(names.len())];
            let prompt = &pool[rng.below(pool.len())];
            server.submit(task, prompt)?;
            submitted += 1;
        }
        for r in server.drain()? {
            digest = fnv(digest, r.id);
            for &v in &r.logits {
                digest = fnv(digest, v.to_bits() as u64);
            }
            completed += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    ensure!(completed == opts.requests, "completed {completed} of {} requests", opts.requests);
    Ok(PassReport {
        wall_secs: wall,
        requests_per_sec: opts.requests as f64 / wall.max(1e-12),
        tokens_per_sec: server.stats.tokens as f64 / wall.max(1e-12),
        hit_rate: server.cache.hit_rate(),
        p50_ms: server.stats.p50_secs() * 1e3,
        p95_ms: server.stats.p95_secs() * 1e3,
        backbone_rows: server.engine.backbone_rows,
        cache_evictions: server.cache.evictions,
        backbone_bytes,
        prefix_resumes: server.stats.prefix_resumes,
        digest,
    })
}

/// Measure what the *disabled* instrumentation costs: each site on the
/// off path pays one relaxed atomic load + branch ([`crate::obs::start`]
/// and [`crate::obs::end`] both lead with it).  Times a large probe loop
/// of exactly that load, scales by a deliberately generous 34 sites per
/// request (the lifecycle + kernel sites plus the continuous-batching
/// `admit_slot`/`queue_wait` pair), and reports it as a percent of the
/// pass's p50 latency.  Reads the flag only — never records — so it is
/// safe whatever state the global recorder is in.
fn trace_off_overhead_pct(p50_secs: f64) -> f64 {
    const PROBES: u64 = 1_000_000;
    let t0 = std::time::Instant::now();
    let mut armed = 0u64;
    for _ in 0..PROBES {
        if std::hint::black_box(crate::obs::enabled()) {
            armed += 1;
        }
    }
    std::hint::black_box(armed);
    let per_site = t0.elapsed().as_secs_f64() / PROBES as f64;
    100.0 * (per_site * 34.0) / p50_secs.max(1e-9)
}

/// Run the repeated-prompt workload with the cache as configured and again
/// with the cache disabled; the workload streams (and their results) are
/// identical — only the backbone recompute count differs.  A third, cached
/// pass runs the same stream over the other backbone storage so the report
/// always carries the f32-vs-W4 comparison.
pub fn run_bench(opts: &BenchServeOpts) -> Result<BenchServeReport> {
    ensure!(opts.tasks >= 1 && opts.requests >= 1 && opts.unique_prompts >= 1);
    ensure!(opts.prompt_len <= opts.seq, "prompt_len must be <= seq");
    let capacity = prompt_pool_capacity(opts.prompt_len, opts.preset.vocab());
    ensure!(
        opts.unique_prompts <= capacity,
        "--unique-prompts {} exceeds the {} distinct prompts expressible at --prompt-len {}",
        opts.unique_prompts,
        capacity,
        opts.prompt_len
    );
    let cached = run_pass(opts, opts.cache_bytes, opts.backbone)?;
    let uncached = run_pass(opts, 0, opts.backbone)?;
    ensure!(
        cached.digest == uncached.digest,
        "cache on/off changed the served bits — the hidden-state cache must be invisible"
    );
    let alt_cached = run_pass(opts, opts.cache_bytes, opts.backbone.other())?;
    let overhead = trace_off_overhead_pct(cached.p50_ms / 1e3);
    let (traced, trace_kinds, trace_spans) = match &opts.trace_out {
        None => (None, Vec::new(), 0),
        Some(path) => {
            // replay the cached pass with the recorder armed; refuse to
            // report unless the replay served the exact same bits
            let _ = crate::obs::drain(); // discard any stale spans
            crate::obs::set_enabled(true);
            let t = run_pass(opts, opts.cache_bytes, opts.backbone);
            crate::obs::set_enabled(false);
            let t = t?;
            let (spans, dropped) = crate::obs::drain();
            ensure!(
                t.digest == cached.digest,
                "tracing changed the served bits — refusing to write {path}"
            );
            if dropped > 0 {
                eprintln!("trace: {dropped} span(s) lost to ring overwrite");
            }
            let tspans = crate::obs::trace::local(spans);
            let kinds: Vec<String> =
                crate::obs::trace::kinds_present(&tspans).iter().map(|s| s.to_string()).collect();
            crate::obs::trace::write_file(path, &tspans)
                .with_context(|| format!("writing trace {path}"))?;
            (Some(t), kinds, tspans.len())
        }
    };
    Ok(BenchServeReport {
        opts: opts.clone(),
        cached,
        uncached,
        alt_cached,
        trace_off_overhead_pct: overhead,
        traced,
        trace_kinds,
        trace_spans,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> BenchServeOpts {
        BenchServeOpts {
            tasks: 2,
            requests: 48,
            unique_prompts: 4,
            prompt_len: 12,
            seq: 16,
            max_batch: 4,
            cache_bytes: 16 << 20,
            registry_bytes: 1 << 20,
            burst: 16,
            seed: 3,
            threads: 1,
            preset: EnginePreset::Small,
            backbone: BackboneKind::F32,
            prefix_block: 0,
            trace_out: None,
        }
    }

    #[test]
    fn prompt_pool_is_distinct_and_padfree() {
        let mut rng = Rng::new(1);
        let pool = prompt_pool(&mut rng, 16, 8, 256);
        for p in &pool {
            assert_eq!(p.len(), 8);
            assert!(p.iter().all(|&t| t > 0));
        }
        for i in 0..pool.len() {
            for j in i + 1..pool.len() {
                assert_ne!(pool[i], pool[j], "prompts {i} and {j} collide");
            }
        }
    }

    #[test]
    fn shared_prefix_pool_shares_exactly_the_prefix() {
        let mut rng = Rng::new(4);
        let pool = shared_prefix_pool(&mut rng, 3, 4, 8, 20, 256);
        assert_eq!(pool.len(), 12);
        for p in &pool {
            assert_eq!(p.len(), 20);
            assert!(p.iter().all(|&t| t > 0));
        }
        for f in 0..3 {
            let fam = &pool[f * 4..(f + 1) * 4];
            for w in fam.windows(2) {
                assert_eq!(w[0][..8], w[1][..8], "family members share the prefix");
                assert_ne!(w[0][8..], w[1][8..], "tails differ");
            }
        }
        assert_ne!(pool[0][..8], pool[4][..8], "families have distinct prefixes");
        let set: std::collections::HashSet<_> = pool.iter().cloned().collect();
        assert_eq!(set.len(), 12, "all prompts pairwise distinct");
    }

    #[test]
    fn mixed_length_pool_interleaves_distinct_lengths() {
        let mut rng = Rng::new(9);
        let pool = mixed_length_pool(&mut rng, 10, &[3, 6, 12], 256);
        assert_eq!(pool.len(), 10);
        // round-robin interleave: consecutive prompts cycle the lengths
        let lens: Vec<usize> = pool.iter().map(|p| p.len()).collect();
        assert_eq!(lens, vec![3, 6, 12, 3, 6, 12, 3, 6, 12, 3]);
        assert!(pool.iter().all(|p| p.iter().all(|&t| t > 0)));
        let set: std::collections::HashSet<_> = pool.iter().cloned().collect();
        assert_eq!(set.len(), 10, "all prompts pairwise distinct");
    }

    #[test]
    fn zipf_is_seeded_and_in_range() {
        let mut a = Zipf::new(50, 1.1, 7);
        let mut b = Zipf::new(50, 1.1, 7);
        let sa: Vec<usize> = (0..500).map(|_| a.sample()).collect();
        let sb: Vec<usize> = (0..500).map(|_| b.sample()).collect();
        assert_eq!(sa, sb, "same (n, s, seed) must reproduce the stream");
        assert!(sa.iter().all(|&r| r < 50));
        let mut c = Zipf::new(50, 1.1, 8);
        let sc: Vec<usize> = (0..500).map(|_| c.sample()).collect();
        assert_ne!(sa, sc, "a different seed must move the stream");
        // degenerate cases stay total
        let mut one = Zipf::new(1, 1.0, 3);
        assert_eq!(one.sample(), 0);
        assert_eq!(one.ranks(), 1);
        let mut uniform = Zipf::new(4, 0.0, 3);
        assert!((0..100).map(|_| uniform.sample()).all(|r| r < 4));
    }

    #[test]
    fn zipf_rank_frequency_follows_the_power_law() {
        // at s = 1.0 rank r is 10x likelier than rank 10*r; pin the shape
        // with a large deterministic draw over 1000 ranks
        let n = 1000;
        let mut z = Zipf::new(n, 1.0, 42);
        let mut freq = vec![0u64; n];
        let draws = 200_000;
        for _ in 0..draws {
            freq[z.sample()] += 1;
        }
        assert!(freq[0] > freq[9] && freq[9] > freq[99], "{:?}", &freq[..10]);
        let ratio = freq[0] as f64 / freq[9].max(1) as f64;
        assert!((7.0..13.0).contains(&ratio), "rank0/rank9 = {ratio}, want ~10");
        let ratio100 = freq[0] as f64 / freq[99].max(1) as f64;
        assert!((70.0..130.0).contains(&ratio100), "rank0/rank99 = {ratio100}, want ~100");
        // the tail is long but populated: a decent share of distinct ranks
        // appear at least once in 200k draws
        let seen = freq.iter().filter(|&&f| f > 0).count();
        assert!(seen > n / 2, "only {seen} of {n} ranks ever sampled");
    }

    #[test]
    fn bench_shows_cache_effect() {
        let rep = run_bench(&tiny()).unwrap();
        // the cached pass must run the frozen forward at most once per
        // distinct prompt; the uncached pass once per *request* modulo
        // within-batch dedupe
        assert!(rep.cached.backbone_rows <= tiny().unique_prompts as u64);
        assert!(rep.uncached.backbone_rows > rep.cached.backbone_rows);
        assert!(rep.cached.hit_rate > 0.5, "hit rate {}", rep.cached.hit_rate);
        // wall-clock speedup is asserted in benches/bench_serve.rs where the
        // workload is big enough to dominate timer noise; here assert the
        // deterministic work ratio that produces it
        assert!(rep.uncached.backbone_rows >= 2 * rep.cached.backbone_rows);
    }

    #[test]
    fn json_report_is_wellformed() {
        let rep = run_bench(&tiny()).unwrap();
        let j = rep.to_json();
        assert!(j.contains("\"bench\": \"serve\""));
        assert!(j.contains("\"preset\": \"small\""));
        assert!(j.contains("\"threads\": 1"));
        assert!(j.contains("\"speedup\""));
        assert!(j.contains("\"cached_hit_rate\""));
        // self-describing shape + backbone storage
        assert!(j.contains("\"d\": 96"));
        assert!(j.contains("\"layers\": 6"));
        assert!(j.contains("\"vocab\": 256"));
        assert!(j.contains("\"backbone\": \"f32\""));
        assert!(j.contains("\"alt_backbone\": \"w4\""));
        assert!(j.contains("\"backbone_bytes_f32\""));
        assert!(j.contains("\"backbone_bytes_w4\""));
        assert!(j.trim_end().ends_with('}'));
    }

    #[test]
    fn w4_primary_reports_shrunk_residency_and_same_work() {
        let mut o = tiny();
        o.backbone = BackboneKind::W4;
        let rep = run_bench(&o).unwrap();
        // primary passes served from the packed backbone
        assert!(rep.to_json().contains("\"backbone\": \"w4\""));
        assert!(
            rep.backbone_bytes(BackboneKind::W4) * 5 <= rep.backbone_bytes(BackboneKind::F32),
            "w4 {} vs f32 {}",
            rep.backbone_bytes(BackboneKind::W4),
            rep.backbone_bytes(BackboneKind::F32)
        );
        assert!(rep.backbone_bytes_ratio() >= 5.0);
        // storage kind is a memory knob, not a scheduling knob: identical
        // deterministic work accounting as the f32 run
        let f32_rep = run_bench(&tiny()).unwrap();
        assert_eq!(rep.cached.backbone_rows, f32_rep.cached.backbone_rows);
        assert_eq!(rep.cached.hit_rate, f32_rep.cached.hit_rate);
    }

    #[test]
    fn threaded_pass_preserves_work_counts() {
        // threading is a wall-clock knob: the deterministic work accounting
        // (backbone rows, hit rate) must not move with the worker count
        let base = run_bench(&tiny()).unwrap();
        let mut o = tiny();
        o.threads = 4;
        let threaded = run_bench(&o).unwrap();
        assert_eq!(base.cached.backbone_rows, threaded.cached.backbone_rows);
        assert_eq!(base.uncached.backbone_rows, threaded.uncached.backbone_rows);
        assert_eq!(base.cached.hit_rate, threaded.cached.hit_rate);
    }

    #[test]
    fn large_preset_runs_the_same_workload() {
        let mut o = tiny();
        o.preset = EnginePreset::Large;
        o.requests = 12;
        o.burst = 6;
        o.threads = 2;
        let rep = run_bench(&o).unwrap();
        assert!(rep.cached.backbone_rows <= o.unique_prompts as u64);
        assert!(rep.to_json().contains("\"preset\": \"large\""));
    }

    #[test]
    fn pool_capacity_enforced_and_len1_distinct() {
        let mut rng = Rng::new(2);
        let pool = prompt_pool(&mut rng, 200, 1, 256);
        let set: std::collections::HashSet<_> = pool.iter().cloned().collect();
        assert_eq!(set.len(), 200, "len-1 prompts must still be pairwise distinct");
        assert_eq!(prompt_pool_capacity(1, 256), 255);
        let mut o = tiny();
        o.unique_prompts = 300;
        o.prompt_len = 1;
        assert!(run_bench(&o).is_err(), "over-capacity unique-prompts must be rejected");
    }

    #[test]
    fn rejects_overlong_prompts() {
        let mut o = tiny();
        o.prompt_len = 32; // > seq 16
        assert!(run_bench(&o).is_err());
    }

    #[test]
    fn overhead_probe_is_finite_and_nonnegative() {
        let rep = run_bench(&tiny()).unwrap();
        assert!(rep.trace_off_overhead_pct.is_finite());
        assert!(rep.trace_off_overhead_pct >= 0.0);
        assert!(rep.traced.is_none() && rep.trace_spans == 0);
        assert!(rep.to_json().contains("\"trace_off_overhead_pct\""));
        // cache on/off digest parity held (run_bench refuses otherwise)
        assert_eq!(rep.cached.digest, rep.uncached.digest);
        assert_ne!(rep.cached.digest, 0);
    }

    #[test]
    fn traced_replay_matches_untraced_bits_and_covers_the_lifecycle() {
        // serializes against the obs unit tests — the recorder is
        // process-global
        let _g = crate::obs::test_lock();
        let path = std::env::temp_dir().join("qst_bench_serve_trace_test.json");
        let mut o = tiny();
        // engage the prefix index so prefix_resume spans appear; small
        // bursts spread first-appearances across drains, so later family
        // members find their donor already cached (prefix donors are
        // looked up in the cache, not within the same micro-batch)
        o.prefix_block = 4;
        o.burst = 2;
        o.trace_out = Some(path.to_string_lossy().into_owned());
        let rep = run_bench(&o).unwrap();
        let t = rep.traced.as_ref().expect("traced pass ran");
        assert_eq!(t.digest, rep.cached.digest, "tracing must not change one bit");
        assert!(rep.trace_spans > 0);
        for k in
            ["admit", "route", "shard_queue", "batch_assemble", "backbone", "prefix_resume", "sidenet", "respond"]
        {
            assert!(rep.trace_kinds.iter().any(|s| s == k), "missing span kind {k}: {:?}", rep.trace_kinds);
        }
        assert!(t.prefix_resumes > 0, "shared-prefix workload must resume prefixes");
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.starts_with("{\"displayTimeUnit\""));
        assert!(body.contains("\"traceEvents\""));
        let j = rep.to_json();
        assert!(j.contains("\"trace_parity\": 1"));
        assert!(j.contains("\"trace_kinds\""));
        let _ = std::fs::remove_file(&path);
    }
}
