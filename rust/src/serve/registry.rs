//! Hot-swappable side-network registry.
//!
//! One quantized backbone is shared by every task; what differs per task is
//! a tiny side network (≤1% of backbone params).  The registry keeps side
//! networks resident under a byte budget with LRU eviction, remembers where
//! each one came from (a `coordinator::checkpoint` file, a synthetic seed,
//! or a content-addressed artifact in an attached [`crate::store`] backend),
//! and transparently reloads evicted entries on demand — so a server can
//! advertise far more tasks than fit in memory at once.  Every cold load is
//! timed into [`Registry::swap_hist`]; eviction counts feed the health
//! plane as `qst_registry_evictions_total`.

use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::Checkpoint;
use crate::costmodel::paperdims::PaperModel;
use crate::obs::LogHistogram;
use crate::tensor::HostTensor;

/// A loaded side network: the per-task trainable state bound to the shared
/// backbone.  `seed` is a stable fingerprint of the weights (used by the
/// synthetic engine to derive deterministic per-task functions; the
/// executor engine uses `tensors` directly).
#[derive(Clone, Debug)]
pub struct SideNetwork {
    pub task: String,
    pub seed: u64,
    pub tensors: HashMap<String, HostTensor>,
    bytes: usize,
}

impl SideNetwork {
    pub fn bytes(&self) -> usize {
        self.bytes
    }
}

/// Where a side network can be (re)loaded from after eviction.
#[derive(Clone, Debug)]
enum Source {
    /// a `coordinator::checkpoint` file; `digest` is the tensors'
    /// fingerprint taken **once** at registration — reloads verify it
    /// instead of silently re-deriving the seed from whatever the file
    /// holds now
    Checkpoint { path: PathBuf, digest: u64 },
    Synthetic { seed: u64, bytes: usize },
    /// a content-addressed artifact in the attached [`crate::store`]
    /// backend; sections are streamed by ranged reads on every swap-in
    Store { id: u64 },
}

/// Nominal registry bytes charged per *synthetic* task (seed-derived side
/// nets carry no tensors, so residency is a bookkeeping figure).  Shared by
/// `qst serve --synthetic`, the gateway shards, and the cost model
/// (`costmodel::memory::gateway_resident_bytes`), so the analytical and
/// live registries agree exactly.
pub const SYNTHETIC_TASK_BYTES: usize = 1 << 16;

/// LRU, byte-budgeted residency manager for side networks.
pub struct Registry {
    budget: usize,
    resident: HashMap<String, (Rc<SideNetwork>, u64)>,
    /// tick -> task, oldest first
    lru: BTreeMap<u64, String>,
    sources: HashMap<String, Source>,
    bytes: usize,
    tick: u64,
    /// cold loads from a source (initial registration + post-eviction reloads)
    pub loads: u64,
    pub evictions: u64,
    /// wall-clock seconds of every cold load (registration included) —
    /// rendered as `qst_swap_in_seconds` and merged fleet-wide
    pub swap_hist: LogHistogram,
    /// artifact store `Source::Store` tasks resolve through
    store: Option<Rc<dyn crate::store::Storage>>,
}

/// Fingerprint a checkpoint's tensors (name-sorted FNV-1a over names+bytes).
fn fingerprint(tensors: &HashMap<String, HostTensor>) -> u64 {
    const FNV_PRIME: u64 = 0x100000001b3;
    let mut names: Vec<&String> = tensors.keys().collect();
    names.sort();
    let mut h: u64 = 0xcbf29ce484222325;
    let mut mix = |bytes: &[u8], h: &mut u64| {
        for &b in bytes {
            *h ^= b as u64;
            *h = h.wrapping_mul(FNV_PRIME);
        }
    };
    for name in names {
        mix(name.as_bytes(), &mut h);
        mix(&tensors[name].data, &mut h);
    }
    h
}

impl Registry {
    pub fn new(budget_bytes: usize) -> Self {
        Registry {
            budget: budget_bytes,
            resident: HashMap::new(),
            lru: BTreeMap::new(),
            sources: HashMap::new(),
            bytes: 0,
            tick: 0,
            loads: 0,
            evictions: 0,
            swap_hist: LogHistogram::default(),
            store: None,
        }
    }

    /// Attach the content-addressed store that [`Registry::register_store`]
    /// tasks load from.  Backends are object-store shaped (`put` / `len` /
    /// ranged reads), so a worker's in-memory store and a local directory
    /// plug in identically.
    pub fn attach_store(&mut self, store: Rc<dyn crate::store::Storage>) {
        self.store = Some(store);
    }

    /// A sensible residency budget for `n_tasks` QST side networks of a
    /// paper-scale model: the cost model's 16-bit side-network footprint
    /// plus 25% slack for per-task bookkeeping.
    pub fn suggested_budget(m: &PaperModel, n_tasks: usize) -> usize {
        let per_task = crate::costmodel::memory::side_network_bytes(m, 16) * 1.25;
        (per_task as usize).max(1) * n_tasks.max(1)
    }

    /// Register a task backed by a side checkpoint on disk and load it.
    /// The tensors are fingerprinted **once**, here; post-eviction reloads
    /// verify the stored digest instead of re-deriving the seed, so a
    /// checkpoint mutated on disk surfaces as a typed error (re-register
    /// to hot-swap new weights deliberately).
    pub fn register_checkpoint(&mut self, task: &str, path: &std::path::Path) -> Result<()> {
        let t0 = Instant::now();
        let ckpt = Checkpoint::load(path)
            .with_context(|| format!("loading side network for '{task}'"))?;
        if ckpt.tensors.is_empty() {
            bail!("side checkpoint {} has no tensors", path.display());
        }
        let digest = fingerprint(&ckpt.tensors);
        let bytes = ckpt.total_bytes();
        self.sources
            .insert(task.to_string(), Source::Checkpoint { path: path.to_path_buf(), digest });
        let net =
            SideNetwork { task: task.to_string(), seed: digest, tensors: ckpt.tensors, bytes };
        self.install(task, net);
        self.swap_hist.record(t0.elapsed().as_secs_f64());
        Ok(())
    }

    /// Register a task backed by a content-addressed artifact in the
    /// attached store and load it, streaming only the sections it needs.
    /// A failed load (junk bytes, missing id) restores whatever source
    /// the task had before, so a bad `Deploy` can never shadow a task
    /// that was serving.
    pub fn register_store(&mut self, task: &str, id: u64) -> Result<()> {
        ensure!(self.store.is_some(), "no artifact store attached (call attach_store first)");
        let prev = self.sources.insert(task.to_string(), Source::Store { id });
        if let Err(e) = self.load(task) {
            match prev {
                Some(p) => {
                    self.sources.insert(task.to_string(), p);
                }
                None => {
                    self.sources.remove(task);
                }
            }
            return Err(e);
        }
        Ok(())
    }

    /// Register a synthetic task (no tensors; the engine derives weights
    /// from `seed`).  `approx_bytes` is what it counts against the budget.
    pub fn register_synthetic(&mut self, task: &str, seed: u64, approx_bytes: usize) -> Result<()> {
        self.sources.insert(task.to_string(), Source::Synthetic { seed, bytes: approx_bytes });
        self.load(task)?;
        Ok(())
    }

    /// Is this task known (resident or reloadable)?
    pub fn contains(&self, task: &str) -> bool {
        self.sources.contains_key(task)
    }

    pub fn resident_count(&self) -> usize {
        self.resident.len()
    }

    pub fn known_tasks(&self) -> Vec<String> {
        let mut v: Vec<String> = self.sources.keys().cloned().collect();
        v.sort();
        v
    }

    /// Resident tasks in LRU order (oldest first) — for tests/introspection.
    pub fn resident_lru_order(&self) -> Vec<String> {
        self.lru.values().cloned().collect()
    }

    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Fetch a task's side network, marking it most-recently-used.  Evicted
    /// entries are reloaded from their source (counted in `loads`).
    pub fn get(&mut self, task: &str) -> Result<Rc<SideNetwork>> {
        if !self.resident.contains_key(task) {
            self.load(task)?;
        }
        let (net, tick) = self.resident.get_mut(task).expect("loaded above");
        self.lru.remove(tick);
        self.tick += 1;
        *tick = self.tick;
        self.lru.insert(self.tick, task.to_string());
        Ok(net.clone())
    }

    fn load(&mut self, task: &str) -> Result<()> {
        let t0 = Instant::now();
        let source = self
            .sources
            .get(task)
            .with_context(|| format!("task '{task}' is not registered"))?
            .clone();
        let net = match source {
            Source::Checkpoint { path, digest } => {
                let ckpt = Checkpoint::load(&path)
                    .with_context(|| format!("loading side network for '{task}'"))?;
                if ckpt.tensors.is_empty() {
                    bail!("side checkpoint {} has no tensors", path.display());
                }
                // registration fingerprinted these tensors; a reload only
                // verifies — a mismatch means the file changed on disk
                // underneath a task that is still advertised with the old
                // weights
                let got = fingerprint(&ckpt.tensors);
                if got != digest {
                    bail!(
                        "side checkpoint {} changed on disk since registration \
                         (digest {got:016x}, registered {digest:016x}); \
                         re-register to hot-swap new weights",
                        path.display()
                    );
                }
                let bytes = ckpt.total_bytes();
                SideNetwork { task: task.to_string(), seed: digest, tensors: ckpt.tensors, bytes }
            }
            Source::Synthetic { seed, bytes } => {
                SideNetwork { task: task.to_string(), seed, tensors: HashMap::new(), bytes }
            }
            Source::Store { id } => {
                let store = self
                    .store
                    .clone()
                    .with_context(|| format!("task '{task}' is store-backed but no store is attached"))?;
                self.load_from_store(task, store.as_ref(), id)?
            }
        };
        self.install(task, net);
        self.swap_hist.record(t0.elapsed().as_secs_f64());
        Ok(())
    }

    /// Materialize a side network from a sectioned artifact.  The reader
    /// issues one ranged read for the index and one per section actually
    /// consumed — the artifact as a whole is never pulled into memory.
    fn load_from_store(
        &self,
        task: &str,
        store: &dyn crate::store::Storage,
        id: u64,
    ) -> Result<SideNetwork> {
        let reader = crate::store::ArtifactReader::open(store, id)
            .with_context(|| format!("opening artifact {id:016x} for '{task}'"))?;
        if reader.has(crate::store::SECTION_SYNTHETIC) {
            let raw = reader.section(store, crate::store::SECTION_SYNTHETIC)?;
            ensure!(
                raw.len() == 16,
                "synthetic section of artifact {id:016x} is {} bytes (want 16)",
                raw.len()
            );
            let seed = u64::from_le_bytes(raw[0..8].try_into().expect("length checked"));
            let bytes = u64::from_le_bytes(raw[8..16].try_into().expect("length checked")) as usize;
            return Ok(SideNetwork { task: task.to_string(), seed, tensors: HashMap::new(), bytes });
        }
        let names: Vec<String> = reader.section_names().iter().map(|s| s.to_string()).collect();
        let mut tensors = HashMap::new();
        let mut bytes = 0usize;
        for name in &names {
            let Some(t_name) = name.strip_prefix(crate::store::TENSOR_SECTION_PREFIX) else {
                continue;
            };
            let raw = reader.section(store, name)?;
            let t = crate::store::decode_tensor_section(&raw)
                .with_context(|| format!("decoding section '{name}' of artifact {id:016x}"))?;
            bytes += t.data.len();
            tensors.insert(t_name.to_string(), t);
        }
        ensure!(!tensors.is_empty(), "artifact {id:016x} has no tensor or synthetic sections");
        // the artifact id *is* the content fingerprint — tasks deployed
        // from identical bytes derive identical side networks everywhere
        Ok(SideNetwork { task: task.to_string(), seed: id, tensors, bytes })
    }

    /// Hot-swap + evict-to-fit + insert: the shared tail of every cold load.
    fn install(&mut self, task: &str, net: SideNetwork) {
        // hot-swap: drop any previous residency of this task first
        if let Some((old, tick)) = self.resident.remove(task) {
            self.lru.remove(&tick);
            self.bytes -= old.bytes;
        }
        // evict LRU entries until the new network fits; a single network
        // larger than the whole budget is allowed to reside alone.
        while self.bytes + net.bytes > self.budget && !self.lru.is_empty() {
            let (&oldest_tick, _) = self.lru.iter().next().expect("non-empty");
            let victim = self.lru.remove(&oldest_tick).expect("tick present");
            if let Some((old, _)) = self.resident.remove(&victim) {
                self.bytes -= old.bytes;
                self.evictions += 1;
            }
        }
        self.bytes += net.bytes;
        self.tick += 1;
        self.lru.insert(self.tick, task.to_string());
        self.resident.insert(task.to_string(), (Rc::new(net), self.tick));
        self.loads += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("qst_serve_reg_{}_{}", std::process::id(), name))
    }

    fn side_ckpt(path: &std::path::Path, tag: f32, floats: usize) {
        let mut tensors = HashMap::new();
        tensors.insert("side.w".to_string(), HostTensor::from_f32(&[floats], &vec![tag; floats]));
        Checkpoint::new(tensors).save(path).unwrap();
    }

    #[test]
    fn loads_checkpoint_and_fingerprints() {
        let p = tmpfile("a.ckpt");
        side_ckpt(&p, 1.0, 8);
        let mut r = Registry::new(1 << 20);
        r.register_checkpoint("a", &p).unwrap();
        let net = r.get("a").unwrap();
        assert_eq!(net.task, "a");
        assert_eq!(net.bytes(), 32);
        assert!(net.seed != 0);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn distinct_checkpoints_distinct_seeds() {
        let (pa, pb) = (tmpfile("fa.ckpt"), tmpfile("fb.ckpt"));
        side_ckpt(&pa, 1.0, 8);
        side_ckpt(&pb, 2.0, 8);
        let mut r = Registry::new(1 << 20);
        r.register_checkpoint("a", &pa).unwrap();
        r.register_checkpoint("b", &pb).unwrap();
        assert_ne!(r.get("a").unwrap().seed, r.get("b").unwrap().seed);
        std::fs::remove_file(pa).ok();
        std::fs::remove_file(pb).ok();
    }

    #[test]
    fn evicts_lru_and_reloads_from_disk() {
        let paths: Vec<PathBuf> = (0..3).map(|i| tmpfile(&format!("ev{i}.ckpt"))).collect();
        for (i, p) in paths.iter().enumerate() {
            side_ckpt(p, i as f32, 64); // 256 bytes each
        }
        let mut r = Registry::new(600); // fits two
        r.register_checkpoint("t0", &paths[0]).unwrap();
        r.register_checkpoint("t1", &paths[1]).unwrap();
        assert_eq!(r.resident_count(), 2);
        r.get("t0").unwrap(); // t1 becomes LRU
        r.register_checkpoint("t2", &paths[2]).unwrap();
        assert_eq!(r.resident_count(), 2);
        assert_eq!(r.evictions, 1);
        assert_eq!(r.resident_lru_order(), vec!["t0", "t2"]);
        // evicted task transparently reloads, evicting the current LRU (t0)
        let loads_before = r.loads;
        let net = r.get("t1").unwrap();
        assert_eq!(net.task, "t1");
        assert_eq!(r.loads, loads_before + 1);
        assert!(r.bytes() <= 600);
        for p in paths {
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn synthetic_tasks_need_no_disk() {
        let mut r = Registry::new(1 << 20);
        r.register_synthetic("s0", 7, 1000).unwrap();
        let net = r.get("s0").unwrap();
        assert_eq!(net.seed, 7);
        assert!(net.tensors.is_empty());
        assert_eq!(r.bytes(), 1000);
    }

    #[test]
    fn suggested_budget_scales_with_tasks() {
        let m = crate::costmodel::paper_model("LLaMA-2-7B").unwrap();
        let one = Registry::suggested_budget(m, 1);
        let ten = Registry::suggested_budget(m, 10);
        assert!(one > 0);
        assert_eq!(ten, one * 10);
    }

    #[test]
    fn unknown_task_errors() {
        let mut r = Registry::new(1 << 20);
        assert!(r.get("nope").is_err());
        assert!(!r.contains("nope"));
    }

    #[test]
    fn hot_swap_replaces_without_leaking_bytes() {
        let p = tmpfile("swap.ckpt");
        side_ckpt(&p, 1.0, 64);
        let mut r = Registry::new(1 << 20);
        r.register_checkpoint("a", &p).unwrap();
        let seed1 = r.get("a").unwrap().seed;
        side_ckpt(&p, 9.0, 64); // new weights, same path
        r.register_checkpoint("a", &p).unwrap();
        assert_eq!(r.resident_count(), 1);
        assert_eq!(r.bytes(), 256);
        assert_ne!(r.get("a").unwrap().seed, seed1, "swap must pick up new weights");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn oversize_network_resides_alone() {
        let mut r = Registry::new(100);
        r.register_synthetic("small", 1, 50).unwrap();
        r.register_synthetic("big", 2, 500).unwrap();
        assert_eq!(r.resident_count(), 1);
        assert_eq!(r.resident_lru_order(), vec!["big"]);
    }

    #[test]
    fn mutated_checkpoint_fails_verification_on_reload() {
        let (pa, pb) = (tmpfile("mut_a.ckpt"), tmpfile("mut_b.ckpt"));
        side_ckpt(&pa, 1.0, 64); // 256 bytes
        side_ckpt(&pb, 2.0, 64);
        let mut r = Registry::new(300); // fits one
        r.register_checkpoint("a", &pa).unwrap();
        side_ckpt(&pa, 5.0, 64); // mutate on disk behind the registry's back
        r.register_checkpoint("b", &pb).unwrap(); // evicts "a"
        assert_eq!(r.evictions, 1);
        let err = r.get("a").unwrap_err();
        assert!(
            format!("{err:#}").contains("changed on disk"),
            "want a digest-mismatch error, got: {err:#}"
        );
        // deliberate hot-swap still works: re-registering fingerprints anew
        r.register_checkpoint("a", &pa).unwrap();
        assert_eq!(r.get("a").unwrap().task, "a");
        std::fs::remove_file(pa).ok();
        std::fs::remove_file(pb).ok();
    }

    #[test]
    fn store_backed_tasks_register_evict_and_reload() {
        use crate::store::Storage;
        let store = Rc::new(crate::store::Mem::new());
        let mut r = Registry::new(1500);
        r.attach_store(store.clone());
        let a1 = crate::store::side_artifact_synthetic(7, 1000);
        let id1 = store.put(&a1).unwrap();
        r.register_store("s0", id1).unwrap();
        assert_eq!(r.get("s0").unwrap().seed, 7);
        assert_eq!(r.bytes(), 1000);
        // parity: a store-backed synthetic task derives the same side
        // network key as a directly registered synthetic one
        let mut plain = Registry::new(1 << 20);
        plain.register_synthetic("s0", 7, 1000).unwrap();
        assert_eq!(plain.get("s0").unwrap().seed, r.get("s0").unwrap().seed);
        // a second artifact evicts the first; the evictee reloads by
        // streaming the artifact back out of the store
        let id2 = store.put(&crate::store::side_artifact_synthetic(8, 1000)).unwrap();
        r.register_store("s1", id2).unwrap();
        assert_eq!(r.resident_count(), 1);
        assert_eq!(r.evictions, 1);
        let loads = r.loads;
        assert_eq!(r.get("s0").unwrap().seed, 7);
        assert_eq!(r.loads, loads + 1);
    }

    #[test]
    fn tensor_artifacts_stream_into_side_networks() {
        use crate::store::Storage;
        let store = Rc::new(crate::store::Mem::new());
        let mut tensors = HashMap::new();
        tensors.insert("side.w".to_string(), HostTensor::from_f32(&[8], &vec![1.5f32; 8]));
        tensors.insert("side.b".to_string(), HostTensor::from_f32(&[2], &vec![0.5f32; 2]));
        let bytes = crate::store::side_artifact_from_tensors(&tensors);
        let id = store.put(&bytes).unwrap();
        let mut r = Registry::new(1 << 20);
        r.attach_store(store);
        r.register_store("t", id).unwrap();
        let net = r.get("t").unwrap();
        assert_eq!(net.seed, id, "tensor artifacts key the engine off their content id");
        assert_eq!(net.tensors.len(), 2);
        assert_eq!(net.tensors["side.w"].as_f32().unwrap(), vec![1.5f32; 8]);
        assert_eq!(net.tensors["side.b"].as_f32().unwrap(), vec![0.5f32; 2]);
        assert_eq!(net.bytes(), 40);
    }

    #[test]
    fn swap_hist_records_every_cold_load() {
        let mut r = Registry::new(100);
        r.register_synthetic("a", 1, 80).unwrap();
        r.register_synthetic("b", 2, 80).unwrap(); // evicts a
        assert_eq!(r.swap_hist.count(), 2);
        r.get("a").unwrap(); // post-eviction reload is a cold load too
        assert_eq!(r.swap_hist.count(), 3);
        assert_eq!(r.loads, 3);
        r.get("a").unwrap(); // resident hit: not a swap-in
        assert_eq!(r.swap_hist.count(), 3);
    }

    #[test]
    fn register_store_without_store_is_a_typed_error() {
        let mut r = Registry::new(1 << 20);
        assert!(r.register_store("x", 1).is_err());
        assert!(!r.contains("x"));
    }

    #[test]
    fn failed_store_register_restores_previous_source() {
        use crate::store::Storage;
        let store = Rc::new(crate::store::Mem::new());
        let mut r = Registry::new(1 << 20);
        r.attach_store(store.clone());
        r.register_synthetic("t", 3, 100).unwrap();
        let junk = store.put(b"not an artifact").unwrap();
        assert!(r.register_store("t", junk).is_err());
        assert_eq!(r.get("t").unwrap().seed, 3, "the old source must keep serving");
        // and a fresh name that fails leaves no phantom registration
        assert!(r.register_store("ghost", junk).is_err());
        assert!(!r.contains("ghost"));
    }
}
