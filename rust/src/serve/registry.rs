//! Hot-swappable side-network registry.
//!
//! One quantized backbone is shared by every task; what differs per task is
//! a tiny side network (≤1% of backbone params).  The registry keeps side
//! networks resident under a byte budget with LRU eviction, remembers where
//! each one came from (a `coordinator::checkpoint` file or a synthetic
//! seed), and transparently reloads evicted entries on demand — so a server
//! can advertise far more tasks than fit in memory at once.

use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::coordinator::Checkpoint;
use crate::costmodel::paperdims::PaperModel;
use crate::tensor::HostTensor;

/// A loaded side network: the per-task trainable state bound to the shared
/// backbone.  `seed` is a stable fingerprint of the weights (used by the
/// synthetic engine to derive deterministic per-task functions; the
/// executor engine uses `tensors` directly).
#[derive(Clone, Debug)]
pub struct SideNetwork {
    pub task: String,
    pub seed: u64,
    pub tensors: HashMap<String, HostTensor>,
    bytes: usize,
}

impl SideNetwork {
    pub fn bytes(&self) -> usize {
        self.bytes
    }
}

/// Where a side network can be (re)loaded from after eviction.
#[derive(Clone, Debug)]
enum Source {
    Checkpoint(PathBuf),
    Synthetic { seed: u64, bytes: usize },
}

/// Nominal registry bytes charged per *synthetic* task (seed-derived side
/// nets carry no tensors, so residency is a bookkeeping figure).  Shared by
/// `qst serve --synthetic`, the gateway shards, and the cost model
/// (`costmodel::memory::gateway_resident_bytes`), so the analytical and
/// live registries agree exactly.
pub const SYNTHETIC_TASK_BYTES: usize = 1 << 16;

/// LRU, byte-budgeted residency manager for side networks.
pub struct Registry {
    budget: usize,
    resident: HashMap<String, (Rc<SideNetwork>, u64)>,
    /// tick -> task, oldest first
    lru: BTreeMap<u64, String>,
    sources: HashMap<String, Source>,
    bytes: usize,
    tick: u64,
    /// cold loads from a source (initial registration + post-eviction reloads)
    pub loads: u64,
    pub evictions: u64,
}

/// Fingerprint a checkpoint's tensors (name-sorted FNV-1a over names+bytes).
fn fingerprint(tensors: &HashMap<String, HostTensor>) -> u64 {
    const FNV_PRIME: u64 = 0x100000001b3;
    let mut names: Vec<&String> = tensors.keys().collect();
    names.sort();
    let mut h: u64 = 0xcbf29ce484222325;
    let mut mix = |bytes: &[u8], h: &mut u64| {
        for &b in bytes {
            *h ^= b as u64;
            *h = h.wrapping_mul(FNV_PRIME);
        }
    };
    for name in names {
        mix(name.as_bytes(), &mut h);
        mix(&tensors[name].data, &mut h);
    }
    h
}

impl Registry {
    pub fn new(budget_bytes: usize) -> Self {
        Registry {
            budget: budget_bytes,
            resident: HashMap::new(),
            lru: BTreeMap::new(),
            sources: HashMap::new(),
            bytes: 0,
            tick: 0,
            loads: 0,
            evictions: 0,
        }
    }

    /// A sensible residency budget for `n_tasks` QST side networks of a
    /// paper-scale model: the cost model's 16-bit side-network footprint
    /// plus 25% slack for per-task bookkeeping.
    pub fn suggested_budget(m: &PaperModel, n_tasks: usize) -> usize {
        let per_task = crate::costmodel::memory::side_network_bytes(m, 16) * 1.25;
        (per_task as usize).max(1) * n_tasks.max(1)
    }

    /// Register a task backed by a side checkpoint on disk and load it.
    pub fn register_checkpoint(&mut self, task: &str, path: &std::path::Path) -> Result<()> {
        self.sources.insert(task.to_string(), Source::Checkpoint(path.to_path_buf()));
        self.load(task)?;
        Ok(())
    }

    /// Register a synthetic task (no tensors; the engine derives weights
    /// from `seed`).  `approx_bytes` is what it counts against the budget.
    pub fn register_synthetic(&mut self, task: &str, seed: u64, approx_bytes: usize) -> Result<()> {
        self.sources.insert(task.to_string(), Source::Synthetic { seed, bytes: approx_bytes });
        self.load(task)?;
        Ok(())
    }

    /// Is this task known (resident or reloadable)?
    pub fn contains(&self, task: &str) -> bool {
        self.sources.contains_key(task)
    }

    pub fn resident_count(&self) -> usize {
        self.resident.len()
    }

    pub fn known_tasks(&self) -> Vec<String> {
        let mut v: Vec<String> = self.sources.keys().cloned().collect();
        v.sort();
        v
    }

    /// Resident tasks in LRU order (oldest first) — for tests/introspection.
    pub fn resident_lru_order(&self) -> Vec<String> {
        self.lru.values().cloned().collect()
    }

    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Fetch a task's side network, marking it most-recently-used.  Evicted
    /// entries are reloaded from their source (counted in `loads`).
    pub fn get(&mut self, task: &str) -> Result<Rc<SideNetwork>> {
        if !self.resident.contains_key(task) {
            self.load(task)?;
        }
        let (net, tick) = self.resident.get_mut(task).expect("loaded above");
        self.lru.remove(tick);
        self.tick += 1;
        *tick = self.tick;
        self.lru.insert(self.tick, task.to_string());
        Ok(net.clone())
    }

    fn load(&mut self, task: &str) -> Result<()> {
        let source = self
            .sources
            .get(task)
            .with_context(|| format!("task '{task}' is not registered"))?
            .clone();
        let net = match source {
            Source::Checkpoint(path) => {
                let ckpt = Checkpoint::load(&path)
                    .with_context(|| format!("loading side network for '{task}'"))?;
                if ckpt.tensors.is_empty() {
                    bail!("side checkpoint {} has no tensors", path.display());
                }
                let bytes = ckpt.total_bytes();
                SideNetwork { task: task.to_string(), seed: fingerprint(&ckpt.tensors), tensors: ckpt.tensors, bytes }
            }
            Source::Synthetic { seed, bytes } => {
                SideNetwork { task: task.to_string(), seed, tensors: HashMap::new(), bytes }
            }
        };
        // hot-swap: drop any previous residency of this task first
        if let Some((old, tick)) = self.resident.remove(task) {
            self.lru.remove(&tick);
            self.bytes -= old.bytes;
        }
        // evict LRU entries until the new network fits; a single network
        // larger than the whole budget is allowed to reside alone.
        while self.bytes + net.bytes > self.budget && !self.lru.is_empty() {
            let (&oldest_tick, _) = self.lru.iter().next().expect("non-empty");
            let victim = self.lru.remove(&oldest_tick).expect("tick present");
            if let Some((old, _)) = self.resident.remove(&victim) {
                self.bytes -= old.bytes;
                self.evictions += 1;
            }
        }
        self.bytes += net.bytes;
        self.tick += 1;
        self.lru.insert(self.tick, task.to_string());
        self.resident.insert(task.to_string(), (Rc::new(net), self.tick));
        self.loads += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("qst_serve_reg_{}_{}", std::process::id(), name))
    }

    fn side_ckpt(path: &std::path::Path, tag: f32, floats: usize) {
        let mut tensors = HashMap::new();
        tensors.insert("side.w".to_string(), HostTensor::from_f32(&[floats], &vec![tag; floats]));
        Checkpoint::new(tensors).save(path).unwrap();
    }

    #[test]
    fn loads_checkpoint_and_fingerprints() {
        let p = tmpfile("a.ckpt");
        side_ckpt(&p, 1.0, 8);
        let mut r = Registry::new(1 << 20);
        r.register_checkpoint("a", &p).unwrap();
        let net = r.get("a").unwrap();
        assert_eq!(net.task, "a");
        assert_eq!(net.bytes(), 32);
        assert!(net.seed != 0);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn distinct_checkpoints_distinct_seeds() {
        let (pa, pb) = (tmpfile("fa.ckpt"), tmpfile("fb.ckpt"));
        side_ckpt(&pa, 1.0, 8);
        side_ckpt(&pb, 2.0, 8);
        let mut r = Registry::new(1 << 20);
        r.register_checkpoint("a", &pa).unwrap();
        r.register_checkpoint("b", &pb).unwrap();
        assert_ne!(r.get("a").unwrap().seed, r.get("b").unwrap().seed);
        std::fs::remove_file(pa).ok();
        std::fs::remove_file(pb).ok();
    }

    #[test]
    fn evicts_lru_and_reloads_from_disk() {
        let paths: Vec<PathBuf> = (0..3).map(|i| tmpfile(&format!("ev{i}.ckpt"))).collect();
        for (i, p) in paths.iter().enumerate() {
            side_ckpt(p, i as f32, 64); // 256 bytes each
        }
        let mut r = Registry::new(600); // fits two
        r.register_checkpoint("t0", &paths[0]).unwrap();
        r.register_checkpoint("t1", &paths[1]).unwrap();
        assert_eq!(r.resident_count(), 2);
        r.get("t0").unwrap(); // t1 becomes LRU
        r.register_checkpoint("t2", &paths[2]).unwrap();
        assert_eq!(r.resident_count(), 2);
        assert_eq!(r.evictions, 1);
        assert_eq!(r.resident_lru_order(), vec!["t0", "t2"]);
        // evicted task transparently reloads, evicting the current LRU (t0)
        let loads_before = r.loads;
        let net = r.get("t1").unwrap();
        assert_eq!(net.task, "t1");
        assert_eq!(r.loads, loads_before + 1);
        assert!(r.bytes() <= 600);
        for p in paths {
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn synthetic_tasks_need_no_disk() {
        let mut r = Registry::new(1 << 20);
        r.register_synthetic("s0", 7, 1000).unwrap();
        let net = r.get("s0").unwrap();
        assert_eq!(net.seed, 7);
        assert!(net.tensors.is_empty());
        assert_eq!(r.bytes(), 1000);
    }

    #[test]
    fn suggested_budget_scales_with_tasks() {
        let m = crate::costmodel::paper_model("LLaMA-2-7B").unwrap();
        let one = Registry::suggested_budget(m, 1);
        let ten = Registry::suggested_budget(m, 10);
        assert!(one > 0);
        assert_eq!(ten, one * 10);
    }

    #[test]
    fn unknown_task_errors() {
        let mut r = Registry::new(1 << 20);
        assert!(r.get("nope").is_err());
        assert!(!r.contains("nope"));
    }

    #[test]
    fn hot_swap_replaces_without_leaking_bytes() {
        let p = tmpfile("swap.ckpt");
        side_ckpt(&p, 1.0, 64);
        let mut r = Registry::new(1 << 20);
        r.register_checkpoint("a", &p).unwrap();
        let seed1 = r.get("a").unwrap().seed;
        side_ckpt(&p, 9.0, 64); // new weights, same path
        r.register_checkpoint("a", &p).unwrap();
        assert_eq!(r.resident_count(), 1);
        assert_eq!(r.bytes(), 256);
        assert_ne!(r.get("a").unwrap().seed, seed1, "swap must pick up new weights");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn oversize_network_resides_alone() {
        let mut r = Registry::new(100);
        r.register_synthetic("small", 1, 50).unwrap();
        r.register_synthetic("big", 2, 500).unwrap();
        assert_eq!(r.resident_count(), 1);
        assert_eq!(r.resident_lru_order(), vec!["big"]);
    }
}
