//! Request queue + per-task micro-batching.
//!
//! Requests for different tasks can't share one side-network dispatch, so
//! the queue groups pending requests by task and forms micro-batches of up
//! to `max_batch`.  Task selection is arrival-ordered (the task owning the
//! oldest pending request goes first) so no task starves.  Rows are padded
//! to the engine's fixed sequence length — the artifact graphs are
//! shape-specialized, so padding happens here, once, before dispatch.

use std::collections::{HashMap, HashSet, VecDeque};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::data::vocabulary::PAD;

/// One pending inference request.
#[derive(Clone, Debug)]
pub struct QueuedRequest {
    pub id: u64,
    pub task: String,
    pub tokens: Vec<i32>,
    pub enqueued: Instant,
}

/// A batch of same-task requests ready for dispatch.
#[derive(Debug)]
pub struct MicroBatch {
    pub task: String,
    pub requests: Vec<QueuedRequest>,
}

/// Multi-task FIFO queue with per-task micro-batching.
#[derive(Default)]
pub struct RequestQueue {
    next_id: u64,
    queues: HashMap<String, VecDeque<QueuedRequest>>,
    /// global arrival order (id, task); stale entries are skipped lazily
    arrivals: VecDeque<(u64, String)>,
    pending_ids: HashSet<u64>,
}

impl RequestQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.pending_ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending_ids.is_empty()
    }

    /// Enqueue a request; returns its id.
    pub fn push(&mut self, task: &str, tokens: Vec<i32>) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        let req = QueuedRequest { id, task: task.to_string(), tokens, enqueued: Instant::now() };
        self.queues.entry(task.to_string()).or_default().push_back(req);
        self.arrivals.push_back((id, task.to_string()));
        self.pending_ids.insert(id);
        id
    }

    /// Next micro-batch: up to `max_batch` requests of the task owning the
    /// oldest pending request.  Returns `None` when the queue is empty.
    pub fn next_batch(&mut self, max_batch: usize) -> Option<MicroBatch> {
        let max_batch = max_batch.max(1);
        loop {
            let (id, task) = self.arrivals.pop_front()?;
            if !self.pending_ids.contains(&id) {
                continue; // already served as part of an earlier batch
            }
            let q = self.queues.get_mut(&task).expect("pending id implies queue");
            let n = q.len().min(max_batch);
            let requests: Vec<QueuedRequest> = q.drain(..n).collect();
            for r in &requests {
                self.pending_ids.remove(&r.id);
            }
            return Some(MicroBatch { task, requests });
        }
    }
}

/// Right-pad a token row with PAD to `seq`; a row longer than `seq` is a
/// caller error (the transport should have truncated or rejected it).
pub fn pad_row(tokens: &[i32], seq: usize) -> Result<Vec<i32>> {
    if tokens.len() > seq {
        bail!("request of {} tokens exceeds the artifact sequence length {}", tokens.len(), seq);
    }
    let mut row = tokens.to_vec();
    row.resize(seq, PAD);
    Ok(row)
}

/// Index of the last non-PAD token of a padded row (0 for an all-PAD row):
/// the position whose logits answer a next-token request.
pub fn query_pos(row: &[i32]) -> usize {
    row.iter().rposition(|&t| t != PAD).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pads_and_rejects_overflow() {
        assert_eq!(pad_row(&[5, 6], 4).unwrap(), vec![5, 6, PAD, PAD]);
        assert_eq!(pad_row(&[], 2).unwrap(), vec![PAD, PAD]);
        assert!(pad_row(&[1, 2, 3], 2).is_err());
    }

    #[test]
    fn query_pos_is_last_non_pad() {
        assert_eq!(query_pos(&[7, 8, PAD, PAD]), 1);
        assert_eq!(query_pos(&[7, PAD, 9, PAD]), 2);
        assert_eq!(query_pos(&[PAD, PAD]), 0);
    }

    #[test]
    fn batches_group_by_task_in_arrival_order() {
        let mut q = RequestQueue::new();
        q.push("a", vec![1]);
        q.push("b", vec![2]);
        q.push("a", vec![3]);
        q.push("b", vec![4]);
        let b1 = q.next_batch(8).unwrap();
        assert_eq!(b1.task, "a");
        assert_eq!(b1.requests.len(), 2);
        let b2 = q.next_batch(8).unwrap();
        assert_eq!(b2.task, "b");
        assert_eq!(b2.requests.len(), 2);
        assert!(q.next_batch(8).is_none());
        assert!(q.is_empty());
    }

    #[test]
    fn respects_max_batch_and_fifo_within_task() {
        let mut q = RequestQueue::new();
        for i in 0..5 {
            q.push("a", vec![i]);
        }
        let b1 = q.next_batch(2).unwrap();
        assert_eq!(b1.requests.iter().map(|r| r.tokens[0]).collect::<Vec<_>>(), vec![0, 1]);
        let b2 = q.next_batch(2).unwrap();
        assert_eq!(b2.requests.iter().map(|r| r.tokens[0]).collect::<Vec<_>>(), vec![2, 3]);
        assert_eq!(q.next_batch(2).unwrap().requests.len(), 1);
    }

    #[test]
    fn no_starvation_across_tasks() {
        let mut q = RequestQueue::new();
        q.push("hot", vec![0]);
        q.push("cold", vec![1]);
        q.push("hot", vec![2]);
        // serving "hot" consumes both hot requests; "cold" must be next even
        // though more "hot" arrivals sit in the arrival queue
        assert_eq!(q.next_batch(8).unwrap().task, "hot");
        q.push("hot", vec![3]);
        assert_eq!(q.next_batch(8).unwrap().task, "cold");
        assert_eq!(q.next_batch(8).unwrap().task, "hot");
    }

    #[test]
    fn ids_are_unique_and_monotonic() {
        let mut q = RequestQueue::new();
        let a = q.push("t", vec![]);
        let b = q.push("t", vec![]);
        assert!(b > a);
    }
}
