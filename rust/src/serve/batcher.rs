//! Request queue + per-task micro-batching.
//!
//! Requests for different tasks can't share one side-network dispatch, so
//! the queue groups pending requests by task and forms micro-batches of up
//! to `max_batch`.  Task selection rotates round-robin across lanes: a
//! lane goes to the back of the rotation after every batch it is served,
//! so a task whose lane stays hot under sustained load cannot starve the
//! others (the old arrival-ordered policy let a hot lane's backlog keep
//! owning the oldest pending request).  Within a lane requests stay FIFO.
//! Rows are padded to the engine's fixed sequence length — the artifact
//! graphs are shape-specialized, so padding happens here, once, before
//! dispatch.

use std::collections::{HashMap, VecDeque};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::data::vocabulary::PAD;

/// One pending inference request.
#[derive(Clone, Debug)]
pub struct QueuedRequest {
    pub id: u64,
    pub task: String,
    pub tokens: Vec<i32>,
    pub enqueued: Instant,
}

/// A batch of same-task requests ready for dispatch.
#[derive(Debug)]
pub struct MicroBatch {
    pub task: String,
    pub requests: Vec<QueuedRequest>,
}

/// Multi-task FIFO queue with per-task micro-batching.
#[derive(Default)]
pub struct RequestQueue {
    next_id: u64,
    queues: HashMap<String, VecDeque<QueuedRequest>>,
    /// round-robin lane rotation: every task with pending requests appears
    /// exactly once; served lanes re-enter at the back
    rotation: VecDeque<String>,
    len: usize,
}

impl RequestQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Enqueue a request; returns its id.
    pub fn push(&mut self, task: &str, tokens: Vec<i32>) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        let req = QueuedRequest { id, task: task.to_string(), tokens, enqueued: Instant::now() };
        let lane = self.queues.entry(task.to_string()).or_default();
        if lane.is_empty() {
            // lane was idle: it joins the rotation at the back, behind
            // every task already waiting for a turn
            self.rotation.push_back(task.to_string());
        }
        lane.push_back(req);
        self.len += 1;
        id
    }

    /// Next micro-batch: up to `max_batch` requests of the task at the
    /// front of the round-robin rotation.  A lane with requests left over
    /// re-enters the rotation at the *back*, so every task is served one
    /// batch per rotation however hot any single lane runs.  Returns
    /// `None` when the queue is empty.
    pub fn next_batch(&mut self, max_batch: usize) -> Option<MicroBatch> {
        let max_batch = max_batch.max(1);
        let task = self.rotation.pop_front()?;
        let q = self.queues.get_mut(&task).expect("rotation entry implies queue");
        let n = q.len().min(max_batch);
        let requests: Vec<QueuedRequest> = q.drain(..n).collect();
        if !q.is_empty() {
            self.rotation.push_back(task.clone());
        }
        self.len -= requests.len();
        Some(MicroBatch { task, requests })
    }

    /// Rolling admission: the next micro-batch sized to the *open* slots —
    /// `max_batch` minus the `inflight` requests already executing
    /// downstream.  This is what a continuously-batching caller uses to
    /// keep a bounded pool of work topped up as requests complete, instead
    /// of draining fully between barriers.  Returns `None` when every slot
    /// is occupied or nothing is pending.
    pub fn refill(&mut self, max_batch: usize, inflight: usize) -> Option<MicroBatch> {
        let open = max_batch.max(1).saturating_sub(inflight);
        if open == 0 {
            return None;
        }
        self.next_batch(open)
    }
}

/// Right-pad a token row with PAD to `seq`; a row longer than `seq` is a
/// caller error (the transport should have truncated or rejected it).
pub fn pad_row(tokens: &[i32], seq: usize) -> Result<Vec<i32>> {
    if tokens.len() > seq {
        bail!("request of {} tokens exceeds the artifact sequence length {}", tokens.len(), seq);
    }
    let mut row = tokens.to_vec();
    row.resize(seq, PAD);
    Ok(row)
}

/// Index of the last non-PAD token of a padded row (0 for an all-PAD row):
/// the position whose logits answer a next-token request.
pub fn query_pos(row: &[i32]) -> usize {
    row.iter().rposition(|&t| t != PAD).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pads_and_rejects_overflow() {
        assert_eq!(pad_row(&[5, 6], 4).unwrap(), vec![5, 6, PAD, PAD]);
        assert_eq!(pad_row(&[], 2).unwrap(), vec![PAD, PAD]);
        assert!(pad_row(&[1, 2, 3], 2).is_err());
    }

    #[test]
    fn query_pos_is_last_non_pad() {
        assert_eq!(query_pos(&[7, 8, PAD, PAD]), 1);
        assert_eq!(query_pos(&[7, PAD, 9, PAD]), 2);
        assert_eq!(query_pos(&[PAD, PAD]), 0);
    }

    #[test]
    fn batches_group_by_task_in_arrival_order() {
        let mut q = RequestQueue::new();
        q.push("a", vec![1]);
        q.push("b", vec![2]);
        q.push("a", vec![3]);
        q.push("b", vec![4]);
        let b1 = q.next_batch(8).unwrap();
        assert_eq!(b1.task, "a");
        assert_eq!(b1.requests.len(), 2);
        let b2 = q.next_batch(8).unwrap();
        assert_eq!(b2.task, "b");
        assert_eq!(b2.requests.len(), 2);
        assert!(q.next_batch(8).is_none());
        assert!(q.is_empty());
    }

    #[test]
    fn respects_max_batch_and_fifo_within_task() {
        let mut q = RequestQueue::new();
        for i in 0..5 {
            q.push("a", vec![i]);
        }
        let b1 = q.next_batch(2).unwrap();
        assert_eq!(b1.requests.iter().map(|r| r.tokens[0]).collect::<Vec<_>>(), vec![0, 1]);
        let b2 = q.next_batch(2).unwrap();
        assert_eq!(b2.requests.iter().map(|r| r.tokens[0]).collect::<Vec<_>>(), vec![2, 3]);
        assert_eq!(q.next_batch(2).unwrap().requests.len(), 1);
    }

    #[test]
    fn no_starvation_across_tasks() {
        let mut q = RequestQueue::new();
        q.push("hot", vec![0]);
        q.push("cold", vec![1]);
        q.push("hot", vec![2]);
        // serving "hot" consumes both hot requests; "cold" must be next even
        // though more "hot" arrivals keep landing
        assert_eq!(q.next_batch(8).unwrap().task, "hot");
        q.push("hot", vec![3]);
        assert_eq!(q.next_batch(8).unwrap().task, "cold");
        assert_eq!(q.next_batch(8).unwrap().task, "hot");
    }

    #[test]
    fn round_robin_rotation_prevents_hot_lane_starvation() {
        // Regression: under the arrival-ordered policy a hot lane with a
        // deep backlog owned the oldest pending request after every batch,
        // so a cold task waited out the hot lane's entire backlog — and
        // newly-arrived hot requests jumped ahead of it.  The rotation
        // sends a served lane to the back: "cold" gets the very next turn.
        let mut q = RequestQueue::new();
        for i in 0..8 {
            q.push("hot", vec![i]);
        }
        q.push("cold", vec![99]);
        let b1 = q.next_batch(2).unwrap();
        assert_eq!(b1.task, "hot");
        // sustained load: the hot lane keeps receiving while it is served
        q.push("hot", vec![100]);
        assert_eq!(q.next_batch(2).unwrap().task, "cold", "cold lane must not starve");
        assert_eq!(q.next_batch(2).unwrap().task, "hot");
        // FIFO within the hot lane survived the rotation
        let b = q.next_batch(8).unwrap();
        assert_eq!(b.requests.iter().map(|r| r.tokens[0]).collect::<Vec<_>>(), vec![4, 5, 6, 7, 100]);
        assert!(q.is_empty());
    }

    #[test]
    fn refill_fills_only_open_slots() {
        let mut q = RequestQueue::new();
        for i in 0..6 {
            q.push("a", vec![i]);
        }
        // 4 slots, 3 in flight: a 1-deep top-up
        let b = q.refill(4, 3).unwrap();
        assert_eq!(b.requests.len(), 1);
        // every slot occupied: nothing is admitted even though work waits
        assert!(q.refill(4, 4).is_none());
        assert!(q.refill(4, 9).is_none());
        assert_eq!(q.len(), 5);
        // slots freed: the pool tops back up
        assert_eq!(q.refill(4, 0).unwrap().requests.len(), 4);
        assert_eq!(q.refill(4, 0).unwrap().requests.len(), 1);
        assert!(q.is_empty());
    }

    #[test]
    fn ids_are_unique_and_monotonic() {
        let mut q = RequestQueue::new();
        let a = q.push("t", vec![]);
        let b = q.push("t", vec![]);
        assert!(b > a);
    }
}
