//! Backbone hidden-state cache: the serving-side payoff of QST's frozen
//! shared backbone.
//!
//! Every task's side network reads the *same* frozen hidden states for a
//! given prompt, so the expensive backbone forward is cacheable across
//! requests AND across tasks.  Keys are a 64-bit FNV-1a hash of the token
//! ids mixed with the backbone identity; entries are byte-budgeted with
//! strict LRU eviction; hit/miss/eviction counters feed
//! [`super::stats::ServeStats`] and `BENCH_serve.json`.
//!
//! # Prefix keys
//!
//! The synthetic backbone computes every sequence position independently,
//! so a prompt that *extends* a cached prompt can reuse the cached
//! positions and run the frozen forward only over its tail (see
//! `Engine::backbone_resume`).  To find such donors the cache maintains a
//! **per-block prefix index**: when a bundle is inserted, its unpadded
//! prompt is walked in one rolling-FNV pass and a key is published at
//! every `block`-aligned boundary `p` — exactly the key `prompt_key`
//! would give the standalone prefix `tokens[..p]`.  A later lookup walks
//! its own boundaries deepest-first and resumes from the deepest entry
//! whose stored tokens actually match (keys are verified, never trusted).
//!
//! Publishing a key is more than exposing the rolling state: the state is
//! folded with the prefix *length*, the backbone id (again), and a
//! terminator, then avalanched.  Without that fold a prefix and its
//! extensions form one hash chain, so a single chain-state collision
//! (between prompts or between backbones — the old scheme mixed the id
//! only into the FNV seed) silently aliases *every* subsequent boundary;
//! the fold confines any collision to one (length, id) slot, and the
//! token-verify on lookup turns it into a counted miss.

use std::collections::{BTreeMap, HashMap};
use std::rc::Rc;

use super::batcher::query_pos;
use super::Hidden;

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

/// Rolling FNV-1a state seeded with the backbone id's bytes (byte-folded,
/// not just multiplied into the offset, so all 64 id bits diffuse).
fn rolling_seed(backbone_id: u64) -> u64 {
    let mut h = FNV_OFFSET;
    for b in backbone_id.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

fn roll_token(mut h: u64, t: i32) -> u64 {
    for b in t.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Publish a key from rolling state: fold length + id + terminator, then
/// avalanche (splitmix64 finalizer) so published keys of related prefixes
/// are unrelated even though their chain states are.
fn publish(h: u64, backbone_id: u64, len: usize) -> u64 {
    let mut k = h;
    k ^= (len as u64).wrapping_mul(FNV_PRIME);
    k = k.wrapping_mul(FNV_PRIME);
    k ^= backbone_id.rotate_left(32);
    k ^= 0xA5; // terminator: no token byte stream can reproduce this fold
    k ^= k >> 30;
    k = k.wrapping_mul(0xbf58476d1ce4e5b9);
    k ^= k >> 27;
    k = k.wrapping_mul(0x94d049bb133111eb);
    k ^= k >> 31;
    k
}

/// Cache key for a prompt: rolling FNV-1a over the token ids, seeded and
/// finalized with the backbone identity and the prompt length (see the
/// module doc for why the length/terminator fold matters).
pub fn prompt_key(backbone_id: u64, tokens: &[i32]) -> u64 {
    let mut h = rolling_seed(backbone_id);
    for &t in tokens {
        h = roll_token(h, t);
    }
    publish(h, backbone_id, tokens.len())
}

/// Block-boundary prefix keys of `tokens`: `(p, key)` for `p = block,
/// 2·block, … ≤ tokens.len()`, each key identical to
/// `prompt_key(backbone_id, &tokens[..p])` but computed in one rolling
/// pass.  `block == 0` disables prefix keying (empty result).
pub fn prefix_keys(backbone_id: u64, tokens: &[i32], block: usize) -> Vec<(usize, u64)> {
    if block == 0 {
        return Vec::new();
    }
    let mut out = Vec::with_capacity(tokens.len() / block);
    let mut h = rolling_seed(backbone_id);
    for (i, &t) in tokens.iter().enumerate() {
        h = roll_token(h, t);
        let p = i + 1;
        if p % block == 0 {
            out.push((p, publish(h, backbone_id, p)));
        }
    }
    out
}

struct Entry {
    hidden: Rc<Hidden>,
    tick: u64,
    /// prefix keys this entry registered in the index (for eviction cleanup)
    prefix_keys: Vec<u64>,
}

/// LRU, byte-budgeted cache of backbone hidden states with an optional
/// per-block prefix index (see module doc).
///
/// A budget of 0 disables the cache entirely (`get` always misses, `insert`
/// is a no-op) — that is the `--cache-bytes 0` baseline of `bench-serve`.
/// A `block` of 0 disables only the prefix index (whole-prompt hits still
/// work) — the pre-gateway behaviour.
pub struct HiddenCache {
    budget: usize,
    /// prefix-index block size in tokens (0 = whole-prompt keys only)
    block: usize,
    entries: HashMap<u64, Entry>,
    /// tick -> key, oldest first (ticks are unique, monotonically increasing)
    lru: BTreeMap<u64, u64>,
    /// prefix key -> full key of the donor entry holding that prefix
    prefix_index: HashMap<u64, u64>,
    bytes: usize,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// whole-prompt misses rescued by a prefix donor (deepest-block hits)
    pub prefix_hits: u64,
    /// key collisions detected (entry present but for a different prompt)
    pub collisions: u64,
    /// inserts dropped because a single entry exceeded the whole budget
    pub oversize_skips: u64,
}

impl HiddenCache {
    pub fn new(budget_bytes: usize) -> Self {
        Self::with_block(budget_bytes, 0)
    }

    /// Cache with the prefix index enabled at `block` tokens per boundary.
    pub fn with_block(budget_bytes: usize, block: usize) -> Self {
        HiddenCache {
            budget: budget_bytes,
            block,
            entries: HashMap::new(),
            lru: BTreeMap::new(),
            prefix_index: HashMap::new(),
            bytes: 0,
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
            prefix_hits: 0,
            collisions: 0,
            oversize_skips: 0,
        }
    }

    pub fn enabled(&self) -> bool {
        self.budget > 0
    }

    /// Prefix-index block size (0 = disabled).
    pub fn block(&self) -> usize {
        self.block
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn bytes(&self) -> usize {
        self.bytes
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Share of whole-prompt misses rescued by a prefix donor.
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.misses == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / self.misses as f64
        }
    }

    /// Look up a prompt's hidden states, counting the hit/miss and marking
    /// the entry most-recently-used on a hit.  The stored prompt is compared
    /// against `tokens`, so a 64-bit key collision is a (counted) miss —
    /// never silently another prompt's hidden states.
    pub fn get(&mut self, key: u64, tokens: &[i32]) -> Option<Rc<Hidden>> {
        match self.entries.get_mut(&key) {
            Some(e) if e.hidden.tokens == tokens => {
                self.hits += 1;
                self.lru.remove(&e.tick);
                self.tick += 1;
                e.tick = self.tick;
                self.lru.insert(self.tick, key);
                Some(e.hidden.clone())
            }
            Some(_) => {
                self.collisions += 1;
                self.misses += 1;
                None
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// After a whole-prompt miss: find the deepest cached donor whose
    /// prompt shares a block-aligned prefix with `row` (a padded row), and
    /// return it with the verified prefix length.  The donor's stored
    /// tokens are compared position-by-position, so an index collision can
    /// only cost a shallower resume, never wrong hidden states.  The donor
    /// is touched most-recently-used; a rescue counts in `prefix_hits`.
    pub fn get_prefix(&mut self, backbone_id: u64, row: &[i32]) -> Option<(Rc<Hidden>, usize)> {
        if self.block == 0 || self.budget == 0 || row.is_empty() {
            return None;
        }
        let plen = query_pos(row) + 1;
        let bounds = prefix_keys(backbone_id, &row[..plen], self.block);
        for &(p, pkey) in bounds.iter().rev() {
            let Some(&full_key) = self.prefix_index.get(&pkey) else { continue };
            let Some(e) = self.entries.get_mut(&full_key) else { continue };
            if e.hidden.tokens.len() >= p && e.hidden.tokens[..p] == row[..p] {
                self.prefix_hits += 1;
                self.lru.remove(&e.tick);
                self.tick += 1;
                e.tick = self.tick;
                self.lru.insert(self.tick, full_key);
                return Some((e.hidden.clone(), p));
            }
        }
        None
    }

    fn remove_entry(&mut self, key: u64) -> Option<Entry> {
        let e = self.entries.remove(&key)?;
        self.bytes -= e.hidden.bytes();
        self.lru.remove(&e.tick);
        for pk in &e.prefix_keys {
            // another entry may have claimed this prefix key since; only
            // drop index slots still pointing at the evicted entry
            if self.prefix_index.get(pk) == Some(&key) {
                self.prefix_index.remove(pk);
            }
        }
        Some(e)
    }

    /// Insert hidden states for a prompt, evicting least-recently-used
    /// entries until the budget holds, and registering the prompt's
    /// block-aligned prefixes in the index under `backbone_id` (the same
    /// identity `key` was derived from).  Entries bigger than the whole
    /// budget are skipped (never worth evicting everything for one prompt).
    pub fn insert(&mut self, key: u64, hidden: Rc<Hidden>, backbone_id: u64) {
        if self.budget == 0 {
            return;
        }
        let sz = hidden.bytes();
        if sz > self.budget {
            self.oversize_skips += 1;
            return;
        }
        self.remove_entry(key);
        while self.bytes + sz > self.budget {
            let Some((&oldest_tick, &oldest_key)) = self.lru.iter().next() else { break };
            // drop the slot itself before the entry lookup: a (hypothetical)
            // lru/entries desync then costs one wasted slot per turn, never
            // an infinite loop
            self.lru.remove(&oldest_tick);
            if self.remove_entry(oldest_key).is_some() {
                self.evictions += 1;
            }
        }
        let mut pkeys = Vec::new();
        if self.block > 0 {
            let plen = (query_pos(&hidden.tokens) + 1).min(hidden.tokens.len());
            for (_, pk) in prefix_keys(backbone_id, &hidden.tokens[..plen], self.block) {
                self.prefix_index.insert(pk, key);
                pkeys.push(pk);
            }
        }
        self.tick += 1;
        self.lru.insert(self.tick, key);
        self.entries.insert(key, Entry { hidden, tick: self.tick, prefix_keys: pkeys });
        self.bytes += sz;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    fn hidden(key: u64, floats: usize) -> Rc<Hidden> {
        Rc::new(Hidden { key, tokens: vec![key as i32], data: vec![0.5; floats] })
    }

    fn get(c: &mut HiddenCache, key: u64) -> Option<Rc<Hidden>> {
        c.get(key, &[key as i32])
    }

    #[test]
    fn key_is_order_sensitive_and_backbone_scoped() {
        let a = prompt_key(1, &[1, 2, 3]);
        assert_eq!(a, prompt_key(1, &[1, 2, 3]));
        assert_ne!(a, prompt_key(1, &[3, 2, 1]));
        assert_ne!(a, prompt_key(2, &[1, 2, 3]));
    }

    #[test]
    fn prefix_and_extension_keys_never_collide_by_construction() {
        // regression for the pre-gateway scheme: with the id only seeding
        // the FNV chain and no length fold, a prefix and its extensions
        // formed one hash chain — one chain-state collision aliased every
        // deeper boundary.  The published keys must all be distinct across
        // every boundary of one prompt, and across backbones.
        let toks: Vec<i32> = (1..=96).collect();
        let mut seen = HashSet::new();
        for id in [0u64, 7, u64::MAX] {
            for p in 0..=96usize {
                assert!(
                    seen.insert(prompt_key(id, &toks[..p])),
                    "prefix of len {p} (backbone {id}) collided"
                );
            }
        }
        // padding extension must not alias the unpadded prefix (PAD = 0
        // token bytes are all zero — the FNV worst case)
        let mut padded = toks[..32].to_vec();
        padded.resize(96, 0);
        assert_ne!(prompt_key(7, &padded), prompt_key(7, &toks[..32]));
    }

    #[test]
    fn prefix_keys_match_standalone_prompt_keys() {
        let toks: Vec<i32> = (10..40).collect();
        for block in [1usize, 4, 16] {
            let keys = prefix_keys(9, &toks, block);
            assert_eq!(keys.len(), toks.len() / block);
            for (p, k) in keys {
                assert_eq!(p % block, 0);
                assert_eq!(k, prompt_key(9, &toks[..p]), "boundary {p}");
            }
        }
        assert!(prefix_keys(9, &toks, 0).is_empty());
    }

    #[test]
    fn hit_miss_accounting() {
        let mut c = HiddenCache::new(1 << 20);
        let k = prompt_key(0, &[5, 6]);
        assert!(get(&mut c, k).is_none());
        c.insert(k, hidden(k, 16), 0);
        assert!(get(&mut c, k).is_some());
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 1);
        assert!((c.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn evicts_lru_under_byte_budget() {
        // each entry is 100 floats = 400 bytes; budget fits two
        let mut c = HiddenCache::new(900);
        c.insert(1, hidden(1, 100), 0);
        c.insert(2, hidden(2, 100), 0);
        assert_eq!(c.len(), 2);
        // touch 1 so 2 becomes LRU
        assert!(get(&mut c, 1).is_some());
        c.insert(3, hidden(3, 100), 0);
        assert_eq!(c.len(), 2);
        assert_eq!(c.evictions, 1);
        assert!(get(&mut c, 1).is_some(), "recently-used entry must survive");
        assert!(get(&mut c, 3).is_some());
        assert!(get(&mut c, 2).is_none(), "LRU entry must be evicted");
        assert!(c.bytes() <= 900);
    }

    #[test]
    fn zero_budget_disables() {
        let mut c = HiddenCache::new(0);
        c.insert(1, hidden(1, 4), 0);
        assert!(!c.enabled());
        assert_eq!(c.len(), 0);
        assert!(get(&mut c, 1).is_none());
    }

    #[test]
    fn oversize_entry_skipped() {
        let mut c = HiddenCache::new(100);
        c.insert(1, hidden(1, 100), 0); // 400 bytes > 100 budget
        assert_eq!(c.len(), 0);
        assert_eq!(c.oversize_skips, 1);
    }

    #[test]
    fn key_collision_is_a_counted_miss_not_a_wrong_hit() {
        let mut c = HiddenCache::new(1 << 20);
        c.insert(42, hidden(42, 8), 0); // stored with tokens [42]
        // same key, different prompt: must NOT return the stored entry
        assert!(c.get(42, &[9, 9, 9]).is_none());
        assert_eq!(c.collisions, 1);
        assert_eq!(c.misses, 1);
        // the genuine prompt still hits
        assert!(c.get(42, &[42]).is_some());
        assert_eq!(c.hits, 1);
    }

    #[test]
    fn reinsert_replaces_without_leak() {
        let mut c = HiddenCache::new(10_000);
        c.insert(1, hidden(1, 100), 0);
        c.insert(1, hidden(1, 200), 0);
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes(), 804);
    }

    /// A padded-row Hidden with real backbone-keyed identity, as the
    /// server inserts them.
    fn padded_hidden(bid: u64, prompt: &[i32], seq: usize) -> (u64, Rc<Hidden>) {
        let mut row = prompt.to_vec();
        row.resize(seq, 0);
        let key = prompt_key(bid, &row);
        (key, Rc::new(Hidden { key, tokens: row, data: vec![1.0; 32] }))
    }

    #[test]
    fn prefix_lookup_finds_deepest_verified_donor() {
        let bid = 5;
        let mut c = HiddenCache::with_block(1 << 20, 4);
        assert_eq!(c.block(), 4);
        // donor prompt: 12 real tokens -> boundaries at 4, 8, 12
        let donor: Vec<i32> = (1..=12).collect();
        let (k, h) = padded_hidden(bid, &donor, 16);
        c.insert(k, h, bid);
        // query extends the donor's first 8 tokens, then diverges
        let mut q: Vec<i32> = (1..=8).collect();
        q.extend([99, 98, 97, 96, 95, 94]);
        q.resize(16, 0);
        let (d, p) = c.get_prefix(bid, &q).expect("prefix donor");
        assert_eq!(p, 8, "deepest matching boundary");
        assert_eq!(&d.tokens[..8], &q[..8]);
        assert_eq!(c.prefix_hits, 1);
        // a query sharing nothing gets no donor
        let mut alien = vec![77i32; 12];
        alien.resize(16, 0);
        assert!(c.get_prefix(bid, &alien).is_none());
        // wrong backbone: same tokens, no donor
        assert!(c.get_prefix(bid ^ 1, &q).is_none());
    }

    #[test]
    fn prefix_lookup_respects_block_disable_and_budget_disable() {
        let donor: Vec<i32> = (1..=8).collect();
        let mut off = HiddenCache::with_block(1 << 20, 0);
        let (k, h) = padded_hidden(3, &donor, 8);
        off.insert(k, h.clone(), 3);
        assert!(off.get_prefix(3, &h.tokens).is_none(), "block 0 disables the index");
        let mut dead = HiddenCache::with_block(0, 4);
        dead.insert(k, h.clone(), 3);
        assert!(dead.get_prefix(3, &h.tokens).is_none());
    }

    #[test]
    fn eviction_cleans_the_prefix_index() {
        let bid = 2;
        // budget fits one padded entry (32 floats + 16 tokens = 192 bytes)
        let mut c = HiddenCache::with_block(200, 4);
        let (k1, h1) = padded_hidden(bid, &(1..=8).collect::<Vec<i32>>(), 16);
        c.insert(k1, h1, bid);
        let mut q: Vec<i32> = (1..=4).collect();
        q.extend([50, 51, 52, 53]);
        q.resize(16, 0);
        assert!(c.get_prefix(bid, &q).is_some());
        // inserting a second entry evicts the first; its prefix slots must go
        let (k2, h2) = padded_hidden(bid, &(101..=108).collect::<Vec<i32>>(), 16);
        c.insert(k2, h2, bid);
        assert_eq!(c.evictions, 1);
        assert!(c.get_prefix(bid, &q).is_none(), "stale index slot must not survive eviction");
    }

    #[test]
    fn shared_prefix_latest_donor_wins_and_eviction_keeps_the_other() {
        let bid = 4;
        let mut c = HiddenCache::with_block(1 << 20, 4);
        // two donors share their first 4 tokens
        let mut a: Vec<i32> = vec![1, 2, 3, 4];
        a.extend([10, 11, 12, 13]);
        let mut b: Vec<i32> = vec![1, 2, 3, 4];
        b.extend([20, 21, 22, 23]);
        let (ka, ha) = padded_hidden(bid, &a, 8);
        let (kb, hb) = padded_hidden(bid, &b, 8);
        c.insert(ka, ha, bid);
        c.insert(kb, hb, bid); // claims the shared 4-token prefix slot
        // evicting donor A must not tear down B's claim
        c.remove_entry(ka);
        let mut q = vec![1i32, 2, 3, 4];
        q.extend([90, 91, 92, 93]);
        let (d, p) = c.get_prefix(bid, &q).expect("surviving donor");
        assert_eq!(p, 4);
        assert_eq!(&d.tokens[..8], &b[..8]);
    }
}
