//! Backbone hidden-state cache: the serving-side payoff of QST's frozen
//! shared backbone.
//!
//! Every task's side network reads the *same* frozen hidden states for a
//! given prompt, so the expensive backbone forward is cacheable across
//! requests AND across tasks.  Keys are a 64-bit FNV-1a hash of the padded
//! token ids mixed with the backbone identity; entries are byte-budgeted
//! with strict LRU eviction; hit/miss/eviction counters feed
//! [`super::stats::ServeStats`] and `BENCH_serve.json`.

use std::collections::{BTreeMap, HashMap};
use std::rc::Rc;

use super::Hidden;

/// Cache key for a prompt: FNV-1a over the padded token ids, mixed with the
/// backbone identity so two different backbones never share entries.
pub fn prompt_key(backbone_id: u64, tokens: &[i32]) -> u64 {
    const FNV_OFFSET: u64 = 0xcbf29ce484222325;
    const FNV_PRIME: u64 = 0x100000001b3;
    let mut h = FNV_OFFSET ^ backbone_id.wrapping_mul(FNV_PRIME);
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

/// LRU, byte-budgeted cache of backbone hidden states.
///
/// A budget of 0 disables the cache entirely (`get` always misses, `insert`
/// is a no-op) — that is the `--cache-bytes 0` baseline of `bench-serve`.
pub struct HiddenCache {
    budget: usize,
    entries: HashMap<u64, (Rc<Hidden>, u64)>,
    /// tick -> key, oldest first (ticks are unique, monotonically increasing)
    lru: BTreeMap<u64, u64>,
    bytes: usize,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// key collisions detected (entry present but for a different prompt)
    pub collisions: u64,
    /// inserts dropped because a single entry exceeded the whole budget
    pub oversize_skips: u64,
}

impl HiddenCache {
    pub fn new(budget_bytes: usize) -> Self {
        HiddenCache {
            budget: budget_bytes,
            entries: HashMap::new(),
            lru: BTreeMap::new(),
            bytes: 0,
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
            collisions: 0,
            oversize_skips: 0,
        }
    }

    pub fn enabled(&self) -> bool {
        self.budget > 0
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn bytes(&self) -> usize {
        self.bytes
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Look up a prompt's hidden states, counting the hit/miss and marking
    /// the entry most-recently-used on a hit.  The stored prompt is compared
    /// against `tokens`, so a 64-bit key collision is a (counted) miss —
    /// never silently another prompt's hidden states.
    pub fn get(&mut self, key: u64, tokens: &[i32]) -> Option<Rc<Hidden>> {
        match self.entries.get_mut(&key) {
            Some((h, tick)) if h.tokens == tokens => {
                self.hits += 1;
                self.lru.remove(tick);
                self.tick += 1;
                *tick = self.tick;
                self.lru.insert(self.tick, key);
                Some(h.clone())
            }
            Some(_) => {
                self.collisions += 1;
                self.misses += 1;
                None
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert hidden states for a prompt, evicting least-recently-used
    /// entries until the budget holds.  Entries bigger than the whole budget
    /// are skipped (never worth evicting everything for one prompt).
    pub fn insert(&mut self, key: u64, hidden: Rc<Hidden>) {
        if self.budget == 0 {
            return;
        }
        let sz = hidden.bytes();
        if sz > self.budget {
            self.oversize_skips += 1;
            return;
        }
        if let Some((old, tick)) = self.entries.remove(&key) {
            self.bytes -= old.bytes();
            self.lru.remove(&tick);
        }
        while self.bytes + sz > self.budget {
            let Some((&oldest_tick, &oldest_key)) = self.lru.iter().next() else { break };
            self.lru.remove(&oldest_tick);
            if let Some((old, _)) = self.entries.remove(&oldest_key) {
                self.bytes -= old.bytes();
                self.evictions += 1;
            }
        }
        self.tick += 1;
        self.lru.insert(self.tick, key);
        self.entries.insert(key, (hidden, self.tick));
        self.bytes += sz;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hidden(key: u64, floats: usize) -> Rc<Hidden> {
        Rc::new(Hidden { key, tokens: vec![key as i32], data: vec![0.5; floats] })
    }

    fn get(c: &mut HiddenCache, key: u64) -> Option<Rc<Hidden>> {
        c.get(key, &[key as i32])
    }

    #[test]
    fn key_is_order_sensitive_and_backbone_scoped() {
        let a = prompt_key(1, &[1, 2, 3]);
        assert_eq!(a, prompt_key(1, &[1, 2, 3]));
        assert_ne!(a, prompt_key(1, &[3, 2, 1]));
        assert_ne!(a, prompt_key(2, &[1, 2, 3]));
    }

    #[test]
    fn hit_miss_accounting() {
        let mut c = HiddenCache::new(1 << 20);
        let k = prompt_key(0, &[5, 6]);
        assert!(get(&mut c, k).is_none());
        c.insert(k, hidden(k, 16));
        assert!(get(&mut c, k).is_some());
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 1);
        assert!((c.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn evicts_lru_under_byte_budget() {
        // each entry is 100 floats = 400 bytes; budget fits two
        let mut c = HiddenCache::new(900);
        c.insert(1, hidden(1, 100));
        c.insert(2, hidden(2, 100));
        assert_eq!(c.len(), 2);
        // touch 1 so 2 becomes LRU
        assert!(get(&mut c, 1).is_some());
        c.insert(3, hidden(3, 100));
        assert_eq!(c.len(), 2);
        assert_eq!(c.evictions, 1);
        assert!(get(&mut c, 1).is_some(), "recently-used entry must survive");
        assert!(get(&mut c, 3).is_some());
        assert!(get(&mut c, 2).is_none(), "LRU entry must be evicted");
        assert!(c.bytes() <= 900);
    }

    #[test]
    fn zero_budget_disables() {
        let mut c = HiddenCache::new(0);
        c.insert(1, hidden(1, 4));
        assert!(!c.enabled());
        assert_eq!(c.len(), 0);
        assert!(get(&mut c, 1).is_none());
    }

    #[test]
    fn oversize_entry_skipped() {
        let mut c = HiddenCache::new(100);
        c.insert(1, hidden(1, 100)); // 400 bytes > 100 budget
        assert_eq!(c.len(), 0);
        assert_eq!(c.oversize_skips, 1);
    }

    #[test]
    fn key_collision_is_a_counted_miss_not_a_wrong_hit() {
        let mut c = HiddenCache::new(1 << 20);
        c.insert(42, hidden(42, 8)); // stored with tokens [42]
        // same key, different prompt: must NOT return the stored entry
        assert!(c.get(42, &[9, 9, 9]).is_none());
        assert_eq!(c.collisions, 1);
        assert_eq!(c.misses, 1);
        // the genuine prompt still hits
        assert!(c.get(42, &[42]).is_some());
        assert_eq!(c.hits, 1);
    }

    #[test]
    fn reinsert_replaces_without_leak() {
        let mut c = HiddenCache::new(10_000);
        c.insert(1, hidden(1, 100));
        c.insert(1, hidden(1, 200));
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes(), 804);
    }
}
