//! Multi-task inference serving with a shared-backbone hidden-state cache.
//!
//! # Design
//!
//! QST's defining property carries from training straight into serving: the
//! 4-bit backbone is frozen and *shared* by every finetuned task — only a
//! tiny side network differs per task.  At inference time that means the
//! expensive part of a forward pass (the frozen backbone) depends only on
//! the prompt, not on the task, so its hidden states can be computed once
//! per distinct prompt, cached, and fanned out to any number of side
//! networks:
//!
//! ```text
//!   request(task, tokens)
//!        │ submit
//!        ▼
//!   [batcher]  per-task micro-batches, padded to the artifact shapes
//!        │ drain
//!        ▼
//!   [cache]    hidden-state lookup by hash(backbone, tokens)
//!        │ miss                                  │ hit
//!        ▼                                       │
//!   [engine.backbone]  frozen forward (heavy) ───┘
//!        ▼
//!   [engine.side]      per-task ladder forward (light, uses registry)
//!        ▼
//!   response(logits) + [stats]
//! ```
//!
//! * [`cache`] — LRU, byte-budgeted hidden-state cache with hit/miss
//!   accounting.  Repeated or shared prompts (classification fan-out,
//!   retries, A/B-ing two side networks over one prompt) skip the frozen
//!   forward entirely; a per-block **prefix index** additionally lets a
//!   prompt that merely *extends* a cached one resume the frozen forward
//!   from the deepest cached block (`Engine::backbone_resume`) instead of
//!   recomputing from token 0.
//! * [`registry`] — hot-swappable side-network residency (load via
//!   `coordinator::checkpoint`, LRU-evict under a byte budget, reload on
//!   demand), so one server can advertise more tasks than fit in memory.
//! * [`batcher`] — multi-task FIFO queue forming per-task micro-batches.
//! * [`engine`] — pluggable backends: a deterministic host-side reference
//!   of the QST split (used by tests and `bench-serve`, forwards running
//!   on the blocked/threaded GEMMs in [`crate::kernels`]) and an
//!   [`crate::runtime::Executor`]-backed artifact path with device-resident
//!   per-task state.
//! * [`stats`] — throughput, batch shape, and p50/p95 latency telemetry.
//! * [`workload`] — synthetic repeated-prompt workloads + the
//!   `bench-serve` runner emitting `BENCH_serve.json`.

pub mod batcher;
pub mod cache;
pub mod engine;
pub mod registry;
pub mod stats;
pub mod workload;

use std::rc::Rc;

use anyhow::{bail, Result};

use crate::obs::{self, SpanKind};

pub use batcher::{MicroBatch, RequestQueue};
pub use cache::HiddenCache;
pub use engine::{Engine, EnginePreset, ExecutorEngine, SyntheticEngine};
pub use crate::nn::BackboneKind;
pub use registry::{Registry, SideNetwork};
pub use stats::{ServeStats, StatsSnapshot, TaskStat};

/// One prompt's frozen-backbone hidden states (engine-defined layout).
#[derive(Clone, Debug)]
pub struct Hidden {
    /// cache key this bundle was computed under
    pub key: u64,
    /// the padded prompt itself — verified on every cache hit so a 64-bit
    /// key collision can never serve another prompt's hidden states
    pub tokens: Vec<i32>,
    pub data: Vec<f32>,
}

impl Hidden {
    /// Payload bytes counted against the cache budget.
    pub fn bytes(&self) -> usize {
        (self.data.len() + self.tokens.len()) * 4
    }
}

/// Server tuning knobs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServeConfig {
    /// hidden-state cache budget; 0 disables the cache
    pub cache_bytes: usize,
    /// side-network residency budget
    pub registry_bytes: usize,
    /// micro-batch size cap
    pub max_batch: usize,
    /// prefix-index block size in tokens (see [`cache`]); 0 disables
    /// prefix caching — whole-prompt hits only, the pre-gateway behaviour
    pub prefix_block: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            cache_bytes: 64 << 20,
            registry_bytes: 256 << 20,
            max_batch: 8,
            prefix_block: 16,
        }
    }
}

/// A completed request.  `PartialEq` compares logits exactly — the wire
/// protocol ([`crate::proto`]) round-trips them bit-for-bit, and the
/// gateway parity gates rely on exact equality across transports.
#[derive(Clone, Debug, PartialEq)]
pub struct Response {
    pub id: u64,
    pub task: String,
    /// vocab-sized next-token logits at the prompt's query position
    pub logits: Vec<f32>,
    pub cache_hit: bool,
}

impl Response {
    /// Argmax token and its logit.
    pub fn top1(&self) -> (usize, f32) {
        let mut best = 0usize;
        let mut bestv = f32::NEG_INFINITY;
        for (i, &v) in self.logits.iter().enumerate() {
            if v > bestv {
                bestv = v;
                best = i;
            }
        }
        (best, bestv)
    }
}

/// The in-process multi-task inference server: queue → cache → backbone →
/// side network, with residency and telemetry.  `submit` enqueues; `step`
/// processes exactly one micro-batch and returns its responses — the unit
/// a continuously-batching caller (the gateway shard loop) interleaves
/// with admission; `drain` loops `step` until nothing is pending.
pub struct Server<E: Engine> {
    pub engine: E,
    pub registry: Registry,
    pub cache: HiddenCache,
    pub stats: ServeStats,
    queue: RequestQueue,
    max_batch: usize,
}

impl<E: Engine> Server<E> {
    pub fn new(engine: E, cfg: ServeConfig) -> Self {
        Server {
            engine,
            registry: Registry::new(cfg.registry_bytes),
            cache: HiddenCache::with_block(cfg.cache_bytes, cfg.prefix_block),
            stats: ServeStats::new(),
            queue: RequestQueue::new(),
            max_batch: cfg.max_batch.max(1),
        }
    }

    /// Enqueue a request; rejects unknown tasks and over-length prompts
    /// up front so errors surface at submit time, not mid-batch.
    pub fn submit(&mut self, task: &str, tokens: &[i32]) -> Result<u64> {
        let t_admit = obs::start();
        if !self.registry.contains(task) {
            bail!("unknown task '{task}' (registered: {:?})", self.registry.known_tasks());
        }
        if tokens.len() > self.engine.seq_len() {
            bail!(
                "prompt of {} tokens exceeds the serving sequence length {}",
                tokens.len(),
                self.engine.seq_len()
            );
        }
        // "routing" at server level is the batcher's per-task dispatch —
        // the queue.push picks (or opens) the task's micro-batch lane
        let t_route = obs::start();
        let id = self.queue.push(task, tokens.to_vec());
        obs::end(SpanKind::Route, t_route, id);
        obs::end(SpanKind::Admit, t_admit, id);
        Ok(id)
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Process exactly **one** pending micro-batch and return its
    /// responses (empty when nothing is pending).  This is the scheduling
    /// unit of continuous batching: a caller keeping a slot pool topped up
    /// calls `step`, emits the completed responses downstream, re-admits
    /// into the freed slots, and steps again — no full-drain barrier.
    ///
    /// A failing micro-batch drops its own requests — counted in
    /// `stats.dropped` and logged — and returns the error; the queue keeps
    /// the other lanes' requests, so the caller can simply step again.
    pub fn step(&mut self) -> Result<Vec<Response>> {
        let Some(mb) = self.queue.next_batch(self.max_batch) else {
            return Ok(Vec::new());
        };
        if obs::enabled() {
            // slot-pool wait, backdated: enqueue → this batch starting
            for req in &mb.requests {
                obs::end_backdated(
                    SpanKind::QueueWait,
                    req.enqueued.elapsed().as_nanos() as u64,
                    req.id,
                );
            }
        }
        let n = mb.requests.len();
        let task = mb.task.clone();
        let mut responses = Vec::with_capacity(n);
        if let Err(e) = self.process_batch(mb, &mut responses) {
            self.stats.dropped += n as u64;
            eprintln!("serve: dropping {n} request(s) for task '{task}': {e:#}");
            return Err(e);
        }
        Ok(responses)
    }

    /// Process every pending request; responses come back in completion
    /// order (batched per task), each tagged with its request id.
    ///
    /// A failing micro-batch (side network unloadable, engine error) drops
    /// only its own requests — counted in `stats.dropped` and logged — and
    /// the drain continues; already-computed responses are never discarded.
    /// `Err` is returned only when nothing at all could be served.
    pub fn drain(&mut self) -> Result<Vec<Response>> {
        let mut responses = Vec::with_capacity(self.queue.len());
        let mut first_err: Option<anyhow::Error> = None;
        while self.pending() > 0 {
            match self.step() {
                Ok(mut batch) => responses.append(&mut batch),
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        match first_err {
            Some(e) if responses.is_empty() => Err(e),
            _ => Ok(responses),
        }
    }

    /// One micro-batch: cache lookup → backbone for the distinct misses →
    /// side network → responses.
    fn process_batch(&mut self, mb: MicroBatch, responses: &mut Vec<Response>) -> Result<()> {
        let t0 = std::time::Instant::now();
        let first_id = mb.requests.first().map(|r| r.id).unwrap_or(0);
        if obs::enabled() {
            // queue-wait spans, backdated to each request's enqueue instant
            for req in &mb.requests {
                obs::end_backdated(
                    SpanKind::ShardQueue,
                    req.enqueued.elapsed().as_nanos() as u64,
                    req.id,
                );
            }
        }
        let t_assemble = obs::start();
        let seq = self.engine.seq_len();
        let use_cache = self.engine.cacheable() && self.cache.enabled();
        // per-task swap-in accounting: a registry load here means this
        // batch's side network had been evicted and was rebuilt on demand
        let loads_before = self.registry.loads;
        let net = self.registry.get(&mb.task)?;
        let swap_ins = self.registry.loads - loads_before;
        let rows: Vec<Vec<i32>> = mb
            .requests
            .iter()
            .map(|r| batcher::pad_row(&r.tokens, seq))
            .collect::<Result<_>>()?;
        // resolve hidden states: cache hits, then one backbone dispatch
        // covering each *distinct* missing prompt exactly once
        let bid = self.engine.backbone_id();
        let mut hiddens: Vec<Option<Rc<Hidden>>> = vec![None; rows.len()];
        let mut hits: Vec<bool> = vec![false; rows.len()];
        let mut miss_rows: Vec<Vec<i32>> = Vec::new();
        let mut miss_keys: Vec<u64> = Vec::new();
        let mut owners: Vec<Vec<usize>> = Vec::new(); // miss index -> row indices
        for (i, row) in rows.iter().enumerate() {
            let key = cache::prompt_key(bid, row);
            if use_cache {
                if let Some(h) = self.cache.get(key, row) {
                    hiddens[i] = Some(h);
                    hits[i] = true;
                    continue;
                }
            }
            match miss_keys.iter().position(|&k| k == key) {
                Some(m) => owners[m].push(i), // duplicate within this batch
                None => {
                    miss_keys.push(key);
                    miss_rows.push(row.clone());
                    owners.push(vec![i]);
                }
            }
        }
        obs::end(SpanKind::BatchAssemble, t_assemble, first_id);
        if !miss_rows.is_empty() {
            // prefix-resume pass: a miss whose prompt extends a cached
            // prefix runs only the tail of the frozen forward (bit-identical
            // to a from-scratch forward — see Engine::backbone_resume)
            let mut resolved: Vec<Option<Rc<Hidden>>> = vec![None; miss_rows.len()];
            if use_cache {
                for (m, row) in miss_rows.iter().enumerate() {
                    if let Some((donor, p)) = self.cache.get_prefix(bid, row) {
                        let t_resume = obs::start();
                        let h = Rc::new(self.engine.backbone_resume(&donor, p, row)?);
                        obs::end(SpanKind::PrefixResume, t_resume, mb.requests[owners[m][0]].id);
                        self.stats.prefix_resumes += 1;
                        resolved[m] = Some(h);
                    }
                }
            }
            // one backbone dispatch for the misses no donor could rescue
            let fresh_idx: Vec<usize> =
                (0..miss_rows.len()).filter(|&m| resolved[m].is_none()).collect();
            if !fresh_idx.is_empty() {
                let fresh_rows: Vec<Vec<i32>> =
                    fresh_idx.iter().map(|&m| miss_rows[m].clone()).collect();
                let t_backbone = obs::start();
                let fresh = self.engine.backbone(&fresh_rows)?;
                obs::end(SpanKind::Backbone, t_backbone, first_id);
                if fresh.len() != fresh_rows.len() {
                    bail!("backbone returned {} bundles for {} rows", fresh.len(), fresh_rows.len());
                }
                for (h, &m) in fresh.into_iter().zip(&fresh_idx) {
                    resolved[m] = Some(Rc::new(h));
                }
            }
            for ((h, key), row_idxs) in resolved.into_iter().zip(&miss_keys).zip(&owners) {
                let h = h.expect("all misses resolved");
                if use_cache {
                    self.cache.insert(*key, h.clone(), bid);
                }
                for &i in row_idxs {
                    hiddens[i] = Some(h.clone());
                }
            }
        }
        let hiddens: Vec<Rc<Hidden>> =
            hiddens.into_iter().map(|h| h.expect("all rows resolved")).collect();
        let t_side = obs::start();
        let logits = self.engine.side(&net, &hiddens, &rows)?;
        obs::end(SpanKind::Sidenet, t_side, first_id);
        if logits.len() != rows.len() {
            bail!("side returned {} rows for {}", logits.len(), rows.len());
        }
        let t_respond = obs::start();
        let hit_count = hits.iter().filter(|&&h| h).count() as u64;
        let mut latencies = Vec::with_capacity(mb.requests.len());
        let mut queue_waits = Vec::with_capacity(mb.requests.len());
        let mut tok_count = 0usize;
        for ((req, lg), hit) in mb.requests.into_iter().zip(logits).zip(hits) {
            latencies.push(req.enqueued.elapsed().as_secs_f64());
            // queue-wait component: enqueue → batch execution start
            // (duration_since saturates to zero; enqueue precedes t0)
            queue_waits.push(t0.duration_since(req.enqueued).as_secs_f64());
            tok_count += req.tokens.len();
            responses.push(Response { id: req.id, task: req.task, logits: lg, cache_hit: hit });
        }
        self.stats.record_batch(
            latencies.len(),
            tok_count,
            t0.elapsed().as_secs_f64(),
            &latencies,
            &queue_waits,
        );
        self.stats.record_task(
            &mb.task,
            latencies.len() as u64,
            tok_count as u64,
            hit_count,
            swap_ins,
        );
        obs::end(SpanKind::Respond, t_respond, first_id);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn server(cache_bytes: usize) -> Server<SyntheticEngine> {
        let engine = SyntheticEngine::small(42, 16);
        let mut s = Server::new(
            engine,
            ServeConfig { cache_bytes, registry_bytes: 1 << 20, max_batch: 4, prefix_block: 8 },
        );
        s.registry.register_synthetic("sst2", 100, 1000).unwrap();
        s.registry.register_synthetic("mnli", 200, 1000).unwrap();
        s
    }

    #[test]
    fn submit_validates_task_and_length() {
        let mut s = server(1 << 20);
        assert!(s.submit("nope", &[1, 2]).is_err());
        assert!(s.submit("sst2", &vec![1; 17]).is_err());
        assert!(s.submit("sst2", &[1, 2]).is_ok());
        assert_eq!(s.pending(), 1);
    }

    #[test]
    fn repeated_prompts_hit_the_cache_and_skip_the_backbone() {
        let mut s = server(16 << 20);
        let prompt = [3i32, 7, 11];
        for _ in 0..3 {
            s.submit("sst2", &prompt).unwrap();
        }
        let r1 = s.drain().unwrap();
        assert_eq!(r1.len(), 3);
        // all three identical prompts in one batch: one backbone row total
        assert_eq!(s.engine.backbone_rows, 1);
        // next wave hits the cache outright
        s.submit("sst2", &prompt).unwrap();
        s.submit("mnli", &prompt).unwrap(); // different task, same backbone!
        let r2 = s.drain().unwrap();
        assert_eq!(s.engine.backbone_rows, 1, "cache must serve both tasks");
        assert!(r2.iter().all(|r| r.cache_hit));
        assert!(s.cache.hits >= 2);
        // same prompt, different tasks -> different logits
        assert_ne!(r2[0].logits, r2[1].logits);
    }

    #[test]
    fn disabled_cache_recomputes_but_matches() {
        let prompt = [5i32, 9];
        let run = |cache_bytes: usize| {
            let mut s = server(cache_bytes);
            for _ in 0..2 {
                s.submit("sst2", &prompt).unwrap();
            }
            let mut r = s.drain().unwrap();
            s.submit("sst2", &prompt).unwrap();
            r.extend(s.drain().unwrap());
            (r, s.engine.backbone_rows)
        };
        let (with_cache, rows_cached) = run(16 << 20);
        let (without, rows_uncached) = run(0);
        assert!(rows_uncached > rows_cached);
        for (a, b) in with_cache.iter().zip(&without) {
            assert_eq!(a.logits, b.logits, "cache must not change results");
        }
        assert!(without.iter().all(|r| !r.cache_hit));
    }

    #[test]
    fn prefix_extension_resumes_instead_of_recomputing() {
        let mk = |cache_bytes: usize, prefix_block: usize| {
            let mut s = Server::new(
                SyntheticEngine::small(42, 16),
                ServeConfig { cache_bytes, registry_bytes: 1 << 20, max_batch: 4, prefix_block },
            );
            s.registry.register_synthetic("sst2", 100, 1000).unwrap();
            s
        };
        let base: Vec<i32> = (1..=8).collect();
        let mut ext = base.clone();
        ext.extend([21, 22, 23]);

        let mut s = mk(16 << 20, 4);
        s.submit("sst2", &base).unwrap();
        s.drain().unwrap();
        assert_eq!(s.engine.backbone_rows, 1);
        // the extension shares the base's first 8 tokens (block-aligned):
        // the backbone must resume from the cached prefix, not recompute
        s.submit("sst2", &ext).unwrap();
        let r = s.drain().unwrap();
        assert_eq!(s.engine.backbone_rows, 1, "extension must not run a full forward");
        assert_eq!(s.engine.resumed_rows, 1);
        assert_eq!(s.engine.resumed_positions, 8);
        assert_eq!(s.stats.prefix_resumes, 1);
        assert_eq!(s.cache.prefix_hits, 1);
        assert!(s.cache.prefix_hit_rate() > 0.0);
        // parity: the resumed response equals an uncached from-scratch one
        let mut fresh = mk(0, 0);
        fresh.submit("sst2", &ext).unwrap();
        let want = fresh.drain().unwrap();
        assert_eq!(r[0].logits, want[0].logits, "resumed forward must be bit-identical");
        // and the resumed bundle itself is now a first-class cache entry
        s.submit("sst2", &ext).unwrap();
        let again = s.drain().unwrap();
        assert!(again[0].cache_hit);
        assert_eq!(s.engine.resumed_rows, 1, "whole-prompt hit, no second resume");
    }

    #[test]
    fn batched_equals_unbatched() {
        // the server (batching + dedupe + cache + threading) must be a pure
        // optimization; the single-threaded unbatched reference is the spec
        // for every thread count (`--threads 4` acceptance criterion)
        for threads in [1usize, 4] {
            let prompts: Vec<Vec<i32>> = vec![vec![1, 2, 3], vec![4], vec![1, 2, 3], vec![9, 9]];
            let mut s = server(16 << 20);
            s.engine.set_threads(threads);
            let mut ids = vec![];
            for p in &prompts {
                ids.push(s.submit("sst2", p).unwrap());
            }
            let mut got = s.drain().unwrap();
            got.sort_by_key(|r| r.id);

            // reference: fresh engine, one request at a time, no cache,
            // single-threaded
            let mut eng = SyntheticEngine::small(42, 16);
            eng.set_threads(1);
            let net = (*s.registry.get("sst2").unwrap()).clone();
            for (resp, p) in got.iter().zip(&prompts) {
                let row = batcher::pad_row(p, 16).unwrap();
                let h: Vec<Rc<Hidden>> = eng
                    .backbone(std::slice::from_ref(&row))
                    .unwrap()
                    .into_iter()
                    .map(Rc::new)
                    .collect();
                let want = eng.side(&net, &h, std::slice::from_ref(&row)).unwrap();
                assert_eq!(
                    resp.logits, want[0],
                    "batched path must match unbatched ({threads} threads)"
                );
            }
        }
    }

    #[test]
    fn stats_accumulate() {
        let mut s = server(1 << 20);
        for i in 0..10 {
            s.submit(if i % 2 == 0 { "sst2" } else { "mnli" }, &[i]).unwrap();
        }
        s.drain().unwrap();
        assert_eq!(s.stats.requests, 10);
        assert!(s.stats.batches >= 2, "two tasks force at least two micro-batches");
        assert!(s.stats.p95_secs() >= s.stats.p50_secs());
        assert_eq!(s.pending(), 0);
    }

    /// Engine that refuses prompts containing the token 666 — for testing
    /// partial-failure semantics of drain().
    struct FlakyEngine(SyntheticEngine);

    impl Engine for FlakyEngine {
        fn seq_len(&self) -> usize {
            self.0.seq_len()
        }
        fn backbone_id(&self) -> u64 {
            self.0.backbone_id()
        }
        fn backbone(&mut self, rows: &[Vec<i32>]) -> Result<Vec<Hidden>> {
            if rows.iter().any(|r| r.contains(&666)) {
                bail!("simulated backbone failure");
            }
            self.0.backbone(rows)
        }
        fn side(
            &mut self,
            net: &SideNetwork,
            hiddens: &[Rc<Hidden>],
            rows: &[Vec<i32>],
        ) -> Result<Vec<Vec<f32>>> {
            self.0.side(net, hiddens, rows)
        }
    }

    #[test]
    fn failing_batch_drops_only_its_requests() {
        let mut s = Server::new(
            FlakyEngine(SyntheticEngine::small(42, 16)),
            ServeConfig { cache_bytes: 1 << 20, registry_bytes: 1 << 20, max_batch: 4, prefix_block: 8 },
        );
        s.registry.register_synthetic("good", 1, 100).unwrap();
        s.registry.register_synthetic("bad", 2, 100).unwrap();
        let good_id = s.submit("good", &[1, 2, 3]).unwrap();
        s.submit("bad", &[666]).unwrap();
        let r = s.drain().unwrap();
        assert_eq!(r.len(), 1, "healthy task must still be served");
        assert_eq!(r[0].id, good_id);
        assert_eq!(s.stats.dropped, 1);
        assert_eq!(s.pending(), 0, "failed requests are dropped, not stuck");
        // when *nothing* can be served, drain surfaces the error
        s.submit("bad", &[666, 667]).unwrap();
        assert!(s.drain().is_err());
        assert_eq!(s.stats.dropped, 2);
    }

    #[test]
    fn top1_picks_argmax() {
        let r = Response { id: 0, task: "t".into(), logits: vec![0.1, 0.9, -3.0], cache_hit: false };
        assert_eq!(r.top1().0, 1);
    }
}
