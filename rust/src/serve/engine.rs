//! Serving engines: how backbone and side-network forwards are computed.
//!
//! Two backends implement [`Engine`]:
//!
//! * [`SyntheticEngine`] — a deterministic host-side reference of the QST
//!   inference split: a frozen backbone (embedding + L residual tanh
//!   layers) whose per-layer hidden states feed per-task ladder side
//!   networks at width d/r.  The backbone forward is O(L·S·d²) while a
//!   side forward is O(L·S·d·(d/r)) — the same asymmetry as the paper's
//!   models — so this is the backend that makes the hidden-state cache's
//!   benefit measurable without GPUs or artifacts.  Same-row outputs are
//!   bit-identical regardless of batch composition or cache state.
//! * [`ExecutorEngine`] — dispatches micro-batches through
//!   [`crate::runtime::Executor`] over per-task AOT eval artifacts, with
//!   the trainable and frozen tensors uploaded once and kept
//!   device-resident.  Today's artifacts are monolithic (tokens → logits),
//!   so this backend reports `cacheable() == false` and the server bypasses
//!   the hidden-state cache for it; when `aot.py` grows a split backbone
//!   artifact the cache applies unchanged.

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use super::batcher::query_pos;
use super::registry::SideNetwork;
use super::Hidden;
use crate::kernels::{gemm, Threads};
use crate::nn::{BackboneKind, Linear};
use crate::runtime::{Executor, Role, Runtime};
use crate::tensor::{DType, HostTensor};
use crate::util::rng::Rng;

/// A serving backend: a frozen shared backbone plus per-task side networks.
pub trait Engine {
    /// Fixed sequence length rows are padded to.
    fn seq_len(&self) -> usize;
    /// Stable identity of the frozen backbone (part of every cache key).
    fn backbone_id(&self) -> u64;
    /// Whether the backbone forward is separable (and hence cacheable).
    fn cacheable(&self) -> bool {
        true
    }
    /// Frozen forward for padded rows; one hidden-state bundle per row.
    fn backbone(&mut self, rows: &[Vec<i32>]) -> Result<Vec<Hidden>>;
    /// Resume the frozen forward for `row` from a `donor` bundle whose
    /// prompt shares the first `prefix_len` (padded-row) positions: reuse
    /// the donor's hidden states for those positions and compute only the
    /// tail.  Must be bit-identical to `backbone(&[row])`.  The default
    /// recomputes from scratch — correct for backends whose forward is not
    /// position-separable (e.g. monolithic artifacts).
    fn backbone_resume(&mut self, donor: &Hidden, prefix_len: usize, row: &[i32]) -> Result<Hidden> {
        let _ = (donor, prefix_len);
        let rows = vec![row.to_vec()];
        let mut out = self.backbone(&rows)?;
        out.pop().ok_or_else(|| anyhow::anyhow!("backbone returned no bundle for the resumed row"))
    }
    /// Side-network forward for one task: per-row logits (vocab-sized).
    fn side(
        &mut self,
        net: &SideNetwork,
        hiddens: &[Rc<Hidden>],
        rows: &[Vec<i32>],
    ) -> Result<Vec<Vec<f32>>>;
}

fn seeded_matrix(rng: &mut Rng, rows: usize, cols: usize, scale: f64) -> Vec<f32> {
    (0..rows * cols).map(|_| (rng.normal() * scale) as f32).collect()
}

/// Per-task side weights derived deterministically from the task seed.
struct SideWeights {
    dg: usize,
    /// [d, dg] shared downsampler
    down: Vec<f32>,
    /// layers × [dg, dg] ladder mixers
    mix: Vec<Vec<f32>>,
    /// [dg, vocab] output head
    head: Vec<f32>,
}

/// Built-in [`SyntheticEngine`] shapes, selectable via `--preset`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EnginePreset {
    /// d=96, 6 layers — the seed default for tests and quick benches.
    Small,
    /// d=256, 8 layers — intractable on the seed's naive triple loops;
    /// unlocked by the blocked/threaded kernels.
    Large,
    /// d=512, 12 layers — ~6x the backbone FLOPs of `large`; serveable at
    /// interactive latency only on the packed-panel microkernel, and cheap
    /// to hold under `--backbone w4` (~0.5 MB resident).
    Xl,
}

impl EnginePreset {
    /// Every preset, in ascending size — tests and sweeps iterate this so
    /// a new preset can't dodge the parity/residency/costmodel pins.
    pub const ALL: [EnginePreset; 3] = [EnginePreset::Small, EnginePreset::Large, EnginePreset::Xl];

    pub fn parse(name: &str) -> anyhow::Result<Self> {
        match name {
            "small" => Ok(EnginePreset::Small),
            "large" => Ok(EnginePreset::Large),
            "xl" => Ok(EnginePreset::Xl),
            other => bail!("unknown preset '{other}' (expected 'small', 'large', or 'xl')"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            EnginePreset::Small => "small",
            EnginePreset::Large => "large",
            EnginePreset::Xl => "xl",
        }
    }

    pub fn vocab(self) -> usize {
        match self {
            EnginePreset::Small => SyntheticEngine::SMALL_VOCAB,
            EnginePreset::Large => SyntheticEngine::LARGE_VOCAB,
            EnginePreset::Xl => SyntheticEngine::XL_VOCAB,
        }
    }

    /// `(d, layers, vocab, r)` of this preset's engine.
    pub fn shape(self) -> (usize, usize, usize, usize) {
        match self {
            EnginePreset::Small => (96, 6, SyntheticEngine::SMALL_VOCAB, 12),
            EnginePreset::Large => (256, 8, SyntheticEngine::LARGE_VOCAB, 16),
            EnginePreset::Xl => (512, 12, SyntheticEngine::XL_VOCAB, 16),
        }
    }

    pub fn build(self, seed: u64, seq: usize) -> SyntheticEngine {
        self.build_backbone(seed, seq, BackboneKind::F32)
    }

    /// Build with the backbone storage selected by `--backbone`.
    pub fn build_backbone(self, seed: u64, seq: usize, kind: BackboneKind) -> SyntheticEngine {
        let (d, layers, vocab, r) = self.shape();
        SyntheticEngine::with_backbone(seed, d, layers, vocab, seq, r, kind)
    }
}

/// Deterministic host-side QST serving reference (see module doc).
///
/// The frozen backbone (embedding table + per-layer `[d, d]` matrices) is
/// held as [`Linear`]s: `--backbone f32` keeps the seeded f32 weights,
/// `--backbone w4` quantizes them through the paper's packed-nibble +
/// double-quantized-scale format at build time and drops the f32 originals
/// — the engine then serves straight through the fused dequant-GEMM.  The
/// per-task side networks stay full-precision by design (QST trains them in
/// 16/32-bit; only the frozen backbone is quantized).
pub struct SyntheticEngine {
    pub d: usize,
    pub layers: usize,
    pub vocab: usize,
    pub seq: usize,
    /// side-network reduction factor (paper default 16; must divide d)
    pub r: usize,
    /// [vocab, d] embedding table (row-gathered, never matmul'd)
    embed: Linear,
    /// layers × [d, d]
    w: Vec<Linear>,
    side_cache: HashMap<u64, Rc<SideWeights>>,
    id: u64,
    /// worker count for the blocked GEMM kernels; results are bit-identical
    /// for any value (see [`crate::kernels::threads`])
    threads: Threads,
    /// rows that actually ran the frozen forward (cache-skipped rows don't)
    pub backbone_rows: u64,
    /// rows served by resuming from a cached prefix (not counted in
    /// `backbone_rows` — they ran only a tail of the frozen forward)
    pub resumed_rows: u64,
    /// positions *skipped* by prefix resumes (donated by cached bundles)
    pub resumed_positions: u64,
}

impl SyntheticEngine {
    pub fn new(seed: u64, d: usize, layers: usize, vocab: usize, seq: usize, r: usize) -> Self {
        Self::with_backbone(seed, d, layers, vocab, seq, r, BackboneKind::F32)
    }

    /// Build the seeded backbone, storing it per `kind`.  The f32 matrices
    /// exist only transiently during quantization: for `W4` nothing
    /// full-precision stays resident.  Seeding is independent of `kind`, so
    /// a W4 engine computes exactly what an f32 engine over the
    /// quantize→dequantize round-trip of the same seed computes.
    pub fn with_backbone(
        seed: u64,
        d: usize,
        layers: usize,
        vocab: usize,
        seq: usize,
        r: usize,
        kind: BackboneKind,
    ) -> Self {
        assert!(d % r == 0 && d / r >= 2, "reduction {r} must divide d={d} with width >= 2");
        assert!(layers >= 1 && vocab >= 2 && seq >= 1);
        let mut rng = Rng::new(seed ^ 0x5157_5345_5256_4531); // "QWSE RVE1"-ish tag
        let scale = 1.0 / (d as f64).sqrt();
        let embed = Linear::build(kind, seeded_matrix(&mut rng, vocab, d, scale), vocab, d);
        let w = (0..layers)
            .map(|_| Linear::build(kind, seeded_matrix(&mut rng, d, d, scale), d, d))
            .collect();
        SyntheticEngine {
            d,
            layers,
            vocab,
            seq,
            r,
            embed,
            w,
            side_cache: HashMap::new(),
            // the storage kind changes the served numerics (round-tripped
            // weights), so it must flow into every cache key
            id: seed.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ match kind {
                    BackboneKind::F32 => 0xB5,
                    BackboneKind::W4 => 0x57_34,
                },
            threads: Threads::default(),
            backbone_rows: 0,
            resumed_rows: 0,
            resumed_positions: 0,
        }
    }

    /// Vocab of the [`SyntheticEngine::small`] configuration.
    pub const SMALL_VOCAB: usize = 256;

    /// Vocab of the [`SyntheticEngine::large`] configuration.
    pub const LARGE_VOCAB: usize = 512;

    /// Vocab of the [`SyntheticEngine::xl`] configuration.
    pub const XL_VOCAB: usize = 1024;

    /// Small default used by tests and `bench-serve`: heavy backbone
    /// (d=96, 6 layers) vs light side nets (width 8).  The shape literals
    /// live in [`EnginePreset::shape`] — the single source of truth.
    pub fn small(seed: u64, seq: usize) -> Self {
        EnginePreset::Small.build(seed, seq)
    }

    /// Big preset (d=256, 8 layers, width-16 side nets): ~9x the backbone
    /// FLOPs of [`SyntheticEngine::small`], serviceable only because the
    /// forwards run on the blocked/threaded kernels.
    pub fn large(seed: u64, seq: usize) -> Self {
        EnginePreset::Large.build(seed, seq)
    }

    /// Biggest preset (d=512, 12 layers, width-32 side nets): ~6x the
    /// backbone FLOPs of [`SyntheticEngine::large`], interactive only on
    /// the packed-panel microkernel (`kernels::pack`).
    pub fn xl(seed: u64, seq: usize) -> Self {
        EnginePreset::Xl.build(seed, seq)
    }

    /// Set the kernel worker count (clamped to >= 1).  Purely a wall-clock
    /// knob: every forward is bit-identical across thread counts.
    pub fn set_threads(&mut self, n: usize) {
        self.threads = Threads::new(n);
    }

    pub fn threads(&self) -> usize {
        self.threads.count()
    }

    /// Bytes of one row's hidden-state bundle (for cache sizing): the
    /// per-layer states plus the verification copy of the prompt tokens.
    pub fn hidden_bytes(&self) -> usize {
        ((self.layers + 1) * self.seq * self.d + self.seq) * 4
    }

    /// How the frozen backbone is stored (`--backbone f32|w4`).
    pub fn backbone_kind(&self) -> BackboneKind {
        self.embed.kind()
    }

    /// Bytes the frozen backbone keeps resident (embedding + layer
    /// matrices) — the figure `bench-serve` reports and
    /// [`crate::costmodel::memory::backbone_resident_bytes`] models.
    pub fn backbone_resident_bytes(&self) -> usize {
        self.embed.resident_bytes() + self.w.iter().map(Linear::resident_bytes).sum::<usize>()
    }

    /// A fresh engine whose backbone holds, in plain f32, exactly the
    /// weights this engine computes with (the quantize→dequantize
    /// round-trip for W4; a copy for f32).  This is the parity-test
    /// reference: its forwards must match this engine's bit-for-bit.
    pub fn to_f32_roundtrip(&self) -> SyntheticEngine {
        SyntheticEngine {
            d: self.d,
            layers: self.layers,
            vocab: self.vocab,
            seq: self.seq,
            r: self.r,
            embed: self.embed.to_f32_roundtrip(),
            w: self.w.iter().map(Linear::to_f32_roundtrip).collect(),
            side_cache: HashMap::new(),
            id: self.id,
            threads: self.threads,
            backbone_rows: 0,
            resumed_rows: 0,
            resumed_positions: 0,
        }
    }

    fn side_weights(&mut self, net: &SideNetwork) -> Rc<SideWeights> {
        let (d, layers, vocab, r) = (self.d, self.layers, self.vocab, self.r);
        self.side_cache
            .entry(net.seed)
            .or_insert_with(|| {
                let dg = d / r;
                let mut rng = Rng::new(net.seed ^ 0x5349_4445); // "SIDE"
                let down = seeded_matrix(&mut rng, d, dg, 1.0 / (d as f64).sqrt());
                let mix = (0..layers)
                    .map(|_| seeded_matrix(&mut rng, dg, dg, 1.0 / (dg as f64).sqrt()))
                    .collect();
                let head = seeded_matrix(&mut rng, dg, vocab, 1.0 / (dg as f64).sqrt());
                Rc::new(SideWeights { dg, down, mix, head })
            })
            .clone()
    }
}

impl Engine for SyntheticEngine {
    fn seq_len(&self) -> usize {
        self.seq
    }

    fn backbone_id(&self) -> u64 {
        self.id
    }

    fn backbone(&mut self, rows: &[Vec<i32>]) -> Result<Vec<Hidden>> {
        let (d, seq) = (self.d, self.seq);
        if rows.is_empty() {
            return Ok(vec![]);
        }
        for row in rows {
            if row.len() != seq {
                bail!("backbone row must be padded to {seq} (got {})", row.len());
            }
        }
        // All prompts run as one [rows·seq, d] activation so the packed
        // kernels see enough rows to partition; every activation row depends
        // only on its own prompt, so outputs stay batch-invariant.  The
        // embedding gather is itself row-partitioned: each activation row
        // gathers only its own token (for W4 backbones that gather decodes
        // nibbles, so it is real work, not a memcpy).
        let total = rows.len() * seq;
        let mut h0 = vec![0f32; total * d];
        let (embed, vocab) = (&self.embed, self.vocab);
        self.threads.par_rows(&mut h0, d, |row0, run| {
            for (rr, hrow) in run.chunks_mut(d).enumerate() {
                let idx = row0 + rr;
                let tok = (rows[idx / seq][idx % seq].max(0) as usize) % vocab;
                embed.row_into(tok, hrow);
            }
        });
        // residual tanh layers: h' = tanh(h·W + h).  Each layer's states are
        // sliced into the per-row bundles as soon as they're produced, so
        // only the current/next activations stay alive beyond the bundles.
        let mut datas: Vec<Vec<f32>> =
            rows.iter().map(|_| Vec::with_capacity((self.layers + 1) * seq * d)).collect();
        fn append_level(datas: &mut [Vec<f32>], level: &[f32], per_row: usize) {
            for (r, data) in datas.iter_mut().enumerate() {
                data.extend_from_slice(&level[r * per_row..(r + 1) * per_row]);
            }
        }
        append_level(&mut datas, &h0, seq * d);
        let mut h = h0;
        for wl in &self.w {
            let mut next = wl.forward(&self.threads, &h, total);
            let h_ref = &h;
            self.threads.par_rows(&mut next, d, |row0, run| {
                for (rr, nrow) in run.chunks_mut(d).enumerate() {
                    let hrow = &h_ref[(row0 + rr) * d..(row0 + rr + 1) * d];
                    for (n, &hv) in nrow.iter_mut().zip(hrow) {
                        *n = (*n + hv).tanh();
                    }
                }
            });
            append_level(&mut datas, &next, seq * d);
            h = next;
        }
        let mut out = Vec::with_capacity(rows.len());
        for (row, data) in rows.iter().zip(datas) {
            self.backbone_rows += 1;
            out.push(Hidden {
                key: super::cache::prompt_key(self.id, row),
                tokens: row.clone(),
                data,
            });
        }
        Ok(out)
    }

    /// Position-separable resume: every backbone position depends only on
    /// its own token (embedding gather + per-position residual tanh
    /// layers), so the donor's first `prefix_len` positions are copied per
    /// level and only the `seq - prefix_len` tail runs the layer stack.
    /// The tail goes through the same kernels with the same per-row
    /// reduction order, so the spliced bundle is bit-identical to a
    /// from-scratch forward of `row` (pinned by tests and the gateway
    /// bench's parity probe).
    fn backbone_resume(&mut self, donor: &Hidden, prefix_len: usize, row: &[i32]) -> Result<Hidden> {
        let (d, seq, layers) = (self.d, self.seq, self.layers);
        if row.len() != seq {
            bail!("resume row must be padded to {seq} (got {})", row.len());
        }
        if prefix_len == 0 || prefix_len > seq {
            bail!("resume prefix of {prefix_len} positions out of range (seq {seq})");
        }
        let per_layer = seq * d;
        if donor.data.len() != (layers + 1) * per_layer {
            bail!(
                "donor bundle has {} floats, expected {} — wrong backbone?",
                donor.data.len(),
                (layers + 1) * per_layer
            );
        }
        if donor.tokens.len() != seq || donor.tokens[..prefix_len] != row[..prefix_len] {
            bail!("donor does not share the first {prefix_len} positions of the resumed row");
        }
        self.resumed_rows += 1;
        self.resumed_positions += prefix_len as u64;
        let key = super::cache::prompt_key(self.id, row);
        if prefix_len == seq {
            // full overlap: the donor bundle is this row's bundle
            return Ok(Hidden { key, tokens: row.to_vec(), data: donor.data.clone() });
        }
        let tail = seq - prefix_len;
        let mut h = vec![0f32; tail * d];
        let (embed, vocab, tail_toks) = (&self.embed, self.vocab, &row[prefix_len..]);
        self.threads.par_rows(&mut h, d, |row0, run| {
            for (rr, hrow) in run.chunks_mut(d).enumerate() {
                let tok = (tail_toks[row0 + rr].max(0) as usize) % vocab;
                embed.row_into(tok, hrow);
            }
        });
        let mut data = Vec::with_capacity((layers + 1) * per_layer);
        data.extend_from_slice(&donor.data[..prefix_len * d]);
        data.extend_from_slice(&h);
        for (l, wl) in self.w.iter().enumerate() {
            let mut next = wl.forward(&self.threads, &h, tail);
            let h_ref = &h;
            self.threads.par_rows(&mut next, d, |row0, run| {
                for (rr, nrow) in run.chunks_mut(d).enumerate() {
                    let hrow = &h_ref[(row0 + rr) * d..(row0 + rr + 1) * d];
                    for (n, &hv) in nrow.iter_mut().zip(hrow) {
                        *n = (*n + hv).tanh();
                    }
                }
            });
            let lvl = (l + 1) * per_layer;
            data.extend_from_slice(&donor.data[lvl..lvl + prefix_len * d]);
            data.extend_from_slice(&next);
            h = next;
        }
        Ok(Hidden { key, tokens: row.to_vec(), data })
    }

    fn side(
        &mut self,
        net: &SideNetwork,
        hiddens: &[Rc<Hidden>],
        rows: &[Vec<i32>],
    ) -> Result<Vec<Vec<f32>>> {
        if hiddens.len() != rows.len() {
            bail!("side: {} hiddens for {} rows", hiddens.len(), rows.len());
        }
        let sw = self.side_weights(net);
        let (d, seq, layers, vocab) = (self.d, self.seq, self.layers, self.vocab);
        let dg = sw.dg;
        let per_layer = seq * d;
        for hidden in hiddens {
            if hidden.data.len() != (layers + 1) * per_layer {
                bail!(
                    "hidden bundle has {} floats, expected {} — wrong backbone?",
                    hidden.data.len(),
                    (layers + 1) * per_layer
                );
            }
        }
        if rows.is_empty() {
            return Ok(vec![]);
        }
        // Batch the whole micro-batch through each ladder step: one
        // [rows, d] gather per layer feeds the shared GEMM kernels; rows
        // stay independent, so per-request results are batch-invariant.
        // The gather is row-partitioned like every other assembly loop
        // (`Rc` handles are unwrapped to plain `&Hidden` first — the
        // bundles themselves are shared-read-only data).
        let nr = rows.len();
        let query_at: Vec<usize> = rows.iter().map(|row| query_pos(row)).collect();
        let bundles: Vec<&Hidden> = hiddens.iter().map(|h| &**h).collect();
        let threads = self.threads;
        let gather = |l: usize| -> Vec<f32> {
            let mut g = vec![0f32; nr * d];
            threads.par_rows(&mut g, d, |row0, run| {
                for (rr, grow) in run.chunks_mut(d).enumerate() {
                    let r = row0 + rr;
                    let pos = query_at[r];
                    let base = l * per_layer + pos * d;
                    grow.copy_from_slice(&bundles[r].data[base..base + d]);
                }
            });
            g
        };
        // ladder: z = tanh(z·mix + down(h_l)), seeded by z0 = down(h0)
        let mut z = gemm::matmul(&self.threads, &gather(0), &sw.down, nr, d, dg);
        for l in 1..=layers {
            let mut next = gemm::matmul(&self.threads, &gather(l), &sw.down, nr, d, dg);
            gemm::matmul_blocked_into(&mut next, &z, &sw.mix[l - 1], nr, dg, dg);
            for v in next.iter_mut() {
                *v = v.tanh();
            }
            z = next;
        }
        let logits = gemm::matmul(&self.threads, &z, &sw.head, nr, dg, vocab);
        Ok(logits.chunks(vocab).map(|c| c.to_vec()).collect())
    }
}

/// One bound task on the executor backend.
struct TaskExec {
    exec: Executor,
    logits_out: usize,
    batch: usize,
}

/// Artifact-backed engine: per-task eval graphs through [`Executor`] with
/// device-resident trainable + frozen state (uploaded once per task).
pub struct ExecutorEngine {
    pub rt: Runtime,
    seq: usize,
    tasks: HashMap<String, TaskExec>,
    id: u64,
    /// worker count for the micro-batch assembly loops (bit-identical for
    /// any value, like every row-partitioned loop in this crate)
    threads: Threads,
}

impl ExecutorEngine {
    pub fn new(rt: Runtime) -> Self {
        ExecutorEngine { rt, seq: 0, tasks: HashMap::new(), id: 0, threads: Threads::default() }
    }

    /// Set the assembly worker count (clamped to >= 1); purely wall-clock.
    pub fn set_threads(&mut self, n: usize) {
        self.threads = Threads::new(n);
    }

    /// Bind a task to an eval artifact, uploading its trainable state and
    /// the shared frozen backbone once.  All bound artifacts must agree on
    /// sequence length (they share the prompt shape).
    pub fn bind_task(
        &mut self,
        task: &str,
        artifact: &str,
        trainable: &HashMap<String, HostTensor>,
        frozen: &HashMap<String, HostTensor>,
    ) -> Result<()> {
        let art = self.rt.load(artifact)?;
        let (b, s) = art
            .manifest
            .batch
            .with_context(|| format!("artifact {artifact} has no batch dims"))?;
        if self.seq == 0 {
            self.seq = s;
        } else if self.seq != s {
            bail!("artifact {artifact} has seq {s}, server is bound to {}", self.seq);
        }
        let logits_out = art.manifest.output_index(Role::Logits).unwrap_or(0);
        let mut exec = Executor::new(art.clone());
        exec.set_many(&self.rt, trainable)?;
        exec.set_many(&self.rt, frozen)?;
        // after binding, only data slots may remain unset
        for slot in &art.manifest.inputs {
            if slot.role != Role::Data && exec.missing().contains(&slot.name.as_str()) {
                bail!("artifact {artifact}: input '{}' ({:?}) not covered by trainable/frozen maps", slot.name, slot.role);
            }
        }
        // fold the artifact identity into the backbone id (cache hygiene,
        // even though this backend is not cacheable today)
        for byte in artifact.bytes() {
            self.id = (self.id ^ byte as u64).wrapping_mul(0x100000001b3);
        }
        self.tasks.insert(task.to_string(), TaskExec { exec, logits_out, batch: b });
        Ok(())
    }
}

impl Engine for ExecutorEngine {
    fn seq_len(&self) -> usize {
        self.seq
    }

    fn backbone_id(&self) -> u64 {
        self.id
    }

    fn cacheable(&self) -> bool {
        false // monolithic artifacts recompute the frozen forward internally
    }

    fn backbone(&mut self, rows: &[Vec<i32>]) -> Result<Vec<Hidden>> {
        // hidden states live inside the fused graph; emit empty markers
        Ok(rows
            .iter()
            .map(|row| Hidden {
                key: super::cache::prompt_key(self.id, row),
                tokens: row.clone(),
                data: vec![],
            })
            .collect())
    }

    fn side(
        &mut self,
        net: &SideNetwork,
        _hiddens: &[Rc<Hidden>],
        rows: &[Vec<i32>],
    ) -> Result<Vec<Vec<f32>>> {
        let te = self
            .tasks
            .get_mut(&net.task)
            .with_context(|| format!("task '{}' not bound to an artifact", net.task))?;
        let seq = self.seq;
        let mut out = Vec::with_capacity(rows.len());
        for chunk in rows.chunks(te.batch) {
            // pad the ragged tail to the artifact batch by repeating the last row
            let mut padded: Vec<&Vec<i32>> = chunk.iter().collect();
            while padded.len() < te.batch {
                padded.push(chunk.last().expect("non-empty chunk"));
            }
            let b = te.batch;
            // validate before fanning out (bail! can't cross par_rows), then
            // assemble the [b, seq] token plane row-partitioned — the last
            // serial stretch on the serve path for artifact-backed batches
            for row in &padded {
                if row.len() != seq {
                    bail!("row must be padded to {seq}");
                }
            }
            let mut tokens = vec![0i32; b * seq];
            let padded_ref = &padded;
            self.threads.par_rows(&mut tokens, seq, |row0, run| {
                for (rr, trow) in run.chunks_mut(seq).enumerate() {
                    trow.copy_from_slice(padded_ref[row0 + rr]);
                }
            });
            let positions: Vec<i32> = padded.iter().map(|row| query_pos(row) as i32).collect();
            // fill data slots by shape: [B,S] i32 -> tokens, [B] i32 -> query
            // positions, anything else -> zeros (loss-only aux inputs)
            let mut filled_tokens = false;
            let mut filled_pos = false;
            let specs: Vec<(usize, DType, Vec<usize>)> = te
                .exec
                .artifact
                .manifest
                .inputs
                .iter()
                .filter(|sl| sl.role == Role::Data)
                .map(|sl| (sl.index, sl.dtype, sl.shape.clone()))
                .collect();
            for (idx, dtype, shape) in specs {
                let t = if !filled_tokens && dtype == DType::I32 && shape == [b, seq] {
                    filled_tokens = true;
                    HostTensor::from_i32(&[b, seq], &tokens)
                } else if !filled_pos && dtype == DType::I32 && shape == [b] {
                    filled_pos = true;
                    HostTensor::from_i32(&[b], &positions)
                } else {
                    HostTensor::zeros(dtype, &shape)
                };
                te.exec.set(&self.rt, idx, &t)?;
            }
            if !filled_tokens {
                bail!("artifact for task '{}' has no [B,S] i32 data slot for tokens", net.task);
            }
            let outputs = te.exec.step(&self.rt)?;
            let logits = outputs
                .get(te.logits_out)
                .with_context(|| format!("missing logits output {}", te.logits_out))?;
            if logits.shape.len() != 2 || logits.shape[0] != b {
                bail!("logits shape {:?} (expected [{}, V])", logits.shape, b);
            }
            let v = logits.shape[1];
            let flat = logits.as_f32()?;
            for i in 0..chunk.len() {
                out.push(flat[i * v..(i + 1) * v].to_vec());
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth_net(task: &str, seed: u64) -> SideNetwork {
        // mirror Registry::register_synthetic without needing a registry
        let mut reg = super::super::registry::Registry::new(1 << 20);
        reg.register_synthetic(task, seed, 100).unwrap();
        (*reg.get(task).unwrap()).clone()
    }

    #[test]
    fn backbone_is_deterministic_and_batch_invariant() {
        let mut e = SyntheticEngine::small(1, 16);
        let a = vec![3i32, 4, 5, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0];
        let b = vec![9i32, 8, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0];
        let solo = e.backbone(std::slice::from_ref(&a)).unwrap();
        let both = e.backbone(&[b.clone(), a.clone()]).unwrap();
        assert_eq!(solo[0].data, both[1].data, "same row must give same hiddens");
        assert_ne!(both[0].data, both[1].data, "different rows must differ");
    }

    #[test]
    fn side_outputs_differ_per_task_but_share_backbone() {
        let mut e = SyntheticEngine::small(1, 16);
        let row = vec![7i32; 16];
        let h: Vec<Rc<Hidden>> =
            e.backbone(std::slice::from_ref(&row)).unwrap().into_iter().map(Rc::new).collect();
        let n1 = synth_net("t1", 11);
        let n2 = synth_net("t2", 22);
        let rows = vec![row];
        let l1 = e.side(&n1, &h, &rows).unwrap();
        let l1b = e.side(&n1, &h, &rows).unwrap();
        let l2 = e.side(&n2, &h, &rows).unwrap();
        assert_eq!(l1[0].len(), e.vocab);
        assert_eq!(l1[0], l1b[0], "side forward must be deterministic");
        assert_ne!(l1[0], l2[0], "different tasks must give different logits");
        assert!(l1[0].iter().all(|x| x.is_finite()));
    }

    #[test]
    fn side_cost_is_much_smaller_than_backbone_cost() {
        // the premise of the hidden-state cache: frozen forward dominates.
        // compare arithmetic volume rather than wall time (robust in CI).
        let e = SyntheticEngine::small(0, 64);
        let backbone_flops = e.layers * e.seq * e.d * e.d;
        let dg = e.d / e.r;
        let side_flops = (e.layers + 1) * e.d * dg + e.layers * dg * dg + dg * e.vocab;
        assert!(backbone_flops > 10 * side_flops, "{backbone_flops} vs {side_flops}");
    }

    #[test]
    fn rejects_unpadded_rows() {
        let mut e = SyntheticEngine::small(1, 16);
        assert!(e.backbone(&[vec![1, 2, 3]]).is_err());
    }

    #[test]
    fn threaded_forward_bit_identical_to_single_threaded() {
        let rows: Vec<Vec<i32>> = (0..5).map(|i| vec![i + 2; 16]).collect();
        let net = synth_net("t", 9);
        let run = |threads: usize| {
            let mut e = SyntheticEngine::small(3, 16);
            e.set_threads(threads);
            let h: Vec<Rc<Hidden>> =
                e.backbone(&rows).unwrap().into_iter().map(Rc::new).collect();
            let logits = e.side(&net, &h, &rows).unwrap();
            (h.iter().map(|x| x.data.clone()).collect::<Vec<_>>(), logits)
        };
        let (h1, l1) = run(1);
        for t in [2usize, 4, 8] {
            let (ht, lt) = run(t);
            assert_eq!(h1, ht, "backbone must be bit-identical at {t} threads");
            assert_eq!(l1, lt, "side must be bit-identical at {t} threads");
        }
    }

    #[test]
    fn large_preset_serves_deterministically() {
        let mut e = SyntheticEngine::large(5, 8);
        assert_eq!((e.d, e.layers, e.vocab), (256, 8, SyntheticEngine::LARGE_VOCAB));
        e.set_threads(2);
        let row = vec![17i32, 300, 2, 0, 0, 0, 0, 0];
        let h: Vec<Rc<Hidden>> =
            e.backbone(std::slice::from_ref(&row)).unwrap().into_iter().map(Rc::new).collect();
        let net = synth_net("big", 77);
        let rows = vec![row];
        let a = e.side(&net, &h, &rows).unwrap();
        let b = e.side(&net, &h, &rows).unwrap();
        assert_eq!(a, b);
        assert_eq!(a[0].len(), SyntheticEngine::LARGE_VOCAB);
        assert!(a[0].iter().all(|x| x.is_finite()));
    }

    #[test]
    fn preset_parse_roundtrip() {
        for p in EnginePreset::ALL {
            assert_eq!(EnginePreset::parse(p.name()).unwrap(), p);
            assert_eq!(p.build(1, 8).vocab, p.vocab());
            let (d, layers, vocab, r) = p.shape();
            let e = p.build(1, 8);
            assert_eq!((e.d, e.layers, e.vocab, e.r), (d, layers, vocab, r));
        }
        assert!(EnginePreset::parse("huge").is_err());
    }

    #[test]
    fn xl_preset_serves_deterministically() {
        let mut e = SyntheticEngine::xl(5, 8);
        assert_eq!((e.d, e.layers, e.vocab), (512, 12, SyntheticEngine::XL_VOCAB));
        e.set_threads(4);
        let row = vec![17i32, 900, 2, 0, 0, 0, 0, 0];
        let h: Vec<Rc<Hidden>> =
            e.backbone(std::slice::from_ref(&row)).unwrap().into_iter().map(Rc::new).collect();
        let net = synth_net("xl-task", 78);
        let rows = vec![row];
        let a = e.side(&net, &h, &rows).unwrap();
        let b = e.side(&net, &h, &rows).unwrap();
        assert_eq!(a, b);
        assert_eq!(a[0].len(), SyntheticEngine::XL_VOCAB);
        assert!(a[0].iter().all(|x| x.is_finite()));
    }

    #[test]
    fn w4_backbone_shrinks_residency_at_least_5x() {
        for p in EnginePreset::ALL {
            let f = p.build_backbone(1, 8, BackboneKind::F32);
            let q = p.build_backbone(1, 8, BackboneKind::W4);
            assert_eq!(f.backbone_kind(), BackboneKind::F32);
            assert_eq!(q.backbone_kind(), BackboneKind::W4);
            assert!(
                q.backbone_resident_bytes() * 5 <= f.backbone_resident_bytes(),
                "{}: w4 {} vs f32 {}",
                p.name(),
                q.backbone_resident_bytes(),
                f.backbone_resident_bytes()
            );
            // distinct numerics -> distinct cache identity
            assert_ne!(f.backbone_id(), q.backbone_id());
        }
    }

    #[test]
    fn w4_engine_matches_f32_roundtrip_engine() {
        let mut w4 = EnginePreset::Small.build_backbone(9, 12, BackboneKind::W4);
        let mut rt = w4.to_f32_roundtrip();
        assert_eq!(rt.backbone_kind(), BackboneKind::F32);
        let rows: Vec<Vec<i32>> = (0..3).map(|i| vec![i * 11 + 1; 12]).collect();
        let hq = w4.backbone(&rows).unwrap();
        let hf = rt.backbone(&rows).unwrap();
        for (a, b) in hq.iter().zip(&hf) {
            assert_eq!(a.data, b.data, "w4 hiddens must equal the f32 round-trip's");
        }
        let net = synth_net("t", 4);
        let h: Vec<Rc<Hidden>> = hq.into_iter().map(Rc::new).collect();
        assert_eq!(
            w4.side(&net, &h, &rows).unwrap(),
            rt.side(&net, &h, &rows).unwrap(),
            "side forwards share f32 weights and identical hiddens"
        );
    }

    #[test]
    fn resume_matches_from_scratch_bitwise() {
        // the prefix-cache acceptance property: a resumed forward must be
        // indistinguishable from a from-scratch forward — for every prefix
        // depth, thread count, and backbone storage kind
        for kind in [BackboneKind::F32, BackboneKind::W4] {
            for threads in [1usize, 4] {
                let mut e = EnginePreset::Small.build_backbone(11, 16, kind);
                e.set_threads(threads);
                let mut donor_row: Vec<i32> = (1..=10).collect();
                donor_row.resize(16, 0);
                let donor = e.backbone(std::slice::from_ref(&donor_row)).unwrap().remove(0);
                for prefix_len in [1usize, 4, 8, 16] {
                    let mut row = donor_row[..prefix_len].to_vec();
                    row.extend((0..16 - prefix_len).map(|i| 40 + i as i32));
                    assert_eq!(row.len(), 16);
                    let resumed = e.backbone_resume(&donor, prefix_len, &row).unwrap();
                    let scratch = e.backbone(std::slice::from_ref(&row)).unwrap().remove(0);
                    assert_eq!(
                        resumed.data, scratch.data,
                        "resume at prefix {prefix_len} must be bit-identical ({threads} threads)"
                    );
                    assert_eq!(resumed.key, scratch.key);
                    assert_eq!(resumed.tokens, scratch.tokens);
                }
                assert_eq!(e.resumed_rows, 4);
            }
        }
    }

    #[test]
    fn resume_validates_donor_and_row() {
        let mut e = SyntheticEngine::small(2, 8);
        let row: Vec<i32> = vec![1, 2, 3, 4, 0, 0, 0, 0];
        let donor = e.backbone(std::slice::from_ref(&row)).unwrap().remove(0);
        // diverging prefix rejected
        let mut other = row.clone();
        other[0] = 9;
        assert!(e.backbone_resume(&donor, 2, &other).is_err());
        // unpadded row rejected
        assert!(e.backbone_resume(&donor, 2, &[1, 2, 3]).is_err());
        // out-of-range prefix rejected
        assert!(e.backbone_resume(&donor, 0, &row).is_err());
        assert!(e.backbone_resume(&donor, 9, &row).is_err());
        // malformed donor rejected
        let bogus = Hidden { key: 0, tokens: row.clone(), data: vec![0.0; 7] };
        assert!(e.backbone_resume(&bogus, 2, &row).is_err());
    }

    /// Engine that keeps the trait's default `backbone_resume` (recompute
    /// from scratch) — the path non-separable backends take.
    struct NoResume(SyntheticEngine);

    impl Engine for NoResume {
        fn seq_len(&self) -> usize {
            self.0.seq_len()
        }
        fn backbone_id(&self) -> u64 {
            self.0.backbone_id()
        }
        fn backbone(&mut self, rows: &[Vec<i32>]) -> Result<Vec<Hidden>> {
            self.0.backbone(rows)
        }
        fn side(
            &mut self,
            net: &SideNetwork,
            hiddens: &[Rc<Hidden>],
            rows: &[Vec<i32>],
        ) -> Result<Vec<Vec<f32>>> {
            self.0.side(net, hiddens, rows)
        }
    }

    #[test]
    fn default_resume_recomputes_and_matches() {
        let row: Vec<i32> = vec![1, 2, 3, 4, 0, 0, 0, 0];
        let mut e = NoResume(SyntheticEngine::small(2, 8));
        let donor = e.backbone(std::slice::from_ref(&row)).unwrap().remove(0);
        let mut ext = row.clone();
        ext[4] = 7;
        let resumed = e.backbone_resume(&donor, 4, &ext).unwrap();
        let scratch = e.backbone(std::slice::from_ref(&ext)).unwrap().remove(0);
        assert_eq!(resumed.data, scratch.data);
        assert_eq!(e.0.resumed_rows, 0, "default path is a full recompute");
    }

    #[test]
    fn side_rejects_foreign_hiddens() {
        let mut e = SyntheticEngine::small(1, 8);
        let net = synth_net("t", 5);
        let bogus = vec![Rc::new(Hidden { key: 1, tokens: vec![0; 8], data: vec![0.0; 3] })];
        assert!(e.side(&net, &bogus, &[vec![0i32; 8]]).is_err());
    }
}
