//! Packed-panel GEMM microkernel: the shared inner loop of [`super::gemm`]
//! and [`super::qgemm`].
//!
//! The activation operand `a[m,k]` is repacked once per kernel call into
//! KC-contiguous stripes ([`pack_a`]): for each k-stripe, every row's
//! `[l0, l0+kc)` slice is stored back-to-back, so the microkernel streams
//! one fully contiguous `kc`-slice per output row instead of striding
//! through `a` with stride `k`.  The MAC itself ([`mac_panel`]) tiles `j`
//! at [`JC`] and unrolls the `l` loop [`KU`]× over four consecutive panel
//! rows; the four updates per output element are written as four separate
//! `acc += a_i * w_i[j]` statements in one `j` pass, so the reduction
//! order and per-add rounding are *exactly* those of four single-step
//! passes — packed results stay bit-identical to [`super::gemm::matmul_naive`]
//! (pinned by exact-equality tests in `gemm`/`qgemm`).
//!
//! Pack-buffer reuse contract: [`with_pack_buf`]/[`with_panel_buf`] hand
//! out thread-local `Vec<f32>` scratch.  Pool workers are long-lived
//! (see [`super::threads`]), so the allocation amortizes across every
//! kernel call a worker ever runs — but the *contents* are invalidated on
//! each call (activations change per micro-batch; only the capacity is
//! reused).  The buffers are taken out of their cell for the duration of
//! the closure, so a reentrant use (which no kernel in this crate does)
//! degrades to a fresh allocation instead of aliasing.

use std::cell::Cell;

/// k-tile (panel height): one packed `a` stripe plus the matching `kc`
/// weight rows stay hot in L1/L2.
pub const KC: usize = 64;
/// j-tile: 256 f32 = 1 KiB output/weight-row segments, L1-friendly.
pub const JC: usize = 256;
/// k-loop unroll factor of the microkernel.
pub const KU: usize = 4;

thread_local! {
    /// Per-worker packed-A scratch (see module doc for the reuse contract).
    static PACK_BUF: Cell<Vec<f32>> = const { Cell::new(Vec::new()) };
    /// Per-thread decoded-weight-panel scratch (the W4 fused epilogue).
    static PANEL_BUF: Cell<Vec<f32>> = const { Cell::new(Vec::new()) };
}

/// Run `f` with this thread's reusable packed-A scratch buffer.
pub fn with_pack_buf<R>(f: impl FnOnce(&mut Vec<f32>) -> R) -> R {
    PACK_BUF.with(|cell| {
        let mut buf = cell.take();
        let r = f(&mut buf);
        cell.set(buf);
        r
    })
}

/// Run `f` with this thread's reusable decoded-panel scratch buffer.
pub fn with_panel_buf<R>(f: impl FnOnce(&mut Vec<f32>) -> R) -> R {
    PANEL_BUF.with(|cell| {
        let mut buf = cell.take();
        let r = f(&mut buf);
        cell.set(buf);
        r
    })
}

/// Pack `a[m,k]` into KC-contiguous stripes, stripe-major:
/// stripe `s` (k-range `[s·KC, min((s+1)·KC, k))`, width `kc_s`) starts at
/// offset `m·s·KC` and holds row `r`'s slice at `[m·s·KC + r·kc_s, +kc_s)`.
/// Total size is exactly `m·k`; `buf` is cleared and refilled (capacity
/// reused).
pub fn pack_a(buf: &mut Vec<f32>, a: &[f32], m: usize, k: usize) {
    assert_eq!(a.len(), m * k);
    buf.clear();
    buf.reserve(m * k);
    let mut l0 = 0;
    while l0 < k {
        let kc = KC.min(k - l0);
        for r in 0..m {
            buf.extend_from_slice(&a[r * k + l0..r * k + l0 + kc]);
        }
        l0 += kc;
    }
}

/// Panel MAC: `out[r, j] += Σ_{l<kc} a[r·a_stride + l] · w[l·n + j]` for
/// `rows × n` outputs, with the `l` reduction ascending.  `a_stride` lets
/// callers feed either a packed stripe (`a_stride == kc`, slices
/// back-to-back) or rows straight out of an unpacked activation matrix
/// (`a_stride == k`).  `j` tiles at [`JC`]; `l` unrolls [`KU`]× with four
/// *separate* single-rounded adds per output element per pass — the exact
/// rounding sequence of the one-step loop, so all paths stay bit-identical.
pub fn mac_panel(
    out: &mut [f32],
    a: &[f32],
    a_stride: usize,
    w: &[f32],
    rows: usize,
    kc: usize,
    n: usize,
) {
    if rows == 0 || kc == 0 || n == 0 {
        return;
    }
    assert_eq!(out.len(), rows * n);
    assert_eq!(w.len(), kc * n);
    assert!(a_stride >= kc && a.len() >= (rows - 1) * a_stride + kc);
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + JC).min(n);
        let jn = j1 - j0;
        for r in 0..rows {
            let arow = &a[r * a_stride..r * a_stride + kc];
            let orow = &mut out[r * n + j0..r * n + j1];
            let mut l = 0;
            while l + KU <= kc {
                let (a0, a1, a2, a3) = (arow[l], arow[l + 1], arow[l + 2], arow[l + 3]);
                let w0 = &w[l * n + j0..l * n + j1];
                let w1 = &w[(l + 1) * n + j0..(l + 1) * n + j1];
                let w2 = &w[(l + 2) * n + j0..(l + 2) * n + j1];
                let w3 = &w[(l + 3) * n + j0..(l + 3) * n + j1];
                for j in 0..jn {
                    let mut acc = orow[j];
                    acc += a0 * w0[j];
                    acc += a1 * w1[j];
                    acc += a2 * w2[j];
                    acc += a3 * w3[j];
                    orow[j] = acc;
                }
                l += KU;
            }
            // kc % KU tail: same single-step adds, still ascending in l
            while l < kc {
                let al = arow[l];
                let wrow = &w[l * n + j0..l * n + j1];
                for (o, &wv) in orow.iter_mut().zip(wrow) {
                    *o += al * wv;
                }
                l += 1;
            }
        }
        j0 = j1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gemm::matmul_naive;
    use crate::util::rng::Rng;

    fn rand(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn pack_layout_roundtrips() {
        // every (row, l) lands exactly once at the documented offset
        let (m, k) = (3usize, KC + 5); // forces a short tail stripe
        let a: Vec<f32> = (0..m * k).map(|v| v as f32).collect();
        let mut buf = Vec::new();
        pack_a(&mut buf, &a, m, k);
        assert_eq!(buf.len(), m * k);
        let mut l0 = 0;
        while l0 < k {
            let kc = KC.min(k - l0);
            for r in 0..m {
                assert_eq!(
                    &buf[m * l0 + r * kc..m * l0 + (r + 1) * kc],
                    &a[r * k + l0..r * k + l0 + kc],
                    "stripe at l0={l0} row {r}"
                );
            }
            l0 += kc;
        }
    }

    #[test]
    fn mac_panel_strided_and_packed_match_naive_bitwise() {
        let mut rng = Rng::new(31);
        // kc values straddle the KU unroll boundary; n straddles JC
        let shapes = [(1usize, 1usize, 1usize), (3, 5, 7), (4, KU, JC + 3), (2, 2 * KU + 3, 19)];
        for (rows, kc, n) in shapes {
            let a = rand(&mut rng, rows * kc);
            let w = rand(&mut rng, kc * n);
            let want = matmul_naive(&a, &w, rows, kc, n);
            let mut got = vec![0f32; rows * n];
            mac_panel(&mut got, &a, kc, &w, rows, kc, n);
            assert_eq!(got, want, "packed-stride {rows}x{kc}x{n}");
            // same inputs viewed through a wider stride
            let stride = kc + 9;
            let mut wide = vec![0f32; (rows - 1) * stride + kc];
            for r in 0..rows {
                wide[r * stride..r * stride + kc].copy_from_slice(&a[r * kc..(r + 1) * kc]);
            }
            let mut got2 = vec![0f32; rows * n];
            mac_panel(&mut got2, &wide, stride, &w, rows, kc, n);
            assert_eq!(got2, want, "wide-stride {rows}x{kc}x{n}");
        }
    }

    #[test]
    fn mac_panel_accumulates_into_existing_output() {
        let mut rng = Rng::new(32);
        let (rows, kc, n) = (2usize, 6usize, 4usize);
        let a = rand(&mut rng, rows * kc);
        let w = rand(&mut rng, kc * n);
        let base = rand(&mut rng, rows * n);
        let mut got = base.clone();
        mac_panel(&mut got, &a, kc, &w, rows, kc, n);
        // reference: the same ascending-l single-add sequence on top of base
        let mut want = base;
        for r in 0..rows {
            for l in 0..kc {
                for j in 0..n {
                    want[r * n + j] += a[r * kc + l] * w[l * n + j];
                }
            }
        }
        assert_eq!(got, want);
    }

    #[test]
    fn scratch_buffers_reuse_capacity() {
        let cap_after_first = with_pack_buf(|buf| {
            buf.resize(1024, 0.0);
            buf.capacity()
        });
        let cap_second = with_pack_buf(|buf| {
            assert!(buf.capacity() >= 1024, "capacity must survive across calls");
            buf.capacity()
        });
        assert!(cap_second >= cap_after_first);
        with_panel_buf(|buf| buf.resize(64, 0.0));
        with_panel_buf(|buf| assert!(buf.capacity() >= 64));
    }
}
