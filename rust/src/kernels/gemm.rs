//! f32 GEMM family with deterministic row-partitioned threading.
//!
//! All variants compute `out[i,j] = Σ_l a[i,l]·b[l,j]` with the reduction
//! over `l` performed in ascending order, so the naive, blocked, packed,
//! and threaded paths are **bit-identical**: blocking/packing tile only
//! the `l` and `j` loops (which never reorders the additions contributing
//! to one output element) and threading partitions output rows `i` across
//! workers.  The kernels equivalence tests pin this with exact equality.
//!
//! The production path ([`matmul`]) is the packed-panel microkernel from
//! [`super::pack`]: each worker repacks its row-run into a thread-local
//! KC-stripe buffer and streams contiguous panels through the KU-unrolled
//! MAC.  The pre-panel cache-blocked kernel stays as [`matmul_blocked`] /
//! [`matmul_blocked_into`] — both the `bench-kernels` baseline that
//! measures the packed win and the in-place accumulate entry point for
//! small side-network shapes.

use super::pack::{self, JC, KC};
use super::threads::Threads;

/// Reference triple loop (ascending `l` accumulation). Kept for the
/// equivalence tests and the `bench-kernels` baseline.
pub fn matmul_naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0f32;
            for l in 0..k {
                acc += a[i * k + l] * b[l * n + j];
            }
            out[i * n + j] = acc;
        }
    }
    out
}

/// Cache-blocked serial GEMM accumulating into `out` (callers must pass
/// zeroed or partial-sum rows).  Inner loop runs contiguously over a
/// `j`-segment of one `b` row and one `out` row, so it vectorizes.
pub fn matmul_blocked_into(out: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(out.len(), m * n);
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut l0 = 0;
    while l0 < k {
        let l1 = (l0 + KC).min(k);
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + JC).min(n);
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                let orow = &mut out[i * n + j0..i * n + j1];
                for l in l0..l1 {
                    let al = arow[l];
                    let brow = &b[l * n + j0..l * n + j1];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += al * bv;
                    }
                }
            }
            j0 = j1;
        }
        l0 = l1;
    }
}

/// Packed-panel serial GEMM accumulating into `out`: repack `a` into this
/// thread's KC-stripe scratch ([`pack::pack_a`]), then stream each stripe
/// through the unrolled [`pack::mac_panel`].  Bit-identical to
/// [`matmul_blocked_into`] (same stripe order, same ascending-`l` adds).
pub fn matmul_packed_into(out: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(out.len(), m * n);
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    pack::with_pack_buf(|buf| {
        pack::pack_a(buf, a, m, k);
        let mut l0 = 0;
        while l0 < k {
            let kc = KC.min(k - l0);
            let apanel = &buf[m * l0..m * l0 + m * kc];
            pack::mac_panel(out, apanel, kc, &b[l0 * n..(l0 + kc) * n], m, kc, n);
            l0 += kc;
        }
    });
}

/// Pre-panel blocked + threaded GEMM, kept as the `bench-kernels` baseline
/// the packed speedup is measured against.  Bit-identical to [`matmul`].
pub fn matmul_blocked(
    threads: &Threads,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut out = vec![0f32; m * n];
    threads.par_rows(&mut out, n, |row0, run| {
        let rows = run.len() / n;
        matmul_blocked_into(run, &a[row0 * k..(row0 + rows) * k], b, rows, k, n);
    });
    out
}

/// Packed-panel + threaded GEMM — the production path: `a[m,k] · b[k,n]`,
/// output rows partitioned across `threads` workers, each worker packing
/// its own row-run into its thread-local scratch.  Bit-identical to
/// [`matmul_naive`].
pub fn matmul(threads: &Threads, a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let t_span = crate::obs::start();
    let mut out = vec![0f32; m * n];
    threads.par_rows(&mut out, n, |row0, run| {
        let rows = run.len() / n;
        matmul_packed_into(run, &a[row0 * k..(row0 + rows) * k], b, rows, k, n);
    });
    crate::obs::end(crate::obs::SpanKind::Gemm, t_span, 0);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    fn rand(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn blocked_matches_naive_bitwise() {
        let mut rng = Rng::new(7);
        for (m, k, n) in [(1, 8, 8), (3, 64, 5), (17, 96, 96), (8, 300, 130)] {
            let a = rand(&mut rng, m * k);
            let b = rand(&mut rng, k * n);
            let want = matmul_naive(&a, &b, m, k, n);
            let mut got = vec![0f32; m * n];
            matmul_blocked_into(&mut got, &a, &b, m, k, n);
            assert_eq!(got, want, "blocked must be bit-identical ({m}x{k}x{n})");
            let mut packed = vec![0f32; m * n];
            matmul_packed_into(&mut packed, &a, &b, m, k, n);
            assert_eq!(packed, want, "packed must be bit-identical ({m}x{k}x{n})");
        }
    }

    #[test]
    fn packed_matches_naive_bitwise_ragged_shapes_all_thread_counts() {
        // shapes deliberately not multiples of KC (64), JC (256), or the
        // KU (4) unroll: short tails on every loop level
        let mut rng = Rng::new(77);
        for (m, k, n) in [(1, 5, 1), (3, 67, 31), (7, 130, 257), (13, 191, 77), (5, 63, 65)] {
            let a = rand(&mut rng, m * k);
            let b = rand(&mut rng, k * n);
            let want = matmul_naive(&a, &b, m, k, n);
            for t in [1usize, 2, 4, 8] {
                let got = matmul(&Threads::new(t), &a, &b, m, k, n);
                assert_eq!(got, want, "packed {m}x{k}x{n} threads={t} must be bit-identical");
            }
        }
    }

    #[test]
    fn threaded_matches_naive_bitwise_all_counts() {
        let mut rng = Rng::new(8);
        let (m, k, n) = (13, 128, 70);
        let a = rand(&mut rng, m * k);
        let b = rand(&mut rng, k * n);
        let want = matmul_naive(&a, &b, m, k, n);
        for t in [1usize, 2, 3, 4, 8] {
            let got = matmul(&Threads::new(t), &a, &b, m, k, n);
            assert_eq!(got, want, "threads={t} must be bit-identical");
            let baseline = matmul_blocked(&Threads::new(t), &a, &b, m, k, n);
            assert_eq!(baseline, want, "blocked baseline threads={t} must be bit-identical");
        }
    }

    #[test]
    fn prop_gemm_equivalence() {
        prop::check(16, 0x6E44, |rng| {
            let m = rng.range(1, 12);
            let k = rng.range(1, 200);
            let n = rng.range(1, 80);
            let a = rand(rng, m * k);
            let b = rand(rng, k * n);
            let want = matmul_naive(&a, &b, m, k, n);
            let got = matmul(&Threads::new(rng.range(1, 5)), &a, &b, m, k, n);
            assert_eq!(got, want);
        });
    }

    #[test]
    fn identity_and_zero() {
        let k = 32;
        let mut eye = vec![0f32; k * k];
        for i in 0..k {
            eye[i * k + i] = 1.0;
        }
        let mut rng = Rng::new(9);
        let a = rand(&mut rng, 4 * k);
        assert_eq!(matmul(&Threads::new(2), &a, &eye, 4, k, k), a);
        let z = vec![0f32; k * 8];
        assert!(matmul(&Threads::new(2), &a, &z, 4, k, 8).iter().all(|&v| v == 0.0));
    }
}
