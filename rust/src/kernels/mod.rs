//! Shared host compute layer: the kernels every host-side forward runs on.
//!
//! The paper's speed claim rests on the frozen 4-bit backbone dominating
//! compute while the side network stays cheap; on the host-side reference
//! backend that dominant cost is a handful of GEMM shapes.  This module
//! centralizes them so serving ([`crate::serve::SyntheticEngine`]), the
//! quantizer ([`crate::quant`]), and the benchmarks all share one tuned
//! implementation instead of hand-rolled triple loops:
//!
//! * [`threads`] — [`Threads`], a persistent channel-fed worker pool that
//!   partitions kernel *outputs* into disjoint whole-row runs; workers are
//!   spawned lazily once and reused across every kernel call (no
//!   spawn/join per GEMM), and results are bit-identical for any thread
//!   count (`--threads` is wall-clock only).  [`Threads::scoped`] keeps
//!   the old spawn-per-call path as a benchmark baseline.
//! * [`pack`] — the packed-panel microkernel layer: KC-stripe activation
//!   packing into reusable per-worker thread-local scratch, plus the
//!   KU-unrolled panel MAC both GEMM families run their inner loop on.
//! * [`gemm`] — naive reference, cache-blocked serial, and the
//!   packed-panel + threaded production f32 GEMM, all bit-identical by
//!   construction (the pre-panel blocked kernel stays as the measured
//!   baseline).
//! * [`qgemm`] — fused W4 dequant-GEMM multiplying straight from packed
//!   nibbles + double-quantized scales, exactly matching
//!   dequantize-then-matmul without materializing the f32 weight: each
//!   KC-stripe of the weight is decoded once per call into a shared panel
//!   (not once per row-run), then MAC'd through [`pack::mac_panel`].  This
//!   is the kernel a `--backbone w4` [`crate::serve::SyntheticEngine`]
//!   serves every backbone matmul through (via [`crate::nn::Linear`]).
//! * [`bench`] — the `qst bench-kernels` runner emitting
//!   `BENCH_kernels.json` (naive vs blocked vs packed vs threaded, pooled
//!   vs scoped-spawn threading, fused panel vs row-run vs
//!   dequantize-then-matmul, with per-kernel GFLOP/s).

pub mod bench;
pub mod gemm;
pub mod pack;
pub mod qgemm;
pub mod threads;

pub use gemm::{matmul, matmul_blocked, matmul_blocked_into, matmul_naive, matmul_packed_into};
pub use qgemm::{w4_matmul, w4_matmul_dq, w4_matmul_rowrun};
pub use threads::{default_threads, pool_workers, set_default_threads, shutdown_pool, Threads};
