//! Shared host compute layer: the kernels every host-side forward runs on.
//!
//! The paper's speed claim rests on the frozen 4-bit backbone dominating
//! compute while the side network stays cheap; on the host-side reference
//! backend that dominant cost is a handful of GEMM shapes.  This module
//! centralizes them so serving ([`crate::serve::SyntheticEngine`]), the
//! quantizer ([`crate::quant`]), and the benchmarks all share one tuned
//! implementation instead of hand-rolled triple loops:
//!
//! * [`threads`] — [`Threads`], a persistent channel-fed worker pool that
//!   partitions kernel *outputs* into disjoint whole-row runs; workers are
//!   spawned lazily once and reused across every kernel call (no
//!   spawn/join per GEMM), and results are bit-identical for any thread
//!   count (`--threads` is wall-clock only).  [`Threads::scoped`] keeps
//!   the old spawn-per-call path as a benchmark baseline.
//! * [`gemm`] — naive reference, cache-blocked serial, and
//!   blocked+threaded f32 GEMM, all bit-identical by construction.
//! * [`qgemm`] — fused W4 dequant-GEMM multiplying straight from packed
//!   nibbles + double-quantized scales, exactly matching
//!   dequantize-then-matmul without materializing the f32 weight.  This is
//!   the kernel a `--backbone w4` [`crate::serve::SyntheticEngine`] serves
//!   every backbone matmul through (via [`crate::nn::Linear`]).
//! * [`bench`] — the `qst bench-kernels` runner emitting
//!   `BENCH_kernels.json` (naive vs blocked vs blocked+threaded, pooled vs
//!   scoped-spawn threading, fused vs dequantize-then-matmul).

pub mod bench;
pub mod gemm;
pub mod qgemm;
pub mod threads;

pub use gemm::{matmul, matmul_blocked_into, matmul_naive};
pub use qgemm::{w4_matmul, w4_matmul_dq};
pub use threads::{default_threads, pool_workers, set_default_threads, shutdown_pool, Threads};
