//! Shared host compute layer: the kernels every host-side forward runs on.
//!
//! The paper's speed claim rests on the frozen 4-bit backbone dominating
//! compute while the side network stays cheap; on the host-side reference
//! backend that dominant cost is a handful of GEMM shapes.  This module
//! centralizes them so serving ([`crate::serve::SyntheticEngine`]), the
//! quantizer ([`crate::quant`]), and the benchmarks all share one tuned
//! implementation instead of hand-rolled triple loops:
//!
//! * [`threads`] — [`Threads`], a scoped-thread pool that partitions
//!   kernel *outputs* into disjoint whole-row runs; results are
//!   bit-identical for any thread count (`--threads` is wall-clock only).
//! * [`gemm`] — naive reference, cache-blocked serial, and
//!   blocked+threaded f32 GEMM, all bit-identical by construction.
//! * [`qgemm`] — fused W4 dequant-GEMM multiplying straight from packed
//!   nibbles + double-quantized scales, exactly matching
//!   dequantize-then-matmul without materializing the f32 weight.
//! * [`bench`] — the `qst bench-kernels` runner emitting
//!   `BENCH_kernels.json` (naive vs blocked vs blocked+threaded, fused
//!   vs dequantize-then-matmul).

pub mod bench;
pub mod gemm;
pub mod qgemm;
pub mod threads;

pub use gemm::{matmul, matmul_blocked_into, matmul_naive};
pub use qgemm::{w4_matmul, w4_matmul_dq};
pub use threads::{default_threads, set_default_threads, Threads};
