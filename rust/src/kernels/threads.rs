//! Scoped-thread pool for host kernels (std only).
//!
//! Every parallel kernel in this crate partitions its *output* into
//! disjoint runs of whole rows and hands each run to one scoped thread.
//! Each row is computed by exactly one thread with the same serial
//! per-row algorithm, so results are bit-identical for any thread count
//! — the `--threads` flag is a pure wall-clock knob, never a numerics
//! knob (the serve tests assert this by comparing N=1 against N=4).
//!
//! The process-wide default is 1 thread; `set_default_threads` (wired to
//! `--threads` in `cli.rs`/`main.rs`) raises it for code that constructs
//! [`Threads::default()`], while kernels callers that need an explicit
//! count use [`Threads::new`].

use std::sync::atomic::{AtomicUsize, Ordering};

static DEFAULT_THREADS: AtomicUsize = AtomicUsize::new(1);

/// Serializes tests that mutate the process-wide default (kernel results
/// never depend on it, but assertions *about* the global itself do).
#[cfg(test)]
pub(crate) static TEST_GLOBAL_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Set the process-wide default worker count (clamped to >= 1).
pub fn set_default_threads(n: usize) {
    DEFAULT_THREADS.store(n.max(1), Ordering::Relaxed);
}

/// Current process-wide default worker count.
pub fn default_threads() -> usize {
    DEFAULT_THREADS.load(Ordering::Relaxed).max(1)
}

/// A worker-count handle for row-partitioned kernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Threads {
    n: usize,
}

impl Default for Threads {
    fn default() -> Self {
        Threads { n: default_threads() }
    }
}

impl Threads {
    pub fn new(n: usize) -> Self {
        Threads { n: n.max(1) }
    }

    pub fn count(&self) -> usize {
        self.n
    }

    /// Split `out` into up to `count()` contiguous runs of whole rows
    /// (`row_len` elements each) and run `f(first_row, run)` for every run,
    /// on scoped threads when more than one run is formed.
    ///
    /// `f` must compute each row of its run independently of the split —
    /// the single-threaded path calls `f(0, out)` once, so any `f` that
    /// only reads shared inputs and writes its own rows is automatically
    /// deterministic across thread counts.
    pub fn par_rows<T, F>(&self, out: &mut [T], row_len: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        assert!(row_len > 0, "row_len must be positive");
        assert_eq!(out.len() % row_len, 0, "output must be whole rows");
        let rows = out.len() / row_len;
        let workers = self.n.min(rows).max(1);
        if workers == 1 {
            f(0, out);
            return;
        }
        let per = rows.div_ceil(workers);
        std::thread::scope(|scope| {
            let f = &f;
            let mut rest = out;
            let mut first_row = 0usize;
            while !rest.is_empty() {
                let take = per.min(rest.len() / row_len);
                let (run, tail) = std::mem::take(&mut rest).split_at_mut(take * row_len);
                rest = tail;
                let row0 = first_row;
                scope.spawn(move || f(row0, run));
                first_row += take;
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_thread_runs_inline() {
        let mut out = vec![0u32; 12];
        Threads::new(1).par_rows(&mut out, 4, |row0, run| {
            for (r, row) in run.chunks_mut(4).enumerate() {
                row.fill((row0 + r) as u32);
            }
        });
        assert_eq!(out, vec![0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2]);
    }

    #[test]
    fn every_row_visited_exactly_once_any_count() {
        for threads in [1usize, 2, 3, 4, 7, 16] {
            let rows = 13;
            let mut out = vec![0u32; rows * 3];
            Threads::new(threads).par_rows(&mut out, 3, |row0, run| {
                for (r, row) in run.chunks_mut(3).enumerate() {
                    for v in row.iter_mut() {
                        *v += (row0 + r) as u32 + 1; // += exposes double visits
                    }
                }
            });
            let want: Vec<u32> =
                (0..rows).flat_map(|r| [r as u32 + 1; 3]).collect();
            assert_eq!(out, want, "threads={threads}");
        }
    }

    #[test]
    fn more_threads_than_rows_is_fine() {
        let mut out = vec![0u8; 2];
        Threads::new(64).par_rows(&mut out, 1, |row0, run| {
            run[0] = row0 as u8 + 1;
        });
        assert_eq!(out, vec![1, 2]);
    }

    #[test]
    fn default_threads_clamps_and_roundtrips() {
        let _guard = TEST_GLOBAL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let before = default_threads();
        set_default_threads(0);
        assert_eq!(default_threads(), 1);
        set_default_threads(3);
        assert_eq!(default_threads(), 3);
        assert_eq!(Threads::default().count(), 3);
        set_default_threads(before);
    }

    #[test]
    #[should_panic]
    fn ragged_output_rejected() {
        let mut out = vec![0f32; 5];
        Threads::new(2).par_rows(&mut out, 2, |_, _| {});
    }
}
