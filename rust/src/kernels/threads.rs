//! Persistent worker pool for host kernels (std only).
//!
//! Every parallel kernel in this crate partitions its *output* into
//! disjoint runs of whole rows and hands each run to one worker.  Each
//! row is computed by exactly one worker with the same serial per-row
//! algorithm, so results are bit-identical for any thread count — the
//! `--threads` flag is a pure wall-clock knob, never a numerics knob
//! (the serve tests assert this by comparing N=1 against N=4).
//!
//! Workers are **long-lived**: a process-wide channel-fed pool spawns
//! them lazily (first time a run needs them) and reuses them for every
//! subsequent kernel call, so a serving engine that issues thousands of
//! small GEMMs per second no longer pays a `thread::spawn` + join per
//! call.  The caller thread always executes one run itself and then
//! blocks on a completion latch, which also keeps the borrowed output
//! slices alive until every pooled run has finished.  The pre-pool
//! behaviour (scoped spawn per call) is kept behind [`Threads::scoped`]
//! as the `bench-kernels` baseline, so the amortization is measured,
//! not assumed.
//!
//! The process-wide default is 1 thread; `set_default_threads` (wired to
//! `--threads` in `cli.rs`/`main.rs`) raises it for code that constructs
//! [`Threads::default()`], while kernels callers that need an explicit
//! count use [`Threads::new`].

use std::sync::atomic::{AtomicUsize, Ordering};

static DEFAULT_THREADS: AtomicUsize = AtomicUsize::new(1);

/// Serializes tests that mutate the process-wide default (kernel results
/// never depend on it, but assertions *about* the global itself do).
#[cfg(test)]
pub(crate) static TEST_GLOBAL_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Set the process-wide default worker count (clamped to >= 1).
pub fn set_default_threads(n: usize) {
    DEFAULT_THREADS.store(n.max(1), Ordering::Relaxed);
}

/// Current process-wide default worker count.
pub fn default_threads() -> usize {
    DEFAULT_THREADS.load(Ordering::Relaxed).max(1)
}

/// Long-lived workers currently spawned in the process-wide pool.
pub fn pool_workers() -> usize {
    pool::worker_count()
}

/// Join every parked pool worker and reset the pool to its never-spawned
/// state; returns how many workers were joined.  The pool is process-wide
/// and its workers otherwise live forever, so teardown points that spawned
/// wide fleets (gateway shard shutdown, CLI command exit, tests that fan
/// out many pools) call this to avoid leaking parked threads.  In-flight
/// kernel calls are drained first (workers only exit on an empty queue),
/// and calls racing the shutdown degrade to inline execution on their own
/// caller — bit-identical, just serial — after which the next pooled call
/// lazily respawns workers.  Not a hot-path operation.
pub fn shutdown_pool() -> usize {
    pool::shutdown()
}

/// A worker-count handle for row-partitioned kernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Threads {
    n: usize,
    /// spawn scoped threads per call instead of using the persistent pool
    /// (the `bench-kernels` baseline; numerics are identical either way)
    scoped: bool,
}

impl Default for Threads {
    fn default() -> Self {
        Threads { n: default_threads(), scoped: false }
    }
}

impl Threads {
    pub fn new(n: usize) -> Self {
        Threads { n: n.max(1), scoped: false }
    }

    /// Like [`Threads::new`] but scope-spawning fresh threads on every
    /// call — the pre-pool behaviour, kept as a measurable baseline.
    pub fn scoped(n: usize) -> Self {
        Threads { n: n.max(1), scoped: true }
    }

    pub fn count(&self) -> usize {
        self.n
    }

    /// Same execution medium (pooled or scoped), different worker count —
    /// for kernels that cap workers below the caller's request.
    pub fn with_count(&self, n: usize) -> Self {
        Threads { n: n.max(1), scoped: self.scoped }
    }

    /// Split `out` into up to `count()` contiguous runs of whole rows
    /// (`row_len` elements each) and run `f(first_row, run)` for every run
    /// — one run inline on the caller, the rest on pool workers (or scoped
    /// threads for [`Threads::scoped`]) when more than one run is formed.
    ///
    /// `f` must compute each row of its run independently of the split —
    /// the single-threaded path calls `f(0, out)` once, so any `f` that
    /// only reads shared inputs and writes its own rows is automatically
    /// deterministic across thread counts.
    pub fn par_rows<T, F>(&self, out: &mut [T], row_len: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        assert!(row_len > 0, "row_len must be positive");
        assert_eq!(out.len() % row_len, 0, "output must be whole rows");
        let rows = out.len() / row_len;
        let workers = self.n.min(rows).max(1);
        if workers == 1 {
            f(0, out);
            return;
        }
        let per = rows.div_ceil(workers);
        // identical partition for the scoped and pooled paths: contiguous
        // whole-row runs of `per` rows (short tail), ascending
        let mut runs: Vec<(usize, &mut [T])> = Vec::with_capacity(workers);
        let mut rest = out;
        let mut first_row = 0usize;
        while !rest.is_empty() {
            let take = per.min(rest.len() / row_len);
            let (run, tail) = std::mem::take(&mut rest).split_at_mut(take * row_len);
            rest = tail;
            runs.push((first_row, run));
            first_row += take;
        }
        if self.scoped {
            std::thread::scope(|scope| {
                let f = &f;
                for (row0, run) in runs {
                    scope.spawn(move || f(row0, run));
                }
            });
        } else {
            let t_span = crate::obs::start();
            let f = &f;
            pool::run(
                runs.into_iter()
                    .map(|(row0, run)| {
                        Box::new(move || f(row0, run)) as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect(),
            );
            crate::obs::end(crate::obs::SpanKind::PoolDispatch, t_span, 0);
        }
    }
}

/// The process-wide persistent worker pool: a mutex-guarded job queue fed
/// by [`pool::run`], drained by detached workers that live for the rest of
/// the process.
mod pool {
    use std::any::Any;
    use std::collections::VecDeque;
    use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
    use std::sync::{Arc, Condvar, Mutex, OnceLock};

    type Job = Box<dyn FnOnce() + Send + 'static>;

    struct Queue {
        jobs: VecDeque<Job>,
        /// workers blocked in `cv.wait` right now
        idle: usize,
        /// workers currently alive (spawned and not yet shut down)
        workers: usize,
        /// set by [`shutdown`]: workers exit once the queue is empty, and
        /// [`run`] degrades to inline execution instead of enqueueing
        shutting_down: bool,
    }

    struct Shared {
        q: Mutex<Queue>,
        cv: Condvar,
        /// join handles of live workers, harvested by [`shutdown`]
        handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    }

    /// Backstop on pool size.  Growth is demand-driven (one worker per
    /// concurrently-queued job that finds no idle worker), so real runs
    /// sit at `--threads - 1` workers.  NOTE: *nested* `par_rows` from
    /// inside a pooled run is not supported — a worker that blocks on a
    /// sub-latch while the pool is at this cap can deadlock, because
    /// waiting callers do not steal queued jobs.  No kernel in this crate
    /// nests; keep it that way (or add job-stealing first).
    const MAX_WORKERS: usize = 256;

    static SHARED: OnceLock<Arc<Shared>> = OnceLock::new();

    fn shared() -> &'static Arc<Shared> {
        SHARED.get_or_init(|| {
            Arc::new(Shared {
                q: Mutex::new(Queue {
                    jobs: VecDeque::new(),
                    idle: 0,
                    workers: 0,
                    shutting_down: false,
                }),
                cv: Condvar::new(),
                handles: Mutex::new(Vec::new()),
            })
        })
    }

    pub(super) fn worker_count() -> usize {
        shared().q.lock().unwrap_or_else(|e| e.into_inner()).workers
    }

    fn worker_loop(sh: Arc<Shared>) {
        loop {
            let job = {
                let mut q = sh.q.lock().unwrap_or_else(|e| e.into_inner());
                loop {
                    if let Some(j) = q.jobs.pop_front() {
                        break j;
                    }
                    if q.shutting_down {
                        // queue drained and a shutdown is in flight: exit
                        q.workers -= 1;
                        return;
                    }
                    q.idle += 1;
                    q = sh.cv.wait(q).unwrap_or_else(|e| e.into_inner());
                    q.idle -= 1;
                }
            };
            job(); // panics are caught inside the wrapper run() queued
        }
    }

    /// Serializes concurrent [`shutdown`] calls: overlapping shutdowns
    /// could otherwise clear `shutting_down` while the first is still
    /// joining, stranding a worker back in its wait loop.
    static SHUTDOWN_LOCK: Mutex<()> = Mutex::new(());

    /// See [`super::shutdown_pool`].  Flag → wake → join → reset: the flag
    /// flips under the queue lock, so no new worker can spawn (and no new
    /// job can enqueue — `run` goes inline) after it is observed set; the
    /// joins therefore cover every live worker.
    pub(super) fn shutdown() -> usize {
        let _one_at_a_time = SHUTDOWN_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let sh = shared();
        {
            let mut q = sh.q.lock().unwrap_or_else(|e| e.into_inner());
            q.shutting_down = true;
        }
        sh.cv.notify_all();
        let handles: Vec<std::thread::JoinHandle<()>> =
            std::mem::take(&mut *sh.handles.lock().unwrap_or_else(|e| e.into_inner()));
        let n = handles.len();
        for h in handles {
            let _ = h.join();
        }
        let mut q = sh.q.lock().unwrap_or_else(|e| e.into_inner());
        q.shutting_down = false;
        n
    }

    /// Completion latch: `run` returns (or unwinds) only after every
    /// submitted job has finished, which is what makes the lifetime
    /// erasure below sound.
    struct Latch {
        left: Mutex<usize>,
        done: Condvar,
        panic: Mutex<Option<Box<dyn Any + Send>>>,
    }

    impl Latch {
        fn finish(&self, panic: Option<Box<dyn Any + Send>>) {
            if let Some(p) = panic {
                self.panic.lock().unwrap_or_else(|e| e.into_inner()).get_or_insert(p);
            }
            let mut left = self.left.lock().unwrap_or_else(|e| e.into_inner());
            *left -= 1;
            if *left == 0 {
                self.done.notify_all();
            }
        }

        fn wait(&self) {
            let mut left = self.left.lock().unwrap_or_else(|e| e.into_inner());
            while *left > 0 {
                left = self.done.wait(left).unwrap_or_else(|e| e.into_inner());
            }
        }
    }

    /// Waits for the latch even if the inline run unwinds, so borrowed
    /// output slices outlive every pooled job no matter what.
    struct WaitOnDrop<'a>(&'a Latch);

    impl Drop for WaitOnDrop<'_> {
        fn drop(&mut self) {
            self.0.wait();
        }
    }

    /// Execute `jobs` to completion: the last job runs inline on the
    /// caller, the rest go to pool workers (spawning new ones only when no
    /// idle worker is available).  A panic in any job is re-raised on the
    /// caller after all jobs finish.
    pub(super) fn run<'a>(mut jobs: Vec<Box<dyn FnOnce() + Send + 'a>>) {
        let Some(inline) = jobs.pop() else { return };
        let latch = Arc::new(Latch {
            left: Mutex::new(jobs.len()),
            done: Condvar::new(),
            panic: Mutex::new(None),
        });
        if !jobs.is_empty() {
            let sh = shared();
            {
                let mut q = sh.q.lock().unwrap_or_else(|e| e.into_inner());
                if q.shutting_down {
                    // a shutdown is in flight: nothing may enqueue or spawn
                    // until it completes, so execute every run on the caller
                    // — same per-row results (see module doc), just serial
                    drop(q);
                    for job in jobs {
                        job();
                    }
                    inline();
                    return;
                }
                let spawn = jobs
                    .len()
                    .saturating_sub(q.idle)
                    .min(MAX_WORKERS.saturating_sub(q.workers));
                for _ in 0..spawn {
                    q.workers += 1;
                    let sh2 = Arc::clone(sh);
                    let handle = std::thread::Builder::new()
                        .name("qst-kernel-pool".into())
                        .spawn(move || worker_loop(sh2))
                        .expect("spawning kernel pool worker");
                    sh.handles.lock().unwrap_or_else(|e| e.into_inner()).push(handle);
                }
                for job in jobs {
                    // SAFETY: `job` borrows the caller's stack (output run +
                    // kernel closure).  Those borrows stay valid because this
                    // function cannot return or unwind before the latch
                    // reaches zero: the normal path waits via WaitOnDrop's
                    // scope below, and the unwind path waits in its Drop.
                    let job: Job = unsafe {
                        std::mem::transmute::<
                            Box<dyn FnOnce() + Send + 'a>,
                            Box<dyn FnOnce() + Send + 'static>,
                        >(job)
                    };
                    let latch = Arc::clone(&latch);
                    q.jobs.push_back(Box::new(move || {
                        let result = catch_unwind(AssertUnwindSafe(job));
                        latch.finish(result.err());
                    }));
                }
            }
            sh.cv.notify_all();
        }
        let guard = WaitOnDrop(&*latch);
        inline();
        drop(guard); // blocks until every pooled job is done
        if let Some(p) = latch.panic.lock().unwrap_or_else(|e| e.into_inner()).take() {
            resume_unwind(p);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_thread_runs_inline() {
        let mut out = vec![0u32; 12];
        Threads::new(1).par_rows(&mut out, 4, |row0, run| {
            for (r, row) in run.chunks_mut(4).enumerate() {
                row.fill((row0 + r) as u32);
            }
        });
        assert_eq!(out, vec![0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2]);
    }

    #[test]
    fn every_row_visited_exactly_once_any_count() {
        for threads in [1usize, 2, 3, 4, 7, 16] {
            for scoped in [false, true] {
                let rows = 13;
                let mut out = vec![0u32; rows * 3];
                let t = if scoped { Threads::scoped(threads) } else { Threads::new(threads) };
                t.par_rows(&mut out, 3, |row0, run| {
                    for (r, row) in run.chunks_mut(3).enumerate() {
                        for v in row.iter_mut() {
                            *v += (row0 + r) as u32 + 1; // += exposes double visits
                        }
                    }
                });
                let want: Vec<u32> = (0..rows).flat_map(|r| [r as u32 + 1; 3]).collect();
                assert_eq!(out, want, "threads={threads} scoped={scoped}");
            }
        }
    }

    #[test]
    fn more_threads_than_rows_is_fine() {
        let mut out = vec![0u8; 2];
        Threads::new(64).par_rows(&mut out, 1, |row0, run| {
            run[0] = row0 as u8 + 1;
        });
        assert_eq!(out, vec![1, 2]);
    }

    #[test]
    fn pooled_matches_scoped_bitwise() {
        // the pool changes only where runs execute, never what they compute
        let compute = |t: Threads| {
            let mut out = vec![0f32; 64 * 9];
            t.par_rows(&mut out, 9, |row0, run| {
                for (r, row) in run.chunks_mut(9).enumerate() {
                    for (j, v) in row.iter_mut().enumerate() {
                        *v = ((row0 + r) as f32).sin() * (j as f32 + 0.5);
                    }
                }
            });
            out
        };
        let want = compute(Threads::new(1));
        for n in [2usize, 3, 8] {
            assert_eq!(compute(Threads::new(n)), want, "pooled n={n}");
            assert_eq!(compute(Threads::scoped(n)), want, "scoped n={n}");
        }
    }

    #[test]
    fn pool_reuses_workers_across_calls() {
        // serialized against shutdown_joins_workers_and_pool_respawns: a
        // concurrent shutdown would zero pool_workers() mid-assertion
        let _guard = TEST_GLOBAL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let t = Threads::new(4);
        let run_once = || {
            let mut out = vec![0u64; 16];
            t.par_rows(&mut out, 1, |row0, run| {
                run[0] = row0 as u64;
            });
        };
        run_once(); // warm the pool
        let after_warmup = pool_workers();
        assert!(after_warmup >= 1, "4-way run must have spawned pool workers");
        for _ in 0..50 {
            run_once();
        }
        // other tests share the pool, so only assert it stays bounded by
        // the hard cap rather than exactly flat
        assert!(pool_workers() <= 256);
    }

    #[test]
    fn worker_panic_propagates_to_caller() {
        let boom = std::panic::catch_unwind(|| {
            let mut out = vec![0u32; 8];
            Threads::new(4).par_rows(&mut out, 1, |row0, _run| {
                if row0 > 0 {
                    panic!("worker {row0} exploded");
                }
            });
        });
        assert!(boom.is_err(), "a pooled worker panic must surface on the caller");
    }

    #[test]
    fn shutdown_joins_workers_and_pool_respawns() {
        let _guard = TEST_GLOBAL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let t = Threads::new(4);
        let compute = || {
            let mut out = vec![0u32; 32];
            t.par_rows(&mut out, 1, |row0, run| run[0] = row0 as u32 * 3);
            out
        };
        let want: Vec<u32> = (0..32).map(|r| r * 3).collect();
        assert_eq!(compute(), want);
        // this run either spawned workers or found earlier-spawned idle
        // ones — either way the pool has live threads to take down
        assert!(shutdown_pool() >= 1, "warm pool must have joined workers");
        // the pool comes back lazily and computes the same thing
        assert_eq!(compute(), want);
        assert_eq!(compute(), want);
    }

    #[test]
    fn default_threads_clamps_and_roundtrips() {
        let _guard = TEST_GLOBAL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let before = default_threads();
        set_default_threads(0);
        assert_eq!(default_threads(), 1);
        set_default_threads(3);
        assert_eq!(default_threads(), 3);
        assert_eq!(Threads::default().count(), 3);
        set_default_threads(before);
    }

    #[test]
    #[should_panic]
    fn ragged_output_rejected() {
        let mut out = vec![0f32; 5];
        Threads::new(2).par_rows(&mut out, 2, |_, _| {});
    }
}
