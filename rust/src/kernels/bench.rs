//! `qst bench-kernels`: host-kernel microbenchmarks → `BENCH_kernels.json`.
//!
//! Four comparisons per matrix size, each verified for exact equivalence
//! before timing so a bench run doubles as an integration check:
//!
//! 1. f32 GEMM (`m×d·d×d`): naive triple loop vs cache-blocked vs the
//!    packed-panel microkernel (serial and threaded) — the
//!    backbone-forward shape that caps `bench-serve`.  `gemm_packed_speedup`
//!    (blocked ÷ packed, serial vs serial) is the microkernel's measured
//!    win; `scripts/check.sh` gates it ≥ 1.2 at d=512.  The O(m·k·n)
//!    naive baseline is skipped above [`BenchKernelsOpts::naive_cap_macs`]
//!    MACs (the blocked kernel stands in as the equivalence reference) so
//!    xl-class shapes don't blow up CI wall-clock.
//! 2. Threading medium: the same packed GEMM on the persistent worker
//!    pool vs scoped spawn-per-call threads — the pool's amortization
//!    delta (`scoped_ms / threaded_ms`).
//! 3. W4 path: dequantize-to-f32-then-matmul vs the fused dequant-GEMM
//!    straight from packed nibbles (panel-shared decode, serial and
//!    threaded).
//! 4. W4 fused generations: the retired row-run kernel (per-run full
//!    nibble re-decode + m/16 worker cap) vs the panel kernel at the same
//!    thread count — `qgemm_packed_speedup`.
//!
//! Every timing is reported both as raw millis and as per-kernel GFLOP/s
//! (2·m·d² FLOPs per call).

use anyhow::{bail, Result};

use super::gemm::{matmul, matmul_blocked_into, matmul_naive, matmul_packed_into};
use super::qgemm::{w4_matmul, w4_matmul_rowrun};
use super::threads::Threads;
use crate::benchkit::{Bench, Json};
use crate::quant::{dequantize_matrix_raw, quantize_matrix_raw};
use crate::util::rng::Rng;

/// Default MAC budget above which the naive baseline is skipped: d=256 at
/// m=64 (4.2M MACs, ~sub-second) still runs it; d=512 (16.8M) does not.
pub const NAIVE_CAP_MACS: usize = 8_000_000;

#[derive(Clone, Debug)]
pub struct BenchKernelsOpts {
    /// matrix sizes: each `d` benches an `m × d · d × d` GEMM
    pub dims: Vec<usize>,
    /// left-operand rows (a sequence's worth of hidden states)
    pub m: usize,
    /// worker count for the threaded variants
    pub threads: usize,
    pub seed: u64,
    /// skip the O(m·k·n) naive baseline when `m·d·d` exceeds this (the
    /// blocked kernel becomes the equivalence reference at that size)
    pub naive_cap_macs: usize,
}

impl Default for BenchKernelsOpts {
    fn default() -> Self {
        BenchKernelsOpts {
            dims: vec![96, 256, 512],
            m: 64,
            threads: 2,
            seed: 0,
            naive_cap_macs: NAIVE_CAP_MACS,
        }
    }
}

/// Median timings (ms) for one size; speedups are vs `naive_ms` for the
/// GEMM family (when measured), blocked-vs-packed for the microkernel,
/// `scoped_ms` vs pool, `w4_dequant_ms` vs fused, and row-run vs panel
/// for the fused-generation delta.
#[derive(Clone, Copy, Debug)]
pub struct KernelRow {
    pub d: usize,
    pub qblock: usize,
    /// `None` when `m·d·d` exceeded the naive MAC budget
    pub naive_ms: Option<f64>,
    pub blocked_ms: f64,
    /// packed-panel microkernel, serial
    pub packed_ms: f64,
    /// packed-panel GEMM on the persistent worker pool
    pub threaded_ms: f64,
    /// packed-panel GEMM with scoped spawn-per-call threads (pre-pool baseline)
    pub scoped_ms: f64,
    pub w4_dequant_ms: f64,
    /// panel-shared-decode fused kernel, serial
    pub w4_fused_ms: f64,
    /// panel-shared-decode fused kernel on the pool
    pub w4_fused_threaded_ms: f64,
    /// retired row-run fused kernel (per-run re-decode, m/16 cap) on the pool
    pub w4_rowrun_ms: f64,
}

impl KernelRow {
    pub fn blocked_speedup(&self) -> Option<f64> {
        self.naive_ms.map(|n| n / self.blocked_ms.max(1e-12))
    }

    pub fn threaded_speedup(&self) -> Option<f64> {
        self.naive_ms.map(|n| n / self.threaded_ms.max(1e-12))
    }

    /// The microkernel's win: cache-blocked serial over packed-panel serial.
    pub fn packed_speedup(&self) -> f64 {
        self.blocked_ms / self.packed_ms.max(1e-12)
    }

    /// Spawn-per-GEMM over persistent-pool wall time (>1 means the pool
    /// amortization pays for itself at this size).
    pub fn pool_speedup(&self) -> f64 {
        self.scoped_ms / self.threaded_ms.max(1e-12)
    }

    pub fn fused_speedup(&self) -> f64 {
        self.w4_dequant_ms / self.w4_fused_ms.max(1e-12)
    }

    /// Panel-shared decode over the retired row-run kernel, both threaded.
    pub fn qgemm_packed_speedup(&self) -> f64 {
        self.w4_rowrun_ms / self.w4_fused_threaded_ms.max(1e-12)
    }

    /// FLOPs of one `m × d · d × d` GEMM call at this size.
    fn flops(&self, m: usize) -> f64 {
        2.0 * (m * self.d * self.d) as f64
    }

    /// GFLOP/s a timing of `ms` milliseconds achieves at this size.
    pub fn gflops(&self, m: usize, ms: f64) -> f64 {
        self.flops(m) / (ms.max(1e-12) * 1e-3) / 1e9
    }
}

#[derive(Clone, Debug)]
pub struct BenchKernelsReport {
    pub m: usize,
    pub threads: usize,
    pub rows: Vec<KernelRow>,
}

impl BenchKernelsReport {
    pub fn to_json(&self) -> String {
        let mut j = Json::new()
            .provenance()
            .str("bench", "kernels")
            .int("m", self.m as u64)
            .int("threads", self.threads as u64);
        for r in &self.rows {
            let d = r.d;
            let ms_and_rate = |j: Json, key: &str, ms: f64| {
                j.num(&format!("gemm_d{d}_{key}_ms"), ms)
                    .num(&format!("gemm_d{d}_{key}_gflops"), r.gflops(self.m, ms))
            };
            match r.naive_ms {
                Some(naive) => {
                    j = ms_and_rate(j, "naive", naive)
                        .int(&format!("gemm_d{d}_naive_skipped"), 0)
                        .num(&format!("gemm_d{d}_blocked_speedup"), r.blocked_speedup().unwrap())
                        .num(&format!("gemm_d{d}_threaded_speedup"), r.threaded_speedup().unwrap());
                }
                None => j = j.int(&format!("gemm_d{d}_naive_skipped"), 1),
            }
            j = ms_and_rate(j, "blocked", r.blocked_ms);
            j = ms_and_rate(j, "packed", r.packed_ms);
            j = ms_and_rate(j, "threaded", r.threaded_ms);
            j = ms_and_rate(j, "scoped", r.scoped_ms);
            j = j
                .num(&format!("gemm_d{d}_packed_speedup"), r.packed_speedup())
                .num(&format!("gemm_d{d}_pool_speedup"), r.pool_speedup())
                .int(&format!("w4_d{d}_qblock"), r.qblock as u64)
                .num(&format!("w4_d{d}_dequant_matmul_ms"), r.w4_dequant_ms)
                .num(&format!("w4_d{d}_fused_ms"), r.w4_fused_ms)
                .num(&format!("w4_d{d}_fused_gflops"), r.gflops(self.m, r.w4_fused_ms))
                .num(&format!("w4_d{d}_fused_threaded_ms"), r.w4_fused_threaded_ms)
                .num(
                    &format!("w4_d{d}_fused_threaded_gflops"),
                    r.gflops(self.m, r.w4_fused_threaded_ms),
                )
                .num(&format!("w4_d{d}_rowrun_ms"), r.w4_rowrun_ms)
                .num(&format!("w4_d{d}_fused_speedup"), r.fused_speedup())
                .num(&format!("w4_d{d}_packed_speedup"), r.qgemm_packed_speedup());
        }
        // headline keys (gated in scripts/check.sh / grepped in CI): the
        // packed wins at the LARGEST benched size, where the microkernel
        // matters most
        if let Some(last) = self.rows.last() {
            j = j
                .int("packed_headline_d", last.d as u64)
                .num("gemm_packed_speedup", last.packed_speedup())
                .num("qgemm_packed_speedup", last.qgemm_packed_speedup());
        }
        j.finish()
    }

    pub fn summary(&self) -> String {
        let mut out = String::new();
        for r in &self.rows {
            let naive = match r.naive_ms {
                Some(ms) => format!("naive {ms:.2} ms"),
                None => "naive skipped".to_string(),
            };
            out.push_str(&format!(
                "kernels d={}: {} | blocked {:.2} ms | packed {:.2} ms ({:.2}x blocked, {:.2} GFLOP/s) | +{} threads {:.2} ms ({:.2} GFLOP/s; pool vs scoped-spawn {:.2} ms = {:.2}x) | w4 dequant+matmul {:.2} ms vs fused {:.2} ms ({:.2}x; threaded {:.2} ms, rowrun {:.2} ms = {:.2}x panel win)\n",
                r.d,
                naive,
                r.blocked_ms,
                r.packed_ms,
                r.packed_speedup(),
                r.gflops(self.m, r.packed_ms),
                self.threads,
                r.threaded_ms,
                r.gflops(self.m, r.threaded_ms),
                r.scoped_ms,
                r.pool_speedup(),
                r.w4_dequant_ms,
                r.w4_fused_ms,
                r.fused_speedup(),
                r.w4_fused_threaded_ms,
                r.w4_rowrun_ms,
                r.qgemm_packed_speedup()
            ));
        }
        out.pop();
        out
    }
}

/// Largest qblock in the quantizer's range that divides `d`.
fn qblock_for(d: usize) -> Result<usize> {
    match crate::quant::qblock_for(d) {
        Some(qb) => Ok(qb),
        None => bail!("dim {d} must be even to bench the W4 path"),
    }
}

pub fn run_bench(opts: &BenchKernelsOpts) -> Result<BenchKernelsReport> {
    let m = opts.m.max(1);
    let serial = Threads::new(1);
    let pool = Threads::new(opts.threads.max(1));
    let scoped = Threads::scoped(opts.threads.max(1));
    let mut rows = Vec::with_capacity(opts.dims.len());
    for &d in &opts.dims {
        let qblock = qblock_for(d)?;
        let mut rng = Rng::new(opts.seed ^ d as u64);
        let a: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..d * d).map(|_| rng.normal() as f32 * 0.3).collect();
        let (packed, scales) = quantize_matrix_raw(&b, d, d, "nf4", qblock);
        let run_naive = m * d * d <= opts.naive_cap_macs;

        // equivalence gate: never publish timings for mismatched kernels.
        // Reference is the naive loop when affordable, the cache-blocked
        // kernel (itself naive-pinned by unit tests) above the MAC budget.
        let want = if run_naive {
            matmul_naive(&a, &b, m, d, d)
        } else {
            let mut blocked = vec![0f32; m * d];
            matmul_blocked_into(&mut blocked, &a, &b, m, d, d);
            blocked
        };
        let mut packed_serial = vec![0f32; m * d];
        matmul_packed_into(&mut packed_serial, &a, &b, m, d, d);
        if packed_serial != want
            || matmul(&pool, &a, &b, m, d, d) != want
            || matmul(&scoped, &a, &b, m, d, d) != want
        {
            bail!("packed/threaded GEMM diverged from the reference at d={d}");
        }
        if run_naive {
            let mut blocked = vec![0f32; m * d];
            matmul_blocked_into(&mut blocked, &a, &b, m, d, d);
            if blocked != want {
                bail!("blocked GEMM diverged from naive at d={d}");
            }
        }
        let wd = dequantize_matrix_raw(&packed, &scales, d, d, "nf4", qblock);
        let w4_want = matmul(&serial, &a, &wd, m, d, d);
        if w4_matmul(&serial, &a, &packed, &scales, m, d, d, "nf4", qblock) != w4_want
            || w4_matmul(&pool, &a, &packed, &scales, m, d, d, "nf4", qblock) != w4_want
            || w4_matmul_rowrun(&pool, &a, &packed, &scales, m, d, d, "nf4", qblock) != w4_want
        {
            bail!("fused dequant-GEMM diverged from dequantize-then-matmul at d={d}");
        }

        let naive = if run_naive {
            Some(
                Bench::quick(&format!("kernels: naive gemm {m}x{d}x{d}"))
                    .run(|| matmul_naive(&a, &b, m, d, d)),
            )
        } else {
            None
        };
        let blocked = Bench::quick(&format!("kernels: blocked gemm {m}x{d}x{d}")).run(|| {
            let mut out = vec![0f32; m * d];
            matmul_blocked_into(&mut out, &a, &b, m, d, d);
            out
        });
        let packed_t = Bench::quick(&format!("kernels: packed gemm {m}x{d}x{d}")).run(|| {
            let mut out = vec![0f32; m * d];
            matmul_packed_into(&mut out, &a, &b, m, d, d);
            out
        });
        let threaded =
            Bench::quick(&format!("kernels: packed gemm {m}x{d}x{d} ({} threads)", pool.count()))
                .run(|| matmul(&pool, &a, &b, m, d, d));
        let scoped_t = Bench::quick(&format!(
            "kernels: packed gemm {m}x{d}x{d} ({} scoped-spawn threads)",
            scoped.count()
        ))
        .run(|| matmul(&scoped, &a, &b, m, d, d));
        let dequant = Bench::quick(&format!("kernels: w4 dequantize+matmul {m}x{d}x{d}")).run(|| {
            let w = dequantize_matrix_raw(&packed, &scales, d, d, "nf4", qblock);
            matmul(&serial, &a, &w, m, d, d)
        });
        let fused = Bench::quick(&format!("kernels: w4 fused dequant-gemm {m}x{d}x{d}"))
            .run(|| w4_matmul(&serial, &a, &packed, &scales, m, d, d, "nf4", qblock));
        let fused_threaded = Bench::quick(&format!(
            "kernels: w4 fused dequant-gemm {m}x{d}x{d} ({} threads)",
            pool.count()
        ))
        .run(|| w4_matmul(&pool, &a, &packed, &scales, m, d, d, "nf4", qblock));
        let rowrun = Bench::quick(&format!(
            "kernels: w4 row-run fused dequant-gemm {m}x{d}x{d} ({} threads, m/16 cap)",
            pool.count()
        ))
        .run(|| w4_matmul_rowrun(&pool, &a, &packed, &scales, m, d, d, "nf4", qblock));

        rows.push(KernelRow {
            d,
            qblock,
            naive_ms: naive.map(|r| r.median_secs * 1e3),
            blocked_ms: blocked.median_secs * 1e3,
            packed_ms: packed_t.median_secs * 1e3,
            threaded_ms: threaded.median_secs * 1e3,
            scoped_ms: scoped_t.median_secs * 1e3,
            w4_dequant_ms: dequant.median_secs * 1e3,
            w4_fused_ms: fused.median_secs * 1e3,
            w4_fused_threaded_ms: fused_threaded.median_secs * 1e3,
            w4_rowrun_ms: rowrun.median_secs * 1e3,
        });
    }
    Ok(BenchKernelsReport { m, threads: pool.count(), rows })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_bench_runs_and_reports() {
        // one small size keeps this a smoke test, not a benchmark
        let rep = run_bench(&BenchKernelsOpts {
            dims: vec![32],
            m: 4,
            threads: 2,
            seed: 1,
            ..BenchKernelsOpts::default()
        })
        .unwrap();
        assert_eq!(rep.rows.len(), 1);
        let j = rep.to_json();
        assert!(j.contains("\"bench\": \"kernels\""));
        assert!(j.contains("gemm_d32_naive_ms"));
        assert!(j.contains("\"gemm_d32_naive_skipped\": 0"));
        assert!(j.contains("gemm_d32_threaded_speedup"));
        assert!(j.contains("gemm_d32_packed_ms"));
        assert!(j.contains("gemm_d32_packed_gflops"));
        assert!(j.contains("gemm_d32_packed_speedup"));
        assert!(j.contains("gemm_d32_scoped_ms"));
        assert!(j.contains("gemm_d32_pool_speedup"));
        assert!(j.contains("w4_d32_fused_speedup"));
        assert!(j.contains("w4_d32_rowrun_ms"));
        assert!(j.contains("w4_d32_packed_speedup"));
        // headline keys for the check.sh / CI gates
        assert!(j.contains("\"packed_headline_d\": 32"));
        assert!(j.contains("\"gemm_packed_speedup\""));
        assert!(j.contains("\"qgemm_packed_speedup\""));
        assert!(rep.summary().contains("d=32"));
    }

    #[test]
    fn naive_skipped_above_mac_budget() {
        // force the skip with a tiny budget: naive keys must vanish, the
        // skipped marker must flip, and the run (blocked-referenced) still
        // passes its equivalence gates
        let rep = run_bench(&BenchKernelsOpts {
            dims: vec![32],
            m: 4,
            threads: 2,
            seed: 1,
            naive_cap_macs: 1,
        })
        .unwrap();
        assert!(rep.rows[0].naive_ms.is_none());
        let j = rep.to_json();
        assert!(j.contains("\"gemm_d32_naive_skipped\": 1"));
        assert!(!j.contains("gemm_d32_naive_ms"));
        assert!(!j.contains("gemm_d32_blocked_speedup"));
        assert!(j.contains("gemm_d32_packed_speedup"));
        assert!(rep.summary().contains("naive skipped"));
    }

    #[test]
    fn odd_dims_rejected() {
        let mut o = BenchKernelsOpts::default();
        o.dims = vec![33];
        assert!(run_bench(&o).is_err());
    }
}
