//! `qst bench-kernels`: host-kernel microbenchmarks → `BENCH_kernels.json`.
//!
//! Three comparisons per matrix size, each verified for exact equivalence
//! before timing so a bench run doubles as an integration check:
//!
//! 1. f32 GEMM (`m×d·d×d`): naive triple loop vs cache-blocked vs
//!    blocked+threaded — the backbone-forward shape that caps `bench-serve`.
//! 2. Threading medium: the same blocked GEMM on the persistent worker
//!    pool vs scoped spawn-per-call threads — the pool's amortization
//!    delta (`scoped_ms / threaded_ms`).
//! 3. W4 path: dequantize-to-f32-then-matmul vs the fused dequant-GEMM
//!    (serial and threaded) straight from packed nibbles.

use anyhow::{bail, Result};

use super::gemm::{matmul, matmul_naive};
use super::qgemm::w4_matmul;
use super::threads::Threads;
use crate::benchkit::{Bench, Json};
use crate::quant::{dequantize_matrix_raw, quantize_matrix_raw};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct BenchKernelsOpts {
    /// matrix sizes: each `d` benches an `m × d · d × d` GEMM
    pub dims: Vec<usize>,
    /// left-operand rows (a sequence's worth of hidden states)
    pub m: usize,
    /// worker count for the threaded variants
    pub threads: usize,
    pub seed: u64,
}

impl Default for BenchKernelsOpts {
    fn default() -> Self {
        BenchKernelsOpts { dims: vec![96, 256], m: 64, threads: 2, seed: 0 }
    }
}

/// Median timings (ms) for one size; speedups are vs `naive_ms` for the
/// GEMM family, vs `scoped_ms` for the pool, and vs `w4_dequant_ms` for
/// the fused family.
#[derive(Clone, Copy, Debug)]
pub struct KernelRow {
    pub d: usize,
    pub qblock: usize,
    pub naive_ms: f64,
    pub blocked_ms: f64,
    /// blocked GEMM on the persistent worker pool
    pub threaded_ms: f64,
    /// blocked GEMM with scoped spawn-per-call threads (pre-pool baseline)
    pub scoped_ms: f64,
    pub w4_dequant_ms: f64,
    pub w4_fused_ms: f64,
    pub w4_fused_threaded_ms: f64,
}

impl KernelRow {
    pub fn blocked_speedup(&self) -> f64 {
        self.naive_ms / self.blocked_ms.max(1e-12)
    }

    pub fn threaded_speedup(&self) -> f64 {
        self.naive_ms / self.threaded_ms.max(1e-12)
    }

    /// Spawn-per-GEMM over persistent-pool wall time (>1 means the pool
    /// amortization pays for itself at this size).
    pub fn pool_speedup(&self) -> f64 {
        self.scoped_ms / self.threaded_ms.max(1e-12)
    }

    pub fn fused_speedup(&self) -> f64 {
        self.w4_dequant_ms / self.w4_fused_ms.max(1e-12)
    }
}

#[derive(Clone, Debug)]
pub struct BenchKernelsReport {
    pub m: usize,
    pub threads: usize,
    pub rows: Vec<KernelRow>,
}

impl BenchKernelsReport {
    pub fn to_json(&self) -> String {
        let mut j = Json::new()
            .provenance()
            .str("bench", "kernels")
            .int("m", self.m as u64)
            .int("threads", self.threads as u64);
        for r in &self.rows {
            let d = r.d;
            j = j
                .num(&format!("gemm_d{d}_naive_ms"), r.naive_ms)
                .num(&format!("gemm_d{d}_blocked_ms"), r.blocked_ms)
                .num(&format!("gemm_d{d}_threaded_ms"), r.threaded_ms)
                .num(&format!("gemm_d{d}_scoped_ms"), r.scoped_ms)
                .num(&format!("gemm_d{d}_blocked_speedup"), r.blocked_speedup())
                .num(&format!("gemm_d{d}_threaded_speedup"), r.threaded_speedup())
                .num(&format!("gemm_d{d}_pool_speedup"), r.pool_speedup())
                .int(&format!("w4_d{d}_qblock"), r.qblock as u64)
                .num(&format!("w4_d{d}_dequant_matmul_ms"), r.w4_dequant_ms)
                .num(&format!("w4_d{d}_fused_ms"), r.w4_fused_ms)
                .num(&format!("w4_d{d}_fused_threaded_ms"), r.w4_fused_threaded_ms)
                .num(&format!("w4_d{d}_fused_speedup"), r.fused_speedup());
        }
        j.finish()
    }

    pub fn summary(&self) -> String {
        let mut out = String::new();
        for r in &self.rows {
            out.push_str(&format!(
                "kernels d={}: naive {:.2} ms | blocked {:.2} ms ({:.2}x) | +{} threads {:.2} ms ({:.2}x; pool vs scoped-spawn {:.2} ms = {:.2}x) | w4 dequant+matmul {:.2} ms vs fused {:.2} ms ({:.2}x)\n",
                r.d,
                r.naive_ms,
                r.blocked_ms,
                r.blocked_speedup(),
                self.threads,
                r.threaded_ms,
                r.threaded_speedup(),
                r.scoped_ms,
                r.pool_speedup(),
                r.w4_dequant_ms,
                r.w4_fused_ms,
                r.fused_speedup()
            ));
        }
        out.pop();
        out
    }
}

/// Largest qblock in the quantizer's range that divides `d`.
fn qblock_for(d: usize) -> Result<usize> {
    match crate::quant::qblock_for(d) {
        Some(qb) => Ok(qb),
        None => bail!("dim {d} must be even to bench the W4 path"),
    }
}

pub fn run_bench(opts: &BenchKernelsOpts) -> Result<BenchKernelsReport> {
    let m = opts.m.max(1);
    let serial = Threads::new(1);
    let pool = Threads::new(opts.threads.max(1));
    let scoped = Threads::scoped(opts.threads.max(1));
    let mut rows = Vec::with_capacity(opts.dims.len());
    for &d in &opts.dims {
        let qblock = qblock_for(d)?;
        let mut rng = Rng::new(opts.seed ^ d as u64);
        let a: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..d * d).map(|_| rng.normal() as f32 * 0.3).collect();
        let (packed, scales) = quantize_matrix_raw(&b, d, d, "nf4", qblock);

        // equivalence gate: never publish timings for mismatched kernels
        let want = matmul_naive(&a, &b, m, d, d);
        if matmul(&serial, &a, &b, m, d, d) != want
            || matmul(&pool, &a, &b, m, d, d) != want
            || matmul(&scoped, &a, &b, m, d, d) != want
        {
            bail!("blocked/threaded GEMM diverged from naive at d={d}");
        }
        let wd = dequantize_matrix_raw(&packed, &scales, d, d, "nf4", qblock);
        let w4_want = matmul(&serial, &a, &wd, m, d, d);
        if w4_matmul(&serial, &a, &packed, &scales, m, d, d, "nf4", qblock) != w4_want
            || w4_matmul(&pool, &a, &packed, &scales, m, d, d, "nf4", qblock) != w4_want
        {
            bail!("fused dequant-GEMM diverged from dequantize-then-matmul at d={d}");
        }

        let naive = Bench::quick(&format!("kernels: naive gemm {m}x{d}x{d}"))
            .run(|| matmul_naive(&a, &b, m, d, d));
        let blocked = Bench::quick(&format!("kernels: blocked gemm {m}x{d}x{d}"))
            .run(|| matmul(&serial, &a, &b, m, d, d));
        let threaded =
            Bench::quick(&format!("kernels: blocked gemm {m}x{d}x{d} ({} threads)", pool.count()))
                .run(|| matmul(&pool, &a, &b, m, d, d));
        let scoped_t = Bench::quick(&format!(
            "kernels: blocked gemm {m}x{d}x{d} ({} scoped-spawn threads)",
            scoped.count()
        ))
        .run(|| matmul(&scoped, &a, &b, m, d, d));
        let dequant = Bench::quick(&format!("kernels: w4 dequantize+matmul {m}x{d}x{d}")).run(|| {
            let w = dequantize_matrix_raw(&packed, &scales, d, d, "nf4", qblock);
            matmul(&serial, &a, &w, m, d, d)
        });
        let fused = Bench::quick(&format!("kernels: w4 fused dequant-gemm {m}x{d}x{d}"))
            .run(|| w4_matmul(&serial, &a, &packed, &scales, m, d, d, "nf4", qblock));
        let fused_threaded = Bench::quick(&format!(
            "kernels: w4 fused dequant-gemm {m}x{d}x{d} ({} threads)",
            pool.count()
        ))
        .run(|| w4_matmul(&pool, &a, &packed, &scales, m, d, d, "nf4", qblock));

        let gflop = 2.0 * (m * d * d) as f64 / 1e9;
        threaded.throughput("GFLOP", gflop);
        rows.push(KernelRow {
            d,
            qblock,
            naive_ms: naive.median_secs * 1e3,
            blocked_ms: blocked.median_secs * 1e3,
            threaded_ms: threaded.median_secs * 1e3,
            scoped_ms: scoped_t.median_secs * 1e3,
            w4_dequant_ms: dequant.median_secs * 1e3,
            w4_fused_ms: fused.median_secs * 1e3,
            w4_fused_threaded_ms: fused_threaded.median_secs * 1e3,
        });
    }
    Ok(BenchKernelsReport { m, threads: pool.count(), rows })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_bench_runs_and_reports() {
        // one small size keeps this a smoke test, not a benchmark
        let rep = run_bench(&BenchKernelsOpts {
            dims: vec![32],
            m: 4,
            threads: 2,
            seed: 1,
        })
        .unwrap();
        assert_eq!(rep.rows.len(), 1);
        let j = rep.to_json();
        assert!(j.contains("\"bench\": \"kernels\""));
        assert!(j.contains("gemm_d32_threaded_speedup"));
        assert!(j.contains("gemm_d32_scoped_ms"));
        assert!(j.contains("gemm_d32_pool_speedup"));
        assert!(j.contains("w4_d32_fused_speedup"));
        assert!(rep.summary().contains("d=32"));
    }

    #[test]
    fn odd_dims_rejected() {
        let mut o = BenchKernelsOpts::default();
        o.dims = vec![33];
        assert!(run_bench(&o).is_err());
    }
}
