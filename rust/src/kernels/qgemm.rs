//! Fused W4 dequant-GEMM: multiply straight out of the packed nibble
//! stream.
//!
//! `y[m,n] = x[m,k] · Ŵ[k,n]` where `Ŵ[l,j] = code[nibble(l,j)] ·
//! scale[l/qblock, j]` — the quantized weight is never materialized as a
//! full f32 matrix.  The kernel walks the reduction in KC-stripes: each
//! stripe's weight panel (`kc × n` floats, at most [`KC`]·n) is decoded
//! **exactly once per call** into a thread-local scratch — the decode
//! itself row-partitioned across workers — and then every output row MACs
//! against the shared panel through the unrolled [`pack::mac_panel`]
//! microkernel.  Because decode cost no longer multiplies by the worker
//! count, threading needs no worker cap: the pre-panel kernel re-decoded
//! the full nibble stream per row-run and had to clamp workers at `m/16`;
//! that kernel survives as [`w4_matmul_rowrun`], the `bench-kernels`
//! baseline the panel speedup is measured against (and a regression test
//! pins that small-`m` calls now really fan out).
//!
//! Scale handling matches the storage format: [`w4_matmul`] copies one
//! stripe's row (`n` floats) out of the caller's scale table, while the
//! double-quantized entry point [`w4_matmul_dq`] — the serving hot path
//! behind a `--backbone w4` [`crate::nn::Linear`] — decodes it straight
//! from the 8-bit `q8`/`gabs`/`gmean` tensors with the exact arithmetic of
//! [`crate::quant::dequantize_scales`] (so the full `k/qblock × n` scale
//! matrix is never allocated per call).
//!
//! Floating-point order is pinned to the reference path: for each output
//! element the `l` reduction ascends (stripes ascend, `l` ascends within a
//! stripe, and the KU-unrolled MAC performs four *separate* single-rounded
//! adds), and each decoded weight is the same single-rounded product
//! `code * scale` the dequantizer produces — so the fused result is
//! **exactly equal** to `dequantize_matrix_raw` followed by
//! [`super::gemm::matmul`], which the equivalence tests assert
//! bit-for-bit.  Threading partitions output rows, as everywhere in
//! [`super`].

use std::sync::atomic::{AtomicU64, Ordering};

use super::pack::{self, KC};
use super::threads::Threads;
use crate::quant::codebook::codebook;

/// Shared fused-kernel body: `fill_scales(stripe, buf)` writes the `n`
/// scales of one K-stripe into `buf` whenever decode crosses into a new
/// stripe.  Both entry points route here, so the nibble/MAC loops and
/// their rounding order exist exactly once.  Returns the output plus the
/// number of MAC row-runs dispatched (the threading-regression probe and
/// the `Qgemm` span annotation).
#[allow(clippy::too_many_arguments)]
fn w4_matmul_impl<S>(
    threads: &Threads,
    x: &[f32],
    packed: &[u8],
    fill_scales: S,
    m: usize,
    k: usize,
    n: usize,
    qdtype: &str,
    qblock: usize,
) -> (Vec<f32>, u64)
where
    S: Fn(usize, &mut [f32]) + Sync,
{
    assert_eq!(x.len(), m * k);
    assert_eq!(k % 2, 0);
    assert_eq!(packed.len(), (k / 2) * n);
    assert_eq!(k % qblock, 0, "K must divide by qblock");
    assert_eq!(qblock % 2, 0, "qblock must be even (nibble pairs share a block)");
    let t_span = crate::obs::start();
    let code = codebook(qdtype);
    let mut out = vec![0f32; m * n];
    if m == 0 {
        crate::obs::end(crate::obs::SpanKind::Qgemm, t_span, 0);
        return (out, 0);
    }
    let mac_runs = AtomicU64::new(0);
    pack::with_panel_buf(|wpanel| {
        wpanel.resize(KC.min(k) * n, 0.0);
        let mut l0 = 0;
        while l0 < k {
            let kc = KC.min(k - l0);
            // decode this stripe's weight panel once, row-partitioned:
            // worker runs split the kc decoded rows, each refilling at most
            // one scale row (O(n)) per qblock boundary it crosses
            {
                let panel = &mut wpanel[..kc * n];
                threads.par_rows(panel, n, |r0, run| {
                    let mut srow = vec![0f32; n];
                    let mut stripe = usize::MAX;
                    for (rr, wrow) in run.chunks_mut(n).enumerate() {
                        let l = l0 + r0 + rr;
                        let s = l / qblock;
                        if s != stripe {
                            stripe = s;
                            fill_scales(s, &mut srow);
                        }
                        // nibble row-pairs share a byte row: 2i low, 2i+1 high
                        let prow = &packed[(l / 2) * n..(l / 2 + 1) * n];
                        let hi = l % 2 == 1;
                        for ((wv, &byte), &sc) in wrow.iter_mut().zip(prow).zip(srow.iter()) {
                            let nib = if hi { byte >> 4 } else { byte & 0xF };
                            *wv = code[nib as usize] * sc;
                        }
                    }
                });
            }
            // MAC every output row against the shared panel — no worker
            // cap: decode cost is already paid once above
            let panel = &wpanel[..kc * n];
            threads.par_rows(&mut out, n, |row0, run| {
                mac_runs.fetch_add(1, Ordering::Relaxed);
                let rows = run.len() / n;
                pack::mac_panel(run, &x[row0 * k + l0..], k, panel, rows, kc, n);
            });
            l0 += kc;
        }
    });
    let runs = mac_runs.load(Ordering::Relaxed);
    crate::obs::end(crate::obs::SpanKind::Qgemm, t_span, runs);
    (out, runs)
}

/// Fused dequant-GEMM from packed nibbles + f32 block scales.
///
/// Layouts match [`crate::quant::quantize_matrix_raw`]: `packed[k/2, n]`
/// holds row `2i` in the low nibble and `2i+1` in the high nibble of byte
/// `[i, j]`; `scales[k/qblock, n]` are per-(stripe, column) absmax.
pub fn w4_matmul(
    threads: &Threads,
    x: &[f32],
    packed: &[u8],
    scales: &[f32],
    m: usize,
    k: usize,
    n: usize,
    qdtype: &str,
    qblock: usize,
) -> Vec<f32> {
    assert!(qblock > 0 && k % qblock == 0);
    assert_eq!(scales.len(), (k / qblock) * n);
    let fill = |stripe: usize, buf: &mut [f32]| {
        buf.copy_from_slice(&scales[stripe * n..(stripe + 1) * n]);
    };
    w4_matmul_impl(threads, x, packed, fill, m, k, n, qdtype, qblock).0
}

/// Test/bench entry exposing how many MAC row-runs the call dispatched —
/// [`Threads::par_rows`] forms `min(workers, m)` runs per stripe
/// deterministically, so the count pins that small-`m` fused calls no
/// longer collapse to serial.
#[doc(hidden)]
pub fn w4_matmul_counting_runs(
    threads: &Threads,
    x: &[f32],
    packed: &[u8],
    scales: &[f32],
    m: usize,
    k: usize,
    n: usize,
    qdtype: &str,
    qblock: usize,
) -> (Vec<f32>, u64) {
    assert!(qblock > 0 && k % qblock == 0);
    assert_eq!(scales.len(), (k / qblock) * n);
    let fill = |stripe: usize, buf: &mut [f32]| {
        buf.copy_from_slice(&scales[stripe * n..(stripe + 1) * n]);
    };
    w4_matmul_impl(threads, x, packed, fill, m, k, n, qdtype, qblock)
}

/// Fused dequant-GEMM from the *double-quantized* storage format
/// (8-bit scales + per-group `gabs`/`gmean`) — the exact tensor set a
/// [`crate::quant::QMatrix`] carries.  Stripe scales are decoded on the
/// fly with the exact arithmetic of [`crate::quant::dequantize_scales`]
/// (single-rounded `q/127·gabs + gmean`), so the result is bit-identical
/// to materializing the scales first — without the per-call `k/qblock × n`
/// allocation the serving hot path used to pay.
#[allow(clippy::too_many_arguments)]
pub fn w4_matmul_dq(
    threads: &Threads,
    x: &[f32],
    packed: &[u8],
    q8: &[i8],
    gabs: &[f32],
    gmean: &[f32],
    qgroup: usize,
    m: usize,
    k: usize,
    n: usize,
    qdtype: &str,
    qblock: usize,
) -> Vec<f32> {
    assert!(qblock > 0 && k % qblock == 0);
    assert_eq!(q8.len(), (k / qblock) * n);
    assert!(qgroup > 0);
    assert!(gabs.len() >= q8.len().div_ceil(qgroup) && gmean.len() >= q8.len().div_ceil(qgroup));
    let fill = |stripe: usize, buf: &mut [f32]| {
        for (j, sv) in buf.iter_mut().enumerate() {
            *sv = crate::quant::scale_at(q8, gabs, gmean, qgroup, stripe * n + j);
        }
    };
    w4_matmul_impl(threads, x, packed, fill, m, k, n, qdtype, qblock).0
}

/// The pre-panel fused kernel: each row-run re-decodes the full nibble
/// stream (O(k·n) per run, independent of its row count), so it caps
/// workers at `m/16` to keep duplicated decode under ~3% of the MAC work.
/// Kept **only** as the `bench-kernels` baseline that measures what the
/// panel-shared decode buys (`qgemm_packed_speedup`); production callers
/// use [`w4_matmul`]/[`w4_matmul_dq`].  Bit-identical to both.
#[allow(clippy::too_many_arguments)]
pub fn w4_matmul_rowrun(
    threads: &Threads,
    x: &[f32],
    packed: &[u8],
    scales: &[f32],
    m: usize,
    k: usize,
    n: usize,
    qdtype: &str,
    qblock: usize,
) -> Vec<f32> {
    assert_eq!(x.len(), m * k);
    assert_eq!(k % 2, 0);
    assert_eq!(packed.len(), (k / 2) * n);
    assert!(qblock > 0 && k % qblock == 0);
    assert_eq!(qblock % 2, 0);
    assert_eq!(scales.len(), (k / qblock) * n);
    let code = codebook(qdtype);
    let mut out = vec![0f32; m * n];
    let threads = threads.with_count(threads.count().min((m / 16).max(1)));
    threads.par_rows(&mut out, n, |row0, run| {
        let rows = run.len() / n;
        let mut w0 = vec![0f32; n];
        let mut w1 = vec![0f32; n];
        let mut srow = vec![0f32; n];
        let mut stripe = usize::MAX;
        for half in 0..k / 2 {
            let s = 2 * half / qblock;
            if s != stripe {
                stripe = s;
                srow.copy_from_slice(&scales[s * n..(s + 1) * n]);
            }
            let prow = &packed[half * n..(half + 1) * n];
            for j in 0..n {
                let sc = srow[j];
                w0[j] = code[(prow[j] & 0xF) as usize] * sc;
                w1[j] = code[(prow[j] >> 4) as usize] * sc;
            }
            for r in 0..rows {
                let x0 = x[(row0 + r) * k + 2 * half];
                let x1 = x[(row0 + r) * k + 2 * half + 1];
                let orow = &mut run[r * n..(r + 1) * n];
                // two separate passes keep the ascending-l rounding order
                for (o, &wv) in orow.iter_mut().zip(&w0) {
                    *o += x0 * wv;
                }
                for (o, &wv) in orow.iter_mut().zip(&w1) {
                    *o += x1 * wv;
                }
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gemm::matmul;
    use crate::quant::{dequantize_matrix_raw, quantize_matrix_raw, quantize_scales};
    use crate::util::{prop, rng::Rng};

    fn rand(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| rng.normal() as f32 * scale).collect()
    }

    #[test]
    fn fused_matches_dequant_then_matmul_exactly() {
        let mut rng = Rng::new(21);
        // m=5 exercises runs shorter than the old serial-collapse regime;
        // m=64 covers multi-stripe row partitioning
        for (m, k, n) in [(5usize, 128usize, 48usize), (64, 128, 48)] {
            for qdtype in ["nf4", "fp4"] {
                let w = rand(&mut rng, k * n, 0.4);
                let x = rand(&mut rng, m * k, 1.0);
                let (packed, scales) = quantize_matrix_raw(&w, k, n, qdtype, 64);
                let t = Threads::new(3);
                let fused = w4_matmul(&t, &x, &packed, &scales, m, k, n, qdtype, 64);
                let wd = dequantize_matrix_raw(&packed, &scales, k, n, qdtype, 64);
                let reference = matmul(&t, &x, &wd, m, k, n);
                assert_eq!(
                    fused, reference,
                    "{qdtype} m={m}: fused must match dequant+matmul bitwise"
                );
                let rowrun = w4_matmul_rowrun(&t, &x, &packed, &scales, m, k, n, qdtype, 64);
                assert_eq!(rowrun, reference, "{qdtype} m={m}: rowrun baseline must match too");
            }
        }
    }

    #[test]
    fn dq_packed_epilogue_matches_dequant_then_matmul_both_qblocks() {
        // the serving entry point (double-quantized scales) against the
        // full dequantize-then-matmul reference, for both codebooks at
        // qblock 64 and 256, serial and threaded
        let mut rng = Rng::new(23);
        for qdtype in ["nf4", "fp4"] {
            for qblock in [64usize, 256] {
                let (m, k, n) = (9usize, 2 * qblock, 33usize);
                let w = rand(&mut rng, k * n, 0.5);
                let x = rand(&mut rng, m * k, 1.0);
                let (packed, scales) = quantize_matrix_raw(&w, k, n, qdtype, qblock);
                let (q8, gabs, gmean) = quantize_scales(&scales, 256);
                let scales_back = crate::quant::dequantize_scales(&q8, &gabs, &gmean, 256);
                let wd = dequantize_matrix_raw(&packed, &scales_back, k, n, qdtype, qblock);
                for t in [1usize, 4] {
                    let threads = Threads::new(t);
                    let fused = w4_matmul_dq(
                        &threads, &x, &packed, &q8, &gabs, &gmean, 256, m, k, n, qdtype, qblock,
                    );
                    let want = matmul(&threads, &x, &wd, m, k, n);
                    assert_eq!(fused, want, "{qdtype} qblock={qblock} threads={t}");
                }
            }
        }
    }

    #[test]
    fn small_m_no_longer_collapses_to_serial() {
        // the retired m/16 cap would have clamped m=8 to 1 worker; the
        // panel kernel must dispatch min(workers, m) = 8 MAC runs per
        // stripe (k=128 → 2 stripes → 16 runs), deterministically
        let mut rng = Rng::new(24);
        let (m, k, n) = (8usize, 128usize, 40usize);
        let w = rand(&mut rng, k * n, 0.5);
        let x = rand(&mut rng, m * k, 1.0);
        let (packed, scales) = quantize_matrix_raw(&w, k, n, "nf4", 64);
        let (out, runs) = w4_matmul_counting_runs(
            &Threads::new(8), &x, &packed, &scales, m, k, n, "nf4", 64,
        );
        assert_eq!(runs, 16, "8 workers on m=8 must form 8 MAC runs per stripe");
        // and the fan-out must not change the bits
        let (serial, serial_runs) = w4_matmul_counting_runs(
            &Threads::new(1), &x, &packed, &scales, m, k, n, "nf4", 64,
        );
        assert_eq!(serial_runs, 2);
        assert_eq!(out, serial);
    }

    #[test]
    fn double_quant_entry_matches_scale_roundtrip() {
        let mut rng = Rng::new(22);
        let (m, k, n) = (3, 256, 20);
        let w = rand(&mut rng, k * n, 0.7);
        let x = rand(&mut rng, m * k, 1.0);
        let (packed, scales) = quantize_matrix_raw(&w, k, n, "nf4", 64);
        let (q8, gabs, gmean) = quantize_scales(&scales, 256);
        let t = Threads::new(2);
        let fused = w4_matmul_dq(&t, &x, &packed, &q8, &gabs, &gmean, 256, m, k, n, "nf4", 64);
        let scales_back = crate::quant::dequantize_scales(&q8, &gabs, &gmean, 256);
        let want = w4_matmul(&t, &x, &packed, &scales_back, m, k, n, "nf4", 64);
        assert_eq!(fused, want);
    }

    #[test]
    fn prop_fused_equivalence_all_thread_counts() {
        prop::check(12, 0x5734, |rng| {
            let m = rng.range(1, 80);
            let k = 64 * rng.range(1, 4);
            let n = rng.range(1, 40);
            let qdtype = if rng.bool(0.5) { "nf4" } else { "fp4" };
            let w = rand(rng, k * n, 0.5);
            let x = rand(rng, m * k, 1.0);
            let (packed, scales) = quantize_matrix_raw(&w, k, n, qdtype, 64);
            let wd = dequantize_matrix_raw(&packed, &scales, k, n, qdtype, 64);
            let want = matmul(&Threads::new(1), &x, &wd, m, k, n);
            for t in [1usize, 2, 4] {
                let got = w4_matmul(&Threads::new(t), &x, &packed, &scales, m, k, n, qdtype, 64);
                assert_eq!(got, want, "{qdtype} threads={t}");
                let rowrun =
                    w4_matmul_rowrun(&Threads::new(t), &x, &packed, &scales, m, k, n, qdtype, 64);
                assert_eq!(rowrun, want, "rowrun {qdtype} threads={t}");
            }
        });
    }
}
