//! Fused W4 dequant-GEMM: multiply straight out of the packed nibble
//! stream.
//!
//! `y[m,n] = x[m,k] · Ŵ[k,n]` where `Ŵ[l,j] = code[nibble(l,j)] ·
//! scale[l/qblock, j]` — the quantized weight is never materialized as a
//! full f32 matrix.  The only f32 side table is the per-block scale
//! stripe (`k/qblock × n`, 1/qblock-th of the weight count), which the
//! double-quantized entry point reconstructs once via
//! [`crate::quant::dequantize_scales`].
//!
//! Floating-point order is pinned to the reference path: for each output
//! element the `l` reduction ascends, and each decoded weight is the same
//! single-rounded product `code * scale` the dequantizer produces — so
//! the fused result is **exactly equal** to `dequantize_matrix_raw`
//! followed by [`super::gemm::matmul`], which the equivalence tests
//! assert bit-for-bit.  Threading partitions output rows, as everywhere
//! in [`super`].

use super::threads::Threads;
use crate::quant::codebook::codebook;
use crate::quant::dequantize_scales;

/// Fused dequant-GEMM from packed nibbles + f32 block scales.
///
/// Layouts match [`crate::quant::quantize_matrix_raw`]: `packed[k/2, n]`
/// holds row `2i` in the low nibble and `2i+1` in the high nibble of byte
/// `[i, j]`; `scales[k/qblock, n]` are per-(stripe, column) absmax.
pub fn w4_matmul(
    threads: &Threads,
    x: &[f32],
    packed: &[u8],
    scales: &[f32],
    m: usize,
    k: usize,
    n: usize,
    qdtype: &str,
    qblock: usize,
) -> Vec<f32> {
    assert_eq!(x.len(), m * k);
    assert_eq!(k % 2, 0);
    assert_eq!(packed.len(), (k / 2) * n);
    assert_eq!(k % qblock, 0, "K must divide by qblock");
    assert_eq!(qblock % 2, 0, "qblock must be even (nibble pairs share a block)");
    assert_eq!(scales.len(), (k / qblock) * n);
    let code = codebook(qdtype);
    let mut out = vec![0f32; m * n];
    // each run re-decodes the full nibble stream (O(k·n), independent of its
    // row count), so cap workers at m/16: with ≥16 rows per run the MAC work
    // (2·rows·k·n flops) keeps duplicated decode under ~3% of the total
    let threads = Threads::new(threads.count().min((m / 16).max(1)));
    threads.par_rows(&mut out, n, |row0, run| {
        let rows = run.len() / n;
        // decode each nibble row-pair once per run, then rank-1-update all
        // of this run's output rows from the two decoded rows — the only
        // f32 weight state alive is this 2×n pair, never the full matrix
        let mut w0 = vec![0f32; n];
        let mut w1 = vec![0f32; n];
        for half in 0..k / 2 {
            // rows 2·half and 2·half+1 share a scale stripe (qblock even)
            let srow = &scales[(2 * half / qblock) * n..][..n];
            let prow = &packed[half * n..(half + 1) * n];
            for j in 0..n {
                let s = srow[j];
                w0[j] = code[(prow[j] & 0xF) as usize] * s;
                w1[j] = code[(prow[j] >> 4) as usize] * s;
            }
            for r in 0..rows {
                let x0 = x[(row0 + r) * k + 2 * half];
                let x1 = x[(row0 + r) * k + 2 * half + 1];
                let orow = &mut run[r * n..(r + 1) * n];
                // two separate passes keep the ascending-l rounding order
                for (o, &wv) in orow.iter_mut().zip(&w0) {
                    *o += x0 * wv;
                }
                for (o, &wv) in orow.iter_mut().zip(&w1) {
                    *o += x1 * wv;
                }
            }
        }
    });
    out
}

/// Fused dequant-GEMM from the *double-quantized* storage format
/// (8-bit scales + per-group `gabs`/`gmean`) — the exact tensor set a
/// [`crate::quant::QMatrix`] carries.
#[allow(clippy::too_many_arguments)]
pub fn w4_matmul_dq(
    threads: &Threads,
    x: &[f32],
    packed: &[u8],
    q8: &[i8],
    gabs: &[f32],
    gmean: &[f32],
    qgroup: usize,
    m: usize,
    k: usize,
    n: usize,
    qdtype: &str,
    qblock: usize,
) -> Vec<f32> {
    let scales = dequantize_scales(q8, gabs, gmean, qgroup);
    w4_matmul(threads, x, packed, &scales, m, k, n, qdtype, qblock)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gemm::matmul;
    use crate::quant::{dequantize_matrix_raw, quantize_matrix_raw, quantize_scales};
    use crate::util::{prop, rng::Rng};

    fn rand(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| rng.normal() as f32 * scale).collect()
    }

    #[test]
    fn fused_matches_dequant_then_matmul_exactly() {
        let mut rng = Rng::new(21);
        // m=5 collapses to the serial path (worker cap is m/16); m=64 runs
        // 3 genuine workers, covering the row-partitioned fused path
        for (m, k, n) in [(5usize, 128usize, 48usize), (64, 128, 48)] {
            for qdtype in ["nf4", "fp4"] {
                let w = rand(&mut rng, k * n, 0.4);
                let x = rand(&mut rng, m * k, 1.0);
                let (packed, scales) = quantize_matrix_raw(&w, k, n, qdtype, 64);
                let t = Threads::new(3);
                let fused = w4_matmul(&t, &x, &packed, &scales, m, k, n, qdtype, 64);
                let wd = dequantize_matrix_raw(&packed, &scales, k, n, qdtype, 64);
                let reference = matmul(&t, &x, &wd, m, k, n);
                assert_eq!(
                    fused, reference,
                    "{qdtype} m={m}: fused must match dequant+matmul bitwise"
                );
            }
        }
    }

    #[test]
    fn double_quant_entry_matches_scale_roundtrip() {
        let mut rng = Rng::new(22);
        let (m, k, n) = (3, 256, 20);
        let w = rand(&mut rng, k * n, 0.7);
        let x = rand(&mut rng, m * k, 1.0);
        let (packed, scales) = quantize_matrix_raw(&w, k, n, "nf4", 64);
        let (q8, gabs, gmean) = quantize_scales(&scales, 256);
        let t = Threads::new(2);
        let fused = w4_matmul_dq(&t, &x, &packed, &q8, &gabs, &gmean, 256, m, k, n, "nf4", 64);
        let scales_back = crate::quant::dequantize_scales(&q8, &gabs, &gmean, 256);
        let want = w4_matmul(&t, &x, &packed, &scales_back, m, k, n, "nf4", 64);
        assert_eq!(fused, want);
    }

    #[test]
    fn prop_fused_equivalence_all_thread_counts() {
        prop::check(12, 0x5734, |rng| {
            let m = rng.range(1, 80); // spans the serial (<16) and threaded regimes
            let k = 64 * rng.range(1, 4);
            let n = rng.range(1, 40);
            let qdtype = if rng.bool(0.5) { "nf4" } else { "fp4" };
            let w = rand(rng, k * n, 0.5);
            let x = rand(rng, m * k, 1.0);
            let (packed, scales) = quantize_matrix_raw(&w, k, n, qdtype, 64);
            let wd = dequantize_matrix_raw(&packed, &scales, k, n, qdtype, 64);
            let want = matmul(&Threads::new(1), &x, &wd, m, k, n);
            for t in [1usize, 2, 4] {
                let got = w4_matmul(&Threads::new(t), &x, &packed, &scales, m, k, n, qdtype, 64);
                assert_eq!(got, want, "{qdtype} threads={t}");
            }
        });
    }
}
