//! True dimensions of every model in the paper's evaluation.

/// Finetuning methods compared in the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    Full,
    Lora,
    QLora,
    Adapter,
    Lst,
    Qst,
}

pub const ALL_METHODS: [Method; 6] =
    [Method::Full, Method::Lora, Method::QLora, Method::Adapter, Method::Lst, Method::Qst];

impl Method {
    pub fn name(self) -> &'static str {
        match self {
            Method::Full => "Full-FT",
            Method::Lora => "LoRA",
            Method::QLora => "QLoRA",
            Method::Adapter => "Adapter",
            Method::Lst => "LST",
            Method::Qst => "QST",
        }
    }

    pub fn key(self) -> &'static str {
        match self {
            Method::Full => "full",
            Method::Lora => "lora",
            Method::QLora => "qlora",
            Method::Adapter => "adapter",
            Method::Lst => "lst",
            Method::Qst => "qst",
        }
    }

    /// 4-bit frozen weights?
    pub fn quantized(self) -> bool {
        matches!(self, Method::QLora | Method::Qst)
    }

    /// Backprop through the backbone?
    pub fn full_backprop(self) -> bool {
        !matches!(self, Method::Lst | Method::Qst)
    }
}

#[derive(Clone, Copy, Debug)]
pub struct PaperModel {
    pub name: &'static str,
    pub d: usize,
    pub layers: usize,
    pub heads: usize,
    pub ff: usize,
    pub vocab: usize,
    /// total backbone parameters (reported size)
    pub params: f64,
}

pub const PAPER_MODELS: [PaperModel; 9] = [
    PaperModel { name: "OPT-1.3B", d: 2048, layers: 24, heads: 32, ff: 8192, vocab: 50272, params: 1.3e9 },
    PaperModel { name: "OPT-2.7B", d: 2560, layers: 32, heads: 32, ff: 10240, vocab: 50272, params: 2.7e9 },
    PaperModel { name: "OPT-6.7B", d: 4096, layers: 32, heads: 32, ff: 16384, vocab: 50272, params: 6.7e9 },
    PaperModel { name: "OPT-13B", d: 5120, layers: 40, heads: 40, ff: 20480, vocab: 50272, params: 13.0e9 },
    PaperModel { name: "OPT-30B", d: 7168, layers: 48, heads: 56, ff: 28672, vocab: 50272, params: 30.0e9 },
    PaperModel { name: "OPT-66B", d: 9216, layers: 64, heads: 72, ff: 36864, vocab: 50272, params: 66.0e9 },
    PaperModel { name: "LLaMA-2-7B", d: 4096, layers: 32, heads: 32, ff: 11008, vocab: 32000, params: 6.7e9 },
    PaperModel { name: "LLaMA-2-13B", d: 5120, layers: 40, heads: 40, ff: 13824, vocab: 32000, params: 13.0e9 },
    PaperModel { name: "LLaMA-2-70B", d: 8192, layers: 80, heads: 64, ff: 28672, vocab: 32000, params: 69.0e9 },
];

pub fn paper_model(name: &str) -> Option<&'static PaperModel> {
    PAPER_MODELS.iter().find(|m| m.name == name)
}

impl PaperModel {
    /// LoRA trainable params: rank-r adapters on every linear (QLoRA's setup,
    /// r = 64 as in Dettmers et al.).
    pub fn lora_params(&self, rank: usize) -> f64 {
        // per layer: q,k,v,o (d->d) + mlp matrices (d->ff, ff->d [, d->ff])
        let attn = 4.0 * (self.d + self.d) as f64;
        let is_llama = self.name.starts_with("LLaMA");
        let mlp = if is_llama {
            2.0 * (self.d + self.ff) as f64 + (self.ff + self.d) as f64
        } else {
            (self.d + self.ff) as f64 + (self.ff + self.d) as f64
        };
        self.layers as f64 * rank as f64 * (attn + mlp)
    }

    /// Houlsby adapter trainable params (bottleneck rank after attn + mlp).
    pub fn adapter_params(&self, rank: usize) -> f64 {
        self.layers as f64 * 2.0 * (2.0 * self.d as f64 * rank as f64 + (rank + self.d) as f64)
    }

    /// Side-network trainable params at reduction r with the given downsample
    /// module ("linear" | "adapter" | "pool").
    pub fn side_params(&self, r: usize, downsample: &str, ds_rank: usize) -> f64 {
        let dg = (self.d / r) as f64;
        let ffg = (self.ff / r) as f64;
        let is_llama = self.name.starts_with("LLaMA");
        let attn = 4.0 * dg * dg;
        let mlp = if is_llama { 3.0 * dg * ffg } else { 2.0 * dg * ffg };
        let blocks = self.layers as f64 * (attn + mlp + 4.0 * dg);
        let down_per = match downsample {
            "linear" => self.d as f64 * dg + dg,
            "pool" | "maxpool" | "avgpool" => 0.0,
            _ => self.d as f64 * ds_rank as f64 + ds_rank as f64 * dg, // lora/adapter
        };
        let down = (self.layers + 1) as f64 * down_per;
        let up = dg * self.d as f64 + self.d as f64;
        blocks + down + up + self.layers as f64 + 2.0
    }

    /// Trainable parameters for each method (paper defaults: LoRA r=64 for
    /// QLoRA/LoRA, adapter rank 64, QST r=16 with adapter-rank-16 downsamples,
    /// LST r=8 with linear downsamples).
    pub fn trainable_params(&self, m: Method) -> f64 {
        match m {
            Method::Full => self.params,
            Method::Lora | Method::QLora => self.lora_params(64),
            Method::Adapter => self.adapter_params(64),
            Method::Lst => self.side_params(8, "linear", 0),
            Method::Qst => self.side_params(16, "adapter", 16),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_works() {
        assert!(paper_model("LLaMA-2-70B").is_some());
        assert!(paper_model("GPT-5").is_none());
    }

    #[test]
    fn trainable_ordering_matches_table1() {
        // paper Table 1 (OPT-6.7B): QLoRA 2.33% >> QST 0.42%
        let m = paper_model("OPT-6.7B").unwrap();
        let qlora_pct = m.trainable_params(Method::QLora) / m.params * 100.0;
        let qst_pct = m.trainable_params(Method::Qst) / m.params * 100.0;
        assert!(qlora_pct > 1.0 && qlora_pct < 5.0, "QLoRA% = {qlora_pct:.2}");
        assert!(qst_pct < 1.0, "QST% = {qst_pct:.2}");
        assert!(qlora_pct / qst_pct > 3.0, "paper reports ~5.5x");
    }

    #[test]
    fn lst_heavier_than_qst() {
        // LST's linear downsamplers + r=8 side dominate QST's r=16 + adapters
        for m in &PAPER_MODELS {
            assert!(m.trainable_params(Method::Lst) > m.trainable_params(Method::Qst), "{}", m.name);
        }
    }
}
