//! Analytical memory + FLOPs models evaluated at the paper's *true* model
//! dimensions (1.3B–70B) — the regenerators for Fig 1a, Fig 4, Fig 5b/5c,
//! Table 2's memory column and Table 3.
//!
//! Memory footprint is an arithmetic consequence of (method, dims, batch,
//! seq): exact for weights/optimizer, Megatron-style for activations.  The
//! constants are calibrated against measured proxy runs
//! (`qst experiments --id calib`) and the calibration is recorded in
//! EXPERIMENTS.md.

pub mod flops;
pub mod memory;
pub mod paperdims;

pub use flops::flops_per_token;
pub use memory::{memory_bytes, MemoryBreakdown};
pub use paperdims::{paper_model, Method, PaperModel, PAPER_MODELS};
