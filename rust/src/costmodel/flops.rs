//! Training-FLOPs model (paper Table 3: FLOPS per token, lower is better).
//!
//! Conventions: forward = 2·P, backward = 4·P MAC-FLOPs per token through any
//! parameter set P that gradients traverse.
//! * Full/LoRA/Adapter/QLoRA: 6·P backbone (+ small method extras).
//! * QST: 2·P frozen forward + 6·P_side — no backbone backward at all.
//! * LST (as evaluated in the paper, 16-bit backbone): activation
//!   checkpointing forces a forward *recompute* during the side backward
//!   (their implementation re-materializes h_f), i.e. 4·P + 6·P_side; once
//!   the 16-bit model spills past device memory (13B/70B on 4×A5000),
//!   offload stalls inflate the effective cost further — modeled as a spill
//!   multiplier from the memory model.  This reproduces Table 3's LST
//!   blow-up at 70B.

use super::memory::memory_bytes;
use super::paperdims::{Method, PaperModel};

/// Aggregate device memory of the paper's testbed (4x RTX A5000, 24 GB).
pub const TESTBED_BYTES: f64 = 4.0 * 24.0e9;

pub fn flops_per_token_r(m: &PaperModel, method: Method, r: usize) -> f64 {
    let p = m.params;
    match method {
        Method::Full => 6.0 * p,
        Method::Lora => 6.0 * p + 6.0 * m.trainable_params(Method::Lora),
        // QLoRA pays the same matmuls plus dequant overhead on every forward
        // weight access (paper: "slightly higher than LoRA")
        Method::QLora => (6.0 * p + 6.0 * m.trainable_params(Method::QLora)) * 1.03,
        Method::Adapter => 6.0 * p + 6.0 * m.trainable_params(Method::Adapter),
        Method::Lst => {
            let side = 6.0 * m.side_params(8, "linear", 0);
            let base = 4.0 * p + side; // fwd + checkpointed recompute
            // spill multiplier once 16-bit weights + activations exceed the testbed
            let need = memory_bytes(m, Method::Lst, 4, 384).total();
            let spill = (need / TESTBED_BYTES).max(1.0);
            base * spill
        }
        Method::Qst => 2.0 * p + 6.0 * m.side_params(r, "adapter", 16),
    }
}

pub fn flops_per_token(m: &PaperModel, method: Method) -> f64 {
    flops_per_token_r(m, method, 16)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::memory::NF4_BITS;
    use crate::costmodel::paperdims::paper_model;

    #[test]
    fn table3_ordering() {
        // paper Table 3: QST lowest everywhere; LST highest at 70B
        for name in ["LLaMA-2-7B", "LLaMA-2-13B", "LLaMA-2-70B"] {
            let m = paper_model(name).unwrap();
            let qst = flops_per_token(m, Method::Qst);
            for meth in [Method::QLora, Method::Lora, Method::Adapter, Method::Lst] {
                assert!(flops_per_token(m, meth) > qst, "{name} {meth:?}");
            }
        }
        let m70 = paper_model("LLaMA-2-70B").unwrap();
        let lst = flops_per_token(m70, Method::Lst);
        for meth in [Method::QLora, Method::Lora, Method::Adapter, Method::Qst] {
            assert!(lst > flops_per_token(m70, meth), "LST must be worst at 70B");
        }
    }

    #[test]
    fn qst_speedup_factor() {
        // paper: ~2.5-3x lower FLOPs/token than QLoRA (11.7 vs 4.4 at 7B)
        let m = paper_model("LLaMA-2-7B").unwrap();
        let ratio = flops_per_token(m, Method::QLora) / flops_per_token(m, Method::Qst);
        assert!(ratio > 2.0 && ratio < 3.5, "ratio {ratio:.2} (paper ~2.66)");
    }

    #[test]
    fn qlora_slightly_above_lora() {
        let m = paper_model("LLaMA-2-13B").unwrap();
        let qlora = flops_per_token(m, Method::QLora);
        let lora = flops_per_token(m, Method::Lora);
        assert!(qlora > lora && qlora < lora * 1.1);
    }

    #[test]
    fn nf4_bits_sane() {
        assert!((NF4_BITS - 4.127).abs() < 0.01);
    }
}
