//! Analytical memory model (DESIGN.md §5) — regenerates Fig 1a, Fig 4,
//! Fig 5b and the memory columns of Tables 1/2.
//!
//! Conventions (calibrated against the paper's reported QLoRA/QST numbers;
//! see EXPERIMENTS.md §Calibration):
//! * 16-bit storage for full-precision weights, NF4+double-quant = 4.127
//!   bits/param for quantized ones; trainable params always 16-bit.
//! * Optimizer: AdamW with fp32 moments + fp16 gradient = 10 bytes per
//!   trainable param (the paper's "threefold" bucket).
//! * Activations: Megatron-style `s·b·(34·h + 5·a·s)` bytes per layer for
//!   full-backprop methods; side-tuning methods store only the side network's
//!   activations (width h/r) + the (L+1) downsampled inputs + a 2-layer live
//!   window of the frozen forward + the logits buffer.

use super::paperdims::{Method, PaperModel};
use crate::nn::{w4_resident_bytes, BackboneKind};
use crate::quant::qblock_for;
use crate::serve::EnginePreset;

#[derive(Clone, Copy, Debug, Default)]
pub struct MemoryBreakdown {
    pub weights: f64,
    pub optimizer: f64,
    pub activations: f64,
}

impl MemoryBreakdown {
    pub fn total(&self) -> f64 {
        self.weights + self.optimizer + self.activations
    }
}

/// Bits per parameter of the NF4 + double-quantization storage format.
pub const NF4_BITS: f64 = 4.127;
/// Bytes of optimizer state per trainable parameter (fp16 grad + fp32 m, v).
pub const OPT_BYTES: f64 = 10.0;
/// Bytes per element of the 16-bit compute dtype.
const B16: f64 = 2.0;

/// Per-layer stored-activation bytes for one sample position (Megatron-LM
/// table 2 shape, 16-bit): 34·h + 5·a·s.
fn act_per_layer(m: &PaperModel, s: usize) -> f64 {
    34.0 * m.d as f64 + 5.0 * m.heads as f64 * s as f64
}

/// Full memory breakdown for finetuning `model` with `method` at batch `b`,
/// sequence `s`, optionally overriding the side-network reduction factor.
pub fn memory_bytes_r(m: &PaperModel, method: Method, b: usize, s: usize, r: usize) -> MemoryBreakdown {
    let p = m.params;
    let pt = match method {
        Method::Qst => m.side_params(r, "adapter", 16),
        other => m.trainable_params(other),
    };

    let frozen_bits = if method.quantized() { NF4_BITS } else { 16.0 };
    let weights = match method {
        Method::Full => p * B16,
        _ => p * frozen_bits / 8.0 + pt * B16,
    };
    let optimizer = pt * OPT_BYTES;

    let tokens = (b * s) as f64;
    let logits = tokens * m.vocab as f64 * B16;
    let activations = if method.full_backprop() {
        m.layers as f64 * tokens * act_per_layer(m, s) + logits
    } else {
        // side network at width h/r (heads scale down too)
        let side = PaperModel { d: m.d / r, heads: (m.heads / r).max(1), ..*m };
        let side_acts = m.layers as f64 * tokens * act_per_layer(&side, s);
        // (L+1) downsampled hidden states kept for the side inputs
        let down_inputs = (m.layers + 1) as f64 * tokens * (m.d / r) as f64 * B16;
        // live working set of the frozen forward (~2 layers, freed as it goes)
        let live = 2.0 * tokens * act_per_layer(m, s);
        side_acts + down_inputs + live + logits
    };
    MemoryBreakdown { weights, optimizer, activations }
}

pub fn memory_bytes(m: &PaperModel, method: Method, b: usize, s: usize) -> MemoryBreakdown {
    let r = match method {
        Method::Lst => 8,
        _ => 16,
    };
    memory_bytes_r(m, method, b, s, r)
}

/// Inference-residency bytes of one QST side network (16-bit params, no
/// optimizer state, no activations) — the unit of the serving registry's
/// byte budget (`serve::registry`).
pub fn side_network_bytes(m: &PaperModel, r: usize) -> f64 {
    m.side_params(r, "adapter", 16) * B16
}

/// Resident bytes of a [`crate::serve::SyntheticEngine`] frozen backbone
/// (embedding `[vocab, d]` + `layers` × `[d, d]`) under the given storage
/// kind.  This is the analytical twin of
/// `SyntheticEngine::backbone_resident_bytes` — a costmodel test pins the
/// two to exact agreement, so `BENCH_serve.json` figures are auditable
/// without building an engine.
pub fn backbone_resident_bytes(preset: EnginePreset, backbone: BackboneKind) -> usize {
    let (d, layers, vocab, _r) = preset.shape();
    match backbone {
        BackboneKind::F32 => 4 * (vocab * d + layers * d * d),
        BackboneKind::W4 => {
            let mat = |k: usize, n: usize| {
                let qb = qblock_for(k).expect("engine dims are even");
                w4_resident_bytes(k, n, qb, crate::nn::linear::QGROUP)
            };
            mat(vocab, d) + layers * mat(d, d)
        }
    }
}

/// Resident bytes of a whole serving gateway: `shards` backbone replicas
/// (each [`backbone_resident_bytes`]) plus each shard's hidden-state cache
/// budget and its side-network registry charge (`tasks` synthetic networks
/// at [`crate::serve::registry::SYNTHETIC_TASK_BYTES`] apiece — the same
/// nominal figure the shards register with, so the model and the live
/// registry agree exactly).  Reported in `BENCH_gateway.json` per shard
/// count, mirroring `backbone_resident_bytes` in `BENCH_serve.json`.
pub fn gateway_resident_bytes(
    preset: EnginePreset,
    backbone: BackboneKind,
    shards: usize,
    tasks: usize,
    cache_budget: usize,
) -> usize {
    shards
        * (backbone_resident_bytes(preset, backbone)
            + cache_budget
            + tasks * crate::serve::registry::SYNTHETIC_TASK_BYTES)
}

/// Per-endpoint buffering one framed socket connection keeps resident —
/// the kernel send/receive buffers plus the frame scratch a peer holds
/// while encoding/decoding (one in-flight frame per direction; the
/// largest honest frame is a shard report with a full latency reservoir,
/// ~0.5 MiB, but steady-state frames are Submit/Done at a few KiB).
pub const SOCKET_ENDPOINT_BUF_BYTES: usize = 64 << 10;

/// Fixed per-worker-process overhead beyond the shard's own state: the
/// process's private copy of the kernel worker-pool stacks, allocator
/// slack, and runtime bookkeeping that in-proc shards amortize across
/// one address space.
pub const WORKER_PROCESS_OVERHEAD_BYTES: usize = 1 << 20;

/// Resident bytes of a gateway whose shards run as separate
/// `qst shard-worker` processes behind framed sockets (`--connect`).
///
/// The cache and registry were *already* per-shard in the in-process
/// model — each shard thread owns private copies — so those carry over
/// 1:1 when a shard becomes a process.  The deployment delta is, per
/// shard: [`WORKER_PROCESS_OVERHEAD_BYTES`] for the worker process
/// itself, plus four socket endpoint buffers
/// ([`SOCKET_ENDPOINT_BUF_BYTES`] each) — send + receive on the worker
/// end and send + receive on the gateway end of its connection.
/// Reported in `BENCH_gateway.json` alongside the in-process figure so
/// the cost of crossing the process boundary is auditable per shard
/// count.
pub fn gateway_resident_bytes_multiproc(
    preset: EnginePreset,
    backbone: BackboneKind,
    shards: usize,
    tasks: usize,
    cache_budget: usize,
) -> usize {
    gateway_resident_bytes(preset, backbone, shards, tasks, cache_budget)
        + shards * (WORKER_PROCESS_OVERHEAD_BYTES + 4 * SOCKET_ENDPOINT_BUF_BYTES)
}

/// Exact on-the-wire size of a sectioned task artifact
/// ([`crate::store::artifact`]): fixed header + one index entry per
/// section (fixed part + the section's name bytes) + the section
/// payloads.  The analytical twin of [`crate::store::ArtifactBuilder`]'s
/// output length — a test pins the two to exact agreement, so deploy
/// payload sizes and store catalog footprints are auditable without
/// building artifacts.
pub fn artifact_bytes(sections: &[(&str, usize)]) -> usize {
    crate::store::artifact::ARTIFACT_HEADER_BYTES
        + sections
            .iter()
            .map(|(name, payload)| {
                crate::store::artifact::INDEX_ENTRY_FIXED_BYTES + name.len() + payload
            })
            .sum::<usize>()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::paperdims::{paper_model, ALL_METHODS};

    const GB: f64 = 1e9;

    #[test]
    fn table2_shape_llama70b() {
        // paper Table 2 (bs 4, seq 384): QLoRA 95.5 GB, QST 56.0 GB (1.7x)
        let m = paper_model("LLaMA-2-70B").unwrap();
        let qlora = memory_bytes(m, Method::QLora, 4, 384).total() / GB;
        let qst = memory_bytes(m, Method::Qst, 4, 384).total() / GB;
        assert!(qlora > 60.0 && qlora < 130.0, "QLoRA {qlora:.1} GB (paper 95.5)");
        assert!(qst > 30.0 && qst < 70.0, "QST {qst:.1} GB (paper 56.0)");
        let ratio = qlora / qst;
        assert!(ratio > 1.4 && ratio < 3.0, "ratio {ratio:.2} (paper 1.7)");
    }

    #[test]
    fn qst_lowest_at_every_batch_size() {
        // Fig 4a: QST lowest at every batch size
        let m = paper_model("LLaMA-2-70B").unwrap();
        for &b in &[1usize, 4, 16, 32] {
            let qst = memory_bytes(m, Method::Qst, b, 512).total();
            for meth in ALL_METHODS {
                if meth != Method::Qst {
                    assert!(
                        memory_bytes(m, meth, b, 512).total() >= qst,
                        "{} beats QST at b={b}",
                        meth.name()
                    );
                }
            }
        }
    }

    #[test]
    fn activation_growth_flatter_for_side_tuning() {
        // Fig 4a/4c: QST/LST activation slope << QLoRA's
        let m = paper_model("LLaMA-2-70B").unwrap();
        let slope = |meth: Method| {
            let a1 = memory_bytes(m, meth, 1, 512).activations;
            let a2 = memory_bytes(m, meth, 16, 512).activations;
            a2 - a1
        };
        assert!(slope(Method::Qst) < slope(Method::QLora) / 5.0);
        assert!(slope(Method::Lst) < slope(Method::Lora) / 5.0);
    }

    #[test]
    fn quantization_gap_widens_with_size(){
        // Fig 4b: the QST-vs-16-bit gap grows with total model bits
        let small = paper_model("OPT-1.3B").unwrap();
        let big = paper_model("OPT-66B").unwrap();
        let gap = |m: &PaperModel| {
            memory_bytes(m, Method::Lst, 4, 512).total()
                - memory_bytes(m, Method::Qst, 4, 512).total()
        };
        assert!(gap(big) > 10.0 * gap(small));
    }

    #[test]
    fn qst_beats_lst_by_weights() {
        // paper §4.4: "~100 GB reduction compared to LST" at 70B
        let m = paper_model("LLaMA-2-70B").unwrap();
        let lst = memory_bytes(m, Method::Lst, 4, 512).total() / GB;
        let qst = memory_bytes(m, Method::Qst, 4, 512).total() / GB;
        assert!(lst - qst > 80.0, "LST {lst:.0} vs QST {qst:.0}");
    }

    #[test]
    fn side_network_residency_is_tiny_vs_backbone() {
        // multi-tenant serving premise: dozens of side networks cost less
        // than one extra backbone copy
        let m = paper_model("LLaMA-2-70B").unwrap();
        let side = side_network_bytes(m, 16);
        let backbone_4bit = m.params * NF4_BITS / 8.0;
        assert!(side > 0.0);
        assert!(32.0 * side < backbone_4bit, "32 side nets {side:.3e} vs backbone {backbone_4bit:.3e}");
    }

    #[test]
    fn backbone_resident_bytes_matches_real_engines() {
        // the analytical figure must equal the bytes an actual engine holds,
        // and the W4 form must be at least 5x smaller (ISSUE acceptance);
        // EnginePreset::ALL keeps new presets (xl) pinned automatically
        for preset in EnginePreset::ALL {
            for kind in [BackboneKind::F32, BackboneKind::W4] {
                let engine = preset.build_backbone(3, 8, kind);
                assert_eq!(
                    backbone_resident_bytes(preset, kind),
                    engine.backbone_resident_bytes(),
                    "{} {}",
                    preset.name(),
                    kind.name()
                );
            }
            let f32b = backbone_resident_bytes(preset, BackboneKind::F32);
            let w4b = backbone_resident_bytes(preset, BackboneKind::W4);
            assert!(w4b * 5 <= f32b, "{}: {w4b} vs {f32b}", preset.name());
        }
    }

    #[test]
    fn gateway_residency_pins_to_real_engine_and_registry() {
        // the analytical gateway figure must equal what a shard actually
        // holds: a real engine's resident backbone + a real registry after
        // registering the same synthetic tasks + the cache budget
        let (preset, kind, tasks, cache_budget) = (EnginePreset::Small, BackboneKind::W4, 3, 1 << 20);
        let engine = preset.build_backbone(7, 8, kind);
        let mut reg = crate::serve::Registry::new(1 << 30);
        for i in 0..tasks {
            reg.register_synthetic(
                &crate::gateway::task_name(i),
                crate::gateway::task_seed(7, i),
                crate::serve::registry::SYNTHETIC_TASK_BYTES,
            )
            .unwrap();
        }
        let per_shard = engine.backbone_resident_bytes() + reg.bytes() + cache_budget;
        for shards in [1usize, 2, 4] {
            assert_eq!(
                gateway_resident_bytes(preset, kind, shards, tasks, cache_budget),
                shards * per_shard,
                "{shards} shards"
            );
        }
        // replication is linear, and W4 replicas stay far cheaper than f32
        let w4 = gateway_resident_bytes(preset, BackboneKind::W4, 4, tasks, 0);
        let f32b = gateway_resident_bytes(preset, BackboneKind::F32, 4, tasks, 0);
        assert!(w4 < f32b, "W4 fleet {w4} must undercut f32 fleet {f32b}");
    }

    #[test]
    fn multiproc_residency_adds_linear_socket_and_process_overhead() {
        // the per-process figure = in-process figure + shards * (worker
        // process overhead + 4 endpoint buffers), exactly
        let per_shard_delta = WORKER_PROCESS_OVERHEAD_BYTES + 4 * SOCKET_ENDPOINT_BUF_BYTES;
        for shards in [1usize, 2, 4] {
            let base = gateway_resident_bytes(EnginePreset::Small, BackboneKind::W4, shards, 3, 1 << 20);
            let multi =
                gateway_resident_bytes_multiproc(EnginePreset::Small, BackboneKind::W4, shards, 3, 1 << 20);
            assert_eq!(multi - base, shards * per_shard_delta, "{shards} shards");
        }
        // the overhead must stay small next to what replication buys:
        // one W4 large-preset shard still fits in the multiproc delta
        // budget many times over is NOT required — but the delta must not
        // dwarf the f32 backbone it replaces
        let f32_backbone = backbone_resident_bytes(EnginePreset::Large, BackboneKind::F32);
        assert!(per_shard_delta < f32_backbone);
    }

    #[test]
    fn artifact_bytes_pins_to_real_builder_output() {
        use crate::store::{side_artifact_synthetic, ArtifactBuilder, SECTION_SYNTHETIC};
        // multi-section artifact: the model must hit the real byte count
        let built = ArtifactBuilder::new()
            .section("tensor:side.w", vec![0u8; 40])
            .section("tensor:side.b", vec![0u8; 12])
            .finish();
        assert_eq!(
            artifact_bytes(&[("tensor:side.w", 40), ("tensor:side.b", 12)]),
            built.len()
        );
        // the synthetic deploy artifact: one 16-byte section
        let synth = side_artifact_synthetic(9, 1 << 12);
        assert_eq!(artifact_bytes(&[(SECTION_SYNTHETIC, 16)]), synth.len());
        // empty artifact is just the header
        assert_eq!(artifact_bytes(&[]), ArtifactBuilder::new().finish().len());
    }

    #[test]
    fn full_ft_7x_claim() {
        // abstract: "QST reduces total memory up to 7x vs full finetuning"
        let m = paper_model("LLaMA-2-70B").unwrap();
        let full = memory_bytes(m, Method::Full, 16, 384).total();
        let qst = memory_bytes(m, Method::Qst, 16, 384).total();
        assert!(full / qst > 5.0, "ratio {:.1}", full / qst);
    }
}
