//! GLUE-like synthetic task family (paper Table 1).
//!
//! Eight tasks mirroring the benchmark's shapes: single- or paired-sequence
//! classification / regression, each built on structure the backbone saw in
//! pretraining.  Every task has a train/eval generator returning
//! `(tokens, label)` where the label is one of the reserved label tokens and
//! prediction happens at the final SEP position (LM-head reuse, as in the
//! paper).

use super::batcher::ClsExample;
use super::corpus::Corpus;
use super::vocabulary::{Vocab, BOS, SEP};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GlueTask {
    Rte,   // 2-way entailment
    Mrpc,  // 2-way paraphrase
    Stsb,  // 5-bucket similarity regression (Pearson reported)
    Cola,  // 2-way acceptability (bigram-grammar violations)
    Sst2,  // 2-way sentiment
    Qnli,  // 2-way answerability
    Qqp,   // 2-way paraphrase (noisier than MRPC)
    Mnli,  // 3-way entailment
}

pub const ALL_TASKS: [GlueTask; 8] = [
    GlueTask::Rte, GlueTask::Mrpc, GlueTask::Stsb, GlueTask::Cola,
    GlueTask::Sst2, GlueTask::Qnli, GlueTask::Qqp, GlueTask::Mnli,
];

impl GlueTask {
    pub fn name(self) -> &'static str {
        match self {
            GlueTask::Rte => "RTE",
            GlueTask::Mrpc => "MRPC",
            GlueTask::Stsb => "STS-B",
            GlueTask::Cola => "CoLA",
            GlueTask::Sst2 => "SST-2",
            GlueTask::Qnli => "QNLI",
            GlueTask::Qqp => "QQP",
            GlueTask::Mnli => "MNLI",
        }
    }

    pub fn n_classes(self) -> usize {
        match self {
            GlueTask::Mnli => 3,
            GlueTask::Stsb => 5,
            _ => 2,
        }
    }

    /// STS-B reports Pearson correlation over bucket scores.
    pub fn is_regression(self) -> bool {
        matches!(self, GlueTask::Stsb)
    }
}

pub struct GlueGen {
    pub task: GlueTask,
    pub vocab: Vocab,
    corpus: Corpus,
    rng: Rng,
    seq: usize,
}

impl GlueGen {
    pub fn new(task: GlueTask, vocab: Vocab, seq: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ (task as u64) << 32);
        let corpus = Corpus::new(vocab.clone(), rng.next_u64());
        GlueGen { task, vocab, corpus, rng, seq }
    }

    fn content_span(&mut self, len: usize) -> Vec<i32> {
        let mut toks = self.corpus.tokens(len * 2);
        toks.retain(|&t| self.vocab.is_content(t));
        toks.truncate(len);
        while toks.len() < len {
            toks.push(self.vocab.content0 + self.rng.below(self.vocab.n_content) as i32);
        }
        toks
    }

    /// Synonym map shared with the pretraining corpus.
    fn synonym(&self, t: i32) -> i32 {
        self.vocab.synonym(t)
    }

    /// Pack `[BOS a... SEP b... SEP]` right-padded to seq; label position is
    /// the last SEP.  When the pair overflows `seq`, truncation replaces the
    /// final kept token with SEP — otherwise the label position would land
    /// on a content token and the model would be supervised there.
    fn pack_pair(&mut self, a: &[i32], b: &[i32]) -> (Vec<i32>, usize) {
        let mut toks = vec![BOS];
        toks.extend_from_slice(a);
        toks.push(SEP);
        toks.extend_from_slice(b);
        toks.push(SEP);
        let truncated = toks.len() > self.seq;
        toks.truncate(self.seq);
        if truncated {
            *toks.last_mut().expect("seq >= 1") = SEP;
        }
        let pos = toks.len() - 1;
        toks.resize(self.seq, super::vocabulary::PAD);
        (toks, pos)
    }

    pub fn example(&mut self) -> ClsExample {
        let span = (self.seq / 2).saturating_sub(3).max(4);
        let (tokens, pos, label) = match self.task {
            GlueTask::Rte | GlueTask::Mnli => {
                // premise; hypothesis ⊂ premise => entail; overlapping-but-
                // shuffled => neutral (MNLI); disjoint => contradict/not-entail
                let prem = self.content_span(span);
                let kind = self.rng.below(self.task.n_classes());
                let hyp: Vec<i32> = match kind {
                    0 => {
                        let idx = self.rng.choose_k(prem.len(), (prem.len() / 2).max(2));
                        let mut v: Vec<i32> = idx.iter().map(|&i| prem[i]).collect();
                        v.sort();
                        v
                    }
                    1 => self.content_span(span / 2 + 1),
                    _ => {
                        let mut v = prem.clone();
                        self.rng.shuffle(&mut v);
                        v.truncate(span / 2 + 1);
                        let extra = self.content_span(2);
                        [v, extra].concat()
                    }
                };
                let (t, p) = self.pack_pair(&prem, &hyp);
                (t, p, kind)
            }
            GlueTask::Mrpc | GlueTask::Qqp => {
                let a = self.content_span(span);
                let paraphrase = self.rng.bool(0.5);
                let noise = if self.task == GlueTask::Qqp { 0.25 } else { 0.1 };
                let b: Vec<i32> = if paraphrase {
                    a.iter()
                        .map(|&t| if self.rng.bool(1.0 - noise) { self.synonym(t) } else { t })
                        .collect()
                } else {
                    self.content_span(span)
                };
                let (t, p) = self.pack_pair(&a, &b);
                (t, p, if paraphrase { 1 } else { 0 })
            }
            GlueTask::Stsb => {
                // overlap fraction in {0, .25, .5, .75, 1} -> bucket 0..4
                let a = self.content_span(span);
                let bucket = self.rng.below(5);
                let keep = (a.len() * bucket) / 4;
                let mut b = Vec::with_capacity(a.len());
                for (i, &t) in a.iter().enumerate() {
                    if i < keep {
                        b.push(self.synonym(t));
                    } else {
                        b.push(self.vocab.content0
                            + self.rng.below(self.vocab.n_content) as i32);
                    }
                }
                let (t, p) = self.pack_pair(&a, &b);
                (t, p, bucket)
            }
            GlueTask::Cola => {
                // acceptable = a bigram-language span; unacceptable = shuffled
                let mut a = Vec::new();
                self.corpus_run(&mut a, span);
                let ok = self.rng.bool(0.5);
                if !ok {
                    self.rng.shuffle(&mut a);
                }
                let (t, p) = self.pack_pair(&a, &[]);
                (t, p, if ok { 1 } else { 0 })
            }
            GlueTask::Sst2 => {
                let v = self.vocab.clone();
                let positive = self.rng.bool(0.5);
                let mut a = self.content_span(span);
                let base = if positive { v.pos0 } else { v.neg0 };
                for _ in 0..3 {
                    let i = self.rng.below(a.len());
                    a[i] = base + self.rng.below(v.n_sent) as i32;
                }
                let (t, p) = self.pack_pair(&a, &[]);
                (t, p, if positive { 1 } else { 0 })
            }
            GlueTask::Qnli => {
                // question = [subj rel QMARK]; context answers it iff it
                // contains the fact's object token
                let v = self.vocab.clone();
                let s = self.rng.below(v.n_subj);
                let r = self.rng.below(v.n_rel);
                let o = super::corpus::fact_object(&v, s, r);
                let q = vec![v.subj(s), v.rel(r), super::vocabulary::QMARK];
                let mut ctx = self.content_span(span);
                let answerable = self.rng.bool(0.5);
                if answerable {
                    let i = self.rng.below(ctx.len());
                    ctx[i] = v.obj(o);
                }
                let (t, p) = self.pack_pair(&q, &ctx);
                (t, p, if answerable { 1 } else { 0 })
            }
        };
        ClsExample { tokens, label_pos: pos, label_tok: self.vocab.label(label), label }
    }

    fn corpus_run(&mut self, out: &mut Vec<i32>, len: usize) {
        let toks = self.corpus.tokens(len * 3);
        for t in toks {
            if self.vocab.is_content(t) {
                out.push(t);
                if out.len() == len {
                    return;
                }
            }
        }
        while out.len() < len {
            out.push(self.vocab.content0);
        }
    }

    pub fn examples(&mut self, n: usize) -> Vec<ClsExample> {
        (0..n).map(|_| self.example()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen(task: GlueTask) -> GlueGen {
        GlueGen::new(task, Vocab::new(512), 32, 42)
    }

    #[test]
    fn all_tasks_generate_valid_examples() {
        for task in ALL_TASKS {
            let mut g = gen(task);
            for ex in g.examples(32) {
                assert_eq!(ex.tokens.len(), 32, "{task:?}");
                assert!(ex.label < task.n_classes(), "{task:?}");
                assert_eq!(ex.tokens[ex.label_pos], SEP, "{task:?} label pos must be SEP");
                assert!(ex.tokens.iter().all(|&t| (t as usize) < 512));
            }
        }
    }

    #[test]
    fn labels_balanced() {
        for task in ALL_TASKS {
            let mut g = gen(task);
            let exs = g.examples(300);
            let mut counts = vec![0usize; task.n_classes()];
            for e in &exs {
                counts[e.label] += 1;
            }
            for (k, &c) in counts.iter().enumerate() {
                assert!(
                    c as f64 > 300.0 / task.n_classes() as f64 * 0.5,
                    "{task:?} class {k} underrepresented: {counts:?}"
                );
            }
        }
    }

    #[test]
    fn label_pos_is_sep_at_every_seq_len() {
        // truncation at small seq used to leave a content token at
        // label_pos; every task/seq combination must supervise at SEP
        for task in ALL_TASKS {
            for seq in [8usize, 12, 16, 32] {
                let mut g = GlueGen::new(task, Vocab::new(512), seq, 7);
                for ex in g.examples(64) {
                    assert_eq!(ex.tokens.len(), seq, "{task:?} seq {seq}");
                    assert_eq!(
                        ex.tokens[ex.label_pos],
                        SEP,
                        "{task:?} seq {seq}: label pos must be SEP"
                    );
                }
            }
        }
    }

    #[test]
    fn paraphrase_pairs_agree_with_vocab_synonyms_at_odd_content_sizes() {
        // vocab 300 has an odd content region — the synonym involution fix
        // must keep MRPC positives consistent with Vocab::synonym
        let vocab = Vocab::new(300);
        let mut g = GlueGen::new(GlueTask::Mrpc, vocab.clone(), 32, 11);
        for ex in g.examples(200) {
            assert!(ex.label < 2);
            for &t in &ex.tokens {
                if vocab.is_content(t) {
                    assert_eq!(vocab.synonym(vocab.synonym(t)), t);
                }
            }
        }
    }

    #[test]
    fn deterministic() {
        let a: Vec<_> = gen(GlueTask::Rte).examples(10).iter().map(|e| e.tokens.clone()).collect();
        let b: Vec<_> = gen(GlueTask::Rte).examples(10).iter().map(|e| e.tokens.clone()).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn sst2_signal_present() {
        // positive examples contain positive-region tokens, negatives don't
        let mut g = gen(GlueTask::Sst2);
        let v = Vocab::new(512);
        for e in g.examples(100) {
            let has_pos = e.tokens.iter().any(|&t| t >= v.pos0 && t < v.neg0);
            let has_neg = e.tokens.iter().any(|&t| t >= v.neg0 && t < v.content0);
            if e.label == 1 {
                assert!(has_pos && !has_neg);
            } else {
                assert!(has_neg && !has_pos);
            }
        }
    }
}
