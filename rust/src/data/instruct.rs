//! Instruction-following SFT data (the OASST1 stand-in; paper Table 7/Fig 6).
//!
//! Eight "categories" mirroring MT-Bench (writing, roleplay, reasoning, math,
//! coding, extraction, STEM, humanities).  Each category has its own template
//! family so per-category evaluation (held-out NLL → score proxy, plus a
//! repetition metric) is meaningful: categories differ in how much they rely
//! on pretrained structure (facts vs. bigram fluency vs. copying).

use super::corpus::{fact_object, Corpus};
use super::vocabulary::{Vocab, BOS, QMARK, RESP, SEP};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Category {
    Writing,
    Roleplay,
    Reasoning,
    Math,
    Coding,
    Extraction,
    Stem,
    Humanities,
}

pub const CATEGORIES: [Category; 8] = [
    Category::Writing, Category::Roleplay, Category::Reasoning, Category::Math,
    Category::Coding, Category::Extraction, Category::Stem, Category::Humanities,
];

impl Category {
    pub fn name(self) -> &'static str {
        match self {
            Category::Writing => "Writing",
            Category::Roleplay => "Roleplay",
            Category::Reasoning => "Reasoning",
            Category::Math => "Math",
            Category::Coding => "Coding",
            Category::Extraction => "Extraction",
            Category::Stem => "STEM",
            Category::Humanities => "Humanities",
        }
    }
}

pub struct InstructGen {
    pub vocab: Vocab,
    corpus: Corpus,
    rng: Rng,
}

impl InstructGen {
    pub fn new(vocab: Vocab, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let corpus = Corpus::new(vocab.clone(), rng.next_u64());
        InstructGen { vocab, corpus, rng }
    }

    fn content(&mut self, len: usize) -> Vec<i32> {
        let mut toks = self.corpus.tokens(len * 2);
        toks.retain(|&t| self.vocab.is_content(t));
        toks.truncate(len);
        while toks.len() < len {
            toks.push(self.vocab.content0);
        }
        toks
    }

    /// (prompt, response) in tokens for one category.
    pub fn pair(&mut self, cat: Category) -> (Vec<i32>, Vec<i32>) {
        let v = self.vocab.clone();
        match cat {
            // fluent continuation of the bigram language
            Category::Writing | Category::Roleplay | Category::Humanities => {
                let prompt = self.content(6);
                // response continues the bigram chain from the prompt's last token
                let mut resp = vec![*prompt.last().unwrap()];
                let c0 = v.content0;
                for _ in 0..10 {
                    let base = (*resp.last().unwrap() - c0) as u64;
                    let slot = self.rng.below(8) as u64;
                    let mut x = base.wrapping_mul(0x2545F4914F6CDD1D)
                        ^ slot.wrapping_mul(0x9E3779B97F4A7C15);
                    x ^= x >> 31;
                    resp.push(c0 + (x as usize % v.n_content) as i32);
                }
                (prompt, resp[1..].to_vec())
            }
            // fact recall (knowledge-heavy, like STEM/extraction questions)
            Category::Stem | Category::Extraction | Category::Reasoning => {
                let s = self.rng.below(v.n_subj);
                let r = self.rng.below(v.n_rel);
                let prompt = vec![v.subj(s), v.rel(r), QMARK];
                (prompt, vec![v.obj(fact_object(&v, s, r))])
            }
            // "math"/"coding": deterministic token-arithmetic (successor of a
            // content token index by a small offset) — hard without tuning
            Category::Math | Category::Coding => {
                let a = self.rng.below(v.n_content / 2);
                let b = self.rng.below(16) + 1;
                let prompt = vec![
                    v.content0 + a as i32,
                    SEP,
                    v.content0 + b as i32,
                ];
                let ans = v.content0 + ((a + b) % v.n_content) as i32;
                (prompt, vec![ans])
            }
        }
    }

    /// Full SFT sequence `[BOS prompt RESP response ...pad]` with loss mask on
    /// the response tokens only.
    ///
    /// When the pair overflows `seq`, the *prompt* is clipped so that RESP
    /// plus at least one response token always survive — otherwise
    /// truncation would silently produce an all-zero loss mask (the PR-2
    /// truncation class: the supervised position clobbered off the row).
    pub fn sft_example(&mut self, cat: Category, seq: usize) -> (Vec<i32>, Vec<i32>, Vec<f32>) {
        assert!(seq >= 2, "seq must hold [BOS RESP] plus a response target");
        let (mut prompt, resp) = self.pair(cat);
        // BOS + prompt + RESP within `seq` leaves the first response token
        // at index <= seq, i.e. still inside inputs/targets after truncation
        prompt.truncate(seq - 2);
        let mut toks = vec![BOS];
        toks.extend(&prompt);
        toks.push(RESP);
        let resp_start = toks.len();
        toks.extend(&resp);
        toks.truncate(seq + 1);
        toks.resize(seq + 1, super::vocabulary::PAD);
        let inputs = toks[..seq].to_vec();
        let targets = toks[1..].to_vec();
        let mut mask = vec![0f32; seq];
        for i in resp_start..(resp_start + resp.len()).min(seq + 1) {
            if i >= 1 {
                mask[i - 1] = 1.0;
            }
        }
        (inputs, targets, mask)
    }

    /// Mixed-category SFT example (training draws uniformly).
    pub fn sft_mixed(&mut self, seq: usize) -> (Vec<i32>, Vec<i32>, Vec<f32>) {
        let cat = CATEGORIES[self.rng.below(8)];
        self.sft_example(cat, seq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_categories_produce_pairs() {
        let mut g = InstructGen::new(Vocab::new(512), 17);
        for cat in CATEGORIES {
            let (p, r) = g.pair(cat);
            assert!(!p.is_empty() && !r.is_empty(), "{cat:?}");
        }
    }

    #[test]
    fn sft_mask_covers_response_only() {
        let mut g = InstructGen::new(Vocab::new(512), 3);
        for cat in CATEGORIES {
            let (inp, _tgt, mask) = g.sft_example(cat, 64);
            assert_eq!(inp.len(), 64);
            let total: f32 = mask.iter().sum();
            assert!(total >= 1.0, "{cat:?} mask empty");
            // the token *before* the first masked position must be RESP or
            // inside the response
            let first = mask.iter().position(|&m| m > 0.0).unwrap();
            assert_eq!(inp[first], RESP, "{cat:?}");
        }
    }

    #[test]
    fn truncated_rows_still_supervise_the_response() {
        // the PR-2 truncation class: at every (category, seq) combination —
        // including ones where prompt+response overflow — the mask must
        // cover at least one surviving response token, sitting right after
        // the RESP marker
        for seq in [2usize, 3, 4, 6, 9, 64] {
            let mut g = InstructGen::new(Vocab::new(512), 21);
            for cat in CATEGORIES {
                let (inp, tgt, mask) = g.sft_example(cat, seq);
                assert_eq!(inp.len(), seq);
                let total: f32 = mask.iter().sum();
                assert!(total >= 1.0, "{cat:?} seq {seq}: empty loss mask");
                let first = mask.iter().position(|&m| m > 0.0).unwrap();
                assert_eq!(inp[first], RESP, "{cat:?} seq {seq}: mask must start at RESP");
                assert_ne!(
                    tgt[first],
                    super::super::vocabulary::PAD,
                    "{cat:?} seq {seq}: supervised target must be a real token"
                );
            }
        }
    }

    #[test]
    fn fact_categories_answer_from_table() {
        let v = Vocab::new(512);
        let mut g = InstructGen::new(v.clone(), 5);
        for _ in 0..20 {
            let (p, r) = g.pair(Category::Stem);
            let s = (p[0] - v.subj0) as usize;
            let rel = (p[1] - v.rel0) as usize;
            assert_eq!(r[0], v.obj(fact_object(&v, s, rel)));
        }
    }
}
