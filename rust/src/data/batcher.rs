//! Batch assembly: examples -> the `batch.*` tensors of the artifact graphs,
//! plus a background prefetch pipeline (std::thread + channel) so data
//! generation overlaps step execution on the single-core testbed.

use std::sync::mpsc;
use std::thread;

use crate::tensor::HostTensor;

/// One classification example (GLUE-like).
#[derive(Clone, Debug)]
pub struct ClsExample {
    pub tokens: Vec<i32>,
    pub label_pos: usize,
    /// label token id fed to the loss (LM-head reuse)
    pub label_tok: i32,
    /// raw class index (for accuracy computation)
    pub label: usize,
}

/// One LM example (pretraining / SFT).
#[derive(Clone, Debug)]
pub struct LmExample {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub mask: Vec<f32>,
}

/// Assembled batch tensors in manifest order.
#[derive(Clone, Debug)]
pub struct Batch {
    pub tensors: Vec<HostTensor>,
    /// per-row class indices (cls batches; empty for lm)
    pub labels: Vec<usize>,
}

pub fn cls_batch(examples: &[ClsExample], seq: usize) -> Batch {
    let b = examples.len();
    let mut tokens = Vec::with_capacity(b * seq);
    let mut pos = Vec::with_capacity(b);
    let mut tok = Vec::with_capacity(b);
    let mut labels = Vec::with_capacity(b);
    for e in examples {
        assert_eq!(e.tokens.len(), seq);
        tokens.extend_from_slice(&e.tokens);
        pos.push(e.label_pos as i32);
        tok.push(e.label_tok);
        labels.push(e.label);
    }
    Batch {
        tensors: vec![
            HostTensor::from_i32(&[b, seq], &tokens),
            HostTensor::from_i32(&[b], &pos),
            HostTensor::from_i32(&[b], &tok),
        ],
        labels,
    }
}

pub fn lm_batch(examples: &[LmExample], seq: usize) -> Batch {
    let b = examples.len();
    let mut tokens = Vec::with_capacity(b * seq);
    let mut targets = Vec::with_capacity(b * seq);
    let mut mask = Vec::with_capacity(b * seq);
    for e in examples {
        assert_eq!(e.tokens.len(), seq);
        tokens.extend_from_slice(&e.tokens);
        targets.extend_from_slice(&e.targets);
        mask.extend_from_slice(&e.mask);
    }
    Batch {
        tensors: vec![
            HostTensor::from_i32(&[b, seq], &tokens),
            HostTensor::from_i32(&[b, seq], &targets),
            HostTensor::from_f32(&[b, seq], &mask),
        ],
        labels: vec![],
    }
}

/// Bounded background prefetcher: runs a generator closure on a worker thread
/// so batch assembly overlaps PJRT execution.
pub struct Prefetcher {
    rx: mpsc::Receiver<Batch>,
    _handle: thread::JoinHandle<()>,
}

impl Prefetcher {
    pub fn new<F>(depth: usize, mut gen: F) -> Self
    where
        F: FnMut() -> Batch + Send + 'static,
    {
        let (tx, rx) = mpsc::sync_channel(depth);
        let handle = thread::spawn(move || {
            loop {
                let b = gen();
                if tx.send(b).is_err() {
                    return; // consumer dropped
                }
            }
        });
        Prefetcher { rx, _handle: handle }
    }

    pub fn next(&self) -> Batch {
        self.rx.recv().expect("prefetcher thread died")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::glue::{GlueGen, GlueTask};
    use crate::data::vocabulary::Vocab;

    #[test]
    fn cls_batch_shapes() {
        let mut g = GlueGen::new(GlueTask::Sst2, Vocab::new(512), 32, 1);
        let b = cls_batch(&g.examples(8), 32);
        assert_eq!(b.tensors[0].shape, vec![8, 32]);
        assert_eq!(b.tensors[1].shape, vec![8]);
        assert_eq!(b.tensors[2].shape, vec![8]);
        assert_eq!(b.labels.len(), 8);
    }

    #[test]
    fn lm_batch_shapes() {
        let ex = LmExample {
            tokens: vec![1; 16],
            targets: vec![2; 16],
            mask: vec![1.0; 16],
        };
        let b = lm_batch(&[ex.clone(), ex], 16);
        assert_eq!(b.tensors[0].shape, vec![2, 16]);
        assert_eq!(b.tensors[2].as_f32().unwrap().iter().sum::<f32>(), 32.0);
    }

    #[test]
    fn prefetcher_delivers() {
        let mut i = 0usize;
        let pf = Prefetcher::new(2, move || {
            i += 1;
            Batch { tensors: vec![HostTensor::scalar_f32(i as f32)], labels: vec![] }
        });
        let a = pf.next().tensors[0].scalar();
        let b = pf.next().tensors[0].scalar();
        assert!(b > a);
    }
}
