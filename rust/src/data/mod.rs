//! Synthetic benchmark substrate (DESIGN.md §4).
//!
//! The sandbox has no GLUE/MMLU/Alpaca access, so this module *is* the
//! datasets: a deterministic token world with (a) a bigram-grammar language,
//! (b) a knowledge base of (subject, relation, object) triples embedded in
//! the pretraining corpus, and (c) sentiment/paraphrase structure — enough
//! signal that every task family the paper evaluates has a learnable,
//! pretraining-dependent analogue.

pub mod batcher;
pub mod corpus;
pub mod glue;
pub mod instruct;
pub mod mmlu;
pub mod vocabulary;

pub use batcher::{Batch, ClsExample, LmExample};
pub use vocabulary::Vocab;
