//! MMLU-like 5-shot knowledge benchmark (paper Table 2, Figure 1b).
//!
//! Questions probe the fact table the backbone saw during pretraining:
//! a 5-shot prompt of `[s r o]` exemplars, then the query `[s r QMARK]`.
//! The model answers by ranking 4 candidate object tokens at the query
//! position — exactly the "pick the best choice token" scoring MMLU uses.
//! Finetuning data (the Alpaca stand-in) is instruction-formatted fact
//! recall, so tuning helps without leaking eval queries: eval uses a held-out
//! subject range.

use super::corpus::fact_object;
use super::vocabulary::{Vocab, BOS, QMARK};
use crate::util::rng::Rng;

pub struct MmluItem {
    /// right-padded prompt tokens
    pub tokens: Vec<i32>,
    /// index of QMARK — the model predicts the answer at this position
    pub pos: usize,
    /// 4 candidate object tokens
    pub choices: [i32; 4],
    /// index of the correct choice
    pub answer: usize,
}

pub struct MmluGen {
    pub vocab: Vocab,
    rng: Rng,
    seq: usize,
    /// eval items use subjects in [holdout_lo, n_subj) — never in finetune data
    holdout_lo: usize,
}

impl MmluGen {
    pub fn new(vocab: Vocab, seq: usize, seed: u64) -> Self {
        let holdout_lo = vocab.n_subj * 3 / 4;
        MmluGen { vocab, rng: Rng::new(seed), seq, holdout_lo }
    }

    /// One k-shot item. `eval` draws query subjects from the held-out range.
    ///
    /// Shots are capped to what `seq` can hold alongside the query triple,
    /// so a short sequence degrades to fewer shots instead of truncation
    /// clobbering the QMARK label position (the PR-2 GLUE truncation class)
    /// — `tokens[pos]` is QMARK at every `seq`/`k_shot` combination.
    pub fn item(&mut self, k_shot: usize, eval: bool) -> MmluItem {
        let v = self.vocab.clone();
        assert!(self.seq >= 4, "seq must hold [BOS s r QMARK]");
        // BOS + 3 per shot + the 3-token query must fit in seq
        let k_shot = k_shot.min((self.seq - 4) / 3);
        let mut toks = vec![BOS];
        for _ in 0..k_shot {
            let s = self.rng.below(self.holdout_lo);
            let r = self.rng.below(v.n_rel);
            toks.push(v.subj(s));
            toks.push(v.rel(r));
            toks.push(v.obj(fact_object(&v, s, r)));
        }
        let s = if eval {
            self.rng.range(self.holdout_lo, v.n_subj)
        } else {
            self.rng.below(self.holdout_lo)
        };
        let r = self.rng.below(v.n_rel);
        let correct_obj = fact_object(&v, s, r);
        toks.push(v.subj(s));
        toks.push(v.rel(r));
        let pos = toks.len();
        toks.push(QMARK);
        assert!(toks.len() <= self.seq, "seq too short for {k_shot}-shot");
        toks.resize(self.seq, super::vocabulary::PAD);

        // distractors: 3 distinct wrong objects
        let mut choices = [0i32; 4];
        let answer = self.rng.below(4);
        let mut used = vec![correct_obj];
        for (i, c) in choices.iter_mut().enumerate() {
            if i == answer {
                *c = v.obj(correct_obj);
            } else {
                let mut o = self.rng.below(v.n_obj);
                while used.contains(&o) {
                    o = self.rng.below(v.n_obj);
                }
                used.push(o);
                *c = v.obj(o);
            }
        }
        MmluItem { tokens: toks, pos, choices, answer }
    }

    /// Instruction-style finetuning sequence (the Alpaca stand-in): a few
    /// fact recalls in instruction format, loss-masked to the answers.
    pub fn finetune_example(&mut self, seq: usize) -> (Vec<i32>, Vec<i32>, Vec<f32>) {
        let v = self.vocab.clone();
        let mut toks = vec![BOS];
        let mut answer_pos = vec![];
        while toks.len() + 5 <= seq {
            let s = self.rng.below(self.holdout_lo);
            let r = self.rng.below(v.n_rel);
            toks.push(v.subj(s));
            toks.push(v.rel(r));
            toks.push(QMARK);
            answer_pos.push(toks.len());
            toks.push(v.obj(fact_object(&v, s, r)));
        }
        toks.resize(seq + 1, super::vocabulary::PAD);
        let inputs = toks[..seq].to_vec();
        let targets = toks[1..].to_vec();
        // mask: only positions whose *target* is an answer token count
        let mut mask = vec![0f32; seq];
        for p in answer_pos {
            if p - 1 < seq {
                mask[p - 1] = 1.0; // predicting toks[p] from position p-1
            }
        }
        (inputs, targets, mask)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn item_well_formed() {
        let mut g = MmluGen::new(Vocab::new(512), 64, 9);
        for _ in 0..50 {
            let it = g.item(5, true);
            assert_eq!(it.tokens.len(), 64);
            assert_eq!(it.tokens[it.pos], QMARK);
            assert!(it.answer < 4);
            // choices distinct
            let set: std::collections::HashSet<i32> = it.choices.iter().copied().collect();
            assert_eq!(set.len(), 4);
            // correct choice consistent with the fact table
            let s = it.tokens[it.pos - 2];
            let r = it.tokens[it.pos - 1];
            let v = Vocab::new(512);
            let o = fact_object(&v, (s - v.subj0) as usize, (r - v.rel0) as usize);
            assert_eq!(it.choices[it.answer], v.obj(o));
        }
    }

    #[test]
    fn short_seq_caps_shots_instead_of_clobbering_qmark() {
        // the PR-2 truncation class: a row that does not fit must still
        // supervise at the QMARK position, never at an overwritten token
        for seq in [4usize, 5, 7, 10, 16] {
            let mut g = MmluGen::new(Vocab::new(512), seq, 4);
            for _ in 0..20 {
                let it = g.item(5, false);
                assert_eq!(it.tokens.len(), seq, "seq {seq}");
                assert_eq!(it.tokens[it.pos], QMARK, "seq {seq}: label pos must be QMARK");
                // the query triple right before QMARK survived intact
                let v = Vocab::new(512);
                let s = it.tokens[it.pos - 2];
                assert!(s >= v.subj0, "seq {seq}: query subject clobbered");
            }
        }
    }

    #[test]
    fn eval_uses_holdout_subjects() {
        let v = Vocab::new(512);
        let mut g = MmluGen::new(v.clone(), 64, 1);
        let lo = v.n_subj * 3 / 4;
        for _ in 0..50 {
            let it = g.item(5, true);
            let s = (it.tokens[it.pos - 2] - v.subj0) as usize;
            assert!(s >= lo, "eval subject {s} not held out");
            let it = g.item(5, false);
            let s = (it.tokens[it.pos - 2] - v.subj0) as usize;
            assert!(s < lo, "train subject {s} leaked from holdout");
        }
    }

    #[test]
    fn finetune_mask_targets_answers() {
        let v = Vocab::new(512);
        let mut g = MmluGen::new(v.clone(), 64, 2);
        let (inp, tgt, mask) = g.finetune_example(64);
        assert_eq!(inp.len(), 64);
        let n_masked: f32 = mask.iter().sum();
        assert!(n_masked >= 4.0, "expect several answer positions");
        for (i, &m) in mask.iter().enumerate() {
            if m > 0.0 {
                assert_eq!(inp[i], QMARK, "mask must sit on QMARK positions");
                let o = tgt[i];
                assert!(o >= v.obj0 && o < v.pos0, "target must be an object token");
            }
        }
    }
}
