//! Pretraining corpus generator: a learnable synthetic language.
//!
//! Three interleaved sources (weights chosen so all are well-represented):
//! 1. **Bigram language** — each content token has a sparse successor
//!    distribution (8 preferred successors); the model can reach low loss
//!    only by learning it.
//! 2. **Knowledge statements** — `[BOS s r o EOS]` for every (s, r) pair in
//!    the world's fact table, the substrate of the MMLU-like benchmark.
//! 3. **Sentiment fields** — runs of positive or negative tokens bracketed
//!    by content, giving the SST-like task a pretrained feature to exploit.

use super::vocabulary::{Vocab, BOS, EOS, SEP};
use crate::util::rng::Rng;

/// The world's ground-truth fact table: object(s, r) = deterministic hash.
pub fn fact_object(v: &Vocab, s: usize, r: usize) -> usize {
    // splitmix-style mixing for a fixed, seed-independent fact table
    let mut x = (s as u64).wrapping_mul(0x9E3779B97F4A7C15) ^ (r as u64).wrapping_mul(0xBF58476D1CE4E5B9);
    x ^= x >> 29;
    x = x.wrapping_mul(0x94D049BB133111EB);
    x ^= x >> 32;
    (x as usize) % v.n_obj
}

pub struct Corpus {
    pub vocab: Vocab,
    rng: Rng,
}

impl Corpus {
    pub fn new(vocab: Vocab, seed: u64) -> Self {
        Corpus { vocab, rng: Rng::new(seed) }
    }

    /// Preferred successors of a content token (sparse bigram structure).
    fn successor(&mut self, t: i32) -> i32 {
        let v = &self.vocab;
        let base = (t - v.content0) as u64;
        let slot = self.rng.below(8) as u64;
        let mut x = base.wrapping_mul(0x2545F4914F6CDD1D) ^ slot.wrapping_mul(0x9E3779B97F4A7C15);
        x ^= x >> 31;
        v.content0 + (x as usize % v.n_content) as i32
    }

    fn content_run(&mut self, out: &mut Vec<i32>, len: usize) {
        let v = &self.vocab;
        let mut t = v.content0 + self.rng.below(v.n_content) as i32;
        out.push(t);
        for _ in 1..len {
            t = self.successor(t);
            out.push(t);
        }
    }

    fn fact_statement(&mut self, out: &mut Vec<i32>) {
        let s = self.rng.below(self.vocab.n_subj);
        let r = self.rng.below(self.vocab.n_rel);
        let o = fact_object(&self.vocab, s, r);
        out.push(BOS);
        out.push(self.vocab.subj(s));
        out.push(self.vocab.rel(r));
        out.push(self.vocab.obj(o));
        out.push(EOS);
    }

    fn sentiment_field(&mut self, out: &mut Vec<i32>) {
        let v = self.vocab.clone();
        let positive = self.rng.bool(0.5);
        let base = if positive { v.pos0 } else { v.neg0 };
        for _ in 0..self.rng.range(3, 7) {
            out.push(base + self.rng.below(v.n_sent) as i32);
        }
        // Annotate half the fields with their verbalizer — the pretraining
        // co-occurrence that makes label verbalizers meaningful (real corpora
        // tie sentiment-bearing text to words like "great"/"terrible").
        if self.rng.bool(0.5) {
            out.push(SEP);
            out.push(v.label(if positive { 1 } else { 0 }));
            out.push(EOS);
        }
    }

    /// Paraphrase statement: [BOS a.. SEP b.. SEP verbalizer EOS] where b is
    /// the synonym-mapped (or an unrelated) span — gives the pretrained model
    /// the pairwise-similarity concept the MRPC/QQP/STS-B tasks probe.
    fn paraphrase_statement(&mut self, out: &mut Vec<i32>) {
        let v = self.vocab.clone();
        let len = self.rng.range(3, 6);
        let start = out.len();
        out.push(BOS);
        self.content_run(out, len);
        let a: Vec<i32> = out[start + 1..].to_vec();
        out.push(SEP);
        let paraphrase = self.rng.bool(0.5);
        if paraphrase {
            for &t in &a {
                out.push(v.synonym(t));
            }
        } else {
            self.content_run(out, len);
        }
        out.push(SEP);
        out.push(v.label(if paraphrase { 1 } else { 0 }));
        out.push(EOS);
    }

    /// Emit a token stream of exactly `len` tokens.
    pub fn tokens(&mut self, len: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(len + 16);
        while out.len() < len {
            match self.rng.below(10) {
                0..=2 => self.fact_statement(&mut out),       // 30%: facts
                3..=4 => self.sentiment_field(&mut out),      // 20%: sentiment
                5 => self.paraphrase_statement(&mut out),     // 10%: paraphrase
                _ => {
                    let run = self.rng.range(4, 12);
                    self.content_run(&mut out, run);          // 40%: language
                }
            }
        }
        out.truncate(len);
        out
    }

    /// One LM training sequence: tokens + next-token targets + full mask.
    pub fn lm_example(&mut self, seq: usize) -> (Vec<i32>, Vec<i32>, Vec<f32>) {
        let toks = self.tokens(seq + 1);
        let inputs = toks[..seq].to_vec();
        let targets = toks[1..].to_vec();
        (inputs, targets, vec![1.0; seq])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let v = Vocab::new(512);
        let a = Corpus::new(v.clone(), 7).tokens(256);
        let b = Corpus::new(v, 7).tokens(256);
        assert_eq!(a, b);
    }

    #[test]
    fn tokens_in_range() {
        let v = Vocab::new(512);
        let toks = Corpus::new(v.clone(), 1).tokens(2048);
        assert!(toks.iter().all(|&t| (t as usize) < v.size && t >= 0));
    }

    #[test]
    fn facts_consistent() {
        let v = Vocab::new(512);
        // the fact table is a function: same (s, r) -> same o, spread over objects
        let o1 = fact_object(&v, 3, 2);
        let o2 = fact_object(&v, 3, 2);
        assert_eq!(o1, o2);
        let distinct: std::collections::HashSet<usize> =
            (0..50).map(|s| fact_object(&v, s, 1)).collect();
        assert!(distinct.len() > 25, "facts must spread over objects");
    }

    #[test]
    fn corpus_contains_fact_statements() {
        let v = Vocab::new(512);
        let toks = Corpus::new(v.clone(), 3).tokens(4096);
        // count [BOS subj rel obj EOS] windows and verify they match the table
        let mut found = 0;
        for w in toks.windows(5) {
            if w[0] == BOS && w[4] == EOS {
                let s = (w[1] - v.subj0) as usize;
                let r = (w[2] - v.rel0) as usize;
                if w[1] >= v.subj0 && s < v.n_subj && w[2] >= v.rel0 && r < v.n_rel {
                    assert_eq!(w[3], v.obj(fact_object(&v, s, r)), "fact mismatch in corpus");
                    found += 1;
                }
            }
        }
        assert!(found > 50, "expected many fact statements, found {found}");
    }

    #[test]
    fn lm_example_shapes() {
        let v = Vocab::new(256);
        let (i, t, m) = Corpus::new(v, 5).lm_example(64);
        assert_eq!(i.len(), 64);
        assert_eq!(t.len(), 64);
        assert_eq!(m.len(), 64);
    }

    #[test]
    fn bigram_structure_is_learnable() {
        // successor distribution must be sparse: the same token's successors
        // concentrate on <= 8 values
        let v = Vocab::new(512);
        let mut c = Corpus::new(v.clone(), 11);
        let mut succ: std::collections::HashMap<i32, std::collections::HashSet<i32>> =
            Default::default();
        let toks = c.tokens(20_000);
        for w in toks.windows(2) {
            if v.is_content(w[0]) && v.is_content(w[1]) {
                succ.entry(w[0]).or_default().insert(w[1]);
            }
        }
        let avg: f64 = succ.values().map(|s| s.len() as f64).sum::<f64>() / succ.len() as f64;
        // runs are length >= 4, so most transitions are in-run (sparse);
        // run boundaries add a few extras
        assert!(avg < 16.0, "bigram fan-out too high: {avg}");
    }
}
