//! Token-id layout of the synthetic world.
//!
//! Fixed specials at the bottom of the id space, then contiguous regions for
//! subjects/relations/objects (the knowledge base), sentiment-bearing tokens,
//! and plain "content" tokens of the bigram language.  The layout scales with
//! the model's vocab size so every config gets proportionate structure.

/// Reserved special tokens (stable across all vocab sizes).
pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const SEP: i32 = 3;
/// Classification label tokens L0..L4 (the LM head predicts these).
pub const LABEL0: i32 = 4;
pub const N_LABELS: usize = 5;
/// Question marker for MMLU/instruction formats.
pub const QMARK: i32 = 9;
/// Instruction marker ("### Response:" analogue).
pub const RESP: i32 = 10;
pub const N_SPECIALS: usize = 11;

#[derive(Clone, Debug)]
pub struct Vocab {
    pub size: usize,
    /// knowledge-base regions
    pub subj0: i32,
    pub n_subj: usize,
    pub rel0: i32,
    pub n_rel: usize,
    pub obj0: i32,
    pub n_obj: usize,
    /// sentiment-bearing tokens: [pos0, pos0+n_sent) positive, then negative
    pub pos0: i32,
    pub neg0: i32,
    pub n_sent: usize,
    /// plain content tokens for the bigram language
    pub content0: i32,
    pub n_content: usize,
}

impl Vocab {
    pub fn new(size: usize) -> Self {
        assert!(size >= 128, "vocab too small for the synthetic world");
        let budget = size - N_SPECIALS;
        // fixed fractions of the non-special space
        let n_subj = budget / 8;
        let n_rel = (budget / 16).max(4);
        let n_obj = budget / 8;
        let n_sent = budget / 16;
        let used = n_subj + n_rel + n_obj + 2 * n_sent;
        let n_content = budget - used;
        let subj0 = N_SPECIALS as i32;
        let rel0 = subj0 + n_subj as i32;
        let obj0 = rel0 + n_rel as i32;
        let pos0 = obj0 + n_obj as i32;
        let neg0 = pos0 + n_sent as i32;
        let content0 = neg0 + n_sent as i32;
        Vocab { size, subj0, n_subj, rel0, n_rel, obj0, n_obj, pos0, neg0, n_sent, content0, n_content }
    }

    /// Label *verbalizer* token for class k.
    ///
    /// Real GLUE finetuning maps labels onto words the model saw in
    /// pretraining ("great"/"terrible"); with a tied LM head, tokens that
    /// never occurred in the corpus get their embeddings uniformly pushed
    /// toward -mean(h) by the softmax, collapsing the distinction between
    /// classes.  We therefore verbalize labels as tokens from the *object*
    /// region (trained by the fact statements) — the reserved LABEL0..4 ids
    /// remain for formats that need untrained markers.
    pub fn label(&self, k: usize) -> i32 {
        assert!(k < N_LABELS);
        self.obj0 + (self.n_obj - 1 - k) as i32
    }

    pub fn subj(&self, i: usize) -> i32 {
        self.subj0 + (i % self.n_subj) as i32
    }

    pub fn rel(&self, i: usize) -> i32 {
        self.rel0 + (i % self.n_rel) as i32
    }

    pub fn obj(&self, i: usize) -> i32 {
        self.obj0 + (i % self.n_obj) as i32
    }

    /// Fixed synonym involution over content tokens (used by the paraphrase
    /// tasks and by the corpus' paraphrase statements — same pairing).
    ///
    /// Adjacent content tokens pair up (0↔1, 2↔3, …); when `n_content` is
    /// odd the last token is its own synonym — the old `(i + 1) %
    /// n_content` wrap sent it to token 0 while 0 mapped to 1, silently
    /// breaking the involution (and hence MRPC/QQP/STS-B labels) for vocab
    /// sizes with odd content regions.  Non-content tokens (which used to
    /// underflow the index math) pass through unchanged.
    pub fn synonym(&self, t: i32) -> i32 {
        if !self.is_content(t) {
            return t;
        }
        let i = (t - self.content0) as usize;
        let j = if i + 1 == self.n_content && self.n_content % 2 == 1 {
            i // odd region: last token is a fixed point
        } else if i % 2 == 0 {
            i + 1
        } else {
            i - 1
        };
        self.content0 + j as i32
    }

    pub fn is_content(&self, t: i32) -> bool {
        t >= self.content0 && (t as usize) < self.size
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regions_disjoint_and_in_range() {
        for size in [256usize, 512, 1024, 2048] {
            let v = Vocab::new(size);
            let ends = [
                (v.subj0, v.n_subj),
                (v.rel0, v.n_rel),
                (v.obj0, v.n_obj),
                (v.pos0, v.n_sent),
                (v.neg0, v.n_sent),
                (v.content0, v.n_content),
            ];
            let mut prev_end = N_SPECIALS as i32;
            for (start, n) in ends {
                assert_eq!(start, prev_end, "regions must be contiguous");
                prev_end = start + n as i32;
            }
            assert_eq!(prev_end as usize, size);
            assert!(v.n_content > 0);
        }
    }

    #[test]
    fn synonym_is_an_involution_for_every_content_token() {
        // 300 and 517 give odd n_content, the rest even — both parities of
        // the pairing (including the odd-region fixed point) must hold
        let mut saw_odd = false;
        let mut saw_even = false;
        for size in [128usize, 256, 300, 512, 517, 1024, 2048] {
            let v = Vocab::new(size);
            match v.n_content % 2 {
                1 => saw_odd = true,
                _ => saw_even = true,
            }
            for i in 0..v.n_content {
                let t = v.content0 + i as i32;
                let s = v.synonym(t);
                assert!(v.is_content(s), "synonym must stay in the content region");
                assert_eq!(v.synonym(s), t, "size {size}: synonym must be an involution");
                if v.n_content % 2 == 1 && i + 1 == v.n_content {
                    assert_eq!(s, t, "odd region: last token is its own synonym");
                } else {
                    assert_ne!(s, t, "paired tokens must actually differ");
                }
            }
        }
        assert!(saw_odd && saw_even, "test sizes must cover both parities");
    }

    #[test]
    fn synonym_passes_non_content_tokens_through() {
        let v = Vocab::new(512);
        for t in [PAD, BOS, SEP, v.subj0, v.rel0, v.obj0, v.pos0, v.neg0, v.content0 - 1] {
            assert_eq!(v.synonym(t), t, "non-content token {t} must be unchanged");
        }
    }

    #[test]
    fn label_verbalizers_distinct_and_pretrained() {
        let v = Vocab::new(512);
        let mut seen = std::collections::HashSet::new();
        for k in 0..N_LABELS {
            let t = v.label(k);
            // verbalizers live in the object region (trained in pretraining)
            assert!(t >= v.obj0 && t < v.pos0);
            assert!(seen.insert(t), "verbalizers must be distinct");
        }
    }
}
