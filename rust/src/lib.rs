//! # QST — Quantized Side Tuning (ACL 2024) reproduction
//!
//! A three-layer Rust + JAX + Pallas system: this crate is **Layer 3**, the
//! training coordinator.  It loads AOT-compiled HLO artifacts (lowered once by
//! `python/compile/aot.py` — Python never runs on the training path), manages
//! checkpoints and 4-bit quantization of frozen backbones, generates the
//! synthetic benchmark suites, runs the finetuning loops, and regenerates
//! every table and figure of the paper's evaluation.
//!
//! Module map (see DESIGN.md §9):
//! * [`tensor`]     — host tensors + PJRT literal marshaling
//! * [`kernels`]    — shared host compute layer: blocked/threaded f32 GEMM +
//!   fused W4 dequant-GEMM (serve forwards, quantizer, `bench-kernels`)
//! * [`nn`]         — [`nn::Linear`]: frozen weights as f32 or packed W4
//!   behind one forward (the serving backbone's storage abstraction)
//! * [`quant`]      — NF4/FP4 blockwise + double quantization (mirrors `python/compile/quant.py`)
//! * [`runtime`]    — PJRT client, artifact manifests, executor with device-resident state
//! * [`coordinator`] — trainer, evaluator, LR schedules, checkpoints, metrics
//! * [`data`]       — deterministic synthetic corpus + GLUE/MMLU/instruction suites
//! * [`costmodel`]  — analytical memory/FLOPs models at the paper's true dims
//! * [`experiments`] — one regenerator per paper table/figure
//! * [`serve`]      — multi-task inference: shared-backbone hidden-state
//!   cache (whole-prompt + per-block prefix index), side-network registry,
//!   micro-batching, serving telemetry
//! * [`proto`]      — the versioned typed wire protocol (binary framing +
//!   canonical text codec) and the pluggable `Transport` seam: in-process
//!   shard threads or cross-process shard workers over unix/tcp sockets
//! * [`gateway`]    — asynchronous sharded serving front-end over [`serve`]:
//!   bounded-queue transports with backpressure (in-proc + socket via
//!   [`proto`]), prefix-locality routing across per-shard backbone
//!   replicas, fleet-wide stats aggregation, `bench-gateway` scaling curves
//! * [`obs`]        — request-lifecycle tracing + mergeable fleet metrics:
//!   per-thread span recorder (Chrome trace export), exactly-mergeable
//!   latency histograms, Prometheus-style `STATS` exposition — always
//!   compiled, runtime-toggled, parity-safe
//! * [`store`]      — content-addressed task-artifact store behind a
//!   [`store::Storage`] trait (local dir + in-memory backends), sectioned
//!   artifacts with index headers for streaming partial reads; feeds the
//!   registry's `Source::Store` and the fleet `Deploy` path
//! * [`cli`], [`benchkit`], [`util`] — in-repo substrates (no external deps)

pub mod benchkit;
pub mod cli;
pub mod coordinator;
pub mod costmodel;
pub mod data;
pub mod experiments;
pub mod gateway;
pub mod kernels;
pub mod nn;
pub mod obs;
pub mod proto;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod store;
pub mod tensor;
pub mod util;

/// Repo-relative artifact directory (override with `QST_ARTIFACTS`).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(d) = std::env::var("QST_ARTIFACTS") {
        return d.into();
    }
    // Walk up from CWD until we find an `artifacts/` directory.
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join("artifacts");
        if cand.is_dir() {
            return cand;
        }
        if !dir.pop() {
            return "artifacts".into();
        }
    }
}

/// Run directory for checkpoints/metrics (override with `QST_RUNS`).
pub fn runs_dir() -> std::path::PathBuf {
    if let Ok(d) = std::env::var("QST_RUNS") {
        return d.into();
    }
    artifacts_dir().parent().unwrap_or(std::path::Path::new(".")).join("runs")
}
