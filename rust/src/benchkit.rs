//! Minimal criterion-style bench harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use `harness = false` and call [`Bench::run`], which
//! does warmup, adaptive iteration counts, and reports median / MAD /
//! throughput in a criterion-like format.  Results can also be appended to a
//! CSV for the EXPERIMENTS.md perf log.

use std::time::Instant;

pub struct Bench {
    pub name: String,
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub target_secs: f64,
}

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_secs: f64,
    pub mad_secs: f64,
    pub mean_secs: f64,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        Bench {
            name: name.to_string(),
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 200,
            target_secs: 2.0,
        }
    }

    pub fn quick(name: &str) -> Self {
        Bench { warmup_iters: 1, min_iters: 3, max_iters: 30, target_secs: 0.7, ..Bench::new(name) }
    }

    /// Time `f` adaptively; prints a criterion-like line and returns stats.
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> BenchResult {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters
            || (start.elapsed().as_secs_f64() < self.target_secs && samples.len() < self.max_iters)
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = samples[samples.len() / 2];
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let mut devs: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = devs[devs.len() / 2];
        let r = BenchResult {
            name: self.name.clone(),
            iters: samples.len(),
            median_secs: median,
            mad_secs: mad,
            mean_secs: mean,
        };
        println!(
            "{:<48} time: [{:>10} median ± {:>9} MAD]  ({} iters)",
            r.name,
            fmt_secs(r.median_secs),
            fmt_secs(r.mad_secs),
            r.iters
        );
        r
    }
}

impl BenchResult {
    /// Report a derived throughput line (e.g. tokens/s, GFLOP/s).
    pub fn throughput(&self, label: &str, units_per_iter: f64) -> f64 {
        let rate = units_per_iter / self.median_secs;
        println!("{:<48}   -> {:.3e} {label}/s", "", rate);
        rate
    }

    pub fn csv_line(&self) -> String {
        format!("{},{},{:.9},{:.9}\n", self.name, self.iters, self.median_secs, self.mad_secs)
    }
}

pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} µs", s * 1e6)
    }
}

/// Minimal JSON object writer (flat objects of numbers/strings — all the
/// bench reports need; serde is unavailable offline).  Used by
/// `bench-serve` (`BENCH_serve.json`) and `bench-kernels`
/// (`BENCH_kernels.json`).
pub struct Json {
    buf: String,
    first: bool,
}

impl Default for Json {
    fn default() -> Self {
        Self::new()
    }
}

impl Json {
    pub fn new() -> Self {
        Json { buf: String::from("{"), first: true }
    }

    fn key(&mut self, k: &str) {
        if !self.first {
            self.buf.push(',');
        }
        self.first = false;
        self.buf.push('\n');
        self.buf.push_str("  \"");
        self.buf.push_str(k);
        self.buf.push_str("\": ");
    }

    pub fn num(mut self, k: &str, v: f64) -> Self {
        self.key(k);
        if v.is_finite() {
            self.buf.push_str(&format!("{v:.6}"));
        } else {
            self.buf.push_str("null");
        }
        self
    }

    pub fn int(mut self, k: &str, v: u64) -> Self {
        self.key(k);
        self.buf.push_str(&v.to_string());
        self
    }

    pub fn str(mut self, k: &str, v: &str) -> Self {
        self.key(k);
        self.buf.push('"');
        for c in v.chars() {
            match c {
                '"' => self.buf.push_str("\\\""),
                '\\' => self.buf.push_str("\\\\"),
                '\n' => self.buf.push_str("\\n"),
                c if (c as u32) < 0x20 => self.buf.push_str(&format!("\\u{:04x}", c as u32)),
                c => self.buf.push(c),
            }
        }
        self.buf.push('"');
        self
    }

    pub fn finish(mut self) -> String {
        self.buf.push_str("\n}\n");
        self.buf
    }
}

/// Schema version of the `BENCH_*.json` trajectory files.  Bump only when
/// a key is renamed or its meaning changes; *adding* keys is
/// backward-compatible and does not bump it.
pub const BENCH_SCHEMA_VERSION: u64 = 2;

/// Days since 1970-01-01 → civil `(year, month, day)` (proleptic
/// Gregorian).  The standard era-based O(1) conversion; no date
/// dependencies offline.
fn civil_from_days(z: i64) -> (i64, u32, u32) {
    let z = z + 719_468;
    let era = if z >= 0 { z } else { z - 146_096 } / 146_097;
    let doe = z - era * 146_097; // [0, 146096]
    let yoe = (doe - doe / 1460 + doe / 36524 - doe / 146096) / 365; // [0, 399]
    let y = yoe + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100); // [0, 365]
    let mp = (5 * doy + 2) / 153; // [0, 11]
    let d = (doy - (153 * mp + 2) / 5 + 1) as u32; // [1, 31]
    let m = if mp < 10 { mp + 3 } else { mp - 9 } as u32; // [1, 12]
    (if m <= 2 { y + 1 } else { y }, m, d)
}

/// ISO-8601 UTC wall-clock timestamp (`2026-08-08T14:03:09Z`), second
/// precision, from the system clock.
pub fn iso8601_utc_now() -> String {
    let secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let (y, m, d) = civil_from_days((secs / 86_400) as i64);
    let rem = secs % 86_400;
    format!("{y:04}-{m:02}-{d:02}T{:02}:{:02}:{:02}Z", rem / 3600, (rem % 3600) / 60, rem % 60)
}

/// Best-effort `git rev-parse HEAD` of the current directory's repo.
/// Empty when git or the repo is unavailable — provenance must never
/// fail a bench run.
pub fn git_rev() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .unwrap_or_default()
}

impl Json {
    /// Stamp the provenance keys every `BENCH_*.json` carries — schema
    /// version, ISO-8601 UTC wall clock, git revision, host core count —
    /// so each trajectory point is attributable to a commit and a
    /// machine, and schema evolution is explicit rather than guessed.
    pub fn provenance(self) -> Self {
        self.int("schema_version", BENCH_SCHEMA_VERSION)
            .str("timestamp_utc", &iso8601_utc_now())
            .str("git_rev", &git_rev())
            .int(
                "host_cores",
                std::thread::available_parallelism().map(|n| n.get() as u64).unwrap_or(1),
            )
    }
}

/// Append results to a CSV log (created with a header if absent).
pub fn log_csv(path: &std::path::Path, results: &[BenchResult]) -> std::io::Result<()> {
    use std::io::Write;
    if let Some(p) = path.parent() {
        std::fs::create_dir_all(p)?;
    }
    let exists = path.exists();
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    if !exists {
        writeln!(f, "name,iters,median_secs,mad_secs")?;
    }
    for r in results {
        f.write_all(r.csv_line().as_bytes())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let r = Bench { warmup_iters: 0, min_iters: 3, max_iters: 5, target_secs: 0.01, ..Bench::new("noop") }
            .run(|| 1 + 1);
        assert!(r.iters >= 3);
        assert!(r.median_secs >= 0.0);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(2.0).contains("s"));
        assert!(fmt_secs(2e-3).contains("ms"));
        assert!(fmt_secs(2e-6).contains("µs"));
    }

    #[test]
    fn json_escapes_and_shapes() {
        let s = Json::new().str("name", "a\"b\\c").int("n", 3).num("x", 1.5).finish();
        assert!(s.starts_with('{') && s.ends_with("}\n"));
        assert!(s.contains("\"name\": \"a\\\"b\\\\c\""));
        assert!(s.contains("\"n\": 3"));
        assert!(s.contains("\"x\": 1.5"));
    }

    #[test]
    fn json_nonfinite_is_null() {
        let s = Json::new().num("bad", f64::NAN).finish();
        assert!(s.contains("\"bad\": null"));
    }

    #[test]
    fn civil_from_days_known_dates() {
        assert_eq!(civil_from_days(0), (1970, 1, 1));
        assert_eq!(civil_from_days(-1), (1969, 12, 31));
        // leap day: 11016 days = 2000-02-29
        assert_eq!(civil_from_days(11_016), (2000, 2, 29));
        assert_eq!(civil_from_days(11_017), (2000, 3, 1));
        // a modern anchor (2026-01-01 = 20454 days since epoch)
        assert_eq!(civil_from_days(20_454), (2026, 1, 1));
    }

    #[test]
    fn iso_timestamp_shape() {
        let t = iso8601_utc_now();
        // YYYY-MM-DDTHH:MM:SSZ
        assert_eq!(t.len(), 20, "{t}");
        assert_eq!(&t[4..5], "-");
        assert_eq!(&t[10..11], "T");
        assert!(t.ends_with('Z'));
        assert!(t.as_str() >= "2024-01-01T00:00:00Z", "clock went backwards? {t}");
    }

    #[test]
    fn provenance_keys_present() {
        let s = Json::new().provenance().str("bench", "x").finish();
        assert!(s.contains("\"schema_version\": 2"));
        assert!(s.contains("\"timestamp_utc\": \""));
        assert!(s.contains("\"git_rev\": "));
        assert!(s.contains("\"host_cores\": "));
    }
}
